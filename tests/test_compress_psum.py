"""Compressed cross-pod psum edge cases (satellite 3).

:func:`repro.optim.compress.make_pod_compressed_psum` with a MoRPolicy
ships real mixed-layout payloads across the pod axis. Pinned here:

* **Degenerate single pod** (``axis_name=None``): the collective
  reduces to a local pack/decode round-trip, bit-exact against the
  fake-quantization reference -- the numerics are testable without a
  mesh, and a 1-pod mesh costs nothing over the local path.
* **Uneven leaves**: shapes that don't divide the 128x128 block grid
  (odd 2-D, vectors, scalars) round-trip at their original shape with
  the same per-block error bound as aligned ones.
* **Outlier witness**: one huge gradient entry destroys the *flat*
  per-tensor E4M3 path's scale for every other element; the per-block
  MoR path isolates the outlier in its own block. This is the test
  that says why the payload machinery is worth shipping.
* **Validation**: the pod axis may appear in neither
  ``policy.mesh_axes`` nor ``inner_axes`` (pods hold independent
  partial sums, not shards of one tensor).
* **4-device (pod x data) identity** (subprocess, 2x2 mesh): with
  ``inner_axes=('data',)`` each shard's pack is bit-identical to the
  single-device pack of its whole pod gradient (PR-3 allreduced group
  amax), and the decoded cross-pod sum equals the single-device
  reference exactly.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mor import mor_quantize
from repro.core.policy import MoRPolicy
from repro.optim.compress import leaf2d, make_pod_compressed_psum

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _xla(recipe, **kw):
    return MoRPolicy(recipe=recipe, backend="xla", **kw)


# --------------------------------------------------- degenerate 1 pod --
@pytest.mark.parametrize("recipe", ("sub2", "sub3", "sub4"))
def test_single_pod_is_local_roundtrip(recipe):
    """axis_name=None: psum(g) == fake-quant of the bf16 2-D view --
    exactly one pack+decode, no collective, bit-exact vs the shared
    decision path."""
    pol = _xla(recipe)
    psum = make_pod_compressed_psum(axis_name=None, policy=pol)
    r = np.random.default_rng(0)
    g = jnp.asarray(
        r.standard_normal((256, 128))
        * np.exp2(r.integers(-10, 10, (256, 128))),
        jnp.float32,
    )
    out = jax.jit(psum)(g)
    ref2d, _ = mor_quantize(leaf2d(g).astype(jnp.bfloat16), pol)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref2d.astype(jnp.float32)))
    assert out.shape == g.shape and out.dtype == g.dtype


def test_single_pod_legacy_flat_path():
    """policy=None keeps the legacy flat per-tensor E4M3 semantics."""
    psum = make_pod_compressed_psum(axis_name=None, policy=None)
    g = jnp.asarray(np.random.default_rng(1).standard_normal((64, 64)),
                    jnp.float32)
    out = jax.jit(psum)(g)
    rel = float(jnp.linalg.norm(out - g) / jnp.linalg.norm(g))
    assert out.shape == g.shape
    assert rel < 0.05, rel


# ------------------------------------------------------ uneven leaves --
@pytest.mark.parametrize("shape", [(100, 70), (1, 300), (37,), ()])
def test_uneven_leaf_shapes_roundtrip(shape):
    """Leaves that don't divide the block grid (or aren't 2-D at all)
    ship through the compressed collective at their original shape."""
    pol = _xla("sub3")
    psum = make_pod_compressed_psum(axis_name=None, policy=pol)
    r = np.random.default_rng(2)
    g = jnp.asarray(r.standard_normal(shape), jnp.float32)
    out = jax.jit(psum)(g)
    assert out.shape == g.shape
    err = float(jnp.max(jnp.abs(out - g)))
    amax = float(jnp.max(jnp.abs(g))) if g.size else 0.0
    # bf16 cast + worst fp8 arm: comfortably under one E5M2 step.
    assert err <= amax * 2.0 ** -2 + 1e-6, (shape, err, amax)


# ---------------------------------------------------- outlier witness --
def test_witness_flat_e4m3_vs_mor_on_outliers():
    """One 1e4 outlier in a ~1e-2 gradient: flat E4M3 spends its only
    scale on the outlier and flattens everything else; per-block MoR
    keeps every non-outlier block at fp8 fidelity."""
    r = np.random.default_rng(3)
    g_np = (r.standard_normal((256, 128)) * 1e-2).astype(np.float32)
    g_np[17, 5] = 1e4  # one outlier block
    g = jnp.asarray(g_np)

    flat = make_pod_compressed_psum(axis_name=None, policy=None)
    mor = make_pod_compressed_psum(axis_name=None, policy=_xla("sub3"))
    out_flat = jax.jit(flat)(g)
    out_mor = jax.jit(mor)(g)

    # Error over everything *except* the outlier's own 128x128 block.
    mask = np.ones_like(g_np, bool)
    mask[0:128, 0:128] = False
    ref = g_np[mask]
    rel_flat = float(np.linalg.norm(np.asarray(out_flat)[mask] - ref)
                     / np.linalg.norm(ref))
    rel_mor = float(np.linalg.norm(np.asarray(out_mor)[mask] - ref)
                    / np.linalg.norm(ref))
    # Flat: the scale 448/1e4 leaves ~1e-2 values with ~100% error.
    assert rel_flat > 0.5, rel_flat
    assert rel_mor < 0.05, rel_mor
    assert rel_mor < rel_flat / 10


# -------------------------------------------------------- validation --
def test_pod_axis_must_not_be_inner():
    with pytest.raises(ValueError):
        make_pod_compressed_psum(
            "pod", policy=_xla("sub3"), inner_axes=("pod",))
    with pytest.raises(ValueError):
        make_pod_compressed_psum(
            "pod", policy=_xla("sub3", mesh_axes=("pod",)))


# ------------------------------------------------ 4-device pod x data --
def _run_mesh(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
    )
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_pod_psum_bit_identical_to_single_device():
    """2x2 (pod, data) mesh: every data shard of a pod packs
    bit-identical payload/tags/scales to a single-device pack of the
    full pod gradient, and the decoded cross-pod sum is exactly the
    single-device reference (same pods, same f32 sum order)."""
    out = _run_mesh("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.collectives import compat_shard_map
    from repro.core.mor import quantize_for_gemm
    from repro.core.policy import MoRPolicy
    from repro.optim.compress import leaf2d, make_pod_compressed_psum

    mesh = jax.make_mesh((2, 2), ('pod', 'data'))
    r = np.random.default_rng(0)
    G = r.standard_normal((2, 256, 128)) * np.exp2(
        r.integers(-12, 12, (2, 256, 128)))
    G = jnp.asarray(G, jnp.float32)  # [pod, rows, cols] partial sums

    for recipe in ('sub3', 'sub4'):
        pol = MoRPolicy(recipe=recipe, backend='xla')
        psum = make_pod_compressed_psum(
            'pod', policy=pol, inner_axes=('data',))
        pol_sh = pol.replace(mesh_axes=('data',))

        def body(a):  # a: (1, 128, 128) -- one pod's data shard
            g_local = a[0]
            mo, _ = quantize_for_gemm(
                leaf2d(g_local).astype(jnp.bfloat16), pol_sh)
            return (psum(g_local)[None],
                    (mo.payload_q[None], mo.tags[None],
                     mo.scales[None]))
        sh = P('pod', 'data', None)
        out, (pq, tags, scales) = jax.jit(compat_shard_map(
            body, mesh, sh, (sh, (sh, sh, sh))))(G)

        # Single-device reference: pack each pod's full gradient.
        refs = []
        for i in range(2):
            moi, _ = jax.jit(lambda a: quantize_for_gemm(
                leaf2d(a).astype(jnp.bfloat16), pol))(G[i])
            refs.append(moi)
            np.testing.assert_array_equal(
                np.asarray(moi.payload_q), np.asarray(pq[i]),
                err_msg=f'{recipe}:payload_q:pod{i}')
            np.testing.assert_array_equal(
                np.asarray(moi.tags), np.asarray(tags[i]),
                err_msg=f'{recipe}:tags:pod{i}')
            np.testing.assert_array_equal(
                np.asarray(moi.scales), np.asarray(scales[i]),
                err_msg=f'{recipe}:scales:pod{i}')

        want = (refs[0].dequant().astype(jnp.float32)
                + refs[1].dequant().astype(jnp.float32))
        for i in range(2):  # both pods hold the identical sum
            np.testing.assert_array_equal(
                np.asarray(out[i]), np.asarray(want),
                err_msg=f'{recipe}:sum:pod{i}')
        print('OK', recipe)
    """)
    assert out.count("OK") == 2, out
