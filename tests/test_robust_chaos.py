"""Differential chaos suite: seeded fault injection against the guard
rails (docs/robustness.md).

Every fault class registered in ``repro.robust.faults`` must be

* **detected** -- the v4 stats guard lanes, the optimizer's
  ``guard_skip`` metric or the engine's ``req.error`` fire on the
  injected run and stay silent on the clean run;
* **contained** -- poison stays inside its block / step / slot: the
  BF16 selection arm preserves the nonfinite values verbatim while
  every *other* element stays finite, the skip-step rung keeps master
  weights, packed Adam moments, EF residuals and the step counter
  bit-exact, and a quarantined serve slot leaves every other slot's
  tokens bit-identical to the uninjected run;
* **reported** -- guard counters surface through
  ``summarize_mor_stats`` and the drift of an injected-and-guarded
  trajectory stays within the PR-8 bound against the dense run.

``test_every_fault_class_has_chaos_coverage`` pins the registry to the
coverage table below, so a new injector without a chaos test fails
tier-1 rather than rotting silently.  The clean-path *cost* of the
guard (structurally zero extra operand passes) is asserted separately
by the ``robust_guard_event`` / ``train_step_taint`` contracts
(tests/test_analysis.py).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mor import (
    GUARD_BLOCK_FALLBACK,
    GUARD_NONFINITE_AMAX,
    GUARD_STALE_SCALE,
    STAT_FALLBACK_COUNT,
    STAT_FRAC_BF16,
    STAT_GUARD_FLAGS,
    mor_quantize,
    quantize_for_gemm,
)
from repro.core.policy import MoRPolicy
from repro.robust import (
    GuardPolicy,
    fault_names,
    get_fault,
    guard_flag_set,
    make_grad_fault,
    poison_tree,
    requantize_with_backoff,
    tree_select,
)

# Fault class -> the tests that exercise it.  Kept next to the
# registry on purpose: set-equality below makes coverage a tier-1
# property, not a convention.
COVERAGE = {
    "grad_nan": "test_nonfinite_operand_* / test_skip_step_* / "
                "test_injected_trajectory_within_drift_bound",
    "grad_inf": "test_nonfinite_operand_* / test_skip_step_*",
    "payload_bitflip": "test_payload_bitflip_contained",
    "scale_corrupt": "test_scale_corrupt_contained",
    "micro_scale_corrupt": "test_micro_scale_corrupt_contained",
    "stale_amax": "test_backoff_*",
    "kv_page_trash": "test_kv_page_trash_* / test_kv_guard_*",
}

RECIPES = ("sub2", "sub3", "sub4", "tensor", "e4m3")
BADS = {"nan": np.nan, "inf": np.inf}


def _xla(recipe, **kw):
    return MoRPolicy(recipe=recipe, backend="xla", **kw)


def _operand(seed=0, shape=(256, 256)):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def test_every_fault_class_has_chaos_coverage():
    assert set(fault_names()) == set(COVERAGE)


def test_injection_is_seed_deterministic():
    """Same seed, same corruption -- the differential assertions below
    are only meaningful if reruns reproduce the injected run exactly."""
    g = {"a": _operand(1, (8, 8)), "b": _operand(2, (4, 4))}
    one = poison_tree(g, np.nan, seed=5)
    two = poison_tree(g, np.nan, seed=5)
    assert jax.tree.all(
        jax.tree.map(lambda x, y: np.array_equal(x, y, equal_nan=True),
                     one, two)
    )
    n_bad = sum(int(np.sum(~np.isfinite(l))) for l in jax.tree.leaves(one))
    assert n_bad == 1


# ------------------------------------------------ detect + contain --
@pytest.mark.parametrize("recipe", RECIPES)
@pytest.mark.parametrize("bad", sorted(BADS))
def test_nonfinite_operand_detected_and_contained(recipe, bad):
    """One poisoned element (the grad_nan / grad_inf classes hitting a
    quantization event): the guard lanes flag it, and the sub-tensor
    recipes route exactly the poisoned 128x128 block to the BF16 arm
    -- poison preserved verbatim, every other element still finite."""
    x = _operand().at[3, 7].set(BADS[bad])
    y, stats = mor_quantize(x, _xla(recipe))

    # Detected: both the nonfinite group amax and the poisoned block's
    # nonfinite error sums ride lanes the recipe already computes.
    assert bool(guard_flag_set(stats[STAT_GUARD_FLAGS],
                               GUARD_NONFINITE_AMAX))
    assert bool(guard_flag_set(stats[STAT_GUARD_FLAGS],
                               GUARD_BLOCK_FALLBACK))
    assert float(stats[STAT_FALLBACK_COUNT]) == 1.0

    if recipe in ("sub2", "sub3", "sub4"):
        # Contained: 1 of 4 blocks falls back, poison rides through.
        assert float(stats[STAT_FRAC_BF16]) == 0.25
        assert not np.isfinite(float(y[3, 7]))
        mask = np.ones(y.shape, bool)
        mask[3, 7] = False
        assert np.isfinite(np.asarray(y)[mask]).all()
    elif recipe == "tensor":
        # Tensor-level accept/reject is global: the whole operand
        # degrades to passthrough rather than shipping a poisoned pack.
        assert float(stats[STAT_FRAC_BF16]) == 1.0
        assert np.array_equal(np.asarray(y), np.asarray(x),
                              equal_nan=True)
    # 'e4m3' (static cast, no selection arm) is detection-only: the
    # flags above are the whole guarantee and the skip-step rung
    # downstream does the containing.


@pytest.mark.parametrize("recipe", RECIPES)
def test_clean_path_has_no_flags(recipe):
    """The clean run of the exact operand shape used above reports
    GUARD_OK -- the detection tests are not satisfied by a guard that
    cries wolf."""
    _, stats = mor_quantize(_operand(), _xla(recipe))
    assert float(stats[STAT_GUARD_FLAGS]) == 0.0
    assert float(stats[STAT_FALLBACK_COUNT]) == 0.0


def test_pack_path_preserves_poison_in_bf16_block():
    """The real-quantization path (MixedOperand) makes the same call:
    the poisoned block packs as TAG_BF16 and decodes the NaN back."""
    from repro.kernels.ref import TAG_BF16

    x = _operand().at[3, 7].set(np.nan).astype(jnp.bfloat16)
    mo, stats = quantize_for_gemm(x, _xla("sub3"))
    assert int(np.sum(np.asarray(mo.tags) == TAG_BF16)) == 1
    assert float(stats[STAT_FALLBACK_COUNT]) == 1.0
    y = np.asarray(mo.dequant(), np.float32)
    assert np.isnan(y[3, 7])
    mask = np.ones(y.shape, bool)
    mask[3, 7] = False
    assert np.isfinite(y[mask]).all()


# ------------------------------------------------------ pack faults --
def _equal_or_both_nan(a, b):
    return np.array_equal(a, b, equal_nan=True)


def test_payload_bitflip_contained():
    """A flipped payload bit perturbs at most the elements sharing that
    byte -- corruption cannot spread past its own lane position."""
    x = _operand(3).astype(jnp.bfloat16)
    mo, _ = quantize_for_gemm(x, _xla("sub3"))
    clean = np.asarray(mo.dequant(), np.float32)
    bad = get_fault("payload_bitflip").inject(mo, seed=11)
    inj = np.asarray(bad.dequant(), np.float32)
    both_nan = np.isnan(clean) & np.isnan(inj)
    diff = (clean != inj) & ~both_nan
    # fp8 payload: one byte == one element; nibble-packed NVFP4 would
    # allow two.  Zero-diff would mean the flip landed in a BF16
    # block's unused byte -- seed 11 is pinned to avoid that.
    assert 1 <= int(diff.sum()) <= 2, int(diff.sum())


def test_scale_corrupt_contained():
    """A NaN GAM scale poisons exactly its own block on decode; every
    other block decodes bit-identically."""
    x = _operand(4).astype(jnp.bfloat16)
    mo, _ = quantize_for_gemm(x, _xla("sub3"))
    clean = np.asarray(mo.dequant(), np.float32)
    bad = get_fault("scale_corrupt").inject(mo, seed=7)
    inj = np.asarray(bad.dequant(), np.float32)

    sc = np.asarray(mo.scales)
    (bi, bj) = np.argwhere(np.asarray(bad.scales) != sc)[0][:2]
    bm = x.shape[0] // sc.shape[0]
    bk = x.shape[1] // sc.shape[1]
    block = np.zeros(x.shape, bool)
    block[bi * bm:(bi + 1) * bm, bj * bk:(bj + 1) * bk] = True
    assert not np.isfinite(inj[block]).all()
    assert _equal_or_both_nan(inj[~block], clean[~block])


def test_micro_scale_corrupt_contained():
    """A trashed NVFP4 micro-scale byte (0xFF = E4M3 NaN) poisons only
    its own 16-element micro group."""
    from repro.kernels.ref import TAG_NVFP4, pack_mixed

    # Tags are forced: gaussian data never *prefers* the 4-bit arm
    # (nv_sums < e4_sums is unreachable), and this test is about the
    # injector + decode containment, not the selection policy.
    x = _operand(5, (128, 256)).astype(jnp.bfloat16)
    tags = jnp.full((1, 2), TAG_NVFP4, jnp.int32)
    mo = pack_mixed(x, tags, (128, 128), with_nvfp4=True)
    assert int((np.asarray(mo.micro_scales) != 0).sum()) > 0
    clean = np.asarray(mo.dequant(), np.float32)
    bad = get_fault("micro_scale_corrupt").inject(mo, seed=9)
    inj = np.asarray(bad.dequant(), np.float32)
    n_bad = int(np.sum(~np.isfinite(inj)))
    assert 1 <= n_bad <= 16, n_bad
    ok = np.isfinite(inj)
    assert _equal_or_both_nan(inj[ok], clean[ok])


# ------------------------------------------- stale-amax re-encode --
def test_backoff_recovers_with_bounded_retries():
    """A 4x-stale amax (the stale_amax class) is covered after exactly
    two scale doublings; the re-encode is finite, unclipped and close
    to the data."""
    x = _operand(6, (128, 128))
    true_amax = jnp.max(jnp.abs(x))
    stale = get_fault("stale_amax").inject(true_amax, shrink=4.0)
    y, stats, attempts = requantize_with_backoff(x, stale, max_retries=3)
    assert int(attempts) == 2
    assert float(stats[STAT_GUARD_FLAGS]) == 0.0
    y = np.asarray(y)
    assert np.isfinite(y).all()
    # e4m3 at a covering scale: ~2^-4 relative error, no saturation.
    assert np.allclose(y, np.asarray(x), rtol=0.08, atol=0.02)
    assert np.abs(y).max() <= float(true_amax) * 1.01


def test_backoff_exhaustion_falls_back_to_bf16():
    """Past the retry budget the event degrades to passthrough and is
    flagged GUARD_STALE_SCALE rather than silently clipping."""
    x = _operand(6, (128, 128))
    stale = jnp.max(jnp.abs(x)) / 1e6
    y, stats, attempts = requantize_with_backoff(x, stale, max_retries=2)
    assert int(attempts) == 2
    assert bool(guard_flag_set(stats[STAT_GUARD_FLAGS],
                               GUARD_STALE_SCALE))
    assert np.array_equal(np.asarray(y), np.asarray(x))


def test_backoff_nonfinite_amax_falls_back():
    x = _operand(6, (128, 128))
    y, stats, _ = requantize_with_backoff(x, jnp.float32(np.inf))
    assert bool(guard_flag_set(stats[STAT_GUARD_FLAGS],
                               GUARD_NONFINITE_AMAX))
    assert np.array_equal(np.asarray(y), np.asarray(x))


# ------------------------------------------------ optimizer rung --
def _tree_bitexact(a, b):
    ok = jax.tree.map(
        lambda x, y: np.array_equal(np.asarray(x), np.asarray(y),
                                    equal_nan=True),
        a, b,
    )
    return all(jax.tree.leaves(ok))


@pytest.mark.parametrize("kind", ["grad_nan", "grad_inf"])
def test_skip_step_preserves_state(kind):
    """The skip-step rung: a poisoned gradient tree leaves master
    weights, *packed* Adam moments (uint8 payload lanes included), the
    step counter and the emitted params bit-exact, and reports
    ``guard_skip``."""
    from repro.optim import AdamWConfig, adamw_update, init_opt_state
    from repro.optim.moments import MomentPolicy

    moments = MomentPolicy(m=_xla("sub3"), v=_xla("sub3", threshold=0.02),
                           min_leaf=0)
    rng = np.random.default_rng(8)
    params = {
        "w": jnp.asarray(rng.normal(size=(128, 128)), jnp.bfloat16),
        "b": jnp.asarray(rng.normal(size=(128,)), jnp.bfloat16),
    }
    cfg = AdamWConfig(peak_lr=1e-3, final_lr=1e-4, warmup_steps=2,
                      total_steps=10)
    opt = init_opt_state(params, moments=moments)
    grads = {k: jnp.asarray(rng.normal(size=v.shape) * 1e-2, jnp.float32)
             for k, v in params.items()}

    # One clean step first so the packed moments hold real payloads.
    params, opt, m0 = adamw_update(cfg, grads, opt, moments=moments,
                                   guard=GuardPolicy())
    assert float(m0["guard_skip"]) == 0.0

    bad = get_fault(kind).inject(grads, seed=2)
    p2, opt2, m2 = adamw_update(cfg, bad, opt, moments=moments,
                                guard=GuardPolicy())
    assert float(m2["guard_skip"]) == 1.0
    assert _tree_bitexact(opt2.master, opt.master)
    assert _tree_bitexact(opt2.m, opt.m)
    assert _tree_bitexact(opt2.v, opt.v)
    assert int(opt2.step) == int(opt.step)
    assert _tree_bitexact(p2, params)

    # The same poisoned grads *without* the guard do corrupt state --
    # the rung is load-bearing, not vacuous.
    p3, opt3, _ = adamw_update(cfg, bad, opt, moments=moments)
    assert not _tree_bitexact(opt3.master, opt.master)


# ------------------------------------------------ train-step rung --
def _make_chaos_step(compress="mor_ef", guard=None, fault=None,
                     total_steps=50):
    from repro.configs import get_config, reduced
    from repro.core import paper_default
    from repro.models import init_params
    from repro.optim import AdamWConfig, init_opt_state
    from repro.train import TrainConfig, make_train_step

    cfg = dataclasses.replace(reduced(get_config("llama3-8b")), vocab=64)
    pol = paper_default("sub3")
    pol = pol.replace(
        act=pol.act.replace(backend="xla"),
        weight=pol.weight.replace(backend="xla"),
        grad=pol.grad.replace(backend="xla"),
    )
    tcfg = TrainConfig(
        optimizer=AdamWConfig(peak_lr=1e-3, final_lr=1e-4,
                              warmup_steps=5, total_steps=total_steps),
        compress_grads=compress,
        grad_policy=_xla("sub3") if compress != "none" else None,
        guard=guard,
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, ef=compress.endswith("_ef"))
    step = jax.jit(make_train_step(cfg, pol, tcfg, grad_fault=fault))
    return params, opt, step


def _batch(rng, inject=0.0):
    return {
        "tokens": jnp.asarray(rng.integers(0, 64, (4, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 64, (4, 32)), jnp.int32),
        "inject": jnp.float32(inject),
    }


def test_train_step_skip_preserves_ef_and_reports():
    """An injected step inside a real mor_ef train step: EF residuals
    are restored bit-exact (no double-count when the retried grads
    recompress), the optimizer state holds, and the guard *reports* --
    guard_skip fires and the stats summarizer counts flagged rows."""
    params, opt, step = _make_chaos_step(
        guard=GuardPolicy(), fault=make_grad_fault("nan", seed=3))
    rng = np.random.default_rng(7)
    for _ in range(4):
        params, opt, metrics = step(params, opt, _batch(rng))
        assert float(metrics["guard_skip"]) == 0.0
    assert float(metrics["guard_flag_events"]) == 0.0

    p2, opt2, m2 = step(params, opt, _batch(rng, inject=1.0))
    assert float(m2["guard_skip"]) == 1.0
    assert float(m2["guard_flag_events"]) > 0.0
    assert np.isfinite(float(m2["loss"]))  # loss precedes the poison
    assert _tree_bitexact(opt2.ef, opt.ef)
    assert _tree_bitexact(opt2.master, opt.master)
    assert _tree_bitexact(opt2.m, opt.m)
    assert _tree_bitexact(opt2.v, opt.v)
    assert int(opt2.step) == int(opt.step)
    assert _tree_bitexact(p2, params)

    # And the very same compiled step keeps training when clean.
    _, opt3, m3 = step(params, opt, _batch(rng))
    assert float(m3["guard_skip"]) == 0.0
    assert int(opt3.step) == int(opt.step) + 1


def _trajectory(steps, inject_at=(), guard=None, fault=None,
                compress="none"):
    params, opt, step = _make_chaos_step(
        compress=compress, guard=guard, fault=fault, total_steps=steps)
    rng = np.random.default_rng(7)
    losses, skips = [], 0.0
    for i in range(steps):
        b = _batch(rng, inject=float(i in inject_at))
        params, opt, metrics = step(params, opt, b)
        losses.append(float(metrics["loss"]))
        skips += float(metrics.get("guard_skip", 0.0))
    return losses, skips


def test_injected_trajectory_within_drift_bound():
    """The headline containment claim: a guarded mor_ef run with NaN
    gradients injected at three steps ends (mean of the last 10
    losses) within the PR-8 drift bound of the *dense, uninjected* run
    on the identical batch stream, and every injection was skipped."""
    dense, _ = _trajectory(50)
    inj, skips = _trajectory(
        50, inject_at={10, 25, 40}, guard=GuardPolicy(),
        fault=make_grad_fault("nan", seed=3), compress="mor_ef")
    assert skips == 3.0
    assert all(np.isfinite(inj)), "poison escaped into the loss"
    drift = abs(np.mean(inj[-10:]) - np.mean(dense[-10:]))
    assert drift <= 0.01, drift
    assert np.mean(dense[-10:]) < dense[0]  # the bound is anchored


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["nan", "inf"])
def test_injected_trajectory_sweep_slow(kind):
    """Fuller sweep: 200 steps, an injection every 20, both poison
    kinds, at the slow lane's 0.02 bound."""
    dense, _ = _trajectory(200)
    inj, skips = _trajectory(
        200, inject_at=set(range(20, 200, 20)), guard=GuardPolicy(),
        fault=make_grad_fault(kind, seed=3), compress="mor_ef")
    assert skips == 9.0
    drift = abs(np.mean(inj[-10:]) - np.mean(dense[-10:]))
    assert drift <= 0.02, drift


# --------------------------------------------------- serve rung --
@pytest.fixture(scope="module")
def serve_model():
    from repro.configs import get_config, reduced
    from repro.models import init_params

    cfg = dataclasses.replace(reduced(get_config("gemma-2b")), vocab=128)
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _serve(cfg, params, n_tok=8, inject_after=None, victim=0,
           victim_page=0, **scfg_kw):
    from repro.core import TENSOR_MOR
    from repro.serve import Engine, Request, ServeConfig

    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, L).astype(np.int32)
               for L in (3, 17, 9)]
    scfg = ServeConfig(slots=3, max_seq=64, page_size=8, prefill_chunk=8,
                       **scfg_kw)
    eng = Engine(cfg, TENSOR_MOR, params, scfg)
    reqs = [Request(i, p, max_tokens=n_tok) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    if inject_after is not None:
        for _ in range(inject_after):
            eng.step()
        assert eng.slot_state[victim] == "decode"
        page = eng.pool._owned[victim][victim_page]
        get_fault("kv_page_trash").inject(eng.pool, page)
    eng.run_to_completion()
    return reqs, eng


@pytest.mark.parametrize("kv_mor", [False, True])
def test_kv_page_trash_quarantines_only_victim(serve_model, kv_mor):
    """The serve differential: trash a live KV page mid-decode.  The
    owning slot is quarantined with the condition on ``req.error`` and
    its pages freed; every *other* request's tokens are bit-identical
    to the uninjected run (decode rows are slot-independent)."""
    cfg, params = serve_model
    ref, _ = _serve(cfg, params, kv_mor=kv_mor)
    assert all(r.done and r.error is None for r in ref)

    inj, eng = _serve(cfg, params, inject_after=5, victim=0,
                      kv_mor=kv_mor)
    v = inj[0]
    assert v.done and v.error and v.error.startswith("quarantined:")
    assert "nonfinite logits" in v.error
    assert v in eng.quarantined
    assert len(v.out) < len(ref[0].out)  # finished early, tokens kept
    for got, want in zip(inj[1:], ref[1:]):
        assert got.error is None
        assert got.out == want.out
    # Quarantine released the pages through the normal finish path.
    assert eng.pool.stats()["owned"] == 0
    assert eng.pool.free_pages() == eng.pool.n_pages


def test_kv_guard_catches_root_cause(serve_model):
    """`kv_guard` sweeps the slot's *owned* pages before the logits
    check, so a corrupted page is attributed as the root cause (the
    page, not the nonfinite logits downstream of it) -- including
    corruption in reserved pages the write frontier hasn't reached."""
    cfg, params = serve_model
    # Victim 1 (prompt 17 + 8 tokens) reserves 4 pages; its last page
    # covers positions the write frontier hasn't reached at step 5.
    inj, eng = _serve(cfg, params, inject_after=5, victim=1,
                      victim_page=-1, kv_guard=True)
    v = inj[1]
    assert v.done and v.error and v.error.startswith("quarantined:")
    assert "KV-page guard" in v.error
    assert v in eng.quarantined
