"""Chunked-attention invariants: masks, window-band scan, GQA grouping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import decode_attention, flash_attention


def _ref(q, k, v, mask):
    d = q.shape[-1]
    s = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (d**-0.5)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


def _make(B=2, S=256, H=4, dh=32, seed=0):
    rng = np.random.default_rng(seed)
    mk = lambda s: jnp.asarray(rng.standard_normal((B, S, H, dh)),
                               jnp.float32)
    return mk(1), mk(2), mk(3)


def test_causal_matches_reference():
    q, k, v = _make()
    S = q.shape[1]
    mask = jnp.tril(jnp.ones((S, S), bool))
    out = flash_attention(q, k, v, kind="causal", q_chunk=64, k_chunk=64)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_ref(q, k, v, mask)), atol=2e-4
    )


@pytest.mark.parametrize("window", [32, 100, 192])
def test_sliding_window_band_matches_reference(window):
    """The band-restricted kv scan must equal the full masked compute."""
    q, k, v = _make(seed=1)
    S = q.shape[1]
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = (j <= i) & (i - j < window)
    out = flash_attention(
        q, k, v, kind="sliding", window=window, q_chunk=64, k_chunk=64
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_ref(q, k, v, mask)), atol=2e-4
    )


def test_prefix_lm_mask():
    q, k, v = _make(seed=2)
    S = q.shape[1]
    P = 50
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = (j <= i) | (j < P)
    out = flash_attention(
        q, k, v, kind="prefix", prefix_len=P, q_chunk=64, k_chunk=64
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(_ref(q, k, v, mask)), atol=2e-4
    )


def test_gqa_grouping_consistent():
    """GQA (kv=2, q=4) equals MHA with kv heads repeated."""
    rng = np.random.default_rng(3)
    B, S, dh = 2, 128, 16
    q = jnp.asarray(rng.standard_normal((B, S, 4, dh)), jnp.float32)
    k2 = jnp.asarray(rng.standard_normal((B, S, 2, dh)), jnp.float32)
    v2 = jnp.asarray(rng.standard_normal((B, S, 2, dh)), jnp.float32)
    out_gqa = flash_attention(q, k2, v2, kind="causal", q_chunk=64,
                              k_chunk=64)
    k4 = jnp.repeat(k2, 2, axis=2)
    v4 = jnp.repeat(v2, 2, axis=2)
    out_mha = flash_attention(q, k4, v4, kind="causal", q_chunk=64,
                              k_chunk=64)
    np.testing.assert_allclose(
        np.asarray(out_gqa), np.asarray(out_mha), atol=1e-5
    )


def test_decode_matches_last_row_of_prefill():
    """decode(q_T | cache) == flash row T for the same sequence."""
    q, k, v = _make(B=1, S=64, seed=4)
    full = flash_attention(q, k, v, kind="causal", q_chunk=32, k_chunk=32)
    out = decode_attention(
        q[:, -1:], k, v, jnp.asarray(63, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(out)[0, 0], np.asarray(full)[0, -1], atol=2e-4
    )
