"""One-pass fused quantize-to-payload (ISSUE 5 tentpole) differential
suite.

The pack-emitting variant of the selection kernel must be *byte
identical* to the two-pass oracle (fused select + ``ref.pack_mixed``)
on every lane of the mixed block layout -- payload bytes, BF16 buffer,
packed nibbles, micro-scale bytes, tags and reconstructed GAM scales --
across recipes x scaling algos x odd/padded shapes, plus:

* ``quantize_for_gemm`` still decodes to the fake-quantization output
  bit-for-bit and reports the identical stats vector (one shared
  decision path, now with zero re-derivation).
* The pallas lowering of a sub-tensor ``quantize_for_gemm`` is exactly
  one ``tpu_custom_call`` with no operand-sized XLA packing ops beyond
  what the bare selection already needs (the "no second pass" claim,
  pinned on the TPU cross-lowering).
* 4-device mesh invariance in the ``tests/test_sharded_mor.py`` style:
  shard-local fused packs are bit-identical to the single-device pack.
* Hypothesis sweeps (importorskip-guarded, conftest convention).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts, hlo_rules
from repro.core.mor import mor_quantize, quantize_for_gemm
from repro.core.partition import Partition
from repro.core.policy import MoRPolicy
from repro.kernels import ops as kops
from repro.kernels import ref as kref

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

RECIPES = ("sub2", "sub3", "sub4")
ALGOS = ("gam", "e8m0", "fp32_amax")

PACK_LANES = ("payload_q", "payload_bf16", "payload_nib",
              "micro_scales", "tags", "scales")


def _mixed_tags(shape, seed=0, dtype=jnp.bfloat16):
    """Data engineered so the cascades genuinely mix all four tags:
    normal rows (E4M3), huge-dynamic-range rows (E5M2/BF16), E2M1-grid
    micro-structured rows (NVFP4 under sub4), and an all-zero stripe
    (the zero-block scale guard)."""
    rng = np.random.default_rng(seed)
    m, k = shape
    kp = -(-k // 16) * 16
    x = rng.standard_normal((m, kp))
    q = max(m // 4, 1)
    x[q:2 * q] *= np.exp2(rng.integers(-20, 20, (q, kp)))
    grid = np.array([0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0])
    mm = grid[rng.integers(0, 7, (q, kp))] * np.exp2(
        rng.integers(-9, 9, (q, kp // 16))
    ).repeat(16, axis=1)
    x[2 * q:3 * q] = mm * np.where(
        rng.standard_normal((q, kp)) > 0, 1.0, -1.0
    )
    x[-max(m // 8, 1):] = 0.0
    return jnp.asarray(x[:, :k], dtype)


def _assert_pack_equal(mo1, mo2, msg=""):
    assert mo1.block == mo2.block and mo1.shape == mo2.shape, msg
    for lane in PACK_LANES:
        a = np.asarray(getattr(mo1, lane))
        b = np.asarray(getattr(mo2, lane))
        if a.dtype == np.dtype(jnp.bfloat16):
            a, b = a.astype(np.float32), b.astype(np.float32)
        np.testing.assert_array_equal(a, b, err_msg=f"{msg} lane={lane}")


# ------------------------------------------------------ kernel parity --
@pytest.mark.parametrize("mode", RECIPES)
@pytest.mark.parametrize("algo", ALGOS)
def test_pack_bit_exact_vs_oracle(mode, algo):
    part = Partition("block", (64, 64), align=(2, 16))
    x = _mixed_tags((256, 128), seed=1)
    mo1, r1 = kref.quantize_pack_ref(x, part, mode, algo)
    mo2, r2 = kops.quantize_pack(x, part, mode, algo,
                                 backend="interpret")
    _assert_pack_equal(mo1, mo2, f"{mode}/{algo}")
    for f in ("sel", "e4_sums", "e5_sums", "counts"):
        np.testing.assert_array_equal(
            np.asarray(getattr(r1, f)), np.asarray(getattr(r2, f)),
            err_msg=f"{mode}/{algo} {f}",
        )
    if mode == "sub4":
        np.testing.assert_array_equal(
            np.asarray(r1.nv_sums), np.asarray(r2.nv_sums)
        )
    # Real quantization never materializes the fake-quant output.
    assert r1.y is None and r2.y is None


@pytest.mark.parametrize(
    "shape", [(64, 64), (200, 100), (30, 18), (128, 192), (2, 16)]
)
def test_pack_odd_and_padded_shapes(shape):
    """Block-non-divisible operands pad inside the kernel path exactly
    like the oracle (zeros pack to zero bytes under the group-amax
    scale guard)."""
    part = Partition("block", (64, 64), align=(2, 16))
    x = _mixed_tags(shape, seed=2)
    for mode in RECIPES:
        mo1, _ = kref.quantize_pack_ref(x, part, mode, "gam")
        mo2, _ = kops.quantize_pack(x, part, mode, "gam",
                                    backend="interpret")
        _assert_pack_equal(mo1, mo2, f"{shape} {mode}")


def test_pack_all_zero_and_f32():
    part = Partition("block", (64, 64), align=(2, 16))
    for mode in RECIPES:
        z = jnp.zeros((128, 128), jnp.bfloat16)
        _assert_pack_equal(
            kref.quantize_pack_ref(z, part, mode, "gam")[0],
            kops.quantize_pack(z, part, mode, "gam",
                               backend="interpret")[0],
            f"zero {mode}",
        )
        xf = _mixed_tags((128, 64), seed=3, dtype=jnp.float32)
        _assert_pack_equal(
            kref.quantize_pack_ref(xf, part, mode, "gam")[0],
            kops.quantize_pack(xf, part, mode, "gam",
                               backend="interpret")[0],
            f"f32 {mode}",
        )


# -------------------------------------------------- recipe-level glue --
@pytest.mark.parametrize("recipe",
                         ("sub2", "sub3", "sub4", "tensor", "e4m3"))
def test_quantize_for_gemm_decode_and_stats(recipe):
    """The one-pass path keeps the two invariants of the shared
    decision path: identical stats vector to mor_quantize, and a pack
    that decodes to the fake-quant output bit-for-bit."""
    x = _mixed_tags((256, 128), seed=4)
    pol = MoRPolicy(recipe=recipe, partition="block", block_shape=(64, 64))
    y, s1 = mor_quantize(x, pol)
    mo, s2 = quantize_for_gemm(x, pol)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(
        np.asarray(y, np.float32), np.asarray(mo.dequant(), np.float32)
    )


def test_quantize_for_gemm_backend_parity():
    """interpret (kernel body) vs xla (two-pass oracle) pack equality
    through the public recipe entry point."""
    x = _mixed_tags((192, 192), seed=5)
    for recipe in RECIPES:
        pol = MoRPolicy(recipe=recipe, partition="block",
                        block_shape=(64, 64))
        mo_i, s_i = quantize_for_gemm(x, pol.replace(backend="interpret"))
        mo_x, s_x = quantize_for_gemm(x, pol.replace(backend="xla"))
        _assert_pack_equal(mo_i, mo_x, recipe)
        np.testing.assert_array_equal(np.asarray(s_i), np.asarray(s_x))


def test_pack_has_nvfp4_hint():
    """The static hint the GEMM kernel keys its NVFP4 decode on: sub4
    packs carry it, three-way packs do not, and compact() refines it to
    the concrete truth."""
    x = _mixed_tags((128, 128), seed=6)
    mo3, _ = quantize_for_gemm(
        x, MoRPolicy(recipe="sub3", partition="block")
    )
    assert mo3.has_nvfp4 is False
    mo4, _ = quantize_for_gemm(
        x, MoRPolicy(recipe="sub4", partition="block")
    )
    assert mo4.has_nvfp4 is True
    # A sub4 pack whose blocks all fell through to other formats
    # compacts down to has_nvfp4=False (drops the dead decode).
    ones, _ = quantize_for_gemm(
        jnp.ones((128, 128), jnp.bfloat16),
        MoRPolicy(recipe="sub4", partition="block"),
    )
    c = ones.compact()
    assert c.has_nvfp4 == bool(
        (np.asarray(ones.tags) == kref.TAG_NVFP4).any()
    )
    assert kref.passthrough_mixed(x, (64, 64)).has_nvfp4 is False


# ------------------------------------------------------- HLO contract --
def _tpu_lowering_text(fn, *args):
    try:
        return hlo_rules.tpu_lowering_text(fn, *args)
    except hlo_rules.CrossLoweringUnavailable:
        pytest.skip("this jax has no cross-platform lowering API")


@pytest.mark.parametrize("recipe", ("sub3", "sub4"))
def test_pack_single_launch_no_xla_pack_pass(recipe):
    """quantize_for_gemm on the pallas backend is one tpu_custom_call,
    and packing adds *zero* operand-sized XLA ops over the bare
    selection (the old lowering re-blocked, re-scaled and re-cast the
    whole operand in XLA after the select). The pins live in the
    contract registry -- this test, bench_kernels and CI's lint job
    all evaluate the same ``quantize_pack_*`` contract."""
    report = contracts.check(f"quantize_pack_{recipe}")
    if report.counters.get("tpu_kernel_launches") == -1:
        pytest.skip("this jax has no cross-platform lowering API")
    assert report.ok, report.render()

    # The two-pass oracle really is a multi-pass XLA program (sanity
    # check that the counter can see what we claim to have removed).
    part = Partition("block", (128, 128), align=(2, 16))
    x = jnp.zeros((256, 256), jnp.bfloat16)

    def two_pass(a):
        r = kops.mor_select(a, part, recipe, "gam", backend="pallas")
        return kref.pack_mixed(
            a, r.sel, (128, 128), "gam", group_amax=r.group_amax,
            with_nvfp4=(recipe == "sub4"),
        )

    def select_only(a):
        return kops.mor_select(
            a, part, recipe, "gam", backend="pallas"
        ).y

    legacy_txt = _tpu_lowering_text(two_pass, x)
    sel_txt = _tpu_lowering_text(select_only, x)
    assert (hlo_rules.operand_sized_ops(legacy_txt, x.shape)
            > hlo_rules.operand_sized_ops(sel_txt, x.shape))


def test_gemm_tile_for_heuristic():
    """Autotune resolution: explicit tile > table > heuristic (cache
    when it fits, wider-bn sweep when it would not)."""
    from repro.kernels.ops import GemmTile, gemm_tile_for

    explicit = GemmTile(decode_cache=False, bn_mult=2)
    assert gemm_tile_for(2, 4, 2, (128, 128, 128), explicit) == explicit
    # Small K: cache fits.
    assert gemm_tile_for(2, 4, 8, (128, 128, 128)) == GemmTile(True, 1)
    # Huge K: falls back to the wider-bn sweep.
    big = gemm_tile_for(2, 4, 512, (128, 128, 128))
    assert big.decode_cache is False and big.bn_mult == 4
    # Single N tile: nothing to amortize.
    assert gemm_tile_for(2, 1, 8, (128, 128, 128)) == GemmTile(False, 1)
    # Registered table entry wins over the heuristic.
    from repro.kernels.ops import _GEMM_TILE_TABLE, register_gemm_tile

    try:
        register_gemm_tile(3, 3, 3, GemmTile(False, 3))
        assert gemm_tile_for(3, 3, 3, (128, 128, 128)) == GemmTile(False, 3)
    finally:
        _GEMM_TILE_TABLE.pop((3, 3, 3), None)


@pytest.mark.parametrize("recipe", ("sub3", "sub4"))
def test_gemm_decode_amortized_tiles_bit_exact(recipe):
    """Every decode-amortization tile (k-keyed cache, wider-bn sweep,
    both composed) reproduces the reference GEMM bit-for-bit."""
    from repro.kernels.ops import GemmTile

    pol = MoRPolicy(recipe=recipe, partition="block",
                    block_shape=(64, 64), backend="interpret")
    a = _mixed_tags((128, 128), seed=7)
    b = _mixed_tags((256, 128), seed=8)
    amo, _ = quantize_for_gemm(a, pol)
    bmo, _ = quantize_for_gemm(b, pol)
    want = np.asarray(kref.mixed_gemm_ref(amo, bmo), np.float32)
    for tile in (GemmTile(False, 1), GemmTile(True, 1),
                 GemmTile(False, 2), GemmTile(False, 4),
                 GemmTile(True, 2), None):
        got = kops.mixed_gemm(amo, bmo, backend="interpret", tile=tile)
        np.testing.assert_array_equal(
            want, np.asarray(got, np.float32),
            err_msg=f"{recipe} {tile}",
        )


def test_pack_kernel_mosaic_lowers():
    """Pack-emitting kernel stays Mosaic-lowerable (TPU cross-lowering
    regression, matching test_mor_select's select-mode guard)."""
    from repro.kernels.mor_select import mor_select_blocks

    x = jnp.zeros((256, 256), jnp.bfloat16)
    for mode in RECIPES:
        f = lambda a: mor_select_blocks(  # noqa: E731
            a, jnp.ones((3,), jnp.float32), jnp.float32(1.0),
            mode=mode, emit="pack",
        )
        txt = _tpu_lowering_text(f, x)
        assert hlo_rules.count_custom_calls(txt) == 1, mode


# ------------------------------------------------------- 4-device mesh --
def _run_mesh(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
    )
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_fused_pack_invariance():
    """Shard-local fused packs on a 4-device mesh are bit-identical to
    the single-device pack for every sub-tensor recipe (the allreduced
    group amax reaches the in-kernel scale guard and micro scales)."""
    out = _run_mesh("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.policy import MoRPolicy
    from repro.core.mor import quantize_for_gemm
    from repro.core.collectives import compat_shard_map

    mesh = jax.make_mesh((4,), ('data',))
    r = np.random.default_rng(0)
    base = r.standard_normal((256, 128)) * np.exp2(
        r.integers(-12, 12, (256, 128)))
    x = jnp.asarray(base, jnp.bfloat16)

    for recipe in ('sub2', 'sub3', 'sub4'):
        for algo in ('gam', 'e8m0'):
            pol = MoRPolicy(recipe=recipe, partition='block',
                            block_shape=(64, 64), algo=algo)
            pol_sh = pol.replace(mesh_axes=('data',))
            mo1, s1 = jax.jit(lambda a: quantize_for_gemm(a, pol))(x)

            def gbody(a):
                mo, s = quantize_for_gemm(a, pol_sh)
                return (mo.payload_q, mo.payload_bf16, mo.payload_nib,
                        mo.micro_scales, mo.tags, mo.scales), s
            sh = P('data', None)
            (pq, pbf, nib, ms, t, sc), s2 = jax.jit(compat_shard_map(
                gbody, mesh, P('data', None),
                ((sh, sh, sh, sh, sh, sh), P())))(x)
            np.testing.assert_array_equal(np.asarray(mo1.tags),
                                          np.asarray(t))
            np.testing.assert_array_equal(np.asarray(mo1.scales),
                                          np.asarray(sc))
            np.testing.assert_array_equal(np.asarray(mo1.payload_q),
                                          np.asarray(pq))
            np.testing.assert_array_equal(
                np.asarray(mo1.payload_bf16, np.float32),
                np.asarray(pbf, np.float32))
            if recipe == 'sub4':
                np.testing.assert_array_equal(
                    np.asarray(mo1.payload_nib), np.asarray(nib))
                np.testing.assert_array_equal(
                    np.asarray(mo1.micro_scales), np.asarray(ms))
            cols = [0, 2, 3, 4, 5, 6, 7, 8, 9]
            np.testing.assert_array_equal(
                np.asarray(s1)[cols], np.asarray(s2)[cols])
            print('OK', recipe, algo)
    """)
    assert out.count("OK") == 6, out


# -------------------------------------------------- hypothesis sweeps --
def test_pack_parity_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    part = Partition("block", (32, 32), align=(2, 16))

    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        m=st.integers(2, 80),
        k=st.integers(16, 96),
        mode=st.sampled_from(RECIPES),
        algo=st.sampled_from(ALGOS),
    )
    def run(seed, m, k, mode, algo):
        x = _mixed_tags((m, k), seed=seed)
        mo1, r1 = kref.quantize_pack_ref(x, part, mode, algo)
        mo2, r2 = kops.quantize_pack(x, part, mode, algo,
                                     backend="interpret")
        _assert_pack_equal(mo1, mo2, f"{seed} {m}x{k} {mode} {algo}")
        np.testing.assert_array_equal(np.asarray(r1.sel),
                                      np.asarray(r2.sel))

    run()
