"""NVFP4 (sub4 recipe) differential suite.

* E2M1 grid snap vs the ``ml_dtypes.float4_e2m1fn`` oracle (bit-exact),
  nibble encode/decode round-trips.
* Pack/unpack round-trips: ``quantize_for_gemm`` payloads decode to the
  fake-quantization output bit-for-bit -- odd shapes, all-zero blocks,
  every scaling algo.
* Backend parity: pallas-interpret vs xla bit-exact for selection,
  packing and the mixed GEMM (including custom_vjp grads via
  ``test_mor_recipes.test_fuse_gemm_parity``'s sub4 rows).
* Serving: a fully-NVFP4 QTensor reaches <= 0.6 B/elt and the qdot
  lowering stays a single ``tpu_custom_call``.

Hypothesis sweeps are importorskip-guarded (conftest convention,
matching ``test_mixed_gemm_props.py``): a missing extra collects as a
skip, never an error.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts, hlo_rules
from repro.core import NVFP4, NVFP4_MICRO, MoRPolicy, mor_quantize
from repro.core.formats import (
    cast_to_nvfp4,
    decode_e2m1,
    encode_e2m1,
    round_to_e2m1,
)
from repro.core.mor import quantize_for_gemm
from repro.kernels import ops as kops
from repro.kernels import ref as kref

E2M1_GRID = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]


def _rand(shape, seed=0, scale=1.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


def _nvfp4_friendly(shape, seed=0, span=9, dtype=jnp.bfloat16):
    """Data the four-way cascade genuinely sends to NVFP4: E2M1-grid
    magnitudes with per-16-element group scales spanning ~2^(2*span)
    (breaks the single per-block E4M3 scale, fine for micro scales).
    span=9 keeps the *realized* micro-group amax ratio around 2^18-2^20
    -- comfortably inside NVFP4_RANGE_RATIO = 12*448/2^-9 ~ 2^21.4 --
    so every block stays NVFP4-eligible (the pathological worst case,
    a lowest-scale group drawing sixteen 0.5s, would need ~(1/7)^16
    luck)."""
    rng = np.random.default_rng(seed)
    r, k = shape
    kp = -(-k // NVFP4_MICRO) * NVFP4_MICRO
    vals = np.asarray(E2M1_GRID[1:])[rng.integers(0, 7, (r, kp))]
    signs = np.where(rng.standard_normal((r, kp)) > 0, 1.0, -1.0)
    gs = np.exp2(
        rng.integers(-span, span + 1, (r, kp // NVFP4_MICRO))
    ).repeat(NVFP4_MICRO, axis=1)
    return jnp.asarray((signs * vals * gs)[:, :k], dtype)


# ------------------------------------------------------------- formats --
def test_round_to_e2m1_matches_ml_dtypes():
    ml_dtypes = pytest.importorskip("ml_dtypes")
    if not hasattr(ml_dtypes, "float4_e2m1fn"):
        pytest.skip("ml_dtypes has no float4_e2m1fn")
    rng = np.random.default_rng(0)
    x = np.concatenate([
        rng.standard_normal(1 << 14).astype(np.float32) * 4,
        np.asarray([0.0, -0.0, 0.25, -0.25, 0.75, 2.5, 3.5, 5.0, -5.0,
                    6.0, 7.0, 1e6, -1e6, 1e-8], np.float32),
        np.asarray(E2M1_GRID, np.float32),
    ])
    mine = np.asarray(round_to_e2m1(jnp.asarray(x)))
    want = x.astype(ml_dtypes.float4_e2m1fn).astype(np.float32)
    np.testing.assert_array_equal(mine, want)


def test_e2m1_code_roundtrip_all_16():
    codes = jnp.arange(16, dtype=jnp.int32)
    vals = np.asarray(decode_e2m1(codes))
    mags = np.asarray(E2M1_GRID)
    np.testing.assert_array_equal(vals[:8], mags)
    np.testing.assert_array_equal(vals[8:], -mags)
    # encode inverts decode on every non-(-0) grid value.
    back = np.asarray(encode_e2m1(jnp.asarray(vals)))
    back_vals = np.asarray(decode_e2m1(jnp.asarray(back)))
    np.testing.assert_array_equal(back_vals, vals)


def test_cast_to_nvfp4_exact_on_grid_multiples():
    """group_scale * E2M1-grid data with power-of-two micro scales is
    representable exactly (micro scale d = amax/6 is a power of two --
    E4M3-exact)."""
    x = np.zeros((4, 32), np.float32)
    for g in range(2):
        x[:, g * 16 : (g + 1) * 16] = (
            np.asarray(E2M1_GRID * 2)[: 16] * 2.0 ** (4 * g - 2)
        )
    got = np.asarray(cast_to_nvfp4(jnp.asarray(x)))
    np.testing.assert_array_equal(got, x)


def test_cast_to_nvfp4_zero_and_ragged():
    # All-zero input stays zero; non-16-divisible last axes pad
    # internally and slice back.
    for k in (1, 7, 16, 17, 40):
        x = jnp.zeros((3, k), jnp.float32)
        got = cast_to_nvfp4(x)
        assert got.shape == (3, k)
        np.testing.assert_array_equal(np.asarray(got), 0.0)
    x = _rand((5, 23), seed=3)
    assert cast_to_nvfp4(x).shape == (5, 23)


def test_nvfp4_formatspec_two_level_target():
    assert NVFP4.amax == 448.0 * 6.0
    assert NVFP4.bits == 4


# ------------------------------------------------- selection + parity ---
@pytest.mark.parametrize("algo", ["gam", "e8m0", "fp32_amax"])
def test_sub4_select_interpret_matches_xla(algo):
    x = _nvfp4_friendly((256, 384), seed=4)
    y0, s0 = mor_quantize(x, MoRPolicy(recipe="sub4", algo=algo,
                                       backend="xla"))
    y1, s1 = mor_quantize(x, MoRPolicy(recipe="sub4", algo=algo,
                                       backend="interpret"))
    np.testing.assert_array_equal(
        np.asarray(y0, np.float32), np.asarray(y1, np.float32)
    )
    np.testing.assert_allclose(
        np.asarray(s0), np.asarray(s1), rtol=1e-6, atol=1e-7
    )


def test_sub4_selects_nvfp4_where_it_wins():
    """The cascade sends micro-structured wide-range blocks to NVFP4
    and plain gaussian blocks to the fp8 cascade -- the dynamic escape
    hatch static sub-byte assignment lacks."""
    x_nv = _nvfp4_friendly((128, 128), seed=5)
    _, s = mor_quantize(x_nv, MoRPolicy(recipe="sub4", backend="xla"))
    assert float(s[8]) == 1.0  # frac_nvfp4
    assert float(s[9]) == pytest.approx(1.0 / NVFP4_MICRO)
    x_g = _rand((128, 128), seed=6, dtype=jnp.bfloat16)
    _, s = mor_quantize(x_g, MoRPolicy(recipe="sub4", backend="xla"))
    assert float(s[8]) == 0.0
    assert float(s[3]) == 1.0  # gaussian block stays E4M3


@pytest.mark.parametrize("shape", [(256, 384), (100, 130), (31, 47),
                                   (128, 16)])
@pytest.mark.parametrize("algo", ["gam", "e8m0"])
def test_pack_decodes_to_fake_quant_bit_exact(shape, algo):
    """quantize_for_gemm payload lanes (packed nibbles + micro scales)
    decode to the fake-quantization output bit-for-bit, odd shapes
    included (sub4 aligns blocks to (2, 16) and zero-pads)."""
    x = _nvfp4_friendly(shape, seed=sum(shape), span=8)
    pol = MoRPolicy(recipe="sub4", algo=algo, backend="xla")
    y, stats = mor_quantize(x, pol)
    mo, stats2 = quantize_for_gemm(x, pol)
    np.testing.assert_array_equal(np.asarray(stats), np.asarray(stats2))
    np.testing.assert_array_equal(
        np.asarray(mo.dequant(), np.float32), np.asarray(y, np.float32)
    )


def test_pack_all_zero_blocks():
    x = jnp.zeros((128, 128), jnp.bfloat16)
    mo, stats = quantize_for_gemm(x, MoRPolicy(recipe="sub4",
                                               backend="xla"))
    np.testing.assert_array_equal(
        np.asarray(mo.dequant(), np.float32), 0.0
    )
    assert np.isfinite(np.asarray(stats)).all()


def test_sub4_pack_rejects_incapable_block():
    x = _rand((64, 64), dtype=jnp.bfloat16)
    with pytest.raises(ValueError, match="even-row"):
        quantize_for_gemm(
            x, MoRPolicy(recipe="sub4", block_shape=(63, 64),
                         backend="xla")
        )


def test_transpose_rejects_nvfp4_pack():
    x = _nvfp4_friendly((128, 128), seed=7)
    mo, _ = quantize_for_gemm(x, MoRPolicy(recipe="sub4", backend="xla"))
    assert (np.asarray(mo.tags) == kref.TAG_NVFP4).any()
    with pytest.raises(AssertionError, match="NVFP4"):
        mo.transpose()


# ------------------------------------------------------- mixed GEMM -----
@pytest.mark.parametrize("compact", [False, True])
def test_mixed_gemm_nvfp4_interpret_matches_xla(compact):
    x = _nvfp4_friendly((128, 256), seed=8)
    w = _nvfp4_friendly((192, 256), seed=9)
    pol = MoRPolicy(recipe="sub4", backend="xla")
    a, _ = quantize_for_gemm(x, pol)
    b, _ = quantize_for_gemm(w, pol)
    if compact:
        a, b = a.compact(), b.compact()
    got = kops.mixed_gemm(a, b, out_dtype=jnp.float32,
                          backend="interpret")
    want = kops.mixed_gemm(a, b, out_dtype=jnp.float32, backend="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mixed_gemm_nvfp4_against_dense_reference():
    """Decoded-operand dense matmul == mixed GEMM (f32 accumulation
    reassociation only)."""
    x = _nvfp4_friendly((64, 128), seed=10, span=4)
    w = _nvfp4_friendly((64, 128), seed=11, span=4)
    pol = MoRPolicy(recipe="sub4", backend="xla")
    a, _ = quantize_for_gemm(x, pol)
    b, _ = quantize_for_gemm(w, pol)
    got = np.asarray(
        kops.mixed_gemm(a, b, out_dtype=jnp.float32, backend="xla")
    )
    A = np.asarray(a.dequant(), np.float32)
    B = np.asarray(b.dequant(), np.float32)
    np.testing.assert_allclose(got, A @ B.T, rtol=1e-5, atol=1e-4)


def test_sub4_mor_dot_grads_interpret_match_xla():
    """Acceptance: the fused sub4 training path -- fwd + custom_vjp
    dgrad/wgrad (which re-packs the transposed views; NVFP4 is not
    transpose-invariant) -- is bit-exact between the Pallas kernel
    bodies (interpret) and the XLA reference."""
    from repro.core import mor_dot, new_token, paper_default

    x = _nvfp4_friendly((48, 128), seed=20, span=6)
    w = _nvfp4_friendly((96, 128), seed=21, span=6).T  # (K, N)

    def outputs(backend):
        base = paper_default("sub4")
        pol = base.replace(
            act=base.act.replace(backend=backend),
            weight=base.weight.replace(backend=backend),
            grad=base.grad.replace(backend=backend),
            fuse_gemm=True,
        )

        def loss(xa, wa, tok):
            y, st = mor_dot(xa, wa, tok, pol)
            return jnp.sum(y.astype(jnp.float32) ** 2), (y, st)

        grad_fn = jax.value_and_grad(loss, argnums=(0, 1, 2),
                                     has_aux=True)
        (_, (y, st)), (gx, gw, gtok) = grad_fn(x, w, new_token())
        return y, st, gx, gw, gtok

    y0, st0, gx0, gw0, gt0 = outputs("xla")
    y1, st1, gx1, gw1, gt1 = outputs("interpret")
    for a, b in ((y0, y1), (gx0, gx1), (gw0, gw1)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )
    np.testing.assert_allclose(np.asarray(st0), np.asarray(st1),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(gt0), np.asarray(gt1),
                               rtol=1e-6, atol=1e-7)


# ----------------------------------------------------------- serving ----
def test_fully_nvfp4_qtensor_bytes_per_element():
    """Acceptance: <= 0.6 B/elt on a fully-NVFP4 weight (0.5 B packed
    nibbles + 1/16 B micro scales + compact don't-care lanes + grids)."""
    from repro.serve.quantized import qdot, quantize_weight

    K, N = 2048, 1024
    w = _nvfp4_friendly((N, K), seed=12).T  # (K, N) weight
    qt, info = quantize_weight(
        jnp.asarray(w, jnp.bfloat16), MoRPolicy(recipe="sub4",
                                                backend="xla")
    )
    assert info["frac_nvfp4"] == 1.0
    bpe = qt.nbytes / (K * N)
    assert bpe <= 0.6, bpe
    # And it still serves, bit-exactly across backends.
    x = _rand((4, K), seed=13, dtype=jnp.bfloat16)
    y0 = qdot(x, qt, backend="xla")
    y1 = qdot(x, qt, backend="interpret")
    np.testing.assert_array_equal(
        np.asarray(y0, np.float32), np.asarray(y1, np.float32)
    )


# ------------------------------------------------- TPU cross-lowering ---
def _check_contract(name):
    """Evaluate a registry contract, skipping on jax versions without
    the cross-platform lowering API (the -1 launch sentinel)."""
    report = contracts.check(name)
    if report.counters.get("tpu_kernel_launches") == -1:
        pytest.skip("this jax has no cross-platform lowering API")
    assert report.ok, report.render()
    return report


def test_sub4_select_kernel_lowers_for_tpu():
    """The fused four-way selection stays one tpu_custom_call
    (``mor_quantize_sub4`` in the contract registry)."""
    _check_contract("mor_quantize_sub4")


def test_sub4_qdot_lowers_to_single_launch():
    """Acceptance: ONE tpu_custom_call per serving GEMM against a
    fully-NVFP4 weight (``qdot_sub4`` in the contract registry), and
    the probe weight really is fully quantized."""
    from repro.serve.quantized import quantize_weight

    w = _nvfp4_friendly((256, 256), seed=15).T
    qt, _ = quantize_weight(
        jnp.asarray(w, jnp.bfloat16), MoRPolicy(recipe="sub4",
                                                backend="xla")
    )
    assert qt.frac_quantized == 1.0
    _check_contract("qdot_sub4")


# Hypothesis property sweeps live in test_nvfp4_props.py behind the
# whole-module importorskip guard (conftest convention).
