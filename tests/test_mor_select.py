"""Fused mor_select kernel (interpret mode) vs the pure-jnp oracle.

The fused Pallas kernel must be *bit-exact* against
:func:`repro.kernels.ref.mor_select_ref` -- output blocks, selection
mask, and stats -- across shape sweeps (including block-non-divisible
shapes, which the ops layer zero-pads), dtypes, scaling algos, and
adversarial high-dynamic-range inputs that flip the Eq. 4 gate.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import E4M3
from repro.core.metrics import E5M2_RANGE_RATIO
from repro.core.partition import Partition
from repro.kernels import ref as kref
from repro.kernels.mor_select import mor_select_blocks
from repro.kernels.ops import mor_select


def _rand(shape, seed=0, scale=1.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


def _assert_select_equal(got, want):
    np.testing.assert_array_equal(
        np.asarray(got.y, np.float32), np.asarray(want.y, np.float32)
    )
    np.testing.assert_array_equal(np.asarray(got.sel), np.asarray(want.sel))
    np.testing.assert_array_equal(
        np.asarray(got.e4_sums), np.asarray(want.e4_sums)
    )
    np.testing.assert_array_equal(
        np.asarray(got.e5_sums), np.asarray(want.e5_sums)
    )
    np.testing.assert_array_equal(
        np.asarray(got.counts), np.asarray(want.counts)
    )


# --------------------------------------------------------- shape sweeps --
@pytest.mark.parametrize(
    "shape", [(128, 128), (256, 384), (100, 130), (64, 100), (130, 257)]
)
@pytest.mark.parametrize("mode", ["sub2", "sub3"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_select_matches_oracle(shape, mode, dtype):
    # hash() of strings is randomized per process; derive seeds stably.
    x = _rand(shape, seed=sum(shape) + len(mode), scale=3.0, dtype=dtype)
    part = Partition("block", (128, 128))
    got = mor_select(x, part, mode, "gam", backend="interpret")
    want = kref.mor_select_ref(x, part, mode, "gam")
    _assert_select_equal(got, want)


@pytest.mark.parametrize("algo", ["e8m0", "fp32_amax"])
def test_fused_select_ablation_algos(algo):
    x = _rand((256, 256), seed=7, scale=2.0)
    part = Partition("block", (128, 128))
    got = mor_select(x, part, "sub3", algo, backend="interpret")
    want = kref.mor_select_ref(x, part, "sub3", algo)
    _assert_select_equal(got, want)


def test_fused_select_block64_nondivisible():
    x = _rand((200, 100), seed=3, scale=1.5, dtype=jnp.bfloat16)
    part = Partition("block", (64, 64))
    got = mor_select(x, part, "sub3", "gam", backend="interpret")
    want = kref.mor_select_ref(x, part, "sub3", "gam")
    assert got.sel.shape == (4, 2)
    _assert_select_equal(got, want)


# ----------------------------------------------------------- edge cases --
def test_all_zero_tensor():
    part = Partition("block", (128, 128))
    for mode in ("sub2", "sub3"):
        x = jnp.zeros((256, 128), jnp.float32)
        got = mor_select(x, part, mode, "gam", backend="interpret")
        want = kref.mor_select_ref(x, part, mode, "gam")
        _assert_select_equal(got, want)
        np.testing.assert_array_equal(np.asarray(got.y), 0.0)
        np.testing.assert_array_equal(np.asarray(got.counts), 0.0)


def test_adversarial_dynamic_range_flips_eq4_gate():
    """Blocks whose nonzero max/min ratio straddles the E5M2 range.

    Construct per-block data where E5M2 beats E4M3 on relative error
    (values living where E4M3 underflows but E5M2 doesn't), then widen
    one block's dynamic range past Eq. 4 so only that block falls back
    to BF16.
    """
    rng = np.random.default_rng(11)
    # ~27-octave log-magnitude spread: wider than E4M3's ~18-octave
    # window (448 down to 2^-9 after scaling) so its underflows cost
    # rel-err 1.0 apiece, but inside E5M2's ~32-octave window and under
    # the Eq. 4 ratio (2^27 < ~9.4e8) -> E5M2 wins Eq. 3 and passes.
    base = 2.0 ** rng.uniform(-25.0, 2.0, (128, 256)).astype(np.float32)
    base *= np.where(rng.random((128, 256)) < 0.5, -1.0, 1.0)
    x = np.array(base, np.float32)
    # Block (0, 0): push ratio far past E5M2_RANGE_RATIO (~9.4e8).
    x[0, 0] = 1e5
    x[1, 0] = 1e-6
    x = jnp.asarray(x)
    part = Partition("block", (128, 128))

    got = mor_select(x, part, "sub3", "gam", backend="interpret")
    want = kref.mor_select_ref(x, part, "sub3", "gam")
    _assert_select_equal(got, want)

    sel = np.asarray(got.sel)
    assert sel[0, 0] == 2, "over-range block must fall back to BF16"
    assert sel[0, 1] == 1, "in-range block with E5M2-shaped data keeps E5M2"
    # BF16 fallback must return the original values untouched.
    np.testing.assert_array_equal(
        np.asarray(got.y)[:, :128], np.asarray(x)[:, :128]
    )


def test_smooth_gaussian_selects_e4m3():
    """Well-conditioned data: every block should accept E4M3 (Eq. 3)."""
    x = _rand((256, 256), seed=5, scale=1.0)
    part = Partition("block", (128, 128))
    got = mor_select(x, part, "sub3", "gam", backend="interpret")
    assert np.all(np.asarray(got.sel) == 0)
    # Selected output actually is the E4M3 fake-quantized candidate.
    q = kref.quant_err_ref(x, part, E4M3, "gam")
    np.testing.assert_array_equal(np.asarray(got.y), np.asarray(q.y))


# ------------------------------------------------- TPU lowerability ----
def _tpu_lowering_text(fn, *args):
    import jax

    try:
        traced = jax.jit(fn).trace(*args)
        return traced.lower(lowering_platforms=("tpu",)).as_text()
    except TypeError:
        pytest.skip("this jax has no cross-platform lowering API")


def test_mor_select_kernel_lowers_for_tpu():
    """Mosaic-lowerable on a CPU host: catches VMEM-scalar-store /
    scalar-bitcast / (1,1)-block-tiling regressions without hardware."""
    from repro.core.formats import E5M2
    from repro.core.gam import split_mantissa_exponent

    x = _rand((256, 256), seed=0, dtype=jnp.bfloat16)

    def f(a):
        g = jnp.max(jnp.abs(a.astype(jnp.float32)))
        m4, _ = split_mantissa_exponent(E4M3.amax / g)
        m5, _ = split_mantissa_exponent(E5M2.amax / g)
        return mor_select_blocks(
            a, jnp.stack([m4, m5]), block=(128, 128), mode="sub3"
        )[0]

    txt = _tpu_lowering_text(f, x)
    assert txt.count("tpu_custom_call") == 1


def test_gam_quant_kernel_lowers_for_tpu():
    from repro.core.gam import split_mantissa_exponent
    from repro.kernels.gam_quant import gam_quant_blocks

    x = _rand((256, 256), seed=0, dtype=jnp.bfloat16)

    def f(a):
        g = jnp.max(jnp.abs(a.astype(jnp.float32)))
        m, _ = split_mantissa_exponent(E4M3.amax / g)
        return gam_quant_blocks(a, m, block=(128, 128))[0]

    txt = _tpu_lowering_text(f, x)
    assert txt.count("tpu_custom_call") == 1


# ------------------------------------------------- direct kernel entry --
@pytest.mark.parametrize("mode", ["sub2", "sub3"])
def test_kernel_entry_point_divisible(mode):
    """mor_select_blocks called directly (no ops padding layer)."""
    from repro.core.formats import E5M2
    from repro.core.gam import split_mantissa_exponent

    x = _rand((256, 128), seed=9, scale=4.0, dtype=jnp.bfloat16)
    g_amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    mg4, _ = split_mantissa_exponent(E4M3.amax / g_amax)
    mg5, _ = split_mantissa_exponent(E5M2.amax / g_amax)
    y, sel, e4, e5, cnt = mor_select_blocks(
        x, jnp.stack([mg4, mg5]), block=(128, 128), mode=mode,
        range_ratio=E5M2_RANGE_RATIO, interpret=True,
    )
    want = kref.mor_select_ref(x, Partition("block", (128, 128)), mode, "gam")
    np.testing.assert_array_equal(
        np.asarray(y, np.float32), np.asarray(want.y, np.float32)
    )
    np.testing.assert_array_equal(np.asarray(sel), np.asarray(want.sel))
    np.testing.assert_array_equal(np.asarray(e4), np.asarray(want.e4_sums))
    np.testing.assert_array_equal(np.asarray(e5), np.asarray(want.e5_sums))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(want.counts))
