"""Stats-vector contract guards (layout v4, STATS_WIDTH = 14).

Four families:

* **Width guard** -- every producer and consumer of the per-event MoR
  stats vector must key on ``repro.core.STATS_WIDTH``; these tests make
  a future layout migration fail loudly at each consumer (train_step's
  summarizer, the model token channel behind serve/engine and
  launch/dryrun, the QTensor serving stats) instead of silently
  dropping or misreading rows.
* **v3/v4 lanes** -- [10] event_kind (EVENT_GEMM/GRAD/MOMENT_M/MOMENT_V)
  and [11] payload bytes/element implied by the tag mixture; every
  producer stamps them consistently (GEMM events default to kind 0,
  optimizer events re-stamp; 'off' rows report the bf16 2.0 B/elt).
  The v4 guard lanes [12] guard_flags / [13] fallback_count are pinned
  by the chaos suite (tests/test_robust_chaos.py): flagged on
  nonfinite operands, identically zero on the clean path.
* **Disabled-event filtering** -- recipe='off' rows carry the -1.0
  decision sentinel and must not dilute the aggregated fractions.
* **grad_accum invariance** -- reported fwd_*/bwd_* metrics must be
  identical (up to f32 reassociation) for grad_accum in {1, 4} on a
  constant batch: the bwd stats used to be jnp.sum'd over the scan
  (inflating them by n) and fwd stats reported only the last
  microbatch. (tests/test_train_compress.py extends this to the
  compressed-state opt_* metrics.)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EVENT_GEMM,
    EVENT_GRAD,
    EVENT_MOMENT_M,
    EVENT_MOMENT_V,
    STAT_EVENT_KIND,
    STATS_WIDTH,
    MoRPolicy,
    mor_quantize,
    new_token,
)
from repro.train.train_step import summarize_mor_stats

ALL_RECIPES = ["off", "tensor", "sub2", "sub3", "sub4", "e4m3"]


# ------------------------------------------------------------ producers --
@pytest.mark.parametrize("recipe", ALL_RECIPES)
def test_every_recipe_emits_stats_width(recipe):
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal((64, 64)), jnp.bfloat16
    )
    _, stats = mor_quantize(x, MoRPolicy(recipe=recipe, backend="xla"))
    assert stats.shape == (STATS_WIDTH,)
    s = np.asarray(stats)
    if recipe == "off":
        assert s[0] == -1.0  # the disabled sentinel
        assert s[11] == 2.0  # passthrough rows price the bf16 payload
    else:
        assert s[0] >= 0.0
    # v2 lanes exist and are sane for non-sub4 recipes.
    if recipe not in ("sub4",):
        assert s[8] == 0.0 and s[9] == 0.0
    # v3 lanes: quantization events default to the GEMM kind; the
    # payload-bpe lane is the tag-mixture price in [NVFP4, BF16].
    assert s[10] == EVENT_GEMM
    assert 0.5 <= s[11] <= 2.0


@pytest.mark.parametrize("recipe", ["sub2", "sub3", "sub4"])
def test_payload_bpe_lane_matches_tag_mixture(recipe):
    """[11] = f_e4m3 + f_e5m2 + 2*f_bf16 + (0.5 + 1/16)*f_nvfp4."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((128, 128)) * np.exp2(
        rng.integers(-16, 16, (128, 128)))
    _, stats = mor_quantize(
        jnp.asarray(x, jnp.bfloat16),
        MoRPolicy(recipe=recipe, backend="xla", block_shape=(32, 32)),
    )
    s = np.asarray(stats)
    want = s[3] + s[4] + 2.0 * s[5] + (0.5 + 1.0 / 16.0) * s[8]
    assert s[11] == pytest.approx(want, rel=1e-6)


def test_optimizer_events_stamp_kind_lane():
    from repro.optim.compress import compress_grads
    from repro.optim.moments import encode_moment

    g = {"w": jnp.ones((256, 128), jnp.float32)}
    _, _, stats = compress_grads(
        g, "mor", policy=MoRPolicy(recipe="sub3", backend="xla"))
    assert float(stats["w"][STAT_EVENT_KIND]) == EVENT_GRAD
    pm = encode_moment(
        jnp.ones((256, 128)), MoRPolicy(recipe="sub3", backend="xla"),
        kind=EVENT_MOMENT_V)
    assert float(pm.stats[STAT_EVENT_KIND]) == EVENT_MOMENT_V
    assert EVENT_MOMENT_M != EVENT_MOMENT_V != EVENT_GRAD != EVENT_GEMM


def test_token_channel_width_matches():
    """new_token / make_tokens are the bwd-stats channel every trainer,
    the serving engine and the dry-run lower; their trailing dim is the
    contract."""
    from repro.configs import get_config, reduced
    from repro.models import make_tokens

    assert new_token().shape[-1] == STATS_WIDTH
    cfg = dataclasses.replace(reduced(get_config("llama3-8b")), vocab=64)
    toks = make_tokens(cfg)
    widths = {
        l.shape[-1] for l in jax.tree.leaves(toks) if hasattr(l, "shape")
    }
    assert widths == {STATS_WIDTH}


def test_qtensor_stats_width():
    from repro.serve.quantized import quantize_weight

    w = jnp.ones((128, 64), jnp.bfloat16)
    qt, info = quantize_weight(w, MoRPolicy(recipe="sub3"))
    assert qt.stats.shape == (STATS_WIDTH,)
    assert "frac_nvfp4" in info


def test_no_stale_width_literals_in_consumers():
    """Source guard: stats consumers must reference STATS_WIDTH, not a
    literal width -- a migration that re-hardcodes the old value should
    fail here by name."""
    import inspect

    from repro.core import linear
    from repro.models import transformer
    from repro.serve import engine
    from repro.train import train_step

    for mod in (train_step, linear, transformer):
        src = inspect.getsource(mod)
        assert "STATS_WIDTH" in src, mod.__name__
    # The engine and dry-run consume stats only through make_tokens /
    # the metrics dict; assert they do not reconstruct the width.
    for mod in (engine,):
        src = inspect.getsource(mod)
        assert "make_tokens" in src


# ---------------------------------------------------- disabled filtering --
def test_summarize_skips_disabled_rows():
    """recipe='off' events (frac_bf16 = 1.0 by construction) must not
    drag fwd_frac_bf16 toward 1 when every enabled event quantized."""
    on = np.zeros((3, STATS_WIDTH), np.float32)
    on[:, 0] = 1.0   # enabled, accepted
    on[:, 1] = 0.01  # rel_err
    on[:, 5] = 0.0   # fully quantized
    off = np.zeros((2, STATS_WIDTH), np.float32)
    off[:, 0] = -1.0  # disabled sentinel
    off[:, 5] = 1.0   # passthrough rows report BF16
    fwd = {"on": jnp.asarray(on), "off": jnp.asarray(off)}
    out = summarize_mor_stats(fwd, None)
    assert float(out["fwd_frac_bf16"]) == pytest.approx(0.0)
    assert float(out["fwd_rel_err"]) == pytest.approx(0.01)

    # Mixed: one enabled BF16-fallback row among quantized ones still
    # counts -- only the sentinel rows are filtered.
    on[0, 5] = 1.0
    out = summarize_mor_stats({"on": jnp.asarray(on),
                               "off": jnp.asarray(off)}, None)
    assert float(out["fwd_frac_bf16"]) == pytest.approx(1.0 / 3.0)


def test_summarize_opt_rows():
    """The optimizer-event family: opt_frac_bf16 / opt_rel_err /
    opt_payload_bpe aggregate the event_kind > 0 rows with the same
    disabled-row filtering as the fwd/bwd families."""
    rows = np.zeros((4, STATS_WIDTH), np.float32)
    rows[:, 0] = 1.0
    rows[:, 1] = 0.02
    rows[:, 10] = EVENT_GRAD
    rows[:, 11] = 1.0
    rows[1, 5] = 1.0   # one bf16 block event
    rows[1, 11] = 2.0
    off = np.zeros((2, STATS_WIDTH), np.float32)
    off[:, 0] = -1.0
    off[:, 5] = 1.0
    off[:, 11] = 2.0
    out = summarize_mor_stats(None, None,
                              {"g": jnp.asarray(rows),
                               "off": jnp.asarray(off)})
    assert set(out) == {"opt_frac_bf16", "opt_rel_err",
                        "opt_payload_bpe", "guard_flag_events",
                        "guard_fallback_blocks"}
    assert float(out["opt_frac_bf16"]) == pytest.approx(0.25)
    assert float(out["opt_rel_err"]) == pytest.approx(0.02)
    assert float(out["opt_payload_bpe"]) == pytest.approx(1.25)
    # Clean rows: the v4 guard counters ride along at zero.
    assert float(out["guard_flag_events"]) == 0.0
    assert float(out["guard_fallback_blocks"]) == 0.0

    # Guard lanes tally over *every* row, disabled sentinels included
    # (a passthrough event can still report a poisoned operand).
    rows[1, 12] = 2.0   # GUARD_BLOCK_FALLBACK
    rows[1, 13] = 3.0
    off[0, 12] = 1.0    # flagged on a disabled row still counts
    out = summarize_mor_stats(None, None,
                              {"g": jnp.asarray(rows),
                               "off": jnp.asarray(off)})
    assert float(out["guard_flag_events"]) == 2.0
    assert float(out["guard_fallback_blocks"]) == 3.0


def test_summarize_all_disabled_is_zero():
    off = np.zeros((4, STATS_WIDTH), np.float32)
    off[:, 0] = -1.0
    off[:, 5] = 1.0
    out = summarize_mor_stats({"off": jnp.asarray(off)},
                              {"off": jnp.asarray(off)})
    assert float(out["fwd_frac_bf16"]) == 0.0
    assert float(out["bwd_frac_bf16"]) == 0.0


def test_tracker_skips_disabled_rows():
    from repro.core import MoRStatsTracker

    tr = MoRStatsTracker()
    on = np.zeros((2, STATS_WIDTH), np.float32)
    on[:, 1] = 0.02
    off = np.zeros((2, STATS_WIDTH), np.float32)
    off[:, 0] = -1.0
    off[:, 5] = 1.0
    tr.update({"a": on, "b": off}, step=0)
    assert tr.total_events == 2  # only the enabled rows
    assert tr.bf16_fallback_pct == 0.0


# ------------------------------------------------- grad_accum invariance --
def _metrics_for_accum(grad_accum):
    from repro.configs import get_config, reduced
    from repro.core import paper_default
    from repro.models import init_params
    from repro.optim import AdamWConfig, init_opt_state
    from repro.train import TrainConfig, make_train_step

    cfg = dataclasses.replace(reduced(get_config("llama3-8b")), vocab=64)
    pol = paper_default("sub3")
    pol = pol.replace(
        act=pol.act.replace(backend="xla"),
        weight=pol.weight.replace(backend="xla"),
        grad=pol.grad.replace(backend="xla"),
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(
        cfg, pol,
        TrainConfig(
            optimizer=AdamWConfig(peak_lr=1e-3, final_lr=1e-4,
                                  warmup_steps=2, total_steps=10),
            grad_accum=grad_accum,
        ),
    ))
    # Constant batch: every microbatch slice is identical, so per-event
    # stats are identical across microbatches and any correct
    # aggregation is invariant to the split.
    rng = np.random.default_rng(5)
    row_t = rng.integers(0, 64, (1, 32))
    row_l = rng.integers(0, 64, (1, 32))
    batch = {
        "tokens": jnp.asarray(np.repeat(row_t, 4, axis=0), jnp.int32),
        "labels": jnp.asarray(np.repeat(row_l, 4, axis=0), jnp.int32),
    }
    _, _, metrics = step(params, opt, batch)
    return metrics


def test_grad_accum_stats_invariance():
    m1 = _metrics_for_accum(1)
    m4 = _metrics_for_accum(4)
    for key in ("fwd_frac_bf16", "fwd_rel_err", "bwd_frac_bf16",
                "bwd_rel_err", "loss"):
        a, b = float(m1[key]), float(m4[key])
        assert a == pytest.approx(b, rel=1e-5, abs=1e-6), (key, a, b)
