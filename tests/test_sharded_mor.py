"""Sharded-vs-single-device MoR invariance suite (ISSUE 3 tentpole).

The contract (docs/sharding.md): quantizing a block-aligned shard inside
``shard_map`` with ``MoRPolicy.mesh_axes`` set produces *bit-identical*
per-block tags, GAM scales, payload bytes and decision stats to the
single-device run, for every recipe; ``mor_dot`` fwd/dgrad/wgrad and the
sharded mixed GEMM match within f32-accumulation-order tolerance. The
only quantity allowed to drift is the *reported* ``rel_err`` scalar
(stats[1]): an f32 sum whose association differs across shardings.

Multi-device tests run in a subprocess with 4 forced host devices
(the main pytest process must keep seeing 1 device); spec-derivation
tests run in-process.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Stats-vector columns that must be bit-identical under sharding:
# decision, amax, frac_e4m3, frac_e5m2, frac_bf16, nonzero_frac, m_g,
# frac_nvfp4, micro_scale_bpe (layout v2).
# Column 1 (rel_err) is an f32 sum -> association drifts ~1 ulp.
EXACT_COLS = "[0, 2, 3, 4, 5, 6, 7, 8, 9]"


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
    )
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


_PRELUDE = f"""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.policy import MoRPolicy, MoRDotPolicy, with_mesh_axes
    from repro.core.mor import mor_quantize, quantize_for_gemm
    from repro.core.linear import mor_dot, new_token
    from repro.core.collectives import compat_shard_map
    from repro.kernels import ops as kops

    mesh = jax.make_mesh((4,), ('data',))
    EXACT = {EXACT_COLS}

    def check_stats(s1, s2):
        s1, s2 = np.asarray(s1), np.asarray(s2)
        np.testing.assert_array_equal(s1[..., EXACT], s2[..., EXACT])
        np.testing.assert_allclose(s1[..., 1], s2[..., 1],
                                   rtol=2e-6, atol=1e-7)
"""


def test_quantize_invariance_all_recipes():
    """Bit-identical y/tags/scales/payloads + stats rows on a forced
    4-device mesh, across every recipe and scaling algo."""
    out = _run(_PRELUDE + """
    r = np.random.RandomState(0)
    # High dynamic range so sub3 genuinely mixes all three tags.
    base = r.randn(256, 128) * np.exp(r.randn(256, 128))
    x = jnp.asarray(base, jnp.bfloat16)

    cases = [(rec, 'gam', 0.045) for rec in
             ('tensor', 'sub2', 'sub3', 'sub4', 'e4m3')]
    cases += [('sub3', 'e8m0', 0.045), ('sub3', 'fp32_amax', 0.045),
              ('sub4', 'e8m0', 0.045),  # NVFP4 micro scales, ablation
              ('tensor', 'gam', 0.0),   # forced reject branch
              ('off', 'gam', 0.045)]    # passthrough stats
    for recipe, algo, th in cases:
        pol = MoRPolicy(recipe=recipe, partition='block',
                        block_shape=(64, 64), algo=algo, threshold=th)
        pol_sh = pol.replace(mesh_axes=('data',))
        y1, s1 = jax.jit(lambda a: mor_quantize(a, pol))(x)

        def body(a):
            y, s = mor_quantize(a, pol_sh)
            return y, s
        y2, s2 = jax.jit(compat_shard_map(
            body, mesh, P('data', None), (P('data', None), P())))(x)
        np.testing.assert_array_equal(
            np.asarray(y1, np.float32), np.asarray(y2, np.float32))
        check_stats(s1, s2)

        if recipe == 'off':
            # Passthrough packs are compact by construction: the
            # single don't-care fp8 block is replicated, not sharded,
            # so there is no assembled payload to compare.
            continue
        mo1, _ = jax.jit(lambda a: quantize_for_gemm(a, pol))(x)

        def gbody(a):
            mo, s = quantize_for_gemm(a, pol_sh)
            return (mo.payload_q, mo.payload_bf16, mo.payload_nib,
                    mo.micro_scales, mo.tags, mo.scales), s
        sh = P('data', None)
        (pq2, pb2, nib2, ms2, t2, sc2), _ = jax.jit(compat_shard_map(
            gbody, mesh, P('data', None),
            ((sh, sh, sh, sh, sh, sh), P())))(x)
        np.testing.assert_array_equal(np.asarray(mo1.tags), np.asarray(t2))
        np.testing.assert_array_equal(
            np.asarray(mo1.scales), np.asarray(sc2))
        np.testing.assert_array_equal(
            np.asarray(mo1.payload_q), np.asarray(pq2))
        np.testing.assert_array_equal(
            np.asarray(mo1.payload_bf16, np.float32),
            np.asarray(pb2, np.float32))
        if recipe == 'sub4':
            # Sub-byte lanes: packed nibbles + E4M3 micro-scale bytes
            # are bit-identical too (micro scales derive from the
            # allreduced group amax + shard-local block data). Other
            # recipes carry compact don't-care lanes the out-spec
            # concatenation mangles harmlessly -- nothing to compare.
            np.testing.assert_array_equal(
                np.asarray(mo1.payload_nib), np.asarray(nib2))
            np.testing.assert_array_equal(
                np.asarray(mo1.micro_scales), np.asarray(ms2))
        print('RECIPE OK', recipe, algo, th)
    print('ALL OK')
    """)
    assert "ALL OK" in out


def test_mor_dot_invariance_fused_and_fake():
    """mor_dot fwd/dgrad/wgrad on a batch-sharded mesh match the
    single-device run: y/dx bit-exact (row-partitioned GEMMs, same
    contraction order), dw within bf16 psum-reassociation tolerance,
    stats rows bit-identical (except the rel_err f32 sum)."""
    out = _run(_PRELUDE + """
    r = np.random.RandomState(1)
    x = jnp.asarray(r.randn(256, 128), jnp.bfloat16)
    w = jnp.asarray(r.randn(128, 64), jnp.bfloat16)
    dy = jnp.asarray(r.randn(256, 64), jnp.bfloat16)

    def run(xx, ww, d, p):
        def f(a, b, t):
            return mor_dot(a, b, t, p)
        (y, st), vjp = jax.vjp(f, xx, ww, new_token())
        dx, dw, dtok = vjp((d, jnp.zeros_like(st)))
        return y, st, dx, dw, dtok

    for recipe in ('tensor', 'sub3'):
        for fuse in (False, True):
            pol = MoRPolicy(recipe=recipe, partition='block',
                            block_shape=(64, 64))
            dp = MoRDotPolicy(act=pol, weight=pol, grad=pol,
                              fuse_gemm=fuse)
            dp_sh = with_mesh_axes(dp, ('data',))
            y1, st1, dx1, dw1, dt1 = jax.jit(
                lambda a, b, d: run(a, b, d, dp))(x, w, dy)

            def body(a, d, b):
                y, st, dx, dw, dtok = run(a, b, d, dp_sh)
                return y, st, dx, jax.lax.psum(dw, 'data'), dtok
            sm = compat_shard_map(
                body, mesh,
                in_specs=(P('data', None), P('data', None),
                          P(None, None)),
                out_specs=(P('data', None), P(), P('data', None),
                           P(None, None), P()))
            y2, st2, dx2, dw2, dt2 = jax.jit(sm)(x, dy, w)

            np.testing.assert_array_equal(
                np.asarray(y1, np.float32), np.asarray(y2, np.float32))
            np.testing.assert_array_equal(
                np.asarray(dx1, np.float32), np.asarray(dx2, np.float32))
            # wgrad: f32-accum over 256 rows vs psum of 4 bf16 partials.
            np.testing.assert_allclose(
                np.asarray(dw1, np.float32), np.asarray(dw2, np.float32),
                rtol=3e-2, atol=2e-1)
            check_stats(st1, st2)
            check_stats(dt1, dt2)
            print('DOT OK', recipe, 'fuse' if fuse else 'fake')
    print('ALL OK')
    """)
    assert "ALL OK" in out


def test_sharded_mixed_gemm_row_col_contract():
    """kops.sharded_mixed_gemm against the single-device kernel: row-
    and col-sharded lanes are bit-exact (pure spatial partitioning);
    the contraction-sharded lane psums f32 partials (1-ulp tolerance
    after the bf16 cast)."""
    out = _run(_PRELUDE + """
    from repro.kernels.ref import passthrough_mixed
    r = np.random.RandomState(2)
    pol = MoRPolicy(recipe='sub3', partition='block',
                    block_shape=(64, 64))
    w = jnp.asarray(r.randn(256, 256) * np.exp(r.randn(256, 256)),
                    jnp.bfloat16)
    x = jnp.asarray(r.randn(256, 256), jnp.bfloat16)
    mo, _ = quantize_for_gemm(w, pol)       # (N, K) view, 4x4 grid
    a = passthrough_mixed(x, (64, 64))
    ref = np.asarray(kops.mixed_gemm(a, mo), np.float32)

    for kw in (dict(row_axis='data'), dict(col_axis='data'),
               dict(contract_axis='data')):
        got = np.asarray(
            kops.sharded_mixed_gemm(a, mo, mesh=mesh, **kw), np.float32)
        if 'contract_axis' in kw:
            np.testing.assert_allclose(got, ref, rtol=1.6e-2, atol=1e-2)
        else:
            np.testing.assert_array_equal(got, ref)
        print('GEMM OK', kw)
    print('ALL OK')
    """)
    assert "ALL OK" in out


@pytest.mark.slow
def test_engine_tensor_parallel_qtensor():
    """Engine with a (1, 2) mesh: QTensor leaves device_put per the
    Megatron rules (payload/tags/scales together) and generation still
    runs end to end through the mixed GEMM path."""
    out = _run("""
    import os
    os.environ['REPRO_KERNEL_INTERPRET'] = '0'  # GSPMD-friendly xla refs
    import dataclasses
    import jax, numpy as np
    from repro.configs import get_config, reduced
    from repro.core import BF16_BASELINE, MoRPolicy
    from repro.launch.mesh import make_local_mesh
    from repro.serve.engine import Engine, Request
    from repro.serve.quantized import QTensor

    cfg = dataclasses.replace(reduced(get_config('llama3-8b')),
                              vocab=256, d_model=64, n_heads=4,
                              n_kv=2, head_dim=16)
    from repro.models import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_local_mesh(data=1, model=2)
    eng = Engine(cfg, BF16_BASELINE, params,
                 quantize=MoRPolicy(recipe='sub3'),
                 quantize_min_size=4096, mesh=mesh)
    qleaves = [l for l in jax.tree.leaves(
        eng.params, is_leaf=lambda x: isinstance(x, QTensor))
        if isinstance(l, QTensor)]
    assert qleaves, 'no QTensor leaves'
    eng.submit(Request(rid=0, prompt=np.arange(5) % 256, max_tokens=4))
    steps = 0
    while eng.step() and steps < 32:
        steps += 1
    done = [r for r in eng.slot_req if r is None]
    print('ENGINE OK', len(qleaves))
    """, devices=2)
    assert "ENGINE OK" in out


# ---------------------------------------------------------------------
# In-process spec derivation (single device, tier-1 fast).
# ---------------------------------------------------------------------


def test_mixed_operand_pspec_compact_replicated():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.kernels.ref import passthrough_mixed
    from repro.sharding.rules import mixed_operand_pspec

    a = passthrough_mixed(jnp.ones((128, 128), jnp.bfloat16), (64, 64))
    pq, pbf, nib, ms, tags, scales = mixed_operand_pspec(a, rows="data")
    assert pq == P(None, None)  # compact fp8 buffer: replicated
    assert pbf == P("data", None)
    # Passthrough packs carry compact (don't-care) sub-byte lanes:
    # replicated like any compact buffer.
    assert nib == P(None, None) and ms == P(None, None)
    assert tags == P("data", None) and scales == P("data", None)


def test_qtensor_pspec_from_dense_transposes():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core import MoRPolicy
    from repro.serve.quantized import quantize_weight
    from repro.sharding.rules import qtensor_pspec_from_dense

    w = jnp.ones((256, 128), jnp.bfloat16)  # (K, N)
    qt, _ = quantize_weight(w, MoRPolicy(recipe="e4m3"))
    # lm_head-style dense rule: shard N ('model'); quant view is (N, K),
    # so the mixed leaves shard their *rows* over 'model'.
    spec = qtensor_pspec_from_dense(qt, P(None, "model"))
    assert spec.mo.tags == P("model", None)
    assert spec.mo.scales == P("model", None)
    assert spec.mo.payload_q == P("model", None)
    # all-fp8 weight: the bf16 dual buffer is compact -> replicated
    assert spec.mo.payload_bf16 == P(None, None)
    assert spec.stats == P(None)
    # row-parallel dense rule: contraction blocks shard instead.
    spec2 = qtensor_pspec_from_dense(qt, P("model", None))
    assert spec2.mo.tags == P(None, "model")


def test_qtensor_pspec_mesh_demotion():
    """A mesh axis that does not divide the block grid is demoted to
    replicated -- quantized leaves shard in whole blocks or not at all.
    (Only ``mesh.shape`` is consulted, so a shape stand-in suffices to
    model meshes larger than this host.)"""
    import types

    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core import MoRPolicy
    from repro.serve.quantized import quantize_weight
    from repro.sharding.rules import qtensor_pspec_from_dense

    w = jnp.ones((256, 128), jnp.bfloat16)  # view (128, 256): 1x2 grid
    qt, _ = quantize_weight(w, MoRPolicy(recipe="e4m3"))
    mesh1 = types.SimpleNamespace(shape={"data": 1, "model": 1})
    spec = qtensor_pspec_from_dense(qt, P(None, "model"), mesh1)
    assert spec.mo.tags == P("model", None)  # 1 divides everything

    # grid rows = 1, model axis size 2 -> demoted to replicated.
    mesh2 = types.SimpleNamespace(shape={"data": 1, "model": 2})
    spec2 = qtensor_pspec_from_dense(qt, P(None, "model"), mesh2)
    assert spec2.mo.tags == P(None, None)
    # contraction grid (2 blocks) divides 2 -> row-parallel stays.
    spec3 = qtensor_pspec_from_dense(qt, P("model", None), mesh2)
    assert spec3.mo.tags == P(None, "model")


def test_quantized_param_specs_tree():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config, reduced
    from repro.core import MoRPolicy
    from repro.serve.quantized import quantize_weight, quantize_weight_stacked
    from repro.sharding.rules import quantized_param_specs

    cfg = reduced(get_config("llama3-8b"))
    qw, _ = quantize_weight(
        jnp.ones((256, 128), jnp.bfloat16), MoRPolicy(recipe="e4m3")
    )
    qs, _ = quantize_weight_stacked(
        jnp.ones((2, 256, 128), jnp.bfloat16), MoRPolicy(recipe="e4m3")
    )
    params = {
        "lm_head": qw,
        "blocks": {"wo": qs, "ln1": {"scale": jnp.ones((2, 64))}},
    }
    specs = quantized_param_specs(cfg, params)
    # lm_head (d, V) -> dense P(None, 'model') -> view rows sharded.
    assert specs["lm_head"].mo.tags == P("model", None)
    # wo row-parallel P('model', None) -> contraction blocks sharded,
    # stacked lead axis unsharded.
    assert specs["blocks"]["wo"].mo.tags == P(None, None, "model")
    # norm scales stay on the dense replicated rule.
    assert specs["blocks"]["ln1"]["scale"] == P(None, None)
