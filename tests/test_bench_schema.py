"""The frozen bench_kernels --json schema (repro.bench_kernels).

Pure-stdlib tests: the validator must be usable by consumers without
jax. CI's slow lane additionally validates the real artifact produced
by the bench smoke (``python -m benchmarks.schema bench_kernels.json``).
"""
import copy

import pytest

from benchmarks.schema import (
    SCHEMA,
    make_artifact,
    rows_from_csv,
    validate_artifact,
)

GOOD_CSV = [
    "kernel/gemm_mixed_xla_512x512x512,2136.7,"
    "hbm_bytes=14155776;operand_passes=26;bytes_vs_legacy=2.25x",
    "kernel/gemm_mixed_pallas_512x512x512,0.0,tpu_kernel_launches=1",
    "kernel/gemm_sharded_row_data4_512x512x512,1360.8,"
    "devices=4;axis=data;per_shard_tpu_kernel_launches=1",
]


def test_make_artifact_roundtrip_validates():
    doc = make_artifact(GOOD_CSV)
    assert doc["schema"] == SCHEMA
    validate_artifact(doc)
    rows = rows_from_csv(GOOD_CSV)
    assert rows[0]["name"] == "kernel/gemm_mixed_xla_512x512x512"
    assert rows[1]["us"] == 0.0
    # derived strings containing commas split only on the first two.
    assert "bytes_vs_legacy=2.25x" in rows[0]["derived"]


@pytest.mark.parametrize("mutate, match", [
    (lambda d: d.pop("schema"), "schema mismatch"),
    (lambda d: d.update(schema="bench/v0"), "schema mismatch"),
    (lambda d: d.update(extra=1), "unknown top-level"),
    (lambda d: d.update(rows=[]), "non-empty"),
    (lambda d: d["rows"][0].update(name="gemm_no_prefix"), "bad name"),
    (lambda d: d["rows"][0].update(name=d["rows"][1]["name"]),
     "duplicate name"),
    (lambda d: d["rows"][0].update(us=float("nan")), "bad us"),
    (lambda d: d["rows"][0].update(us=-1.0), "bad us"),
    (lambda d: d["rows"][0].update(us="12"), "bad us"),
    (lambda d: d["rows"][0].update(derived="keyvalue_without_eq"),
     "not key=value"),
    (lambda d: d["rows"][0].pop("derived"), "keys must be exactly"),
    (lambda d: d["rows"][0].update(notes="x"), "keys must be exactly"),
])
def test_validate_rejects_drift(mutate, match):
    doc = copy.deepcopy(make_artifact(GOOD_CSV))
    mutate(doc)
    with pytest.raises(ValueError, match=match):
        validate_artifact(doc)


def test_legacy_bare_list_rejected():
    """The PR 1/PR 2 artifact shape (a bare list of rows) is exactly the
    drift this schema freezes out."""
    legacy = rows_from_csv(GOOD_CSV)
    with pytest.raises(ValueError, match="must be an object"):
        validate_artifact(legacy)


def test_known_versions_accepted_unknown_rejected():
    """Each additive bump keeps stored history validating; unknown
    versions stay hard errors."""
    from benchmarks.schema import (
        SCHEMA_V1, SCHEMA_V2, SCHEMA_V3, SCHEMA_V4, SCHEMA_V5,
        SCHEMA_V6,
    )

    doc = make_artifact(GOOD_CSV)
    assert doc["schema"] == SCHEMA_V6
    validate_artifact(doc)
    for old in (SCHEMA_V1, SCHEMA_V2, SCHEMA_V3, SCHEMA_V4, SCHEMA_V5):
        prev = copy.deepcopy(doc)
        prev["schema"] = old
        validate_artifact(prev)
    v7 = copy.deepcopy(doc)
    v7["schema"] = "repro.bench_kernels/v7"
    with pytest.raises(ValueError, match="schema mismatch"):
        validate_artifact(v7)


def test_serve_kv_cache_row_names_fit_grammar():
    """The v3 contract's serve kv-cache + flash q_offset row ids parse."""
    rows = [
        "kernel/serve_kv_cache_bf16,0.0,kv_bytes_per_token=128",
        "kernel/serve_kv_cache_mor,0.0,"
        "kv_bytes_per_token=84;kv_bpe_milli_hot=1000;"
        "kv_bpe_milli_cold=562",
        "kernel/flash_qoffset_interp,431.0,S=8;T=64;max_err=2.1e-07",
    ]
    validate_artifact(make_artifact(rows))


def test_optim_state_row_names_fit_grammar():
    """The v4 contract's compressed training-state row ids parse,
    including the gated moment_bytes_per_param_milli counter."""
    rows = [
        "kernel/grad_compress_mor_ef_1024x1024,3371.2,"
        "payload_bpe=1.188;ef=1",
        "kernel/optim_moments_fp8_1024x1024,54028.8,"
        "moment_bytes_per_param_milli=1041;payload_bpe=1.000;"
        "frac_nvfp4=0.00",
        "kernel/optim_moments_sub4_1024x1024,79202.9,"
        "moment_bytes_per_param_milli=610;payload_bpe=0.562;"
        "frac_nvfp4=1.00",
    ]
    validate_artifact(make_artifact(rows))


def test_gemm_nvfp4_row_names_fit_grammar():
    """The v2 contract's kernel/gemm_nvfp4_* row ids parse."""
    rows = [
        "kernel/gemm_nvfp4_xla_512x1024x1024,12.5,"
        "frac_nvfp4=1.00;weight_bytes_per_elt=0.563",
        "kernel/gemm_nvfp4_pallas_512x1024x1024,0.0,"
        "tpu_kernel_launches=1",
    ]
    validate_artifact(make_artifact(rows))
