"""Recipe-level regression: the backend-dispatched mor_quantize must be
bit-identical to the pre-refactor XLA lowering for every recipe x algo.

The pre-refactor path (three separate full passes over the blocked
operand for sub-tensor recipes) is frozen below as ``_legacy_*`` -- a
verbatim copy of the old ``repro.core.mor`` internals -- and compared
against the dispatched implementation on both the 'xla' backend
(must be exactly equal) and the 'interpret' backend (Pallas kernel
bodies; equal outputs, stats to float tolerance).

Also holds the hypothesis-free property test of the GAM no-saturation
invariant: block_amax * scale <= fmt.amax for E4M3 and E5M2.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    E4M3,
    E5M2,
    MoRPolicy,
    Partition,
    compute_scales,
    mor_quantize,
)
from repro.core.formats import cast_to_format
from repro.core.gam import scales_from_bmax
from repro.core.metrics import E5M2_RANGE_RATIO
from repro.core.mor import _stats, partition_of
from repro.core.partition import block_amax, from_blocks, to_blocks

RECIPES = ["tensor", "sub2", "sub3", "e4m3"]
# The frozen legacy lowering predates sub4, so the legacy-equivalence
# sweeps exclude it; the fake-vs-fused parity sweep covers it.
FUSE_RECIPES = RECIPES + ["sub4"]
ALGOS = ["gam", "e8m0", "fp32_amax"]


def _rand(shape, seed=0, scale=1.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# ------------------------------------------------------------------------
# Frozen pre-refactor implementation (the 3-pass XLA lowering).
# ------------------------------------------------------------------------
def _legacy_fused_quant_err(xb, fmt, algo):
    bmax = jnp.max(jnp.abs(xb), axis=(2, 3)).astype(jnp.float32)
    scales = scales_from_bmax(bmax, fmt, algo)
    s = scales.scale[:, :, None, None]
    xqb_f32 = cast_to_format(xb.astype(jnp.float32) * s, fmt) / s
    xqb = xqb_f32.astype(xb.dtype)
    xf = xb.astype(jnp.float32)
    nz = xf != 0.0
    err = jnp.where(
        nz,
        jnp.abs((xf - xqb.astype(jnp.float32)) / jnp.where(nz, xf, 1.0)),
        0.0,
    )
    return xqb, scales, jnp.sum(err, (2, 3)), jnp.sum(nz, (2, 3))


def _legacy_tensor_level(x2d, policy):
    part = partition_of(policy)
    xb = to_blocks(x2d, part)
    xqb, scales, err_sums, counts = _legacy_fused_quant_err(
        xb, E4M3, policy.algo
    )
    n = jnp.maximum(jnp.sum(counts.astype(jnp.float32)), 1.0)
    err = jnp.sum(err_sums) / n
    ok = err < policy.threshold
    y = from_blocks(jnp.where(ok, xqb, xb), x2d.shape)
    okf = ok.astype(jnp.float32)
    nz = jnp.sum(counts) / jnp.float32(x2d.size)
    stats = _stats(
        okf, err, scales.group_amax, okf, 0.0, 1.0 - okf, nz,
        scales.group_mantissa,
    )
    return y, stats


def _legacy_sub_tensor(x2d, policy):
    part = partition_of(policy)
    xb = to_blocks(x2d, part)

    q4b, scales4, e4_sum, n = _legacy_fused_quant_err(xb, E4M3, policy.algo)
    q5b, _, e5_sum, _ = _legacy_fused_quant_err(xb, E5M2, policy.algo)

    m1 = e4_sum < e5_sum

    nblocks = jnp.float32(m1.size)
    nz = jnp.sum(n) / jnp.float32(x2d.size)
    tot_n = jnp.maximum(jnp.sum(n.astype(jnp.float32)), 1.0)
    global_e4_err = jnp.sum(e4_sum) / tot_n
    m1b = m1[:, :, None, None]

    if policy.recipe == "sub2":
        y = from_blocks(jnp.where(m1b, q4b, xb), x2d.shape)
        f4 = jnp.sum(m1) / nblocks
        stats = _stats(
            f4, global_e4_err, scales4.group_amax, f4, 0.0, 1.0 - f4, nz,
            scales4.group_mantissa,
        )
        return y, stats

    xabs = jnp.abs(xb)
    anynz = n > 0
    bmax = jnp.max(xabs, axis=(2, 3)).astype(jnp.float32)
    big = jnp.asarray(jnp.finfo(xb.dtype).max, xb.dtype)
    bmin = jnp.min(jnp.where(xb != 0, xabs, big), axis=(2, 3)).astype(
        jnp.float32
    )
    ratio = jnp.where(anynz, bmax / jnp.where(anynz, bmin, 1.0), 1.0)
    m2 = ratio < E5M2_RANGE_RATIO
    use5 = jnp.logical_and(jnp.logical_not(m1), m2)
    y = from_blocks(
        jnp.where(m1b, q4b, jnp.where(use5[:, :, None, None], q5b, xb)),
        x2d.shape,
    )
    f4 = jnp.sum(m1) / nblocks
    f5 = jnp.sum(use5) / nblocks
    stats = _stats(
        f4, global_e4_err, scales4.group_amax, f4, f5, 1.0 - f4 - f5, nz,
        scales4.group_mantissa,
    )
    return y, stats


def _legacy_static_e4m3(x2d, policy):
    part = partition_of(policy)
    xb = to_blocks(x2d, part)
    xqb, scales, err_sums, counts = _legacy_fused_quant_err(
        xb, E4M3, policy.algo
    )
    n = jnp.maximum(jnp.sum(counts.astype(jnp.float32)), 1.0)
    err = jnp.sum(err_sums) / n
    nz = jnp.sum(counts) / jnp.float32(x2d.size)
    stats = _stats(1.0, err, scales.group_amax, 1.0, 0.0, 0.0, nz,
                   scales.group_mantissa)
    return from_blocks(xqb, x2d.shape), stats


def _legacy_mor_quantize(x2d, policy):
    if policy.recipe == "tensor":
        y, stats = _legacy_tensor_level(x2d, policy)
    elif policy.recipe in ("sub2", "sub3"):
        y, stats = _legacy_sub_tensor(x2d, policy)
    elif policy.recipe == "e4m3":
        y, stats = _legacy_static_e4m3(x2d, policy)
    else:
        raise ValueError(policy.recipe)
    return y.astype(x2d.dtype), stats


# ------------------------------------------------------------------------
# Equivalence tests.
# ------------------------------------------------------------------------
@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("recipe", RECIPES)
@pytest.mark.parametrize(
    "partition,shape",
    [("block", (256, 384)), ("block", (100, 130)), ("channel", (48, 128))],
)
def test_recipe_equivalence_xla(recipe, algo, partition, shape):
    # hash() of strings is randomized per process; derive seeds stably.
    x = _rand(shape, seed=sum(map(ord, recipe + algo)) + sum(shape),
              scale=2.5, dtype=jnp.bfloat16)
    pol = MoRPolicy(recipe=recipe, partition=partition, algo=algo,
                    backend="xla")
    y, stats = mor_quantize(x, pol)
    y_ref, stats_ref = _legacy_mor_quantize(x, pol)
    np.testing.assert_array_equal(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32)
    )
    np.testing.assert_array_equal(np.asarray(stats), np.asarray(stats_ref))


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("recipe", RECIPES)
def test_recipe_equivalence_interpret(recipe, algo):
    x = _rand((256, 384), seed=sum(map(ord, recipe + algo)), scale=2.5,
              dtype=jnp.bfloat16)
    pol = MoRPolicy(recipe=recipe, algo=algo, backend="interpret")
    y, stats = mor_quantize(x, pol)
    y_ref, stats_ref = _legacy_mor_quantize(
        x, MoRPolicy(recipe=recipe, algo=algo)
    )
    np.testing.assert_array_equal(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32)
    )
    np.testing.assert_allclose(
        np.asarray(stats), np.asarray(stats_ref), rtol=1e-6, atol=1e-7
    )


def test_disabled_recipe_passthrough():
    x = _rand((64, 64), seed=1)
    y, stats = mor_quantize(x, MoRPolicy(recipe="off"))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    # decision carries the disabled-event sentinel (stats layout v2) so
    # aggregation consumers can skip passthrough rows.
    assert np.asarray(stats)[0] == -1.0
    assert np.asarray(stats)[5] == 1.0  # the event itself is BF16


# ------------------------------------------------------------------------
# Fused mixed-GEMM parity: mor_dot(fuse_gemm=True) vs the fake-quant
# path. Same decisions -> bit-identical stats rows (fwd and bwd token
# cotangent); outputs and grads agree to f32-accumulation-order
# tolerance (the decoded operand values are bit-identical, only the
# K-block summation order differs).
# ------------------------------------------------------------------------
def _mor_dot_outputs(policy, seed=0, shape=((4, 48, 130), (130, 96))):
    import jax

    from repro.core import mor_dot, new_token

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(shape[0]), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal(shape[1]), jnp.bfloat16)

    def loss(xa, wa, tok):
        y, st = mor_dot(xa, wa, tok, policy)
        return jnp.sum(y.astype(jnp.float32) ** 2), (y, st)

    grad_fn = jax.value_and_grad(loss, argnums=(0, 1, 2), has_aux=True)
    (_, (y, fwd_stats)), (gx, gw, gtok) = grad_fn(x, w, new_token())
    return y, fwd_stats, gx, gw, gtok


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("recipe", FUSE_RECIPES)
def test_fuse_gemm_parity(recipe, algo):
    from repro.core import paper_default

    base = paper_default(recipe, algo=algo)
    base = base.replace(
        act=base.act.replace(backend="xla"),
        weight=base.weight.replace(backend="xla"),
        grad=base.grad.replace(backend="xla"),
    )
    seed = sum(map(ord, recipe + algo))
    y0, st0, gx0, gw0, gt0 = _mor_dot_outputs(base, seed)
    y1, st1, gx1, gw1, gt1 = _mor_dot_outputs(
        base.replace(fuse_gemm=True), seed
    )
    # Stats rows: one shared decision path -> bit-identical.
    np.testing.assert_array_equal(np.asarray(st0), np.asarray(st1))
    np.testing.assert_array_equal(np.asarray(gt0), np.asarray(gt1))
    # Outputs/grads: identical operand values, f32 ordering tolerance.
    for a, b in ((y0, y1), (gx0, gx1), (gw0, gw1)):
        af = np.asarray(a, np.float32)
        bf = np.asarray(b, np.float32)
        tol = 2e-2 * max(np.abs(af).max(), 1.0)
        np.testing.assert_allclose(af, bf, rtol=2e-2, atol=tol * 1e-2)


def test_fuse_gemm_parity_interpret_backend():
    """The Pallas kernel bodies (interpret mode) keep the same parity."""
    from repro.core import paper_default

    base = paper_default("sub3")
    base = base.replace(
        act=base.act.replace(backend="interpret"),
        weight=base.weight.replace(backend="interpret"),
        grad=base.grad.replace(backend="interpret"),
    )
    y0, st0, _, _, gt0 = _mor_dot_outputs(base, seed=3)
    y1, st1, _, _, gt1 = _mor_dot_outputs(
        base.replace(fuse_gemm=True), seed=3
    )
    np.testing.assert_allclose(
        np.asarray(st0), np.asarray(st1), rtol=1e-6, atol=1e-7
    )
    np.testing.assert_allclose(
        np.asarray(gt0), np.asarray(gt1), rtol=1e-6, atol=1e-7
    )
    np.testing.assert_allclose(
        np.asarray(y0, np.float32), np.asarray(y1, np.float32),
        rtol=2e-2, atol=2e-1,
    )


def test_fuse_gemm_rejects_channel_partition():
    import jax

    from repro.core import mor_dot, new_token, paper_default

    p = paper_default("sub3", partition="channel").replace(fuse_gemm=True)
    x = _rand((8, 64), dtype=jnp.bfloat16)
    w = _rand((64, 32), dtype=jnp.bfloat16)
    with pytest.raises(ValueError, match="partition='block'"):
        mor_dot(x, w, jnp.zeros((4, 8), jnp.float32), p)


# ------------------------------------------------------------------------
# GAM no-saturation invariant (hypothesis-free property sweep).
# ------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", [E4M3, E5M2], ids=["e4m3", "e5m2"])
@pytest.mark.parametrize("algo", ["gam", "e8m0"])
def test_gam_no_saturation_invariant(fmt, algo):
    parts = [
        Partition("block", (128, 128)),
        Partition("block", (64, 64)),
        Partition("tensor"),
        Partition("channel"),
    ]
    for seed in range(5):
        # Scales spanning tiny to huge magnitudes, plus zero rows.
        x = np.array(_rand((96, 160), seed=seed, scale=10.0**(seed - 2)))
        x[seed] = 0.0
        x = jnp.asarray(x)
        for part in parts:
            sc = compute_scales(x, part, fmt, algo=algo)
            bmax = np.asarray(block_amax(x, part), np.float64)
            scale = np.asarray(sc.scale, np.float64)
            assert np.all(bmax * scale <= fmt.amax * (1 + 1e-6)), (
                fmt.name, algo, part.kind, seed,
            )
            assert np.all(np.isfinite(scale)) and np.all(scale > 0)
