"""MoR KV-cache tier: per-block tag-select quantization, cold-page sub4
recompression, score-space scale folding, and the paged pool's packed
lanes (docs/numerics.md, docs/serving.md)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import TENSOR_MOR
from repro.core.mor import (
    STAT_DECISION,
    STAT_FRAC_BF16,
    STAT_FRAC_E4M3,
    STAT_FRAC_E5M2,
    STAT_FRAC_NVFP4,
    STAT_NONZERO_FRAC,
    STATS_WIDTH,
)
from repro.kernels.ref import TAG_BF16, TAG_E4M3, TAG_E5M2, TAG_NVFP4
from repro.models import init_cache, init_params, make_decode_fn, make_tokens
from repro.models.attention import (
    _mor_kv_values,
    decode_attention,
    kv_bytes_per_element,
    kv_stats_row,
    quantize_kv_mor,
    recompress_kv_nvfp4,
)
from repro.serve import PagedKVPool


def _rand(shape, seed=0, scale=1.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


def _dequant(payload, tags, scales):
    vals = _mor_kv_values(payload, tags)
    ss = jnp.where(scales > 0, scales, 1.0)
    return np.asarray(vals / ss[..., None], np.float32)


# ------------------------------------------------------ hot-tier quantize --
def test_quantize_kv_mor_roundtrip():
    x = _rand((2, 16, 4, 16), seed=0, scale=3.0)
    payload, tags, scales = quantize_kv_mor(x)
    assert payload.dtype == jnp.uint8 and payload.shape == x.shape
    assert tags.shape == scales.shape == x.shape[:-1]
    # Hot storage mixture is the two fp8 arms only (bytes stay bounded).
    assert set(np.unique(np.asarray(tags))) <= {TAG_E4M3, TAG_E5M2}
    assert np.all(np.asarray(scales) > 0)  # zero scale = empty marker
    deq = _dequant(payload, tags, scales)
    xf = np.asarray(x, np.float32)
    rel = np.abs(deq - xf) / (np.abs(xf) + 1e-3)
    assert np.median(rel) < 0.04


def test_quantize_kv_mor_outlier_rows_pick_e5m2():
    """A block with a huge dynamic range overwhelms E4M3's exponent
    span; the Eq. 3 comparison must route it to the E5M2 arm."""
    rng = np.random.default_rng(3)
    x = np.full((1, 4, 1, 16), 1e-4, np.float32)
    x[..., 0] = 3e4  # ~8 binades above the rest
    x *= rng.choice([-1.0, 1.0], x.shape)
    _, tags, _ = quantize_kv_mor(jnp.asarray(x))
    assert np.all(np.asarray(tags) == TAG_E5M2)
    xg = _rand((1, 8, 2, 16), seed=4)  # plain Gaussian rows: E4M3 wins
    _, tg, _ = quantize_kv_mor(xg)
    assert np.all(np.asarray(tg) == TAG_E4M3)


def test_quantize_kv_mor_stats_row():
    x = _rand((1, 8, 2, 16), seed=5)
    *_, row = quantize_kv_mor(x, with_stats=True)
    row = np.asarray(row)
    assert row.shape == (STATS_WIDTH,)
    assert row[STAT_DECISION] == 1.0
    assert row[STAT_NONZERO_FRAC] == 16  # block count in cache rows
    assert abs(row[STAT_FRAC_E4M3] + row[STAT_FRAC_E5M2]
               + row[STAT_FRAC_BF16] - 1.0) < 1e-6


# ----------------------------------------------------- cold-tier sub4 --
def test_recompress_kv_nvfp4_roundtrip():
    x = _rand((2, 8, 2, 16), seed=6, scale=2.0)
    hot = quantize_kv_mor(x)
    payload, tags, scales = recompress_kv_nvfp4(*hot)
    assert np.all(np.asarray(tags) == TAG_NVFP4)
    assert np.all(np.asarray(scales) > 0)
    # Bytes beyond nibbles + micro scales stay zero (dh/2 + dh/16).
    dh = x.shape[-1]
    used = dh // 2 + dh // 16
    assert np.all(np.asarray(payload)[..., used:] == 0)
    deq = _dequant(payload, tags, scales)
    xf = np.asarray(x, np.float32)
    rel = np.abs(deq - xf) / (np.abs(xf) + 1e-2)
    assert np.median(rel) < 0.25  # 4-bit storage: coarse but bounded
    assert np.all(np.isfinite(deq))


def test_recompress_rejects_unaligned_head_dim():
    x = _rand((1, 4, 1, 8), seed=7)
    payload, tags, scales = quantize_kv_mor(x)
    with pytest.raises(ValueError, match="divisible"):
        recompress_kv_nvfp4(payload, tags, scales)


def test_kv_bytes_per_element_by_tag():
    mk = lambda tag: jnp.full((4,), tag, jnp.uint8)
    assert float(kv_bytes_per_element(mk(TAG_E4M3))) == 1.0
    assert float(kv_bytes_per_element(mk(TAG_E5M2))) == 1.0
    assert float(kv_bytes_per_element(mk(TAG_BF16))) == 2.0
    assert abs(float(kv_bytes_per_element(mk(TAG_NVFP4))) - 0.5625) < 1e-6
    mixed = jnp.asarray([TAG_E4M3, TAG_NVFP4], jnp.uint8)
    assert abs(float(kv_bytes_per_element(mixed)) - 0.78125) < 1e-6
    row = np.asarray(kv_stats_row(mixed))
    assert row[STAT_FRAC_E4M3] == 0.5
    assert row[STAT_FRAC_NVFP4] == 0.5
    assert row[STAT_NONZERO_FRAC] == 2


# ------------------------------------------------------- decode parity --
def test_decode_attention_mor_matches_bf16():
    rng = np.random.default_rng(1)
    B, T, Hq, Hkv, dh = 2, 32, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    cur = jnp.asarray(T - 1, jnp.int32)

    ref = decode_attention(q, k, v, cur)
    kp, kt, ks = quantize_kv_mor(k)
    vp, vt, vs = quantize_kv_mor(v)
    out = decode_attention(
        q, kp, vp, cur, k_scale=ks, v_scale=vs, k_tags=kt, v_tags=vt
    )
    # Same tolerance as the fp8 cache suite: the hot tier stores fp8.
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=0.1, atol=0.05,
    )


def test_decode_attention_mor_per_row_positions():
    rng = np.random.default_rng(2)
    B, T, Hq, Hkv, dh = 3, 24, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    cur = jnp.asarray([5, 13, 23], jnp.int32)

    ref = decode_attention(q, k, v, cur)
    kp, kt, ks = quantize_kv_mor(k)
    vp, vt, vs = quantize_kv_mor(v)
    out = decode_attention(
        q, kp, vp, cur, k_scale=ks, v_scale=vs, k_tags=kt, v_tags=vt
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=0.1, atol=0.05,
    )


def test_decode_attention_cold_pages_stay_usable():
    """Sub4-recompressed (cold) cache blocks decode through the same
    tag-select path; accuracy degrades gracefully, never to garbage."""
    rng = np.random.default_rng(8)
    B, T, Hq, Hkv, dh = 2, 32, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    cur = jnp.asarray(T - 1, jnp.int32)

    ref = np.asarray(decode_attention(q, k, v, cur), np.float32)
    kp, kt, ks = recompress_kv_nvfp4(*quantize_kv_mor(k))
    vp, vt, vs = recompress_kv_nvfp4(*quantize_kv_mor(v))
    out = np.asarray(
        decode_attention(
            q, kp, vp, cur, k_scale=ks, v_scale=vs, k_tags=kt, v_tags=vt
        ),
        np.float32,
    )
    assert np.all(np.isfinite(out))
    assert float(np.max(np.abs(out - ref))) < 0.5  # 4-bit, looser


# -------------------------------------------- trash-page poison hygiene --
def _poison_beyond(arr, cur, value):
    """Overwrite cache positions past ``cur`` (garbage by contract)."""
    a = np.asarray(arr).copy()
    a[:, cur + 1:] = value
    return jnp.asarray(a)


def test_decode_mor_trash_rows_cannot_poison_output():
    """Regression for the NaN/denormal hazard: payload bytes that
    bitcast to fp8 NaN plus NaN/zero/denormal scales in rows beyond
    ``cur`` (trash-page reads, stale pages) must not perturb the
    output. A masked probability is 0, but 0 * NaN = NaN -- the divide
    must fold inside the mask and garbage value rows must be zeroed."""
    rng = np.random.default_rng(9)
    B, T, Hq, Hkv, dh = 2, 16, 4, 2, 16
    cur_i = 9
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    cur = jnp.asarray(cur_i, jnp.int32)

    kp, kt, ks = quantize_kv_mor(k)
    vp, vt, vs = quantize_kv_mor(v)
    clean = np.asarray(
        decode_attention(
            q, kp, vp, cur, k_scale=ks, v_scale=vs, k_tags=kt, v_tags=vt
        ),
        np.float32,
    )
    # 0x7F bitcasts to E4M3 NaN; tag 3 routes through the NVFP4 decode
    # whose micro-scale bytes are then NaN too.
    kp2 = _poison_beyond(kp, cur_i, 0x7F)
    vp2 = _poison_beyond(vp, cur_i, 0x7F)
    kt2 = _poison_beyond(kt, cur_i, TAG_NVFP4)
    vt2 = _poison_beyond(vt, cur_i, TAG_NVFP4)
    for bad_scale in (np.nan, 0.0, 1e-42, np.inf):
        ks2 = _poison_beyond(ks, cur_i, bad_scale)
        vs2 = _poison_beyond(vs, cur_i, bad_scale)
        out = np.asarray(
            decode_attention(
                q, kp2, vp2, cur, k_scale=ks2, v_scale=vs2,
                k_tags=kt2, v_tags=vt2,
            ),
            np.float32,
        )
        assert np.all(np.isfinite(out)), bad_scale
        np.testing.assert_array_equal(out, clean)


def test_decode_fp8_trash_rows_cannot_poison_output():
    """Same hazard on the plain fp8 cache path (no tags)."""
    rng = np.random.default_rng(10)
    from repro.models.attention import quantize_kv

    B, T, Hq, Hkv, dh = 2, 16, 4, 2, 16
    cur_i = 6
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    cur = jnp.asarray(cur_i, jnp.int32)
    kp, ks = quantize_kv(k)
    vp, vs = quantize_kv(v)
    clean = np.asarray(
        decode_attention(q, kp, vp, cur, k_scale=ks, v_scale=vs),
        np.float32,
    )
    kp2 = _poison_beyond(np.asarray(kp, np.float32), cur_i,
                         np.nan).astype(kp.dtype)
    vp2 = _poison_beyond(np.asarray(vp, np.float32), cur_i,
                         np.nan).astype(vp.dtype)
    ks2 = _poison_beyond(ks, cur_i, np.nan)
    vs2 = _poison_beyond(vs, cur_i, 0.0)
    out = np.asarray(
        decode_attention(q, kp2, vp2, cur, k_scale=ks2, v_scale=vs2),
        np.float32,
    )
    assert np.all(np.isfinite(out))
    np.testing.assert_array_equal(out, clean)


# ------------------------------------------------ model + pool plumbing --
def test_decode_step_with_mor_cache():
    cfg = dataclasses.replace(reduced(get_config("llama3-8b")), vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = make_tokens(cfg)
    decode = jax.jit(make_decode_fn(cfg, TENSOR_MOR))

    cache_m = init_cache(cfg, 2, 32, kv_mor=True)
    cache16 = init_cache(cfg, 2, 32)
    tok = jnp.asarray([[3], [5]], jnp.int32)
    cur = jnp.asarray(4, jnp.int32)

    lm, cm, _ = decode(params, tokens, cache_m, tok, cur)
    l16, _, _ = decode(params, tokens, cache16, tok, cur)
    assert np.all(np.isfinite(np.asarray(lm, np.float32)))
    a = jax.nn.softmax(np.asarray(lm[..., : cfg.vocab], np.float32))
    b = jax.nn.softmax(np.asarray(l16[..., : cfg.vocab], np.float32))
    assert float(np.max(np.abs(a - b))) < 0.05
    assert cm["dense"]["k"].dtype == jnp.uint8
    assert cm["dense"]["k_tags"].dtype == jnp.uint8
    assert cm["dense"]["k_scale"].dtype == jnp.float32


def test_init_cache_rejects_fp8_plus_mor():
    cfg = dataclasses.replace(reduced(get_config("llama3-8b")), vocab=128)
    with pytest.raises(ValueError):
        init_cache(cfg, 1, 16, kv_fp8=True, kv_mor=True)


def test_pool_mor_lanes_and_bytes_per_token():
    cfg = reduced(get_config("gemma-2b"))
    mk = lambda **kw: PagedKVPool(cfg, slots=2, max_seq=32, page_size=8,
                                  **kw)
    bf16, fp8, mor = mk(), mk(kv_fp8=True), mk(kv_mor=True)
    # Physical gather/scatter bytes per position: MoR's u8 payload +
    # tag/scale lanes beat bf16; fp8 (no tag lane) is smallest.
    assert mor.bytes_per_token() < bf16.bytes_per_token()
    assert fp8.bytes_per_token() <= mor.bytes_per_token()
    with pytest.raises(ValueError, match="kv_mor"):
        bf16.recompress_pages([0])
    assert mor.recompress_pages([mor.trash]) == 0  # trash filtered


def test_pool_recompress_pages_in_place():
    cfg = reduced(get_config("gemma-2b"))
    pool = PagedKVPool(cfg, slots=1, max_seq=32, page_size=8, kv_mor=True)
    assert pool.alloc(0, 16)  # pages 0..1
    # Write one page worth of quantized rows into every k/v lane group.
    x = _rand((1, 8, cfg.n_kv, cfg.head_dim), seed=11)
    pay, tags, sc = quantize_kv_mor(x)
    for pi, ti, si in pool._kv_lane_indices():
        n_units = pool._leaves[pi].shape[0]
        pool._leaves[pi] = pool._leaves[pi].at[:, 0].set(
            jnp.broadcast_to(pay, (n_units, *pay.shape[1:])))
        pool._leaves[ti] = pool._leaves[ti].at[:, 0].set(
            jnp.broadcast_to(tags, (n_units, *tags.shape[1:])))
        pool._leaves[si] = pool._leaves[si].at[:, 0].set(
            jnp.broadcast_to(sc, (n_units, *sc.shape[1:])))
    st = pool.kv_cache_stats()
    assert st["written"] > 0 and st["frac_fp8"] == 1.0
    assert abs(st["payload_bpe"] - 1.0) < 1e-6
    assert pool.recompress_pages([0]) == 1
    st2 = pool.kv_cache_stats()
    assert st2["frac_nvfp4"] > 0 and st2["frac_fp8"] < 1.0
    assert st2["payload_bpe"] < 1.0
    # Page 1 was never recompressed: its tags lane is untouched.
    for _, ti, _ in pool._kv_lane_indices():
        assert np.all(np.asarray(pool._leaves[ti][:, 1]) != TAG_NVFP4)
