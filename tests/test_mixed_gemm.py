"""Mixed-representation block GEMM: differential suite.

Pins the three lowerings of ``repro.kernels.ops.mixed_gemm`` --
pallas-interpret (real kernel body), the pure-jnp reference, and the
``backend='xla'`` dispatch -- bit-exact against each other across tag
patterns, shapes (including block-non-divisible, handled by the packing
layer's zero padding), and stored dtypes; plus packing round-trips,
serving (QTensor / qdot) round-trips, and TPU cross-lowering
regressions (the acceptance criterion: ONE ``tpu_custom_call`` per
GEMM).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts, hlo_rules
from repro.core import MoRPolicy, mor_quantize
from repro.core.mor import quantize_for_gemm
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.mixed_gemm import mixed_gemm_blocks
from repro.kernels.ref import (
    TAG_BF16,
    TAG_E4M3,
    TAG_E5M2,
    MixedOperand,
    decode_mixed_ref,
    pack_mixed,
    passthrough_mixed,
)


def _rand(shape, seed=0, scale=1.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


def _tags(pattern: str, nr: int, nk: int, seed: int = 0) -> jnp.ndarray:
    if pattern == "all_e4m3":
        t = np.full((nr, nk), TAG_E4M3)
    elif pattern == "all_e5m2":
        t = np.full((nr, nk), TAG_E5M2)
    elif pattern == "all_bf16":
        t = np.full((nr, nk), TAG_BF16)
    elif pattern == "checkerboard":
        t = np.indices((nr, nk)).sum(0) % 3
    elif pattern == "random":
        t = np.random.default_rng(seed).integers(0, 3, (nr, nk))
    else:
        raise ValueError(pattern)
    return jnp.asarray(t, jnp.int32)


def _pack(shape, pattern, seed, dtype, block=128, scale=2.0):
    x = _rand(shape, seed=seed, scale=scale, dtype=dtype)
    br = min(block, shape[0])
    bk = min(block, shape[1])
    nr, nk = -(-shape[0] // br), -(-shape[1] // bk)
    tags = _tags(pattern, nr, nk, seed)
    return pack_mixed(x, tags, (br, bk), "gam"), x


# --------------------------------------------------- backend equivalence --
@pytest.mark.parametrize(
    "pattern", ["all_e4m3", "all_bf16", "checkerboard", "random"]
)
@pytest.mark.parametrize(
    "mnk", [(128, 128, 128), (256, 128, 384), (100, 96, 130), (64, 257, 200)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mixed_gemm_backends_bit_exact(pattern, mnk, dtype):
    M, N, K = mnk
    seed = sum(mnk) + len(pattern)
    a, _ = _pack((M, K), pattern, seed, dtype)
    b, _ = _pack((N, K), pattern, seed + 1, dtype)
    got_i = kops.mixed_gemm(a, b, out_dtype=jnp.float32,
                            backend="interpret")
    got_x = kops.mixed_gemm(a, b, out_dtype=jnp.float32, backend="xla")
    want = kref.mixed_gemm_ref(a, b, jnp.float32)
    assert got_i.shape == (M, N)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_x), np.asarray(want))


def test_mixed_gemm_matches_plain_dot_when_all_bf16():
    """All-passthrough packs must reproduce the dense f32 block matmul."""
    x = _rand((100, 260), seed=3, dtype=jnp.float32)
    w = _rand((96, 260), seed=4, dtype=jnp.float32)
    a = passthrough_mixed(x, (128, 128))
    b = passthrough_mixed(w, (128, 128))
    got = kops.mixed_gemm(a, b, out_dtype=jnp.float32, backend="interpret")
    want = np.asarray(x) @ np.asarray(w).T
    # Block-wise K accumulation vs one dense dot: f32 ordering tolerance.
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-4)


def test_mixed_gemm_fp8_fidelity():
    """Quantized blocks approximate the dense product (fp8 fidelity)."""
    a, x = _pack((256, 256), "all_e4m3", 7, jnp.float32)
    b, w = _pack((128, 256), "all_e4m3", 8, jnp.float32)
    got = np.asarray(
        kops.mixed_gemm(a, b, out_dtype=jnp.float32, backend="interpret")
    )
    exact = np.asarray(x) @ np.asarray(w).T
    rel = np.abs(got - exact) / (np.abs(exact) + 1e-2)
    assert np.median(rel) < 0.1


@pytest.mark.slow
@pytest.mark.parametrize("pattern", ["random", "checkerboard"])
def test_mixed_gemm_large_shape_interpret(pattern):
    """Training-scale tile grid (8x4x8 blocks) through the real kernel
    body: interpret vs ref bit-exact. Slow lane (--runslow)."""
    a, _ = _pack((1024, 1024), pattern, 31, jnp.bfloat16)
    b, _ = _pack((512, 1024), pattern, 32, jnp.bfloat16)
    got = kops.mixed_gemm(a, b, out_dtype=jnp.float32, backend="interpret")
    want = kref.mixed_gemm_ref(a, b, jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------ packing contract --
@pytest.mark.parametrize("recipe", ["tensor", "sub2", "sub3", "e4m3", "off"])
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_pack_decodes_to_fake_quant_bit_exact(recipe, backend):
    """decode(quantize_for_gemm(x)) == mor_quantize(x) bit-for-bit: the
    payload layout loses nothing relative to the fake-quant path."""
    x = _rand((100, 130), seed=len(recipe), scale=2.5, dtype=jnp.bfloat16)
    pol = MoRPolicy(recipe=recipe, partition="block", backend=backend)
    y, stats = mor_quantize(x, pol)
    mo, stats2 = quantize_for_gemm(x, pol)
    np.testing.assert_allclose(
        np.asarray(stats), np.asarray(stats2), rtol=1e-6, atol=1e-7
    )
    np.testing.assert_array_equal(
        np.asarray(mo.dequant(), np.float32), np.asarray(y, np.float32)
    )


def test_pack_transpose_is_exact_for_square_blocks():
    x = _rand((256, 384), seed=9, dtype=jnp.bfloat16)
    mo, _ = quantize_for_gemm(
        x, MoRPolicy(recipe="sub3", partition="block", backend="xla")
    )
    moT, _ = quantize_for_gemm(
        x.T, MoRPolicy(recipe="sub3", partition="block", backend="xla")
    )
    t = mo.transpose()
    np.testing.assert_array_equal(np.asarray(t.tags), np.asarray(moT.tags))
    np.testing.assert_array_equal(
        np.asarray(t.scales), np.asarray(moT.scales)
    )
    np.testing.assert_array_equal(
        np.asarray(t.payload_q), np.asarray(moT.payload_q)
    )


def test_quantize_for_gemm_rejects_non_block_partitions():
    x = _rand((64, 128), seed=1)
    with pytest.raises(ValueError, match="partition='block'"):
        quantize_for_gemm(x, MoRPolicy(recipe="sub3", partition="channel"))


def test_pack_padding_blocks_contribute_zero():
    """Padded rows/cols must not leak into the product."""
    M, N, K = 100, 96, 130  # pads to 128 / 128 / 256
    a, xa = _pack((M, K), "checkerboard", 11, jnp.float32)
    b, xb = _pack((N, K), "checkerboard", 12, jnp.float32)
    dec_a = np.asarray(decode_mixed_ref(a))
    assert (dec_a[M:] == 0).all() and (dec_a[:, K:] == 0).all()
    got = kops.mixed_gemm(a, b, out_dtype=jnp.float32, backend="interpret")
    want = dec_a[:M, :K].astype(np.float32) @ np.asarray(
        decode_mixed_ref(b)
    )[:N, :K].astype(np.float32).T
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-4)


# ------------------------------------------------------- serving / qdot --
def test_qdot_roundtrip_within_policy_threshold():
    """quantize_params -> sub-tensor QTensor -> qdot vs dense bf16."""
    from repro.serve.quantized import quantize_params

    rng = np.random.default_rng(21)
    params = {
        "proj": jnp.asarray(rng.standard_normal((256, 192)), jnp.bfloat16),
        "tiny": jnp.asarray(rng.standard_normal((4, 4)), jnp.bfloat16),
    }
    pol = MoRPolicy(recipe="sub3", partition="block", backend="xla",
                    threshold=0.045)
    qparams, stats = quantize_params(params, pol, min_size=1024)
    from repro.serve.quantized import QTensor, qdot

    assert isinstance(qparams["proj"], QTensor)
    assert not isinstance(qparams["tiny"], QTensor)  # below min_size
    x = jnp.asarray(rng.standard_normal((16, 256)), jnp.bfloat16)
    y = qdot(x, qparams["proj"], backend="interpret")
    y_dense = (
        x.astype(jnp.float32) @ params["proj"].astype(jnp.float32)
    )
    err = np.abs(
        np.asarray(y, np.float32) - np.asarray(y_dense)
    ) / (np.abs(np.asarray(y_dense)) + 1e-2)
    # Per-element relative error of an fp8-quantized GEMM: bounded by
    # ~sqrt(K)*eps aggregation; the policy threshold bounds the per-
    # element operand error at 4.5%.
    assert np.median(err) < pol.threshold
    # And qdot must agree with the explicit dequantized product.
    y_deq = x.astype(jnp.float32) @ qparams[
        "proj"
    ].mo.dequant().T.astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_deq), rtol=2e-2, atol=2e-1
    )


def test_qtensor_survives_jit_donation():
    from repro.serve.quantized import qdot, quantize_weight

    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.bfloat16)
    qt, _ = quantize_weight(
        w, MoRPolicy(recipe="sub3", partition="block", backend="xla")
    )
    # Round-trip through flatten/unflatten.
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert qt2.shape == qt.shape and qt2.mo.block == qt.mo.block
    x = jnp.asarray(rng.standard_normal((8, 256)), jnp.bfloat16)
    f = jax.jit(
        lambda q, a: qdot(a, q, backend="xla"), donate_argnums=(0,)
    )
    y0 = qdot(x, qt, backend="xla")
    y1 = f(qt2, x)
    np.testing.assert_array_equal(
        np.asarray(y0, np.float32), np.asarray(y1, np.float32)
    )


def test_qtensor_tensor_recipe_accept_reject():
    """The legacy all-or-nothing behaviour survives as recipe='tensor'."""
    from repro.serve.quantized import quantize_weight

    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    qt, st = quantize_weight(w, MoRPolicy(recipe="tensor", backend="xla"))
    assert qt.is_quantized and st["quantized"] == 1.0
    assert (np.asarray(qt.tags) == TAG_E4M3).all()
    bad = jnp.asarray(
        np.exp2(rng.uniform(-30, 30, (256, 128))).astype(np.float32)
    )
    qt2, st2 = quantize_weight(
        bad, MoRPolicy(recipe="tensor", backend="xla")
    )
    assert not qt2.is_quantized and st2["quantized"] == 0.0
    assert (np.asarray(qt2.tags) == TAG_BF16).all()


def test_qtensor_sub3_mixes_representations():
    """A weight with per-block heterogeneous ranges actually mixes tags."""
    from repro.serve.quantized import quantize_weight

    rng = np.random.default_rng(5)
    w = np.asarray(rng.standard_normal((256, 256)), np.float32)
    # Block column 1: E5M2-shaped data (wide but in-range log-uniform).
    w[:, 128:] = 2.0 ** rng.uniform(-25.0, 2.0, (256, 128))
    qt, st = quantize_weight(
        jnp.asarray(w), MoRPolicy(recipe="sub3", backend="xla")
    )
    tags = np.asarray(qt.tags)
    assert (tags != tags.flat[0]).any(), f"expected mixed tags, got {tags}"


def test_quantize_params_skips_norm_scales_and_routers():
    """Regression: stacked norm scales are 2-D ('blocks/.../ln1/scale',
    (L, d)) and routers are 3-D -- both must stay dense or the layer
    scan crashes at prefill."""
    from repro.serve.quantized import QTensor, quantize_params

    rng = np.random.default_rng(0)
    params = {
        "blocks": {
            "dense": {
                "ln1": {"scale": jnp.ones((4, 512), jnp.float32)},
                "wqkv": jnp.asarray(
                    rng.standard_normal((4, 128, 384)), jnp.bfloat16
                ),
                "moe": {"router": jnp.ones((4, 128, 8), jnp.float32)},
            }
        },
        "embed": jnp.ones((512, 128), jnp.bfloat16),
    }
    q, stats = quantize_params(
        params, MoRPolicy(recipe="sub3", backend="xla"), min_size=1024
    )
    assert list(stats) == ["blocks/dense/wqkv"]
    assert isinstance(q["blocks"]["dense"]["wqkv"], QTensor)
    assert not isinstance(q["blocks"]["dense"]["ln1"]["scale"], QTensor)
    assert not isinstance(q["blocks"]["dense"]["moe"]["router"], QTensor)
    assert not isinstance(q["embed"], QTensor)


def test_stacked_qtensor_scan_slices_and_matches_dense():
    """A layer-stacked QTensor sliced by lax.scan feeds mor_dot's
    serving path per layer, matching per-layer qdot."""
    from repro.core import mor_dot, new_token, paper_default
    from repro.serve.quantized import (
        qdot,
        quantize_weight,
        quantize_weight_stacked,
    )

    rng = np.random.default_rng(13)
    w3 = jnp.asarray(rng.standard_normal((3, 256, 128)), jnp.bfloat16)
    qt, st = quantize_weight_stacked(
        w3, MoRPolicy(recipe="sub3", backend="xla")
    )
    assert qt.is_stacked and st["quantized"] == 1.0
    x = jnp.asarray(rng.standard_normal((8, 256)), jnp.bfloat16)
    pol = paper_default("sub3")

    def body(carry, qw):
        y, _ = mor_dot(x, qw, new_token(), pol)
        return carry, y

    _, ys = jax.lax.scan(body, 0, qt)
    for l in range(3):
        qt_l, _ = quantize_weight(
            w3[l], MoRPolicy(recipe="sub3", backend="xla")
        )
        want = qdot(x, qt_l)
        np.testing.assert_allclose(
            np.asarray(ys[l], np.float32), np.asarray(want, np.float32),
            rtol=2e-2, atol=2e-1,
        )
    # Stacked dequant approximates the dense stack.
    deq = np.asarray(qt.dequant(), np.float32)
    rel = np.abs(deq - np.asarray(w3, np.float32)) / (
        np.abs(np.asarray(w3, np.float32)) + 1e-2
    )
    assert np.median(rel) < 0.05


def test_compact_halves_fully_quantized_storage():
    """A fully-fp8 weight's bf16 buffer collapses to one block: stored
    bytes ~ half of dense bf16 (plus tag/scale metadata)."""
    from repro.serve.quantized import quantize_weight

    rng = np.random.default_rng(17)
    w = jnp.asarray(rng.standard_normal((512, 512)), jnp.bfloat16)
    qt, st = quantize_weight(
        w, MoRPolicy(recipe="e4m3", partition="block", backend="xla")
    )
    assert st["frac_bf16"] == 0.0
    dense = w.size * 2
    assert qt.nbytes < 0.65 * dense, (qt.nbytes, dense)
    # And the compact pack still decodes / multiplies correctly.
    x = jnp.asarray(rng.standard_normal((16, 512)), jnp.bfloat16)
    from repro.serve.quantized import qdot

    y_i = qdot(x, qt, backend="interpret")
    y_x = qdot(x, qt, backend="xla")
    np.testing.assert_array_equal(
        np.asarray(y_i, np.float32), np.asarray(y_x, np.float32)
    )


def test_activation_row_block_decode_shapes():
    """Decode-sized activations (a few rows) must not be padded to a
    full 128-row block on the serving hot path."""
    from repro.kernels.ref import activation_row_block

    assert activation_row_block(4, 128) == 16
    assert activation_row_block(100, 128) == 112
    assert activation_row_block(512, 128) == 128
    from repro.serve.quantized import qdot, quantize_weight

    rng = np.random.default_rng(19)
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.bfloat16)
    qt, _ = quantize_weight(
        w, MoRPolicy(recipe="sub3", backend="xla")
    )
    x = jnp.asarray(rng.standard_normal((4, 256)), jnp.bfloat16)
    y = qdot(x, qt, backend="interpret")
    want = qdot(x, qt, backend="xla")
    assert y.shape == (4, 128)
    np.testing.assert_array_equal(
        np.asarray(y, np.float32), np.asarray(want, np.float32)
    )


# ------------------------------------------------- TPU cross-lowering ----
def _tpu_lowering_text(fn, *args):
    try:
        return hlo_rules.tpu_lowering_text(fn, *args)
    except hlo_rules.CrossLoweringUnavailable:
        pytest.skip("this jax has no cross-platform lowering API")


def _check_contract(name):
    report = contracts.check(name)
    if report.counters.get("tpu_kernel_launches") == -1:
        pytest.skip("this jax has no cross-platform lowering API")
    assert report.ok, report.render()
    return report


def test_mixed_gemm_kernel_lowers_for_tpu_single_launch():
    """Acceptance criterion: ONE tpu_custom_call per mixed GEMM."""
    a, _ = _pack((256, 256), "checkerboard", 0, jnp.bfloat16)
    b, _ = _pack((128, 256), "checkerboard", 1, jnp.bfloat16)

    def f(aq, abf, anib, ams, at, asc, bq, bbf, bnib, bms, bt, bsc):
        return mixed_gemm_blocks(
            aq, abf, anib, ams, at, asc, bq, bbf, bnib, bms, bt, bsc,
            block=(128, 128, 128), out_dtype=jnp.bfloat16,
        )

    txt = _tpu_lowering_text(
        f, a.payload_q, a.payload_bf16, a.payload_nib, a.micro_scales,
        a.tags, a.scales,
        b.payload_q, b.payload_bf16, b.payload_nib, b.micro_scales,
        b.tags, b.scales,
    )
    assert hlo_rules.count_custom_calls(txt) == 1
    # The registry's mixed_gemm contract carries the same pin plus the
    # f32-accumulation and payload-taint rules.
    _check_contract("mixed_gemm")


def test_qdot_lowers_to_single_launch():
    """Sub-tensor qdot: the whole serving GEMM is one fused kernel
    (``qdot_sub3`` in the contract registry)."""
    _check_contract("qdot_sub3")


def test_fused_mor_dot_fwd_launch_count():
    """mor_dot(fuse_gemm=True) forward: 2 selection kernels + 1 GEMM
    kernel -- the GEMM itself is a single tpu_custom_call."""
    from repro.core import mor_dot, new_token, paper_default

    p = paper_default("sub3").replace(fuse_gemm=True)
    p = p.replace(
        act=p.act.replace(backend="pallas"),
        weight=p.weight.replace(backend="pallas"),
        grad=p.grad.replace(backend="pallas"),
    )
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((128, 256)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.bfloat16)

    txt = _tpu_lowering_text(
        lambda a, b: mor_dot(a, b, new_token(), p)[0], x, w
    )
    # One fused launch per event: 2 selection events + 1 GEMM, with
    # dedup latitude -- the pin is MOR_DOT_FWD_LAUNCHES in the
    # contract registry (also checked as ``mor_dot_fused_fwd``).
    lo, hi = contracts.MOR_DOT_FWD_LAUNCHES
    assert lo <= hlo_rules.count_custom_calls(txt) <= hi
    _check_contract("mor_dot_fused_fwd")
    _check_contract("mor_dot_fused_grads")
