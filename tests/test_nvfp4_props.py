"""Hypothesis property sweeps for the NVFP4 (sub4) pack/unpack path.

Own module so the whole-module ``importorskip`` guard (conftest
convention: hypothesis is an optional test extra; a missing import must
collect as a skip, not an error) only removes the property sweeps --
the deterministic differential suite lives in ``test_nvfp4.py``.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the 'hypothesis' test extra"
)
st = pytest.importorskip("hypothesis.strategies")

from repro.core import MoRPolicy, mor_quantize
from repro.core.formats import round_to_e2m1
from repro.core.mor import quantize_for_gemm

from test_nvfp4 import _nvfp4_friendly


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(
    m=st.integers(2, 140),
    k=st.integers(16, 300),
    seed=st.integers(0, 2**16),
    span=st.integers(0, 12),
    algo=st.sampled_from(["gam", "e8m0"]),
)
def test_property_pack_roundtrip(m, k, seed, span, algo):
    """Random shapes / group spans: the packed sub4 payload decodes to
    the fake-quant output bit-for-bit (odd shapes, ragged tails and
    all-zero micro-groups included)."""
    x = _nvfp4_friendly((m, k), seed=seed, span=span)
    pol = MoRPolicy(recipe="sub4", algo=algo, backend="xla")
    y, _ = mor_quantize(x, pol)
    mo, _ = quantize_for_gemm(x, pol)
    np.testing.assert_array_equal(
        np.asarray(mo.dequant(), np.float32), np.asarray(y, np.float32)
    )


@hypothesis.settings(deadline=None, max_examples=15)
@hypothesis.given(
    data=st.lists(
        st.floats(min_value=-1e30, max_value=1e30, allow_nan=False,
                  width=32),
        min_size=1, max_size=64,
    )
)
def test_property_e2m1_matches_ml_dtypes(data):
    ml_dtypes = pytest.importorskip("ml_dtypes")
    if not hasattr(ml_dtypes, "float4_e2m1fn"):
        pytest.skip("ml_dtypes has no float4_e2m1fn")
    x = np.asarray(data, np.float32)
    mine = np.asarray(round_to_e2m1(jnp.asarray(x)))
    want = x.astype(ml_dtypes.float4_e2m1fn).astype(np.float32)
    np.testing.assert_array_equal(mine, want)
