"""Validate the trip-count-aware HLO walker against analytic FLOP counts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo, parse_hlo


def test_scan_matmul_flops_trip_multiplied():
    """A scanned matmul must count flops ~= trips * 2*M*N*K."""
    M = N = K = 128
    trips = 7

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, None, length=trips)
        return y

    x = jnp.zeros((M, K), jnp.float32)
    w = jnp.zeros((K, N), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    cost = analyze_hlo(compiled.as_text())
    expect = trips * 2 * M * N * K
    assert 0.9 * expect < cost.flops < 1.6 * expect, (
        f"walked={cost.flops:.3e} expected~{expect:.3e}"
    )
    # XLA's own analysis (trip-count-blind) must be well below ours.
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax: one dict per device
        ca = ca[0]
    xla = float(ca.get("flops", 0.0))
    assert xla < 0.5 * cost.flops


def test_plain_matmul_flops():
    M, N, K = 64, 96, 256

    def f(x, w):
        return x @ w

    compiled = (
        jax.jit(f)
        .lower(
            jnp.zeros((M, K), jnp.float32), jnp.zeros((K, N), jnp.float32)
        )
        .compile()
    )
    cost = analyze_hlo(compiled.as_text())
    expect = 2 * M * N * K
    assert 0.9 * expect <= cost.flops < 1.3 * expect


def test_parse_finds_computations():
    hlo = """\
HloModule test

%helper (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  ROOT %t = f32[4]{0} tanh(%a)
}

ENTRY %main (x: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  %c = s32[] constant(5)
  ROOT %call.1 = f32[4]{0} call(%x), to_apply=%helper
}
"""
    comps = parse_hlo(hlo)
    assert "helper" in comps and "main" in comps
    cost = analyze_hlo(hlo)
    assert cost.flops == 4.0  # tanh over 4 elements, via the call


def test_collective_accounting():
    hlo = """\
HloModule test

ENTRY %main (x: f32[16,1024]) -> f32[16,1024] {
  %x = f32[16,1024]{1,0} parameter(0)
  ROOT %ar = f32[16,1024]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
}
"""
    cost = analyze_hlo(hlo, n_partitions=256)
    sz = 16 * 1024 * 4
    assert cost.coll_operand_bytes["all-reduce"] == sz
    np.testing.assert_allclose(
        cost.coll_traffic_bytes["all-reduce"], 2 * sz * 15 / 16
    )
