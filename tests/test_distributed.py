"""Distribution-layer tests that need >1 device: run in a subprocess with
XLA_FLAGS forcing 8 host devices (the main test process must keep 1)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
    )
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_train_step_runs_sharded():
    """Real execution (not just compile) of the sharded train step on a
    4x2 mesh, MoR on, ZeRO-2 grads."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config, reduced
        from repro.core import TENSOR_MOR
        from repro.models import init_params
        from repro.models.common import use_mesh
        from repro.optim import AdamWConfig, init_opt_state
        from repro.sharding import rules
        from repro.train import TrainConfig, make_train_step

        cfg = dataclasses.replace(reduced(get_config('llama3-8b')),
                                  vocab=256, d_model=64, n_heads=4,
                                  n_kv=2, head_dim=16)
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        with use_mesh(mesh):
            params = init_params(cfg, jax.random.PRNGKey(0))
            pspec = rules.param_specs(cfg, params)
            params = jax.tree.map(
                lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
                params, pspec)
            opt = init_opt_state(params)
            step = jax.jit(make_train_step(
                cfg, TENSOR_MOR,
                TrainConfig(optimizer=AdamWConfig(total_steps=10),
                            grad_accum=2)))
            B, S = 8, 64
            batch = {
                'tokens': jax.device_put(
                    np.random.randint(0, 256, (B, S)).astype(np.int32),
                    NamedSharding(mesh, P('data'))),
                'labels': jax.device_put(
                    np.random.randint(0, 256, (B, S)).astype(np.int32),
                    NamedSharding(mesh, P('data'))),
            }
            p1, o1, m1 = step(params, opt, batch)
            p2, o2, m2 = step(p1, o1, batch)
            assert np.isfinite(float(m1['loss']))
            assert float(m2['loss']) < float(m1['loss']) + 1.0
            print('LOSS', float(m1['loss']), float(m2['loss']))
    """))


def test_grad_accum_matches_single_batch():
    """grad_accum=2 must match grad_accum=1 closely (same global batch)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config, reduced
        from repro.core import BF16_BASELINE
        from repro.models import init_params
        from repro.optim import AdamWConfig, init_opt_state
        from repro.train import TrainConfig, make_train_step

        cfg = dataclasses.replace(reduced(get_config('llama3-8b')),
                                  vocab=128)
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = {
            'tokens': jnp.asarray(
                np.random.RandomState(0).randint(0, 128, (8, 32)), jnp.int32),
            'labels': jnp.asarray(
                np.random.RandomState(1).randint(0, 128, (8, 32)), jnp.int32),
        }
        outs = []
        for accum in (1, 2):
            opt = init_opt_state(params)
            step = jax.jit(make_train_step(
                cfg, BF16_BASELINE,
                TrainConfig(optimizer=AdamWConfig(total_steps=10),
                            grad_accum=accum)))
            p, o, m = step(params, opt, batch)
            outs.append((float(m['loss']),
                         np.asarray(jax.tree.leaves(p)[0], np.float32)))
        # bf16 numerics differ with microbatch shape; ~0.5% is expected.
        assert abs(outs[0][0] - outs[1][0]) / outs[0][0] < 7e-3, (
            outs[0][0], outs[1][0])
        np.testing.assert_allclose(outs[0][1], outs[1][1], atol=5e-2)
        print('OK', outs[0][0], outs[1][0])
    """, devices=1)
    assert "OK" in out


def test_elastic_remesh_resume():
    """Checkpoint on an 8-device mesh, restore onto 4 devices."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses, tempfile
        from jax.sharding import NamedSharding
        from repro.checkpoint import Checkpointer
        from repro.configs import get_config, reduced
        from repro.models import init_params
        from repro.sharding import rules
        from repro.sharding.elastic import make_elastic_mesh, reshard_tree

        cfg = dataclasses.replace(reduced(get_config('llama3-8b')),
                                  vocab=256)
        params = init_params(cfg, jax.random.PRNGKey(0))
        mesh8 = jax.make_mesh((4, 2), ('data', 'model'))
        pspec = rules.param_specs(cfg, params)
        params8 = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh8, s)),
            params, pspec)
        d = tempfile.mkdtemp()
        ck = Checkpointer(d, async_save=False)
        ck.save(3, params8)
        # "failure": only 4 devices remain.
        mesh4 = make_elastic_mesh(jax.devices()[:4], prefer_model=2)
        restored = ck.restore(3, params)
        resharded = reshard_tree(restored, pspec, mesh4)
        a = np.asarray(jax.tree.leaves(params)[0], np.float32)
        b = np.asarray(jax.tree.leaves(resharded)[0], np.float32)
        np.testing.assert_array_equal(a, b)
        print('ELASTIC OK', mesh4.shape)
    """))


def test_fp8_compressed_pod_psum():
    """shard_map cross-pod FP8 all-gather sum matches plain psum ~1%."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import make_pod_compressed_psum

        mesh = jax.make_mesh((2, 4), ('pod', 'data'))
        g = jnp.asarray(np.random.RandomState(0).randn(2, 64, 64),
                        jnp.float32)

        psum_fp8 = make_pod_compressed_psum('pod')

        def f(gs):
            return psum_fp8(gs[0])

        if hasattr(jax, 'shard_map'):
            sm = jax.shard_map(f, mesh=mesh, in_specs=P('pod'),
                               out_specs=P(), check_vma=False)
        else:  # older jax: experimental API, check_rep kwarg
            from jax.experimental.shard_map import shard_map
            sm = shard_map(f, mesh=mesh, in_specs=P('pod'),
                           out_specs=P(), check_rep=False)

        out = jax.jit(sm)(g)
        ref = jnp.sum(g, axis=0)
        rel = np.abs(np.asarray(out) - np.asarray(ref)) / (
            np.abs(np.asarray(ref)) + 1e-3)
        assert np.median(rel) < 0.05, np.median(rel)
        # The compressed collective moves f8 payloads: check in HLO.
        hlo = jax.jit(sm).lower(g).compile().as_text()
        assert 'f8e4m3' in hlo and 'all-gather' in hlo
        print('COMPRESS OK', float(np.median(rel)))
    """))


def test_fp8_ef_tracks_uncompressed():
    """Error feedback keeps compressed-SGD close to uncompressed SGD."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.optim.compress import (compress_decompress_grads,
                                          ef_init)
        w_ref = jnp.ones(64); w_c = jnp.ones(64); w_nc = jnp.ones(64)
        tgt = jnp.asarray(np.random.RandomState(0).randn(64),
                          jnp.float32)
        ef = ef_init({'w': w_c})
        lr = 0.05
        for i in range(120):
            g = {'w': 2 * (w_ref - tgt)}
            w_ref = w_ref - lr * g['w']
            gq, ef = compress_decompress_grads(
                {'w': 2 * (w_c - tgt)}, 'fp8_ef', ef)
            w_c = w_c - lr * gq['w']
            gq2, _ = compress_decompress_grads(
                {'w': 2 * (w_nc - tgt)}, 'fp8')
            w_nc = w_nc - lr * gq2['w']
        err_ef = float(jnp.linalg.norm(w_c - w_ref))
        err_nc = float(jnp.linalg.norm(w_nc - w_ref))
        assert err_ef <= err_nc + 1e-6, (err_ef, err_nc)
        assert err_ef < 0.05
        print('EF OK', err_ef, err_nc)
    """, devices=1)
    assert "EF OK" in out
