"""Unit + property tests for GAM scaling (Algorithm 1)."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the 'hypothesis' test extra"
)
hnp = pytest.importorskip("hypothesis.extra.numpy")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    E4M3,
    E5M2,
    PER_BLOCK_128,
    PER_CHANNEL,
    PER_TENSOR,
    Partition,
    compute_scales,
    split_mantissa_exponent,
)
from repro.core.partition import block_amax


@pytest.fixture(autouse=True)
def _f32_numerics():
    # The GAM mantissa-split tables below assume f32 math; pin it per
    # test instead of mutating global config at import time (MOR004).
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", False)
    yield
    jax.config.update("jax_enable_x64", prev)


PARTS = [PER_TENSOR, PER_BLOCK_128, PER_CHANNEL, Partition("block", (64, 64)),
         Partition("subchannel", sub=32)]


def test_split_mantissa_exponent_roundtrip():
    s = jnp.array([1.0, 0.75, 448.0, 3.1e-5, 1e8, 2.0, 1.9999999], jnp.float32)
    m, e = split_mantissa_exponent(s)
    np.testing.assert_allclose(
        np.asarray(m) * np.exp2(np.asarray(e, np.float64)), np.asarray(s),
        rtol=1e-6,
    )
    assert np.all(np.asarray(m) >= 1.0) and np.all(np.asarray(m) < 2.0)


@pytest.mark.parametrize("part", PARTS)
@pytest.mark.parametrize("algo", ["gam", "e8m0", "fp32_amax"])
def test_no_saturation_invariant(part, algo):
    """block_amax * scale <= q_amax for every block (the Alg. 1 guarantee)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(
        rng.standard_normal((256, 384)) * np.exp(rng.uniform(-20, 20, (256, 384))),
        jnp.float32,
    )
    for fmt in (E4M3, E5M2):
        sc = compute_scales(x, part, fmt, algo=algo)
        bmax = block_amax(x, part)
        scaled = np.asarray(bmax) * np.asarray(sc.scale)
        assert np.all(scaled <= fmt.amax * (1 + 1e-6)), (
            f"{algo}/{fmt.name}: max scaled amax {scaled.max()}"
        )


def test_gam_shared_mantissa():
    """Every reconstructed block scale shares the group mantissa m_g."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    sc = compute_scales(x, PER_BLOCK_128, E4M3, algo="gam")
    m, _ = split_mantissa_exponent(sc.scale.reshape(-1))
    np.testing.assert_allclose(
        np.asarray(m), float(sc.group_mantissa), rtol=1e-6
    )


def test_group_amax_preserved_exactly():
    """Per-tensor GAM scale maps the tensor amax to exactly fmt.amax."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    sc = compute_scales(x, PER_TENSOR, E4M3, algo="gam")
    amax_scaled = float(sc.group_amax) * float(sc.scale[0, 0])
    # GAM preserves the full fp32 mantissa of s_g; per-tensor (single block)
    # the reconstruction equals s_g, so amax maps to q_amax exactly.
    np.testing.assert_allclose(amax_scaled, E4M3.amax, rtol=1e-6)


def test_exponent_clamp_edges_no_double_rounding():
    """Regression for the e8m0/gam clamp asymmetry: e_b was clipped to
    [-126, 126] while exp2i supports [-126, 127], so a tiny-amax block
    whose ideal exponent is 127 got its scale needlessly halved (double
    rounding). Both clamp edges must reconstruct exactly and keep the
    no-saturation invariant."""
    from repro.core.gam import exp2i, scales_from_bmax

    # exp2i is exact at both edges of the E8M0 domain.
    e = jnp.asarray([-126, 127], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(exp2i(e), np.float64), [2.0**-126, 2.0**127]
    )

    # Upper edge: bmax = 2^-119 gives ideal s_b = 448 * 2^119 ~ 2^127.8
    # -> e_b = 127 exactly (previously clipped to 126, halving the
    # scale and costing one bit of quantization precision for nothing).
    bmax = jnp.asarray([[2.0**-119, 1.0]], jnp.float32)
    for algo in ("e8m0", "gam"):
        sc = scales_from_bmax(bmax, E4M3, algo)
        assert int(np.asarray(sc.block_exp)[0, 0]) == 127, algo
        scale = np.asarray(sc.scale, np.float64)
        assert np.all(np.isfinite(scale)) and np.all(scale > 0)
        # No-saturation invariant holds at the clamp edge.
        scaled = np.asarray(bmax, np.float64) * scale
        assert np.all(scaled <= E4M3.amax * (1 + 1e-6)), (algo, scaled)
    # e8m0 now reconstructs the full-power scale (the double-rounding
    # fix): 2^127, not 2^126.
    sc = scales_from_bmax(bmax, E4M3, "e8m0")
    assert float(np.asarray(sc.scale)[0, 0]) == 2.0**127

    # Lower edge: the largest finite f32 bmax gives the most negative
    # ideal exponent reachable in-range; the invariant must hold there
    # too (the -126 clamp side is unreachable with finite f32 inputs
    # but exp2i's edge exactness above pins it).
    bmax_lo = jnp.asarray([[3.0e38]], jnp.float32)
    for fmt in (E4M3, E5M2):
        for algo in ("e8m0", "gam"):
            sc = scales_from_bmax(bmax_lo, fmt, algo)
            scaled = np.asarray(bmax_lo, np.float64) * np.asarray(
                sc.scale, np.float64
            )
            assert np.all(scaled <= fmt.amax * (1 + 1e-6)), (fmt.name, algo)


def test_zero_tensor_scales_are_finite():
    x = jnp.zeros((128, 128), jnp.float32)
    for algo in ("gam", "e8m0", "fp32_amax"):
        sc = compute_scales(x, PER_BLOCK_128, E4M3, algo=algo)
        assert np.all(np.isfinite(np.asarray(sc.scale)))


@hypothesis.settings(deadline=None, max_examples=25)
@hypothesis.given(
    data=hnp.arrays(
        np.float32,
        hnp.array_shapes(min_dims=2, max_dims=2, min_side=1, max_side=80),
        elements=st.floats(
            min_value=-(2.0**90), max_value=2.0**90, allow_nan=False, width=32
        ),
    ),
    algo=st.sampled_from(["gam", "e8m0"]),
    kind=st.sampled_from(["tensor", "block", "channel"]),
)
def test_property_no_saturation(data, algo, kind):
    part = Partition(kind, (32, 32))
    x = jnp.asarray(data)
    sc = compute_scales(x, part, E4M3, algo=algo)
    bmax = np.asarray(block_amax(x, part), np.float64)
    scale = np.asarray(sc.scale, np.float64)
    assert np.all(bmax * scale <= E4M3.amax * (1 + 1e-6))
    assert np.all(np.isfinite(scale)) and np.all(scale > 0)
