"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import E4M3, E5M2
from repro.core.gam import compute_scales
from repro.core.partition import Partition
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.fp8_gemm import fp8_gemm
from repro.kernels.gam_quant import gam_quant_blocks
from repro.kernels.ops import gam_quant


def _rand(shape, seed=0, scale=1.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# ------------------------------------------------------------- gam_quant --
@pytest.mark.parametrize("shape", [(128, 128), (256, 384), (512, 128)])
@pytest.mark.parametrize("block", [(128, 128), (64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("algo", ["gam", "e8m0", "fp32_amax"])
def test_gam_quant_kernel_matches_ref(shape, block, dtype, algo):
    if shape[0] % block[0] or shape[1] % block[1]:
        pytest.skip("kernel requires divisible shapes")
    # hash() of strings is randomized per process; derive seeds stably.
    x = _rand(shape, seed=sum(shape) + sum(block) + len(algo), scale=3.0,
              dtype=dtype)
    part = Partition("block", block)

    from repro.core.gam import split_mantissa_exponent

    g_amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    m_g, _ = split_mantissa_exponent(E4M3.amax / g_amax)
    if algo != "gam":
        m_g = jnp.float32(1.0)

    xq, exp, err, cnt = gam_quant_blocks(
        x, m_g, block=block, q_amax=E4M3.amax, fmt_dtype=E4M3.dtype,
        algo=algo, interpret=True,
    )
    rxq, rexp, rerr, rcnt = kref.gam_quant_ref(x, part, E4M3, algo)

    np.testing.assert_array_equal(
        np.asarray(xq, np.float32), np.asarray(rxq, np.float32)
    )
    np.testing.assert_array_equal(np.asarray(exp), np.asarray(rexp))
    np.testing.assert_allclose(
        np.asarray(err), np.asarray(rerr), rtol=2e-5, atol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(rcnt))


def test_gam_quant_no_saturation_property():
    """Kernel output, re-scaled, never exceeds the format amax."""
    for seed in range(3):
        x = _rand((256, 256), seed=seed, scale=10.0**seed)
        xq, exp, _, _ = gam_quant(
            x, block=(128, 128), backend="interpret"
        )
        assert np.all(np.isfinite(np.asarray(xq, np.float32)))


# -------------------------------------------------------------- fp8_gemm --
@pytest.mark.parametrize("mnk", [(128, 128, 128), (256, 128, 384),
                                 (128, 256, 256)])
def test_fp8_gemm_matches_ref(mnk):
    M, N, K = mnk
    block = (128, 128, 128)
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    part = Partition("block", (128, 128))
    sa = compute_scales(a, part, E4M3).scale
    sb = compute_scales(b, part, E4M3).scale

    def quantize(x, s, bm, bk):
        xb = x.reshape(x.shape[0] // bm, bm, x.shape[1] // bk, bk)
        xs = xb * s[:, None, :, None]
        return (
            jnp.clip(xs, -E4M3.amax, E4M3.amax)
            .astype(jnp.float8_e4m3fn)
            .reshape(x.shape)
        )

    aq = quantize(a, sa, 128, 128)
    bq = quantize(b, sb, 128, 128)

    out = fp8_gemm(aq, bq, sa, sb, block=block, out_dtype=jnp.float32,
                   interpret=True)
    ref = kref.fp8_gemm_ref(aq, bq, sa, sb, block, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-3
    )
    # And the dequantized GEMM approximates the f32 GEMM (fp8 fidelity).
    exact = np.asarray(a) @ np.asarray(b)
    rel = np.abs(np.asarray(out) - exact) / (np.abs(exact) + 1e-2)
    assert np.median(rel) < 0.1


# ------------------------------------------------------- flash_attention --
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 256, 64), (4, 512, 128),
                                   (1, 128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(causal, shape, dtype):
    BH, S, d = shape
    q = _rand((BH, S, d), seed=2, dtype=dtype)
    k = _rand((BH, S, d), seed=3, dtype=dtype)
    v = _rand((BH, S, d), seed=4, dtype=dtype)
    out = flash_attention_fwd(
        q, k, v, causal=causal, block_q=128, block_k=128, interpret=True
    )
    ref = kref.flash_attention_ref(q, k, v, causal)
    atol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=atol,
    )


def test_flash_attention_matches_model_attention():
    """Kernel vs the chunked-XLA model attention (same math, two impls)."""
    from repro.models.attention import flash_attention as xla_flash

    B, S, H, dh = 2, 256, 4, 64
    q = _rand((B, S, H, dh), seed=5, dtype=jnp.float32)
    k = _rand((B, S, H, dh), seed=6, dtype=jnp.float32)
    v = _rand((B, S, H, dh), seed=7, dtype=jnp.float32)
    out_xla = xla_flash(q, k, v, kind="causal", q_chunk=128, k_chunk=128)
    qf = jnp.moveaxis(q, 2, 1).reshape(B * H, S, dh)
    kf = jnp.moveaxis(k, 2, 1).reshape(B * H, S, dh)
    vf = jnp.moveaxis(v, 2, 1).reshape(B * H, S, dh)
    out_k = flash_attention_fwd(
        qf, kf, vf, causal=True, block_q=128, block_k=128, interpret=True
    )
    out_k = jnp.moveaxis(out_k.reshape(B, H, S, dh), 1, 2)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_xla), rtol=1e-4, atol=1e-4
    )
