"""Shared test substrate.

* Defaults REPRO_KERNEL_INTERPRET=1 (before any repro import) so
  backend='auto' resolves to Pallas interpret mode on CPU -- every test
  run exercises the real kernel bodies, not just the XLA references.
  Export REPRO_KERNEL_INTERPRET=0 to force the XLA lowering instead.
* Registers the ``slow`` marker; slow tests are skipped unless --runslow
  is passed, keeping tier-1 (`pytest -x -q`) to a few minutes.
* Provides fixed-seed PRNG helpers so tests are reproducible by default.
"""
import os

os.environ.setdefault("REPRO_KERNEL_INTERPRET", "1")

import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="also run tests marked @pytest.mark.slow",
    )


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test, skipped unless --runslow"
    )


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


@pytest.fixture
def rng():
    """Fixed-seed numpy Generator (seed 0)."""
    return np.random.default_rng(0)


@pytest.fixture
def make_rng():
    """Factory for seeded numpy Generators: make_rng(seed)."""
    return np.random.default_rng


@pytest.fixture
def rand():
    """rand(shape, seed=0, scale=1.0, dtype=f32) -> deterministic jnp array."""
    import jax.numpy as jnp

    def _rand(shape, seed=0, scale=1.0, dtype=jnp.float32):
        r = np.random.default_rng(seed)
        return jnp.asarray(r.standard_normal(shape) * scale, dtype)

    return _rand
