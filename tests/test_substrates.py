"""Substrate tests: data determinism, checkpoint round-trip + fault
tolerance, trainer resume, optimizer math, serving engine, QTensor path."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step
from repro.configs import get_config, reduced
from repro.core import MoRPolicy, TENSOR_MOR
from repro.data import DataConfig, SyntheticLM, prefetch
from repro.models import init_params
from repro.optim import AdamWConfig, adamw_update, cosine_lr, init_opt_state
from repro.serve import Engine, Request, ServeConfig, quantize_params
from repro.serve.quantized import quantize_weight
from repro.train import Trainer, TrainerConfig, TrainConfig


# ------------------------------------------------------------------ data --
def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, num_shards=2,
                     shard_id=0)
    a = SyntheticLM(cfg).batch_at(7)
    b = SyntheticLM(cfg).batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    other = SyntheticLM(dataclasses.replace(cfg, shard_id=1)).batch_at(7)
    assert not np.array_equal(a["tokens"], other["tokens"])
    # Labels are next-token shifted.
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_data_is_learnable_structure():
    cfg = DataConfig(vocab=64, seq_len=64, global_batch=4, order=1.0)
    b = SyntheticLM(cfg).batch_at(0)
    perm = SyntheticLM(cfg).perm
    np.testing.assert_array_equal(perm[b["tokens"]], b["labels"])


def test_prefetch_preserves_order():
    it = prefetch(iter(range(10)), depth=3)
    assert list(it) == list(range(10))


# ------------------------------------------------------------ checkpoint --
def test_checkpoint_roundtrip_and_keep(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for s in (10, 20, 30):
        ck.save(s, jax.tree.map(lambda x: x * s, tree))
    assert latest_step(str(tmp_path)) == 30
    assert not os.path.exists(tmp_path / "step_10")  # gc'd
    got = ck.restore(30, tree)
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.arange(6).reshape(2, 3) * 30)


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir must never be visible as a checkpoint."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(5, {"x": jnp.ones(3)})
    os.makedirs(tmp_path / "step_99.tmp")
    assert latest_step(str(tmp_path)) == 5


# -------------------------------------------------------------- optimizer --
def test_adamw_decreases_quadratic_loss():
    cfg = AdamWConfig(peak_lr=0.1, final_lr=0.1, warmup_steps=0,
                      total_steps=100, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0], jnp.bfloat16)}
    opt = init_opt_state(params)

    def loss(p):
        return jnp.sum(p["w"].astype(jnp.float32) ** 2)

    val = None
    for _ in range(50):
        g = jax.grad(loss)(jax.tree.map(lambda m: m.astype(jnp.bfloat16),
                                        opt.master))
        params, opt, _ = adamw_update(cfg, g, opt)
        val = loss(params)
    assert float(val) < 0.5


def test_cosine_lr_schedule():
    cfg = AdamWConfig(peak_lr=1.0, final_lr=0.1, warmup_steps=10,
                      total_steps=110)
    assert float(cosine_lr(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(cosine_lr(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(cosine_lr(cfg, jnp.asarray(110))) == pytest.approx(
        0.1, abs=1e-6
    )


# --------------------------------------------------------------- trainer --
def _tiny_trainer(tmp_path, total_steps, ckpt_every=5):
    cfg = dataclasses.replace(
        reduced(get_config("llama3-8b")), vocab=128
    )
    return Trainer(
        cfg,
        TENSOR_MOR,
        TrainConfig(optimizer=AdamWConfig(
            peak_lr=1e-3, final_lr=1e-4, warmup_steps=5, total_steps=200
        )),
        TrainerConfig(
            total_steps=total_steps, ckpt_dir=str(tmp_path),
            ckpt_every=ckpt_every, log_every=100,
        ),
        DataConfig(vocab=128, seq_len=32, global_batch=4),
    )


def test_trainer_runs_and_loss_drops(tmp_path):
    out = _tiny_trainer(tmp_path / "a", total_steps=30).run()
    losses = [h["loss"] for h in out["history"]]
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_trainer_restart_resumes_bitexact(tmp_path):
    d = tmp_path / "b"
    # Run 1: 10 steps (checkpoint at 5 and 10).
    r1 = _tiny_trainer(d, total_steps=10, ckpt_every=5).run()
    # Simulated failure: new trainer, same dir -> resumes from step 10.
    t2 = _tiny_trainer(d, total_steps=14, ckpt_every=5)
    r2 = t2.run()
    assert r2["history"][0]["step"] == 10
    # Reference: uninterrupted 14-step run.
    r3 = _tiny_trainer(tmp_path / "c", total_steps=14).run()
    l_resumed = [h["loss"] for h in r2["history"]]
    l_straight = [h["loss"] for h in r3["history"][10:]]
    # Checkpoint state round-trips bit-exactly; the residual tolerance is
    # XLA-CPU thread-pool reduction-order nondeterminism (order changes
    # under load), not resume error -- first resumed steps match exactly.
    np.testing.assert_allclose(l_resumed, l_straight, rtol=5e-4)


def test_trainer_straggler_watchdog(tmp_path):
    hits = []
    tr = _tiny_trainer(tmp_path / "d", total_steps=12)
    tr.straggler_cb = lambda step, ratio: hits.append((step, ratio))
    tr.run_cfg = dataclasses.replace(
        tr.run_cfg, straggler_factor=0.0  # every step is a "straggler"
    )
    tr.run()
    assert len(hits) > 0


# --------------------------------------------------------------- serving --
def test_engine_batched_decode():
    cfg = dataclasses.replace(reduced(get_config("gemma-2b")), vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, TENSOR_MOR, params, ServeConfig(slots=3, max_seq=64))
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, 128, 8).astype(np.int32), max_tokens=4)
        for i in range(5)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    for r in reqs:
        assert r.done and len(r.out) >= 4
        assert all(0 <= t < 128 for t in r.out)


def test_qtensor_weight_quantization():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    qt, st = quantize_weight(w, MoRPolicy(recipe="tensor"))
    assert qt.is_quantized and st["quantized"] == 1.0
    deq = np.asarray(qt.dequant(), np.float32)
    rel = np.abs(deq - np.asarray(w)) / (np.abs(np.asarray(w)) + 1e-6)
    assert np.median(rel) < 0.05
    # Wide-dynamic-range tensor falls back to BF16 storage.
    bad = jnp.asarray(
        np.exp2(rng.uniform(-30, 30, (256, 128))).astype(np.float32)
    )
    qt2, st2 = quantize_weight(bad, MoRPolicy(recipe="tensor"))
    assert not qt2.is_quantized and st2["quantized"] == 0.0


def test_quantize_params_tree():
    cfg = dataclasses.replace(reduced(get_config("llama3-8b")), vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(2))
    qparams, stats = quantize_params(
        params, MoRPolicy(recipe="tensor"), min_size=1024
    )
    assert len(stats) > 0
    frac_q = np.mean([s["quantized"] for s in stats.values()])
    assert frac_q > 0.9  # gaussian init weights all quantize


def test_engine_decode_with_quantized_weights():
    """The serving engine over sub-tensor QTensor weights: every matmul
    against a quantized leaf runs through the mixed-representation block
    GEMM, and greedy decode still completes."""
    cfg = dataclasses.replace(reduced(get_config("llama3-8b")), vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(
        cfg, TENSOR_MOR, params, ServeConfig(slots=2, max_seq=64),
        quantize=MoRPolicy(recipe="sub3"), quantize_min_size=1024,
    )
    assert eng.qstats and any(
        s["quantized"] for s in eng.qstats.values()
    ), eng.qstats
    # The layer-stacked block weights must be covered, not just lm_head.
    assert any("blocks/" in name for name in eng.qstats), eng.qstats
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, 128, 8).astype(np.int32), max_tokens=4)
        for i in range(3)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    for r in reqs:
        assert r.done and len(r.out) >= 4
        assert all(0 <= t < 128 for t in r.out)


def test_train_step_with_fused_mixed_gemm():
    """A full jitted train step (scan over layers, remat, custom_vjp,
    ZeRO-2 constraints) with every GEMM routed through the mixed-
    representation kernel: finite loss, stats populated."""
    from repro.core import paper_default
    from repro.data import SyntheticLM
    from repro.optim import init_opt_state
    from repro.train import make_train_step

    cfg = dataclasses.replace(
        reduced(get_config("llama3-8b")), vocab=128
    )
    pol = paper_default("sub3")
    pol = pol.replace(
        act=pol.act.replace(backend="xla"),
        weight=pol.weight.replace(backend="xla"),
        grad=pol.grad.replace(backend="xla"),
        fuse_gemm=True,
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(
        cfg, pol,
        TrainConfig(optimizer=AdamWConfig(
            peak_lr=1e-3, final_lr=1e-4, warmup_steps=2, total_steps=10
        )),
    ))
    data = SyntheticLM(DataConfig(vocab=128, seq_len=32, global_batch=4))
    batch = jax.tree.map(jnp.asarray, data.batch_at(0))
    params, opt, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    # The mixed path must still report MoR decisions.
    assert float(m["fwd_rel_err"]) > 0.0


# ------------------------------------------------------------ mor stats --
def test_summarize_mor_stats_uses_stats_width():
    """Regression: train_step's stats-leaf filter must track STATS_WIDTH
    (it used to hard-code 8 and would silently drop every stats row if
    the layout grew)."""
    from repro.core import STATS_WIDTH
    from repro.train.train_step import summarize_mor_stats

    row = np.zeros((3, STATS_WIDTH), np.float32)
    row[:, 5] = 0.5  # frac_bf16
    row[:, 1] = 0.25  # rel_err
    fwd = {"layer": jnp.asarray(row)}
    # Decoys with a non-STATS_WIDTH trailing dim must be ignored.
    bwd = {
        "stats": jnp.asarray(row),
        "decoy": jnp.ones((4, STATS_WIDTH + 1), jnp.float32),
    }
    out = summarize_mor_stats(fwd, bwd)
    assert float(out["fwd_frac_bf16"]) == pytest.approx(0.5)
    assert float(out["fwd_rel_err"]) == pytest.approx(0.25)
    assert float(out["bwd_frac_bf16"]) == pytest.approx(0.5)
