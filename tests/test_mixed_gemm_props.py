"""Hypothesis property sweeps for the mixed-representation block GEMM.

Kept in their own module so the whole-module ``importorskip`` guard
(conftest convention: hypothesis is an optional test extra; a missing
import must collect as a skip, not an error) only removes the property
sweeps -- the deterministic differential suite lives in
``test_mixed_gemm.py``.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the 'hypothesis' test extra"
)
st = pytest.importorskip("hypothesis.strategies")

from repro.core import MoRPolicy, mor_quantize
from repro.core.mor import quantize_for_gemm
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.ref import pack_mixed


def _rand(shape, seed=0, scale=1.0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


def _pack(shape, seed, dtype, block=64, scale=2.0):
    x = _rand(shape, seed=seed, scale=scale, dtype=dtype)
    br = min(block, shape[0])
    bk = min(block, shape[1])
    nr, nk = -(-shape[0] // br), -(-shape[1] // bk)
    tags = jnp.asarray(
        np.random.default_rng(seed).integers(0, 3, (nr, nk)), jnp.int32
    )
    return pack_mixed(x, tags, (br, bk), "gam")


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(
    m=st.integers(8, 140),
    n=st.integers(8, 140),
    k=st.integers(8, 300),
    seed=st.integers(0, 2**16),
    dtype=st.sampled_from(["f32", "bf16"]),
    scale_exp=st.integers(-3, 3),
)
def test_property_backends_agree(m, n, k, seed, dtype, scale_exp):
    """Random shapes / tags / magnitudes: interpret == ref == xla,
    bit-exact."""
    dt = jnp.float32 if dtype == "f32" else jnp.bfloat16
    a = _pack((m, k), seed, dt, scale=10.0 ** scale_exp)
    b = _pack((n, k), seed + 1, dt, scale=10.0 ** scale_exp)
    got = kops.mixed_gemm(a, b, out_dtype=jnp.float32, backend="interpret")
    want = kref.mixed_gemm_ref(a, b, jnp.float32)
    assert got.shape == (m, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@hypothesis.settings(deadline=None, max_examples=15)
@hypothesis.given(
    seed=st.integers(0, 2**16),
    recipe=st.sampled_from(["tensor", "sub2", "sub3", "e4m3"]),
)
def test_property_decode_pack_roundtrip(seed, recipe):
    """pack -> decode reproduces the fake-quant output bit-for-bit for
    every recipe's block decisions."""
    x = _rand((128, 256), seed=seed, scale=3.0, dtype=jnp.bfloat16)
    pol = MoRPolicy(recipe=recipe, partition="block", backend="xla")
    mo, _ = quantize_for_gemm(x, pol)
    y, _ = mor_quantize(x, pol)
    np.testing.assert_array_equal(
        np.asarray(mo.dequant(), np.float32), np.asarray(y, np.float32)
    )
