"""benchmarks/compare.py: the perf-regression comparator (stdlib-only,
like the schema validator it builds on)."""
import copy
import json

import pytest

from benchmarks.compare import (
    COUNTER_KEYS,
    MIN_COUNTER_KEYS,
    compare_artifacts,
    main,
    parse_derived,
)
from benchmarks.schema import SCHEMA, make_artifact

BASE_CSV = [
    "kernel/quantize_pack_fused_sub3_1024x1024,100.0,"
    "tpu_kernel_launches=1;tpu_pack_ops=0;us_twopass_xla=120.0",
    "kernel/gemm_mixed_pallas_512x512x512,0.0,"
    "tpu_kernel_launches=1;legacy_operand_passes=6",
    "kernel/sub3_fused_xla_1024x1024,200.0,"
    "hbm_bytes=86028876;operand_passes=33;speedup=1.34x",
]


def _artifact(csv_rows):
    return make_artifact(csv_rows)


def test_parse_derived():
    d = parse_derived("a=1;b=two;speedup=1.34x;;c=")
    assert d == {"a": "1", "b": "two", "speedup": "1.34x", "c": ""}


def test_identical_artifacts_clean():
    base = _artifact(BASE_CSV)
    regs, notes = compare_artifacts(base, copy.deepcopy(base))
    assert regs == [] and notes == []


def test_count_regression_flagged_at_zero_threshold():
    base = _artifact(BASE_CSV)
    cur = copy.deepcopy(base)
    cur["rows"][0]["derived"] = (
        "tpu_kernel_launches=2;tpu_pack_ops=5;us_twopass_xla=120.0"
    )
    regs, _ = compare_artifacts(base, cur)
    assert len(regs) == 2  # both counters grew
    assert any("tpu_kernel_launches 2" in r for r in regs)
    assert any("tpu_pack_ops 5" in r for r in regs)


def test_count_improvement_is_a_note_not_a_regression():
    base = _artifact(BASE_CSV)
    cur = copy.deepcopy(base)
    cur["rows"][2]["derived"] = (
        "hbm_bytes=86028876;operand_passes=3;speedup=10x"
    )
    regs, notes = compare_artifacts(base, cur)
    assert regs == []
    assert any("operand_passes 3" in n for n in notes)


def test_time_regression_needs_ratio_and_absolute_floor():
    base = _artifact(BASE_CSV)
    cur = copy.deepcopy(base)
    # 5x on a 100us row: above the default 2.0 ratio and the 200us
    # floor -> flagged; suppressed when the floor exceeds the delta.
    cur["rows"][0]["us"] = 500.0
    regs, _ = compare_artifacts(base, cur)
    assert any(r.startswith("TIME") for r in regs)
    regs, _ = compare_artifacts(base, cur, min_us=500.0)
    assert regs == []
    # Ratio below threshold: never flagged however large the delta.
    cur["rows"][0]["us"] = 180.0
    regs, _ = compare_artifacts(base, cur)
    assert regs == []


def test_interp_and_sharded_lanes_exempt_from_time_check():
    """Interpreter/subprocess wall clocks swing >2x run to run; their
    rows compare on counts only (unless time_all) so the advisory gate
    is not red on every rerun."""
    base = _artifact([
        "kernel/mor_select_interp_512,2952.8,mode=interpret",
        "kernel/gemm_sharded_row_data4_512x512x512,1360.8,"
        "devices=4;per_shard_tpu_kernel_launches=1",
    ])
    cur = copy.deepcopy(base)
    cur["rows"][0]["us"] = 9000.0
    cur["rows"][1]["us"] = 9000.0
    assert compare_artifacts(base, cur) == ([], [])
    regs, _ = compare_artifacts(base, cur, time_all=True)
    assert len(regs) == 2
    # Count regressions still flag on exempt lanes.
    cur["rows"][1]["derived"] = "devices=4;per_shard_tpu_kernel_launches=2"
    regs, _ = compare_artifacts(base, cur)
    assert len(regs) == 1 and "per_shard_tpu_kernel_launches" in regs[0]


def test_missing_row_flagged_new_row_noted():
    base = _artifact(BASE_CSV)
    cur = _artifact(BASE_CSV[:2] + [
        "kernel/brand_new_lane,1.0,tpu_kernel_launches=1",
    ])
    regs, notes = compare_artifacts(base, cur)
    assert any("MISSING" in r and "sub3_fused_xla" in r for r in regs)
    assert any("new row" in n and "brand_new_lane" in n for n in notes)


def test_negative_sentinel_counters_skipped():
    """-1 means 'lane unavailable on this host' (e.g. no cross-platform
    lowering); it must compare as neither regression nor improvement."""
    base = _artifact(["kernel/x,1.0,tpu_kernel_launches=-1"])
    cur = _artifact(["kernel/x,1.0,tpu_kernel_launches=1"])
    assert compare_artifacts(base, cur) == ([], [])
    assert compare_artifacts(cur, base) == ([], [])


def test_counter_keys_cover_the_bench_contract():
    for key in ("operand_passes", "tpu_kernel_launches", "tpu_pack_ops",
                "contract_violations"):
        assert key in COUNTER_KEYS
    for key in ("contracts_checked", "contract_rules_evaluated"):
        assert key in MIN_COUNTER_KEYS


_ANALYSIS_ROW = (
    "kernel/analysis_contracts,0.0,"
    "contracts_checked={c};contract_rules_evaluated={r};"
    "contract_violations={v}"
)


def test_coverage_counters_gate_shrink_not_growth():
    """contracts_checked/rules_evaluated regress when they DECREASE (a
    registered contract silently vanished); growth is only a note."""
    base = _artifact([_ANALYSIS_ROW.format(c=13, r=39, v=0)])
    fewer = _artifact([_ANALYSIS_ROW.format(c=12, r=36, v=0)])
    regs, notes = compare_artifacts(base, fewer)
    assert any("COVERAGE" in r and "contracts_checked" in r for r in regs)
    assert any("COVERAGE" in r and "contract_rules_evaluated" in r
               for r in regs)
    regs, notes = compare_artifacts(fewer, base)
    assert regs == []
    assert any("grew" in n and "contracts_checked" in n for n in notes)


def test_contract_violations_gate_at_zero():
    base = _artifact([_ANALYSIS_ROW.format(c=13, r=39, v=0)])
    red = _artifact([_ANALYSIS_ROW.format(c=13, r=39, v=1)])
    regs, _ = compare_artifacts(base, red)
    assert any("COUNT" in r and "contract_violations" in r for r in regs)


def test_main_exit_codes(tmp_path):
    base = _artifact(BASE_CSV)
    cur = copy.deepcopy(base)
    pb, pc = tmp_path / "base.json", tmp_path / "cur.json"
    pb.write_text(json.dumps(base))
    pc.write_text(json.dumps(cur))
    assert main([str(pb), str(pc)]) == 0
    cur["rows"][1]["derived"] = "tpu_kernel_launches=3"
    pc.write_text(json.dumps(cur))
    assert main([str(pb), str(pc)]) == 1
    assert main([str(pb), str(tmp_path / "nope.json")]) == 2
    pc.write_text(json.dumps({"schema": "bogus", "rows": []}))
    assert main([str(pb), str(pc)]) == 2


def test_checked_in_baseline_validates_and_self_compares():
    """The committed BENCH_baseline.json must conform to the frozen
    schema and compare clean against itself -- the starting point of
    the perf trajectory."""
    import os

    path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "benchmarks", "BENCH_baseline.json",
    )
    if not os.path.exists(path):
        pytest.fail("benchmarks/BENCH_baseline.json is not checked in")
    with open(path) as f:
        doc = json.load(f)
    from benchmarks.schema import validate_artifact

    assert doc["schema"] == SCHEMA
    validate_artifact(doc)
    regs, notes = compare_artifacts(doc, copy.deepcopy(doc))
    assert regs == [] and notes == []
    names = {r["name"] for r in doc["rows"]}
    # The lanes this PR's acceptance criteria name must be present.
    assert any(n.startswith("kernel/quantize_pack_fused_") for n in names)
    assert any(n.startswith("kernel/quantize_pack_twopass_")
               for n in names)
    assert any(n.startswith("kernel/gemm_autotune_") for n in names)
    assert any(n.startswith("kernel/gemm_decode_reuse_") for n in names)
    assert "kernel/analysis_contracts" in names
