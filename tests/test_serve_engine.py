"""Serving-engine regression suite: per-slot positions, the paged KV
pool, chunked prefill, admission/termination edges.

The anchor test pins the batched continuous-batching engine
token-for-token against a *dense sequential* reference -- one request
at a time through make_prefill_fn/make_decode_fn with a plain
init_cache, no engine code involved -- across staggered prompt lengths
and mid-stream admissions. The witness test reproduces the pre-paged
engine's shared ``cur = max(slot_pos)`` decode on the same traffic and
shows it diverges, which is why that engine corrupted mixed-length
batches.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import TENSOR_MOR
from repro.models import (
    init_cache,
    init_params,
    make_decode_fn,
    make_prefill_fn,
    make_tokens,
)
from repro.serve import (
    Engine,
    PagedKVPool,
    PromptTooLongError,
    Request,
    ServeConfig,
)


@pytest.fixture(scope="module")
def dense_model():
    cfg = dataclasses.replace(reduced(get_config("gemma-2b")), vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _splice_b1(full, part):
    """Pad a (n_units, 1, P, ...) prefill leaf out to the cache seq."""
    if full.ndim >= 4 and part.ndim == full.ndim and \
            full.shape[2] != part.shape[2]:
        part = jax.lax.dynamic_update_slice_in_dim(
            jnp.zeros((part.shape[0], 1, full.shape[2], *part.shape[3:]),
                      full.dtype),
            part.astype(full.dtype), 0, axis=2,
        )
    return part.astype(full.dtype)


def _sequential_reference(cfg, params, prompt, n_tokens, max_seq):
    """Greedy-generate one request through the dense B=1 prefill+decode
    path -- the oracle the batched paged engine must reproduce."""
    toks = make_tokens(cfg)
    prefill = jax.jit(make_prefill_fn(cfg, TENSOR_MOR))
    decode = jax.jit(make_decode_fn(cfg, TENSOR_MOR))
    cache = init_cache(cfg, 1, max_seq)
    logits, pc, _ = prefill(
        params, toks, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
    )
    cache = jax.tree.map(_splice_b1, cache, pc)
    out = [int(jnp.argmax(logits[0, -1, : cfg.vocab]))]
    pos = len(prompt)
    while len(out) < n_tokens and pos < max_seq:
        lg, cache, _ = decode(
            params, toks, cache,
            jnp.asarray([[out[-1]]], jnp.int32),
            jnp.asarray([pos], jnp.int32),
        )
        out.append(int(jnp.argmax(lg[0, 0, : cfg.vocab])))
        pos += 1
    return out


# ----------------------------------------------------- headline bugfix --
def test_mixed_length_batched_matches_sequential(dense_model):
    """Staggered prompt lengths + admissions mid-stream: the batched
    paged engine is token-identical to the one-request-at-a-time dense
    reference. (Fails on the pre-paged engine, whose shared
    max(slot_pos) wrote short slots' KV past their true position.)"""
    cfg, params = dense_model
    max_seq, n_tok = 64, 5
    rng = np.random.default_rng(7)
    lengths = [3, 17, 9, 26, 5, 12]  # deliberately staggered
    prompts = [rng.integers(0, cfg.vocab, L).astype(np.int32)
               for L in lengths]
    refs = [_sequential_reference(cfg, params, p, n_tok, max_seq)
            for p in prompts]

    eng = Engine(cfg, TENSOR_MOR, params,
                 ServeConfig(slots=3, max_seq=max_seq, page_size=16,
                             prefill_chunk=8))
    reqs = [Request(i, p, max_tokens=n_tok) for i, p in enumerate(prompts)]
    # Mid-stream admission: 6 requests > 3 slots, plus two submitted
    # only after the engine has started stepping.
    for r in reqs[:4]:
        eng.submit(r)
    steps = 0
    while eng.step() and steps < 200:
        steps += 1
        if steps == 3:
            eng.submit(reqs[4])
        if steps == 5:
            eng.submit(reqs[5])
    for r, ref in zip(reqs, refs):
        assert r.done and r.error is None
        assert r.out == ref, (
            f"req {r.rid} (P={len(r.prompt)}): {r.out} != {ref}"
        )


def test_shared_cur_index_decode_diverges(dense_model):
    """Witness for the headline bug: replaying the old engine's decode
    -- one shared cur = max(slot_pos) for a staggered batch -- produces
    different logits than per-slot positions, because the short slot's
    KV lands past its true position and the zero-filled hole is scored
    (exp(0) = 1 takes real softmax mass). This is what the anchor test
    would have caught on the pre-paged engine."""
    cfg, params = dense_model
    max_seq = 32
    toks = make_tokens(cfg)
    prefill = jax.jit(make_prefill_fn(cfg, TENSOR_MOR))
    decode = jax.jit(make_decode_fn(cfg, TENSOR_MOR))
    rng = np.random.default_rng(3)
    p_short = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    p_long = rng.integers(0, cfg.vocab, 20).astype(np.int32)

    cache = init_cache(cfg, 2, max_seq)
    nxt, pos = [], []
    for b, p in enumerate((p_short, p_long)):
        lg, pc, _ = prefill(
            params, toks, {"tokens": jnp.asarray(p, jnp.int32)[None]}
        )
        cache = jax.tree.map(
            lambda full, part, b=b: jax.lax.dynamic_update_slice_in_dim(
                full, _splice_b1(full, part), b, axis=1
            ),
            cache, pc,
        )
        nxt.append(int(jnp.argmax(lg[0, -1, : cfg.vocab])))
        pos.append(len(p))

    tok = jnp.asarray(nxt, jnp.int32)[:, None]
    lg_vec, _, _ = decode(
        params, toks, cache, tok, jnp.asarray(pos, jnp.int32)
    )
    lg_old, _, _ = decode(
        params, toks, cache, tok, jnp.asarray(max(pos), jnp.int32)
    )
    short = np.asarray(lg_vec[0, 0, : cfg.vocab])
    short_old = np.asarray(lg_old[0, 0, : cfg.vocab])
    assert not np.allclose(short, short_old, atol=1e-3), (
        "shared-max cur_index reproduced the per-slot logits; the "
        "witness lost its teeth"
    )
    # The long slot sits AT the shared position, so it agrees -- up to
    # cross-trace compilation noise: the two decode calls jit-compile
    # different programs (vector vs scalar cur_index), and XLA's float
    # reassociation between them varies with the process hash seed
    # (observed up to ~2e-3 across PYTHONHASHSEED values). 5e-3 clears
    # that noise while staying ~4x below the short slot's real
    # divergence (~2e-2). This is the repo's one known remaining
    # hash-seed sensitivity, carried on the lint allowlist:
    # docs/analysis.md#allowlist.
    np.testing.assert_allclose(
        np.asarray(lg_vec[1, 0, : cfg.vocab]),
        np.asarray(lg_old[1, 0, : cfg.vocab]), atol=5e-3,
    )


def test_chunked_prefill_spans_many_pages(dense_model):
    """A prompt much longer than both the chunk and the page still
    matches the dense reference (chunk padding is overwritten
    position-by-position before it is ever attended)."""
    cfg, params = dense_model
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab, 41).astype(np.int32)
    ref = _sequential_reference(cfg, params, prompt, 4, 64)
    eng = Engine(cfg, TENSOR_MOR, params,
                 ServeConfig(slots=2, max_seq=64, page_size=8,
                             prefill_chunk=16))
    req = Request(0, prompt, max_tokens=4)
    eng.submit(req)
    eng.run_to_completion()
    assert req.done and req.out == ref
    assert eng.prefill_chunks == 3  # ceil(41 / 16), never re-prefilled


# ------------------------------------------------ admission/termination --
def test_admission_guard_boundaries(dense_model):
    cfg, params = dense_model
    max_seq = 32
    eng = Engine(cfg, TENSOR_MOR, params,
                 ServeConfig(slots=1, max_seq=max_seq, prefill_chunk=8))
    ok = Request(0, np.arange(max_seq - 1) % cfg.vocab, max_tokens=2)
    eng.submit(ok)  # P == max_seq - 1: last admissible prompt
    with pytest.raises(PromptTooLongError):
        eng.submit(Request(1, np.arange(max_seq) % cfg.vocab))
    eng.run_to_completion()
    assert ok.done and len(ok.out) == 2 and ok.error is None

    # Truncate mode: clipped, surfaced, still completes.
    eng2 = Engine(cfg, TENSOR_MOR, params,
                  ServeConfig(slots=1, max_seq=max_seq, prefill_chunk=8,
                              on_long_prompt="truncate"))
    long_req = Request(2, np.arange(max_seq + 5) % cfg.vocab, max_tokens=2)
    eng2.submit(long_req)
    assert len(long_req.prompt) == max_seq - 1
    assert long_req.error and "truncated" in long_req.error
    eng2.run_to_completion()
    assert long_req.done and len(long_req.out) == 2


def test_termination_edges(dense_model):
    cfg, params = dense_model
    eng = Engine(cfg, TENSOR_MOR, params,
                 ServeConfig(slots=2, max_seq=32, prefill_chunk=8))
    # max_tokens=1: the prefill-sampled token is the whole budget -- no
    # decode step may run for this request.
    one = Request(0, np.arange(5, dtype=np.int32), max_tokens=1)
    # Cache-bound: position max_seq - 1 is usable, so the request gets
    # one prefill-sampled token + (max_seq - P) decoded tokens.
    fill = Request(1, np.arange(28, dtype=np.int32), max_tokens=1000)
    eng.submit(one)
    eng.submit(fill)
    eng.run_to_completion()
    assert one.done and len(one.out) == 1
    assert fill.done and len(fill.out) == 32 - 28 + 1


def test_run_to_completion_reports_unfinished(dense_model):
    cfg, params = dense_model
    eng = Engine(cfg, TENSOR_MOR, params,
                 ServeConfig(slots=1, max_seq=32, prefill_chunk=8))
    a = Request(0, np.arange(4, dtype=np.int32), max_tokens=20)
    b = Request(1, np.arange(4, dtype=np.int32), max_tokens=20)
    eng.submit(a)
    eng.submit(b)
    steps = eng.run_to_completion(max_steps=3)
    assert steps == 3
    assert not a.done and not b.done
    assert a in eng.unfinished and b in eng.unfinished
    assert a.error and "unfinished" in a.error
    # Draining afterwards clears the report.
    eng.run_to_completion()
    assert a.done and b.done and not eng.unfinished


def test_sampling_params_reproducible(dense_model):
    cfg, params = dense_model
    scfg = ServeConfig(slots=1, max_seq=32, prefill_chunk=8)

    def run(seed, temperature):
        eng = Engine(cfg, TENSOR_MOR, params, scfg)
        r = Request(0, np.arange(6, dtype=np.int32), max_tokens=6,
                    temperature=temperature, top_k=8, seed=seed)
        eng.submit(r)
        eng.run_to_completion()
        return r.out

    assert run(1, 1.0) == run(1, 1.0)  # same seed: reproducible
    outs = {tuple(run(s, 1.0)) for s in range(4)}
    assert len(outs) > 1  # temperature actually samples


# ------------------------------------------------------------ the pool --
def test_paged_pool_alloc_release_reuse(dense_model):
    cfg, _ = dense_model
    pool = PagedKVPool(cfg, slots=2, max_seq=64, page_size=16)
    assert pool.n_pages == 2 * 4 and pool.free_pages() == 8
    assert pool.alloc(0, 40)  # 3 pages
    assert pool.free_pages() == 5
    assert (pool.block_table[0, :3] != pool.trash).all()
    assert (pool.block_table[0, 3:] == pool.trash).all()
    assert pool.alloc(0, 40)  # idempotent: already covered
    assert pool.free_pages() == 5
    taken = list(pool.block_table[0, :3])
    pool.release(0)
    assert pool.free_pages() == 8
    assert (pool.block_table[0] == pool.trash).all()
    # Freed pages recycle (FIFO: they rejoin at the back of the list,
    # so the second full-sequence alloc drains down to them).
    assert pool.alloc(1, 64)
    assert pool.alloc(0, 64)
    assert pool.free_pages() == 0
    assert set(taken) <= set(pool.block_table[0])

    with pytest.raises(ValueError, match="MoR-block aligned"):
        PagedKVPool(cfg, slots=1, max_seq=96, page_size=48)
    with pytest.raises(ValueError, match="divide max_seq"):
        PagedKVPool(cfg, slots=1, max_seq=64, page_size=24)


def test_oversubscribed_pool_queues_and_completes(dense_model):
    """pool_pages < slots * pages_per_seq: admission waits on the free
    list instead of failing, and every request still finishes
    correctly."""
    cfg, params = dense_model
    max_seq, n_tok = 64, 4
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, L).astype(np.int32)
               for L in (30, 21, 26)]
    refs = [_sequential_reference(cfg, params, p, n_tok, max_seq)
            for p in prompts]
    # 4 slots x 4 pages/seq would be 16; give the pool 6 (+ trash).
    eng = Engine(cfg, TENSOR_MOR, params,
                 ServeConfig(slots=4, max_seq=max_seq, page_size=16,
                             prefill_chunk=16, pool_pages=6))
    reqs = [Request(i, p, max_tokens=n_tok)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    for r, ref in zip(reqs, refs):
        assert r.done and r.out == ref
    assert eng.pool.free_pages() == 6  # everything returned


def test_kv_fp8_paged_engine_smoke(dense_model):
    cfg, params = dense_model
    eng = Engine(cfg, TENSOR_MOR, params,
                 ServeConfig(slots=2, max_seq=32, page_size=8,
                             prefill_chunk=8, kv_fp8=True))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, 6 + 7 * i).astype(
        np.int32), max_tokens=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    for r in reqs:
        assert r.done and len(r.out) == 4
        assert all(0 <= t < cfg.vocab for t in r.out)


def _run_engine(cfg, params, prompts, n_tok, **scfg_kw):
    kw = dict(slots=2, max_seq=64, page_size=8, prefill_chunk=8)
    kw.update(scfg_kw)
    eng = Engine(cfg, TENSOR_MOR, params, ServeConfig(**kw))
    reqs = [Request(i, p, max_tokens=n_tok) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    for r in reqs:
        assert r.done and r.error is None, (r.rid, r.error)
    return [r.out for r in reqs], eng


def test_kv_mor_paged_engine_matches_bf16_engine(dense_model):
    """Decode served from MoR-packed KV pages (uint8 payload + tag +
    scale lanes, gather/scatter moving packed bytes) is token-for-token
    against the bf16-cache engine on staggered mixed-length traffic."""
    cfg, params = dense_model
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, L).astype(np.int32)
               for L in (3, 17, 9, 26)]
    ref, eng_b = _run_engine(cfg, params, prompts, 6)
    out, eng_m = _run_engine(cfg, params, prompts, 6, kv_mor=True)
    assert out == ref
    # The MoR pool's per-position gather/scatter bytes beat bf16's.
    assert eng_m.pool.bytes_per_token() < eng_b.pool.bytes_per_token()
    assert eng_m.pool.free_pages() == eng_b.pool.free_pages()


def test_kv_mor_cold_sealing_recompresses_and_stays_exact(dense_model):
    """With the cold-page policy on, pages behind the write frontier
    are sub4-recompressed mid-stream (visible as NVFP4 tags in the
    cache census) and generation still matches the bf16 engine."""
    cfg, params = dense_model
    rng = np.random.default_rng(12)
    prompt = rng.integers(0, cfg.vocab, 10).astype(np.int32)
    ref, _ = _run_engine(cfg, params, [prompt], 24, slots=1)

    eng = Engine(cfg, TENSOR_MOR, params,
                 ServeConfig(slots=1, max_seq=64, page_size=8,
                             prefill_chunk=8, kv_mor=True, kv_mor_cold=2))
    r = Request(0, prompt, max_tokens=24)
    eng.submit(r)
    saw_cold = 0.0
    steps = 0
    while eng.step() and steps < 200:
        steps += 1
        st = eng.kv_cache_stats()
        if st.get("written"):
            saw_cold = max(saw_cold, st["frac_nvfp4"])
    assert r.done and r.out == ref[0]
    assert saw_cold > 0.5, "cold sealing never recompressed a page"
    assert not eng._sealed  # cleared when the slot finished
    assert eng.pool.free_pages() == eng.pool.n_pages


def test_kv_mor_config_validation(dense_model):
    cfg, params = dense_model
    with pytest.raises(ValueError, match="mutually exclusive"):
        Engine(cfg, TENSOR_MOR, params,
               ServeConfig(slots=1, max_seq=32, page_size=8,
                           kv_fp8=True, kv_mor=True))
    with pytest.raises(ValueError, match="kv_mor_cold"):
        Engine(cfg, TENSOR_MOR, params,
               ServeConfig(slots=1, max_seq=32, page_size=8,
                           kv_mor_cold=4))


# ------------------------------------------- recurrent-state fallback --
def test_hybrid_family_fallback_matches_sequential():
    """Hymba (attention + SSM state) can't chunk its prefill; the
    one-shot fallback must still match the dense sequential reference
    under mixed prompt lengths."""
    cfg = dataclasses.replace(reduced(get_config("hymba-1.5b")), vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(1))
    max_seq, n_tok = 32, 3
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, L).astype(np.int32)
               for L in (4, 13)]
    refs = [_sequential_reference(cfg, params, p, n_tok, max_seq)
            for p in prompts]
    eng = Engine(cfg, TENSOR_MOR, params,
                 ServeConfig(slots=2, max_seq=max_seq, page_size=8,
                             prefill_chunk=8))
    assert not eng.chunked_prefill  # state leaves force the fallback
    reqs = [Request(i, p, max_tokens=n_tok)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_to_completion()
    for r, ref in zip(reqs, refs):
        assert r.done and r.out == ref


# -------------------------------------------- structural contract --
def test_decode_step_contract(dense_model):
    """This engine's jitted decode step satisfies the registry's
    ``engine_decode_step`` contract (repro.analysis.contracts): no
    host round-trips inside the step, the KV pool buffers donated, no
    f64, and the quantized weights' payload lanes consumed only by
    sanctioned decode sites -- the same rules CI's lint job and the
    bench sweep evaluate on the registry's own probe engine."""
    from repro.analysis import engine_decode_report
    from repro.core import MoRPolicy

    cfg, params = dense_model
    eng = Engine(
        cfg, TENSOR_MOR, params,
        ServeConfig(slots=4, max_seq=64, page_size=16, kv_mor=True),
        quantize=MoRPolicy(recipe="sub3", backend="interpret"),
        quantize_min_size=0,
    )
    report = engine_decode_report(eng)
    assert report.ok, report.render()
    assert report.counters["donated_args"] >= 1
    assert report.counters["tainted_lanes"] > 0  # QTensor lanes seeded


# ---------------------------------------- unsatisfiable admission --
def test_unsatisfiable_reservation_rejected_not_starved(dense_model):
    """A request whose worst-case reservation exceeds the *total* pool
    can never be admitted -- no amount of eviction frees enough pages.
    Pre-fix, it sat at the queue head forever and starved everything
    behind it; now it is rejected with the condition surfaced and the
    queue behind it drains normally."""
    cfg, params = dense_model
    # 3-page pool (24 positions), oversubscribed vs max_seq = 64.
    eng = Engine(cfg, TENSOR_MOR, params,
                 ServeConfig(slots=2, max_seq=64, page_size=8,
                             prefill_chunk=8, pool_pages=3))
    hog = Request(0, np.arange(16, dtype=np.int32) % cfg.vocab,
                  max_tokens=30)   # horizon 45 -> 6 pages > 3
    small = Request(1, np.arange(5, dtype=np.int32) % cfg.vocab,
                    max_tokens=4)  # horizon 8 -> 1 page
    eng.submit(hog)
    eng.submit(small)
    eng.run_to_completion()
    assert hog.done and not hog.out
    assert hog.error and "rejected at admission" in hog.error
    assert "6 pages" in hog.error and "3 total" in hog.error
    assert hog in eng.rejected
    assert small.done and small.error is None and len(small.out) == 4


def test_exact_fit_reservation_admitted(dense_model):
    """Boundary: a reservation of exactly the pool's total page count
    is satisfiable (once the pool drains) and must not be rejected."""
    cfg, params = dense_model
    eng = Engine(cfg, TENSOR_MOR, params,
                 ServeConfig(slots=2, max_seq=64, page_size=8,
                             prefill_chunk=8, pool_pages=3))
    fit = Request(0, np.arange(16, dtype=np.int32) % cfg.vocab,
                  max_tokens=9)    # horizon 24 -> exactly 3 pages
    eng.submit(fit)
    eng.run_to_completion()
    assert fit.done and fit.error is None and len(fit.out) == 9
    assert not eng.rejected
