"""Cross-implementation flash parity with query offsets (S < T).

The headline PR-7 bug: the Pallas kernel computed the causal mask from
the kernel-local query index (``q_pos = qi * bq + iota``), which is only
the true position when S == T. Called with a short query chunk against a
longer cache, queries silently masked out every key between their local
index and their true position ``T - S + i``. This suite pins all three
implementations -- the Pallas kernel (interpret lowering), the
backend-dispatched ``ops.flash_attention`` wrapper, and the chunked-XLA
``models.attention.flash_attention`` -- against one dense oracle across
causal/full, GQA groupings, and S < T with scalar and per-row offsets,
plus a witness that the old local-index assumption diverges.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ops import flash_attention as ops_flash
from repro.models.attention import flash_attention as xla_flash


def _rand(shape, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _dense_oracle(q, k, v, causal=True, q_offset=None):
    """Materialized-scores attention: the ground truth every flash
    implementation must reproduce. q (BH, S, d), k/v (BH, T, d);
    q_offset scalar or (BH,), default T - S."""
    q = np.asarray(q, np.float64)
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    BH, S, d = q.shape
    T = k.shape[1]
    off = np.broadcast_to(
        np.asarray(T - S if q_offset is None else q_offset), (BH,)
    )
    s = np.einsum("bsd,btd->bst", q, k) * d**-0.5
    if causal:
        q_pos = off[:, None] + np.arange(S)  # (BH, S)
        mask = np.arange(T)[None, None, :] <= q_pos[:, :, None]
        s = np.where(mask, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bst,btd->bsd", p, v)


def _fold(x):  # (B, L, H, dh) -> (B*H, L, dh)
    B, L, H, dh = x.shape
    return jnp.moveaxis(x, 2, 1).reshape(B * H, L, dh)


# ------------------------------------------------- kernel vs dense oracle --
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("S,T", [(8, 64), (64, 64), (16, 128), (1, 96)])
def test_kernel_chunk_against_longer_cache(causal, S, T):
    """Default q_offset (None -> T - S): a query chunk at the end of a
    longer key sequence. The pre-fix kernel failed every S < T case."""
    BH, d = 4, 32
    q = _rand((BH, S, d), seed=S + T)
    k = _rand((BH, T, d), seed=S + T + 1)
    v = _rand((BH, T, d), seed=S + T + 2)
    out = flash_attention_fwd(
        q, k, v, causal=causal, block_q=8, block_k=32, interpret=True
    )
    ref = _dense_oracle(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), ref, rtol=2e-2, atol=2e-5
    )


@pytest.mark.parametrize("q_offset", [0, 5, 40])
def test_kernel_scalar_offset(q_offset):
    BH, S, T, d = 2, 8, 48, 16
    q = _rand((BH, S, d), seed=10)
    k = _rand((BH, T, d), seed=11)
    v = _rand((BH, T, d), seed=12)
    out = flash_attention_fwd(
        q, k, v, q_offset=q_offset, block_q=8, block_k=16, interpret=True
    )
    ref = _dense_oracle(q, k, v, q_offset=q_offset)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), ref, rtol=2e-2, atol=2e-5
    )


def test_kernel_per_row_offsets():
    """(BH,) offsets: each folded row at its own position -- the serving
    engine's mixed-length decode batches."""
    BH, S, T, d = 6, 4, 64, 16
    q = _rand((BH, S, d), seed=20)
    k = _rand((BH, T, d), seed=21)
    v = _rand((BH, T, d), seed=22)
    off = jnp.asarray([0, 7, 13, 28, 44, 60], jnp.int32)
    out = flash_attention_fwd(
        q, k, v, q_offset=off, block_q=4, block_k=16, interpret=True
    )
    ref = _dense_oracle(q, k, v, q_offset=np.asarray(off))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), ref, rtol=2e-2, atol=2e-5
    )


def test_pure_jnp_ref_matches_oracle():
    """ref.flash_attention_ref (the backend='xla' lowering) honors the
    same q_offset contract as the kernel."""
    BH, S, T, d = 3, 8, 40, 16
    q = _rand((BH, S, d), seed=30)
    k = _rand((BH, T, d), seed=31)
    v = _rand((BH, T, d), seed=32)
    for off in (None, 3, jnp.asarray([0, 10, 30], jnp.int32)):
        got = kref.flash_attention_ref(q, k, v, True, q_offset=off)
        want = _dense_oracle(
            q, k, v, q_offset=None if off is None else np.asarray(off)
        )
        np.testing.assert_allclose(
            np.asarray(got, np.float32), want, rtol=2e-2, atol=2e-5
        )


# ------------------------------------------------------ witness (old bug) --
def test_local_index_mask_assumption_diverges():
    """Witness for the headline bug: masking by the kernel-local query
    index (equivalent to q_offset=0) is NOT the aligned-chunk answer --
    with S < T it hides the (T - S)-key prefix band from every query."""
    BH, S, T, d = 2, 8, 64, 16
    q = _rand((BH, S, d), seed=40)
    k = _rand((BH, T, d), seed=41)
    v = _rand((BH, T, d), seed=42)
    old = flash_attention_fwd(  # the pre-fix mask, reproduced exactly
        q, k, v, q_offset=0, block_q=8, block_k=16, interpret=True
    )
    ref = _dense_oracle(q, k, v)  # true alignment: last q at last k
    assert float(np.max(np.abs(np.asarray(old, np.float32) - ref))) > 0.1


# -------------------------------------------- wrapper GQA contract + dims --
@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_ops_wrapper_gqa_matches_model_attention(Hq, Hkv, backend):
    """ops.flash_attention's documented 4-D GQA contract: (B,S,Hq,dh)
    against (B,T,Hkv,dh), kv heads folded/repeated by the wrapper."""
    B, S, dh = 2, 64, 16
    q = _rand((B, S, Hq, dh), seed=Hq)
    k = _rand((B, S, Hkv, dh), seed=Hq + 1)
    v = _rand((B, S, Hkv, dh), seed=Hq + 2)
    out = ops_flash(q, k, v, block_q=32, block_k=32, backend=backend)
    want = xla_flash(q, k, v, kind="causal", q_chunk=32, k_chunk=32)
    assert out.shape == (B, S, Hq, dh)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-5,
    )


@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_ops_wrapper_gqa_short_chunk_per_batch_offset(backend):
    """4-D GQA with S < T and per-batch (B,) offsets: the wrapper
    repeats the offset across q heads before folding."""
    B, S, T, Hq, Hkv, dh = 2, 4, 32, 4, 2, 16
    q = _rand((B, S, Hq, dh), seed=50)
    k = _rand((B, T, Hkv, dh), seed=51)
    v = _rand((B, T, Hkv, dh), seed=52)
    off = jnp.asarray([5, 20], jnp.int32)
    out = ops_flash(
        q, k, v, q_offset=off, block_q=4, block_k=16, backend=backend
    )
    G = Hq // Hkv
    qf = _fold(q)
    kf = _fold(jnp.repeat(k, G, axis=2))
    vf = _fold(jnp.repeat(v, G, axis=2))
    ref = _dense_oracle(
        qf, kf, vf, q_offset=np.repeat(np.asarray(off), Hq)
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32).transpose(0, 2, 1, 3).reshape(
            B * Hq, S, dh
        ),
        ref, rtol=2e-2, atol=2e-5,
    )


# --------------------------------------- ragged extents + input validation --
def test_ragged_extents_shrink_blocks():
    """Non-power-of-two S/T no longer trip an assert: the launcher
    shrinks block_q/block_k to the largest dividing block."""
    BH, S, T, d = 2, 6, 30, 16
    q = _rand((BH, S, d), seed=60)
    k = _rand((BH, T, d), seed=61)
    v = _rand((BH, T, d), seed=62)
    out = flash_attention_fwd(
        q, k, v, block_q=512, block_k=512, interpret=True
    )
    ref = _dense_oracle(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), ref, rtol=2e-2, atol=2e-5
    )


def test_launcher_rejects_bad_inputs():
    q = _rand((2, 8, 16), seed=70)
    k = _rand((2, 16, 16), seed=71)
    with pytest.raises(ValueError, match="folded"):
        flash_attention_fwd(q[0], k, k, interpret=True)
    with pytest.raises(ValueError, match="match"):
        flash_attention_fwd(q, k, k[:1], interpret=True)
    with pytest.raises(ValueError, match="positive"):
        flash_attention_fwd(q, k, k, block_q=0, interpret=True)
    with pytest.raises(ValueError, match="q_offset"):
        flash_attention_fwd(
            q, k, k, q_offset=jnp.zeros(3, jnp.int32), interpret=True
        )
    with pytest.raises(ValueError, match="GQA"):
        ops_flash(
            _rand((2, 8, 3, 16), seed=72), _rand((2, 8, 2, 16), seed=73),
            _rand((2, 8, 2, 16), seed=74), backend="interpret",
        )
