"""Per-architecture smoke tests: reduced config, one forward + one train
step (loss + grads) on CPU, asserting output shapes and no NaNs; plus a
prefill->decode consistency check per family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, reduced
from repro.core import TENSOR_MOR, BF16_BASELINE
from repro.models import (
    cache_specs,
    init_cache,
    init_params,
    make_decode_fn,
    make_loss_fn,
    make_prefill_fn,
    make_tokens,
)
from repro.models.transformer import padded_vocab

ARCHS = [
    "moonshot-v1-16b-a3b", "granite-moe-1b-a400m", "gemma-2b",
    "deepseek-coder-33b", "llama3-8b", "minitron-4b", "whisper-tiny",
    "xlstm-350m", "paligemma-3b", "hymba-1.5b",
]

B, S = 2, 32


def _batch(cfg, key, mode="train"):
    kt, kl = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab, jnp.int32),
    }
    if mode == "train":
        batch["labels"] = jax.random.randint(
            kl, (B, S), 0, cfg.vocab, jnp.int32
        )
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            kl, (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            kl, (B, cfg.img_tokens, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens = make_tokens(cfg)
    batch = _batch(cfg, key)

    loss_fn = make_loss_fn(cfg, TENSOR_MOR)
    (loss, aux), grads = jax.jit(
        jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)
    )(params, tokens, batch)

    assert np.isfinite(float(loss)), f"{arch}: loss is not finite"
    # Sanity: loss near ln(vocab) at init.
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 2.5 * np.log(cfg.vocab)
    g_params, g_tokens = grads
    for path, leaf in jax.tree_util.tree_flatten_with_path(g_params)[0]:
        assert np.all(np.isfinite(np.asarray(leaf, np.float32))), (
            f"{arch}: non-finite grad at {path}"
        )
    # Backward MoR stats came out through the token cotangents.
    tok_mags = jax.tree.map(
        lambda x: float(jnp.sum(jnp.abs(x))), g_tokens
    )
    total = sum(jax.tree.leaves(tok_mags))
    assert total > 0.0, f"{arch}: no backward MoR stats collected"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    tokens = make_tokens(cfg)
    batch = _batch(cfg, key, mode="prefill")

    prefill = jax.jit(make_prefill_fn(cfg, TENSOR_MOR))
    logits, cache, _ = prefill(params, tokens, batch)
    Vp = padded_vocab(cfg)
    assert logits.shape == (B, 1, Vp)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    # One decode step continuing from prefill. Pad the prefill cache into
    # a longer decode cache where the layout is positional (kv caches).
    total = S + (cfg.img_tokens if cfg.family == "vlm" else 0)
    decode = jax.jit(make_decode_fn(cfg, TENSOR_MOR))
    full = init_cache(cfg, B, total + 8)

    def merge(dst, src):
        if dst.ndim >= 3 and src.ndim == dst.ndim and dst.shape[2] != src.shape[2]:
            # seq-dim padded kv cache: (L, B, S, ...)
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), 0, axis=2
            )
        return src.astype(dst.dtype)

    cache = jax.tree.map(merge, full, cache)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    logits2, cache2, _ = decode(
        params, tokens, cache, tok, jnp.asarray(total, jnp.int32)
    )
    assert logits2.shape == (B, 1, Vp)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


def test_bf16_baseline_runs():
    cfg = reduced(get_config("llama3-8b"))
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    tokens = make_tokens(cfg)
    batch = _batch(cfg, key)
    loss_fn = make_loss_fn(cfg, BF16_BASELINE)
    (loss, _), grads = jax.jit(
        jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)
    )(params, tokens, batch)
    assert np.isfinite(float(loss))


def test_mor_close_to_bf16_loss():
    """Fake-quant MoR loss should be close to the BF16 loss at init."""
    cfg = reduced(get_config("llama3-8b"))
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    tokens = make_tokens(cfg)
    batch = _batch(cfg, key)
    l_bf, _ = jax.jit(make_loss_fn(cfg, BF16_BASELINE))(params, tokens, batch)
    l_mor, _ = jax.jit(make_loss_fn(cfg, TENSOR_MOR))(params, tokens, batch)
    assert abs(float(l_bf) - float(l_mor)) / float(l_bf) < 0.05
