"""Tests for the MoR framework recipes (Algorithm 2) and mor_dot."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the 'hypothesis' test extra"
)
hnp = pytest.importorskip("hypothesis.extra.numpy")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BF16_BASELINE,
    E4M3,
    PER_BLOCK_128,
    MoRPolicy,
    Partition,
    mor_dot,
    mor_quantize,
    new_token,
    paper_default,
    quant_dequant,
    relative_error,
)
from repro.core.mor import (
    STAT_DECISION,
    STAT_FRAC_BF16,
    STAT_FRAC_E4M3,
    STAT_FRAC_E5M2,
    STATS_WIDTH,
)


def _rand(shape, scale=1.0, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


# ---------------------------------------------------------------- recipes --
def test_tensor_level_accepts_wellscaled():
    x = _rand((256, 256))
    pol = MoRPolicy(recipe="tensor", partition="block")
    y, stats = mor_quantize(x, pol)
    # Gaussian data quantizes well under per-block GAM: accepted.
    assert float(stats[STAT_DECISION]) == 1.0
    err = float(relative_error(x, y))
    assert err < 0.045
    assert not np.allclose(np.asarray(y), np.asarray(x))  # actually quantized


def test_tensor_level_rejects_wide_dynamic_range():
    # Values spanning ~2^40 within each block force large relative error
    # for small values -> fallback to BF16 (identity).
    rng = np.random.default_rng(3)
    mag = np.exp2(rng.uniform(-30, 30, (256, 256))).astype(np.float32)
    x = jnp.asarray(mag * np.sign(rng.standard_normal((256, 256))))
    pol = MoRPolicy(recipe="tensor", partition="tensor")
    y, stats = mor_quantize(x, pol)
    assert float(stats[STAT_DECISION]) == 0.0
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_threshold_monotonicity():
    """Raising the threshold can only flip decisions BF16 -> E4M3."""
    x = _rand((128, 128), scale=100.0, seed=4)
    decisions = []
    for th in (1e-5, 0.01, 0.045, 0.5):
        _, stats = mor_quantize(x, MoRPolicy(recipe="tensor", threshold=th))
        decisions.append(float(stats[STAT_DECISION]))
    assert decisions == sorted(decisions)


def test_sub2_blocks_mix():
    # Half the tensor is benign, half has huge dynamic range per block.
    rng = np.random.default_rng(5)
    good = rng.standard_normal((128, 256)).astype(np.float32)
    bad = (
        np.exp2(rng.uniform(-34, 34, (128, 256))).astype(np.float32)
        * np.sign(rng.standard_normal((128, 256)))
    )
    x = jnp.asarray(np.concatenate([good, bad], axis=0))
    pol = MoRPolicy(recipe="sub2", partition="block")
    y, stats = mor_quantize(x, pol)
    f4, f5, fbf = (float(stats[STAT_FRAC_E4M3]),
                   float(stats[STAT_FRAC_E5M2]),
                   float(stats[STAT_FRAC_BF16]))
    assert f5 == 0.0  # two-way never selects E5M2
    assert 0.0 < f4 < 1.0 and 0.0 < fbf < 1.0
    # BF16 blocks are bit-identical to the input.
    yb = np.asarray(y)[128:]
    xb = np.asarray(x)[128:]
    # At least the rows in fallback blocks should match exactly somewhere:
    assert np.mean(yb == xb) > 0.1


def test_sub3_uses_e5m2():
    # Moderate dynamic range: too wide for E4M3's ~2^17 span per block,
    # within E5M2's ~2^29 normal span (Eq. 4).
    rng = np.random.default_rng(6)
    mag = np.exp2(rng.uniform(-12, 12, (128, 128))).astype(np.float32)
    x = jnp.asarray(mag)
    pol = MoRPolicy(recipe="sub3", partition="tensor")
    y, stats = mor_quantize(x, pol)
    assert float(stats[STAT_FRAC_E5M2]) > 0.0  # some E5M2 usage
    assert np.all(np.isfinite(np.asarray(y)))


def test_quant_dequant_idempotent():
    """Q(Q(x)) == Q(x): fake-quantized values are fixed points."""
    x = _rand((128, 128), seed=7)
    y1, sc = quant_dequant(x, PER_BLOCK_128, E4M3)
    y2, _ = quant_dequant(y1, PER_BLOCK_128, E4M3)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=0, atol=0)


def test_rel_err_bounded_by_format_eps():
    """For benign data, per-element rel-err <= 2^-4 + scale rounding slack."""
    x = _rand((256, 256), seed=8)
    y, _ = quant_dequant(x, PER_BLOCK_128, E4M3)
    err = float(relative_error(x, y))
    # E4M3 eps = 2^-4 = 6.25%; mean err should be well under that.
    assert err < E4M3.eps


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(
    data=hnp.arrays(
        np.float32,
        hnp.array_shapes(min_dims=2, max_dims=2, min_side=2, max_side=64),
        elements=st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
                           width=32),
    ),
    recipe=st.sampled_from(["tensor", "sub2", "sub3"]),
)
def test_property_mor_finite_and_shaped(data, recipe):
    x = jnp.asarray(data)
    pol = MoRPolicy(recipe=recipe, partition="block", block_shape=(32, 32))
    y, stats = mor_quantize(x, pol)
    assert y.shape == x.shape and y.dtype == x.dtype
    assert np.all(np.isfinite(np.asarray(y)))
    assert stats.shape == (STATS_WIDTH,)
    assert np.all(np.isfinite(np.asarray(stats)))
    # Fractions sum to ~1.
    s = np.asarray(stats)
    np.testing.assert_allclose(s[3] + s[4] + s[5], 1.0, atol=1e-5)


# ---------------------------------------------------------------- mor_dot --
def test_mor_dot_matches_plain_dot_when_off():
    x = _rand((4, 32, 64), seed=9)
    w = _rand((64, 48), seed=10)
    y, stats = mor_dot(x, w, new_token(), BF16_BASELINE)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x) @ np.asarray(w), rtol=1e-4, atol=1e-4
    )
    assert float(jnp.sum(jnp.abs(stats))) == 0.0


def test_mor_dot_close_to_plain_dot_when_on():
    x = _rand((8, 64), seed=11)
    w = _rand((64, 32), seed=12)
    y, stats = mor_dot(x, w, new_token(), paper_default())
    ref = np.asarray(x) @ np.asarray(w)
    rel = np.abs(np.asarray(y) - ref) / (np.abs(ref) + 1e-3)
    assert np.median(rel) < 0.15  # fp8-level fidelity on the GEMM output
    assert float(stats[0, 0]) in (0.0, 1.0)


def test_mor_dot_grads_flow_and_token_carries_stats():
    x = _rand((16, 64), seed=13)
    w = _rand((64, 32), seed=14)
    tok = new_token()
    pol = paper_default()

    def loss(x, w, tok):
        y, _ = mor_dot(x, w, tok, pol)
        return jnp.sum(y**2)

    (dx, dw, dtok) = jax.grad(loss, argnums=(0, 1, 2))(x, w, tok)
    assert dx.shape == x.shape and dw.shape == w.shape
    assert np.all(np.isfinite(np.asarray(dx)))
    assert np.all(np.isfinite(np.asarray(dw)))
    # Bwd stats rode out through the token cotangent.
    assert dtok.shape == tok.shape
    assert float(jnp.max(dtok[:, 2])) > 0.0  # amax entries populated

    # Gradients approximate the unquantized ones.
    def loss_ref(x, w):
        return jnp.sum((x @ w) ** 2)

    rx, rw = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    cos = float(
        jnp.sum(dx * rx)
        / (jnp.linalg.norm(dx) * jnp.linalg.norm(rx) + 1e-9)
    )
    assert cos > 0.98


def test_mor_dot_jit_and_vmap():
    pol = paper_default("sub2")
    x = _rand((4, 8, 32), seed=15)
    w = _rand((4, 32, 16), seed=16)

    @jax.jit
    def f(x, w):
        return jax.vmap(lambda a, b: mor_dot(a, b, new_token(), pol))(x, w)

    y, stats = f(x, w)
    assert y.shape == (4, 8, 16)
    assert stats.shape[0] == 4


@pytest.mark.parametrize("partition", ["tensor", "block", "channel"])
def test_mor_dot_partitions_all_work(partition):
    pol = paper_default(partition=partition)
    x = _rand((32, 96), seed=17)
    w = _rand((96, 64), seed=18)

    def loss(x, w, tok):
        y, _ = mor_dot(x, w, tok, pol)
        return jnp.sum(y**2)

    g = jax.grad(loss, argnums=(0, 1))(x, w, new_token())
    for arr in g:
        assert np.all(np.isfinite(np.asarray(arr)))
