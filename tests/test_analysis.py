"""Precision-flow static analysis suite (docs/analysis.md).

Three layers, each with a positive (violation fires) and negative
(clean code passes) witness:

* AST rules MOR001..MOR005 over source fixtures, plus the inline and
  central allowlist machinery.
* The jaxpr payload-lane taint checker: sanctioned kernel consumption
  passes, a raw payload read fires, and the real
  quantize_pack -> mixed_gemm -> dequant chain verifies end to end.
* HLO/jaxpr contracts: a deliberately-broken contract reports
  violations, and the whole registered registry passes clean on the
  interpret/cross-lowering backends (the same ``check_all`` CI's lint
  job and the bench sweep run).
"""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    REGISTRY,
    Contract,
    ContractCase,
    ast_rules,
    check_all,
    check_contract,
    contracts,
    hlo_rules,
    lint_payload_flow,
)
from repro.core import MoRPolicy
from repro.core.mor import quantize_for_gemm
from repro.kernels import ops as kops


def _lint(src, path="src/repro/fake.py"):
    return ast_rules.lint_source(textwrap.dedent(src), path)


def _rules_hit(violations):
    return sorted({v.rule for v in violations})


# ------------------------------------------------------- AST: MOR001 --
def test_mor001_hash_fires():
    vs = _lint("seed = hash(name) % 2**31\n")
    assert _rules_hit(vs) == ["MOR001"]


def test_mor001_crc32_clean():
    vs = _lint("import zlib\nseed = zlib.crc32(name.encode())\n")
    assert vs == []


# ------------------------------------------------------- AST: MOR002 --
def test_mor002_bare_assert_fires():
    vs = _lint("def f(x):\n    assert x.ndim == 2\n    return x\n")
    assert _rules_hit(vs) == ["MOR002"]


def test_mor002_typed_exception_clean():
    vs = _lint(
        """
        def f(x):
            if x.ndim != 2:
                raise ValueError(x.shape)
            return x
        """
    )
    assert vs == []


def test_mor002_exempt_in_kernels_and_tests():
    src = "def f(x):\n    assert x == 1\n"
    assert _lint(src, "src/repro/kernels/mor_select.py") == []
    assert _lint(src, "tests/test_foo.py") == []
    assert _lint(src, "benchmarks/bench_foo.py") == []


# ------------------------------------------------------- AST: MOR003 --
def test_mor003_magic_stats_index_fires():
    for src in (
        "x = stats[11]\n",
        "y = pm.stats[8]\n",
        "s = stats.at[10].set(kind)\n",
        "z = row[5]\n",
    ):
        assert _rules_hit(_lint(src)) == ["MOR003"], src


def test_mor003_named_constant_clean():
    vs = _lint(
        "from repro.core.mor import STAT_PAYLOAD_BPE\n"
        "x = stats[STAT_PAYLOAD_BPE]\n"
    )
    assert vs == []


def test_mor003_ignores_non_stats_arrays():
    assert _lint("x = weights[3]\n") == []


# ------------------------------------------------------- AST: MOR004 --
def test_mor004_import_time_config_fires():
    vs = _lint('import jax\njax.config.update("jax_enable_x64", True)\n')
    assert _rules_hit(vs) == ["MOR004"]


def test_mor004_config_inside_function_clean():
    vs = _lint(
        """
        import jax

        def main():
            jax.config.update("jax_enable_x64", True)
        """
    )
    assert vs == []


# ------------------------------------------------------- AST: MOR005 --
def test_mor005_clock_in_jitted_fn_fires():
    vs = _lint(
        """
        import time
        import jax

        def step(x):
            t0 = time.time()
            return x + t0

        run = jax.jit(step)
        """
    )
    assert _rules_hit(vs) == ["MOR005"]


def test_mor005_host_rng_under_jit_decorator_fires():
    vs = _lint(
        """
        import jax
        import numpy as np

        @jax.jit
        def step(x):
            return x + np.random.standard_normal()
        """
    )
    assert _rules_hit(vs) == ["MOR005"]


def test_mor005_clock_outside_jit_clean():
    vs = _lint(
        """
        import time

        def bench(f, x):
            t0 = time.time()
            f(x)
            return time.time() - t0
        """
    )
    assert vs == []


# ------------------------------------------------------- AST: MOR006 --
_KERNEL_BODY = """
    def _select_kernel(x_ref, o_ref, amax_ref):
        assert x_ref.shape[0] == 128
        o_ref[...] = x_ref[...]
"""


def test_mor006_kernel_body_assert_fires():
    vs = _lint(_KERNEL_BODY, "src/repro/kernels/mor_select.py")
    assert _rules_hit(vs) == ["MOR006"]


def test_mor006_launcher_assert_is_mor002_territory():
    # One *_ref param (or none) is a launcher/helper, not a kernel
    # body: MOR002's kernel-dir exemption applies, MOR006 stays quiet.
    src = """
        def launch(x, o_ref):
            assert x.ndim == 2
            return x
    """
    assert _lint(src, "src/repro/kernels/mor_select.py") == []


def test_mor006_scoped_to_kernels_dir():
    # Outside the kernels dir the same source is MOR002's problem
    # (plain bare-assert rule), never MOR006's.
    hits = _rules_hit(_lint(_KERNEL_BODY, "src/repro/train/train_step.py"))
    assert hits == ["MOR002"]
    assert _lint(_KERNEL_BODY, "tests/test_foo.py") == []


def test_mor006_nested_defs_not_attributed_to_kernel():
    # An assert inside a *nested* non-kernel function must not be
    # blamed on the enclosing kernel body.
    src = """
        def _kern(x_ref, o_ref):
            def helper(v):
                assert v > 0
                return v
            o_ref[...] = x_ref[...]
    """
    assert _lint(src, "src/repro/kernels/mor_select.py") == []


# ------------------------------------------------------- allowlists --
def test_inline_allow_suppresses():
    vs = _lint("seed = hash(n)  # lint: allow(MOR001) fixture\n")
    assert vs == []
    # ...but only for the named rule.
    vs = _lint("seed = hash(n)  # lint: allow(MOR002) wrong rule\n")
    assert _rules_hit(vs) == ["MOR001"]


def test_central_allowlist_is_rationaled_and_applies():
    for entry in ast_rules.ALLOWLIST:
        assert entry.rationale, entry
        assert entry.rule in ast_rules.RULES, entry
    # The PYTHONHASHSEED reassociation entry suppresses MOR001 in the
    # serve-engine test module (and nowhere else).
    src = "x = hash(n)\n"
    assert _lint(src, "tests/test_serve_engine.py") == []
    assert _rules_hit(_lint(src, "tests/test_other.py")) == ["MOR001"]


def test_repo_lints_clean():
    """Day-one guarantee: the whole repo passes its own linter."""
    import os

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    vs = ast_rules.lint_paths([
        os.path.join(root, d)
        for d in ("src", "tools", "benchmarks", "tests")
    ])
    assert vs == [], "\n".join(v.render() for v in vs)


# ---------------------------------------------------- jaxpr taint ----
def _mo(seed=0, shape=(256, 256)):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(shape), jnp.bfloat16)
    mo, _ = quantize_for_gemm(
        x, MoRPolicy(recipe="sub3", backend="interpret")
    )
    return mo


def test_taint_clean_through_sanctioned_gemm():
    a, b = _mo(0), _mo(1, (128, 256))
    rep = lint_payload_flow(
        lambda x, y: kops.mixed_gemm(x, y, backend="interpret"), (a, b)
    )
    assert rep.ok, rep.render()
    assert any("payload_q" in s for s in rep.seeded)
    assert any("tags" in s for s in rep.seeded)


def test_taint_raw_payload_read_fires():
    a = _mo(2)

    def leak(m):
        return m.payload_q.astype(jnp.float32).sum() * 2.0

    rep = lint_payload_flow(leak, (a,))
    assert not rep.ok
    assert any("payload_q" in v.lane for v in rep.violations)


def test_taint_structural_ops_propagate_without_firing():
    # Slicing/transposing payload bytes moves them without reading
    # them: structural, not a violation (consuming them would be).
    a = _mo(3)
    rep = lint_payload_flow(lambda m: m.payload_q.T[:64], (a,))
    assert rep.ok, rep.render()


def test_taint_end_to_end_pack_gemm_decode_chain():
    """The acceptance chain: quantize_pack -> mixed_gemm -> dequant,
    with kernel outputs re-seeded, verifies end to end -- and a
    deliberate raw-payload leak in the same chain is caught."""
    pol = MoRPolicy(recipe="sub3", backend="interpret")
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((256, 256)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((128, 256)), jnp.bfloat16)

    def chain(a, b):
        amo, _ = quantize_for_gemm(a, pol)
        bmo, _ = quantize_for_gemm(b, pol)
        y = kops.mixed_gemm(amo, bmo, backend="interpret")
        return amo.dequant().astype(jnp.float32).sum() + y.sum()

    rep = lint_payload_flow(chain, (x, w), seed_kernel_outputs=True)
    assert rep.ok, rep.render()
    assert rep.n_eqns > 10  # really walked the whole program

    def leaky_chain(a, b):
        amo, _ = quantize_for_gemm(a, pol)
        bmo, _ = quantize_for_gemm(b, pol)
        y = kops.mixed_gemm(amo, bmo, backend="interpret")
        return y.sum() + amo.payload_q.astype(jnp.float32).mean()

    rep = lint_payload_flow(
        leaky_chain, (x, w), seed_kernel_outputs=True
    )
    assert not rep.ok


# ------------------------------------------------------- contracts ---
def test_contract_violation_fires():
    """A contract with unsatisfiable rules reports every miss (and the
    report carries which rule missed)."""
    from jax.experimental import enable_x64

    bad = Contract(
        name="fixture_bad",
        build=lambda: ContractCase(
            fn=lambda x: (x.astype(jnp.float64) * 2).sum(),
            args=(jnp.ones((8, 8), jnp.float32),),
        ),
        forbid_f64=True,
        taint=r"\[0\]",  # seed the whole first argument
    )
    with enable_x64():
        report = check_contract(bad)
    assert not report.ok
    assert any("f64" in v for v in report.violations)
    # The tainted arg is consumed by `convert_element_type` in this
    # (unsanctioned) module: the taint rule fires too.
    assert any("consumed" in v for v in report.violations)
    assert report.rules_evaluated == 2


def test_contract_custom_call_range_fires():
    low = Contract(
        name="fixture_launches",
        build=lambda: ContractCase(
            fn=lambda x: x + 1.0,  # zero custom calls
            args=(jnp.ones((8, 8), jnp.float32),),
        ),
        custom_calls=(1, 1),
        forbid_f64=False,
    )
    report = check_contract(low)
    if report.counters.get("tpu_kernel_launches") == -1:
        pytest.skip("this jax has no cross-platform lowering API")
    assert not report.ok
    assert "custom calls" in report.violations[0]


def test_registry_names_and_constants():
    expected = {
        "quantize_pack_sub3", "quantize_pack_sub4",
        "mor_quantize_sub4", "mixed_gemm", "qdot_sub3", "qdot_sub4",
        "mor_dot_fused_fwd", "mor_dot_fused_grads", "flash_attention",
        "compress_grads_mor", "adamw_packed_moments",
        "engine_decode_step", "engine_prefill",
    }
    assert expected <= set(REGISTRY)
    assert contracts.SINGLE_LAUNCH == (1, 1)
    assert contracts.MAX_PACK_OPS_OVER_SELECT == 0
    # The decode-tile pin matches the kernel layer's own resolution.
    assert contracts.DECODE_ROW_BLOCK == kops.decode_row_block(4)


@pytest.mark.slow
def test_check_all_registry_clean():
    """Every registered entry-point contract passes on this host (the
    blocking CI lint job runs exactly this sweep)."""
    summary = check_all()
    assert summary.contracts_checked == len(REGISTRY)
    assert summary.rules_evaluated >= summary.contracts_checked
    assert summary.ok, "\n".join(summary.violations)


def test_kernel_contracts_clean_fast():
    """Tier-1 subset of the sweep: the kernel-level contracts (no
    engine build) pass clean."""
    summary = check_all([
        "quantize_pack_sub3", "mixed_gemm", "qdot_sub3",
        "flash_attention",
    ])
    assert summary.ok, "\n".join(summary.violations)


# ------------------------------------------------------- hlo_rules ---
def test_operand_sized_ops_counts_and_families():
    txt = "\n".join([
        "func something",
        '%0 = stablehlo.convert %arg0 : tensor<256x256xbf16>',
        '%1 = stablehlo.add %0, %0 : tensor<256x256xf32>',
        '%2 = stablehlo.pad %1 : tensor<16xf32>',  # small: not counted
        "return %1",
    ])
    assert hlo_rules.operand_sized_ops(txt, (256, 256)) == 2
    fams = hlo_rules.operand_sized_packing_ops(txt, (256, 256))
    assert len(fams) == 1 and "convert" in fams[0]


def test_f64_and_host_transfer_detection():
    assert hlo_rules.f64_lines(
        "%0 = stablehlo.add %a : tensor<4x4xf64>"
    )
    assert not hlo_rules.f64_lines(
        "%0 = stablehlo.add %a : tensor<4x4xf32>"
    )
    assert hlo_rules.host_transfer_lines(
        '%1 = "stablehlo.send"(%a) : tensor<4xf32>'
    )


def test_donated_arg_count_sees_donation():
    def f(pool, x):
        return {"kv": pool["kv"] + x}, x.sum()

    args = ({"kv": jnp.ones((8, 8))}, jnp.ones((8, 8)))
    txt = hlo_rules.lowering_text(f, *args, donate_argnums=(0,))
    assert hlo_rules.donated_arg_count(txt) >= 1
    txt0 = hlo_rules.lowering_text(f, *args)
    assert hlo_rules.donated_arg_count(txt0) == 0
