"""Checkpoint round-trip of the *compressed* optimizer state.

The PR-8 OptState is no longer a pytree of plain f32 leaves: packed
Adam moments carry uint8 fp8 payload lanes, packed E2M1 nibbles and
E4M3 micro-scale bytes (PackedMoment/MixedOperand leaves), and the EF
residual tree rides next to them.  The checkpointer's dtype sidecar
(``_EXOTIC`` views for sub-f32 dtypes) must reproduce every one of
those lanes bit-exact -- a payload byte that round-trips through the
wrong view silently corrupts the moment estimate it encodes.  The
resume test closes the loop: a trajectory interrupted by a
save/restore at the midpoint lands on bit-identical parameters and
optimizer state to the unbroken run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core.policy import MoRPolicy
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.optim.compress import compress_decompress_grads
from repro.optim.moments import MomentPolicy

_MOMENTS = MomentPolicy(
    m=MoRPolicy(recipe="sub3", backend="xla"),
    v=MoRPolicy(recipe="sub3", backend="xla", threshold=0.02),
    min_leaf=0,
)
_CFG = AdamWConfig(peak_lr=1e-2, final_lr=1e-3, warmup_steps=2,
                   total_steps=10)


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(128, 128)), jnp.bfloat16),
        "b": jnp.asarray(rng.normal(size=(128,)), jnp.bfloat16),
    }


def _grads(rng, params, scale=1e-2):
    return {k: jnp.asarray(rng.normal(size=v.shape) * scale, jnp.float32)
            for k, v in params.items()}


def _step(params, opt, grads):
    """One compressed optimizer step: mor_ef gradients then packed-
    moment AdamW -- every exotic OptState lane gets exercised."""
    g, ef = compress_decompress_grads(
        grads, "mor_ef", opt.ef,
        MoRPolicy(recipe="sub3", backend="xla"))
    params, opt, _ = adamw_update(_CFG, g, opt, moments=_MOMENTS)
    return params, opt._replace(ef=ef)


def _assert_tree_bitexact(got, want):
    gl, gt = jax.tree_util.tree_flatten(got)
    wl, wt = jax.tree_util.tree_flatten(want)
    assert gt == wt
    for g, w in zip(gl, wl):
        g, w = np.asarray(g), np.asarray(w)
        assert g.dtype == w.dtype, (g.dtype, w.dtype)
        np.testing.assert_array_equal(g, w)


def _warm_state(steps=3):
    params = _params()
    opt = init_opt_state(params, moments=_MOMENTS, ef=True)
    rng = np.random.default_rng(1)
    for _ in range(steps):
        params, opt = _step(params, opt, _grads(rng, params))
    return params, opt


def test_packed_opt_state_roundtrips_bitexact(tmp_path):
    params, opt = _warm_state()
    # The state actually holds exotic lanes, or this test is vacuous.
    dts = {str(np.asarray(l).dtype) for l in jax.tree_util.tree_leaves(opt)}
    assert "uint8" in dts, dts

    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(3, {"params": params, "opt": opt})
    target = {"params": _params(),
              "opt": init_opt_state(_params(), moments=_MOMENTS, ef=True)}
    got = ck.restore(3, target)
    _assert_tree_bitexact(got["params"], params)
    _assert_tree_bitexact(got["opt"], opt)
    assert int(got["opt"].step) == 3


def test_resumed_trajectory_matches_unbroken(tmp_path):
    """Save at step 3 of 6, restore into a fresh process-shaped
    target, continue on the identical grad stream: the resumed run's
    params and full OptState (packed lanes, EF, step counter) are
    bit-identical to the run that never stopped."""
    # Unbroken run.
    params_u, opt_u = _warm_state(3)
    rng_tail = np.random.default_rng(2)
    for _ in range(3):
        params_u, opt_u = _step(params_u, opt_u, _grads(rng_tail, params_u))

    # Interrupted run: same head, checkpoint, fresh restore, same tail.
    params_h, opt_h = _warm_state(3)
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(3, {"params": params_h, "opt": opt_h})
    got = ck.restore(3, {"params": _params(),
                         "opt": init_opt_state(_params(), moments=_MOMENTS,
                                               ef=True)})
    params_r, opt_r = got["params"], got["opt"]
    rng_tail = np.random.default_rng(2)
    for _ in range(3):
        params_r, opt_r = _step(params_r, opt_r, _grads(rng_tail, params_r))

    _assert_tree_bitexact(params_r, params_u)
    _assert_tree_bitexact(opt_r, opt_u)
    assert int(opt_r.step) == 6
