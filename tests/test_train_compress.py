"""MoR-compressed training state: the differential trajectory harness.

The PR-8 tentpole: gradients ('mor'/'mor_ef'), Adam moments
(PackedMoment leaves) and the cross-pod collective all flow through the
*real* per-block selection machinery. This suite pins the training-
level contract:

* **Differential trajectories** -- N steps of the reduced llama config
  under {dense f32, legacy fp8, MoR grads + EF, MoR moments, all-on},
  identical batch stream: every compressed run's final loss stays
  within a pinned tolerance of the dense run, and the dense run itself
  learned (so the tolerance is not vacuous). Tier-1 runs N=50; the
  ``--runslow`` lane re-runs the two extreme modes at N=200.
* **Error feedback** -- the residual norm is bounded and non-increasing
  in trend (last-quarter mean <= first-quarter mean x 1.05): EF absorbs
  per-step quantization error instead of accumulating it.
* **grad_accum invariance** extends to the compressed state: splitting
  the batch into 4 microbatches leaves loss, optimizer-event stats and
  the EF norm invariant (the stats-contract guarantee, now including
  event_kind > 0 rows).
* **Bytes-per-param budget** -- packed moments at the 1024x1024 leaf
  scale cost <= 1.05 B/param when fully-fp8 and <= 0.65 B/param for a
  fully-NVFP4 sub4 second moment, asserted on both the logical
  (stats-lane) and physical (post-``compact()`` HBM bytes) number.
* **Signature pinning** -- ``compress_decompress_grads`` returns
  ``(grads, ef_state)`` for *every* mode (satellite 1: the pre-PR-8
  'fp8' mode returned a bare tree and callers mis-assigned the tuple).
* **Sharding** -- ``opt_state_specs`` mirrors the OptState pytree
  (PackedMoment leaves included) so the compressed state ZeRO-shards.
* **Mesh invariance** -- a 4-device data-sharded ``encode_moment``
  emits bit-identical payloads/tags/scales to the single-device pack
  (the PR-3 allreduced-group-amax path, subprocess like
  tests/test_quantize_pack.py).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mor import (
    STAT_EVENT_KIND,
    STAT_FRAC_E4M3,
    STAT_FRAC_E5M2,
    STAT_FRAC_NVFP4,
)
from repro.core.policy import MoRPolicy
from repro.optim.compress import (
    GRAD_COMPRESS_MODES,
    compress_decompress_grads,
    ef_init,
)
from repro.optim.moments import (
    MomentPolicy,
    PackedMoment,
    encode_moment,
    decode_moment,
    logical_bytes_per_param,
    physical_bytes_per_param,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _xla(recipe, **kw):
    return MoRPolicy(recipe=recipe, backend="xla", **kw)


# Second moment under the wide-range threshold (squared grads).
_MOMENTS = MomentPolicy(m=_xla("sub3"), v=_xla("sub3", threshold=0.02))

MODES = {
    "dense": dict(),
    "fp8": dict(compress="fp8"),
    "mor_grads": dict(compress="mor_ef"),
    "mor_moments": dict(moments=_MOMENTS),
    "all_on": dict(compress="mor_ef", moments=_MOMENTS),
}


def _run_trajectory(steps, compress="none", moments=None, grad_accum=1,
                    batch_seed=7, constant_batch=False):
    """N jitted train steps on the reduced llama config; returns
    (losses, ef_norms, last_metrics). The batch stream is a fixed
    function of ``batch_seed`` so different modes see identical data."""
    from repro.configs import get_config, reduced
    from repro.core import paper_default
    from repro.models import init_params
    from repro.optim import AdamWConfig, init_opt_state
    from repro.train import TrainConfig, make_train_step

    cfg = dataclasses.replace(reduced(get_config("llama3-8b")), vocab=64)
    pol = paper_default("sub3")
    pol = pol.replace(
        act=pol.act.replace(backend="xla"),
        weight=pol.weight.replace(backend="xla"),
        grad=pol.grad.replace(backend="xla"),
    )
    tcfg = TrainConfig(
        optimizer=AdamWConfig(peak_lr=1e-3, final_lr=1e-4,
                              warmup_steps=5, total_steps=steps),
        grad_accum=grad_accum,
        compress_grads=compress,
        grad_policy=_xla("sub3"),
        moments=moments,
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, moments=moments,
                         ef=compress.endswith("_ef"))
    step = jax.jit(make_train_step(cfg, pol, tcfg))
    rng = np.random.default_rng(batch_seed)
    losses, efs, metrics = [], [], None
    for _ in range(steps):
        if constant_batch:
            # One row repeated: every microbatch slice is identical, so
            # metrics must be invariant to the grad_accum split.
            row_t = rng.integers(0, 64, (1, 32))
            row_l = rng.integers(0, 64, (1, 32))
            t = np.repeat(row_t, 4, axis=0)
            l = np.repeat(row_l, 4, axis=0)
        else:
            t = rng.integers(0, 64, (4, 32))
            l = rng.integers(0, 64, (4, 32))
        batch = {"tokens": jnp.asarray(t, jnp.int32),
                 "labels": jnp.asarray(l, jnp.int32)}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if "ef_norm" in metrics:
            efs.append(float(metrics["ef_norm"]))
    return losses, efs, metrics


@pytest.fixture(scope="module")
def traj50():
    """All five 50-step trajectories on the identical batch stream."""
    return {name: _run_trajectory(50, **kw) for name, kw in MODES.items()}


# ----------------------------------------------- differential trajectory --
def test_loss_drift_within_tolerance(traj50):
    """Every compressed mode's final loss (mean of the last 10 steps)
    stays within 0.01 of the dense-f32 run on the same batches --
    observed drift is ~5e-4, the tolerance leaves ~20x headroom without
    admitting a diverged run (the dense loss only moves ~6e-3 total at
    this scale)."""
    dense = np.mean(traj50["dense"][0][-10:])
    for name in ("fp8", "mor_grads", "mor_moments", "all_on"):
        final = np.mean(traj50[name][0][-10:])
        assert abs(final - dense) <= 0.01, (name, final, dense)


def test_dense_run_learned(traj50):
    """The tolerance above is anchored: the dense run's loss decreased,
    so 'within tolerance of dense' is not satisfied by divergence."""
    losses = traj50["dense"][0]
    assert np.mean(losses[-10:]) < losses[0], (losses[0], losses[-10:])


def test_compressed_runs_report_opt_stats(traj50):
    """The optimizer-event stats surface in metrics for every mode that
    compresses state, and the logical payload cost they report is in
    the fp8 regime (payload <= bf16's 2 B/param, > NVFP4's floor)."""
    for name in ("mor_grads", "mor_moments", "all_on"):
        m = traj50[name][2]
        assert "opt_payload_bpe" in m, name
        bpe = float(m["opt_payload_bpe"])
        assert 0.5 < bpe <= 2.0, (name, bpe)
    assert "opt_payload_bpe" not in traj50["dense"][2]
    # Legacy fp8 bypasses the stats machinery by construction.
    assert "opt_payload_bpe" not in traj50["fp8"][2]


def test_ef_norm_bounded_and_non_increasing(traj50):
    """EF residual norms: bounded (no drift across steps -- that is the
    whole point of error feedback) and non-increasing in trend."""
    for name in ("mor_grads", "all_on"):
        efs = traj50[name][1]
        assert len(efs) == 50, name
        assert max(efs) < 0.1, (name, max(efs))  # observed ~0.032
        q = len(efs) // 4
        first, last = np.mean(efs[:q]), np.mean(efs[-q:])
        assert last <= first * 1.05, (name, first, last)


@pytest.mark.slow
def test_loss_drift_200_steps():
    """The N=200 slow-lane variant on the extreme modes."""
    dense, _, _ = _run_trajectory(200)
    assert np.mean(dense[-10:]) < dense[0]
    all_on, efs, _ = _run_trajectory(200, compress="mor_ef",
                                     moments=_MOMENTS)
    assert abs(np.mean(all_on[-10:]) - np.mean(dense[-10:])) <= 0.02
    q = len(efs) // 4
    assert np.mean(efs[-q:]) <= np.mean(efs[:q]) * 1.05
    assert max(efs) < 0.1


# --------------------------------------------------- grad_accum extension --
def test_grad_accum_invariance_compressed_state():
    """Splitting the batch into 4 microbatches leaves the compressed-
    state metrics invariant: the stats-contract guarantee extends to
    the optimizer-event rows, moment byte costs and the EF norm."""
    _, _, m1 = _run_trajectory(1, compress="mor_ef", moments=_MOMENTS,
                               grad_accum=1, constant_batch=True)
    _, _, m4 = _run_trajectory(1, compress="mor_ef", moments=_MOMENTS,
                               grad_accum=4, constant_batch=True)
    # Structural metrics -- per-block decisions and the byte costs they
    # imply -- are exactly invariant: the accumulated gradient differs
    # from the unsplit one only by accumulation rounding, far below any
    # decision threshold.
    for key in ("loss", "opt_frac_bf16", "opt_payload_bpe",
                "moment_bpe_m", "moment_bpe_v",
                "fwd_frac_bf16", "bwd_frac_bf16"):
        a, b = float(m1[key]), float(m4[key])
        assert a == pytest.approx(b, rel=1e-5, abs=1e-6), (key, a, b)
    # Value metrics of the quantization error itself are only as
    # invariant as the accumulated gradient is bitwise stable: summing
    # g/4 four times perturbs elements near rounding boundaries, so the
    # residual norms see ~1e-3 relative jitter (not drift -- jitter).
    for key in ("opt_rel_err", "ef_norm"):
        a, b = float(m1[key]), float(m4[key])
        assert a == pytest.approx(b, rel=1e-2, abs=1e-6), (key, a, b)


# ------------------------------------------------------ signature pinning --
def test_compress_decompress_signature_all_modes():
    """(grads, ef_state) for *every* mode -- the pre-PR-8 'fp8' mode
    returned a bare tree and 'fp8_ef' a tuple, and the caller that
    forgot which was which silently trained on a tuple."""
    g = {"w": jnp.ones((8, 8)), "b": jnp.ones((8,))}
    for mode in GRAD_COMPRESS_MODES:
        ef = ef_init(g) if mode.endswith("_ef") else None
        out = compress_decompress_grads(
            g, mode, ef, policy=_xla("sub3"))
        assert isinstance(out, tuple) and len(out) == 2, mode
        new_g, new_e = out
        assert jax.tree.structure(new_g) == jax.tree.structure(g), mode
        if mode.endswith("_ef"):
            assert jax.tree.structure(new_e) == jax.tree.structure(g)
        else:
            assert new_e is None, mode


def test_compress_grads_rejects_bad_mode_and_missing_ef():
    from repro.optim.compress import compress_grads

    g = {"w": jnp.ones((4, 4))}
    with pytest.raises(ValueError):
        compress_grads(g, "gzip")
    with pytest.raises(ValueError):
        compress_grads(g, "mor_ef", ef_state=None)


# -------------------------------------------------- bytes-per-param budget --
def _nvfp4_exact(shape, seed=3):
    """Values exactly on the E2M1 grid times power-of-two micro scales
    shared by each 16-element group: the sub4 cascade sends every block
    to the NVFP4 arm."""
    rng = np.random.default_rng(seed)
    m, k = shape
    grid = np.array([0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0])
    micro = np.exp2(rng.integers(-6, 6, (m, k // 16)).astype(np.float64))
    x = grid[rng.integers(0, 7, (m, k))] * np.repeat(micro, 16, axis=1)
    return jnp.asarray(x, jnp.float32)


def test_moment_budget_fully_fp8():
    """A 1024x1024 all-E4M3 moment leaf costs <= 1.05 B/param, logical
    (stats lane + block metadata) and physical (post-compact HBM)."""
    x = jnp.ones((1024, 1024), jnp.float32)  # exact under GAM E4M3
    pm = encode_moment(x, _xla("sub3"), kind=2.0)
    # Every block lands on an fp8 arm (ones are exact in both; the
    # dynamic-range gate picks which) -- 1 B/param payload either way.
    assert float(pm.stats[STAT_FRAC_E4M3] + pm.stats[STAT_FRAC_E5M2]) == 1.0
    logical = float(logical_bytes_per_param(pm))
    physical = physical_bytes_per_param(pm)
    assert logical <= 1.05, logical
    assert physical <= 1.05, physical
    # Round-trip at this scale is exact: ones are representable.
    np.testing.assert_array_equal(np.asarray(decode_moment(pm)),
                                  np.asarray(x))


def test_moment_budget_fully_nvfp4_sub4():
    """A fully-NVFP4 sub4 second moment costs <= 0.65 B/param."""
    x = _nvfp4_exact((1024, 1024))
    pm = encode_moment(x, _xla("sub4"), kind=3.0)
    assert float(pm.stats[STAT_FRAC_NVFP4]) == 1.0  # every block NVFP4
    assert float(logical_bytes_per_param(pm)) <= 0.65
    assert physical_bytes_per_param(pm) <= 0.65


def test_moment_event_kind_stamped():
    from repro.core import EVENT_MOMENT_M, EVENT_MOMENT_V
    from repro.optim import init_opt_state

    params = {"w": jnp.ones((256, 128)), "scale": jnp.ones((64,))}
    opt = init_opt_state(params, moments=_MOMENTS)
    assert isinstance(opt.m["w"], PackedMoment)
    assert isinstance(opt.v["w"], PackedMoment)
    # min_leaf floor: small leaves stay dense f32.
    assert isinstance(opt.m["scale"], jnp.ndarray)
    assert float(opt.m["w"].stats[STAT_EVENT_KIND]) == EVENT_MOMENT_M
    assert float(opt.v["w"].stats[STAT_EVENT_KIND]) == EVENT_MOMENT_V


# ------------------------------------------------------------ sharding --
def test_opt_state_specs_matches_compressed_state():
    """The spec tree mirrors the OptState pytree with PackedMoment
    leaves and the EF residual, so the compressed state ZeRO-shards
    like the dense one did."""
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config, reduced
    from repro.models import init_params
    from repro.optim import init_opt_state
    from repro.sharding import rules as _rules

    cfg = dataclasses.replace(reduced(get_config("llama3-8b")), vocab=64)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, moments=_MOMENTS, ef=True)
    specs = _rules.opt_state_specs(cfg, opt)
    is_p = lambda x: isinstance(x, P)
    assert jax.tree.structure(opt) == jax.tree.structure(
        specs, is_leaf=is_p)
    assert specs.step == P()
    # A packed moment leaf's spec is PackedMoment-shaped with P leaves.
    packed_specs = [
        s for s in jax.tree.leaves(
            specs.m, is_leaf=lambda x: isinstance(x, PackedMoment))
        if isinstance(s, PackedMoment)
    ]
    assert packed_specs, "no packed moment leaves in the spec tree"
    for s in packed_specs:
        assert isinstance(s.mo.tags, P) and isinstance(s.stats, P)
    # EF residual shards like the master weights.
    assert jax.tree.structure(specs.ef) == jax.tree.structure(
        specs.master, is_leaf=is_p)


# ----------------------------------------------------- 4-device identity --
def _run_mesh(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
    )
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_packed_moment_mesh_bit_identity():
    """encode_moment on a 4-device data-sharded mesh emits bit-identical
    payload bytes, tags and GAM scales to the single-device pack: the
    PR-3 allreduced group amax reaches the moment encoder, so a sharded
    optimizer state is byte-for-byte the unsharded one."""
    out = _run_mesh("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.collectives import compat_shard_map
    from repro.core.policy import MoRPolicy
    from repro.optim.moments import encode_moment

    mesh = jax.make_mesh((4,), ('data',))
    r = np.random.default_rng(0)
    base = r.standard_normal((512, 128)) * np.exp2(
        r.integers(-12, 12, (512, 128)))
    x = jnp.asarray(base, jnp.float32)

    for recipe in ('sub3', 'sub4'):
        pol = MoRPolicy(recipe=recipe, backend='xla')
        pm1 = jax.jit(
            lambda a: encode_moment(a, pol, kind=2.0))(x)

        pol_sh = pol.replace(mesh_axes=('data',))

        def body(a):
            pm = encode_moment(a, pol_sh, kind=2.0)
            mo = pm.mo
            return (mo.payload_q, mo.payload_bf16, mo.payload_nib,
                    mo.micro_scales, mo.tags, mo.scales), pm.stats
        sh = P('data', None)
        lanes, s2 = jax.jit(compat_shard_map(
            body, mesh, P('data', None),
            ((sh, sh, sh, sh, sh, sh), P())))(x)
        mo1 = pm1.mo
        # nib/micro lanes are compact don't-care buffers without the
        # NVFP4 arm; byte-compare them only where they are live.
        live = (('payload_q', mo1.payload_q, lanes[0]),
                ('payload_bf16', mo1.payload_bf16, lanes[1]),
                ('tags', mo1.tags, lanes[4]),
                ('scales', mo1.scales, lanes[5]))
        if recipe == 'sub4':
            live += (('payload_nib', mo1.payload_nib, lanes[2]),
                     ('micro_scales', mo1.micro_scales, lanes[3]))
        for name, a, b in live:
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                err_msg=f'{recipe}:{name}')
        cols = [0, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11]
        np.testing.assert_array_equal(
            np.asarray(pm1.stats)[cols], np.asarray(s2)[cols],
            err_msg=recipe)
        print('OK', recipe)
    """)
    assert out.count("OK") == 2, out
