"""FP8 KV cache: decode with quantized cache matches bf16-cache decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import TENSOR_MOR
from repro.models import (
    init_cache,
    init_params,
    make_decode_fn,
    make_tokens,
)
from repro.models.attention import decode_attention, quantize_kv


def test_quantize_kv_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, 4, 32)) * 3, jnp.bfloat16)
    payload, s = quantize_kv(x)
    deq = payload.astype(jnp.float32) / np.asarray(s)[..., None]
    rel = np.abs(deq - np.asarray(x, np.float32)) / (
        np.abs(np.asarray(x, np.float32)) + 1e-3
    )
    assert np.median(rel) < 0.04


def test_decode_attention_fp8_matches_bf16():
    rng = np.random.default_rng(1)
    B, T, Hq, Hkv, dh = 2, 32, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hkv, dh)), jnp.float32)
    cur = jnp.asarray(T - 1, jnp.int32)

    ref = decode_attention(q, k, v, cur)
    kp, ks = quantize_kv(k)
    vp, vs = quantize_kv(v)
    out = decode_attention(q, kp, vp, cur, k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=0.1, atol=0.05,
    )


def test_decode_step_with_fp8_cache():
    cfg = dataclasses.replace(reduced(get_config("llama3-8b")), vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = make_tokens(cfg)
    decode = jax.jit(make_decode_fn(cfg, TENSOR_MOR))

    cache8 = init_cache(cfg, 2, 32, kv_fp8=True)
    cache16 = init_cache(cfg, 2, 32, kv_fp8=False)
    tok = jnp.asarray([[3], [5]], jnp.int32)
    cur = jnp.asarray(4, jnp.int32)

    l8, c8, _ = decode(params, tokens, cache8, tok, cur)
    l16, _, _ = decode(params, tokens, cache16, tok, cur)
    assert np.all(np.isfinite(np.asarray(l8, np.float32)))
    # Caches were empty except the new token: logits should agree closely.
    a = jax.nn.softmax(np.asarray(l8[..., : cfg.vocab], np.float32))
    b = jax.nn.softmax(np.asarray(l16[..., : cfg.vocab], np.float32))
    assert float(np.max(np.abs(a - b))) < 0.05
    # Cache dtypes are FP8 payloads + f32 scales.
    assert c8["dense"]["k"].dtype == jnp.float8_e4m3fn
    assert c8["dense"]["k_scale"].dtype == jnp.float32
