"""Property tests for the compressed training state (satellite 2).

Two properties, each run as a deterministic seeded sweep (always) and a
hypothesis sweep (importorskip-guarded, conftest convention):

* **EF step bound** -- after an error-feedback gradient-compression
  event, the residual of every element is at most one quantization
  step of the representation *its block selected*: with GAM scaling the
  grid spacing of a block is bounded by ``amax_block * C(tag)`` for
      C = {E4M3: 2^-3, E5M2: 2^-2, BF16: 2^-7, NVFP4: 2^-1}
  (top-binade spacing of the 3/2/8-mantissa-bit formats; for NVFP4 the
  E2M1 grid's worst gap of 2 against a micro-scale of group_amax/6),
  plus an underflow floor of the smallest f32 normal: an all-denormal
  block flushes to a zero block under the bf16-ranged scale guard and
  its residual *is* the input. Because EF adds the residual back before
  the next event's selection, this per-event bound is what keeps the
  accumulated error from drifting (the trajectory harness pins the
  norm trend; this pins the per-event contract the trend relies on).

* **Packed-moment parity** -- ``decode_moment(encode_moment(x))`` is
  bit-exact against :func:`mor_quantize` fake-quantization of the same
  bf16-cast 2-D view, for every recipe: the moment store is the *same*
  decision path as the GEMM operands, not a reimplementation.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mor import EVENT_GRAD, mor_quantize, quantize_for_gemm
from repro.core.policy import MoRPolicy
from repro.optim.compress import compress_grads, ef_init, leaf2d
from repro.optim.moments import decode_moment, encode_moment

RECIPES = ("sub2", "sub3", "sub4")
BLOCK = (32, 32)

# Max grid spacing of each representation relative to the block amax
# under GAM scaling (amax -> format amax), see module docstring.
STEP_C = {0: 2.0 ** -3, 1: 2.0 ** -2, 2: 2.0 ** -7, 3: 2.0 ** -1}
# Underflow floor: bf16/f32-normal boundary below which a block's
# values flush to the zero-block path and the residual is the input.
FLOOR = 1.2e-38


def _pol(recipe):
    return MoRPolicy(recipe=recipe, backend="xla", block_shape=BLOCK)


def _assert_step_bound(x2d: np.ndarray, resid2d: np.ndarray, pol):
    """|residual| <= one quantization step of each block's selected
    representation. Tags come from quantize_for_gemm on the identical
    input -- the shared decision path, pinned bit-exact below."""
    mo, _ = quantize_for_gemm(jnp.asarray(x2d), pol)
    tags = np.asarray(mo.tags)
    br, bk = pol.block_shape
    for bi in range(tags.shape[0]):
        for bj in range(tags.shape[1]):
            blk = np.s_[bi * br:(bi + 1) * br, bj * bk:(bj + 1) * bk]
            xb, rb = x2d[blk], resid2d[blk]
            if xb.size == 0:
                continue
            amax = float(np.abs(xb).max())
            bound = amax * STEP_C[int(tags[bi, bj])] + FLOOR
            assert float(np.abs(rb).max()) <= bound, (
                (bi, bj), int(tags[bi, bj]), float(np.abs(rb).max()),
                bound, amax,
            )


def _ef_event(g: np.ndarray, ef: np.ndarray, pol):
    """One EF compression event; returns (quantized, new residual)."""
    tree = {"w": jnp.asarray(g)}
    ef_tree = {"w": jnp.asarray(ef)}
    new_g, new_ef, stats = compress_grads(
        tree, "mor_ef", ef_tree, policy=pol)
    assert float(stats["w"][10]) == EVENT_GRAD
    return np.asarray(new_g["w"]), np.asarray(new_ef["w"])


def _cases(seed=0):
    """Deterministic leaf zoo: dense/wide-range/zero-striped/odd-shaped
    plus the degenerate all-zero, all-denormal, vector and scalar
    leaves."""
    r = np.random.default_rng(seed)
    wide = r.standard_normal((64, 64)) * np.exp2(
        r.integers(-18, 18, (64, 64)))
    striped = r.standard_normal((96, 64))
    striped[32:64] = 0.0
    mixed_denorm = r.standard_normal((64, 64))
    mixed_denorm[:32] = 1e-40
    return {
        "normal": r.standard_normal((64, 96)).astype(np.float32),
        "wide_range": wide.astype(np.float32),
        "zero_stripe": striped.astype(np.float32),
        "all_zero": np.zeros((64, 64), np.float32),
        "all_denormal": np.full((64, 64), 1e-40, np.float32),
        "mixed_denormal": mixed_denorm.astype(np.float32),
        "odd_shape": (r.standard_normal((37, 53)) * 3.0).astype(
            np.float32),
        "vector": r.standard_normal((192,)).astype(np.float32),
        "scalar": np.float32(0.73),
    }


# ------------------------------------------------------ EF step bound --
@pytest.mark.parametrize("recipe", RECIPES)
@pytest.mark.parametrize("case", sorted(_cases()))
def test_ef_residual_one_step_bound(recipe, case):
    g = _cases()[case]
    pol = _pol(recipe)
    q, resid = _ef_event(g, np.zeros_like(g), pol)
    # resid = corrected - quantized by construction (ef_in = 0). XLA
    # flushes f32 denormals to zero, so a denormal leaf's in-jit
    # residual may read 0 where the host-side g - q keeps ~1e-40:
    # allow exactly the underflow floor, nothing above it.
    np.testing.assert_allclose(resid, g - q, rtol=0, atol=FLOOR)
    x2d = np.asarray(leaf2d(jnp.asarray(g)))
    _assert_step_bound(x2d, np.asarray(leaf2d(jnp.asarray(resid))), pol)


@pytest.mark.parametrize("recipe", RECIPES)
def test_ef_bound_holds_across_chained_events(recipe):
    """Five chained EF events on drifting gradients: the bound is
    *per event* on the corrected values -- the residual fed forward
    never escapes one step of the current event's selection."""
    pol = _pol(recipe)
    r = np.random.default_rng(42)
    g = r.standard_normal((64, 64)).astype(np.float32)
    ef = np.zeros_like(g)
    for i in range(5):
        corrected = g + ef
        _, ef = _ef_event(g, ef, pol)
        x2d = np.asarray(leaf2d(jnp.asarray(corrected)))
        _assert_step_bound(
            x2d, np.asarray(leaf2d(jnp.asarray(ef))), pol)
        g = (g + 0.1 * r.standard_normal(g.shape)).astype(np.float32)


def test_ef_all_zero_leaf_residual_is_zero():
    g = np.zeros((64, 64), np.float32)
    q, resid = _ef_event(g, np.zeros_like(g), _pol("sub3"))
    np.testing.assert_array_equal(q, 0.0)
    np.testing.assert_array_equal(resid, 0.0)


# ------------------------------------------------ packed-moment parity --
@pytest.mark.parametrize("recipe", RECIPES)
@pytest.mark.parametrize("case", sorted(_cases()))
def test_packed_moment_decode_bit_exact(recipe, case):
    """decode(encode(x)) == fake-quant of the bf16-cast 2-D view,
    bit for bit: one decision path, not a moment-specific fork."""
    x = jnp.asarray(_cases()[case])
    pol = _pol(recipe)
    pm = encode_moment(x, pol, kind=2.0)
    ref2d, _ = mor_quantize(leaf2d(x).astype(jnp.bfloat16), pol)
    ref = np.asarray(ref2d.astype(jnp.float32)).reshape(np.shape(x))
    np.testing.assert_array_equal(np.asarray(decode_moment(pm)), ref)


# ------------------------------------------------- hypothesis sweeps --
def _leaf_strategy(st):
    shapes = st.tuples(st.integers(1, 80), st.integers(1, 80))
    exps = st.integers(-30, 30)

    @st.composite
    def leaves(draw):
        shape = draw(shapes)
        seed = draw(st.integers(0, 2 ** 16))
        exp = draw(exps)
        zero_rows = draw(st.booleans())
        r = np.random.default_rng(seed)
        x = r.standard_normal(shape) * np.exp2(exp)
        if zero_rows and shape[0] > 2:
            x[: shape[0] // 3] = 0.0
        return x.astype(np.float32)

    return leaves()


def test_ef_step_bound_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(g=_leaf_strategy(st),
           recipe=st.sampled_from(RECIPES))
    def prop(g, recipe):
        pol = _pol(recipe)
        _, resid = _ef_event(g, np.zeros_like(g), pol)
        x2d = np.asarray(leaf2d(jnp.asarray(g)))
        _assert_step_bound(
            x2d, np.asarray(leaf2d(jnp.asarray(resid))), pol)

    prop()


def test_packed_moment_parity_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(x=_leaf_strategy(st),
           recipe=st.sampled_from(RECIPES))
    def prop(x, recipe):
        xj = jnp.asarray(x)
        pol = _pol(recipe)
        pm = encode_moment(xj, pol, kind=3.0)
        ref2d, _ = mor_quantize(leaf2d(xj).astype(jnp.bfloat16), pol)
        np.testing.assert_array_equal(
            np.asarray(decode_moment(pm)),
            np.asarray(ref2d.astype(jnp.float32)).reshape(x.shape))

    prop()
