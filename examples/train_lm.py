"""End-to-end training driver: train an LM with MoR mixed-precision,
checkpointing, restart tolerance, and MoR statistics.

Presets:
  tiny  (~2M params, 50 steps)   -- seconds; CI smoke.
  small (~25M params, 200 steps) -- minutes on CPU.
  100m  (~100M params, 300 steps)-- the deliverable-scale run (hours on
                                     CPU; minutes on one accelerator).

    PYTHONPATH=src python examples/train_lm.py --preset tiny \
        --arch llama3-8b --policy mor_block --ckpt /tmp/mor_ckpt
"""
import argparse
import dataclasses
import json

import numpy as np

from repro.configs import get_config, reduced
from repro.core import BF16_BASELINE, paper_default
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig, TrainConfig

PRESETS = {
    # name: (d_model, n_layers, n_heads, d_ff, vocab, seq, batch, steps)
    "tiny": (128, 2, 4, 384, 512, 128, 8, 50),
    "small": (320, 6, 8, 1024, 2048, 256, 8, 200),
    "100m": (640, 12, 10, 2048, 8192, 512, 8, 300),
}


def build_cfg(arch: str, preset: str):
    d, L, H, f, v, seq, batch, steps = PRESETS[preset]
    base = reduced(get_config(arch))
    kv = 1 if base.n_kv == 1 else max(2, H // 4)
    cfg = dataclasses.replace(
        base,
        name=f"{arch}-{preset}",
        d_model=d,
        n_layers=L * len(base.unit),
        n_heads=H,
        n_kv=kv,
        head_dim=d // H,
        d_ff=0 if base.d_ff == 0 else f,
        vocab=v,
        n_experts=min(base.n_experts, 8),
        top_k=min(base.top_k, 2),
    )
    return cfg, seq, batch, steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--preset", default="tiny", choices=PRESETS)
    ap.add_argument("--policy", default="mor_block",
                    choices=["bf16", "mor_block", "mor_tensor",
                             "mor_channel", "sub2", "sub3", "sub4"])
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg, seq, batch, steps = build_cfg(args.arch, args.preset)
    steps = args.steps or steps
    if args.policy == "bf16":
        policy = BF16_BASELINE
    elif args.policy.startswith("mor_"):
        policy = paper_default(partition=args.policy.split("_")[1])
    else:
        policy = paper_default(args.policy)

    n_params = cfg.param_count()
    print(f"arch={cfg.name} params~{n_params/1e6:.1f}M policy={args.policy} "
          f"steps={steps} seq={seq} batch={batch}")

    trainer = Trainer(
        cfg,
        policy,
        TrainConfig(
            optimizer=AdamWConfig(
                peak_lr=args.lr, final_lr=args.lr / 10,
                warmup_steps=max(steps // 20, 5), total_steps=steps,
            )
        ),
        TrainerConfig(
            total_steps=steps,
            ckpt_dir=args.ckpt,
            ckpt_every=max(steps // 4, 10),
            log_every=10,
        ),
        DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch),
    )
    out = trainer.run()
    hist = out["history"]
    for h in hist[:: max(len(hist) // 20, 1)]:
        print(
            f"step {h['step']:5d}  loss {h['loss']:.4f}  "
            f"dt {h['dt']*1e3:7.1f}ms  fwd_bf16 {h['fwd_bf16']*100:5.1f}%  "
            f"bwd_bf16 {h['bwd_bf16']*100:5.1f}%"
        )
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    print(f"\nloss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    print(json.dumps({"final_loss": last, "steps": out["final_step"]}))


if __name__ == "__main__":
    main()
