"""Serve a small LM with batched requests, MoR-quantized (real FP8)
weights, and continuous batching.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma-2b --requests 6
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import MoRPolicy, TENSOR_MOR
from repro.models import init_params
from repro.serve import Engine, Request, ServeConfig, quantize_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(get_config(args.arch)), vocab=512)
    params = init_params(cfg, jax.random.PRNGKey(0))

    # Ahead-of-time MoR decision -> real FP8 storage for accepted weights.
    qparams, qstats = quantize_params(
        params, MoRPolicy(recipe="tensor"), min_size=1024
    )
    n_q = sum(s["quantized"] for s in qstats.values())
    print(f"weights quantized to FP8 storage: {int(n_q)}/{len(qstats)} "
          f"({100 * n_q / max(len(qstats), 1):.1f}%)")
    bytes_bf16 = sum(
        l.size * 2 for l in jax.tree.leaves(params) if hasattr(l, "size")
    )
    print(f"weight bytes bf16={bytes_bf16/1e6:.2f}MB -> "
          f"fp8-mixed~{bytes_bf16 * (1 - 0.5 * n_q / max(len(qstats),1))/1e6:.2f}MB")

    eng = Engine(cfg, TENSOR_MOR, params,
                 ServeConfig(slots=args.slots, max_seq=128))
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_tokens=args.max_tokens)
        for i in range(args.requests)
    ]
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    steps = eng.run_to_completion()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in reqs)
    print(f"{args.requests} requests, {total_tokens} tokens in {steps} "
          f"decode steps, {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s on CPU)")
    for r in reqs[:3]:
        print(f"req {r.rid}: prompt={r.prompt[:4].tolist()}... "
              f"-> {r.out}")


if __name__ == "__main__":
    main()
