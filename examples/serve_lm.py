"""Serve a small LM with batched requests, MoR-quantized (real FP8)
weights, and continuous batching.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma-2b --requests 6
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import MoRPolicy, TENSOR_MOR
from repro.models import init_params
from repro.serve import Engine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(get_config(args.arch)), vocab=512)
    params = init_params(cfg, jax.random.PRNGKey(0))
    bytes_bf16 = sum(
        l.size * 2 for l in jax.tree.leaves(params) if hasattr(l, "size")
    )

    # Ahead-of-time per-block MoR decision -> sub-tensor QTensor storage;
    # every matmul against a quantized weight runs through the
    # mixed-representation block GEMM kernel.
    eng = Engine(cfg, TENSOR_MOR, params,
                 ServeConfig(slots=args.slots, max_seq=128),
                 quantize=MoRPolicy(recipe="sub3"), quantize_min_size=1024)
    qstats = eng.qstats or {}
    n_q = sum(s["quantized"] for s in qstats.values())
    print(f"weights quantized to mixed fp8 storage: {int(n_q)}/{len(qstats)} "
          f"({100 * n_q / max(len(qstats), 1):.1f}%)")
    bytes_mixed = sum(
        l.size * l.dtype.itemsize for l in jax.tree.leaves(eng.params)
        if hasattr(l, "size")
    )
    print(f"param bytes bf16={bytes_bf16/1e6:.2f}MB -> "
          f"mixed={bytes_mixed/1e6:.2f}MB (actual stored bytes)")
    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab, 8).astype(np.int32),
                max_tokens=args.max_tokens)
        for i in range(args.requests)
    ]
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    steps = eng.run_to_completion()
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in reqs)
    print(f"{args.requests} requests, {total_tokens} tokens in {steps} "
          f"decode steps, {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s on CPU)")
    for r in reqs[:3]:
        print(f"req {r.rid}: prompt={r.prompt[:4].tolist()}... "
              f"-> {r.out}")


if __name__ == "__main__":
    main()
