"""Quickstart: MoR-quantize tensors and watch the dynamic decisions.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    E4M3,
    MoRPolicy,
    compute_scales,
    mor_dot,
    mor_quantize,
    new_token,
    paper_default,
    relative_error,
)
from repro.core.partition import PER_BLOCK_128


def main():
    rng = np.random.default_rng(0)

    print("=== GAM scaling (Algorithm 1) ===")
    x = jnp.asarray(rng.standard_normal((256, 256)) * 5, jnp.float32)
    sc = compute_scales(x, PER_BLOCK_128, E4M3)
    print(f"group amax      : {float(sc.group_amax):.4f}")
    print(f"group mantissa  : {float(sc.group_mantissa):.7f}  (in [1,2))")
    print(f"block exponents : {np.asarray(sc.block_exp).ravel()}")
    print("no-saturation   :",
          bool(np.all(np.asarray(sc.scale) * float(sc.group_amax)
                      <= E4M3.amax * 1.000001)))

    print("\n=== Tensor-level MoR decision (Algorithm 2, Eq. 2) ===")
    pol = MoRPolicy(recipe="tensor", partition="block")
    for name, t in (
        ("well-scaled gaussian", x),
        ("wide-dynamic-range",
         jnp.asarray(np.exp2(rng.uniform(-30, 30, (256, 256))),
                     jnp.float32)),
    ):
        y, stats = mor_quantize(t, pol)
        dec = "E4M3" if stats[0] == 1 else "BF16 (fallback)"
        print(f"{name:22s}: rel_err={float(stats[1])*100:6.2f}%  -> {dec}")

    print("\n=== MoR-quantized GEMM (fwd + bwd quantization) ===")
    a = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 32)), jnp.float32)

    def loss(a, w, tok):
        y, _ = mor_dot(a, w, tok, paper_default())
        return jnp.sum(y * y)

    g_a, g_w, g_tok = jax.grad(loss, argnums=(0, 1, 2))(a, w, new_token())
    exact = np.asarray(a) @ np.asarray(w)
    y, stats = mor_dot(a, w, new_token(), paper_default())
    err = relative_error(jnp.asarray(exact), y)
    print(f"GEMM output rel-err vs f32: {float(err)*100:.2f}%")
    print(f"fwd events  (act, weight) decisions: "
          f"{np.asarray(stats)[:, 0].tolist()}")
    print(f"bwd events rel-errs (dy, w, x^T, dy^T): "
          f"{[round(float(v), 4) for v in np.asarray(g_tok)[:, 1]]}")


if __name__ == "__main__":
    main()
