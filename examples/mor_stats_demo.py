"""MoR decision-dynamics demo (paper §4.1.3): train a tiny model and
render the per-tensor relative-error heatmap + BF16 fallback stats.

    PYTHONPATH=src python examples/mor_stats_demo.py --steps 40
"""
import argparse

from benchmarks.bench_fig11 import main as fig11_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()
    rows, heat = fig11_main(steps=args.steps)
    print()
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
