"""Deterministic, seed-keyed fault injection (the chaos harness).

Every fault class the guard rails claim to survive is registered here,
so the differential chaos suite (tests/test_robust_chaos.py) and the
``kernel/robust_guard`` bench lane can *enumerate* the classes -- a new
injector without a test asserting detection + containment shows up as
a coverage gap, and `compare.py` gates the registered/covered counts
against shrinking.

All injectors are pure functions of (object, seed): the same seed
always corrupts the same leaf/byte/bit, so an injected failure
reproduces exactly and the differential assertions ("every other
slot's tokens are bit-identical to the uninjected run") are meaningful.

Layers:

- ``train``: poison a gradient tree (NaN/Inf leaves) -- exercises the
  BF16 selection arm through gradient compression and the optimizer
  skip-step rung.
- ``pack``: corrupt a :class:`~repro.kernels.ref.MixedOperand` after
  packing (payload bit-flips, scale / micro-scale corruption) --
  exercises decode-side containment and the serve quarantine.
- ``quant``: stale group amax -- exercises the bounded re-encode
  retry (:func:`repro.robust.guard.requantize_with_backoff`).
- ``serve``: trash live KV pages in a :class:`PagedKVPool` --
  exercises the engine's slot quarantine.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FaultSpec",
    "register_fault",
    "fault_names",
    "fault_specs",
    "get_fault",
    "poison_tree",
    "make_grad_fault",
]


class FaultSpec(NamedTuple):
    name: str
    layer: str  # train | pack | quant | serve
    description: str
    inject: Callable


_REGISTRY: Dict[str, FaultSpec] = {}


def register_fault(name: str, layer: str, description: str):
    """Decorator: add an injector to the fault-class registry."""

    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"duplicate fault class {name!r}")
        _REGISTRY[name] = FaultSpec(name, layer, description, fn)
        return fn

    return deco


def fault_names() -> Tuple[str, ...]:
    """All registered fault-class names, registration-ordered."""
    return tuple(_REGISTRY)


def fault_specs() -> Tuple[FaultSpec, ...]:
    return tuple(_REGISTRY.values())


def get_fault(name: str) -> FaultSpec:
    return _REGISTRY[name]


def _pick_leaf(leaves, seed: int):
    """Deterministic (leaf index, flat element index) among the float
    leaves of a flattened tree."""
    rng = np.random.default_rng(seed)
    cands = [
        i for i, leaf in enumerate(leaves)
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact)
        and leaf.size > 0
    ]
    if not cands:
        raise ValueError("tree has no non-empty float leaves to poison")
    k = cands[int(rng.integers(len(cands)))]
    return k, int(rng.integers(leaves[k].size))


def poison_tree(tree, value, seed: int = 0):
    """Set one seed-keyed element of one float leaf to ``value``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    k, idx = _pick_leaf(leaves, seed)
    leaf = jnp.asarray(leaves[k])
    flat = leaf.reshape(-1).at[idx].set(
        jnp.asarray(value, jnp.float32).astype(leaf.dtype)
    )
    leaves[k] = flat.reshape(leaf.shape)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def make_grad_fault(kind: str = "nan", seed: int = 0):
    """A jit-stable gradient-poisoning hook for ``make_train_step``.

    The returned ``hook(grads, batch)`` poisons one seed-keyed element
    when the (traced) scalar ``batch['inject']`` is nonzero and is the
    identity otherwise -- the leaf/element choice is host-side static,
    so one compiled train step serves clean and injected steps and a
    trajectory can flip faults on per-step from the batch stream.
    """
    bad = {"nan": np.nan, "inf": np.inf}[kind]

    def hook(grads, batch):
        flag = batch.get("inject")
        if flag is None:
            return grads
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        k, idx = _pick_leaf(leaves, seed)
        leaf = jnp.asarray(leaves[k])
        poisoned = leaf.reshape(-1).at[idx].set(
            jnp.asarray(bad, jnp.float32).astype(leaf.dtype)
        ).reshape(leaf.shape)
        fire = jnp.any(jnp.asarray(flag) > 0)
        leaves[k] = jnp.where(fire, poisoned, leaf)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    return hook


@register_fault(
    "grad_nan", "train",
    "one gradient element becomes NaN (e.g. 0/0 in a fused loss) -- "
    "must be preserved through compression's BF16 arm and dropped by "
    "the optimizer skip-step",
)
def inject_grad_nan(grads, seed: int = 0):
    return poison_tree(grads, np.nan, seed)


@register_fault(
    "grad_inf", "train",
    "one gradient element overflows to +Inf -- must not poison the "
    "Alg. 1 group mantissa of clean blocks and must be dropped by the "
    "optimizer skip-step",
)
def inject_grad_inf(grads, seed: int = 0):
    return poison_tree(grads, np.inf, seed)


@register_fault(
    "payload_bitflip", "pack",
    "one bit of the fp8 payload lane flips (bus/HBM upset) -- decodes "
    "to a wrong-but-finite or NaN value; containment is the consumer's "
    "nonfinite checks (skip-step / quarantine), detection the guard "
    "counters downstream",
)
def inject_payload_bitflip(mo, seed: int = 0):
    rng = np.random.default_rng(seed)
    pay = mo.payload_q
    idx = int(rng.integers(pay.size))
    bit = np.uint8(1 << int(rng.integers(8)))
    flat = pay.reshape(-1)
    flat = flat.at[idx].set(flat[idx] ^ bit)
    return dataclasses.replace(mo, payload_q=flat.reshape(pay.shape))


@register_fault(
    "scale_corrupt", "pack",
    "one per-block GAM scale becomes NaN (corrupted scale buffer) -- "
    "every element of that block decodes nonfinite. (An *Inf* scale "
    "would decode to silent zeros -- dequant divides by the scale -- "
    "which no finiteness guard can see; catching that class needs "
    "payload checksums, out of scope here.)",
)
def inject_scale_corrupt(mo, seed: int = 0):
    rng = np.random.default_rng(seed)
    sc = mo.scales
    idx = int(rng.integers(sc.size))
    flat = sc.reshape(-1).at[idx].set(jnp.float32(np.nan))
    return dataclasses.replace(mo, scales=flat.reshape(sc.shape))


@register_fault(
    "micro_scale_corrupt", "pack",
    "one NVFP4 micro-scale byte becomes 0xFF (an E4M3 NaN bit "
    "pattern) -- the micro-group decodes NaN",
)
def inject_micro_scale_corrupt(mo, seed: int = 0):
    rng = np.random.default_rng(seed)
    ms = mo.micro_scales
    if ms.size == 0:
        raise ValueError("operand has no micro-scale lane to corrupt")
    idx = int(rng.integers(ms.size))
    flat = ms.reshape(-1).at[idx].set(jnp.uint8(0xFF))
    return dataclasses.replace(mo, micro_scales=flat.reshape(ms.shape))


@register_fault(
    "stale_amax", "quant",
    "the group amax driving the scales is a stale history value that "
    "under-covers the live tensor -- the saturating cast would "
    "silently clip; the bounded re-encode retry must widen or fall "
    "back to BF16 with GUARD_STALE_SCALE",
)
def inject_stale_amax(amax, seed: int = 0, shrink: float = 8.0):
    del seed  # the staleness factor is the whole fault
    return jnp.asarray(amax, jnp.float32) / jnp.float32(shrink)


@register_fault(
    "kv_page_trash", "serve",
    "a live KV page's lanes are overwritten with garbage (NaN floats, "
    "0xFF payload bytes = fp8 NaN) -- the owning slot's decode emits "
    "nonfinite logits and must be quarantined without perturbing any "
    "other slot's tokens",
)
def inject_kv_page_trash(pool, page: int, seed: int = 0):
    """Host-side, in-place on the pool's leaves (mirrors how the engine
    owns its pool). Integer tag lanes are left alone: the fault models
    data corruption the *guard* must catch, not an impossible tag."""
    del seed  # whole-page trash: position within the page is moot
    for i, (key, paged) in enumerate(zip(pool._keys, pool._paged)):
        if not paged:
            continue
        leaf = pool._leaves[i]
        if jnp.issubdtype(leaf.dtype, jnp.inexact):
            bad = jnp.asarray(np.nan, jnp.float32).astype(leaf.dtype)
        elif leaf.dtype == jnp.uint8:
            bad = jnp.uint8(0xFF)
        else:
            continue
        pool._leaves[i] = leaf.at[:, page].set(bad)
