"""Numerics guard rails and fault injection (docs/robustness.md).

Two halves, one subsystem:

- :mod:`repro.robust.guard` -- the *containment* side. ``GuardPolicy``
  configures the escalation ladder (block BF16 fallback -> tensor BF16
  fallback -> optimizer skip-step -> bounded re-encode retry) whose
  detection signals ride the stats guard lanes emitted by
  ``repro.core.mor`` (layout v4, lanes [12]/[13]) at zero extra
  operand-sized cost on the clean path.
- :mod:`repro.robust.faults` -- the *adversary* side. A deterministic,
  seed-keyed fault-injection registry (NaN/Inf gradients, payload
  bit-flips, scale corruption, stale amaxes, trashed KV pages) that the
  differential chaos suite (tests/test_robust_chaos.py) and the
  ``kernel/robust_guard`` bench lane enumerate, so every registered
  fault class is provably detected, contained, and reported.
"""
from .guard import (
    GuardPolicy,
    guard_flag_set,
    requantize_with_backoff,
    tree_select,
)
from .faults import (
    FaultSpec,
    fault_names,
    fault_specs,
    get_fault,
    make_grad_fault,
    poison_tree,
)

__all__ = [
    "GuardPolicy",
    "guard_flag_set",
    "requantize_with_backoff",
    "tree_select",
    "FaultSpec",
    "fault_names",
    "fault_specs",
    "get_fault",
    "make_grad_fault",
    "poison_tree",
]
