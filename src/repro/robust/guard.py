"""Nonfinite containment: the guard policy and its escalation ladder.

The detection signals are free: every quantization event already
computes a group amax and per-block error sums, and a NaN/Inf element
forces both nonfinite (max/sum propagate). ``repro.core.mor`` turns
them into the layout-v4 stats guard lanes ([12] guard_flags,
[13] fallback_count) with scalar/block-grid arithmetic only -- the
'robust_guard_event' analysis contract asserts the clean path lowers
to zero additional operand-sized HLO passes.

Containment escalates through four rungs (docs/robustness.md):

1. **Block BF16 fallback** (structural, always on): the sub-tensor
   selection's error comparisons route a poisoned block to the BF16
   arm -- NaN compares False against every fp8 candidate and an Inf
   error sum exceeds any acceptance gate -- so the original bytes
   (poison included) are preserved verbatim instead of being laundered
   through a saturating fp8 cast.
2. **Tensor BF16 fallback** (structural, always on): the tensor-level
   recipe's global accept test ``err < threshold`` is False for a
   nonfinite error, degrading the whole event to passthrough.
3. **Skip-step** (``GuardPolicy.skip_nonfinite_updates``): a nonfinite
   global grad norm makes :func:`repro.optim.adamw.adamw_update` keep
   master weights, both Adam moments (packed lanes bit-exact) and the
   step counter, and ``train_step`` keep the EF residuals -- the
   poisoned update is dropped whole, with no EF double-count.
4. **Bounded re-encode retry** (:func:`requantize_with_backoff`): a
   delayed/stale scale that under-covers the operand is widened
   through ``max_requant_retries`` amax doublings; if the ladder still
   cannot cover, the event falls back to BF16 and flags
   ``GUARD_STALE_SCALE``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import E4M3, FormatSpec, cast_to_format
from repro.core.gam import exp2i
from repro.core.mor import (
    EVENT_GEMM,
    GUARD_NONFINITE_AMAX,
    GUARD_STALE_SCALE,
    STAT_AMAX,
    STAT_DECISION,
    STAT_EVENT_KIND,
    STAT_FRAC_BF16,
    STAT_FRAC_E4M3,
    STAT_GROUP_MANTISSA,
    STAT_GUARD_FLAGS,
    STAT_PAYLOAD_BPE,
    STATS_WIDTH,
)

__all__ = [
    "GuardPolicy",
    "guard_flag_set",
    "tree_select",
    "requantize_with_backoff",
]


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    """Configuration for the optimizer-level rungs of the ladder.

    Rungs 1-2 (block/tensor BF16 fallback) are structural properties of
    the selection math and are always on; this policy only governs what
    the training step does when poison reaches the update.
    """

    # Rung 3: drop a whole optimizer update when the (already computed)
    # global grad norm is nonfinite, preserving master weights, packed
    # moments, EF residuals and the step counter bit-exactly.
    skip_nonfinite_updates: bool = True
    # Rung 4: amax doublings requantize_with_backoff may spend before
    # declaring a stale scale unrecoverable and falling back to BF16.
    max_requant_retries: int = 2


def guard_flag_set(guard_flags, flag) -> jnp.ndarray:
    """True where the power-of-two ``flag`` is set in a guard_flags
    lane value (flags are sums of distinct powers of two, stored f32).

    >>> import jax.numpy as jnp
    >>> from repro.core.mor import GUARD_NONFINITE_AMAX, GUARD_BLOCK_FALLBACK
    >>> bool(guard_flag_set(jnp.float32(3.0), GUARD_BLOCK_FALLBACK))
    True
    >>> bool(guard_flag_set(jnp.float32(4.0), GUARD_NONFINITE_AMAX))
    False
    """
    f = jnp.asarray(guard_flags, jnp.float32)
    return jnp.mod(jnp.floor_divide(f, jnp.float32(flag)), 2.0) >= 1.0


def tree_select(ok, new_tree, old_tree):
    """Per-leaf ``where(ok, new, old)`` over two same-structure trees.

    ``ok`` is a scalar bool. ``select`` picks *values*, so NaN/Inf in
    the untaken branch never propagates -- the skip-step rung relies on
    this to return a bit-exact old state (uint8/nibble payload lanes
    included) when an update is dropped.
    """
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(ok, n, o.astype(n.dtype)), new_tree, old_tree
    )


def requantize_with_backoff(
    x2d: jnp.ndarray,
    stale_amax,
    *,
    fmt: FormatSpec = E4M3,
    max_retries: int = 2,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Rung 4: encode under a delayed (possibly stale) amax, with a
    bounded widening retry collapsed into one pass.

    Delayed scaling (the ROADMAP item this rung is the safety net for)
    derives the tensor scale ``s = fmt.amax / stale_amax`` from a
    *previous* step's statistics; when the live tensor has outgrown the
    stale amax, the saturating cast silently clips its tail. Instead of
    re-encoding up to ``max_retries`` times, the ladder of candidate
    amaxes ``stale_amax * 2**[0..max_retries]`` is evaluated against
    the true amax with scalar arithmetic only, and the single encode
    runs at the smallest covering rung. If even the widest rung cannot
    cover (or the operand is nonfinite), the event falls back to BF16
    passthrough and flags ``GUARD_STALE_SCALE``.

    Returns ``(y, stats, attempts)``: the fake-quantized (or
    passthrough) f32 tensor, a layout-v4 stats row, and the number of
    doublings spent (0 = the stale amax still covered; ``max_retries``
    on fallback).

    >>> import jax.numpy as jnp
    >>> x = jnp.linspace(-1.0, 1.0, 64).reshape(8, 8)
    >>> y, stats, attempts = requantize_with_backoff(x, jnp.float32(1.0))
    >>> int(attempts)       # fresh amax covers: no retry
    0
    >>> y, stats, attempts = requantize_with_backoff(x, jnp.float32(0.3))
    >>> int(attempts)       # 0.3 -> 0.6 -> 1.2 covers amax 1.0
    2
    """
    xf = x2d.astype(jnp.float32)
    true_amax = jnp.max(jnp.abs(xf))
    stale = jnp.asarray(stale_amax, jnp.float32)
    ladder = stale * exp2i(jnp.arange(max_retries + 1, dtype=jnp.int32))
    covered = ladder >= true_amax
    recoverable = (
        jnp.any(covered) & jnp.isfinite(true_amax)
        & jnp.isfinite(stale) & (stale > 0)
    )
    # First covering rung (argmax of the monotone mask); pinned to the
    # top rung when nothing covers so `attempts` reports the full spend.
    attempts = jnp.where(
        recoverable,
        jnp.argmax(covered).astype(jnp.int32),
        jnp.int32(max_retries),
    )
    eff_amax = jnp.where(recoverable, ladder[attempts], jnp.float32(1.0))
    s = fmt.amax / eff_amax
    y = jnp.where(recoverable, cast_to_format(xf * s, fmt) / s, xf)

    # A nonfinite *stale* amax is a corrupted scale buffer, not mere
    # staleness -- flag it like nonfinite data so the two failure modes
    # stay distinguishable from a plain out-of-range event.
    amax_ok = jnp.isfinite(true_amax) & jnp.isfinite(stale)
    flags = (
        jnp.where(amax_ok, 0.0, GUARD_NONFINITE_AMAX)
        + jnp.where(recoverable, 0.0, GUARD_STALE_SCALE)
    )
    okf = recoverable.astype(jnp.float32)
    stats = (
        jnp.zeros((STATS_WIDTH,), jnp.float32)
        .at[STAT_DECISION].set(okf)
        .at[STAT_AMAX].set(true_amax)
        .at[STAT_FRAC_E4M3].set(okf)
        .at[STAT_FRAC_BF16].set(1.0 - okf)
        .at[STAT_GROUP_MANTISSA].set(1.0)
        .at[STAT_EVENT_KIND].set(EVENT_GEMM)
        .at[STAT_PAYLOAD_BPE].set(okf + 2.0 * (1.0 - okf))
        .at[STAT_GUARD_FLAGS].set(flags)
    )
    return y, stats, attempts
