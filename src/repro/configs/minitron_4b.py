"""minitron-4b: pruned Nemotron (squared-ReLU MLP). [arXiv:2407.14679; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv=8, head_dim=128,
    d_ff=9216, vocab=256000, unit=("dense",), act="relu2",
    rope_theta=10000.0,
))
