"""moonshot-v1-16b-a3b (Moonlight-16B-A3B): 64-expert top-6 MoE.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
    d_ff=1408, vocab=163840, unit=("moe",), act="swiglu",
    n_experts=64, top_k=6, rope_theta=50000.0,
))
