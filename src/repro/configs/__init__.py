from .base import (
    SHAPES,
    ArchConfig,
    ShapeConfig,
    cell_is_runnable,
    get_config,
    input_specs,
    list_archs,
    reduced,
    register,
)

__all__ = [
    "SHAPES", "ArchConfig", "ShapeConfig", "cell_is_runnable", "get_config",
    "input_specs", "list_archs", "reduced", "register",
]
