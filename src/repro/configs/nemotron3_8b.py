"""nemotron3-8b: the paper's experiment model (dense, squared-ReLU MLP,
MHA). Used for the paper-faithful quality benchmarks. [NGC nemotron-3-8b]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="nemotron3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=32, head_dim=128,
    d_ff=16384, vocab=256000, unit=("dense",), act="relu2",
    rope_theta=10000.0,
))
