"""Architecture configs, input shapes, and ShapeDtypeStruct input specs.

Every assigned architecture is a frozen :class:`ArchConfig`; the registry
maps ``--arch <id>`` names to configs. ``input_specs`` builds the
allocation-free ShapeDtypeStruct stand-ins the multi-pod dry-run lowers
against. ``reduced()`` produces the CPU-smoke-test downscale of the same
family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "register", "get_config",
    "list_archs", "reduced", "input_specs", "cell_is_runnable",
]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'audio' | 'ssm' | 'vlm' | 'hybrid'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    # Block composition: the repeating unit of layer types; the full stack is
    # unit * (n_layers // len(unit)). Types: 'dense' (attn+mlp), 'moe'
    # (attn+moe), 'mlstm', 'slstm', 'hymba' (parallel attn+ssm, +mlp).
    unit: Tuple[str, ...] = ("dense",)
    act: str = "swiglu"  # 'swiglu' | 'geglu' | 'gelu' | 'relu2'
    norm: str = "rms"  # 'rms' | 'ln'
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    d_inner: int = 0  # mamba inner dim (0 => 2*d_model)
    conv_width: int = 4
    # Enc-dec (whisper): encoder layers + stub-frontend frame count.
    enc_layers: int = 0
    enc_seq: int = 0
    # VLM (paligemma): stub-frontend patch-token count (bidirectional prefix).
    img_tokens: int = 0
    # Hymba sliding-window size used by attention for the long_500k shape.
    window: int = 0
    tie_embed: bool = False
    # True when sequence mixing is sub-quadratic (may run long_500k).
    subquadratic: bool = False
    dtype: str = "bfloat16"

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.unit)

    @property
    def mamba_d_inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hq, hkv, hd = self.n_heads, self.n_kv, self.head_dim
        per_type = {}
        attn = d * (hq + 2 * hkv) * hd + hq * hd * d
        gated = self.act in ("swiglu", "geglu")
        mlp = d * f * (3 if gated else 2)
        per_type["dense"] = attn + mlp
        per_type["moe"] = attn + self.n_experts * d * f * (
            3 if gated else 2
        ) + d * self.n_experts
        di = self.mamba_d_inner
        per_type["hymba"] = (
            attn + mlp + 2 * d * di + di * d
            + di * (2 * self.ssm_state + 2) + di * self.conv_width
        )
        per_type["mlstm"] = 2 * d * (2 * d) + (2 * d) * d + 3 * d
        per_type["slstm"] = 8 * d * d // max(self.n_heads, 1) * self.n_heads
        total = 0
        for t in self.unit:
            total += per_type.get(t, per_type["dense"]) * self.n_units
        total += v * d * (1 if self.tie_embed else 2)
        total += self.enc_layers * (attn + mlp)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        gated = self.act in ("swiglu", "geglu")
        dense_experts = self.n_experts * d * f * (3 if gated else 2)
        active_experts = self.top_k * d * f * (3 if gated else 2)
        return self.param_count() - (
            dense_experts - active_experts
        ) * self.n_units


_REGISTRY: Dict[str, ArchConfig] = {}

_ARCH_MODULES = [
    "moonshot_v1_16b_a3b", "granite_moe_1b_a400m", "gemma_2b",
    "deepseek_coder_33b", "llama3_8b", "minitron_4b", "whisper_tiny",
    "xlstm_350m", "paligemma_3b", "hymba_1_5b", "nemotron3_8b",
]


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _ensure_loaded() -> None:
    if len(_REGISTRY) >= len(_ARCH_MODULES):
        return
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def cell_is_runnable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch x shape) is a defined dry-run cell (see
    repro.launch.dryrun)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention (skip noted)"
    return True, ""


def reduced(cfg: ArchConfig) -> ArchConfig:
    """CPU-smoke-scale downscale preserving the family's structure."""
    kv = 1 if cfg.n_kv == 1 else 2
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=2 * len(cfg.unit) if len(cfg.unit) > 1 else 2,
        d_model=64,
        n_heads=4,
        n_kv=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 96,
        vocab=512,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        d_inner=128 if cfg.family in ("hybrid",) else 0,
        enc_layers=min(cfg.enc_layers, 2),
        enc_seq=min(cfg.enc_seq, 16) if cfg.enc_seq else 0,
        img_tokens=min(cfg.img_tokens, 8) if cfg.img_tokens else 0,
        window=min(cfg.window, 8) if cfg.window else 0,
    )


def _frontend_specs(cfg: ArchConfig, batch: int):
    """Stub modality-frontend inputs (precomputed embeddings)."""
    dt = jnp.bfloat16
    extras = {}
    if cfg.family == "audio":
        extras["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_seq, cfg.d_model), dt
        )
    if cfg.family == "vlm":
        extras["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.img_tokens, cfg.d_model), dt
        )
    return extras


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train  -> {'tokens', 'labels', frontends...}
    prefill-> {'tokens', frontends...}
    decode -> {'token', 'cur_index'}; the KV/state cache specs come from
              repro.models.api.cache_specs (they depend on layer structure).
    """
    b, s = shape.global_batch, shape.seq_len
    specs = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs.update(_frontend_specs(cfg, b))
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs.update(_frontend_specs(cfg, b))
    elif shape.kind == "decode":
        specs["token"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        # One position per slot: mixed-length continuous batching reads
        # and writes each row at its own position (docs/serving.md).
        specs["cur_index"] = jax.ShapeDtypeStruct((b,), jnp.int32)
    else:
        raise ValueError(shape.kind)
    return specs
