"""whisper-tiny: enc-dec; conv frontend is a stub (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv=6, head_dim=64,
    d_ff=1536, vocab=51865, unit=("dense",), act="gelu", norm="ln",
    enc_layers=4, enc_seq=1500, tie_embed=True,
))
