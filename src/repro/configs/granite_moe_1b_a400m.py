"""granite-3.0-1b-a400m: 32-expert top-8 MoE.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv=8, head_dim=64,
    d_ff=512, vocab=49155, unit=("moe",), act="swiglu",
    n_experts=32, top_k=8, rope_theta=10000.0, tie_embed=True,
))
