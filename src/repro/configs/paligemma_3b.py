"""paligemma-3b: SigLIP stub (precomputed patch embeddings) + gemma
backbone; image prefix attends bidirectionally. [arXiv:2407.07726; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv=1, head_dim=256,
    d_ff=16384, vocab=257216, unit=("dense",), act="geglu",
    rope_theta=10000.0, img_tokens=256, tie_embed=True,
))
