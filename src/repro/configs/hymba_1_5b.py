"""hymba-1.5b: parallel attention + mamba heads per layer, ssm_state=16;
sliding-window attention for the long-context shape. [arXiv:2411.13676; hf]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv=5, head_dim=64,
    d_ff=5504, vocab=32001, unit=("hymba",), act="swiglu",
    ssm_state=16, d_inner=3200, window=2048, subquadratic=True,
))
