"""xlstm-350m: alternating mLSTM + sLSTM blocks. [arXiv:2405.04517; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv=4, head_dim=256,
    d_ff=0, vocab=50304, unit=("mlstm", "slstm"), act="gelu",
    subquadratic=True, tie_embed=True,
))
