"""llama3-8b: GQA, 128k vocab. [arXiv:2407.21783; unverified]"""
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=128256, unit=("dense",), act="swiglu",
    rope_theta=500000.0,
))
