"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell
against 512 placeholder host devices, and extract roofline inputs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single --out out.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The two env lines below MUST run before any other import (jax locks the
device count at first init).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

# ruff: noqa: E402
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (
    SHAPES,
    cell_is_runnable,
    get_config,
    input_specs,
    list_archs,
)
from repro.core import BF16_BASELINE, TENSOR_MOR, paper_default
from repro.launch.mesh import HW, make_production_mesh
from repro.models import (
    cache_specs,
    init_params,
    make_decode_fn,
    make_prefill_fn,
    make_tokens,
)
from repro.models.common import use_mesh
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.sharding import rules
from repro.train.train_step import TrainConfig, make_train_step

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes_from_hlo(hlo: str) -> Dict[str, Any]:
    """Sum operand bytes of collective ops in the partitioned HLO.

    Shapes in the partitioned module are per-device, so the totals here
    are per-device traffic per step (see benchmarks/roofline.py).
    """
    per_op = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo.splitlines():
        ls = line.strip()
        m = re.search(r"=\s+\S+\s+(all-reduce|all-gather|reduce-scatter|"
                      r"all-to-all|collective-permute)(-start)?\(", ls)
        if not m:
            continue
        op = m.group(1)
        # Operand shapes: everything inside the call parens.
        args = ls[m.end():]
        operands = _SHAPE_RE.findall(args.split("),")[0] + ")")
        total = 0
        for dt, dims in operands:
            total += _shape_bytes(f"{dt}[{dims}]")
        per_op[op] += total
        counts[op] += 1
    return {
        "bytes_per_op": per_op,
        "counts": counts,
        "total_bytes": sum(per_op.values()),
    }


def _attach(struct_tree, spec_tree, mesh):
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)
        ),
        struct_tree,
        spec_tree,
    )


def _replicated(struct_tree, mesh):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, P())
        ),
        struct_tree,
    )


def _policy(name: str):
    if name == "bf16":
        return BF16_BASELINE
    if name == "mor":
        return TENSOR_MOR
    if name == "mor_channel":
        return paper_default(partition="channel")
    if name == "mor_tensor":
        return paper_default(partition="tensor")
    if name == "sub2":
        return paper_default("sub2")
    if name in ("sub3", "sub4"):
        return paper_default(name)
    raise ValueError(name)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               policy_name: str = "mor", train_cfg: TrainConfig = None,
               kv_fp8: bool = False):
    """Lower + compile one cell. Returns (lowered, compiled, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        raise SkipCell(why)

    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = _policy(policy_name)
    bspec = rules.batch_spec(multi_pod) if shape.global_batch > 1 else P()

    with use_mesh(mesh):
        pshape = jax.eval_shape(
            lambda k: init_params(cfg, k),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
        pspecs = rules.param_specs(cfg, pshape)
        p_structs = _attach(pshape, pspecs, mesh)

        ins = input_specs(cfg, shape)

        if shape.kind == "train":
            if train_cfg is None:
                # Auto microbatching: big models need smaller live
                # activation footprints to fit 16 GB HBM.
                n = cfg.param_count()
                accum = 4 if n > 20e9 else (2 if n > 3e9 else 1)
                train_cfg = TrainConfig(
                    optimizer=AdamWConfig(total_steps=100000),
                    grad_accum=accum,
                )
            tcfg = train_cfg
            step = make_train_step(cfg, policy, tcfg)
            oshape = jax.eval_shape(init_opt_state, pshape)
            ospecs_master = rules.opt_state_spec_from_param(cfg, pshape)
            ospecs = type(oshape)(
                master=ospecs_master, m=ospecs_master, v=ospecs_master,
                step=P(),
            )
            o_structs = _attach(oshape, ospecs, mesh)
            batch_structs = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    s.shape, s.dtype, sharding=NamedSharding(mesh, bspec)
                ),
                ins,
            )
            lowered = jax.jit(step).lower(
                p_structs, o_structs, batch_structs
            )
        elif shape.kind == "prefill":
            fn = make_prefill_fn(cfg, policy)

            def step(params, batch):
                return fn(params, make_tokens(cfg), batch)

            batch_structs = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    s.shape, s.dtype, sharding=NamedSharding(mesh, bspec)
                ),
                ins,
            )
            lowered = jax.jit(step).lower(p_structs, batch_structs)
        else:  # decode
            fn = make_decode_fn(cfg, policy)

            def step(params, cache, token, cur_index):
                return fn(params, make_tokens(cfg), cache, token, cur_index)

            cshape = cache_specs(cfg, shape.global_batch, shape.seq_len,
                                 kv_fp8=kv_fp8)
            cspecs = rules.cache_specs_tree(cfg, cshape, multi_pod)
            if shape.global_batch == 1:
                cspecs = jax.tree.map(
                    lambda sp: P(*(
                        None if (e == "data" or e == ("pod", "data")
                                 or e == "batch") else e
                        for e in sp
                    )),
                    cspecs,
                    is_leaf=lambda x: isinstance(x, P),
                )
            c_structs = _attach(cshape, cspecs, mesh)
            tok_struct = jax.ShapeDtypeStruct(
                ins["token"].shape, jnp.int32,
                sharding=NamedSharding(mesh, bspec),
            )
            idx_struct = jax.ShapeDtypeStruct(
                ins["cur_index"].shape, jnp.int32,
                sharding=NamedSharding(mesh, P()),
            )
            # Serving donates the cache: the update happens in place
            # instead of temp-buffering a second full cache.
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                p_structs, c_structs, tok_struct, idx_struct
            )

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "policy": policy_name,
        "kind": shape.kind,
        "compile_seconds": round(compile_s, 1),
    }
    return lowered, compiled, meta


class SkipCell(Exception):
    pass


def analyze(lowered, compiled, meta, cfg, shape) -> Dict[str, Any]:
    from repro.launch.hlo_analysis import analyze_hlo

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()

    chips = meta["chips"]
    # Trip-count-aware walk (XLA's cost_analysis counts while bodies once;
    # scan-over-layers models need the corrected numbers).
    walked = analyze_hlo(hlo, n_partitions=chips)
    coll = {
        "operand_bytes_per_op": walked.coll_operand_bytes,
        "traffic_bytes_per_op": walked.coll_traffic_bytes,
        "counts": walked.coll_counts,
        "total_operand_bytes": walked.total_coll_operand_bytes,
        "total_bytes": walked.total_coll_traffic_bytes,
    }
    flops_dev = walked.flops
    bytes_dev = walked.bytes
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    # Model (useful) FLOPs: 6*N*D train, 2*N*D prefill, 2*N*B decode.
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        model_flops = 2 * n_active * shape.global_batch

    compute_s = flops_dev / HW.PEAK_FLOPS_BF16
    memory_s = bytes_dev / HW.HBM_BW
    collective_s = coll["total_bytes"] / HW.ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1],
    )[0]

    out = {
        **meta,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
            "fits_16gb": bool(
                mem.argument_size_in_bytes + mem.temp_size_in_bytes
                < HW.HBM_BYTES
            ),
        },
        "cost": {
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "flops_global": flops_dev * chips,
            "xla_flops_per_device_unrolled": xla_flops,
            "xla_bytes_per_device_unrolled": xla_bytes,
        },
        "collectives": coll,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "model_flops": float(model_flops),
            "useful_flops_ratio": (
                float(model_flops) / (flops_dev * chips)
                if flops_dev else 0.0
            ),
        },
    }
    return out


def run_cell(arch, shape_name, multi_pod, policy_name="mor", out=None,
             kv_fp8=False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    try:
        lowered, compiled, meta = lower_cell(
            arch, shape_name, multi_pod, policy_name, kv_fp8=kv_fp8
        )
        meta["kv_fp8"] = kv_fp8
        result = analyze(lowered, compiled, meta, cfg, shape)
        result["status"] = "ok"
    except SkipCell as e:
        result = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "policy": policy_name, "status": "skip", "reason": str(e),
        }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--policy", default="mor")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--kv-fp8", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = (
        [False, True] if args.mesh == "both"
        else [args.mesh == "multi"]
    )
    cells = []
    if args.all:
        for a in list_archs():
            if a == "nemotron3-8b":
                continue  # paper model: quality benches, not an assigned cell
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape_name in cells:
        for mp in meshes:
            tag = f"{arch} x {shape_name} x {'multi' if mp else 'single'}"
            try:
                res = run_cell(arch, shape_name, mp, args.policy, args.out,
                               kv_fp8=args.kv_fp8)
                if res["status"] == "ok":
                    r = res["roofline"]
                    print(
                        f"[ok]   {tag}: dominant={r['dominant']} "
                        f"compute={r['compute_s']:.3f}s "
                        f"memory={r['memory_s']:.3f}s "
                        f"collective={r['collective_s']:.3f}s "
                        f"fits={res['memory']['fits_16gb']}"
                    )
                    print(json.dumps(res["memory"]))
                    print(json.dumps(res["cost"]))
                else:
                    print(f"[skip] {tag}: {res['reason']}")
            except Exception:
                failures += 1
                print(f"[FAIL] {tag}")
                traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
