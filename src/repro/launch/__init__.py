from .mesh import HW, make_local_mesh, make_production_mesh

__all__ = ["HW", "make_local_mesh", "make_production_mesh"]
