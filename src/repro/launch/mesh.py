"""Production mesh factory.

Single-pod: (data=16, model=16) = 256 chips of TPU v5e.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is an
additional pure-data-parallel dimension crossing the inter-pod DCN/ICI
boundary (gradient all-reduces over 'pod' are the cross-pod traffic the
compression tricks in repro.optim target).

Defined as functions (never module-level constants) so importing this
module can never touch jax device state -- smoke tests must keep seeing
one CPU device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


class HW:
    """TPU v5e roofline constants (per chip)."""

    PEAK_FLOPS_BF16 = 197e12  # FLOP/s
    HBM_BW = 819e9  # B/s
    ICI_BW = 50e9  # B/s per link (~per-direction per chip)
    HBM_BYTES = 16 * 2**30
