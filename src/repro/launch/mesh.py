"""Production mesh factory.

Single-pod: (data=16, model=16) = 256 chips of TPU v5e.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is an
additional pure-data-parallel dimension crossing the inter-pod DCN/ICI
boundary (gradient all-reduces over 'pod' are the cross-pod traffic the
compression tricks in repro.optim target).

Defined as functions (never module-level constants) so importing this
module can never touch jax device state -- smoke tests must keep seeing
one CPU device.
"""
from __future__ import annotations

import os

import jax

__all__ = [
    "make_production_mesh", "make_local_mesh", "host_device_env",
    "HW",
]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def host_device_env(n: int, base: dict | None = None) -> dict:
    """Environment for a *subprocess* that should see ``n`` host (CPU)
    devices -- the standard substrate for multi-device CPU runs
    (tests/test_sharded_mor.py, the bench sharded lane).

    XLA fixes the device count at backend init, so this cannot apply to
    an already-running process; spawn a child with this env instead.
    """
    env = dict(os.environ if base is None else base)
    flag = f"--xla_force_host_platform_device_count={n}"
    # Drop any pre-existing count flag: the caller's n must win.
    kept = [
        f for f in env.get("XLA_FLAGS", "").split()
        if "xla_force_host_platform_device_count" not in f
    ]
    env["XLA_FLAGS"] = " ".join(kept + [flag])
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


class HW:
    """TPU v5e roofline constants (per chip)."""

    PEAK_FLOPS_BF16 = 197e12  # FLOP/s
    HBM_BW = 819e9  # B/s
    ICI_BW = 50e9  # B/s per link (~per-direction per chip)
    HBM_BYTES = 16 * 2**30
