"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` visits ``while`` bodies once,
so any model using ``lax.scan`` over layers (all of ours) is undercounted
by ~n_layers x. This module parses the optimized (partitioned, per-device)
HLO text, walks the computation graph, and multiplies loop bodies by their
trip counts, producing:

  * flops          -- dot/elementwise/reduce FLOPs per device per step
  * bytes          -- HBM traffic proxy: operand+result bytes of every
                      top-level (post-fusion) instruction
  * collectives    -- per-op operand bytes AND ring-traffic estimates,
                      with replica-group sizes

Validated against analytic 6*N*D model FLOPs in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["ModuleCost", "analyze_hlo", "COLLECTIVE_OPS"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
    "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Ops that move no data / cost nothing.
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

# Elementwise-ish ops: 1 flop per output element.
_EW_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "rsqrt", "sqrt", "tanh", "logistic",
    "power", "and", "or", "xor", "not", "compare", "select", "clamp",
    "floor", "ceil", "round-nearest-even", "sign", "cosine", "sine",
    "atan2", "expm1", "log1p", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "convert",
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of a shape string; handles tuples by summing components."""
    total = 0
    for dt, dims in _SHAPE_TOKEN.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOKEN.findall(shape_str):
        if dt not in _DTYPE_BYTES or dt in ("token", "opaque"):
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: List[str]
    attrs: str
    args: str = ""  # raw text inside the call parens (constants, etc.)


@dataclasses.dataclass
class ModuleCost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    coll_operand_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVE_OPS}
    )
    coll_traffic_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVE_OPS}
    )
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVE_OPS}
    )

    def add(self, other: "ModuleCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k in COLLECTIVE_OPS:
            self.coll_operand_bytes[k] += other.coll_operand_bytes[k] * mult
            self.coll_traffic_bytes[k] += other.coll_traffic_bytes[k] * mult
            self.coll_counts[k] += other.coll_counts[k] * mult

    @property
    def total_coll_operand_bytes(self) -> float:
        return sum(self.coll_operand_bytes.values())

    @property
    def total_coll_traffic_bytes(self) -> float:
        return sum(self.coll_traffic_bytes.values())


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$"
)


def _split_shape_op(rest: str) -> Optional[Tuple[str, str, str]]:
    """'f32[2]{0} dot(%a, %b), attrs' -> (shape, op, tail)."""
    rest = rest.strip()
    if rest.startswith("("):  # tuple shape
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape = rest[: i + 1]
                    tail = rest[i + 1 :].strip()
                    break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape = rest[:sp]
        tail = rest[sp + 1 :]
    m = re.match(r"([\w\-]+)\(", tail)
    if not m:
        return None
    op = m.group(1)
    return shape, op, tail[m.end() - 1 :]


def _call_args(tail: str) -> Tuple[str, str]:
    """tail starts at '(' of the call; returns (inside, after)."""
    depth = 0
    for i, ch in enumerate(tail):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return tail[1:i], tail[i + 1 :]
    return tail[1:], ""


def parse_hlo(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rest = m.groups()
        so = _split_shape_op(rest)
        if not so:
            continue
        shape, op, tail = so
        inside, after = _call_args(tail)
        operands = re.findall(r"%([\w\.\-]+)", inside)
        comps[cur].append(Instr(name, shape, op, operands, after, inside))
    return comps


def _group_size(attrs: str, default: int) -> int:
    # replica_groups=[128,2]<=[256]  (iota form: 128 groups of 2)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    # explicit: replica_groups={{0,1,2,3},{...}}
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return default


_INT_CONST = re.compile(r"constant\((\d+)\)")


def _trip_count(cond_instrs: List[Instr]) -> int:
    """Max integer constant in a while condition == the loop bound for
    canonical 0..N counted loops (all lax.scan/map loops)."""
    best = 1
    for ins in cond_instrs:
        if ins.op == "constant":
            m = re.fullmatch(r"-?(\d+)", ins.args.strip())
            if m:
                best = max(best, int(m.group(1)))
    return best


def _callee(attrs: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", attrs)
    return m.group(1) if m else None


def analyze_hlo(text: str, n_partitions: int = 1) -> ModuleCost:
    comps = parse_hlo(text)
    shapes: Dict[str, Dict[str, str]] = {
        c: {i.name: i.shape for i in instrs} for c, instrs in comps.items()
    }
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: the largest computation
        entry = max(comps, key=lambda c: len(comps[c]))

    memo: Dict[Tuple[str, bool], ModuleCost] = {}

    def comp_cost(cname: str, flops_only: bool = False) -> ModuleCost:
        key = (cname, flops_only)
        if key in memo:
            return memo[key]
        cost = ModuleCost()
        smap = shapes.get(cname, {})
        for ins in comps.get(cname, []):
            op = ins.op
            if op in _FREE_OPS:
                continue
            res_b = _shape_bytes(ins.shape)
            opnd_b = sum(
                _shape_bytes(smap.get(o, "")) for o in ins.operands
            )
            if op == "while":
                body = _callee(ins.attrs, "body")
                cond = _callee(ins.attrs, "condition")
                trips = _trip_count(comps.get(cond, [])) if cond else 1
                if body:
                    cost.add(comp_cost(body, flops_only), trips)
                continue
            if op == "conditional":
                # count the most expensive branch
                branches = [
                    n for n in re.findall(r"%([\w\.\-]+)", ins.attrs)
                    if n in comps
                ]
                subs = [comp_cost(b, flops_only) for b in branches]
                if subs:
                    biggest = max(subs, key=lambda c: c.flops + c.bytes)
                    cost.add(biggest)
                continue
            if op == "call":
                callee = _callee(ins.attrs, "to_apply")
                if callee:
                    cost.add(comp_cost(callee, flops_only))
                continue
            if op == "fusion":
                callee = _callee(ins.attrs, "calls")
                if callee:
                    sub = comp_cost(callee, True)  # flops only inside
                    cost.flops += sub.flops
                    cost.transcendentals += sub.transcendentals
                if not flops_only:
                    cost.bytes += res_b + opnd_b
                continue
            base = op
            for suf in ("-start", "-done"):
                if base.endswith(suf):
                    base = base[: -len(suf)]
            if base in COLLECTIVE_OPS:
                if op.endswith("-done"):
                    continue
                if True:
                    n = _group_size(ins.attrs, n_partitions)
                    if base == "all-gather":
                        operand_bytes = res_b / max(n, 1)
                        traffic = res_b * (n - 1) / max(n, 1)
                    elif base == "all-reduce":
                        operand_bytes = res_b
                        traffic = 2.0 * res_b * (n - 1) / max(n, 1)
                    elif base == "reduce-scatter":
                        operand_bytes = res_b * n
                        traffic = res_b * (n - 1)
                    elif base == "all-to-all":
                        operand_bytes = res_b
                        traffic = res_b * (n - 1) / max(n, 1)
                    else:  # collective-permute
                        operand_bytes = res_b
                        traffic = res_b
                    cost.coll_operand_bytes[base] += operand_bytes
                    cost.coll_traffic_bytes[base] += traffic
                    cost.coll_counts[base] += 1
                    if not flops_only:
                        cost.bytes += res_b + opnd_b
                    continue
            if op == "dot":
                k = 1
                m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                              ins.attrs)
                if m and ins.operands:
                    lhs_shape = smap.get(ins.operands[0], "")
                    t = _SHAPE_TOKEN.search(lhs_shape)
                    if t:
                        dims = [int(d) for d in t.group(2).split(",") if d]
                        for ci in m.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                k *= dims[int(ci)]
                cost.flops += 2.0 * _shape_elems(ins.shape) * k
                if not flops_only:
                    cost.bytes += res_b + opnd_b
                continue
            if op in ("reduce", "reduce-window"):
                cost.flops += sum(
                    _shape_elems(smap.get(o, "")) for o in ins.operands
                )
                if not flops_only:
                    cost.bytes += res_b + opnd_b
                continue
            if op in _EW_OPS:
                cost.flops += _shape_elems(ins.shape)
                if op in ("exponential", "log", "rsqrt", "sqrt", "tanh",
                          "logistic", "power", "cosine", "sine"):
                    cost.transcendentals += _shape_elems(ins.shape)
                if not flops_only:
                    cost.bytes += res_b + opnd_b
                continue
            # everything else (copy, reshape, transpose, dynamic-slice,
            # scatter, gather, pad, concatenate, ...): data movement.
            if not flops_only:
                cost.bytes += res_b + opnd_b
        memo[key] = cost
        return cost

    return comp_cost(entry)


def top_bytes_contributors(text: str, top: int = 30):
    """Leaf instructions ranked by bytes x trip-multiplier (debugging aid
    for the perf loop: shows exactly where HBM traffic goes)."""
    comps = parse_hlo(text)
    shapes = {
        c: {i.name: i.shape for i in instrs} for c, instrs in comps.items()
    }
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    rows = []

    def walk(cname: str, mult: float):
        smap = shapes.get(cname, {})
        for ins in comps.get(cname, []):
            op = ins.op
            if op in _FREE_OPS:
                continue
            if op == "while":
                body = _callee(ins.attrs, "body")
                cond = _callee(ins.attrs, "condition")
                trips = _trip_count(comps.get(cond, [])) if cond else 1
                if body:
                    walk(body, mult * trips)
                continue
            if op == "call":
                callee = _callee(ins.attrs, "to_apply")
                if callee:
                    walk(callee, mult)
                continue
            res_b = _shape_bytes(ins.shape)
            opnd_b = sum(
                _shape_bytes(smap.get(o, "")) for o in ins.operands
            )
            total = (res_b + opnd_b) * mult
            if total > 0:
                meta = re.search(r'op_name="([^"]*)"', ins.attrs)
                rows.append(
                    (total, mult, op, ins.shape[:48],
                     (meta.group(1)[-80:] if meta else ""))
                )

    if entry:
        walk(entry, 1.0)
    rows.sort(reverse=True)
    return rows[:top]
