"""Sequential dry-run sweep over every (arch x shape x mesh) cell.

One process, cells ordered cheap-to-expensive so results bank early;
per-cell JSON lands in experiments/dryrun/ and a progress line in the log.
jax caches are cleared between cells to bound memory.

  PYTHONPATH=src python -m repro.launch.sweep [--mesh single|multi|both]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)

# ruff: noqa: E402
import argparse
import gc
import json
import time
import traceback

import jax

from repro.configs.base import SHAPES, cell_is_runnable, get_config
from repro.launch import dryrun

ARCHS_BY_COST = [
    "whisper-tiny",
    "granite-moe-1b-a400m",
    "xlstm-350m",
    "hymba-1.5b",
    "gemma-2b",
    "minitron-4b",
    "paligemma-3b",
    "llama3-8b",
    "moonshot-v1-16b-a3b",
    "deepseek-coder-33b",
]
SHAPES_BY_COST = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--policy", default="mor")
    ap.add_argument("--only-arch", default=None)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh
    ]

    cells = []
    for mp in meshes:
        for shape in SHAPES_BY_COST:
            for arch in ARCHS_BY_COST:
                if args.only_arch and arch != args.only_arch:
                    continue
                cells.append((arch, shape, mp))

    done = fails = skips = 0
    for arch, shape_name, mp in cells:
        mesh_tag = "multi" if mp else "single"
        out = os.path.join(
            args.out, f"{arch}__{shape_name}__{mesh_tag}.json"
        )
        if os.path.exists(out):
            done += 1
            continue
        cfg = get_config(arch)
        ok, why = cell_is_runnable(cfg, SHAPES[shape_name])
        t0 = time.time()
        try:
            res = dryrun.run_cell(arch, shape_name, mp, args.policy, out)
            dt = time.time() - t0
            if res["status"] == "ok":
                done += 1
                r = res["roofline"]
                print(
                    f"[{done+fails+skips:3d}] ok   {arch} {shape_name} "
                    f"{mesh_tag} ({dt:.0f}s) dom={r['dominant']} "
                    f"c={r['compute_s']:.2f} m={r['memory_s']:.2f} "
                    f"x={r['collective_s']:.2f} "
                    f"fits={res['memory']['fits_16gb']}",
                    flush=True,
                )
            else:
                skips += 1
                print(
                    f"[{done+fails+skips:3d}] skip {arch} {shape_name} "
                    f"{mesh_tag}: {res['reason']}",
                    flush=True,
                )
        except Exception as e:
            fails += 1
            with open(out + ".fail", "w") as f:
                f.write(traceback.format_exc())
            print(
                f"[{done+fails+skips:3d}] FAIL {arch} {shape_name} "
                f"{mesh_tag} ({time.time()-t0:.0f}s): {e}",
                flush=True,
            )
        jax.clear_caches()
        gc.collect()
    print(f"sweep complete: ok={done} skip={skips} fail={fails}",
          flush=True)


if __name__ == "__main__":
    main()
