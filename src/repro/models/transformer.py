"""Unified model assembly for all assigned architectures.

One forward covers: dense/MoE decoder LMs, Hymba hybrids, xLSTM stacks,
Whisper enc-dec, and PaliGemma prefix-LM -- assembled from the block types
in ``cfg.unit`` and scanned over layers (homogeneous stacks compile to one
HLO body regardless of depth; xLSTM's (mlstm, slstm) unit scans pairs).

Modes: 'train' (full-seq causal/prefix forward), 'prefill' (forward +
emit caches), 'decode' (new tokens against caches at per-row positions:
one token per step, or an S-token chunk for chunked prefill).

Vocab handling: embeddings are padded to a multiple of 128 so the vocab
axis shards evenly at TP=16; padded logit columns are masked to -inf
before softmax (Megatron-style), so quality is unaffected.
"""
from __future__ import annotations

import functools
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import N_BWD_EVENTS, STATS_WIDTH, MoRDotPolicy
from repro.core.linear import mor_dot
from repro.kernels import ops as kops

from . import blocks as B
from . import recurrent as R
from .common import constrain, sinusoidal_positions

__all__ = [
    "init_params", "make_tokens", "cache_specs", "forward", "padded_vocab",
]


def padded_vocab(cfg: ArchConfig) -> int:
    return -(-cfg.vocab // 128) * 128


# ================================================================== init ==
def _norm_p(key, d, cfg, out_scale=False):
    p = {"scale": jnp.zeros((d,), jnp.float32)}
    if cfg.norm == "ln":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def _lin(key, shape, std=0.02):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(
        jnp.bfloat16
    )


def _ffin(cfg: ArchConfig, f: int) -> int:
    return 2 * f if cfg.act in ("swiglu", "geglu") else f


def _attn_params(key, cfg: ArchConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    k1, k2 = jax.random.split(key)
    depth_std = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    return {
        "wqkv": _lin(k1, (d, (hq + 2 * hkv) * hd)),
        "wo": _lin(k2, (hq * hd, d), std=depth_std),
    }


def _mlp_params(key, cfg: ArchConfig, d=None, f=None):
    d = d or cfg.d_model
    f = f or cfg.d_ff
    k1, k2 = jax.random.split(key)
    depth_std = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    return {
        "wi": _lin(k1, (d, _ffin(cfg, f))),
        "wo": _lin(k2, (f, d), std=depth_std),
    }


def _dense_layer(key, cfg: ArchConfig):
    ka, km, kn = jax.random.split(key, 3)
    p = _attn_params(ka, cfg)
    p["mlp"] = _mlp_params(km, cfg)
    p["ln1"] = _norm_p(kn, cfg.d_model, cfg)
    p["ln2"] = _norm_p(kn, cfg.d_model, cfg)
    return p


def _moe_layer(key, cfg: ArchConfig):
    ka, kr, k1, k2, kn = jax.random.split(key, 5)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    depth_std = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    p = _attn_params(ka, cfg)
    p["moe"] = {
        "router": (jax.random.normal(kr, (d, E), jnp.float32) * 0.02).astype(
            jnp.float32
        ),
        "w1": _lin(k1, (E, d, _ffin(cfg, f))),
        "w2": _lin(k2, (E, f, d), std=depth_std),
    }
    p["ln1"] = _norm_p(kn, d, cfg)
    p["ln2"] = _norm_p(kn, d, cfg)
    return p


def _mamba_params(key, cfg: ArchConfig):
    di, N, cw = cfg.mamba_d_inner, cfg.ssm_state, cfg.conv_width
    d = cfg.d_model
    dt_rank = max(1, d // 16)
    keys = jax.random.split(key, 6)
    depth_std = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    return {
        "w_in": _lin(keys[0], (d, 2 * di)),
        "conv_w": (jax.random.normal(keys[1], (cw, di)) * 0.02).astype(
            jnp.float32
        ),
        "w_bc": _lin(keys[2], (di, 2 * N)).astype(jnp.float32),
        "w_dt_down": _lin(keys[3], (di, dt_rank)).astype(jnp.float32),
        "w_dt_up": _lin(keys[4], (dt_rank, di)).astype(jnp.float32),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
        ),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": _lin(keys[5], (di, d), std=depth_std),
    }


def _hymba_layer(key, cfg: ArchConfig):
    ka, ks, km, kn = jax.random.split(key, 4)
    p = _attn_params(ka, cfg)
    p["ssm"] = _mamba_params(ks, cfg)
    p["mlp"] = _mlp_params(km, cfg)
    p["ln1"] = _norm_p(kn, cfg.d_model, cfg)
    p["ln2"] = _norm_p(kn, cfg.d_model, cfg)
    return p


def _mlstm_layer(key, cfg: ArchConfig):
    d = cfg.d_model
    di = 2 * d
    H = cfg.n_heads
    keys = jax.random.split(key, 5)
    depth_std = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    return {
        "ln1": _norm_p(keys[0], d, cfg),
        "w_up": _lin(keys[0], (d, 2 * di)),
        "w_qkv": _lin(keys[1], (di, 3 * di)),
        "w_gate": _lin(keys[2], (di, 2 * H)),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((H,)), jnp.full((H,), 3.0)]
        ).astype(jnp.float32),
        "out_norm": jnp.zeros((di,), jnp.float32),
        "w_down": _lin(keys[3], (di, d), std=depth_std),
    }


def _slstm_layer(key, cfg: ArchConfig):
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ff = -(-int(d * 4 / 3) // 64) * 64
    keys = jax.random.split(key, 4)
    depth_std = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    return {
        "ln1": _norm_p(keys[0], d, cfg),
        "w_x": _lin(keys[0], (d, 4 * d)),
        "r": _lin(keys[1], (H, dh, 4 * dh)),
        "out_norm": jnp.zeros((d,), jnp.float32),
        "w_ff1": _lin(keys[2], (d, 2 * ff)),
        "w_ff2": _lin(keys[3], (ff, d), std=depth_std),
    }


def _wdec_layer(key, cfg: ArchConfig):
    """Whisper decoder layer: self-attn + cross-attn + mlp."""
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    keys = jax.random.split(key, 6)
    depth_std = 0.02 / max(1.0, (2 * cfg.n_layers) ** 0.5)
    p = _attn_params(keys[0], cfg)
    p["xwq"] = _lin(keys[1], (d, hq * hd))
    p["xwkv"] = _lin(keys[2], (d, 2 * hkv * hd))
    p["xwo"] = _lin(keys[3], (hq * hd, d), std=depth_std)
    p["mlp"] = _mlp_params(keys[4], cfg)
    p["ln1"] = _norm_p(keys[5], d, cfg)
    p["lnx"] = _norm_p(keys[5], d, cfg)
    p["ln2"] = _norm_p(keys[5], d, cfg)
    return p


_LAYER_INIT = {
    "dense": _dense_layer,
    "moe": _moe_layer,
    "hymba": _hymba_layer,
    "mlstm": _mlstm_layer,
    "slstm": _slstm_layer,
    "wdec": _wdec_layer,
}


def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    kE, kH, kB, kEnc = jax.random.split(key, 4)
    Vp = padded_vocab(cfg)
    embed = jax.random.normal(kE, (Vp, cfg.d_model), jnp.float32) * 0.02
    embed = embed.at[cfg.vocab :].set(0.0)
    params: Dict[str, Any] = {
        "embed": embed.astype(jnp.bfloat16),
        "final_norm": _norm_p(kE, cfg.d_model, cfg),
    }
    if not cfg.tie_embed:
        params["lm_head"] = _lin(kH, (cfg.d_model, Vp))

    unit = _unit_types(cfg)
    params["blocks"] = {}
    for t in unit:
        # crc32, not hash(): str hashing is salted per process
        # (PYTHONHASHSEED), which made init_params draw *different
        # parameters in every process* -- differential tests comparing
        # runs across processes, and anything pinning draw-dependent
        # values, were silently seeded by the interpreter.
        keys = jax.random.split(
            jax.random.fold_in(kB, zlib.crc32(t.encode()) % 2**31),
            cfg.n_units,
        )
        params["blocks"][t] = jax.vmap(
            lambda k: _LAYER_INIT[t](k, cfg)
        )(keys)

    if cfg.family == "audio":  # whisper encoder stack
        keys = jax.random.split(kEnc, cfg.enc_layers)
        params["enc"] = {
            "blocks": jax.vmap(lambda k: _dense_layer(k, cfg))(keys),
            "final_norm": _norm_p(kEnc, cfg.d_model, cfg),
        }
    return params


def _unit_types(cfg: ArchConfig) -> Tuple[str, ...]:
    if cfg.family == "audio":
        return ("wdec",)
    return cfg.unit


# ================================================================ tokens ==
def _tok():
    return jnp.zeros((N_BWD_EVENTS, STATS_WIDTH), jnp.float32)


def _layer_tokens(t: str, cfg: ArchConfig):
    if t == "dense":
        names = ["qkv", "proj", "fc1", "fc2"]
    elif t == "moe":
        return {
            "qkv": _tok(),
            "proj": _tok(),
            "w1": jnp.zeros(
                (cfg.n_experts, N_BWD_EVENTS, STATS_WIDTH), jnp.float32
            ),
            "w2": jnp.zeros(
                (cfg.n_experts, N_BWD_EVENTS, STATS_WIDTH), jnp.float32
            ),
        }
    elif t == "hymba":
        names = ["qkv", "proj", "ssm_in", "ssm_out", "fc1", "fc2"]
    elif t == "mlstm":
        names = ["up", "qkv", "down"]
    elif t == "slstm":
        names = ["wx", "ff1", "ff2"]
    elif t == "wdec":
        names = ["qkv", "proj", "xq", "xkv", "xproj", "fc1", "fc2"]
    else:
        raise ValueError(t)
    return {n: _tok() for n in names}


def make_tokens(cfg: ArchConfig):
    """Zero-valued bwd-stat tokens; grads w.r.t. these carry the backward
    quantization stats out of the train step (see repro.core.linear)."""
    stack = lambda tree: jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_units, *x.shape)), tree
    )
    toks = {
        "blocks": {
            t: stack(_layer_tokens(t, cfg)) for t in _unit_types(cfg)
        }
    }
    if cfg.family == "audio":
        enc = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.enc_layers, *x.shape)),
            _layer_tokens("dense", cfg),
        )
        toks["enc"] = enc
    return toks


# ================================================================= cache ==
def _layer_cache_spec(t: str, cfg: ArchConfig, b: int, s: int,
                      kv_fp8: bool = False, kv_mor: bool = False):
    hkv, hd = cfg.n_kv, cfg.head_dim
    if kv_fp8 and kv_mor:
        raise ValueError("kv_fp8 and kv_mor are mutually exclusive")
    if kv_mor:
        # MoR cache tier (docs/numerics.md): uint8 payload lanes with
        # per-(position, head) representation tags + GAM scales --
        # per-block E4M3/E5M2 selection hot, NVFP4 sub4 when pages go
        # cold (tags/scales are the MixedOperand lanes of a page).
        kv = {
            "k": jax.ShapeDtypeStruct((b, s, hkv, hd), jnp.uint8),
            "v": jax.ShapeDtypeStruct((b, s, hkv, hd), jnp.uint8),
            "k_tags": jax.ShapeDtypeStruct((b, s, hkv), jnp.uint8),
            "v_tags": jax.ShapeDtypeStruct((b, s, hkv), jnp.uint8),
            "k_scale": jax.ShapeDtypeStruct((b, s, hkv), jnp.float32),
            "v_scale": jax.ShapeDtypeStruct((b, s, hkv), jnp.float32),
        }
    elif kv_fp8:
        # Beyond-paper: E4M3 payload + per-(position, head) f32 scales
        # (halves the decode cache; see models.attention.decode_attention).
        kv = {
            "k": jax.ShapeDtypeStruct((b, s, hkv, hd), jnp.float8_e4m3fn),
            "v": jax.ShapeDtypeStruct((b, s, hkv, hd), jnp.float8_e4m3fn),
            "k_scale": jax.ShapeDtypeStruct((b, s, hkv), jnp.float32),
            "v_scale": jax.ShapeDtypeStruct((b, s, hkv), jnp.float32),
        }
    else:
        kv = {
            "k": jax.ShapeDtypeStruct((b, s, hkv, hd), jnp.bfloat16),
            "v": jax.ShapeDtypeStruct((b, s, hkv, hd), jnp.bfloat16),
        }
    if t in ("dense", "moe"):
        return kv
    if t == "hymba":
        di, cw = cfg.mamba_d_inner, cfg.conv_width
        return {
            **kv,
            "ssm": {
                "h": jax.ShapeDtypeStruct(
                    (b, di, cfg.ssm_state), jnp.float32
                ),
                "conv": jax.ShapeDtypeStruct((b, cw - 1, di), jnp.bfloat16),
            },
        }
    if t == "mlstm":
        di = 2 * cfg.d_model
        H = cfg.n_heads
        dh = di // H
        return {
            "C": jax.ShapeDtypeStruct((b, H, dh, dh), jnp.float32),
            "n": jax.ShapeDtypeStruct((b, H, dh), jnp.float32),
            "m": jax.ShapeDtypeStruct((b, H), jnp.float32),
        }
    if t == "slstm":
        d = cfg.d_model
        return {
            n: jax.ShapeDtypeStruct((b, d), jnp.float32)
            for n in ("h", "c", "n", "m")
        }
    if t == "wdec":
        return {
            **kv,
            "xk": jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, hkv, hd), jnp.bfloat16
            ),
            "xv": jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, hkv, hd), jnp.bfloat16
            ),
        }
    raise ValueError(t)


def cache_specs(cfg: ArchConfig, batch: int, seq: int,
                kv_fp8: bool = False, kv_mor: bool = False):
    """ShapeDtypeStruct pytree for the decode cache (stacked over units)."""
    stack = lambda spec: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((cfg.n_units, *x.shape), x.dtype), spec
    )
    return {
        t: stack(_layer_cache_spec(t, cfg, batch, seq, kv_fp8, kv_mor))
        for t in _unit_types(cfg)
    }


def init_cache(cfg: ArchConfig, batch: int, seq: int, kv_fp8: bool = False,
               kv_mor: bool = False):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        cache_specs(cfg, batch, seq, kv_fp8, kv_mor),
    )


# =============================================================== forward ==
def _block_fn(t: str):
    if t == "dense":
        return B.dense_block
    if t == "moe":
        return B.moe_block
    if t == "hymba":
        return _hymba_block
    if t == "mlstm":
        return _mlstm_block
    if t == "slstm":
        return _slstm_block
    if t == "wdec":
        return _wdec_block
    raise ValueError(t)


def _hymba_block(p, x, tok, policy, cfg, mode, cache, cur_index, **attn_kw):
    xn = B.norm(p["ln1"], x, cfg)
    kv_cache = (
        {"k": cache["k"], "v": cache["v"]} if cache is not None else None
    )
    a, new_kv, st_a = B.attn_sublayer(
        p, xn, tok, policy, cfg, mode, kv_cache, cur_index, **attn_kw
    )
    s, new_ssm, st_s = R.mamba_mix(
        p["ssm"], xn, tok, policy, cfg, mode,
        cache["ssm"] if cache is not None else None,
    )
    x = x + a + s
    xn2 = B.norm(p["ln2"], x, cfg)
    m, st_m = B.mlp_sublayer(p["mlp"], xn2, tok, policy, cfg)
    x = x + m
    new_cache = (
        {**new_kv, "ssm": new_ssm} if new_kv is not None else None
    )
    return x, new_cache, {**st_a, **st_s, **st_m}


def _mlstm_block(p, x, tok, policy, cfg, mode, cache, cur_index, **attn_kw):
    xn = B.norm(p["ln1"], x, cfg)
    y, new_cache, st = R.mlstm_mix(p, xn, tok, policy, cfg, mode, cache)
    return x + y, new_cache, st


def _slstm_block(p, x, tok, policy, cfg, mode, cache, cur_index, **attn_kw):
    xn = B.norm(p["ln1"], x, cfg)
    y, new_cache, st = R.slstm_mix(p, xn, tok, policy, cfg, mode, cache)
    return x + y, new_cache, st


def _wdec_block(p, x, tok, policy, cfg, mode, cache, cur_index,
                enc_out=None, **attn_kw):
    # Self-attention (causal, sinusoidal positions -> no rope).
    xn = B.norm(p["ln1"], x, cfg)
    kv_cache = (
        {"k": cache["k"], "v": cache["v"]} if cache is not None else None
    )
    a, new_kv, st_a = B.attn_sublayer(
        p, xn, tok, policy, cfg, mode, kv_cache, cur_index,
        kind="causal", use_rope=False,
    )
    x = x + a
    # Cross-attention against encoder output (cached at prefill).
    xq = B.norm(p["lnx"], x, cfg)
    Bsz, S, _ = xq.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q, st_xq = mor_dot(xq, p["xwq"], tok["xq"], policy)
    q = q.reshape(Bsz, S, hq, hd)
    if mode == "decode":
        xk, xv = cache["xk"], cache["xv"]
        st_xkv = jnp.zeros_like(st_xq)
    else:
        kvx, st_xkv = mor_dot(enc_out, p["xwkv"], tok["xkv"], policy)
        xk, xv = jnp.split(kvx, 2, axis=-1)
        xk = xk.reshape(Bsz, -1, hkv, hd)
        xv = xv.reshape(Bsz, -1, hkv, hd)
    from .attention import flash_attention

    xo = flash_attention(q, xk, xv, kind="full")
    xo = xo.reshape(Bsz, S, hq * hd)
    xa, st_xo = mor_dot(xo, p["xwo"], tok["xproj"], policy)
    x = x + xa
    xn2 = B.norm(p["ln2"], x, cfg)
    m, st_m = B.mlp_sublayer(p["mlp"], xn2, tok, policy, cfg)
    x = x + m
    new_cache = None
    if new_kv is not None:
        new_cache = {
            **new_kv,
            "xk": xk.astype(jnp.bfloat16),
            "xv": xv.astype(jnp.bfloat16),
        }
    return x, new_cache, {
        **st_a, "xq": st_xq, "xkv": st_xkv, "xproj": st_xo, **st_m
    }


def _run_stack(
    types, cfg, policy, block_params, block_tokens, x, mode, cache,
    cur_index, attn_kw, enc_out=None, remat=True,
):
    """Scan ``x`` through a stacked block group. Returns (x, caches, stats)."""

    def body(x, xs):
        p_all, tok_all, cache_all = xs
        new_caches = {}
        stats = {}
        # Sequence parallelism (Megatron SP): the residual stream lives
        # sharded over ('model' x seq) between layers; GSPMD inserts the
        # all-gather on the *quantized* qkv/fc1 inputs and reduce-scatters
        # after proj/fc2. Cuts checkpointed activations and norm-backward
        # traffic by the TP degree (Perf iteration 3).
        if mode != "decode" and x.shape[1] > 1:
            x = constrain(x, "batch", "model", None)
        for t in types:
            fn = _block_fn(t)
            kw = dict(attn_kw)
            if t == "wdec":
                kw["enc_out"] = enc_out
            x, nc, st = fn(
                p_all[t], x, tok_all[t], policy, cfg, mode,
                None if cache_all is None else cache_all[t],
                cur_index, **kw,
            )
            new_caches[t] = nc
            stats[t] = st
        return x, (new_caches, stats)

    if remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    xs = (block_params, block_tokens, cache)
    x, (new_caches, stats) = jax.lax.scan(body, x, xs)
    if mode == "train":
        new_caches = None
    return x, new_caches, stats


def _sinusoidal_at(index, d_model: int) -> jnp.ndarray:
    """Sinusoidal position embedding at a (possibly traced) position."""
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)
    ang = index.astype(jnp.float32) / (10000.0 ** (dim / d_model))
    out = jnp.zeros((d_model,), jnp.float32)
    out = out.at[0::2].set(jnp.sin(ang))
    out = out.at[1::2].set(jnp.cos(ang))
    return out


def forward(
    cfg: ArchConfig,
    policy: MoRDotPolicy,
    params,
    tokens,
    batch: Dict[str, jnp.ndarray],
    *,
    mode: str = "train",
    cache=None,
    cur_index=None,
    remat: bool = True,
):
    """Returns (logits, new_cache, stats).

    batch keys: 'tokens' (B,S) [train/prefill], 'token' (B,S) [decode:
    S == 1 for plain decode, S > 1 for a prefill chunk against the
    cache], plus 'frames' (audio) / 'patches' (vlm) stubs. In decode
    mode ``cur_index`` -- scalar or (B,) vector -- is the position of
    the last incoming token per batch row (docs/serving.md).
    """
    Vp = padded_vocab(cfg)
    embed = params["embed"]

    ids = batch["token"] if mode == "decode" else batch["tokens"]
    x = embed[ids]  # gather, (B, S, d)
    if cfg.family in ("dense", "vlm") and cfg.tie_embed:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)  # gemma-style

    attn_kw: Dict[str, Any] = {"kind": "causal"}
    enc_out = None
    all_stats: Dict[str, Any] = {}

    if cfg.family == "vlm" and mode != "decode":
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        attn_kw = {"kind": "prefix", "prefix_len": cfg.img_tokens}
    if cfg.family == "hybrid" and cfg.window:
        # Hymba: sliding-window attention + global SSM state
        # (docs/architecture.md).
        attn_kw = {"kind": "sliding", "window": cfg.window}
    if cfg.family == "audio":
        attn_kw = {"use_rope": False, "kind": "causal"}
        if mode == "decode":
            # cur_index: () or (B,) position of the last incoming token
            # (same convention as decode_attention); the S incoming
            # tokens sit at cur - (S-1) .. cur per batch row.
            S = x.shape[1]
            cur = jnp.atleast_1d(jnp.asarray(cur_index, jnp.int32))
            posn = cur[:, None] - (S - 1) + jnp.arange(S)  # (b, S)
            pos = jax.vmap(jax.vmap(
                lambda i: _sinusoidal_at(i, cfg.d_model)
            ))(posn)  # (b, S, d)
        else:
            pos = sinusoidal_positions(x.shape[1], cfg.d_model)[None]
        x = x + pos.astype(x.dtype)
        if mode != "decode":
            frames = batch["frames"].astype(x.dtype)
            ep = sinusoidal_positions(frames.shape[1], cfg.d_model)
            e = frames + ep[None].astype(x.dtype)
            e, _, enc_stats = _run_stack(
                ("dense",), cfg, policy, {"dense": params["enc"]["blocks"]},
                {"dense": tokens["enc"]}, e, "train", None, None,
                {"kind": "full", "use_rope": False}, remat=remat,
            )
            enc_out = B.norm(params["enc"]["final_norm"], e, cfg)
            all_stats["enc"] = enc_stats

    x = constrain(x, "batch", None, None)
    x, new_cache, stats = _run_stack(
        _unit_types(cfg), cfg, policy, params["blocks"], tokens["blocks"],
        x, mode, cache, cur_index, attn_kw, enc_out=enc_out, remat=remat,
    )
    all_stats["blocks"] = stats

    x = B.norm(params["final_norm"], x, cfg)
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    if hasattr(head, "as_mixed_operand"):
        # Real-quantized serving head (serve.quantized.QTensor): feed
        # the stored per-block payloads straight into the mixed GEMM.
        mo = head.as_mixed_operand()  # (Vp, d) quantization view
        bsz, seq = x.shape[0], x.shape[1]
        logits = kops.mixed_dot(
            x.reshape(-1, x.shape[-1]), mo,
            out_dtype=jnp.float32, backend=policy.weight.backend,
        ).reshape(bsz, seq, head.shape[1])
    else:
        logits = jnp.einsum(
            "bsd,dv->bsv", x, head, preferred_element_type=jnp.float32
        )
    # Mask padded vocab columns (Megatron-style; no resharding slice).
    col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, Vp), 2)
    logits = jnp.where(col < cfg.vocab, logits, -1e30)
    logits = constrain(logits, "batch", None, "model")
    return logits, new_cache, all_stats
