"""Public model API: loss, train/prefill/decode step builders.

These are the functions the trainer, server, benchmarks and the multi-pod
dry-run all lower. MoR statistics flow out of the train step as
``aux['mor']`` = {'fwd': stats pytree, 'bwd': token-cotangent pytree}.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import MoRDotPolicy

from . import transformer as T
from .common import constrain

__all__ = [
    "cross_entropy", "make_loss_fn", "make_prefill_fn", "make_decode_fn",
    "init_params", "make_tokens", "cache_specs", "init_cache",
]

init_params = T.init_params
make_tokens = T.make_tokens
cache_specs = T.cache_specs
init_cache = T.init_cache


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token cross-entropy; logits (B,S,V) f32, labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def _collect_aux_losses(stats) -> jnp.ndarray:
    """Sum MoE load-balance aux losses found anywhere in the stats tree."""
    total = jnp.float32(0.0)
    flat, _ = jax.tree_util.tree_flatten_with_path(stats)
    for path, leaf in flat:
        if any("aux_loss" in str(k) for k in path):
            total = total + jnp.sum(leaf)
    return total


def make_loss_fn(cfg: ArchConfig, policy: MoRDotPolicy, *,
                 remat: bool = True, aux_coef: float = 0.01):
    """loss_fn(params, tokens, batch) -> (loss, aux).

    ``tokens`` are the zero bwd-stat tokens from make_tokens; take grads
    w.r.t. them to recover backward quantization stats.
    """

    def loss_fn(params, tokens, batch):
        logits, _, stats = T.forward(
            cfg, policy, params, tokens, batch, mode="train", remat=remat
        )
        labels = batch["labels"]
        if cfg.family == "vlm":
            # Labels cover text positions only; drop image-prefix logits.
            logits = logits[:, cfg.img_tokens :]
        loss = cross_entropy(logits, labels)
        aux_loss = _collect_aux_losses(stats)
        total = loss + aux_coef * aux_loss
        return total, {"loss": loss, "aux_loss": aux_loss, "mor_fwd": stats}

    return loss_fn


def make_prefill_fn(cfg: ArchConfig, policy: MoRDotPolicy):
    def prefill_fn(params, tokens, batch):
        logits, cache, stats = T.forward(
            cfg, policy, params, tokens, batch, mode="prefill", remat=False
        )
        return logits[:, -1:], cache, stats

    return prefill_fn


def make_decode_fn(cfg: ArchConfig, policy: MoRDotPolicy):
    """decode_fn(params, tokens, cache, token, cur_index) -> (logits,
    new_cache, stats).

    ``token`` is (B, S) int32 -- S == 1 for a plain decode step, S > 1
    for a prefill chunk written into the cache. ``cur_index`` is the
    position of the last incoming token: a scalar () shared by the
    batch, or a (B,) vector so each row of a mixed-length batch reads
    and writes at its own true position (docs/serving.md)."""

    def decode_fn(params, tokens, cache, token, cur_index):
        logits, new_cache, stats = T.forward(
            cfg, policy, params, tokens, {"token": token},
            mode="decode", cache=cache, cur_index=cur_index, remat=False,
        )
        return logits, new_cache, stats

    return decode_fn
