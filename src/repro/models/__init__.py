from .api import (
    cache_specs,
    cross_entropy,
    init_cache,
    init_params,
    make_decode_fn,
    make_loss_fn,
    make_prefill_fn,
    make_tokens,
)
from .common import use_mesh

__all__ = [
    "cache_specs", "cross_entropy", "init_cache", "init_params",
    "make_decode_fn", "make_loss_fn", "make_prefill_fn", "make_tokens",
    "use_mesh",
]
