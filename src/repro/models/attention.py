"""Chunked flash-style attention (pure JAX) + decode attention.

Training/prefill attention never materializes the (S, T) score matrix:
queries are processed in chunks (lax.map) and keys/values are streamed with
an online-softmax scan -- O(q_chunk * k_chunk) live memory per (batch, head).
This is the XLA-portable analogue of the Pallas flash kernel in
repro/kernels/flash_attention.py (used on real TPUs); both match the
reference oracle in tests.

Supports GQA/MQA (grouped heads), causal / full / prefix-LM / sliding-window
masking, all of which the assigned architectures need.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import constrain, pick_chunk

__all__ = ["flash_attention", "decode_attention"]

_NEG = -1e30


def _mask(kind: str, q_pos, k_pos, prefix_len: int, window: int):
    """(qc, kc) bool mask. q_pos: (qc,), k_pos: (kc,)."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    if kind == "full":
        m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    elif kind == "causal":
        m = kp <= qp
    elif kind == "prefix":
        m = (kp <= qp) | (kp < prefix_len)
    elif kind == "sliding":
        m = (kp <= qp) & (qp - kp < window)
    else:
        raise ValueError(kind)
    return m


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    kind: str = "causal",
    prefix_len: int = 0,
    window: int = 0,
    q_chunk: int = 512,
    k_chunk: int = 1024,
) -> jnp.ndarray:
    """q: (B, S, Hq, dh); k, v: (B, T, Hkv, dh) with Hq % Hkv == 0.

    Returns (B, S, Hq, dh) in q.dtype. Softmax in f32.
    """
    B, S, Hq, dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qc = pick_chunk(S, q_chunk)
    kc = pick_chunk(T, k_chunk)
    nq, nk = S // qc, T // kc

    scale = dh**-0.5
    qg = (q.astype(jnp.float32) * scale).reshape(B, nq, qc, Hkv, G, dh)
    qg = jnp.moveaxis(qg, 1, 0)  # (nq, B, qc, Hkv, G, dh)
    kcs = jnp.moveaxis(k.reshape(B, nk, kc, Hkv, dh), 1, 0)
    vcs = jnp.moveaxis(v.reshape(B, nk, kc, Hkv, dh), 1, 0)

    # Sliding-window: each q chunk only sees a static-size band of kv
    # chunks (dynamic start). Without this the scan visits all nk chunks
    # and masks ~(T/window)x of them away -- measured 8x wasted traffic
    # for hymba prefill_32k (Perf iteration H2).
    band = nk
    if kind == "sliding" and window > 0:
        band = min((window + qc - 2) // kc + 2, nk)

    def q_chunk_fn(args):
        qi, q_i = args  # q_i: (B, qc, Hkv, G, dh)
        q_pos = qi * qc + jnp.arange(qc)

        m0 = jnp.full((B, Hkv, G, qc), _NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qc, dh), jnp.float32)

        if band < nk:
            start = jnp.clip(
                (qi * qc - window + 1) // kc, 0, nk - band
            )
            k_sel = jax.lax.dynamic_slice_in_dim(kcs, start, band, axis=0)
            v_sel = jax.lax.dynamic_slice_in_dim(vcs, start, band, axis=0)
            k_idx = start + jnp.arange(band)
        else:
            k_sel, v_sel, k_idx = kcs, vcs, jnp.arange(nk)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, k_j, v_j = inp
            k_pos = kj * kc + jnp.arange(kc)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", q_i, k_j.astype(jnp.float32)
            )
            msk = _mask(kind, q_pos, k_pos, prefix_len, window)
            s = jnp.where(msk[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_j.astype(jnp.float32)
            )
            return (m_new, l, acc), None

        # Remat each kv step: the backward recomputes the (qc, kc) score
        # chunk from q/k instead of saving every probability chunk -- the
        # flash-attention backward. Without this, autodiff stores the full
        # S x S score matrix in f32 (measured: ~40% of HBM traffic).
        kv_step = jax.checkpoint(kv_step, prevent_cse=False)

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (k_idx, k_sel, v_sel)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)  # (B, qc, Hkv, G, dh)

    outs = jax.lax.map(q_chunk_fn, (jnp.arange(nq), qg))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, Hq, dh)
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    cur_index: jnp.ndarray,
    *,
    window: int = 0,
    k_scale: jnp.ndarray = None,
    v_scale: jnp.ndarray = None,
) -> jnp.ndarray:
    """Attention for decode / chunked prefill against a KV cache.

    q: (B, S, Hq, dh) with S >= 1 (S == 1 is plain decode; S > 1 is a
    prefill chunk); caches: (B, T, Hkv, dh). ``cur_index`` is the
    position of the *last* query token, either a scalar () shared by
    the batch or a (B,) vector of per-sequence positions -- each row of
    the batch masks against its own position, so mixed-length batches
    never attend across another request's length (docs/serving.md).
    Query s of row b sits at position cur_index[b] - (S - 1) + s; only
    cache entries at k_pos <= that position are visible. Entries beyond
    a row's own position are garbage by contract and must stay masked:
    zero-filled keys are NOT harmless (exp(0) = 1 takes real softmax
    mass).

    FP8 caches (beyond-paper, docs/serving.md): payloads are
    float8_e4m3 with per-(position, head) scales (B, T, Hkv). The
    scales factor out of both einsums -- scores divide by k_scale after
    the QK dot, and v_scale folds into the probabilities -- so the
    dequant never materializes a full-precision cache copy.
    """
    B, S, Hq, dh = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = dh**-0.5
    qg = (q.astype(jnp.float32) * scale).reshape(B, S, Hkv, G, dh)
    s = jnp.einsum("bshgd,bkhd->bhgsk", qg, k_cache.astype(jnp.float32))
    if k_scale is not None:
        ks = jnp.where(k_scale > 0, k_scale, 1.0)  # empty slots: scale 0
        s = s / jnp.moveaxis(ks, 1, 2)[:, :, None, None, :]  # (B,Hkv,1,1,T)
    cur = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(cur_index, jnp.int32)), (B,)
    )
    q_pos = cur[:, None] - (S - 1) + jnp.arange(S)  # (B, S)
    k_pos = jnp.arange(T)
    valid = k_pos[None, None, :] <= q_pos[:, :, None]  # (B, S, T)
    if window:
        valid &= k_pos[None, None, :] > q_pos[:, :, None] - window
    s = jnp.where(valid[:, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        vs = jnp.where(v_scale > 0, v_scale, 1.0)
        p = p / jnp.moveaxis(vs, 1, 2)[:, :, None, None, :]
    out = jnp.einsum("bhgsk,bkhd->bshgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, S, Hq, dh).astype(q.dtype)


def quantize_kv(x: jnp.ndarray):
    """(B, S, H, dh) -> (float8_e4m3 payload, (B, S, H) f32 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    s = jnp.where(amax > 0, 448.0 / amax, 1.0)
    payload = jnp.clip(
        x.astype(jnp.float32) * s[..., None], -448.0, 448.0
    ).astype(jnp.float8_e4m3fn)
    return payload, s
