"""Chunked flash-style attention (pure JAX) + decode attention.

Training/prefill attention never materializes the (S, T) score matrix:
queries are processed in chunks (lax.map) and keys/values are streamed with
an online-softmax scan -- O(q_chunk * k_chunk) live memory per (batch, head).
This is the XLA-portable analogue of the Pallas flash kernel in
repro/kernels/flash_attention.py (used on real TPUs); both match the
reference oracle in tests.

Supports GQA/MQA (grouped heads), causal / full / prefix-LM / sliding-window
masking, all of which the assigned architectures need.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.formats import (
    E2M1_AMAX,
    E4M3,
    E5M2,
    NVFP4,
    NVFP4_MICRO,
    cast_to_format,
    decode_e2m1,
    encode_e2m1,
    round_to_e2m1,
)
from repro.core.gam import scales_from_bmax
from repro.kernels.ref import (
    TAG_BF16,
    TAG_E4M3,
    TAG_E5M2,
    TAG_NVFP4,
    pack_mixed,
)

from .common import constrain, pick_chunk

__all__ = [
    "flash_attention",
    "decode_attention",
    "quantize_kv",
    "quantize_kv_mor",
    "recompress_kv_nvfp4",
    "kv_bytes_per_element",
    "kv_stats_row",
]

_NEG = -1e30


def _mask(kind: str, q_pos, k_pos, prefix_len: int, window: int):
    """(qc, kc) bool mask. q_pos: (qc,), k_pos: (kc,)."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    if kind == "full":
        m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    elif kind == "causal":
        m = kp <= qp
    elif kind == "prefix":
        m = (kp <= qp) | (kp < prefix_len)
    elif kind == "sliding":
        m = (kp <= qp) & (qp - kp < window)
    else:
        raise ValueError(kind)
    return m


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    kind: str = "causal",
    prefix_len: int = 0,
    window: int = 0,
    q_chunk: int = 512,
    k_chunk: int = 1024,
) -> jnp.ndarray:
    """q: (B, S, Hq, dh); k, v: (B, T, Hkv, dh) with Hq % Hkv == 0.

    Returns (B, S, Hq, dh) in q.dtype. Softmax in f32.
    """
    B, S, Hq, dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qc = pick_chunk(S, q_chunk)
    kc = pick_chunk(T, k_chunk)
    nq, nk = S // qc, T // kc

    scale = dh**-0.5
    qg = (q.astype(jnp.float32) * scale).reshape(B, nq, qc, Hkv, G, dh)
    qg = jnp.moveaxis(qg, 1, 0)  # (nq, B, qc, Hkv, G, dh)
    kcs = jnp.moveaxis(k.reshape(B, nk, kc, Hkv, dh), 1, 0)
    vcs = jnp.moveaxis(v.reshape(B, nk, kc, Hkv, dh), 1, 0)

    # Sliding-window: each q chunk only sees a static-size band of kv
    # chunks (dynamic start). Without this the scan visits all nk chunks
    # and masks ~(T/window)x of them away -- measured 8x wasted traffic
    # for hymba prefill_32k (Perf iteration H2).
    band = nk
    if kind == "sliding" and window > 0:
        band = min((window + qc - 2) // kc + 2, nk)

    def q_chunk_fn(args):
        qi, q_i = args  # q_i: (B, qc, Hkv, G, dh)
        q_pos = qi * qc + jnp.arange(qc)

        m0 = jnp.full((B, Hkv, G, qc), _NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qc, dh), jnp.float32)

        if band < nk:
            start = jnp.clip(
                (qi * qc - window + 1) // kc, 0, nk - band
            )
            k_sel = jax.lax.dynamic_slice_in_dim(kcs, start, band, axis=0)
            v_sel = jax.lax.dynamic_slice_in_dim(vcs, start, band, axis=0)
            k_idx = start + jnp.arange(band)
        else:
            k_sel, v_sel, k_idx = kcs, vcs, jnp.arange(nk)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, k_j, v_j = inp
            k_pos = kj * kc + jnp.arange(kc)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", q_i, k_j.astype(jnp.float32)
            )
            msk = _mask(kind, q_pos, k_pos, prefix_len, window)
            s = jnp.where(msk[None, None, None], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_j.astype(jnp.float32)
            )
            return (m_new, l, acc), None

        # Remat each kv step: the backward recomputes the (qc, kc) score
        # chunk from q/k instead of saving every probability chunk -- the
        # flash-attention backward. Without this, autodiff stores the full
        # S x S score matrix in f32 (measured: ~40% of HBM traffic).
        kv_step = jax.checkpoint(kv_step, prevent_cse=False)

        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (k_idx, k_sel, v_sel)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)  # (B, qc, Hkv, G, dh)

    outs = jax.lax.map(q_chunk_fn, (jnp.arange(nq), qg))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, Hq, dh)
    return out.astype(q.dtype)


def _mor_kv_values(payload: jnp.ndarray, tags: jnp.ndarray) -> jnp.ndarray:
    """Tag-select decode of a MoR KV payload into scaled-space f32.

    ``payload``: (..., dh) uint8; ``tags``: (...) per-(position, head)
    representation tags. E4M3/E5M2 bytes bitcast per tag (the mixture
    generalization of the fp8 path's monolithic e4m3 cast). TAG_NVFP4
    rows (cold sub4 pages) keep packed E2M1 nibbles in bytes
    [0, dh/2) and E4M3 micro-scale bytes (one per NVFP4_MICRO elements)
    at [dh/2, dh/2 + dh/16) -- decoded here with micro scales folded in
    (they vary along the contraction axis so they cannot fold into
    score space; the per-block scale can, and does, downstream).
    Values stay in scaled space: the caller divides scores (or
    probabilities) by the per-(position, head) block scale.
    """
    e4 = jax.lax.bitcast_convert_type(
        payload, jnp.float8_e4m3fn
    ).astype(jnp.float32)
    e5 = jax.lax.bitcast_convert_type(
        payload, jnp.float8_e5m2
    ).astype(jnp.float32)
    t = tags[..., None]
    vals = jnp.where(t == TAG_E5M2, e5, e4)
    dh = payload.shape[-1]
    if dh % NVFP4_MICRO == 0:
        nh = dh // 2
        codes = payload[..., :nh]
        lo = decode_e2m1(codes & jnp.uint8(0xF))
        hi = decode_e2m1(codes >> 4)
        pairs = jnp.stack([lo, hi], axis=-1).reshape(payload.shape)
        ms = jax.lax.bitcast_convert_type(
            payload[..., nh:nh + dh // NVFP4_MICRO], jnp.float8_e4m3fn
        ).astype(jnp.float32)
        micro = jnp.repeat(
            jnp.where(ms > 0, ms, 1.0), NVFP4_MICRO, axis=-1
        )
        vals = jnp.where(t == TAG_NVFP4, pairs * micro, vals)
    return vals


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    cur_index: jnp.ndarray,
    *,
    window: int = 0,
    k_scale: jnp.ndarray = None,
    v_scale: jnp.ndarray = None,
    k_tags: jnp.ndarray = None,
    v_tags: jnp.ndarray = None,
) -> jnp.ndarray:
    """Attention for decode / chunked prefill against a KV cache.

    q: (B, S, Hq, dh) with S >= 1 (S == 1 is plain decode; S > 1 is a
    prefill chunk); caches: (B, T, Hkv, dh). ``cur_index`` is the
    position of the *last* query token, either a scalar () shared by
    the batch or a (B,) vector of per-sequence positions -- each row of
    the batch masks against its own position, so mixed-length batches
    never attend across another request's length (docs/serving.md).
    Query s of row b sits at position cur_index[b] - (S - 1) + s; only
    cache entries at k_pos <= that position are visible. Entries beyond
    a row's own position are garbage by contract and must stay masked:
    zero-filled keys are NOT harmless (exp(0) = 1 takes real softmax
    mass).

    FP8 caches (beyond-paper, docs/serving.md): payloads are
    float8_e4m3 with per-(position, head) scales (B, T, Hkv). The
    scales factor out of both einsums -- scores divide by k_scale after
    the QK dot, and v_scale folds into the probabilities -- so the
    dequant never materializes a full-precision cache copy.

    MoR caches (docs/numerics.md): uint8 payloads + per-(position,
    head) ``k_tags``/``v_tags`` choose E4M3 / E5M2 / NVFP4 per block;
    scales fold into score space exactly as the fp8 path, the payload
    decode is the tag-select in :func:`_mor_kv_values`.

    Garbage hygiene (quantized caches): the score dequant divide is
    folded *inside* the validity mask (garbage scales from trash/stale
    pages never touch a surviving score), and value rows beyond each
    row's own position are zeroed before the PV einsum -- a masked
    probability is exactly 0, but ``0 * NaN`` (NaN/Inf payload bytes in
    the trash page) is NaN and would otherwise poison the whole output
    row. A bf16 cache only ever holds finite computed values, so its
    path keeps the original (guard-free) graph.
    """
    B, S, Hq, dh = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = dh**-0.5
    qg = (q.astype(jnp.float32) * scale).reshape(B, S, Hkv, G, dh)
    kv = (_mor_kv_values(k_cache, k_tags) if k_tags is not None
          else k_cache.astype(jnp.float32))
    s = jnp.einsum("bshgd,bkhd->bhgsk", qg, kv)
    cur = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(cur_index, jnp.int32)), (B,)
    )
    q_pos = cur[:, None] - (S - 1) + jnp.arange(S)  # (B, S)
    k_pos = jnp.arange(T)
    valid = k_pos[None, None, :] <= q_pos[:, :, None]  # (B, S, T)
    if window:
        valid &= k_pos[None, None, :] > q_pos[:, :, None] - window
    vmask = valid[:, None, None]  # (B, 1, 1, S, T)
    if k_scale is not None:
        # Mask-before-divide: garbage scales read from trash/stale
        # pages (NaN, denormal, inf) must never reach a kept score.
        ks = jnp.where(k_scale > 0, k_scale, 1.0)  # empty slots: scale 0
        s = jnp.where(
            vmask, s / jnp.moveaxis(ks, 1, 2)[:, :, None, None, :], _NEG
        )
    else:
        s = jnp.where(vmask, s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        vs = jnp.where(v_scale > 0, v_scale, 1.0)
        p = jnp.where(
            vmask, p / jnp.moveaxis(vs, 1, 2)[:, :, None, None, :], 0.0
        )
    vv = (_mor_kv_values(v_cache, v_tags) if v_tags is not None
          else v_cache.astype(jnp.float32))
    if v_tags is not None or v_scale is not None:
        # Value rows no query of this step can see are garbage by
        # contract; zero them so 0-probability lanes cannot contribute
        # 0 * NaN. Only quantized caches need this: their payload bytes
        # / scales can decode to NaN or Inf (trash page, stale rows),
        # while a bf16 cache only ever holds finite computed values.
        k_any = k_pos[None, :] <= cur[:, None]  # (B, T)
        vv = jnp.where(k_any[:, :, None, None], vv, 0.0)
    out = jnp.einsum("bhgsk,bkhd->bshgd", p, vv)
    return out.reshape(B, S, Hq, dh).astype(q.dtype)


def quantize_kv(x: jnp.ndarray):
    """(B, S, H, dh) -> (float8_e4m3 payload, (B, S, H) f32 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    s = jnp.where(amax > 0, 448.0 / amax, 1.0)
    payload = jnp.clip(
        x.astype(jnp.float32) * s[..., None], -448.0, 448.0
    ).astype(jnp.float8_e4m3fn)
    return payload, s


# ------------------------------------------------------- MoR KV cache --
# The cache tier's MoR block is one (position, head) row: the
# contraction axis of both attention einsums is dh, so a block scale is
# constant across everything a score sums over and folds into score
# space -- the same property the fp8 path's per-(position, head) scales
# exploit. Pages tile this grid exactly (a page is page_size * Hkv
# whole blocks), so per-page requantization never splits a block.
#
# The hot mixture is the two fp8 arms of the §3.2 cascade (Eq. 3 error
# comparison per block); the BF16 fallback arm is deliberately absent
# from the *storage*: a serving cache must bound bytes per token, and
# the E5M2 arm already covers the high-dynamic-range blocks BF16 would
# catch. TAG_BF16 remains representable (decode treats unknown tags as
# E4M3 only through explicit tag equality, so a BF16 tag simply never
# matches) and TAG_NVFP4 marks cold sub4-recompressed pages.


def quantize_kv_mor(x: jnp.ndarray, with_stats: bool = False):
    """MoR-quantize KV rows: (B, S, H, dh) -> (payload, tags, scales).

    Per (position, head) block: both GAM fp8 candidates, the Eq. 3
    relative-error comparison, and the winner's real payload bytes --
    routed through the same ``scales_from_bmax`` / ``pack_mixed``
    primitives as ``quantize_pack``, so cache bytes are bit-identical
    to what the GEMM-side packer would emit for the same tags.

    Returns ``(payload (B,S,H,dh) u8, tags (B,S,H) u8, scales (B,S,H)
    f32)``; scales are always > 0 for written rows (unwritten cache
    rows keep their zero-initialized scale, the emptiness marker the
    decode guard keys on). With ``with_stats``, also returns a
    STATS_WIDTH stats row (:func:`kv_stats_row`).
    """
    B, S, H, dh = x.shape
    x2 = x.astype(jnp.float32).reshape(B * S * H, dh)
    bmax = jnp.max(jnp.abs(x2), axis=-1, keepdims=True)  # (R, 1)
    s4 = scales_from_bmax(bmax, E4M3, "gam").scale
    s5 = scales_from_bmax(bmax, E5M2, "gam").scale

    nz = x2 != 0
    safe = jnp.where(nz, x2, 1.0)

    def err(s, fmt):
        dq = cast_to_format(
            jnp.clip(x2 * s, -fmt.amax, fmt.amax), fmt
        ) / s
        return jnp.sum(
            jnp.where(nz, jnp.abs((x2 - dq) / safe), 0.0), axis=-1
        )

    e4 = err(s4, E4M3)
    e5 = err(s5, E5M2)
    sel = jnp.where(e4 < e5, TAG_E4M3, TAG_E5M2)  # Eq. 3, two fp8 arms
    mo = pack_mixed(x2, sel.reshape(-1, 1), (1, dh))
    payload = mo.payload_q.reshape(B, S, H, dh)
    tags = sel.astype(jnp.uint8).reshape(B, S, H)
    scales = mo.scales.astype(jnp.float32).reshape(B, S, H)
    if with_stats:
        return payload, tags, scales, kv_stats_row(tags)
    return payload, tags, scales


def recompress_kv_nvfp4(payload: jnp.ndarray, tags: jnp.ndarray,
                        scales: jnp.ndarray):
    """Sub4-recompress cold KV rows in place of their fp8 payloads.

    ``payload`` (..., H, dh) u8, ``tags``/``scales`` (..., H): any
    leading shape (the pool passes whole page slabs). Each
    (position, head) block re-encodes from its stored hot-tier values
    to the two-level NVFP4 representation -- packed E2M1 nibble pairs
    in payload bytes [0, dh/2), E4M3 micro-scale bytes (one per
    NVFP4_MICRO elements) at [dh/2, dh/2 + dh/16), remainder zero --
    so a cold page occupies 0.5625 logical bytes per element inside
    the same lane. Requires ``dh % NVFP4_MICRO == 0``.
    """
    dh = payload.shape[-1]
    if dh % NVFP4_MICRO:
        raise ValueError(
            f"sub4 KV recompression needs head_dim divisible by "
            f"{NVFP4_MICRO}, got {dh}"
        )
    ss = jnp.where(scales > 0, scales, 1.0)[..., None]
    vals = _mor_kv_values(payload, tags) / ss  # stored true values
    bmax = jnp.max(jnp.abs(vals), axis=-1, keepdims=True)
    s_nv = scales_from_bmax(bmax, NVFP4, "gam").scale
    xs = vals * s_nv
    g = xs.reshape(*xs.shape[:-1], dh // NVFP4_MICRO, NVFP4_MICRO)
    d = jnp.max(jnp.abs(g), axis=-1) / E2M1_AMAX
    d_q = cast_to_format(d, E4M3)
    safe_d = jnp.where(d_q > 0, d_q, 1.0)
    codes = encode_e2m1(
        round_to_e2m1(g / safe_d[..., None])
    ).reshape(xs.shape).astype(jnp.uint8)
    nib = (codes[..., 0::2] | (codes[..., 1::2] << 4)).astype(jnp.uint8)
    ms = jax.lax.bitcast_convert_type(
        safe_d.astype(jnp.float8_e4m3fn), jnp.uint8
    )
    pad = jnp.zeros(
        (*payload.shape[:-1], dh - dh // 2 - dh // NVFP4_MICRO),
        jnp.uint8,
    )
    new_payload = jnp.concatenate([nib, ms, pad], axis=-1)
    new_tags = jnp.full_like(tags, TAG_NVFP4)
    return new_payload, new_tags, s_nv[..., 0].astype(jnp.float32)


# Logical payload bytes per cache element by tag (fp8 byte, BF16 pair,
# NVFP4 nibble + its amortized micro-scale byte).
_TAG_BPE = {
    TAG_E4M3: 1.0,
    TAG_E5M2: 1.0,
    TAG_BF16: 2.0,
    TAG_NVFP4: 0.5 + 1.0 / NVFP4_MICRO,
}


def kv_bytes_per_element(tags: jnp.ndarray) -> jnp.ndarray:
    """Mean logical payload bytes per element implied by ``tags``."""
    t = jnp.asarray(tags).reshape(-1).astype(jnp.int32)
    bpe = jnp.zeros(t.shape, jnp.float32)
    for tag, b in _TAG_BPE.items():
        bpe = jnp.where(t == tag, b, bpe)
    return jnp.mean(bpe)


def kv_stats_row(tags: jnp.ndarray) -> jnp.ndarray:
    """One STATS_WIDTH (layout v4) stats row for a KV-cache
    quantization event.

    Same layout as the GEMM events (core.mor): [0] decision (1.0, the
    cache tier always quantizes), [3..5] frac_e4m3/e5m2/bf16, [6] block
    count, [7] m_g slot (1.0 -- per-event group), [8] frac_nvfp4,
    [9] micro-scale bytes per element, [11] payload bytes/element of
    the tag mixture. [1]/[2] (rel_err, amax) are 0: the cache path
    never re-reads its operand to price the error. [10] (event_kind)
    stays 0 -- cache rows ride the GEMM-event channel.
    """
    from repro.core.mor import (
        STAT_DECISION,
        STAT_FRAC_BF16,
        STAT_FRAC_E4M3,
        STAT_FRAC_E5M2,
        STAT_FRAC_NVFP4,
        STAT_GROUP_MANTISSA,
        STAT_MICRO_SCALE_BPE,
        STAT_NONZERO_FRAC,
        STAT_PAYLOAD_BPE,
        STATS_WIDTH,
    )

    t = jnp.asarray(tags).reshape(-1).astype(jnp.int32)
    n = t.size
    frac = lambda tag: jnp.mean((t == tag).astype(jnp.float32))
    f_nv = frac(TAG_NVFP4)
    row = jnp.zeros((STATS_WIDTH,), jnp.float32)
    row = row.at[STAT_DECISION].set(1.0)
    row = row.at[STAT_FRAC_E4M3].set(frac(TAG_E4M3))
    row = row.at[STAT_FRAC_E5M2].set(frac(TAG_E5M2))
    row = row.at[STAT_FRAC_BF16].set(frac(TAG_BF16))
    row = row.at[STAT_NONZERO_FRAC].set(float(n))
    row = row.at[STAT_GROUP_MANTISSA].set(1.0)
    row = row.at[STAT_FRAC_NVFP4].set(f_nv)
    row = row.at[STAT_MICRO_SCALE_BPE].set(f_nv / NVFP4_MICRO)
    row = row.at[STAT_PAYLOAD_BPE].set(
        frac(TAG_E4M3) + frac(TAG_E5M2) + 2.0 * frac(TAG_BF16)
        + (0.5 + 1.0 / NVFP4_MICRO) * f_nv
    )
    return row
