"""Shared model building blocks: norms, RoPE, activations, scan helpers,
and mesh-agnostic sharding constraints.

Model code never imports a concrete mesh; `constrain(x, *axes)` applies a
``with_sharding_constraint`` only when a mesh has been installed via
:func:`use_mesh` (done by the dry-run / trainer before tracing). This keeps
the model definitions runnable on a single CPU device for smoke tests.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "use_mesh", "current_mesh", "constrain", "batch_axes", "rms_norm",
    "layer_norm", "apply_rope", "rope_freqs", "sinusoidal_positions",
    "activation", "chunked_scan", "pick_chunk", "glu_split",
]

_MESH: contextvars.ContextVar = contextvars.ContextVar("repro_mesh",
                                                       default=None)


@contextlib.contextmanager
def use_mesh(mesh):
    token = _MESH.set(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _MESH.reset(token)


def current_mesh():
    return _MESH.get()


def batch_axes() -> Tuple[str, ...]:
    """Mesh axes the global batch is sharded over."""
    mesh = current_mesh()
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def constrain(x: jnp.ndarray, *spec: Any) -> jnp.ndarray:
    """with_sharding_constraint(x, P(*spec)) if a mesh is installed.

    Spec entries may be axis names, None, tuples, or the sentinel 'batch'
    which expands to the batch axes of the current mesh.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    resolved = []
    for s in spec:
        if s == "batch":
            resolved.append(batch_axes() or None)
        elif isinstance(s, str):
            resolved.append(s if s in names else None)
        elif isinstance(s, (tuple, list)):
            kept = tuple(a for a in s if a in names)
            resolved.append(kept or None)
        else:
            resolved.append(s)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved))
    )


# ------------------------------------------------------------------ norms --
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(
        x.dtype
    )


# ------------------------------------------------------------------- rope --
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, S, H, dh), positions: (B, S) or (S,) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, dh/2)
    if ang.ndim == 2:  # (S, dh/2) -> broadcast over batch
        ang = ang[None]
    cos = jnp.cos(ang)[:, :, None, :]  # (B, S, 1, dh/2)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d_model: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d_model))
    out = jnp.zeros((seq, d_model), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out


# ------------------------------------------------------------- activations --
def activation(name: str) -> Callable[[jnp.ndarray], jnp.ndarray]:
    if name in ("swiglu", "silu"):
        return jax.nn.silu
    if name in ("geglu", "gelu"):
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def glu_split(h: jnp.ndarray, gated: bool, act_fn):
    """Apply (gated) activation to the fc1 output."""
    if gated:
        g, u = jnp.split(h, 2, axis=-1)
        return act_fn(g) * u
    return act_fn(h)


# ------------------------------------------------------------------- scans --
def pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (>=1)."""
    c = min(target, n)
    while n % c:
        c -= 1
    return c


def chunked_scan(
    f: Callable,
    init,
    xs,
    length: int,
    chunk: int,
    remat: bool = True,
):
    """lax.scan over ``length`` steps in outer chunks with inner remat.

    ``f(carry, x_t) -> (carry, y_t)``. xs is a pytree with leading axis
    ``length``. Memory for backward is O(length/chunk boundary states +
    one chunk of per-step residuals).
    """
    chunk = pick_chunk(length, chunk)
    n_out = length // chunk

    def reshape_leaf(x):
        return x.reshape(n_out, chunk, *x.shape[1:])

    xs_c = jax.tree.map(reshape_leaf, xs)

    def inner(carry, xc):
        return jax.lax.scan(f, carry, xc)

    if remat:
        inner = jax.checkpoint(inner, prevent_cse=False)

    carry, ys = jax.lax.scan(inner, init, xs_c)

    def unreshape_leaf(y):
        return y.reshape(length, *y.shape[2:])

    return carry, jax.tree.map(unreshape_leaf, ys)
