"""Recurrent sequence mixers: Mamba-style selective SSM (Hymba's parallel
head branch) and xLSTM cells (mLSTM matrix memory + sLSTM scalar memory).

TPU adaptation notes (docs/architecture.md): all *time-parallel* projections are
hoisted out of the recurrence and MoR-quantized (they are the GEMM hot
spots); the per-step recurrences run under a remat-chunked lax.scan with
states sharded over the model axis (d_inner channels for Mamba, the value
dim of the mLSTM matrix memory), so the 500k-token decode state stays
O(d*state/TP) per chip.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import MoRDotPolicy, mor_dot
from repro.configs.base import ArchConfig

from .common import activation, chunked_scan, constrain, rms_norm

__all__ = ["mamba_mix", "mlstm_mix", "slstm_mix"]

SCAN_CHUNK = 64


# ------------------------------------------------------------------ mamba --
def _causal_dw_conv(x, w, conv_state=None):
    """Depthwise causal conv along time. x: (B, S, D); w: (cw, D).

    Returns (y, new_state) where state is the trailing (cw-1) inputs.
    """
    B, S, D = x.shape
    cw = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((B, cw - 1, D), x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = jnp.zeros((B, S, D), jnp.float32)
    for i in range(cw):  # cw is tiny (4): unrolled taps
        y = y + xp[:, i : i + S].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )
    new_state = xp[:, -(cw - 1) :]
    return y.astype(x.dtype), new_state


def mamba_mix(
    p,
    xn: jnp.ndarray,
    tok,
    policy: MoRDotPolicy,
    cfg: ArchConfig,
    mode: str,
    cache: Optional[Dict[str, jnp.ndarray]],
):
    """Selective SSM branch. xn: (B, S, d) -> (B, S, d).

    cache = {'h': (B, di, N) f32, 'conv': (B, cw-1, di)}.
    """
    B, S, d = xn.shape
    di, N, cw = cfg.mamba_d_inner, cfg.ssm_state, cfg.conv_width

    xz, st_in = mor_dot(xn, p["w_in"], tok["ssm_in"], policy)  # (B,S,2di)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = constrain(x_in, "batch", None, "model")

    conv_state = cache["conv"] if cache is not None else None
    x_c, new_conv = _causal_dw_conv(x_in, p["conv_w"], conv_state)
    x_c = jax.nn.silu(x_c.astype(jnp.float32))

    # Data-dependent SSM parameters (small projections, BF16 per paper
    # policy -- only the big linears are quantized).
    bc = jnp.einsum("bsd,dn->bsn", x_c, p["w_bc"].astype(jnp.float32))
    B_t, C_t = jnp.split(bc, 2, axis=-1)  # (B, S, N) each
    dt = jax.nn.softplus(
        jnp.einsum(
            "bsd,dr,re->bse",
            x_c,
            p["w_dt_down"].astype(jnp.float32),
            p["w_dt_up"].astype(jnp.float32),
        )
        + p["dt_bias"].astype(jnp.float32)
    )  # (B, S, di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, N)

    h0 = (
        cache["h"].astype(jnp.float32)
        if cache is not None
        else jnp.zeros((B, di, N), jnp.float32)
    )
    h0 = constrain(h0, "batch", "model", None)

    # The (di x N)-sized per-step quantities da = exp(dt*A) and
    # dbx = dt*B*x are formed *inside* the step from the (di)- and
    # (N)-sized streams: materializing them for all S costs S*di*N
    # traffic (~16x the inputs) and dominated the hymba prefill memory
    # roofline (Perf iteration H1).
    def ssm_step(h, inp):
        dt_t, b_t, c_t, x_t = inp  # (B,di), (B,N), (B,N), (B,di)
        da_t = jnp.exp(dt_t[..., None] * A)
        h = da_t * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        return h, jnp.einsum("bdn,bn->bd", h, c_t)

    if mode == "decode":  # S == 1: one recurrence step, no scan.
        new_h, y = ssm_step(h0, (dt[:, 0], B_t[:, 0], C_t[:, 0], x_c[:, 0]))
        y = y[:, None]
    else:
        xs = (
            jnp.moveaxis(dt, 1, 0),
            jnp.moveaxis(B_t, 1, 0),
            jnp.moveaxis(C_t, 1, 0),
            jnp.moveaxis(x_c, 1, 0),
        )
        new_h, ys = chunked_scan(ssm_step, h0, xs, S, SCAN_CHUNK)
        y = jnp.moveaxis(ys, 0, 1)  # (B, S, di)

    y = y + x_c * p["D"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(xn.dtype)
    out, st_out = mor_dot(y, p["w_out"], tok["ssm_out"], policy)

    new_cache = (
        {"h": new_h.astype(jnp.float32), "conv": new_conv}
        if mode in ("decode", "prefill")
        else None
    )
    return out, new_cache, {"ssm_in": st_in, "ssm_out": st_out}


# ------------------------------------------------------------------ mLSTM --
def mlstm_mix(
    p,
    xn: jnp.ndarray,
    tok,
    policy: MoRDotPolicy,
    cfg: ArchConfig,
    mode: str,
    cache,
):
    """xLSTM mLSTM block body (matrix memory, exponential gating).

    cache = {'C': (B,H,dh,dh) f32, 'n': (B,H,dh) f32, 'm': (B,H) f32}.
    """
    B, S, d = xn.shape
    H = cfg.n_heads
    di = 2 * d  # xLSTM mLSTM expansion factor 2
    dh = di // H

    up, st_up = mor_dot(xn, p["w_up"], tok["up"], policy)  # (B,S,2di)
    x_i, z = jnp.split(up, 2, axis=-1)
    qkv, st_qkv = mor_dot(x_i, p["w_qkv"], tok["qkv"], policy)  # (B,S,3di)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, H, dh) * (dh**-0.5)
    v = v.reshape(B, S, H, dh)
    # Gate pre-activations (tiny projection, BF16).
    gates = jnp.einsum(
        "bsd,dg->bsg", x_i, p["w_gate"].astype(x_i.dtype)
    ).astype(jnp.float32) + p["gate_bias"].astype(jnp.float32)
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)  # (B, S, H)

    if cache is not None:
        C0 = cache["C"].astype(jnp.float32)
        n0 = cache["n"].astype(jnp.float32)
        m0 = cache["m"].astype(jnp.float32)
    else:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    C0 = constrain(C0, "batch", None, "model", None)

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, i_t, f_t = inp  # (B,H,dh) x3, (B,H) x2
        log_f = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(log_f + m, i_t)
        i_p = jnp.exp(i_t - m_new)[..., None]
        f_p = jnp.exp(log_f + m - m_new)[..., None]
        C = f_p[..., None] * C + i_p[..., None] * (
            v_t[..., :, None] * k_t[..., None, :]
        )
        n = f_p * n + i_p * k_t
        num = jnp.einsum("bhvk,bhk->bhv", C, q_t)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t))
        den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (C, n, m_new), num / den

    if mode == "decode":
        (C1, n1, m1), y = step(
            (C0, n0, m0),
            (
                q[:, 0].astype(jnp.float32),
                k[:, 0].astype(jnp.float32),
                v[:, 0].astype(jnp.float32),
                i_raw[:, 0],
                f_raw[:, 0],
            ),
        )
        y = y[:, None]  # (B, 1, H, dh)
    else:
        xs = (
            jnp.moveaxis(q, 1, 0).astype(jnp.float32),
            jnp.moveaxis(k, 1, 0).astype(jnp.float32),
            jnp.moveaxis(v, 1, 0).astype(jnp.float32),
            jnp.moveaxis(i_raw, 1, 0),
            jnp.moveaxis(f_raw, 1, 0),
        )
        (C1, n1, m1), ys = chunked_scan(step, (C0, n0, m0), xs, S, SCAN_CHUNK)
        y = jnp.moveaxis(ys, 0, 1)  # (B, S, H, dh)

    y = rms_norm(y.reshape(B, -1, di).astype(xn.dtype), p["out_norm"])
    y = y * jax.nn.silu(z)
    out, st_dn = mor_dot(y, p["w_down"], tok["down"], policy)

    new_cache = (
        {"C": C1, "n": n1, "m": m1}
        if mode in ("decode", "prefill")
        else None
    )
    return out, new_cache, {"up": st_up, "qkv": st_qkv, "down": st_dn}


# ------------------------------------------------------------------ sLSTM --
def slstm_mix(
    p,
    xn: jnp.ndarray,
    tok,
    policy: MoRDotPolicy,
    cfg: ArchConfig,
    mode: str,
    cache,
):
    """xLSTM sLSTM block body (scalar memory, block-diagonal recurrence).

    cache = {'h','c','n','m'}: (B, d) f32 each.
    The input projection W (d -> 4d) is time-parallel and MoR-quantized;
    the per-step block-diagonal recurrence R stays BF16 (inside the scan).
    """
    B, S, d = xn.shape
    H = cfg.n_heads
    dh = d // H

    wx, st_w = mor_dot(xn, p["w_x"], tok["wx"], policy)  # (B, S, 4d)
    wx = wx.astype(jnp.float32)
    R = p["r"].astype(jnp.float32)  # (H, dh, 4*dh)

    if cache is not None:
        h0, c0 = cache["h"].astype(jnp.float32), cache["c"].astype(jnp.float32)
        n0, m0 = cache["n"].astype(jnp.float32), cache["m"].astype(jnp.float32)
    else:
        h0 = jnp.zeros((B, d), jnp.float32)
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.ones((B, d), jnp.float32)
        m0 = jnp.zeros((B, d), jnp.float32)

    def step(carry, wx_t):
        h, c, n, m = carry
        rh = jnp.einsum(
            "bhk,hkg->bhg", h.reshape(B, H, dh), R
        ).reshape(B, 4 * d)
        pre = wx_t + rh
        z_p, i_p, f_p, o_p = jnp.split(pre, 4, axis=-1)
        z_t = jnp.tanh(z_p)
        log_f = jax.nn.log_sigmoid(f_p)
        m_new = jnp.maximum(log_f + m, i_p)
        i_g = jnp.exp(i_p - m_new)
        f_g = jnp.exp(log_f + m - m_new)
        c = f_g * c + i_g * z_t
        n = f_g * n + i_g
        h = jax.nn.sigmoid(o_p) * (c / jnp.maximum(n, 1e-6))
        return (h, c, n, m_new), h

    if mode == "decode":
        (h1, c1, n1, m1), y = step((h0, c0, n0, m0), wx[:, 0])
        y = y[:, None]
    else:
        (h1, c1, n1, m1), ys = chunked_scan(
            step, (h0, c0, n0, m0), jnp.moveaxis(wx, 1, 0), S, SCAN_CHUNK
        )
        y = jnp.moveaxis(ys, 0, 1)

    # Gated feed-forward (factor 4/3, per the xLSTM block spec).
    y = rms_norm(y.astype(xn.dtype), p["out_norm"])
    hf, st_f1 = mor_dot(y, p["w_ff1"], tok["ff1"], policy)
    g, u = jnp.split(hf, 2, axis=-1)
    hf = jax.nn.silu(g) * u
    out, st_f2 = mor_dot(hf, p["w_ff2"], tok["ff2"], policy)

    new_cache = (
        {"h": h1, "c": c1, "n": n1, "m": m1}
        if mode in ("decode", "prefill")
        else None
    )
    return out, new_cache, {"wx": st_w, "ff1": st_f1, "ff2": st_f2}
