"""Transformer sublayers with MoR-quantized linears.

Every GEMM the paper quantizes (linear_qkv, linear_proj, fc1, fc2, and the
MoE expert FFNs) goes through :func:`repro.core.mor_dot`; routers, norms and
embeddings stay BF16, matching the paper's policy.

Block functions share the signature
    f(p, x, tok, policy, cfg, mode, cache, cur_index) -> (x, cache, stats)
where ``p``/``tok``/``cache`` are this layer's slices of the stacked
per-layer pytrees (see transformer.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import MoRDotPolicy, mor_dot
from repro.configs.base import ArchConfig

from .attention import decode_attention, flash_attention
from .common import (
    activation,
    apply_rope,
    constrain,
    glu_split,
    layer_norm,
    pick_chunk,
    rms_norm,
)

__all__ = [
    "norm", "attn_sublayer", "mlp_sublayer", "moe_sublayer",
    "dense_block", "moe_block",
]


def norm(p_norm, x, cfg: ArchConfig):
    if cfg.norm == "ln":
        return layer_norm(x, p_norm["scale"], p_norm["bias"])
    return rms_norm(x, p_norm["scale"])


def _split_qkv(qkv, cfg: ArchConfig):
    B, S = qkv.shape[:2]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q, k, v = jnp.split(qkv, [hq * hd, (hq + hkv) * hd], axis=-1)
    return (
        q.reshape(B, S, hq, hd),
        k.reshape(B, S, hkv, hd),
        v.reshape(B, S, hkv, hd),
    )


def attn_sublayer(
    p,
    xn,
    tok,
    policy: MoRDotPolicy,
    cfg: ArchConfig,
    mode: str,
    cache: Optional[Dict[str, jnp.ndarray]],
    cur_index,
    *,
    kind: str = "causal",
    prefix_len: int = 0,
    window: int = 0,
    use_rope: bool = True,
):
    """Self-attention with GQA + RoPE + KV cache. Returns (y, cache, stats)."""
    B, S, _ = xn.shape
    qkv, st_qkv = mor_dot(xn, p["wqkv"], tok["qkv"], policy)
    # Pin the SP->TP transition on the BF16 GEMM output: without this
    # GSPMD reshards f32 rope/quant intermediates (2x collective bytes,
    # Perf iteration 5).
    if mode != "decode" and S > 1:
        qkv = constrain(qkv, "batch", None, "model")
    q, k, v = _split_qkv(qkv, cfg)

    if mode == "decode":
        # cur_index is the position of the LAST query token: a scalar
        # shared across the batch, or a (B,) vector of per-slot
        # positions (the serving engine's mixed-length batches). The
        # incoming S tokens land at positions cur - (S-1) .. cur, each
        # row at its own offset, written *before* attention so a row's
        # own keys are always visible (docs/serving.md).
        cur = jnp.broadcast_to(
            jnp.atleast_1d(jnp.asarray(cur_index, jnp.int32)), (B,)
        )
        pos = cur[:, None] - (S - 1) + jnp.arange(S, dtype=jnp.int32)[None]
        if use_rope:
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
        rows = jnp.arange(B, dtype=jnp.int32)[:, None]
        upd = lambda buf, val: buf.at[rows, pos].set(val.astype(buf.dtype))
        mor_cache = "k_tags" in cache
        fp8_cache = (not mor_cache) and "k_scale" in cache
        if mor_cache:
            # MoR cache tier: per-(position, head) tag-select between
            # the fp8 arms + GAM scales (docs/numerics.md); decode
            # folds the scales into score space per tag.
            from .attention import quantize_kv_mor

            k_pay, k_t, k_s = quantize_kv_mor(k)
            v_pay, v_t, v_s = quantize_kv_mor(v)
            new_cache = {
                "k": upd(cache["k"], k_pay),
                "v": upd(cache["v"], v_pay),
                "k_tags": upd(cache["k_tags"], k_t),
                "v_tags": upd(cache["v_tags"], v_t),
                "k_scale": upd(cache["k_scale"], k_s),
                "v_scale": upd(cache["v_scale"], v_s),
            }
            out = decode_attention(
                q, new_cache["k"], new_cache["v"], cur,
                window=window, k_scale=new_cache["k_scale"],
                v_scale=new_cache["v_scale"],
                k_tags=new_cache["k_tags"], v_tags=new_cache["v_tags"],
            )
        elif fp8_cache:
            from .attention import quantize_kv

            k_pay, k_s = quantize_kv(k)
            v_pay, v_s = quantize_kv(v)
            new_cache = {
                "k": upd(cache["k"], k_pay),
                "v": upd(cache["v"], v_pay),
                "k_scale": upd(cache["k_scale"], k_s),
                "v_scale": upd(cache["v_scale"], v_s),
            }
            out = decode_attention(
                q, new_cache["k"], new_cache["v"], cur,
                window=window, k_scale=new_cache["k_scale"],
                v_scale=new_cache["v_scale"],
            )
        else:
            k_cache = upd(cache["k"], k)
            v_cache = upd(cache["v"], v)
            out = decode_attention(
                q, k_cache, v_cache, cur, window=window
            )
            new_cache = {"k": k_cache, "v": v_cache}
    else:
        pos = jnp.arange(S, dtype=jnp.int32)[None]
        if use_rope:
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
        out = flash_attention(
            q, k, v, kind=kind, prefix_len=prefix_len, window=window
        )
        new_cache = (
            {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}
            if mode == "prefill"
            else None
        )

    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    y, st_proj = mor_dot(out, p["wo"], tok["proj"], policy)
    return y, new_cache, {"qkv": st_qkv, "proj": st_proj}


def mlp_sublayer(p, xn, tok, policy: MoRDotPolicy, cfg: ArchConfig,
                 d_ff: Optional[int] = None):
    gated = cfg.act in ("swiglu", "geglu")
    act_fn = activation(cfg.act)
    h, st1 = mor_dot(xn, p["wi"], tok["fc1"], policy)
    h = glu_split(h, gated, act_fn)
    y, st2 = mor_dot(h, p["wo"], tok["fc2"], policy)
    return y, {"fc1": st1, "fc2": st2}


# -------------------------------------------------------------------- MoE --
def moe_sublayer(p, xn, tok, policy: MoRDotPolicy, cfg: ArchConfig):
    """Capacity-based MoE with per-(example, chunk) grouping.

    Tokens are chunked along the sequence axis (scan => bounded transients);
    each (example, chunk) group dispatches into an (E, C, d) buffer via
    one-hot einsums (GSPMD-friendly: group dim rides the data axis, expert
    dim rides the model axis). Expert FFN GEMMs are MoR-quantized per
    expert via vmap(mor_dot).
    """
    B, S, d = xn.shape
    E, K = cfg.n_experts, cfg.top_k
    gated = cfg.act in ("swiglu", "geglu")
    act_fn = activation(cfg.act)

    s_sub = pick_chunk(S, 256)
    n_sub = S // s_sub
    C = max(1, int(K * s_sub / E * cfg.capacity_factor))

    w1, w2, router = p["w1"], p["w2"], p["router"]
    tok_w1, tok_w2 = tok["w1"], tok["w2"]

    xc = xn.reshape(B, n_sub, s_sub, d)
    xc = jnp.moveaxis(xc, 1, 0)  # (n_sub, B, s_sub, d)

    def chunk_fn(_, x_c):
        # x_c: (B, t, d)
        logits = jnp.einsum(
            "btd,de->bte", x_c, router, preferred_element_type=jnp.float32
        )
        probs = jax.nn.softmax(logits, axis=-1)
        vals, idx = jax.lax.top_k(probs, K)  # (B, t, K)
        vals = vals / jnp.maximum(
            jnp.sum(vals, -1, keepdims=True), 1e-9
        )
        # Flatten the K token-copies.
        t = x_c.shape[1]
        ids = idx.reshape(B, t * K)
        gate = vals.reshape(B, t * K)
        oh = jax.nn.one_hot(ids, E, dtype=jnp.float32)  # (B, tK, E)
        pos = jnp.cumsum(oh, axis=1) - oh
        slot = jnp.sum(pos * oh, axis=-1)  # (B, tK)
        keep = (slot < C).astype(jnp.float32)
        slot_oh = jax.nn.one_hot(
            jnp.minimum(slot, C - 1).astype(jnp.int32), C, dtype=jnp.float32
        ) * keep[..., None]
        x_rep = jnp.repeat(
            x_c.astype(jnp.float32), K, axis=1
        )  # (B, tK, d)

        xbuf = jnp.einsum("bse,bsc,bsd->ebcd", oh, slot_oh, x_rep)
        xbuf = constrain(xbuf, "model", "batch", None, None)
        xbuf = xbuf.astype(xn.dtype)

        h, st1 = jax.vmap(
            lambda a, w, tk: mor_dot(a, w, tk, policy)
        )(xbuf, w1, tok_w1)
        h = glu_split(h, gated, act_fn)
        ybuf, st2 = jax.vmap(
            lambda a, w, tk: mor_dot(a, w, tk, policy)
        )(h, w2, tok_w2)

        y = jnp.einsum(
            "bse,bsc,bs,ebcd->bsd",
            oh, slot_oh, gate, ybuf.astype(jnp.float32),
        )
        y = y.reshape(B, t, K, d).sum(axis=2)

        # Load-balance aux loss (Switch-style) + drop fraction.
        me = jnp.mean(oh.reshape(B, t, K, E).sum(2), axis=(0, 1))
        ce = jnp.mean(probs, axis=(0, 1))
        aux = jnp.sum(me * ce) * E
        dropped = 1.0 - jnp.mean(keep)
        return None, (y.astype(xn.dtype), st1, st2, aux, dropped)

    _, (ys, st1, st2, aux, dropped) = jax.lax.scan(chunk_fn, None, xc)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d)
    stats = {
        "w1": jnp.mean(st1, axis=0),  # (E, 2, W) averaged over chunks
        "w2": jnp.mean(st2, axis=0),
        "aux_loss": jnp.mean(aux),
        "dropped": jnp.mean(dropped),
    }
    return y, stats


# ------------------------------------------------------------ full blocks --
def dense_block(p, x, tok, policy, cfg, mode, cache, cur_index, **attn_kw):
    xn = norm(p["ln1"], x, cfg)
    a, new_cache, st_a = attn_sublayer(
        p, xn, tok, policy, cfg, mode, cache, cur_index, **attn_kw
    )
    x = x + a
    xn2 = norm(p["ln2"], x, cfg)
    m, st_m = mlp_sublayer(p["mlp"], xn2, tok, policy, cfg)
    x = x + m
    return x, new_cache, {**st_a, **st_m}


def moe_block(p, x, tok, policy, cfg, mode, cache, cur_index, **attn_kw):
    xn = norm(p["ln1"], x, cfg)
    a, new_cache, st_a = attn_sublayer(
        p, xn, tok, policy, cfg, mode, cache, cur_index, **attn_kw
    )
    x = x + a
    xn2 = norm(p["ln2"], x, cfg)
    m, st_m = moe_sublayer(p["moe"], xn2, tok, policy, cfg)
    x = x + m
    return x, new_cache, {**st_a, **st_m}
