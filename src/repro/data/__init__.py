from .pipeline import DataConfig, SyntheticLM, prefetch

__all__ = ["DataConfig", "SyntheticLM", "prefetch"]
