"""Deterministic, shardable synthetic data pipeline.

Produces a reproducible token stream from a seed: batch ``i`` is a pure
function of (seed, step, shard), so any host in a multi-host job can
generate exactly its shard without communication, and restarts resume
bit-identically from the step counter (fault tolerance depends on this).

The generator is a structured Markov-ish stream (not uniform noise) so
small models actually have something learnable: token t+1 depends on
token t through a fixed random permutation plus noise -- cross-entropy
drops well below ln(V) within a few hundred steps, which the quality
benchmarks (paper Tables 2-4 analogues) rely on.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "prefetch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    # Structure: probability the next token follows the permutation rule.
    order: float = 0.8
    shard_id: int = 0
    num_shards: int = 1


class SyntheticLM:
    """step -> {'tokens': (B_local, S) i32, 'labels': (B_local, S) i32}."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.num_shards:
            raise ValueError(
                f"global_batch {cfg.global_batch} must divide evenly "
                f"over {cfg.num_shards} shard(s)"
            )
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_shards
        root = np.random.default_rng(cfg.seed)
        self.perm = root.permutation(cfg.vocab)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.shard_id)
        )
        B, S = self.local_batch, cfg.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, B)
        follow = rng.random((B, S)) < cfg.order
        noise = rng.integers(0, cfg.vocab, (B, S))
        for t in range(S):
            nxt = self.perm[toks[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, noise[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Background-thread prefetcher (overlaps host datagen with steps)."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
