"""AdamW with f32 master weights + moments (Megatron-style mixed precision).

Model params live in BF16; the optimizer state holds an f32 master copy
plus Adam moments, all ZeRO-1-shardable (see repro.sharding.rules). The
update runs on the master weights and re-casts to BF16 params.

No optax in this environment -- this is a standalone implementation with
global-norm clipping and a cosine LR schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "adamw_update",
           "cosine_lr", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    final_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    master: Any  # f32 master weights (pytree like params)
    m: Any
    v: Any
    step: jnp.ndarray  # () int32


def init_opt_state(params) -> OptState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.final_lr + 0.5 * (cfg.peak_lr - cfg.final_lr) * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    cfg: AdamWConfig,
    grads,
    opt_state: OptState,
    *,
    decay_mask=None,
) -> Tuple[Any, OptState, dict]:
    """Returns (new bf16 params, new opt state, metrics)."""
    step = opt_state.step + 1
    lr = cosine_lr(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(
        lambda g: g.astype(jnp.float32) * scale, grads
    )

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g, opt_state.m, grads
    )
    new_v = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * g * g, opt_state.v, grads
    )

    if decay_mask is None:
        decay_mask = jax.tree.map(
            lambda p: 1.0 if p.ndim >= 2 else 0.0, opt_state.master
        )

    def upd(master, m, v, wd):
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * wd * master
        return master - lr * delta

    new_master = jax.tree.map(
        upd, opt_state.master, new_m, new_v, decay_mask
    )
    new_params = jax.tree.map(
        lambda p: p.astype(jnp.bfloat16), new_master
    )
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, OptState(new_master, new_m, new_v, step), metrics
