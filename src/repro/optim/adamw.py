"""AdamW with f32 master weights + moments (Megatron-style mixed precision).

Model params live in BF16; the optimizer state holds an f32 master copy
plus Adam moments, all ZeRO-1-shardable (see repro.sharding.rules). The
update runs on the master weights and re-casts to BF16 params.

With a :class:`~repro.optim.moments.MomentPolicy` the Adam moments are
stored as packed MoR payloads (:class:`~repro.optim.moments.PackedMoment`
leaves): decoded to f32 at the top of the update, re-encoded through the
real per-block selection machinery at the bottom -- see
repro.optim.moments for the bytes-per-param budget and docs/training.md
for the layout. ``OptState.ef`` carries the gradient-compression
error-feedback residual when the train step runs an ``*_ef`` mode
(repro.optim.compress); it defaults to None and is absent from the
pytree then.

No optax in this environment -- this is a standalone implementation with
global-norm clipping and a cosine LR schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mor import EVENT_MOMENT_M, EVENT_MOMENT_V
from repro.optim.moments import (
    MomentPolicy,
    PackedMoment,
    decode_any,
    maybe_encode_moment,
    mean_logical_bpe,
    moment_stats_rows,
)

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "adamw_update",
           "cosine_lr", "global_norm"]


def _is_pm(x) -> bool:
    return isinstance(x, PackedMoment)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    final_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    master: Any  # f32 master weights (pytree like params)
    m: Any  # f32 moments, or PackedMoment leaves under a MomentPolicy
    v: Any
    step: jnp.ndarray  # () int32
    # Gradient-compression error-feedback residual (f32, params-shaped)
    # for the '*_ef' compress modes; None (an empty subtree) otherwise.
    ef: Any = None


def init_opt_state(
    params,
    moments: Optional[MomentPolicy] = None,
    ef: bool = False,
) -> OptState:
    """Fresh optimizer state. ``moments`` packs the Adam moment leaves
    (repro.optim.moments); ``ef=True`` allocates the error-feedback
    residual tree the '*_ef' gradient-compression modes thread through
    steps."""
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)

    def moment(p, kind):
        return maybe_encode_moment(zeros(p), moments, kind)

    return OptState(
        master=jax.tree.map(f32, params),
        m=jax.tree.map(lambda p: moment(p, EVENT_MOMENT_M), params),
        v=jax.tree.map(lambda p: moment(p, EVENT_MOMENT_V), params),
        step=jnp.zeros((), jnp.int32),
        ef=jax.tree.map(zeros, params) if ef else None,
    )


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.final_lr + 0.5 * (cfg.peak_lr - cfg.final_lr) * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    cfg: AdamWConfig,
    grads,
    opt_state: OptState,
    *,
    decay_mask=None,
    moments: Optional[MomentPolicy] = None,
    guard: Optional["GuardPolicy"] = None,
) -> Tuple[Any, OptState, dict]:
    """Returns (new bf16 params, new opt state, metrics).

    With ``moments``, PackedMoment leaves in ``opt_state.m``/``.v`` are
    decoded to f32 for the update and the new moments are re-encoded
    through the same policy (the dense/packed split per leaf is static,
    so the state pytree structure is step-invariant). Metrics then also
    carry the optimizer-event stats rows (``moment_stats_m/v``, used by
    train_step's summarizer) and the parameter-weighted logical
    bytes/param of each packed moment tree (``moment_bpe_m/v``).
    ``opt_state.ef`` rides through untouched -- the gradient
    compression that owns it runs *before* this update.

    With a ``guard`` (:class:`repro.robust.GuardPolicy`) whose
    ``skip_nonfinite_updates`` is set, a nonfinite global grad norm --
    any NaN/Inf gradient element makes the already-computed ``gnorm``
    nonfinite, so detection is free -- drops the whole update: master
    weights, both Adam moments (packed payload lanes bit-exact, since
    ``select`` picks values and the poisoned branch never propagates)
    and the step counter all keep their previous values. Metrics then
    carry ``guard_skip`` (1.0 on a dropped step) for train_step's EF
    preservation and the chaos suite's counters."""
    step = opt_state.step + 1
    lr = cosine_lr(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(
        lambda g: g.astype(jnp.float32) * scale, grads
    )

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    m_dec = jax.tree.map(decode_any, opt_state.m, is_leaf=_is_pm)
    v_dec = jax.tree.map(decode_any, opt_state.v, is_leaf=_is_pm)
    new_m = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g, m_dec, grads
    )
    new_v = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * g * g, v_dec, grads
    )

    if decay_mask is None:
        decay_mask = jax.tree.map(
            lambda p: 1.0 if p.ndim >= 2 else 0.0, opt_state.master
        )

    def upd(master, m, v, wd):
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * wd * master
        return master - lr * delta

    new_master = jax.tree.map(
        upd, opt_state.master, new_m, new_v, decay_mask
    )
    new_params = jax.tree.map(
        lambda p: p.astype(jnp.bfloat16), new_master
    )
    metrics = {"lr": lr, "grad_norm": gnorm}
    if moments is not None and moments.enabled:
        new_m = jax.tree.map(
            lambda x: maybe_encode_moment(x, moments, EVENT_MOMENT_M),
            new_m,
        )
        new_v = jax.tree.map(
            lambda x: maybe_encode_moment(x, moments, EVENT_MOMENT_V),
            new_v,
        )
        for name, tree in (("m", new_m), ("v", new_v)):
            rows = moment_stats_rows(tree)
            if rows is not None:
                metrics[f"moment_stats_{name}"] = rows
            metrics[f"moment_bpe_{name}"] = mean_logical_bpe(tree)
    if guard is not None and guard.skip_nonfinite_updates:
        from repro.robust.guard import tree_select

        ok = jnp.isfinite(gnorm)
        new_master = tree_select(ok, new_master, opt_state.master)
        new_m = tree_select(ok, new_m, opt_state.m)
        new_v = tree_select(ok, new_v, opt_state.v)
        step = jnp.where(ok, step, opt_state.step)
        # Params re-derive from the *selected* master so a skipped step
        # republishes the exact previous weights.
        new_params = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16), new_master
        )
        metrics["guard_skip"] = 1.0 - ok.astype(jnp.float32)
    new_state = OptState(
        new_master, new_m, new_v, step, opt_state.ef
    )
    return new_params, new_state, metrics
