"""Gradient compression for cross-pod reduction (beyond-paper).

Two pieces:

1. :func:`compress_decompress_grads` -- MoR/GAM-style FP8 round-trip on
   gradient leaves, optionally with a persistent error-feedback residual
   (the EF trick keeps the *accumulated* quantization error bounded, so
   SGD/Adam trajectories track the uncompressed run). This is what a
   compressed hierarchical all-reduce delivers numerically; in the jit
   train step it models the cross-pod stage operating on FP8 payloads.

2. :func:`make_pod_compressed_psum` -- the explicit collective for
   shard_map-based trainers: within-pod reduction stays BF16 (GSPMD),
   the cross-pod stage all-gathers real float8_e4m3fn payloads + per-leaf
   scales (half the DCN/ICI bytes of a bf16 all-reduce) and sums locally
   in f32. Used by the multi-pod perf experiments.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import E4M3

__all__ = [
    "compress_decompress_grads", "ef_init", "make_pod_compressed_psum",
]


def _q_roundtrip(g: jnp.ndarray) -> jnp.ndarray:
    """Per-tensor GAM-scaled E4M3 round-trip in the gradient dtype."""
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.where(amax > 0, E4M3.amax / amax, 1.0)
    q = jnp.clip(gf * scale, -E4M3.amax, E4M3.amax).astype(
        jnp.float8_e4m3fn
    )
    return (q.astype(jnp.float32) / scale).astype(g.dtype)


def ef_init(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_decompress_grads(
    grads, mode: str = "fp8", ef_state: Optional[Any] = None
) -> Tuple[Any, Optional[Any]] | Any:
    """mode='fp8': plain round-trip. mode='fp8_ef': adds the residual from
    the previous step before quantizing and returns the new residual."""
    if mode == "fp8":
        return jax.tree.map(_q_roundtrip, grads)
    if mode == "fp8_ef":
        assert ef_state is not None

        def one(g, e):
            corrected = g.astype(jnp.float32) + e
            q = _q_roundtrip(corrected)
            return q.astype(g.dtype), corrected - q.astype(jnp.float32)

        pairs = jax.tree.map(one, grads, ef_state)
        new_g = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_e = jax.tree.map(lambda p: p[1], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_g, new_e
    raise ValueError(mode)


def make_pod_compressed_psum(axis_name: str = "pod"):
    """Explicit FP8-compressed cross-pod sum for shard_map trainers.

    g -> all_gather(fp8(g)) over ``axis_name`` -> dequant-sum in f32.
    Halves the bytes crossing the pod boundary vs a bf16 all-reduce
    (visible as f8 all-gather ops in the lowered HLO).
    """

    def psum_fp8(g: jnp.ndarray) -> jnp.ndarray:
        gf = g.astype(jnp.float32)
        amax = jnp.max(jnp.abs(gf))
        scale = jnp.where(amax > 0, E4M3.amax / amax, 1.0)
        q = jnp.clip(gf * scale, -E4M3.amax, E4M3.amax).astype(
            jnp.float8_e4m3fn
        )
        qs = jax.lax.all_gather(q, axis_name)  # (n_pods, ...) fp8 payload
        ss = jax.lax.all_gather(scale, axis_name)  # (n_pods,) f32
        deq = qs.astype(jnp.float32) / ss.reshape(
            (-1,) + (1,) * (qs.ndim - 1)
        )
        return jnp.sum(deq, axis=0).astype(g.dtype)

    return psum_fp8
