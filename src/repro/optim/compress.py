"""Training-state compression through the real MoR selection machinery.

Three pieces:

1. :func:`compress_decompress_grads` / :func:`compress_grads` -- the
   gradient round-trip the jit train step applies before the optimizer.
   Legacy modes ('fp8', 'fp8_ef') keep the PR-2 per-tensor GAM-scaled
   E4M3 round-trip; the 'mor' / 'mor_ef' modes route every gradient
   leaf through :func:`repro.core.mor.mor_quantize` -- per-block
   selection between the recipe's representations (sub2/sub3/sub4),
   exactly the decision path the forward/backward GEMM operands use.
   The ``_ef`` variants keep a persistent error-feedback residual per
   leaf (Mellempudi et al.): the residual is added to the raw gradient
   *before* selection, so the per-block decisions see the corrected
   values, and the new residual is ``corrected - quantized`` -- the
   accumulated quantization error stays bounded by one quantization
   step of the chosen block format instead of drifting across steps
   (tests/test_compress_props.py pins that bound).

2. :func:`make_pod_compressed_psum` -- the explicit cross-pod collective
   for shard_map trainers. With a :class:`~repro.core.policy.MoRPolicy`
   it ships *real* MoR payloads across the pod axis: each pod packs its
   local partial gradient with :func:`quantize_for_gemm` (uint8 fp8
   payload + packed NVFP4 nibbles + micro scales + per-block tags + GAM
   scales), all-gathers the six lanes, decodes every pod's pack and
   sums in f32. Within-pod sharding axes go in ``inner_axes``: the pack
   then uses the PR-3 allreduced group amax, so the payload bytes, tags
   and scales each shard ships are bit-identical to a single-device
   pack of the whole pod gradient (tests/test_compress_psum.py).
   Without a policy the legacy flat per-tensor E4M3 path is kept.

3. :func:`ef_init` -- zero residual state, shaped like the grads.

Bytes on the wire / in HBM per element: a fully-fp8 selection ships
1 B/elt payload (+8 B per 128x128 block of tag+scale), fully-NVFP4
0.5625 B/elt -- vs 2 B/elt for a bf16 all-reduce and 1 B/elt for flat
E4M3 with *one* scale per tensor. The witness test in
tests/test_compress_psum.py shows where the per-block machinery pays:
one outlier block no longer destroys the scale of every other block.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.collectives import all_gather_over
from repro.core.formats import E4M3
from repro.core.mor import (
    EVENT_GRAD,
    STAT_EVENT_KIND,
    mor_quantize,
    quantize_for_gemm,
)
from repro.core.policy import MoRPolicy
from repro.kernels.ref import MixedOperand

__all__ = [
    "GRAD_COMPRESS_MODES",
    "DEFAULT_GRAD_POLICY",
    "compress_decompress_grads",
    "compress_grads",
    "ef_init",
    "leaf2d",
    "make_pod_compressed_psum",
]

GRAD_COMPRESS_MODES = ("fp8", "fp8_ef", "mor", "mor_ef")

# Per-block three-way selection is the default gradient recipe: E5M2's
# wider exponent range matters most for gradients (the paper's Eq. 4
# dynamic-range gate exists for exactly this tensor class).
DEFAULT_GRAD_POLICY = MoRPolicy(recipe="sub3")


def leaf2d(x: jnp.ndarray) -> jnp.ndarray:
    """The 2-D quantization view of one pytree leaf: trailing axis kept
    (it is the contraction axis of the GEMM that produced the grad),
    leading axes flattened; vectors become one row, scalars (1, 1)."""
    if x.ndim == 0:
        return x.reshape(1, 1)
    if x.ndim == 1:
        return x.reshape(1, -1)
    return x.reshape(-1, x.shape[-1])


def _q_roundtrip(g: jnp.ndarray) -> jnp.ndarray:
    """Per-tensor GAM-scaled E4M3 round-trip in the gradient dtype
    (legacy 'fp8' mode -- one scale per tensor, no selection)."""
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.where(amax > 0, E4M3.amax / amax, 1.0)
    q = jnp.clip(gf * scale, -E4M3.amax, E4M3.amax).astype(
        jnp.float8_e4m3fn
    )
    return (q.astype(jnp.float32) / scale).astype(g.dtype)


def _mor_roundtrip(
    g: jnp.ndarray, policy: MoRPolicy
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fake-quantize one gradient leaf through the shared MoR decision
    path. Returns (round-tripped leaf in g's dtype, stats row stamped
    EVENT_GRAD)."""
    gf = g.astype(jnp.float32)
    y2d, stats = mor_quantize(leaf2d(gf), policy)
    return (
        y2d.reshape(g.shape).astype(g.dtype),
        stats.at[STAT_EVENT_KIND].set(EVENT_GRAD),
    )


def ef_init(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_grads(
    grads,
    mode: str = "mor",
    ef_state: Optional[Any] = None,
    policy: Optional[MoRPolicy] = None,
) -> Tuple[Any, Optional[Any], Optional[Any]]:
    """Gradient compression round-trip with per-event stats.

    Returns ``(new_grads, new_ef_state, stats)``:

    * ``new_grads`` -- grads after the round-trip, original dtypes.
    * ``new_ef_state`` -- the updated residual tree for ``*_ef`` modes;
      for the plain modes, ``ef_state`` passed through unchanged.
    * ``stats`` -- for 'mor'/'mor_ef', a tree like ``grads`` whose
      leaves are STATS_WIDTH rows with ``event_kind = EVENT_GRAD``;
      ``None`` for the legacy per-tensor modes (they bypass the stats
      machinery by construction).

    'mor' / 'mor_ef' quantize each leaf's 2-D view (:func:`leaf2d`)
    under ``policy`` (default :data:`DEFAULT_GRAD_POLICY`); the EF
    variant adds the persistent residual *before* selection so the
    per-block decisions price the corrected values.
    """
    if mode not in GRAD_COMPRESS_MODES:
        raise ValueError(
            f"mode {mode!r} not in {GRAD_COMPRESS_MODES}"
        )
    pol = policy if policy is not None else DEFAULT_GRAD_POLICY

    if mode == "fp8":
        return jax.tree.map(_q_roundtrip, grads), ef_state, None

    if mode == "mor":
        pairs = jax.tree.map(lambda g: _mor_roundtrip(g, pol), grads)
        is_pair = lambda x: isinstance(x, tuple)
        new_g = jax.tree.map(lambda p: p[0], pairs, is_leaf=is_pair)
        stats = jax.tree.map(lambda p: p[1], pairs, is_leaf=is_pair)
        return new_g, ef_state, stats

    # Error-feedback variants.
    if ef_state is None:
        raise ValueError(f"mode {mode!r} needs ef_state (see ef_init)")

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        if mode == "fp8_ef":
            q = _q_roundtrip(corrected)
            stats = None
        else:  # mor_ef
            q, stats = _mor_roundtrip(corrected, pol)
        return q.astype(g.dtype), corrected - q.astype(jnp.float32), stats

    triples = jax.tree.map(one, grads, ef_state)
    is_triple = lambda x: isinstance(x, tuple)
    new_g = jax.tree.map(lambda t: t[0], triples, is_leaf=is_triple)
    new_e = jax.tree.map(lambda t: t[1], triples, is_leaf=is_triple)
    if mode == "fp8_ef":
        return new_g, new_e, None
    stats = jax.tree.map(lambda t: t[2], triples, is_leaf=is_triple)
    return new_g, new_e, stats


def compress_decompress_grads(
    grads,
    mode: str = "fp8",
    ef_state: Optional[Any] = None,
    policy: Optional[MoRPolicy] = None,
) -> Tuple[Any, Optional[Any]]:
    """Signature-stable wrapper: **always** returns ``(grads,
    ef_state)`` for every mode (the pre-PR-8 version returned a bare
    tree for mode='fp8' and a tuple for 'fp8_ef', and the train step
    mis-assigned the tuple; tests/test_train_compress.py pins this).
    Non-EF modes return ``ef_state`` unchanged (``None`` if not given).
    """
    new_g, new_e, _ = compress_grads(grads, mode, ef_state, policy)
    return new_g, new_e


def _gather_decode_sum(
    mo: MixedOperand, axis_name: Optional[str], out_dtype
) -> jnp.ndarray:
    """all-gather the six payload lanes of ``mo`` over the pod axis,
    decode each pod's pack and sum in f32. The per-pod loop is a
    static Python loop (the gathered leading dim is the static axis
    size); decode is the shared XLA reference, so the summed value is
    exactly sum(dequant(pack(g_pod)))."""
    lanes = (
        mo.payload_q, mo.payload_bf16, mo.payload_nib,
        mo.micro_scales, mo.tags, mo.scales,
    )
    g = [all_gather_over(l, axis_name) for l in lanes]
    n_pods = g[0].shape[0]
    total = None
    for i in range(n_pods):
        moi = MixedOperand(
            payload_q=g[0][i], payload_bf16=g[1][i], tags=g[4][i],
            scales=g[5][i], block=mo.block, shape=mo.shape,
            payload_nib=g[2][i], micro_scales=g[3][i],
            has_nvfp4=mo.has_nvfp4,
        )
        d = moi.dequant().astype(jnp.float32)
        total = d if total is None else total + d
    return total.astype(out_dtype)


def make_pod_compressed_psum(
    axis_name: str = "pod",
    policy: Optional[MoRPolicy] = None,
    inner_axes: Tuple[str, ...] = (),
):
    """Compressed cross-pod sum for shard_map trainers.

    Without ``policy``: the legacy flat path -- one per-tensor E4M3
    payload + one f32 scale per pod, all-gathered and dequant-summed.

    With ``policy``: each pod packs its local partial gradient through
    the real selection machinery (:func:`quantize_for_gemm` on the
    :func:`leaf2d` view, in bf16 -- the within-pod reduction dtype) and
    the collective ships the six mixed-layout lanes instead. When the
    pod's gradient is itself sharded within the pod, name those mesh
    axes in ``inner_axes``: every pack statistic (group amax, Eq. 3/4
    gates) is then allreduced within the pod, so the shards of one pod
    emit bit-identical tags/scales and exactly the payload bytes a
    single-device pack of the full pod gradient would
    (tests/test_compress_psum.py). ``axis_name`` must *not* be in
    ``inner_axes`` -- pods hold different partial sums, not shards of
    one tensor.

    ``axis_name=None`` degenerates to a local pack/decode round-trip
    (single-pod mesh, or unit-testing the numerics outside shard_map).
    """
    if policy is not None and axis_name in policy.mesh_axes:
        raise ValueError(
            f"policy.mesh_axes {policy.mesh_axes} must not include the "
            f"pod axis {axis_name!r}"
        )
    if policy is not None and axis_name in inner_axes:
        raise ValueError(
            f"inner_axes {inner_axes} must not include the pod axis "
            f"{axis_name!r}: pods hold independent partial sums"
        )

    def psum_fp8(g: jnp.ndarray) -> jnp.ndarray:
        gf = g.astype(jnp.float32)
        amax = jnp.max(jnp.abs(gf))
        scale = jnp.where(amax > 0, E4M3.amax / amax, 1.0)
        q = jnp.clip(gf * scale, -E4M3.amax, E4M3.amax).astype(
            jnp.float8_e4m3fn
        )
        qs = all_gather_over(q, axis_name)  # (n_pods, ...) fp8 payload
        ss = all_gather_over(scale, axis_name)  # (n_pods,) f32
        deq = qs.astype(jnp.float32) / ss.reshape(
            (-1,) + (1,) * (qs.ndim - 1)
        )
        return jnp.sum(deq, axis=0).astype(g.dtype)

    if policy is None:
        return psum_fp8

    pol = policy.replace(mesh_axes=tuple(inner_axes))

    def psum_mor(g: jnp.ndarray) -> jnp.ndarray:
        # bf16 is the stored dtype of the pack's original-precision
        # lane -- the same dtype a within-pod GSPMD reduction delivers.
        x2d = leaf2d(g).astype(jnp.bfloat16)
        mo, _ = quantize_for_gemm(x2d, pol)
        out2d = _gather_decode_sum(mo, axis_name, jnp.float32)
        return out2d.reshape(g.shape).astype(g.dtype)

    return psum_mor
