"""Adam moments as packed MoR payloads (compressed optimizer state).

A dense-f32 Adam state costs 8 bytes/param (two f32 moments) on top of
the 4-byte master copy. This module stores each moment leaf as a
:class:`~repro.kernels.ref.MixedOperand` instead -- the same per-block
tag-selected layout the mixed GEMM consumes -- encoded through
:func:`repro.core.mor.quantize_for_gemm`, i.e. the *real* selection
machinery: per-block Eq. 3 error comparison and Eq. 4 dynamic-range
gates decide which representation each 128x128 block of the moment
gets. A fully-fp8 selection stores ~1 B/param per moment (+8 bytes per
block of tag+scale, ~0.0005 B/param); a fully-NVFP4 second moment
0.5625 B/param. :func:`logical_bytes_per_param` (stats-derived, inside
jit) and :func:`physical_bytes_per_param` (host-side, after
``compact()``) assert the budget -- tests/test_train_compress.py pins
<= 1.05 B/param for fully-fp8 and <= 0.65 for fully-NVFP4 sub4 second
moments, and ``bench_kernels`` gates ``moment_bytes_per_param_milli``.

The second moment is non-negative with a huge dynamic range (squared
gradients), which is exactly the tensor class the paper's Eq. 4 gate
promotes to wider-exponent arms -- :data:`WIDE_RANGE_V` pins more
blocks to the E5M2/BF16 arms by tightening the acceptance threshold,
and a ``recipe='sub4'`` v-policy adds the NVFP4 arm for the
narrow-range majority. Moments are decoded to f32 inside the optimizer
update and re-encoded after (optim.adamw); the EMA structure tolerates
the per-step quantization error without error feedback because each
step re-quantizes the *accumulated* state, not a residual stream.

Leaves smaller than ``MomentPolicy.min_leaf`` elements stay dense f32:
norm scales and biases are a rounding error of the byte budget, and the
per-block metadata would cost more than it saves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mor import (
    EVENT_MOMENT_M,
    EVENT_MOMENT_V,
    STAT_EVENT_KIND,
    STAT_PAYLOAD_BPE,
    STATS_WIDTH,
    quantize_for_gemm,
)
from repro.core.policy import MoRPolicy
from repro.kernels.ref import MixedOperand

__all__ = [
    "MomentPolicy",
    "PackedMoment",
    "FP8_MOMENTS",
    "WIDE_RANGE_V",
    "SUB4_V_MOMENTS",
    "encode_moment",
    "decode_moment",
    "maybe_encode_moment",
    "decode_any",
    "moment_stats_rows",
    "mean_logical_bpe",
    "block_overhead_bpe",
    "logical_bytes_per_param",
    "physical_bytes_per_param",
]


@dataclasses.dataclass(frozen=True)
class MomentPolicy:
    """Which MoR recipe each Adam moment is stored under.

    ``m`` / ``v`` are per-moment :class:`MoRPolicy` values ('off' =
    dense f32, the pre-PR-8 layout). ``min_leaf`` is the element-count
    floor below which a leaf stays dense regardless."""

    m: MoRPolicy = MoRPolicy(recipe="off")
    v: MoRPolicy = MoRPolicy(recipe="off")
    min_leaf: int = 1024

    @property
    def enabled(self) -> bool:
        return self.m.enabled or self.v.enabled

    def replace(self, **kw) -> "MomentPolicy":
        return dataclasses.replace(self, **kw)


# Both moments per-block three-way selected (the training default).
FP8_MOMENTS = MomentPolicy(
    m=MoRPolicy(recipe="sub3"), v=MoRPolicy(recipe="sub3")
)
# Second-moment policy biased toward the wide-exponent arms: squared
# gradients span a huge dynamic range, so the Eq. 3 acceptance gate is
# tightened -- blocks that would scrape through E4M3 at 4.5% pin to
# E5M2/BF16 instead.
WIDE_RANGE_V = MoRPolicy(recipe="sub3", threshold=0.02)
# NVFP4 arm on the second moment (sub4 cascade; 0.5625 B/param when
# fully selected).
SUB4_V_MOMENTS = MomentPolicy(
    m=MoRPolicy(recipe="sub3"), v=MoRPolicy(recipe="sub4")
)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class PackedMoment:
    """One moment leaf in the mixed block layout.

    ``mo`` holds the payload lanes in the leaf's 2-D quantization view
    (:func:`repro.optim.compress.leaf2d`); ``stats`` is the encode
    event's STATS_WIDTH row (event_kind stamped EVENT_MOMENT_M/V);
    ``shape`` is the original leaf shape, static."""

    mo: MixedOperand
    stats: jnp.ndarray
    shape: Tuple[int, ...]

    def tree_flatten(self):
        return (self.mo, self.stats), (tuple(self.shape),)

    def tree_flatten_with_keys(self):
        # Named key paths so the payload-lane taint checker
        # (repro.analysis.jaxpr_lint) sees .mo.payload_q etc. when an
        # opt state rides in a traced argument tree.
        return (
            (
                (jax.tree_util.GetAttrKey("mo"), self.mo),
                (jax.tree_util.GetAttrKey("stats"), self.stats),
            ),
            (tuple(self.shape),),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        mo, stats = children
        return cls(mo=mo, stats=stats, shape=aux[0])


def _is_pm(x) -> bool:
    return isinstance(x, PackedMoment)


def encode_moment(
    x: jnp.ndarray, policy: MoRPolicy, kind: float
) -> PackedMoment:
    """Pack one f32 moment leaf. The 2-D view is cast to bf16 first:
    BF16 *is* the top-precision arm of every recipe -- the stored
    representation is per-block {fp8/nvfp4 payload | bf16}, never f32.
    """
    from repro.optim.compress import leaf2d  # sibling; late import

    x2d = leaf2d(x).astype(jnp.bfloat16)
    mo, stats = quantize_for_gemm(x2d, policy)
    return PackedMoment(
        mo=mo, stats=stats.at[STAT_EVENT_KIND].set(kind),
        shape=tuple(x.shape)
    )


def decode_moment(pm: PackedMoment) -> jnp.ndarray:
    """The stored f32 values of a packed moment leaf."""
    return pm.mo.dequant().astype(jnp.float32).reshape(pm.shape)


def maybe_encode_moment(
    x: jnp.ndarray,
    moments: Optional[MomentPolicy],
    kind: float,
) -> Any:
    """Pack ``x`` under the policy for ``kind``, or return it dense.

    The dense/packed split is a *static* property of (leaf size,
    policy) so init and every update step agree on the pytree
    structure."""
    if moments is None:
        return x
    pol = moments.m if kind == EVENT_MOMENT_M else moments.v
    if not pol.enabled or x.size < moments.min_leaf:
        return x
    return encode_moment(x, pol, kind)


def decode_any(x: Any) -> jnp.ndarray:
    """decode_moment for packed leaves, identity for dense ones."""
    return decode_moment(x) if _is_pm(x) else x


def block_overhead_bpe(mo: MixedOperand) -> float:
    """Static per-element byte cost of the tag/scale grids (int32 tag +
    f32 scale = 8 bytes per block), over the *logical* element count."""
    nblocks = int(np.prod(mo.tags.shape))
    nelem = int(np.prod(mo.shape))
    return 8.0 * nblocks / max(nelem, 1)


def logical_bytes_per_param(pm: PackedMoment) -> jnp.ndarray:
    """Payload bytes/param implied by the encode event's tag mixture
    (the payload_bpe stats lane) plus the static block metadata
    overhead. Traceable -- this is the in-jit budget the train step
    reports."""
    return pm.stats[STAT_PAYLOAD_BPE] + jnp.float32(
        block_overhead_bpe(pm.mo)
    )


def physical_bytes_per_param(pm: PackedMoment) -> float:
    """Host-side physical HBM bytes/param of the pack after
    ``compact()`` -- unused payload lanes really dropped. This is the
    number the acceptance budget is asserted against in tests."""
    mo = pm.mo.compact()
    nbytes = sum(
        l.size * l.dtype.itemsize
        for l in (mo.payload_q, mo.payload_bf16, mo.payload_nib,
                  mo.micro_scales, mo.tags, mo.scales)
    )
    return nbytes / max(int(np.prod(pm.shape)), 1)


def moment_stats_rows(tree) -> Optional[jnp.ndarray]:
    """Stack the STATS_WIDTH rows of every packed leaf in a moment
    tree -- the optimizer-event rows the train step folds into its
    metrics. None when the tree holds no packed leaves."""
    rows = [
        l.stats for l in jax.tree.leaves(tree, is_leaf=_is_pm)
        if _is_pm(l)
    ]
    if not rows:
        return None
    return jnp.stack(rows).reshape(-1, STATS_WIDTH)


def mean_logical_bpe(tree) -> jnp.ndarray:
    """Parameter-weighted mean logical bytes/param over the packed
    leaves of a moment tree (0.0 when none are packed)."""
    leaves = [
        l for l in jax.tree.leaves(tree, is_leaf=_is_pm) if _is_pm(l)
    ]
    if not leaves:
        return jnp.float32(0.0)
    sizes = jnp.asarray(
        [float(np.prod(l.shape)) for l in leaves], jnp.float32
    )
    bpes = jnp.stack([logical_bytes_per_param(l) for l in leaves])
    return jnp.sum(bpes * sizes) / jnp.sum(sizes)
