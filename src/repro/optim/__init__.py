from .adamw import (
    AdamWConfig,
    OptState,
    adamw_update,
    cosine_lr,
    global_norm,
    init_opt_state,
)

__all__ = [
    "AdamWConfig", "OptState", "adamw_update", "cosine_lr", "global_norm",
    "init_opt_state",
]
