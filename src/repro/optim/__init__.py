from .adamw import (
    AdamWConfig,
    OptState,
    adamw_update,
    cosine_lr,
    global_norm,
    init_opt_state,
)
from .compress import (
    DEFAULT_GRAD_POLICY,
    GRAD_COMPRESS_MODES,
    compress_decompress_grads,
    compress_grads,
    ef_init,
    make_pod_compressed_psum,
)
from .moments import (
    FP8_MOMENTS,
    SUB4_V_MOMENTS,
    WIDE_RANGE_V,
    MomentPolicy,
    PackedMoment,
    decode_moment,
    encode_moment,
    logical_bytes_per_param,
    physical_bytes_per_param,
)

__all__ = [
    "AdamWConfig", "OptState", "adamw_update", "cosine_lr", "global_norm",
    "init_opt_state",
    "DEFAULT_GRAD_POLICY", "GRAD_COMPRESS_MODES",
    "compress_decompress_grads", "compress_grads", "ef_init",
    "make_pod_compressed_psum",
    "MomentPolicy", "PackedMoment", "FP8_MOMENTS", "SUB4_V_MOMENTS",
    "WIDE_RANGE_V", "encode_moment", "decode_moment",
    "logical_bytes_per_param", "physical_bytes_per_param",
]
