"""The jitted training step: loss -> grads -> (optional microbatching,
gradient compression) -> AdamW update, with MoR stats as outputs.

This is the function the multi-pod dry-run lowers and the trainer runs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import STATS_WIDTH, MoRDotPolicy, MoRPolicy, with_mesh_axes
from repro.core.mor import STAT_FALLBACK_COUNT, STAT_GUARD_FLAGS
from repro.models import make_loss_fn, make_tokens
from repro.models.common import constrain
from repro.optim.adamw import (
    AdamWConfig,
    OptState,
    adamw_update,
    global_norm,
)
from repro.optim.compress import DEFAULT_GRAD_POLICY
from repro.optim.moments import MomentPolicy
from repro.robust.guard import GuardPolicy, tree_select
from repro.sharding import rules as _rules

__all__ = ["TrainConfig", "make_train_step", "summarize_mor_stats"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    # Microbatching: split the global batch into n accumulation steps.
    grad_accum: int = 1
    remat: bool = True
    # Gradient compression (repro.optim.compress): legacy per-tensor
    # E4M3 ('fp8'/'fp8_ef') or per-block MoR selection ('mor'/'mor_ef')
    # under ``grad_policy``. The '*_ef' modes keep an error-feedback
    # residual in OptState.ef -- create the state with
    # ``init_opt_state(params, ef=True)``.
    compress_grads: str = "none"  # 'none'|'fp8'|'fp8_ef'|'mor'|'mor_ef'
    grad_policy: MoRPolicy = DEFAULT_GRAD_POLICY
    # Adam moments stored as packed MoR payloads (repro.optim.moments);
    # None keeps the dense-f32 layout. Must match the MomentPolicy the
    # opt state was initialized with.
    moments: MomentPolicy | None = None
    aux_coef: float = 0.01
    # ZeRO-2: constrain gradients to the data-sharded optimizer layout so
    # GSPMD reduce-scatters them instead of all-reducing (halves DP
    # gradient traffic; optimizer math runs on the scattered shards).
    zero2_grads: bool = True
    # shard_map embedding: when the returned step runs *inside* a
    # shard_map body (manual SPMD, e.g. the cross-pod compressed-psum
    # trainer), name the batch-sharded mesh axes here so every MoR
    # quantization event allreduces its global statistics and the
    # precision decisions match the single-device run bit-for-bit
    # (docs/sharding.md). Leave () for the jit/GSPMD trainer: there the
    # compiler already makes jnp reductions over sharded operands
    # global, so no explicit collectives are needed.
    mor_mesh_axes: Tuple[str, ...] = ()
    # Numerics guard rails (docs/robustness.md): with a GuardPolicy,
    # adamw_update drops updates whose global grad norm is nonfinite
    # (master/moments/step preserved bit-exactly) and this step keeps
    # the EF residuals of the skipped update -- a dropped step must not
    # absorb its own quantization error into EF (no double count).
    # None keeps the unguarded behavior.
    guard: GuardPolicy | None = None


def summarize_mor_stats(
    fwd_stats, bwd_stats, opt_stats=None
) -> Dict[str, jnp.ndarray]:
    """Reduce the per-layer/per-event stats pytrees to scalar metrics.

    Disabled-policy events (recipe 'off', decision column == -1) are
    excluded: a passthrough event reports ``frac_bf16 = 1.0`` by
    construction, and averaging those rows in dragged ``fwd_frac_bf16``
    toward 1 even when every *enabled* event quantized. With no enabled
    events at all, every metric is 0.

    ``opt_stats`` carries the optimizer-event rows (stats layout v4,
    event_kind > 0): gradient-compression and packed-moment encode
    events, summarized into the ``opt_*`` family the same way --
    ``opt_frac_bf16``/``opt_rel_err`` plus ``opt_payload_bpe`` (mean
    stats lane [11], the logical bytes/param of the compressed state).

    Guard counters (docs/robustness.md) aggregate over *every* row,
    disabled events included (a passthrough event can still carry a
    poisoned operand worth reporting): ``guard_flag_events`` counts
    rows with any guard flag set, ``guard_fallback_blocks`` sums the
    nonfinite-block fallback counts.
    """

    def rows(tree):
        leaves = [
            l.reshape(-1, l.shape[-1])
            for l in jax.tree.leaves(tree)
            if hasattr(l, "ndim") and l.ndim >= 1
            and l.shape[-1] == STATS_WIDTH
        ]
        if not leaves:
            return None
        return jnp.concatenate(leaves)

    def frac(cat, idx):
        if cat is None:
            return jnp.float32(0.0)
        enabled = cat[:, 0] >= 0.0  # decision == -1: disabled sentinel
        n = jnp.maximum(jnp.sum(enabled.astype(jnp.float32)), 1.0)
        return jnp.sum(jnp.where(enabled, cat[:, idx], 0.0)) / n

    out = {}
    guard_events = jnp.float32(0.0)
    fallback_blocks = jnp.float32(0.0)

    def guard_tally(cat):
        nonlocal guard_events, fallback_blocks
        if cat is None:
            return
        guard_events += jnp.sum(
            (cat[:, STAT_GUARD_FLAGS] > 0.0).astype(jnp.float32)
        )
        fallback_blocks += jnp.sum(cat[:, STAT_FALLBACK_COUNT])

    if fwd_stats is not None:
        cat = rows(fwd_stats)
        out["fwd_frac_bf16"] = frac(cat, 5)
        out["fwd_rel_err"] = frac(cat, 1)
        guard_tally(cat)
    if bwd_stats is not None:
        cat = rows(bwd_stats)
        out["bwd_frac_bf16"] = frac(cat, 5)
        out["bwd_rel_err"] = frac(cat, 1)
        guard_tally(cat)
    if opt_stats is not None:
        cat = rows(opt_stats)
        out["opt_frac_bf16"] = frac(cat, 5)
        out["opt_rel_err"] = frac(cat, 1)
        out["opt_payload_bpe"] = frac(cat, 11)
        guard_tally(cat)
    out["guard_flag_events"] = guard_events
    out["guard_fallback_blocks"] = fallback_blocks
    return out


def make_train_step(
    cfg: ArchConfig,
    policy: MoRDotPolicy,
    tcfg: TrainConfig,
    grad_fault=None,
):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics).

    ``grad_fault``: optional ``hook(grads, batch) -> grads`` applied to
    the accumulated gradients *before* compression -- the chaos
    harness's injection point (repro.robust.faults.make_grad_fault
    builds hooks gated on a ``batch['inject']`` flag, so one compiled
    step serves clean and injected steps). Production steps leave it
    None; the hook must be the identity for clean batches or the
    differential chaos assertions are meaningless."""
    if tcfg.mor_mesh_axes:
        policy = with_mesh_axes(policy, tcfg.mor_mesh_axes)
    loss_fn = make_loss_fn(
        cfg, policy, remat=tcfg.remat, aux_coef=tcfg.aux_coef
    )
    grad_fn = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)

    if tcfg.compress_grads != "none":
        from repro.optim.compress import compress_grads as _compress

        # Gradient compression quantizes *global* gradients; under a
        # shard_map trainer its statistics must allreduce like every
        # other event's.
        grad_policy = (
            tcfg.grad_policy.replace(mesh_axes=tuple(tcfg.mor_mesh_axes))
            if tcfg.mor_mesh_axes else tcfg.grad_policy
        )

    def single_micro(params, tokens, batch):
        (total, aux), (g_params, g_tokens) = grad_fn(params, tokens, batch)
        return total, aux, g_params, g_tokens

    def train_step(params, opt_state: OptState, batch):
        tokens = make_tokens(cfg)
        zspecs = (
            _rules.opt_state_spec_from_param(cfg, params)
            if tcfg.zero2_grads else None
        )

        def to_zero2(g_tree):
            # ZeRO-2: data-sharded gradient layout -> GSPMD emits
            # reduce-scatter instead of all-reduce (half the DP traffic)
            # and the f32 accumulation buffer is 1/DP the size. Applied
            # *inside* the microbatch loop so accumulation happens on
            # scattered shards (Megatron main-grads style).
            if zspecs is None:
                return g_tree
            return jax.tree.map(
                lambda g, sp: constrain(g, *sp), g_tree, zspecs
            )

        if tcfg.grad_accum > 1:
            n = tcfg.grad_accum

            def micro(carry, mb):
                g_acc, l_acc = carry
                total, aux, g_params, g_tokens = single_micro(
                    params, tokens, mb
                )
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / n,
                    g_acc, to_zero2(g_params),
                )
                return (g_acc, l_acc + total / n), (aux, g_tokens)

            mb_batch = jax.tree.map(
                lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch
            )
            g0 = to_zero2(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ))
            (g_params, total), (auxs, g_tokens) = jax.lax.scan(
                micro, (g0, jnp.float32(0.0)), mb_batch
            )
            # Stats/aux leaves are *per-microbatch means*: average over
            # the scan axis. Summing the bwd token cotangents inflated
            # bwd_frac_bf16 / bwd_rel_err by grad_accum x, and taking
            # aux[-1] silently reported only the last microbatch's fwd
            # stats and loss -- reported metrics must be invariant to
            # the grad_accum split (tests/test_stats_contract.py).
            aux = jax.tree.map(lambda x: jnp.mean(x, 0), auxs)
            g_tokens = jax.tree.map(lambda x: jnp.mean(x, 0), g_tokens)
        else:
            total, aux, g_params, g_tokens = single_micro(
                params, tokens, batch
            )
            g_params = to_zero2(g_params)

        if grad_fault is not None:
            g_params = grad_fault(g_params, batch)

        grad_stats = None
        new_ef = opt_state.ef
        if tcfg.compress_grads != "none":
            g_params, new_ef, grad_stats = _compress(
                g_params, mode=tcfg.compress_grads,
                ef_state=opt_state.ef, policy=grad_policy,
            )

        new_params, new_opt, opt_metrics = adamw_update(
            tcfg.optimizer, g_params, opt_state, moments=tcfg.moments,
            guard=tcfg.guard,
        )
        if "guard_skip" in opt_metrics and new_ef is not None:
            # Skip-step EF preservation: compress_grads already folded
            # this step's residual into `corrected` and re-split it; if
            # the update is dropped, keeping the new residual would
            # make the *next* step absorb this step's quantization
            # error twice. Select the old residuals back (bit-exact).
            ok = opt_metrics["guard_skip"] < 0.5
            new_ef = tree_select(ok, new_ef, opt_state.ef)
        new_opt = new_opt._replace(ef=new_ef)
        # Optimizer-event rows (stats v4): gradient-compression events
        # plus the packed-moment encode events adamw_update reports.
        opt_rows = {
            "grad": grad_stats,
            "m": opt_metrics.pop("moment_stats_m", None),
            "v": opt_metrics.pop("moment_stats_v", None),
        }
        opt_rows = {k: s for k, s in opt_rows.items() if s is not None}
        metrics = {
            "loss": aux["loss"],
            "total_loss": total,
            "aux_loss": aux["aux_loss"],
            **opt_metrics,
            **summarize_mor_stats(
                aux.get("mor_fwd"), g_tokens, opt_rows or None
            ),
        }
        if new_ef is not None:
            metrics["ef_norm"] = global_norm(new_ef)
        return new_params, new_opt, metrics

    return train_step
