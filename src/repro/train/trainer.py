"""Fault-tolerant training loop.

Responsibilities beyond make_train_step:
  * checkpoint/restart: resumes bit-identically (data pipeline is a pure
    function of the step counter; RNG-free steps),
  * preemption handling: SIGTERM -> synchronous final checkpoint,
  * straggler mitigation: per-step deadline watchdog; steps that exceed
    ``straggler_factor`` x the trailing-median step time are logged with
    the host set, and repeated offenders trigger a (pluggable) callback --
    on a real cluster this is where you'd eject/replace the slow host and
    trigger the elastic re-mesh path (repro.checkpoint restores onto the
    surviving mesh),
  * MoR statistics streaming into MoRStatsTracker (Fig. 10/11 machinery).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from collections import deque
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import Checkpointer, latest_step
from repro.configs.base import ArchConfig
from repro.core import (
    STAT_FRAC_BF16,
    STAT_GROUP_MANTISSA,
    STAT_REL_ERR,
    STATS_WIDTH,
    MoRDotPolicy,
    MoRStatsTracker,
)
from repro.data.pipeline import DataConfig, SyntheticLM, prefetch
from repro.models import init_params
from repro.optim.adamw import init_opt_state
from repro.train.train_step import TrainConfig, make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    seed: int = 0


class Trainer:
    def __init__(
        self,
        cfg: ArchConfig,
        policy: MoRDotPolicy,
        tcfg: TrainConfig,
        run_cfg: TrainerConfig,
        data_cfg: Optional[DataConfig] = None,
        straggler_cb: Optional[Callable[[int, float], None]] = None,
    ):
        self.cfg = cfg
        self.policy = policy
        self.run_cfg = run_cfg
        self.data_cfg = data_cfg or DataConfig(
            vocab=cfg.vocab, seq_len=256, global_batch=8,
            seed=run_cfg.seed,
        )
        self.step_fn = jax.jit(make_train_step(cfg, policy, tcfg))
        self.tracker = MoRStatsTracker()
        self.ckpt = (
            Checkpointer(run_cfg.ckpt_dir, keep=run_cfg.keep)
            if run_cfg.ckpt_dir
            else None
        )
        self.straggler_cb = straggler_cb or (lambda step, t: None)
        self._preempted = False
        self.history: list = []

    def _install_sigterm(self):
        def handler(signum, frame):
            self._preempted = True

        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not main thread (tests)

    def run(self) -> Dict[str, Any]:
        self._install_sigterm()
        params = init_params(self.cfg, jax.random.PRNGKey(self.run_cfg.seed))
        opt_state = init_opt_state(params)
        start = 0

        if self.ckpt is not None:
            last = latest_step(self.run_cfg.ckpt_dir)
            if last is not None:
                state = self.ckpt.restore(last, (params, opt_state))
                params, opt_state = state
                start = last
        data = SyntheticLM(
            dataclasses.replace(self.data_cfg, seed=self.run_cfg.seed)
        )

        times: deque = deque(maxlen=32)
        step = start
        for step in range(start, self.run_cfg.total_steps):
            batch = jax.tree.map(
                jax.numpy.asarray, data.batch_at(step)
            )
            t0 = time.time()
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch
            )
            loss = float(metrics["loss"])  # blocks; acts as step barrier
            dt = time.time() - t0
            # Straggler watchdog.
            if len(times) >= 8:
                med = float(np.median(times))
                if dt > self.run_cfg.straggler_factor * med:
                    self.straggler_cb(step, dt / med)
            times.append(dt)

            self.history.append(
                {"step": step, "loss": loss, "dt": dt,
                 "fwd_bf16": float(metrics.get("fwd_frac_bf16", 0.0)),
                 "bwd_bf16": float(metrics.get("bwd_frac_bf16", 0.0))}
            )
            row = np.zeros(STATS_WIDTH, np.float64)
            row[STAT_REL_ERR] = float(metrics.get("fwd_rel_err", 0.0))
            row[STAT_FRAC_BF16] = float(
                metrics.get("fwd_frac_bf16", 0.0)
            )
            row[STAT_GROUP_MANTISSA] = 1.0
            self.tracker.update({"global": row}, step)
            if self.ckpt and (
                (step + 1) % self.run_cfg.ckpt_every == 0 or self._preempted
            ):
                self.ckpt.save(step + 1, (params, opt_state))
                if self._preempted:
                    self.ckpt.wait()
                    break

        if self.ckpt:
            self.ckpt.save(self.run_cfg.total_steps, (params, opt_state))
            self.ckpt.wait()
        return {
            "params": params,
            "opt_state": opt_state,
            "history": self.history,
            "final_step": step + 1,
        }
