from .train_step import TrainConfig, make_train_step, summarize_mor_stats
from .trainer import Trainer, TrainerConfig

__all__ = ["TrainConfig", "make_train_step", "summarize_mor_stats", "Trainer", "TrainerConfig"]
