"""Partitioning strategies over 2-D operand views (paper §3, Fig. 2).

Every MoR quantization event sees its operand as a 2-D matrix
``(M, K)`` where the *last* axis is the GEMM contraction axis (callers
transpose/flatten so this holds). A :class:`Partition` resolves to a
concrete block shape ``(bm, bk)``:

- ``tensor``      -> one block, the whole tensor           (per-tensor scaling)
- ``block``       -> ``block_shape`` tiles, default 128x128 (per-block scaling)
- ``channel``     -> (1, K) rows: one scale per dot-product vector
                     (per-channel scaling; for the second GEMM operand callers
                     pass the transposed view so "channel" is always a row here)
- ``subchannel``  -> (1, sub) chunks of each row (DeepSeek/MX-style 1x128/1x32)

Blocking pads with zeros up to a multiple of the block shape. Zero padding
is invisible to every downstream consumer: amax ignores zeros unless the
whole block is padding (guarded), and the non-zero-element masks used by the
error metrics exclude pads by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

__all__ = [
    "Partition",
    "PER_TENSOR",
    "PER_BLOCK_128",
    "PER_BLOCK_64",
    "PER_CHANNEL",
    "SUB_CHANNEL_128",
    "to_blocks",
    "from_blocks",
    "block_amax",
]


@dataclasses.dataclass(frozen=True)
class Partition:
    kind: str  # 'tensor' | 'block' | 'channel' | 'subchannel'
    block_shape: Tuple[int, int] = (128, 128)
    sub: int = 128
    # Alignment the resolved block dims are rounded *up* to (after the
    # shrink-to-operand min). (1, 1) = legacy behaviour. The sub4
    # recipe uses (2, 16): NVFP4 nibble packing pairs rows and the
    # micro-block scales group 16 contraction elements, so blocks of a
    # small operand must stay 2x16-divisible (zero padding is invisible
    # to every consumer, as with normal block padding).
    align: Tuple[int, int] = (1, 1)

    def resolve(self, shape: Tuple[int, int]) -> Tuple[int, int]:
        """Concrete (bm, bk) block dims for a 2-D operand ``shape``."""
        m, k = shape
        if self.kind == "tensor":
            return (m, k)
        if self.kind == "block":
            bm, bk = self.block_shape
            am, ak = self.align
            return (
                min(bm, -(-m // am) * am),
                min(bk, -(-k // ak) * ak),
            )
        if self.kind == "channel":
            return (1, k)
        if self.kind == "subchannel":
            return (1, min(self.sub, k))
        raise ValueError(f"unknown partition kind: {self.kind}")

    def grid(self, shape: Tuple[int, int]) -> Tuple[int, int]:
        bm, bk = self.resolve(shape)
        m, k = shape
        return (-(-m // bm), -(-k // bk))


PER_TENSOR = Partition("tensor")
PER_BLOCK_128 = Partition("block", (128, 128))
PER_BLOCK_64 = Partition("block", (64, 64))
PER_CHANNEL = Partition("channel")
SUB_CHANNEL_128 = Partition("subchannel", sub=128)


def _pad2d(x: jnp.ndarray, bm: int, bk: int) -> jnp.ndarray:
    m, k = x.shape
    pm = (-m) % bm
    pk = (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    return x


def to_blocks(x: jnp.ndarray, part: Partition) -> jnp.ndarray:
    """(M, K) -> (nm, nk, bm, bk) zero-padded block view."""
    if x.ndim != 2:
        raise ValueError(f"to_blocks wants 2-D, got {x.shape}")
    bm, bk = part.resolve(x.shape)
    xp = _pad2d(x, bm, bk)
    mp, kp = xp.shape
    xb = xp.reshape(mp // bm, bm, kp // bk, bk)
    return xb.transpose(0, 2, 1, 3)


def from_blocks(
    xb: jnp.ndarray, shape: Tuple[int, int]
) -> jnp.ndarray:
    """(nm, nk, bm, bk) -> (M, K), dropping padding."""
    nm, nk, bm, bk = xb.shape
    x = xb.transpose(0, 2, 1, 3).reshape(nm * bm, nk * bk)
    m, k = shape
    return x[:m, :k]


def block_amax(x: jnp.ndarray, part: Partition) -> jnp.ndarray:
    """Per-block absolute maxima, shape (nm, nk), f32."""
    xb = to_blocks(x.astype(jnp.float32), part)
    return jnp.max(jnp.abs(xb), axis=(2, 3))
