"""Host-side MoR statistics aggregation (paper §4.1.3, Figs. 10-11).

The jitted train step emits, per layer and per quantization event, the
STATS_WIDTH vector from :mod:`repro.core.mor`. This module accumulates those
on the host into:

  * BF16-fallback percentages over training (Fig. 10), and
  * relative-error histograms with 0.5%-wide bins, reset every
    ``reset_every`` steps (the Fig. 11 heatmap machinery).

Rendering is plain text (the container has no display); `render_heatmap`
emits an ASCII heat row per tensor, densest bin darkest.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.mor import STAT_DECISION, STAT_FRAC_BF16, STAT_REL_ERR

__all__ = ["RelErrHistogram", "MoRStatsTracker"]

# Bins: [0, .5%), [.5, 1%), ..., [5.5%, inf). Matches the paper's Fig. 11.
BIN_EDGES = np.arange(0.0, 0.06, 0.005)
N_BINS = len(BIN_EDGES)  # last bin is open-ended
SHADES = " .:-=+*#%@"


@dataclasses.dataclass
class RelErrHistogram:
    counts: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(N_BINS, dtype=np.int64)
    )

    def add(self, rel_err: float) -> None:
        idx = int(np.searchsorted(BIN_EDGES, rel_err, side="right")) - 1
        self.counts[min(max(idx, 0), N_BINS - 1)] += 1

    def normalized(self) -> np.ndarray:
        total = self.counts.sum()
        return self.counts / total if total else self.counts.astype(float)

    def render(self) -> str:
        norm = self.normalized()
        return "".join(SHADES[min(int(v * (len(SHADES) - 1) * 3), len(SHADES) - 1)]
                       for v in norm)


class MoRStatsTracker:
    """Accumulates per-tensor MoR stats streamed out of train steps."""

    def __init__(self, threshold: float = 0.045, reset_every: int = 6000):
        self.threshold = threshold
        self.reset_every = reset_every
        self.hists: Dict[str, RelErrHistogram] = {}
        self.fallback_events = 0
        self.total_events = 0
        self.step = 0

    def update(self, named_stats: Dict[str, np.ndarray], step: int) -> None:
        """named_stats: tensor-name -> STATS_WIDTH vector (or (L, W) stack)."""
        if self.reset_every and step // self.reset_every != self.step // max(
            self.reset_every, 1
        ):
            self.hists.clear()
        self.step = step
        for name, vec in named_stats.items():
            arr = np.asarray(vec, dtype=np.float64)
            rows = arr.reshape(-1, arr.shape[-1])
            for i, row in enumerate(rows):
                if row[STAT_DECISION] < 0:
                    # decision == -1: disabled-policy (recipe 'off')
                    # event -- its frac_bf16 = 1.0 is definitional, not
                    # a fallback decision; counting it would drag the
                    # fallback percentage toward 100% on partially
                    # quantized models.
                    continue
                key = f"{name}[{i}]" if rows.shape[0] > 1 else name
                self.hists.setdefault(key, RelErrHistogram()).add(
                    float(row[STAT_REL_ERR])
                )
                self.total_events += 1
                # decision==0 and recipe active => BF16 fallback; the
                # frac_bf16 lane covers both tensor- and sub-tensor
                # recipes.
                self.fallback_events += float(row[STAT_FRAC_BF16])

    @property
    def bf16_fallback_pct(self) -> float:
        if not self.total_events:
            return 0.0
        return 100.0 * self.fallback_events / self.total_events

    def render_heatmap(self, limit: int = 48) -> str:
        lines: List[str] = []
        header = "tensor".ljust(44) + "|" + "0.5% bins -> 5.5%+"
        lines.append(header)
        for name in sorted(self.hists)[:limit]:
            lines.append(name.ljust(44)[:44] + "|" + self.hists[name].render())
        lines.append(
            f"bf16 fallback: {self.bf16_fallback_pct:.2f}% of "
            f"{self.total_events} events (th={self.threshold*100:.1f}%)"
        )
        return "\n".join(lines)
