"""Numeric format specifications for MoR (paper §1-2 and the NVFP4
outlook in §5).

E4M3:  4 exponent bits, 3 mantissa bits. Positive range [2^-9, 448]
       (min subnormal to max). No inf; NaN only.
E5M2:  5 exponent bits, 2 mantissa bits. Positive range [2^-16, 57344].
NVFP4: E2M1 4-bit payload (magnitudes {0, 0.5, 1, 1.5, 2, 3, 4, 6})
       with one E4M3 micro-block scale per NVFP4_MICRO=16 contiguous
       elements of the contraction axis, *two-level* with the GAM block
       scale: the block scale targets ``q_amax = 448 * 6 = 2688`` so
       every micro scale ``micro_amax_scaled / 6`` lands inside E4M3's
       finite range (the NVIDIA NVFP4 recipe, with the per-tensor FP32
       scale replaced by the per-block Alg. 1 GAM scale).
BF16:  passthrough (the "original precision" fallback).

FP8 casts go through ml_dtypes-backed jnp dtypes with
round-to-nearest-even; we clamp to +-max first so no overflow-to-NaN
can occur (GAM scaling guarantees no saturation anyway -- the clamp is
a safety net and is what real TPU/NV cast units do in saturating mode).
The E2M1 payload has no jnp storage dtype on this jax, so
:func:`round_to_e2m1` implements the RNE grid snap with exact
power-of-two bit arithmetic (validated bit-for-bit against
``ml_dtypes.float4_e2m1fn`` in ``tests/test_nvfp4.py``); the same
formula lowers inside the Pallas kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "FormatSpec", "E4M3", "E5M2", "BF16", "NVFP4", "FORMATS",
    "cast_to_format", "cast_to_nvfp4", "round_to_e2m1",
    "encode_e2m1", "decode_e2m1",
    "NVFP4_MICRO", "E2M1_AMAX",
]

# NVFP4 micro-block geometry: one E4M3 scale per 16 contiguous elements
# along the contraction (last) axis, E2M1 max magnitude 6.
NVFP4_MICRO = 16
E2M1_AMAX = 6.0


@dataclasses.dataclass(frozen=True)
class FormatSpec:
    """A quantization target format."""

    name: str
    # Largest finite magnitude (q_amax in Algorithm 1).
    amax: float
    # Smallest positive *normal* magnitude (used by Eq. 4's range metric).
    min_normal: float
    # Smallest positive subnormal magnitude.
    min_subnormal: float
    # Storage dtype for the real-quantization path (None => passthrough).
    dtype: Any
    # Number of explicit mantissa bits (relative error of RNE quantization
    # for in-range values is bounded by 2^-(mantissa_bits+1)).
    mantissa_bits: int
    # Bits per element when stored for real.
    bits: int

    @property
    def is_passthrough(self) -> bool:
        return self.dtype is None or self.name == "bf16"

    @property
    def eps(self) -> float:
        """Max relative rounding error for in-range normal values."""
        return 2.0 ** -(self.mantissa_bits + 1)


E4M3 = FormatSpec(
    name="e4m3",
    amax=448.0,
    min_normal=2.0**-6,
    min_subnormal=2.0**-9,
    dtype=jnp.float8_e4m3fn,
    mantissa_bits=3,
    bits=8,
)

E5M2 = FormatSpec(
    name="e5m2",
    amax=57344.0,
    min_normal=2.0**-14,
    min_subnormal=2.0**-16,
    dtype=jnp.float8_e5m2,
    mantissa_bits=2,
    bits=8,
)

BF16 = FormatSpec(
    name="bf16",
    amax=3.3895314e38,
    min_normal=2.0**-126,
    min_subnormal=2.0**-133,
    dtype=None,
    mantissa_bits=7,
    bits=16,
)

# NVFP4's FormatSpec drives the *block-level* GAM scale of the
# two-level scheme: q_amax = E4M3.amax * E2M1_AMAX, so the Alg. 1
# no-saturation invariant (block_amax * scale <= 2688) guarantees every
# per-16-element micro scale (micro_amax_scaled / 6 <= 448) is finite
# in E4M3 without saturation. min_normal/min_subnormal describe the
# E2M1 payload itself (4 binades of magnitudes: 0.5 .. 6).
NVFP4 = FormatSpec(
    name="nvfp4",
    amax=E4M3.amax * E2M1_AMAX,  # 2688.0: two-level block-scale target
    min_normal=1.0,
    min_subnormal=0.5,
    dtype=None,  # sub-byte: packed nibbles, no jnp storage dtype
    mantissa_bits=1,
    bits=4,  # payload bits; +8/16 micro-scale bits per element on top
)

FORMATS = {f.name: f for f in (E4M3, E5M2, BF16, NVFP4)}


def _e2m1_ulp(a: jnp.ndarray) -> jnp.ndarray:
    """Distance between adjacent E2M1 magnitudes at |a| (a in [0, 6]).

    Exact bit arithmetic, no transcendentals: the ulp is 2^{e-1} with
    e = floor(log2(max(a, 1))) read from the f32 exponent field
    (0.5 for the subnormal/first binade, 1 in [2, 4), 2 in [4, 6]).
    """
    a1 = jnp.maximum(a, 1.0)
    bits = jax.lax.bitcast_convert_type(a1.astype(jnp.float32), jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127  # floor(log2 a1): 0, 1 or 2
    return jax.lax.bitcast_convert_type(
        (e - 1 + 127) << 23, jnp.float32
    )


def round_to_e2m1(x: jnp.ndarray) -> jnp.ndarray:
    """RNE snap of f32 ``x`` to the E2M1 grid, saturating at +-6.

    Pure vector bit arithmetic + one ``jnp.round`` (RNE), so the same
    formula runs in XLA and inside the Pallas kernels, and matches
    ``ml_dtypes.float4_e2m1fn`` casts bit-for-bit (tests/test_nvfp4.py).
    """
    a = jnp.minimum(jnp.abs(x.astype(jnp.float32)), E2M1_AMAX)
    ulp = _e2m1_ulp(a)
    mag = jnp.round(a / ulp) * ulp  # a/ulp exact (power-of-two divide)
    return jnp.where(x < 0, -mag, mag)


def encode_e2m1(v: jnp.ndarray) -> jnp.ndarray:
    """E2M1 grid values -> 4-bit codes (sign<<3 | magnitude code).

    ``v`` must already lie on the grid (output of :func:`round_to_e2m1`).
    Magnitude codes: 0..3 = {0, 0.5, 1, 1.5}, 4..7 = {2, 3, 4, 6}.
    Returns int32 in [0, 15] (callers narrow/pack to nibbles).
    """
    m = jnp.abs(v.astype(jnp.float32))
    ulp = _e2m1_ulp(m)
    bits = jax.lax.bitcast_convert_type(
        jnp.maximum(m, 1.0).astype(jnp.float32), jnp.int32
    )
    e = ((bits >> 23) & 0xFF) - 127  # 0, 1, 2
    hi = 4 + 2 * (e - 1) + (m / ulp).astype(jnp.int32) - 2
    code = jnp.where(
        m < 2.0, (m * 2.0).astype(jnp.int32), hi
    )
    sign = (v < 0).astype(jnp.int32)
    return code | (sign << 3)


def decode_e2m1(code: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    """4-bit E2M1 codes (int) -> grid values. Select-only (kernel-safe).

    ``dtype`` is the arithmetic/output dtype: every E2M1 grid value
    (and its sign flip) is exact in bf16 and wider, so a bf16 decode is
    bit-identical to the f32 one after any downstream cast -- the GEMM
    kernel decodes straight to the storage dtype at half the vector
    register width.
    """
    c = code.astype(jnp.int32)
    m = c & 7
    mag = jnp.where(
        m < 4,
        m.astype(dtype) * jnp.asarray(0.5, dtype),
        (jnp.asarray(1.0, dtype)
         + jnp.asarray(0.5, dtype) * (m & 1).astype(dtype))
        * jnp.where(
            m >= 6, jnp.asarray(4.0, dtype), jnp.asarray(2.0, dtype)
        ),
    )
    return jnp.where((c >> 3) == 1, -mag, mag)


def cast_to_nvfp4(xs: jnp.ndarray) -> jnp.ndarray:
    """Two-level NVFP4 fake-quantization of a *block-scaled* array.

    ``xs`` is ``x * scale`` with the GAM block scale targeting
    ``NVFP4.amax`` (so ``|xs| <= 2688`` and every micro scale fits
    E4M3). Along the last axis, per group of ``NVFP4_MICRO`` elements:

        d   = micro_amax(|xs|) / 6          (<= 448 by the invariant)
        d_q = RNE E4M3 round-trip of d      (1.0 for all-zero groups)
        q   = round_to_e2m1(xs / d_q)       (saturating at +-6)
        out = q * d_q                       (same scale domain as xs)

    The last axis is zero-padded to a multiple of NVFP4_MICRO
    internally (zeros quantize exactly), so any block width works; the
    *packed* payload path additionally requires 16-divisible blocks
    (see kernels/ref.py pack_mixed).
    """
    xs = xs.astype(jnp.float32)
    k = xs.shape[-1]
    pad = (-k) % NVFP4_MICRO
    if pad:
        xs = jnp.concatenate(
            [xs, jnp.zeros((*xs.shape[:-1], pad), jnp.float32)], axis=-1
        )
    g = xs.reshape(*xs.shape[:-1], -1, NVFP4_MICRO)
    d = jnp.max(jnp.abs(g), axis=-1, keepdims=True) / E2M1_AMAX
    d_q = cast_to_format(d, E4M3)
    safe_d = jnp.where(d_q > 0, d_q, 1.0)
    out = round_to_e2m1(g / safe_d) * safe_d
    out = out.reshape(*xs.shape[:-1], xs.shape[-1])
    return out[..., :k]


def cast_to_format(x: jnp.ndarray, fmt: FormatSpec) -> jnp.ndarray:
    """Round-trip ``x`` (f32) through ``fmt`` with saturating cast.

    Returns an f32 array carrying the information loss of ``fmt``
    (the paper's fake-quantization primitive, Fig. 4). For BF16 the
    round-trip goes through jnp.bfloat16; for NVFP4 through the
    two-level micro-scaled E2M1 snap (:func:`cast_to_nvfp4` -- ``x``
    is then the block-scaled value, as for the fp8 formats).
    """
    if fmt.name == "nvfp4":
        return cast_to_nvfp4(x)
    if fmt.is_passthrough:
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    clipped = jnp.clip(x, -fmt.amax, fmt.amax)
    return clipped.astype(fmt.dtype).astype(jnp.float32)
