"""Numeric format specifications for MoR (paper §1-2).

E4M3: 4 exponent bits, 3 mantissa bits. Positive range [2^-9, 448]
      (min subnormal to max). No inf; NaN only.
E5M2: 5 exponent bits, 2 mantissa bits. Positive range [2^-16, 57344].
BF16: passthrough (the "original precision" fallback).

Casts go through ml_dtypes-backed jnp dtypes with round-to-nearest-even;
we clamp to +-max first so no overflow-to-NaN can occur (GAM scaling
guarantees no saturation anyway -- the clamp is a safety net and is what
real TPU/NV cast units do in saturating mode).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

__all__ = ["FormatSpec", "E4M3", "E5M2", "BF16", "FORMATS", "cast_to_format"]


@dataclasses.dataclass(frozen=True)
class FormatSpec:
    """A quantization target format."""

    name: str
    # Largest finite magnitude (q_amax in Algorithm 1).
    amax: float
    # Smallest positive *normal* magnitude (used by Eq. 4's range metric).
    min_normal: float
    # Smallest positive subnormal magnitude.
    min_subnormal: float
    # Storage dtype for the real-quantization path (None => passthrough).
    dtype: Any
    # Number of explicit mantissa bits (relative error of RNE quantization
    # for in-range values is bounded by 2^-(mantissa_bits+1)).
    mantissa_bits: int
    # Bits per element when stored for real.
    bits: int

    @property
    def is_passthrough(self) -> bool:
        return self.dtype is None or self.name == "bf16"

    @property
    def eps(self) -> float:
        """Max relative rounding error for in-range normal values."""
        return 2.0 ** -(self.mantissa_bits + 1)


E4M3 = FormatSpec(
    name="e4m3",
    amax=448.0,
    min_normal=2.0**-6,
    min_subnormal=2.0**-9,
    dtype=jnp.float8_e4m3fn,
    mantissa_bits=3,
    bits=8,
)

E5M2 = FormatSpec(
    name="e5m2",
    amax=57344.0,
    min_normal=2.0**-14,
    min_subnormal=2.0**-16,
    dtype=jnp.float8_e5m2,
    mantissa_bits=2,
    bits=8,
)

BF16 = FormatSpec(
    name="bf16",
    amax=3.3895314e38,
    min_normal=2.0**-126,
    min_subnormal=2.0**-133,
    dtype=None,
    mantissa_bits=7,
    bits=16,
)

FORMATS = {f.name: f for f in (E4M3, E5M2, BF16)}


def cast_to_format(x: jnp.ndarray, fmt: FormatSpec) -> jnp.ndarray:
    """Round-trip ``x`` (f32) through ``fmt`` with saturating cast.

    Returns an f32 array carrying the information loss of ``fmt``
    (the paper's fake-quantization primitive, Fig. 4). For BF16 the
    round-trip goes through jnp.bfloat16.
    """
    if fmt.is_passthrough:
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    clipped = jnp.clip(x, -fmt.amax, fmt.amax)
    return clipped.astype(fmt.dtype).astype(jnp.float32)
