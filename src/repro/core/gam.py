"""Group Amax Mantissa (GAM) scaling -- Algorithm 1 of the paper.

GAM decouples the FP32 scaling factor ``s = q_amax / amax`` into

  * one group-level mantissa ``m_g in [1, 2)`` shared by every block of the
    group (group = whole tensor in all paper experiments), kept at full
    FP32-mantissa precision, and
  * one per-block E8M0 exponent ``e_b`` (8-bit, bias-127 storage).

The reconstructed per-block scale is ``m_g * 2^{e_b}``. The rounding step
(``e_b -= 1`` when ``m_g > m_b``) guarantees the *no-saturation invariant*::

    block_amax * (m_g * 2^{e_b}) <= q_amax        for every block,

which property tests assert for random tensors (tests/test_gam.py).

Ablation variants (paper §4.1.2):
  * ``gam``       -- the above (default).
  * ``e8m0``      -- per-block scale 2^{floor(log2 s_b)} (no mantissa; also
                     saturation-free since it only rounds the scale down).
  * ``fp32_amax`` -- standard per-block full-FP32 amax scaling s_b.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .formats import FormatSpec
from .partition import Partition, block_amax

__all__ = ["GamScales", "split_mantissa_exponent", "compute_scales", "scales_from_bmax", "exp2i", "E8M0_BIAS"]

E8M0_BIAS = 127


class GamScales(NamedTuple):
    """Scale metadata for one quantization event.

    scale:      (nm, nk) f32 reconstructed per-block scale factors.
    group_mantissa: () f32 in [1, 2) -- the shared 23-bit mantissa m_g
                    (1.0 for the e8m0 / fp32_amax ablations).
    block_exp:  (nm, nk) int32 per-block exponent (E8M0 payload, unbiased).
    group_amax: () f32 -- amax of the whole group (tensor).
    """

    scale: jnp.ndarray
    group_mantissa: jnp.ndarray
    block_exp: jnp.ndarray
    group_amax: jnp.ndarray


def exp2i(e: jnp.ndarray) -> jnp.ndarray:
    """Exact 2^e for integer e in [-126, 127] via exponent-field bitcast.

    jnp.exp2 is an approximate transcendental on some backends; scale
    reconstruction must be *exact* power-of-two arithmetic or the shared
    mantissa property of GAM is destroyed.
    """
    e = jnp.clip(e.astype(jnp.int32), -126, 127)
    bits = (e + 127) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def split_mantissa_exponent(s: jnp.ndarray):
    """s = m * 2^e with m in [1, 2) (element-wise, s > 0). Exact (frexp)."""
    fr, e = jnp.frexp(s.astype(jnp.float32))  # fr in [0.5, 1)
    return (fr * 2.0).astype(jnp.float32), (e - 1).astype(jnp.int32)


def compute_scales(
    x2d: jnp.ndarray,
    part: Partition,
    fmt: FormatSpec,
    algo: str = "gam",
) -> GamScales:
    """Algorithm 1 with a single group covering the whole tensor.

    Returns per-block f32 scales such that ``x * scale`` is guaranteed not to
    saturate ``fmt`` (for 'gam' and 'e8m0'; 'fp32_amax' maps block amax to
    q_amax exactly).
    """
    return scales_from_bmax(block_amax(x2d, part), fmt, algo)


def scales_from_bmax(
    bmax: jnp.ndarray, fmt: FormatSpec, algo: str = "gam",
    group_amax: jnp.ndarray | None = None,
) -> GamScales:
    """Algorithm 1 from precomputed per-block amax (fused callers).

    ``group_amax`` overrides the group amax (default: max over the
    supplied block amaxes). Mesh-sharded events pass the allreduced
    global amax here so the shared mantissa ``m_g`` -- and with it every
    per-block scale -- is bit-identical across any sharding of the
    group (docs/sharding.md).
    """
    g_amax = jnp.max(bmax) if group_amax is None else group_amax

    # Zero guards: all-zero tensor / all-zero (or padding-only) blocks get
    # scale 1.0 -- quantizing zeros is exact under any scale. Nonfinite
    # guards ride the same selects: an Inf/NaN amax (poisoned operand)
    # would otherwise zero out or NaN the scale of every block sharing
    # the group mantissa. Sanitizing keeps clean blocks' scales exact;
    # poisoned blocks are contained downstream (BF16 selection arm /
    # skip-step) and reported via the stats guard lanes.
    g_ok = (g_amax > 0) & jnp.isfinite(g_amax)
    safe_g = jnp.where(g_ok, g_amax, 1.0)
    safe_b = jnp.where((bmax > 0) & jnp.isfinite(bmax), bmax, safe_g)

    s_g = fmt.amax / safe_g
    s_b = fmt.amax / safe_b  # ideal per-block FP32 scale

    if algo == "fp32_amax":
        scale = s_b.astype(jnp.float32)
        return GamScales(
            scale=scale,
            group_mantissa=jnp.float32(1.0),
            block_exp=split_mantissa_exponent(s_b)[1],
            group_amax=g_amax.astype(jnp.float32),
        )

    m_b, e_b = split_mantissa_exponent(s_b)
    if algo == "e8m0":
        # Round scale down to a pure power of two -> saturation-free.
        # Clamp matches exp2i's full [-126, 127] domain: clipping at 126
        # (the old off-by-one) halved the scale of tiny-amax blocks a
        # second time for no reason (the "double rounding" bug) --
        # 2^127 is exactly representable and m_g * 2^127 <= f32max
        # since m_g <= 2 - 2^-23.
        e_b = jnp.clip(e_b, -126, 127)
        scale = exp2i(e_b)
        return GamScales(
            scale=scale,
            group_mantissa=jnp.float32(1.0),
            block_exp=e_b,
            group_amax=g_amax.astype(jnp.float32),
        )

    if algo != "gam":
        raise ValueError(f"unknown scaling algo: {algo}")

    m_g, _ = split_mantissa_exponent(s_g)
    # Saturation-prevention rounding (Algorithm 1): if the shared mantissa
    # exceeds this block's ideal mantissa, m_g * 2^{e_b} > s_b would map
    # block_amax above q_amax; drop the exponent by one.
    e_b = jnp.where(m_g <= m_b, e_b, e_b - 1)
    e_b = jnp.clip(e_b, -126, 127)  # exp2i's full domain (see e8m0 note)
    scale = m_g * exp2i(e_b)
    return GamScales(
        scale=scale.astype(jnp.float32),
        group_mantissa=m_g,
        block_exp=e_b,
        group_amax=g_amax.astype(jnp.float32),
    )
