"""The MoR framework (Algorithm 2) and the paper's concrete recipes.

Entry point: :func:`mor_quantize` -- fake-quantize a 2-D operand view under a
:class:`~repro.core.policy.MoRPolicy`, returning the (possibly passthrough)
tensor plus a fixed-size stats vector. Everything is functional and jittable:
dynamic decisions are data-dependent *selects*, exactly matching the paper's
fake-quantization workflow (Fig. 4) where both representations exist
transiently and one is chosen from live numerics.

Every quantization event dispatches through the backend-resolved entry
points in :mod:`repro.kernels.ops` (`quant_err` for the one-format
recipes, `mor_select` for the sub-tensor recipes), so the fused Pallas
kernels, their interpret-mode validation, and the pure-jnp XLA lowering
share one implementation. The recipe layer only aggregates the per-block
sums into decisions and the stats vector below.

Mesh-sharded events (``MoRPolicy.mesh_axes`` non-empty, inside
``shard_map``): all tensor-global aggregates in this module -- the
Eq. 2 error/count sums, the stats fractions, and (via the kernel entry
points) the group amax behind the Alg. 1 mantissa -- are allreduced
over the named axes, so per-block decisions are bit-identical to the
single-device run. See docs/sharding.md.

Stats vector layout v4 (f32, STATS_WIDTH = 14):
  [0] decision        1.0 if the preferred low-precision type was accepted
                      (tensor-level), the fraction of blocks in the
                      recipe's preferred format (sub-*: E4M3 for
                      sub2/sub3, NVFP4 for sub4), or -1.0 for a
                      *disabled* ('off') event -- the sentinel
                      aggregation consumers filter on so passthrough
                      rows cannot dilute the enabled-event fractions.
  [1] rel_err         global mean relative error of the E4M3 candidate.
  [2] amax            group (tensor) absolute maximum.
  [3] frac_e4m3       fraction of blocks quantized to E4M3.
  [4] frac_e5m2       fraction of blocks quantized to E5M2 (sub3/sub4).
  [5] frac_bf16       fraction of blocks left in BF16.
  [6] nonzero_frac    fraction of non-zero elements.
  [7] group_mantissa  m_g of the GAM scale.
  [8] frac_nvfp4      fraction of blocks quantized to NVFP4 (sub4 only).
  [9] micro_scale_bpe extra bytes/element spent on NVFP4 micro scales
                      over the whole operand (= frac_nvfp4 / 16: one
                      E4M3 byte per 16 elements of each NVFP4 block).
  [10] event_kind     which pipeline stage emitted the row: 0.0 = GEMM
                      operand event (mor_dot fwd/bwd), 1.0 = gradient
                      compression (optim.compress), 2.0 = Adam first
                      moment, 3.0 = Adam second moment (optim.moments).
                      Producers in this module always emit 0.0; the
                      optimizer layer stamps its kind so consumers can
                      split GEMM rows from optimizer-event rows.
  [11] payload_bpe    logical payload bytes/element implied by the tag
                      mixture, micro scales included: 1*frac_e4m3 +
                      1*frac_e5m2 + 2*frac_bf16 + 0.5625*frac_nvfp4.
                      Excludes the per-block tag/scale grids (8 bytes
                      per block; see optim.moments.block_overhead_bpe).
                      A fully-fp8 selection reads 1.0, fully-NVFP4
                      0.5625, a disabled ('off') event 2.0 -- this lane
                      is the HBM bytes-per-param budget the optimizer
                      state asserts against.
  [12] guard_flags    nonfinite-containment sentinels (repro.robust), a
                      sum of power-of-two flag values: +1.0 the group
                      amax was nonfinite (the whole operand is suspect;
                      Alg. 1 scales were derived from a sanitized amax
                      of 1.0), +2.0 at least one block's error sums
                      were nonfinite (those blocks carry NaN/Inf
                      values; the sub-tensor recipes route them to the
                      BF16 arm so the poison is preserved verbatim, not
                      laundered through an fp8 cast), +4.0 a stale
                      delayed-scaling amax failed to cover the operand
                      even after the bounded re-encode backoff
                      (repro.robust.guard.requantize_with_backoff).
                      0.0 on every clean event. Detection rides the
                      amax / per-block error sums the event already
                      computes: the clean path pays zero additional
                      operand-sized passes (asserted by the
                      'robust_guard_event' analysis contract).
  [13] fallback_count number of blocks whose error sums were nonfinite
                      (psum'd under mesh_axes like every other block
                      count, so shards agree bit-identically). The
                      block-granular measure behind guard_flags'
                      +2.0 bit.

v1 (width 8, PRs 1-3) is layout v2 without [8]/[9] and with 0.0 instead
of the -1.0 disabled sentinel; v2 (width 10, PRs 4-7) is v3 without the
optimizer-event lanes [10]/[11]; v3 (width 12, PRs 8-9) is v4 without
the guard lanes [12]/[13]. Every consumer keys on STATS_WIDTH
(tests/test_stats_contract.py guards the migration).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from .collectives import global_size, pmax_over, psum_over
from .formats import E4M3, E5M2, FormatSpec, cast_to_format
from .gam import GamScales, compute_scales
from .partition import Partition, from_blocks, to_blocks
from .policy import MoRPolicy

# Imported after every core sibling so the core -> kernels -> core-submodule
# import chain stays acyclic (kernels only touches formats/gam/metrics/
# partition, all loaded above).
from repro.kernels import ops as kops
from repro.kernels import ref as _kref
from repro.kernels.ref import TAG_BF16, TAG_E4M3, TAG_NVFP4, MixedOperand

__all__ = [
    "STATS_WIDTH",
    "STAT_DECISION",
    "STAT_REL_ERR",
    "STAT_AMAX",
    "STAT_FRAC_E4M3",
    "STAT_FRAC_E5M2",
    "STAT_FRAC_BF16",
    "STAT_NONZERO_FRAC",
    "STAT_GROUP_MANTISSA",
    "STAT_FRAC_NVFP4",
    "STAT_MICRO_SCALE_BPE",
    "STAT_EVENT_KIND",
    "STAT_PAYLOAD_BPE",
    "STAT_GUARD_FLAGS",
    "STAT_FALLBACK_COUNT",
    "GUARD_OK",
    "GUARD_NONFINITE_AMAX",
    "GUARD_BLOCK_FALLBACK",
    "GUARD_STALE_SCALE",
    "EVENT_GEMM",
    "EVENT_GRAD",
    "EVENT_MOMENT_M",
    "EVENT_MOMENT_V",
    "quant_dequant",
    "quant_dequant_with_scales",
    "mor_quantize",
    "quantize_for_gemm",
    "partition_of",
]

STATS_WIDTH = 14

# Named lane indices of the layout-v4 stats row documented above. All
# stats-row consumers index through these -- the v1->v2->v3 migrations
# re-numbered lanes twice, and the MOR003 lint rule
# (repro.analysis.ast_rules) rejects new literal-index sites.
STAT_DECISION = 0
STAT_REL_ERR = 1
STAT_AMAX = 2
STAT_FRAC_E4M3 = 3
STAT_FRAC_E5M2 = 4
STAT_FRAC_BF16 = 5
STAT_NONZERO_FRAC = 6
STAT_GROUP_MANTISSA = 7
STAT_FRAC_NVFP4 = 8
STAT_MICRO_SCALE_BPE = 9
STAT_EVENT_KIND = 10
STAT_PAYLOAD_BPE = 11
STAT_GUARD_FLAGS = 12
STAT_FALLBACK_COUNT = 13

# Stats lane [12] (guard_flags) values: a sum of the power-of-two flags
# below (0.0 = clean event). Produced here and by
# repro.robust.guard.requantize_with_backoff; consumed by the skip-step
# ladder (repro.optim.adamw), summarize_mor_stats' guard counters, and
# the chaos suite (tests/test_robust_chaos.py). docs/robustness.md is
# the story.
GUARD_OK = 0.0
GUARD_NONFINITE_AMAX = 1.0
GUARD_BLOCK_FALLBACK = 2.0
GUARD_STALE_SCALE = 4.0

# Stats lane [10] (event_kind) values. GEMM operand events are emitted
# by this module; the optimizer layer (repro.optim) stamps its rows so
# aggregation consumers can split training-math events from
# training-state (gradient / moment storage) events.
EVENT_GEMM = 0.0
EVENT_GRAD = 1.0
EVENT_MOMENT_M = 2.0
EVENT_MOMENT_V = 3.0


def partition_of(policy: MoRPolicy) -> Partition:
    # sub4 blocks must pair rows (nibble packing) and 16-divide the
    # contraction axis (micro scales); align rounds small-operand
    # blocks up instead of shrinking them to odd shapes.
    align = (2, 16) if policy.recipe == "sub4" else (1, 1)
    return Partition(
        kind=policy.partition, block_shape=policy.block_shape,
        sub=policy.sub, align=align,
    )


def quant_dequant_with_scales(
    x2d: jnp.ndarray, part: Partition, fmt: FormatSpec, scales: GamScales
) -> jnp.ndarray:
    """Fake-quantize with precomputed per-block scales. Returns f32 (M, K)."""
    xb = to_blocks(x2d.astype(jnp.float32), part)
    s = scales.scale[:, :, None, None]
    xq = cast_to_format(xb * s, fmt) / s
    return from_blocks(xq, x2d.shape)


def quant_dequant(
    x2d: jnp.ndarray, part: Partition, fmt: FormatSpec, algo: str = "gam"
) -> Tuple[jnp.ndarray, GamScales]:
    """GAM-scale + fake-quantize. Returns (f32 (M,K), scales)."""
    scales = compute_scales(x2d, part, fmt, algo=algo)
    return quant_dequant_with_scales(x2d, part, fmt, scales), scales


def _stats(
    decision, rel_err, amax, f_e4, f_e5, f_bf, nz_frac, m_g,
    f_nv=0.0, micro_bpe=0.0, guard_flags=0.0, fallback_count=0.0,
) -> jnp.ndarray:
    # [11] payload_bpe follows from the tag mixture: fp8 arms store one
    # byte/elt, BF16 two, NVFP4 half a byte plus one E4M3 micro-scale
    # byte per NVFP4_MICRO elements (= 0.5625 total).
    payload_bpe = (
        jnp.float32(f_e4) + jnp.float32(f_e5)
        + 2.0 * jnp.float32(f_bf)
        + (0.5 + 1.0 / _kref.NVFP4_MICRO) * jnp.float32(f_nv)
    )
    return jnp.stack(
        [
            jnp.float32(decision),
            jnp.float32(rel_err),
            jnp.float32(amax),
            jnp.float32(f_e4),
            jnp.float32(f_e5),
            jnp.float32(f_bf),
            jnp.float32(nz_frac),
            jnp.float32(m_g),
            jnp.float32(f_nv),
            jnp.float32(micro_bpe),
            jnp.float32(EVENT_GEMM),
            payload_bpe,
            jnp.float32(guard_flags),
            jnp.float32(fallback_count),
        ]
    )


def _guard_lanes(group_amax, block_err_sums=None, mesh_axes=()):
    """Guard lanes [12]/[13] from aggregates the event already computed.

    ``group_amax`` is the (allreduced) tensor amax and
    ``block_err_sums`` the per-block quantization-error sums -- both
    scalar / block-grid sized, so the nonfinite checks below add zero
    operand-sized work. A NaN/Inf element forces its block's amax and
    error sum nonfinite (max/sum propagate), so per-block error sums
    are a complete poisoned-block detector.
    """
    amax_bad = ~jnp.isfinite(jnp.float32(group_amax))
    flags = jnp.where(amax_bad, GUARD_NONFINITE_AMAX, GUARD_OK)
    if block_err_sums is None:
        return flags, jnp.float32(0.0)
    fallback = psum_over(
        jnp.sum((~jnp.isfinite(block_err_sums)).astype(jnp.float32)),
        mesh_axes,
    )
    flags = flags + jnp.where(fallback > 0, GUARD_BLOCK_FALLBACK, GUARD_OK)
    return flags, fallback


def _tensor_level(x2d: jnp.ndarray, policy: MoRPolicy):
    """Tensor-level MoR [E4M3, BF16] (paper §3.1).

    The quantization uses the policy's partitioning for scales, but the
    accept/reject decision is a single global one: per-partition local
    errors aggregated globally (Fig. 2) vs the Eq. 2 threshold. Under
    ``policy.mesh_axes`` the error/count aggregates are psum'd across
    the mesh, so every shard takes the same accept/reject branch as the
    single-device run.
    """
    axes = policy.mesh_axes
    part = partition_of(policy)
    q = kops.quant_err(
        x2d, part, E4M3, policy.algo, backend=policy.backend,
        mesh_axes=axes,
    )
    n = jnp.maximum(psum_over(jnp.sum(q.counts), axes), 1.0)
    err = psum_over(jnp.sum(q.err_sums), axes) / n
    ok = err < policy.threshold
    y = jnp.where(ok, q.y, x2d)
    okf = ok.astype(jnp.float32)
    nz = psum_over(jnp.sum(q.counts), axes) / global_size(x2d.size, axes)
    # A nonfinite global error rejects (NaN < threshold is False), so a
    # poisoned event degrades to whole-tensor BF16 passthrough.
    gf, fb = _guard_lanes(q.group_amax, q.err_sums, axes)
    stats = _stats(
        okf, err, q.group_amax, okf, 0.0, 1.0 - okf, nz, q.group_mantissa,
        guard_flags=gf, fallback_count=fb,
    )
    tags = jnp.broadcast_to(
        jnp.where(ok, TAG_E4M3, TAG_BF16).astype(jnp.int32),
        q.err_sums.shape,
    )
    return y, stats, tags


def _sub_tensor_stats(r, policy: MoRPolicy, x_size: int) -> jnp.ndarray:
    """Aggregate one sub-tensor selection event (``MorSelect``-shaped
    ``r``) into the STATS_WIDTH vector -- shared by the fake-quant and
    the one-pass real-pack paths, which therefore can never disagree on
    a stats row."""
    axes = policy.mesh_axes
    nblocks = psum_over(jnp.float32(r.sel.size), axes)
    nz = psum_over(jnp.sum(r.counts), axes) / global_size(x_size, axes)
    tot_n = jnp.maximum(psum_over(jnp.sum(r.counts), axes), 1.0)
    global_e4_err = psum_over(jnp.sum(r.e4_sums), axes) / tot_n
    f4 = psum_over(
        jnp.sum((r.sel == 0).astype(jnp.float32)), axes
    ) / nblocks
    # Poisoned blocks (nonfinite error sums) lose every fp8/NVFP4
    # comparison (NaN compares False, Inf error exceeds any gate), so
    # selection routes them to the BF16 arm -- the guard lanes report
    # how many blocks took that containment path.
    gf, fb = _guard_lanes(r.group_amax, r.e4_sums, axes)

    if policy.recipe == "sub2":
        return _stats(
            f4, global_e4_err, r.group_amax, f4, 0.0, 1.0 - f4, nz,
            r.group_mantissa, guard_flags=gf, fallback_count=fb,
        )

    f5 = psum_over(
        jnp.sum((r.sel == 1).astype(jnp.float32)), axes
    ) / nblocks
    if policy.recipe == "sub3":
        return _stats(
            f4, global_e4_err, r.group_amax, f4, f5, 1.0 - f4 - f5, nz,
            r.group_mantissa, guard_flags=gf, fallback_count=fb,
        )

    # sub4: the preferred format is NVFP4; decision = frac_nvfp4 and the
    # micro-scale byte overhead rides in the new stats lane.
    f_nv = psum_over(
        jnp.sum((r.sel == TAG_NVFP4).astype(jnp.float32)), axes
    ) / nblocks
    return _stats(
        f_nv, global_e4_err, r.group_amax, f4, f5,
        1.0 - f4 - f5 - f_nv, nz, r.group_mantissa,
        f_nv, f_nv / _kref.NVFP4_MICRO,
        guard_flags=gf, fallback_count=fb,
    )


def _sub_tensor(x2d: jnp.ndarray, policy: MoRPolicy):
    """Sub-tensor MoR (§3.2 + sub4): two/three/four-way per-block choice.

    The whole per-block pipeline -- the fp8 (and sub4: NVFP4)
    candidates, the Eq. 3 error comparisons and the Eq. 4 dynamic-range
    gates -- runs in one fused pass per block (`kops.mor_select`); only
    the stats aggregation lives here.
    """
    part = partition_of(policy)
    r = kops.mor_select(
        x2d, part, mode=policy.recipe, algo=policy.algo,
        backend=policy.backend, mesh_axes=policy.mesh_axes,
    )
    return r.y, _sub_tensor_stats(r, policy, x2d.size), r.sel


def _static_e4m3(x2d: jnp.ndarray, policy: MoRPolicy):
    axes = policy.mesh_axes
    part = partition_of(policy)
    q = kops.quant_err(
        x2d, part, E4M3, policy.algo, backend=policy.backend,
        mesh_axes=axes,
    )
    n = jnp.maximum(psum_over(jnp.sum(q.counts), axes), 1.0)
    err = psum_over(jnp.sum(q.err_sums), axes) / n
    nz = psum_over(jnp.sum(q.counts), axes) / global_size(x2d.size, axes)
    # Static recipe: no BF16 arm to fall back to, so guard_flags is
    # pure detection here -- poisoned blocks stay E4M3-cast and the
    # optimizer-level skip-step rung is the containment.
    gf, fb = _guard_lanes(q.group_amax, q.err_sums, axes)
    stats = _stats(1.0, err, q.group_amax, 1.0, 0.0, 0.0, nz,
                   q.group_mantissa, guard_flags=gf, fallback_count=fb)
    tags = jnp.full(q.err_sums.shape, TAG_E4M3, jnp.int32)
    return q.y, stats, tags


def _off_stats(x2d: jnp.ndarray, mesh_axes=()) -> jnp.ndarray:
    nz = psum_over(
        jnp.sum((x2d != 0).astype(jnp.float32)), mesh_axes
    ) / global_size(x2d.size, mesh_axes)
    amax = pmax_over(
        jnp.max(jnp.abs(x2d.astype(jnp.float32))), mesh_axes
    )
    # decision = -1.0: the disabled-event sentinel. A recipe='off' row
    # still reports frac_bf16 = 1.0 (it *is* BF16), but aggregation
    # consumers (summarize_mor_stats, MoRStatsTracker) must skip it or
    # passthrough events drag fwd_frac_bf16 toward 1 even when every
    # enabled event quantized.
    gf, _ = _guard_lanes(amax)
    return _stats(-1.0, 0.0, amax, 0.0, 0.0, 1.0, nz, 1.0,
                  guard_flags=gf)


def _decide(x2d: jnp.ndarray, policy: MoRPolicy):
    """Shared recipe dispatch: (fake-quant y, stats, per-block tags).

    The single decision path behind both :func:`mor_quantize` (fake
    quantization, training numerics) and :func:`quantize_for_gemm`
    (real payload packing for the mixed GEMM) -- the two can therefore
    never disagree on a block's representation or on the stats vector.
    """
    if policy.recipe == "tensor":
        return _tensor_level(x2d, policy)
    if policy.recipe in ("sub2", "sub3", "sub4"):
        return _sub_tensor(x2d, policy)
    if policy.recipe == "e4m3":
        return _static_e4m3(x2d, policy)
    raise ValueError(f"unknown recipe: {policy.recipe}")


def mor_quantize(
    x2d: jnp.ndarray, policy: MoRPolicy
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fake-quantize one 2-D operand view under ``policy``.

    Returns ``(y, stats)`` where ``y`` has x2d's dtype and shape and
    ``stats`` is the STATS_WIDTH f32 vector documented in the module
    docstring. Contraction axis must be the last axis of ``x2d``.

    When ``policy.mesh_axes`` is non-empty the call must run inside a
    ``shard_map`` binding those axis names; ``x2d`` is then this
    device's shard and every global statistic is allreduced, making the
    per-block decisions bit-identical to the single-device run
    (docs/sharding.md).

    >>> import jax.numpy as jnp
    >>> from repro.core.mor import mor_quantize
    >>> from repro.core.policy import MoRPolicy
    >>> x = jnp.ones((128, 128), jnp.bfloat16)
    >>> y, stats = mor_quantize(x, MoRPolicy(recipe="sub3"))
    >>> y.shape == x.shape and y.dtype == x.dtype
    True
    >>> stats.shape            # the STATS_WIDTH vector
    (14,)
    >>> float(stats[5])        # all-ones quantizes exactly: no BF16 blocks
    0.0
    """
    if not policy.enabled:
        return x2d, _off_stats(x2d, policy.mesh_axes)
    y, stats, _ = _decide(x2d, policy)
    return y.astype(x2d.dtype), stats


def quantize_for_gemm(
    x2d: jnp.ndarray, policy: MoRPolicy
) -> Tuple[MixedOperand, jnp.ndarray]:
    """Real-quantize one 2-D operand view into the mixed block layout.

    Same per-block decisions and stats vector as :func:`mor_quantize`
    (one shared decision path), but instead of fake-quantized BF16
    values it returns a :class:`~repro.kernels.ref.MixedOperand` --
    uint8 fp8 payloads + original-precision buffer + per-block tags and
    GAM scales -- ready for :func:`repro.kernels.ops.mixed_gemm`.
    Decoding the pack reproduces the fake-quantization output
    bit-for-bit (``tests/test_mixed_gemm.py``).

    Only 'block' partitioning maps onto the GEMM tiling; other
    partition kinds must keep the fake-quantization path.

    Sub-tensor recipes (sub2/sub3/sub4) are *one pass*: the fused
    selection kernel emits the payload lanes, tags and GAM scales
    directly (``kops.quantize_pack``), so on the pallas backend the
    whole event is a single ``tpu_custom_call`` with no operand-sized
    XLA packing pass. The one-format recipes ('tensor', 'e4m3') keep
    the select-then-pack lowering: the tensor-level accept/reject is a
    *global* reduction over every block's error, which no single
    in-register block pass can decide.

    Under ``policy.mesh_axes`` (inside shard_map) the pack receives the
    allreduced group amax, so a shard packs exactly the payload bytes,
    tags and GAM scales its blocks would get on one device.

    >>> import jax.numpy as jnp
    >>> from repro.core.mor import quantize_for_gemm
    >>> from repro.core.policy import MoRPolicy
    >>> x = jnp.ones((128, 128), jnp.bfloat16)
    >>> mo, stats = quantize_for_gemm(x, MoRPolicy(recipe="sub3"))
    >>> mo.payload_q.shape, str(mo.payload_q.dtype), mo.tags.shape
    ((128, 128), 'uint8', (1, 1))
    >>> bool((mo.dequant() == x).all())   # decodes bit-for-bit
    True
    """
    if not policy.enabled:
        part = Partition("block", policy.block_shape)
        return (
            _kref.passthrough_mixed(x2d, part.resolve(x2d.shape)),
            _off_stats(x2d, policy.mesh_axes),
        )
    if policy.partition != "block":
        raise ValueError(
            "quantize_for_gemm requires partition='block' (got "
            f"{policy.partition!r}); channel/subchannel/tensor scales "
            "do not tile a block GEMM -- use the fake-quant path"
        )
    part = partition_of(policy)
    block = part.resolve(x2d.shape)
    if policy.recipe == "sub4" and not _kref.nvfp4_block_capable(block):
        raise ValueError(
            f"sub4 packing needs an even-row, 16-divisible-column "
            f"block; policy block_shape {policy.block_shape} resolved "
            f"to {block} for operand {tuple(x2d.shape)}"
        )
    if policy.recipe in ("sub2", "sub3", "sub4"):
        # One fused pass: selection + payload emission in the same
        # kernel (bit-identical to the two-pass select + pack_mixed
        # oracle; tests/test_quantize_pack.py).
        mo, r = kops.quantize_pack(
            x2d, part, mode=policy.recipe, algo=policy.algo,
            backend=policy.backend, mesh_axes=policy.mesh_axes,
        )
        return mo, _sub_tensor_stats(r, policy, x2d.size)
    _, stats, tags = _decide(x2d, policy)
    # The decision path's group amax -- already allreduced under
    # mesh_axes -- so the pack's Alg. 1 scales can never disagree with
    # the decisions in `tags`.
    mo = _kref.pack_mixed(
        x2d, tags, block, policy.algo,
        group_amax=stats[STAT_AMAX],
        with_nvfp4=(policy.recipe == "sub4"),
    )
    return mo, stats
