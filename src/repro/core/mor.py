"""The MoR framework (Algorithm 2) and the paper's concrete recipes.

Entry point: :func:`mor_quantize` -- fake-quantize a 2-D operand view under a
:class:`~repro.core.policy.MoRPolicy`, returning the (possibly passthrough)
tensor plus a fixed-size stats vector. Everything is functional and jittable:
dynamic decisions are data-dependent *selects*, exactly matching the paper's
fake-quantization workflow (Fig. 4) where both representations exist
transiently and one is chosen from live numerics.

Stats vector layout (f32, STATS_WIDTH):
  [0] decision        1.0 if the preferred low-precision type was accepted
                      (tensor-level), or fraction of blocks in E4M3 (sub-*).
  [1] rel_err         global mean relative error of the E4M3 candidate.
  [2] amax            group (tensor) absolute maximum.
  [3] frac_e4m3       fraction of blocks quantized to E4M3.
  [4] frac_e5m2       fraction of blocks quantized to E5M2 (sub3 only).
  [5] frac_bf16       fraction of blocks left in BF16.
  [6] nonzero_frac    fraction of non-zero elements.
  [7] group_mantissa  m_g of the GAM scale.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from .formats import E4M3, E5M2, FormatSpec, cast_to_format
from .gam import GamScales, compute_scales, scales_from_bmax
from .metrics import (
    E5M2_RANGE_RATIO,
    block_dynamic_range_ok,
    block_relative_error_sums,
    relative_error,
)
from .partition import Partition, from_blocks, to_blocks
from .policy import MoRPolicy

__all__ = [
    "STATS_WIDTH",
    "quant_dequant",
    "quant_dequant_with_scales",
    "mor_quantize",
    "partition_of",
]

STATS_WIDTH = 8


def partition_of(policy: MoRPolicy) -> Partition:
    return Partition(
        kind=policy.partition, block_shape=policy.block_shape, sub=policy.sub
    )


def quant_dequant_with_scales(
    x2d: jnp.ndarray, part: Partition, fmt: FormatSpec, scales: GamScales
) -> jnp.ndarray:
    """Fake-quantize with precomputed per-block scales. Returns f32 (M, K)."""
    xb = to_blocks(x2d.astype(jnp.float32), part)
    s = scales.scale[:, :, None, None]
    xq = cast_to_format(xb * s, fmt) / s
    return from_blocks(xq, x2d.shape)


def quant_dequant(
    x2d: jnp.ndarray, part: Partition, fmt: FormatSpec, algo: str = "gam"
) -> Tuple[jnp.ndarray, GamScales]:
    """GAM-scale + fake-quantize. Returns (f32 (M,K), scales)."""
    scales = compute_scales(x2d, part, fmt, algo=algo)
    return quant_dequant_with_scales(x2d, part, fmt, scales), scales


def _stats(
    decision, rel_err, amax, f_e4, f_e5, f_bf, nz_frac, m_g
) -> jnp.ndarray:
    return jnp.stack(
        [
            jnp.float32(decision),
            jnp.float32(rel_err),
            jnp.float32(amax),
            jnp.float32(f_e4),
            jnp.float32(f_e5),
            jnp.float32(f_bf),
            jnp.float32(nz_frac),
            jnp.float32(m_g),
        ]
    )


def _fused_quant_err(xb: jnp.ndarray, fmt: FormatSpec, algo: str):
    """Single-pass quantize + per-block error sums on a blocked view.

    xb: (nm, nk, bm, bk) in its *original* dtype (bf16 in training -- the
    paper's Fig. 4 pipeline is BF16-in/BF16-out, so large intermediates
    never materialize in f32; per-block scale math runs in f32 on the tiny
    (nm, nk) arrays). Returns (xqb in xb.dtype, scales, err_sums, counts).
    This is the XLA analogue of the fused gam_quant Pallas kernel and the
    subject of §Perf iterations 1-2.
    """
    bmax = jnp.max(jnp.abs(xb), axis=(2, 3)).astype(jnp.float32)
    scales = scales_from_bmax(bmax, fmt, algo)
    s = scales.scale[:, :, None, None]
    xqb_f32 = cast_to_format(xb.astype(jnp.float32) * s, fmt) / s
    xqb = xqb_f32.astype(xb.dtype)  # Fig. 4: output stays BF16
    xf = xb.astype(jnp.float32)
    nz = xf != 0.0
    err = jnp.where(
        nz,
        jnp.abs((xf - xqb.astype(jnp.float32)) / jnp.where(nz, xf, 1.0)),
        0.0,
    )
    return xqb, scales, jnp.sum(err, (2, 3)), jnp.sum(nz, (2, 3))


def _tensor_level(x2d: jnp.ndarray, policy: MoRPolicy):
    """Tensor-level MoR [E4M3, BF16] (paper §3.1).

    The quantization uses the policy's partitioning for scales, but the
    accept/reject decision is a single global one: per-partition local
    errors aggregated globally (Fig. 2) vs the Eq. 2 threshold.
    """
    part = partition_of(policy)
    xb = to_blocks(x2d, part)
    xqb, scales, err_sums, counts = _fused_quant_err(xb, E4M3, policy.algo)
    n = jnp.maximum(jnp.sum(counts.astype(jnp.float32)), 1.0)
    err = jnp.sum(err_sums) / n
    ok = err < policy.threshold
    y = from_blocks(jnp.where(ok, xqb, xb), x2d.shape)
    okf = ok.astype(jnp.float32)
    nz = jnp.sum(counts) / jnp.float32(x2d.size)
    stats = _stats(
        okf, err, scales.group_amax, okf, 0.0, 1.0 - okf, nz,
        scales.group_mantissa,
    )
    return y, stats


def _sub_tensor(x2d: jnp.ndarray, policy: MoRPolicy):
    """Sub-tensor MoR (paper §3.2): two-way or three-way per-block choice."""
    part = partition_of(policy)
    xb = to_blocks(x2d, part)

    q4b, scales4, e4_sum, n = _fused_quant_err(xb, E4M3, policy.algo)
    q5b, _, e5_sum, _ = _fused_quant_err(xb, E5M2, policy.algo)

    m1 = e4_sum < e5_sum  # Eq. 3: E4M3 beats E5M2 on total rel-err.

    nblocks = jnp.float32(m1.size)
    nz = jnp.sum(n) / jnp.float32(x2d.size)
    tot_n = jnp.maximum(jnp.sum(n.astype(jnp.float32)), 1.0)
    global_e4_err = jnp.sum(e4_sum) / tot_n
    m1b = m1[:, :, None, None]

    if policy.recipe == "sub2":
        # Two-way: E4M3 if it beats the E5M2 *benchmark*, else straight BF16.
        y = from_blocks(jnp.where(m1b, q4b, xb), x2d.shape)
        f4 = jnp.sum(m1) / nblocks
        stats = _stats(
            f4, global_e4_err, scales4.group_amax, f4, 0.0, 1.0 - f4, nz,
            scales4.group_mantissa,
        )
        return y, stats

    # Three-way: E4M3 -> E5M2 (Eq. 4 dynamic-range gate) -> BF16.
    xabs = jnp.abs(xb)
    anynz = n > 0
    bmax = jnp.max(xabs, axis=(2, 3)).astype(jnp.float32)
    big = jnp.asarray(jnp.finfo(xb.dtype).max, xb.dtype)
    bmin = jnp.min(jnp.where(xb != 0, xabs, big), axis=(2, 3)).astype(
        jnp.float32
    )
    ratio = jnp.where(anynz, bmax / jnp.where(anynz, bmin, 1.0), 1.0)
    m2 = ratio < E5M2_RANGE_RATIO
    use5 = jnp.logical_and(jnp.logical_not(m1), m2)
    y = from_blocks(
        jnp.where(m1b, q4b, jnp.where(use5[:, :, None, None], q5b, xb)),
        x2d.shape,
    )
    f4 = jnp.sum(m1) / nblocks
    f5 = jnp.sum(use5) / nblocks
    stats = _stats(
        f4, global_e4_err, scales4.group_amax, f4, f5, 1.0 - f4 - f5, nz,
        scales4.group_mantissa,
    )
    return y, stats


def _static_e4m3(x2d: jnp.ndarray, policy: MoRPolicy):
    part = partition_of(policy)
    xb = to_blocks(x2d, part)
    xqb, scales, err_sums, counts = _fused_quant_err(xb, E4M3, policy.algo)
    n = jnp.maximum(jnp.sum(counts.astype(jnp.float32)), 1.0)
    err = jnp.sum(err_sums) / n
    nz = jnp.sum(counts) / jnp.float32(x2d.size)
    stats = _stats(1.0, err, scales.group_amax, 1.0, 0.0, 0.0, nz,
                   scales.group_mantissa)
    return from_blocks(xqb, x2d.shape), stats


def mor_quantize(
    x2d: jnp.ndarray, policy: MoRPolicy
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fake-quantize one 2-D operand view under ``policy``.

    Returns ``(y, stats)`` where ``y`` has x2d's dtype and shape and
    ``stats`` is the STATS_WIDTH f32 vector documented in the module
    docstring. Contraction axis must be the last axis of ``x2d``.
    """
    if not policy.enabled:
        nz = jnp.mean((x2d != 0).astype(jnp.float32))
        amax = jnp.max(jnp.abs(x2d.astype(jnp.float32)))
        return x2d, _stats(0.0, 0.0, amax, 0.0, 0.0, 1.0, nz, 1.0)

    if policy.recipe == "tensor":
        y, stats = _tensor_level(x2d, policy)
    elif policy.recipe in ("sub2", "sub3"):
        y, stats = _sub_tensor(x2d, policy)
    elif policy.recipe == "e4m3":
        y, stats = _static_e4m3(x2d, policy)
    else:
        raise ValueError(f"unknown recipe: {policy.recipe}")
    return y.astype(x2d.dtype), stats
