"""Acceptance metrics for the MoR framework (paper Eqs. 1-4).

All metrics are computed over *non-zero* elements of the original tensor
(zero quantizes exactly and would otherwise dilute relative error; zero
padding introduced by blocking is excluded for the same reason).
"""
from __future__ import annotations

import jax.numpy as jnp

from .partition import Partition, to_blocks

__all__ = [
    "relative_error",
    "block_relative_error_sums",
    "block_dynamic_range_ok",
    "E5M2_RANGE_RATIO",
    "NVFP4_RANGE_RATIO",
]

# Eq. 4: max-representable(E5M2) / min-normal(E5M2) = 57344 / 2^-14.
E5M2_RANGE_RATIO = 57344.0 / 2.0**-14

# Eq. 4 analog for the NVFP4 candidate of the sub4 recipe, tuned to
# the *two-level* structure: the gated quantity is the block amax over
# the smallest non-zero micro-group amax (not the element minimum --
# intra-group fidelity is what the Eq. 3 error sums already measure,
# and E2M1's 4-binade payload only ever sees one micro group). A block
# is NVFP4-representable iff every micro-group's scale fits E4M3's
# finite span (448 / 2^-9) with E2M1's subnormal headroom (6 / 0.5)
# on top; past this ratio micro scales flush and the block degrades
# the way out-of-range E5M2 does in the paper's Eq. 4, so it falls
# through to the fp8 cascade. docs/numerics.md#nvfp4 derives this.
NVFP4_RANGE_RATIO = (6.0 / 0.5) * (448.0 / 2.0**-9)


def relative_error(x: jnp.ndarray, xq: jnp.ndarray) -> jnp.ndarray:
    """Mean relative quantization error over non-zero elements (Eqs. 1-2).

    Returns a scalar f32. Defined as 0 for an all-zero tensor.
    """
    x = x.astype(jnp.float32)
    xq = xq.astype(jnp.float32)
    nz = x != 0
    n = jnp.sum(nz)
    err = jnp.where(nz, jnp.abs((x - xq) / jnp.where(nz, x, 1.0)), 0.0)
    return jnp.where(n > 0, jnp.sum(err) / jnp.maximum(n, 1), 0.0)


def block_relative_error_sums(
    x2d: jnp.ndarray, xq2d: jnp.ndarray, part: Partition
):
    """Per-block (sum of relative errors over non-zero elems, non-zero count).

    Used both for the sub-tensor metrics (Eq. 3 compares *total* per-block
    error sums) and to aggregate the global tensor-level error of Eq. 2
    (global_err = sum(err_sums) / sum(counts)) -- this is how tensor-level
    MoR composes the per-partition local errors (Fig. 2).
    """
    xb = to_blocks(x2d.astype(jnp.float32), part)
    xqb = to_blocks(xq2d.astype(jnp.float32), part)
    nz = xb != 0
    err = jnp.where(nz, jnp.abs((xb - xqb) / jnp.where(nz, xb, 1.0)), 0.0)
    return jnp.sum(err, axis=(2, 3)), jnp.sum(nz, axis=(2, 3))


def block_dynamic_range_ok(x2d: jnp.ndarray, part: Partition) -> jnp.ndarray:
    """Eq. 4: per-block max(abs)/min(abs over non-zeros) < E5M2 normal range.

    Blocks with <= 1 distinct non-zero magnitude trivially pass.
    Returns (nm, nk) bool.
    """
    xb = jnp.abs(to_blocks(x2d.astype(jnp.float32), part))
    nz = xb != 0
    bmax = jnp.max(xb, axis=(2, 3))
    big = jnp.float32(jnp.finfo(jnp.float32).max)
    bmin = jnp.min(jnp.where(nz, xb, big), axis=(2, 3))
    any_nz = jnp.any(nz, axis=(2, 3))
    ratio = jnp.where(any_nz, bmax / jnp.where(any_nz, bmin, 1.0), 1.0)
    return ratio < E5M2_RANGE_RATIO
