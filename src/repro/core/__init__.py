"""MoR core: GAM scaling (Alg. 1) + Mixture-of-Representations (Alg. 2)."""
from .formats import (
    BF16,
    E4M3,
    E5M2,
    FORMATS,
    NVFP4,
    NVFP4_MICRO,
    FormatSpec,
    cast_to_format,
    cast_to_nvfp4,
)
from .gam import GamScales, compute_scales, split_mantissa_exponent
from .linear import N_BWD_EVENTS, N_FWD_EVENTS, mor_dot, new_token
from .metrics import (
    block_dynamic_range_ok,
    block_relative_error_sums,
    relative_error,
)
from .mor import (
    EVENT_GEMM,
    EVENT_GRAD,
    EVENT_MOMENT_M,
    EVENT_MOMENT_V,
    STAT_AMAX,
    STAT_DECISION,
    STAT_EVENT_KIND,
    STAT_FRAC_BF16,
    STAT_FRAC_E4M3,
    STAT_FRAC_E5M2,
    STAT_FRAC_NVFP4,
    STAT_GROUP_MANTISSA,
    STAT_MICRO_SCALE_BPE,
    STAT_NONZERO_FRAC,
    STAT_PAYLOAD_BPE,
    STAT_REL_ERR,
    STATS_WIDTH,
    mor_quantize,
    partition_of,
    quant_dequant,
    quantize_for_gemm,
)
from .partition import (
    PER_BLOCK_64,
    PER_BLOCK_128,
    PER_CHANNEL,
    PER_TENSOR,
    SUB_CHANNEL_128,
    Partition,
    block_amax,
)
from .collectives import compat_shard_map, pmax_over, psum_over
from .policy import (
    BF16_BASELINE,
    SUBTENSOR2_MOR,
    SUBTENSOR3_MOR,
    SUBTENSOR4_MOR,
    TENSOR_MOR,
    MoRDotPolicy,
    MoRPolicy,
    paper_default,
    with_mesh_axes,
)
from .stats import MoRStatsTracker, RelErrHistogram

__all__ = [
    "BF16", "E4M3", "E5M2", "FORMATS", "NVFP4", "NVFP4_MICRO",
    "FormatSpec", "cast_to_format", "cast_to_nvfp4",
    "GamScales", "compute_scales", "split_mantissa_exponent",
    "N_BWD_EVENTS", "N_FWD_EVENTS", "mor_dot", "new_token",
    "block_dynamic_range_ok", "block_relative_error_sums", "relative_error",
    "STATS_WIDTH", "mor_quantize", "partition_of", "quant_dequant",
    "quantize_for_gemm",
    "STAT_DECISION", "STAT_REL_ERR", "STAT_AMAX", "STAT_FRAC_E4M3",
    "STAT_FRAC_E5M2", "STAT_FRAC_BF16", "STAT_NONZERO_FRAC",
    "STAT_GROUP_MANTISSA", "STAT_FRAC_NVFP4", "STAT_MICRO_SCALE_BPE",
    "STAT_EVENT_KIND", "STAT_PAYLOAD_BPE",
    "EVENT_GEMM", "EVENT_GRAD", "EVENT_MOMENT_M", "EVENT_MOMENT_V",
    "PER_BLOCK_64", "PER_BLOCK_128", "PER_CHANNEL", "PER_TENSOR",
    "SUB_CHANNEL_128", "Partition", "block_amax",
    "BF16_BASELINE", "SUBTENSOR2_MOR", "SUBTENSOR3_MOR", "SUBTENSOR4_MOR",
    "TENSOR_MOR", "MoRDotPolicy", "MoRPolicy", "paper_default",
    "with_mesh_axes",
    "compat_shard_map", "pmax_over", "psum_over",
    "MoRStatsTracker", "RelErrHistogram",
]
