"""Axis-gated collectives for mesh-aware MoR statistics.

The MoR decision metrics (group amax, Eq. 3 error sums, the Eq. 2
global accept ratio, the stats-vector fractions) are *tensor-global*
quantities. When a quantization event runs inside ``shard_map`` each
device only sees its shard, so every global aggregate must be
allreduced over the sharded mesh axes before any decision consumes it
-- otherwise per-shard recipes silently diverge from the single-device
choice (see docs/sharding.md).

``MoRPolicy.mesh_axes`` names those axes; these helpers are no-ops when
the tuple is empty, so the single-device path is byte-for-byte the
pre-mesh code.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "psum_over", "pmax_over", "global_size", "all_gather_over",
    "compat_shard_map",
]


def compat_shard_map(f, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (with replication checks off:
    MoR bodies produce device-invariant stats via explicit psums, which
    the static replication checker cannot see through)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )


def psum_over(x: jnp.ndarray, axes: Sequence[str]) -> jnp.ndarray:
    """lax.psum over ``axes`` when non-empty, identity otherwise."""
    return jax.lax.psum(x, tuple(axes)) if axes else x


def pmax_over(x: jnp.ndarray, axes: Sequence[str]) -> jnp.ndarray:
    """lax.pmax over ``axes`` when non-empty, identity otherwise."""
    return jax.lax.pmax(x, tuple(axes)) if axes else x


def all_gather_over(x: jnp.ndarray, axis: str | None) -> jnp.ndarray:
    """lax.all_gather over ``axis`` when named, else the degenerate
    single-participant stack ``x[None]`` -- so a collective body (e.g.
    the MoR-payload pod psum in :mod:`repro.optim.compress`) lowers
    unchanged on a single-pod mesh or entirely outside shard_map."""
    if axis is None:
        return x[None]
    return jax.lax.all_gather(x, axis)


def global_size(local_size: int, axes: Sequence[str]) -> jnp.ndarray:
    """Global element count of a sharded operand (psum of the local
    count). For a *replicated* operand this over-counts by the axis
    product -- harmless for MoR because every consumer is a ratio of
    two psums (see docs/sharding.md, 'replication safety')."""
    return psum_over(jnp.float32(local_size), axes)
