"""MoR recipe / policy configuration.

A :class:`MoRPolicy` describes *how* one quantization event behaves
(recipe, partitioning, scaling algorithm, threshold) and a
:class:`MoRDotPolicy` bundles the per-operand policies of one GEMM
(activation / weight / gradient roles), mirroring the paper's setup where
MoR is applied to act, weight and grad tensors (and their transposes) of
the four linear layers per transformer block.

Everything is a frozen dataclass so policies can ride through
``jax.custom_vjp`` nondiff args and ``jax.jit`` static args.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["MoRPolicy", "MoRDotPolicy", "TENSOR_MOR", "SUBTENSOR2_MOR",
           "SUBTENSOR3_MOR", "BF16_BASELINE", "paper_default"]


@dataclasses.dataclass(frozen=True)
class MoRPolicy:
    """Policy for one quantization event (one tensor view).

    recipe:
      'off'      -- passthrough (BF16 baseline).
      'tensor'   -- tensor-level MoR [E4M3, BF16] with threshold (Eq. 2).
      'sub2'     -- sub-tensor two-way  [E4M3, BF16]        (Eq. 3 gate).
      'sub3'     -- sub-tensor three-way [E4M3, E5M2, BF16] (Eq. 3 + Eq. 4).
      'e4m3'     -- always-quantize static recipe (no dynamic decision);
                    useful as the non-MoR FP8 baseline.
    partition: 'tensor' | 'block' | 'channel' | 'subchannel'
    backend: 'auto' | 'pallas' | 'interpret' | 'xla' -- which lowering the
      quantization events of this policy use (see repro.kernels.ops;
      'auto' resolves to the Pallas kernels on TPU, interpret mode under
      REPRO_KERNEL_INTERPRET=1, and the XLA reference otherwise).
    """

    recipe: str = "tensor"
    partition: str = "block"
    block_shape: Tuple[int, int] = (128, 128)
    sub: int = 128
    threshold: float = 0.045  # th_E4M3, paper default 4.5%
    algo: str = "gam"  # 'gam' | 'e8m0' | 'fp32_amax'
    backend: str = "auto"  # 'auto' | 'pallas' | 'interpret' | 'xla'

    @property
    def enabled(self) -> bool:
        return self.recipe != "off"

    def replace(self, **kw) -> "MoRPolicy":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class MoRDotPolicy:
    """Per-operand policies for one mor_dot GEMM (fwd + both bwd GEMMs)."""

    act: MoRPolicy = MoRPolicy()
    weight: MoRPolicy = MoRPolicy()
    grad: MoRPolicy = MoRPolicy()
    # When False the bwd GEMMs run unquantized (ablation hook).
    quantize_bwd: bool = True
    # Route all three GEMMs (fwd, dgrad, wgrad) through the
    # mixed-representation block GEMM kernel (repro.kernels.mixed_gemm):
    # real uint8 fp8 payloads + per-block tags/scales consumed directly
    # by the matmul, instead of dequantize-then-bf16-dot. Requires every
    # enabled operand policy to use square 'block' partitioning with one
    # shared block shape.
    fuse_gemm: bool = False
    # Beyond-paper: reuse cached decisions/scales for K steps (0 = paper
    # behaviour, recompute metrics every micro-batch).
    decision_cache_steps: int = 0

    @property
    def enabled(self) -> bool:
        return self.act.enabled or self.weight.enabled or self.grad.enabled

    def replace(self, **kw) -> "MoRDotPolicy":
        return dataclasses.replace(self, **kw)


def paper_default(
    recipe: str = "tensor",
    partition: str = "block",
    block_shape: Tuple[int, int] = (128, 128),
    threshold: float = 0.045,
    algo: str = "gam",
) -> MoRDotPolicy:
    p = MoRPolicy(
        recipe=recipe,
        partition=partition,
        block_shape=block_shape,
        threshold=threshold,
        algo=algo,
    )
    return MoRDotPolicy(act=p, weight=p, grad=p)


TENSOR_MOR = paper_default("tensor")
SUBTENSOR2_MOR = paper_default("sub2")
SUBTENSOR3_MOR = paper_default("sub3")
BF16_BASELINE = MoRDotPolicy(
    act=MoRPolicy(recipe="off"),
    weight=MoRPolicy(recipe="off"),
    grad=MoRPolicy(recipe="off"),
)
