"""MoR recipe / policy configuration.

A :class:`MoRPolicy` describes *how* one quantization event behaves
(recipe, partitioning, scaling algorithm, threshold) and a
:class:`MoRDotPolicy` bundles the per-operand policies of one GEMM
(activation / weight / gradient roles), mirroring the paper's setup where
MoR is applied to act, weight and grad tensors (and their transposes) of
the four linear layers per transformer block.

Everything is a frozen dataclass so policies can ride through
``jax.custom_vjp`` nondiff args and ``jax.jit`` static args.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["MoRPolicy", "MoRDotPolicy", "TENSOR_MOR", "SUBTENSOR2_MOR",
           "SUBTENSOR3_MOR", "SUBTENSOR4_MOR", "BF16_BASELINE",
           "paper_default", "with_mesh_axes"]


@dataclasses.dataclass(frozen=True)
class MoRPolicy:
    """Policy for one quantization event (one tensor view).

    recipe:
      'off'      -- passthrough (BF16 baseline).
      'tensor'   -- tensor-level MoR [E4M3, BF16] with threshold (Eq. 2).
      'sub2'     -- sub-tensor two-way  [E4M3, BF16]        (Eq. 3 gate).
      'sub3'     -- sub-tensor three-way [E4M3, E5M2, BF16] (Eq. 3 + Eq. 4).
      'sub4'     -- sub-tensor four-way [NVFP4, E4M3, E5M2, BF16]: the
                    paper's §5 NVFP4 outlook. A block takes the packed
                    4-bit E2M1 payload (per-16-element E4M3 micro
                    scales, two-level with the GAM block scale) when it
                    beats the E4M3 benchmark on Eq. 3 *and* passes the
                    NVFP4 dynamic-range gate; otherwise it falls
                    through the sub3 cascade. Blocks align to (2, 16)
                    (docs/numerics.md#nvfp4).
      'e4m3'     -- always-quantize static recipe (no dynamic decision);
                    useful as the non-MoR FP8 baseline.
    partition: 'tensor' | 'block' | 'channel' | 'subchannel'
    backend: 'auto' | 'pallas' | 'interpret' | 'xla' -- which lowering the
      quantization events of this policy use (see repro.kernels.ops;
      'auto' resolves to the Pallas kernels on TPU, interpret mode under
      REPRO_KERNEL_INTERPRET=1, and the XLA reference otherwise).
    mesh_axes: mesh axis names this event's operand is sharded (or
      replicated) over *inside a shard_map*. When non-empty, every
      tensor-global statistic -- group amax (hence the Alg. 1 shared
      mantissa), Eq. 2/3 error aggregates, the stats-vector fractions --
      is allreduced over these axes before any decision consumes it, so
      the per-block tags and GAM scales chosen on N devices are
      *bit-identical* to the single-device choice (docs/sharding.md;
      tests/test_sharded_mor.py). Must be () outside shard_map: the
      collectives need the axis names bound.

    Example -- a policy is a frozen, hashable value object (it rides
    through jit static args), and ``replace`` derives variants:

    >>> from repro.core.policy import MoRPolicy
    >>> p = MoRPolicy(recipe="sub3", block_shape=(64, 64))
    >>> p.enabled, p.threshold
    (True, 0.045)
    >>> p.replace(mesh_axes=("data",)).mesh_axes
    ('data',)
    >>> p == MoRPolicy(recipe="sub3", block_shape=(64, 64))
    True
    """

    recipe: str = "tensor"
    partition: str = "block"
    block_shape: Tuple[int, int] = (128, 128)
    sub: int = 128
    threshold: float = 0.045  # th_E4M3, paper default 4.5%
    algo: str = "gam"  # 'gam' | 'e8m0' | 'fp32_amax'
    backend: str = "auto"  # 'auto' | 'pallas' | 'interpret' | 'xla'
    mesh_axes: Tuple[str, ...] = ()  # shard_map axes to allreduce over

    def __post_init__(self):
        # Lists are a footgun (unhashable under jit static args).
        object.__setattr__(self, "mesh_axes", tuple(self.mesh_axes))
        object.__setattr__(self, "block_shape", tuple(self.block_shape))

    @property
    def enabled(self) -> bool:
        return self.recipe != "off"

    def replace(self, **kw) -> "MoRPolicy":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class MoRDotPolicy:
    """Per-operand policies for one mor_dot GEMM (fwd + both bwd GEMMs)."""

    act: MoRPolicy = MoRPolicy()
    weight: MoRPolicy = MoRPolicy()
    grad: MoRPolicy = MoRPolicy()
    # When False the bwd GEMMs run unquantized (ablation hook).
    quantize_bwd: bool = True
    # Route all three GEMMs (fwd, dgrad, wgrad) through the
    # mixed-representation block GEMM kernel (repro.kernels.mixed_gemm):
    # real uint8 fp8 payloads + per-block tags/scales consumed directly
    # by the matmul, instead of dequantize-then-bf16-dot. Requires every
    # enabled operand policy to use square 'block' partitioning with one
    # shared block shape.
    fuse_gemm: bool = False
    # Beyond-paper: reuse cached decisions/scales for K steps (0 = paper
    # behaviour, recompute metrics every micro-batch).
    decision_cache_steps: int = 0

    @property
    def enabled(self) -> bool:
        return self.act.enabled or self.weight.enabled or self.grad.enabled

    def replace(self, **kw) -> "MoRDotPolicy":
        return dataclasses.replace(self, **kw)


def paper_default(
    recipe: str = "tensor",
    partition: str = "block",
    block_shape: Tuple[int, int] = (128, 128),
    threshold: float = 0.045,
    algo: str = "gam",
) -> MoRDotPolicy:
    p = MoRPolicy(
        recipe=recipe,
        partition=partition,
        block_shape=block_shape,
        threshold=threshold,
        algo=algo,
    )
    return MoRDotPolicy(act=p, weight=p, grad=p)


def with_mesh_axes(
    policy: MoRDotPolicy, axes: Tuple[str, ...]
) -> MoRDotPolicy:
    """The same dot policy with every operand event allreducing its
    global statistics over ``axes`` (for bodies running inside
    ``shard_map``). Safe to apply uniformly: a *replicated* operand's
    decisions are unchanged because every decision-bearing aggregate is
    a ratio of two psums (docs/sharding.md, 'replication safety').

    >>> from repro.core.policy import SUBTENSOR3_MOR, with_mesh_axes
    >>> dp = with_mesh_axes(SUBTENSOR3_MOR, ("data",))
    >>> dp.act.mesh_axes, dp.weight.mesh_axes, dp.grad.mesh_axes
    (('data',), ('data',), ('data',))
    """
    axes = tuple(axes)
    return policy.replace(
        act=policy.act.replace(mesh_axes=axes),
        weight=policy.weight.replace(mesh_axes=axes),
        grad=policy.grad.replace(mesh_axes=axes),
    )


TENSOR_MOR = paper_default("tensor")
SUBTENSOR2_MOR = paper_default("sub2")
SUBTENSOR3_MOR = paper_default("sub3")
SUBTENSOR4_MOR = paper_default("sub4")
BF16_BASELINE = MoRDotPolicy(
    act=MoRPolicy(recipe="off"),
    weight=MoRPolicy(recipe="off"),
    grad=MoRPolicy(recipe="off"),
)
