"""mor_dot: the MoR-quantized GEMM primitive (paper §4 integration point).

Faithful to the paper's Megatron hook placement: for a linear layer
``y = x @ w`` we fake-quantize, per policy,

  forward:   Q(x) @ Q(w)                          (act + weight events)
  backward:  dx = Q(dy) @ Q(w)^T                  (grad + weight events)
             dw = Q(x^T) @ Q(dy^T)                (act^T + grad^T events)

Each quantization event sees its operand as a 2-D view whose *last* axis is
that GEMM's contraction axis, so per-channel/sub-channel partitioning is
aligned with the dot-product dimension in all three GEMMs (paper §3.1,
"based on the dot product direction").

Stats plumbing: forward stats are a normal output; backward stats leave the
VJP as the cotangent of a zero-valued ``token`` argument -- a purely
functional channel that stacks naturally under ``lax.scan`` over layers.

mor_dot returns f32-accumulated results cast back to the input dtype
(bf16 in training), matching mixed-precision GEMM semantics.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .mor import STATS_WIDTH, mor_quantize
from .policy import MoRDotPolicy

__all__ = [
    "N_FWD_EVENTS",
    "N_BWD_EVENTS",
    "new_token",
    "mor_dot",
]

N_FWD_EVENTS = 2  # x, w
N_BWD_EVENTS = 4  # dy(dgrad), w(dgrad), x^T(wgrad), dy^T(wgrad)


def new_token() -> jnp.ndarray:
    """Zero token whose cotangent carries the N_BWD_EVENTS stats rows."""
    return jnp.zeros((N_BWD_EVENTS, STATS_WIDTH), dtype=jnp.float32)


def _flat2d(x: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def mor_dot(x, w, token, policy: MoRDotPolicy):
    """y = MoR(x) @ MoR(w).  x: (..., K), w: (K, N), token: new_token().

    Returns (y: (..., N) in x.dtype, fwd_stats: (N_FWD_EVENTS, STATS_WIDTH)).
    """
    out, _ = _fwd(x, w, token, policy)
    return out


def _plain_dot(x, w):
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def _fwd(x, w, token, policy: MoRDotPolicy):
    del token
    if not policy.enabled:
        y = _plain_dot(x, w)
        fwd_stats = jnp.zeros((N_FWD_EVENTS, STATS_WIDTH), jnp.float32)
        return (y, fwd_stats), (x, w)

    x2, lead = _flat2d(x)
    # Activation event: (M, K), contraction last.
    xq, x_stats = mor_quantize(x2, policy.act)
    # Weight event for the fwd GEMM: w is (K, N), contraction first ->
    # quantize the (N, K) transposed view so channels align with the dot dim.
    wq_t, w_stats = mor_quantize(w.T, policy.weight)
    y = jnp.dot(
        xq, wq_t.T, preferred_element_type=jnp.float32
    ).astype(x.dtype)
    y = y.reshape(*lead, w.shape[1])
    fwd_stats = jnp.stack([x_stats, w_stats])
    return (y, fwd_stats), (x, w)


def _transpose_invariant(p) -> bool:
    """Quantizing the transposed view == transposing the quantized view.

    Holds exactly for per-tensor scaling and square per-block scaling
    (block amaxes/scales are permutation-invariant under block transpose);
    per-channel / sub-channel scaling is direction-dependent (paper §3.1),
    so those must re-quantize the transposes.
    """
    if p.partition == "tensor":
        return True
    if p.partition == "block" and p.block_shape[0] == p.block_shape[1]:
        return True
    return False


def _bwd(policy: MoRDotPolicy, res, cts):
    x, w = res
    dy, _dstats = cts
    dy2, _ = _flat2d(dy)
    x2, lead = _flat2d(x)

    if not (policy.enabled and policy.quantize_bwd):
        dx = jnp.dot(
            dy2, w.T, preferred_element_type=jnp.float32
        ).astype(x.dtype).reshape(x.shape)
        dw = jnp.dot(
            x2.T, dy2, preferred_element_type=jnp.float32
        ).astype(w.dtype)
        return dx, dw, jnp.zeros((N_BWD_EVENTS, STATS_WIDTH), jnp.float32)

    # dgrad GEMM: dx[m,k] = sum_n dy[m,n] * w[k,n].
    dyq, dy_stats = mor_quantize(dy2, policy.grad)          # (M, N) contr. n
    w_kn, w_stats = mor_quantize(w, policy.weight)          # (K, N) contr. n
    dx = jnp.dot(
        dyq, w_kn.T, preferred_element_type=jnp.float32
    ).astype(x.dtype).reshape(*lead, x.shape[-1])

    # wgrad GEMM: dw[k,n] = sum_m x[m,k] * dy[m,n].
    # For transpose-invariant partitions, Q(x^T) == Q(x)^T bit-exactly, so
    # re-quantizing along M re-uses the same quantized values (avoids two
    # extra full-tensor quantization passes; Perf iteration 2).
    if _transpose_invariant(policy.act) and _transpose_invariant(policy.grad):
        xTq, xT_stats = mor_quantize(x2, policy.act)
        dyTq, dyT_stats = dyq, dy_stats  # Q(dy^T) == Q(dy)^T: reuse
        dw = jax.lax.dot_general(
            xTq, dyTq, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(w.dtype)
    else:
        xTq, xT_stats = mor_quantize(x2.T, policy.act)      # (K, M) contr. m
        dyTq, dyT_stats = mor_quantize(dy2.T, policy.grad)  # (N, M) contr. m
        dw = jnp.dot(
            xTq, dyTq.T, preferred_element_type=jnp.float32
        ).astype(w.dtype)

    token_grad = jnp.stack([dy_stats, w_stats, xT_stats, dyT_stats])
    return dx, dw, token_grad


mor_dot.defvjp(_fwd, _bwd)
