"""mor_dot: the MoR-quantized GEMM primitive (paper §4 integration point).

Faithful to the paper's Megatron hook placement: for a linear layer
``y = x @ w`` we fake-quantize, per policy,

  forward:   Q(x) @ Q(w)                          (act + weight events)
  backward:  dx = Q(dy) @ Q(w)^T                  (grad + weight events)
             dw = Q(x^T) @ Q(dy^T)                (act^T + grad^T events)

Each quantization event sees its operand as a 2-D view whose *last* axis is
that GEMM's contraction axis, so per-channel/sub-channel partitioning is
aligned with the dot-product dimension in all three GEMMs (paper §3.1,
"based on the dot product direction").

Stats plumbing: forward stats are a normal output; backward stats leave the
VJP as the cotangent of a zero-valued ``token`` argument -- a purely
functional channel that stacks naturally under ``lax.scan`` over layers.

mor_dot returns f32-accumulated results cast back to the input dtype
(bf16 in training), matching mixed-precision GEMM semantics.

GEMM lowerings (``MoRDotPolicy.fuse_gemm``):

  * fake-quant (default): each event dequantizes back to BF16 and the
    three GEMMs are plain bf16 ``jnp.dot`` -- the per-block E4M3/E5M2
    decisions never reach the matmul.
  * fused: each event packs real uint8 fp8 payloads + per-block
    tags/scales (``core.mor.quantize_for_gemm``) and all three GEMMs run
    through the mixed-representation block kernel
    (``repro.kernels.mixed_gemm``) -- per-block representations are
    decoded in-register inside the matmul. Same decisions, same stats
    rows (one shared decision path), outputs within f32-accumulation
    ordering tolerance.

Serving: a weight that is already real-quantized (``serve.quantized
.QTensor``; anything exposing ``as_mixed_operand()``) is consumed
directly by the mixed kernel against a BF16-passthrough activation
pack -- no dequantize-materialize step, no grad support.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .mor import STATS_WIDTH, mor_quantize, quantize_for_gemm
from .policy import MoRDotPolicy

# Loaded after .mor so the core -> kernels import chain is already
# resolved (see the import note in core/mor.py).
from repro.kernels import ops as kops

__all__ = [
    "N_FWD_EVENTS",
    "N_BWD_EVENTS",
    "new_token",
    "mor_dot",
]

N_FWD_EVENTS = 2  # x, w
N_BWD_EVENTS = 4  # dy(dgrad), w(dgrad), x^T(wgrad), dy^T(wgrad)


def new_token() -> jnp.ndarray:
    """Zero token whose cotangent carries the N_BWD_EVENTS stats rows."""
    return jnp.zeros((N_BWD_EVENTS, STATS_WIDTH), dtype=jnp.float32)


def _flat2d(x: jnp.ndarray) -> Tuple[jnp.ndarray, Tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _is_mixed_weight(w) -> bool:
    """Real-quantized serving weight (QTensor or compatible)."""
    return hasattr(w, "as_mixed_operand")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def mor_dot(x, w, token, policy: MoRDotPolicy):
    """y = MoR(x) @ MoR(w).  x: (..., K), w: (K, N), token: new_token().

    Returns (y: (..., N) in x.dtype, fwd_stats: (N_FWD_EVENTS, STATS_WIDTH)).

    >>> import jax.numpy as jnp
    >>> from repro.core.linear import mor_dot, new_token
    >>> from repro.core.policy import SUBTENSOR3_MOR
    >>> x = jnp.ones((4, 128), jnp.bfloat16)
    >>> w = jnp.ones((128, 32), jnp.bfloat16)
    >>> y, fwd_stats = mor_dot(x, w, new_token(), SUBTENSOR3_MOR)
    >>> y.shape, fwd_stats.shape       # one stats row per fwd event
    ((4, 32), (2, 14))
    >>> float(y[0, 0])                 # ones @ ones, exact under fp8
    128.0

    The fused GEMM lowering is a policy flag, not a different API:

    >>> yf, _ = mor_dot(x, w, new_token(), SUBTENSOR3_MOR.replace(
    ...     fuse_gemm=True))
    >>> bool(jnp.allclose(yf.astype(jnp.float32), y.astype(jnp.float32)))
    True

    Mesh-sharded use (docs/sharding.md): inside a ``shard_map`` body,
    run mor_dot on the local batch shard with every operand policy
    carrying ``mesh_axes`` (``core.policy.with_mesh_axes``). The
    quantization decisions then match the single-device run
    bit-for-bit; the wgrad output is a per-shard partial that the
    caller psums over the batch axes, exactly like an unquantized dot.
    """
    out, _ = _fwd(x, w, token, policy)
    return out


def _plain_dot(x, w):
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def _check_fusable(policy: MoRDotPolicy):
    """The mixed GEMM tiles all three dots with one block grid: every
    enabled operand policy must be 'block'-partitioned with one shared
    block shape (so the contraction blocks of both operands of each
    GEMM, and of the transposed wgrad views, line up)."""
    ps = [("act", policy.act), ("weight", policy.weight)]
    if policy.quantize_bwd:
        ps.append(("grad", policy.grad))
    shapes = set()
    for name, p in ps:
        # Disabled events still pack (as BF16 passthrough) on this
        # policy's block grid, so its block_shape must agree too.
        shapes.add(tuple(p.block_shape))
        if p.enabled and p.partition != "block":
            raise ValueError(
                f"fuse_gemm=True needs partition='block' for the {name} "
                f"policy (got {p.partition!r})"
            )
    if len(shapes) > 1:
        raise ValueError(
            f"fuse_gemm=True needs one shared block_shape, got {shapes}"
        )


def _serve_fwd(x, w, policy: MoRDotPolicy):
    """Forward against a real-quantized (mixed-layout) serving weight."""
    mo = w.as_mixed_operand()  # (N, K) quantization view
    x2, lead = _flat2d(x)
    y = kops.mixed_dot(
        x2, mo, out_dtype=x.dtype, backend=policy.weight.backend
    ).reshape(*lead, w.shape[1])
    fwd_stats = jnp.zeros((N_FWD_EVENTS, STATS_WIDTH), jnp.float32)
    return (y, fwd_stats), (x, w)


def _fwd(x, w, token, policy: MoRDotPolicy):
    del token
    if _is_mixed_weight(w):
        return _serve_fwd(x, w, policy)
    if not policy.enabled:
        y = _plain_dot(x, w)
        fwd_stats = jnp.zeros((N_FWD_EVENTS, STATS_WIDTH), jnp.float32)
        return (y, fwd_stats), (x, w)

    x2, lead = _flat2d(x)
    if policy.fuse_gemm:
        _check_fusable(policy)
        # Activation event (M, K) and weight event (N, K): both packed
        # for real, contraction last; the kernel consumes the payloads.
        a_mo, x_stats = quantize_for_gemm(x2, policy.act)
        b_mo, w_stats = quantize_for_gemm(w.T, policy.weight)
        y = kops.mixed_gemm(
            a_mo, b_mo, out_dtype=x.dtype, backend=policy.act.backend
        )
    else:
        # Activation event: (M, K), contraction last.
        xq, x_stats = mor_quantize(x2, policy.act)
        # Weight event for the fwd GEMM: w is (K, N), contraction first ->
        # quantize the (N, K) transposed view so channels align with the
        # dot dim.
        wq_t, w_stats = mor_quantize(w.T, policy.weight)
        y = jnp.dot(
            xq, wq_t.T, preferred_element_type=jnp.float32
        ).astype(x.dtype)
    y = y.reshape(*lead, w.shape[1])
    fwd_stats = jnp.stack([x_stats, w_stats])
    return (y, fwd_stats), (x, w)


def _transpose_invariant(p) -> bool:
    """Quantizing the transposed view == transposing the quantized view.

    Holds exactly for per-tensor scaling and square per-block scaling
    (block amaxes/scales are permutation-invariant under block transpose);
    per-channel / sub-channel scaling is direction-dependent (paper §3.1),
    so those must re-quantize the transposes. NVFP4 (sub4) is likewise
    direction-dependent: its 1x16 micro-blocks and row-paired nibble
    packing follow the contraction axis, so sub4 events always
    re-quantize (and re-pack) the transposed views.
    """
    if p.recipe == "sub4":
        return False
    if p.partition == "tensor":
        return True
    if p.partition == "block" and p.block_shape[0] == p.block_shape[1]:
        return True
    return False


def _bwd_fused(policy: MoRDotPolicy, x2, dy2, lead, x, w):
    """dgrad + wgrad through the mixed-representation kernel, mirroring
    the fake-quant branch structure event for event (same stats rows)."""
    be = policy.grad.backend
    # dgrad GEMM: dx[m,k] = sum_n dy[m,n] * w[k,n] -- both views
    # contraction-last already.
    dy_mo, dy_stats = quantize_for_gemm(dy2, policy.grad)      # (M, N)
    w_mo, w_stats = quantize_for_gemm(w, policy.weight)        # (K, N)
    dx = kops.mixed_gemm(
        dy_mo, w_mo, out_dtype=x.dtype, backend=be
    ).reshape(*lead, x.shape[-1])

    # wgrad GEMM: dw[k,n] = sum_m x[m,k] * dy[m,n].
    if _transpose_invariant(policy.act) and _transpose_invariant(policy.grad):
        # Q(x^T) == Q(x)^T bit-exactly: pack the (M, K) view and
        # transpose the pack (tags/scales/payloads permute with the
        # blocks), reusing the dy pack outright.
        x_mo, xT_stats = quantize_for_gemm(x2, policy.act)
        dw = kops.mixed_gemm(
            x_mo.transpose(), dy_mo.transpose(),
            out_dtype=w.dtype, backend=be,
        )
        dyT_stats = dy_stats
    else:
        xT_mo, xT_stats = quantize_for_gemm(x2.T, policy.act)    # (K, M)
        dyT_mo, dyT_stats = quantize_for_gemm(dy2.T, policy.grad)  # (N, M)
        dw = kops.mixed_gemm(
            xT_mo, dyT_mo, out_dtype=w.dtype, backend=be
        )
    token_grad = jnp.stack([dy_stats, w_stats, xT_stats, dyT_stats])
    return dx, dw, token_grad


def _bwd(policy: MoRDotPolicy, res, cts):
    x, w = res
    if _is_mixed_weight(w):
        raise NotImplementedError(
            "mor_dot cannot differentiate through a real-quantized "
            "(QTensor) serving weight"
        )
    dy, _dstats = cts
    dy2, _ = _flat2d(dy)
    x2, lead = _flat2d(x)

    if not (policy.enabled and policy.quantize_bwd):
        dx = jnp.dot(
            dy2, w.T, preferred_element_type=jnp.float32
        ).astype(x.dtype).reshape(x.shape)
        dw = jnp.dot(
            x2.T, dy2, preferred_element_type=jnp.float32
        ).astype(w.dtype)
        return dx, dw, jnp.zeros((N_BWD_EVENTS, STATS_WIDTH), jnp.float32)

    if policy.fuse_gemm:
        _check_fusable(policy)
        return _bwd_fused(policy, x2, dy2, lead, x, w)

    # dgrad GEMM: dx[m,k] = sum_n dy[m,n] * w[k,n].
    dyq, dy_stats = mor_quantize(dy2, policy.grad)          # (M, N) contr. n
    w_kn, w_stats = mor_quantize(w, policy.weight)          # (K, N) contr. n
    dx = jnp.dot(
        dyq, w_kn.T, preferred_element_type=jnp.float32
    ).astype(x.dtype).reshape(*lead, x.shape[-1])

    # wgrad GEMM: dw[k,n] = sum_m x[m,k] * dy[m,n].
    # For transpose-invariant partitions, Q(x^T) == Q(x)^T bit-exactly, so
    # re-quantizing along M re-uses the same quantized values (avoids two
    # extra full-tensor quantization passes; Perf iteration 2).
    if _transpose_invariant(policy.act) and _transpose_invariant(policy.grad):
        xTq, xT_stats = mor_quantize(x2, policy.act)
        dyTq, dyT_stats = dyq, dy_stats  # Q(dy^T) == Q(dy)^T: reuse
        dw = jax.lax.dot_general(
            xTq, dyTq, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(w.dtype)
    else:
        xTq, xT_stats = mor_quantize(x2.T, policy.act)      # (K, M) contr. m
        dyTq, dyT_stats = mor_quantize(dy2.T, policy.grad)  # (N, M) contr. m
        dw = jnp.dot(
            xTq, dyTq.T, preferred_element_type=jnp.float32
        ).astype(w.dtype)

    token_grad = jnp.stack([dy_stats, w_stats, xT_stats, dyT_stats])
    return dx, dw, token_grad


mor_dot.defvjp(_fwd, _bwd)
