"""StableHLO / HLO structural-rule primitives.

Single source of truth for the cross-lowering helpers that used to be
copy-pasted between ``benchmarks/bench_kernels.py``,
``tests/test_quantize_pack.py``, ``tests/test_nvfp4.py`` and
``tests/test_mixed_gemm.py``: lowering a jitted entry point for TPU on
any host, counting fused-kernel launches, counting operand-sized XLA
passes, and scanning for forbidden op families (f64 arithmetic,
operand-sized convert/pad/bitcast packing passes, host transfers).

The contract registry (:mod:`repro.analysis.contracts`) evaluates its
declarative rules with these primitives; benches and tests import the
same functions so the two can never drift apart. Compiled-HLO rules
(donation aliasing on the running backend) lean on
:mod:`repro.launch.hlo_analysis` for parsing.
"""
from __future__ import annotations

import re
from typing import Callable, List, Sequence, Tuple

import jax

__all__ = [
    "CrossLoweringUnavailable",
    "tpu_lowering_text",
    "lowering_text",
    "compiled_hlo_text",
    "count_custom_calls",
    "operand_sized_ops",
    "operand_sized_packing_ops",
    "f64_lines",
    "host_transfer_lines",
    "donated_arg_count",
    "compiled_f64_instrs",
]


class CrossLoweringUnavailable(RuntimeError):
    """This jax has no cross-platform lowering API (``lowering_platforms``
    keyword): structural TPU rules cannot be evaluated on this host."""


def tpu_lowering_text(fn: Callable, *args) -> str:
    """StableHLO text of ``jit(fn)(*args)`` cross-lowered for TPU.

    Works on any host (no TPU needed): the Pallas path becomes
    ``tpu_custom_call`` ops in the text. Raises
    :class:`CrossLoweringUnavailable` on jax versions without the
    cross-platform lowering API (callers translate that into a skip or
    the ``-1`` lane-unavailable sentinel).
    """
    try:
        traced = jax.jit(fn).trace(*args)
        return traced.lower(lowering_platforms=("tpu",)).as_text()
    except TypeError as e:
        raise CrossLoweringUnavailable(
            "this jax has no cross-platform lowering API"
        ) from e


def lowering_text(fn: Callable, *args, donate_argnums=()) -> str:
    """StableHLO text on the *default* platform (donation markers --
    ``tf.aliasing_output`` -- preserved on the func signature)."""
    return (
        jax.jit(fn, donate_argnums=donate_argnums)
        .trace(*args)
        .lower()
        .as_text()
    )


def compiled_hlo_text(fn: Callable, *args, donate_argnums=()) -> str:
    """Optimized (post-fusion) HLO text on the running backend --
    the input :func:`repro.launch.hlo_analysis.parse_hlo` consumes."""
    return (
        jax.jit(fn, donate_argnums=donate_argnums)
        .lower(*args)
        .compile()
        .as_text()
    )


def count_custom_calls(txt: str) -> int:
    """Fused-kernel launches in a TPU cross-lowering."""
    return txt.count("tpu_custom_call")


TENSOR_DIMS_RE = re.compile(r"tensor<([0-9]+(?:x[0-9]+)*)x[a-z]")


def _line_max_elements(ln: str) -> int:
    best = 0
    for m in TENSOR_DIMS_RE.finditer(ln):
        p = 1
        for d in m.group(1).split("x"):
            p *= int(d)
        best = max(best, p)
    return best


def _operand_sized_lines(txt: str, shape: Tuple[int, int]) -> List[str]:
    thresh = shape[0] * shape[1] // 2
    out = []
    for ln in txt.splitlines():
        if ("=" not in ln or "custom_call" in ln or "func" in ln
                or "return" in ln):
            continue
        if _line_max_elements(ln) >= thresh:
            out.append(ln)
    return out


def operand_sized_ops(txt: str, shape: Tuple[int, int]) -> int:
    """Operand-sized op count in a TPU cross-lowering (stablehlo): how
    many non-custom-call ops still touch an operand-sized buffer -- the
    'XLA pass' count of the pallas path. Counted by element product
    (>= half the operand), so blocked 4-D views ((nm, nk, bm, bk)
    reshapes/transposes of the old packer) and the packed-nibble lane
    count too, whatever their rank."""
    return len(_operand_sized_lines(txt, shape))


# The op families a fused pack/GEMM lowering must not re-introduce at
# operand size: XLA packing passes re-blocking (`pad`), re-casting
# (`convert`) or re-interpreting (`bitcast_convert`) the whole operand
# after the kernel already emitted the payload lanes.
PACKING_OP_FAMILIES = ("convert", "pad", "bitcast_convert")


def operand_sized_packing_ops(
    txt: str,
    shape: Tuple[int, int],
    families: Sequence[str] = PACKING_OP_FAMILIES,
) -> List[str]:
    """Operand-sized lines from the forbidden packing-op families."""
    hits = []
    for ln in _operand_sized_lines(txt, shape):
        if any(f"stablehlo.{fam}" in ln for fam in families):
            hits.append(ln.strip())
    return hits


_F64_RE = re.compile(r"xf64[>x]|tensor<f64>")


def f64_lines(txt: str) -> List[str]:
    """Lines of a stablehlo lowering that touch an f64 tensor. MoR
    kernels and their callers are bf16/f32 (+ sub-byte payload lanes);
    any f64 means an accidental x64 promotion doubled a buffer."""
    return [ln.strip() for ln in txt.splitlines() if _F64_RE.search(ln)]


# Markers of host<->device traffic in a lowering: infeed/outfeed,
# send/recv, host callbacks (io_callback / pure_callback / debug
# prints) and host-placement annotations. A jitted decode step with
# any of these stalls the accelerator on the host every token.
HOST_TRANSFER_MARKERS = (
    "stablehlo.infeed",
    "stablehlo.outfeed",
    "stablehlo.send",
    "stablehlo.recv",
    "xla_python_cpu_callback",
    "xla_ffi_python",
    "host_callback",
    "annotate_device_placement",
)


def host_transfer_lines(txt: str) -> List[str]:
    """Lines of a lowering that move data between host and device."""
    return [
        ln.strip()
        for ln in txt.splitlines()
        if any(m in ln for m in HOST_TRANSFER_MARKERS)
    ]


def donated_arg_count(txt: str) -> int:
    """Number of donated (output-aliased) arguments in a lowering --
    ``tf.aliasing_output`` markers on the main func signature."""
    return txt.count("tf.aliasing_output")


def compiled_f64_instrs(hlo_text: str) -> List[str]:
    """Names of optimized-HLO instructions with an f64 result, via the
    :mod:`repro.launch.hlo_analysis` parser (post-fusion view: catches
    promotions the stablehlo text hides behind composites)."""
    from repro.launch.hlo_analysis import parse_hlo

    out = []
    for instrs in parse_hlo(hlo_text).values():
        for ins in instrs:
            if "f64[" in ins.shape:
                out.append(ins.name)
    return out
