"""Repo-convention AST lint rules.

Every rule encodes a bug class this repo actually shipped (the table in
``docs/analysis.md`` maps each rule to the PR that fixed the original):

=======  ==============================================================
MOR001   builtin ``hash()`` anywhere under ``src/`` -- str hashing is
         salted per process (PYTHONHASHSEED), so hash-derived values
         (e.g. per-tensor init seeds) differ run to run. PR 8 shipped
         and then fixed exactly this in ``models/transformer.py``
         (``zlib.crc32`` is the stable replacement).
MOR002   bare ``assert`` used for user-facing validation in non-kernel
         ``src/`` modules -- asserts vanish under ``python -O`` and
         crash with context-free tracebacks; PR 7 converted the flash
         launcher's asserts to typed ``ValueError``s after exactly
         such a crash. Kernel bodies (``src/repro/kernels/``) are
         exempt: in-kernel asserts are compile-time shape checks on
         the traced path, not user-facing validation. ``tests/`` and
         ``benchmarks/`` are out of scope entirely -- there the bare
         assert is the pytest reporting idiom.
MOR003   magic-integer indexing into a MoR stats row (``stats[11]``,
         ``row[5]``, ``stats.at[10]``) instead of the named
         ``STAT_*`` lane constants in :mod:`repro.core.mor` -- the
         STATS_WIDTH v1->v2->v3 migrations re-numbered lanes twice
         and every literal index was a silent corruption hazard.
MOR004   import-time ``jax.config.update(...)`` -- module import order
         silently decides global numerics (x64, default matmul
         precision) for every other module in the process.
MOR005   wall-clock (``time.time``/``perf_counter``/``monotonic``) or
         host RNG (``random.*``, ``np.random.*``) calls inside a
         jit-compiled function -- they execute once at trace time and
         freeze into the compiled program, so the "timestamp" or
         "random" value is a constant across every call.
MOR006   bare ``assert`` inside a Pallas *kernel body* (a function
         under ``src/repro/kernels/`` taking ``*_ref`` buffer
         parameters) -- a kernel body runs on *traced* refs, so the
         assert either fires on abstract values at trace time (a
         confusing Tracer-bool crash) or is a compile-time constant
         that never checks runtime data. Value checks belong in
         ``pl.debug_check`` (once the installed jax ships it) and
         static shape checks in the *launcher*, where MOR002's
         kernel-dir exemption already sanctions them. The complement
         of MOR002: launchers may assert, kernel bodies may not.
=======  ==============================================================

Stdlib-only on purpose: ``tools/lint_repro.py`` runs the AST pass
without jax installed. Suppression: a trailing ``# lint: allow(MORxxx)
reason`` comment on the offending line, or a central
:data:`ALLOWLIST` entry carrying the rationale (the auditable path --
prefer it for anything longer-lived than a test fixture).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "RULES",
    "ALLOWLIST",
    "AllowEntry",
    "LintViolation",
    "lint_source",
    "lint_file",
    "lint_paths",
]

RULES = {
    "MOR001": "builtin hash() is PYTHONHASHSEED-salted; use zlib.crc32",
    "MOR002": "bare assert for validation in non-kernel src module; "
              "raise a typed exception",
    "MOR003": "magic integer index into a stats row; use the STAT_* "
              "lane constants (repro.core.mor)",
    "MOR004": "import-time jax.config mutation; configure inside an "
              "entry point",
    "MOR005": "wall-clock/host-RNG call inside jitted code; it freezes "
              "at trace time",
    "MOR006": "bare assert inside a pallas kernel body; use "
              "pl.debug_check (when available) or hoist the check to "
              "the launcher",
}

# Path fragments exempt from MOR002: kernel bodies assert traced-shape
# invariants at compile time (pallas BlockSpec plumbing), which is the
# one place an assert is the right tool.
KERNEL_PATH_FRAGMENT = "repro/kernels/"

# MOR002 only covers library code: in tests/ and benchmarks/ the bare
# assert IS the reporting idiom (pytest rewrites them into rich
# failure messages). Lint fixtures with no real path ("<string>") are
# treated as library code so the rule is testable.
_MOR002_SCOPE = "src/"

_INLINE_ALLOW = "# lint: allow("


@dataclasses.dataclass(frozen=True)
class AllowEntry:
    """One audited suppression: *why* a rule does not apply somewhere.

    ``line_contains`` of ``None`` matches the whole file (rare; prefer
    a line anchor). The rationale is mandatory and shows up in
    ``--list-rules`` output so the allowlist stays reviewable.
    """

    rule: str
    path_fragment: str
    line_contains: Optional[str]
    rationale: str


ALLOWLIST: Tuple[AllowEntry, ...] = (
    # PR 7/8 post-mortem residue, kept on the books deliberately: the
    # one *known remaining* PYTHONHASHSEED sensitivity in this repo is
    # not a hash() call (MOR001 bans those outright) but a cross-trace
    # XLA reassociation coin flip -- two separately-jitted programs of
    # the same math may reassociate reductions differently depending
    # on trace-time dict ordering, so the serving-vs-sequential parity
    # test carries a 5e-3 tolerance instead of bit-equality. Pinned and
    # explained in tests/test_serve_engine.py (test_engine_matches_
    # sequential_reference's tolerance comment) and docs/analysis.md;
    # recorded here so the lint's "no seed-unstable constructs" claim
    # is honest about its scope.
    AllowEntry(
        rule="MOR001",
        path_fragment="tests/test_serve_engine.py",
        line_contains=None,
        rationale="documented PYTHONHASHSEED-dependent cross-trace XLA "
                  "reassociation tolerance (not a hash() call); see "
                  "docs/analysis.md#allowlist",
    ),
)


@dataclasses.dataclass(frozen=True)
class LintViolation:
    rule: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _is_name(node: ast.AST, name: str) -> bool:
    return isinstance(node, ast.Name) and node.id == name


def _dotted(node: ast.AST) -> str:
    """'jax.config.update' for Attribute chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _int_index(sub: ast.Subscript) -> Optional[int]:
    sl = sub.slice
    if isinstance(sl, ast.Constant) and isinstance(sl.value, int) \
            and not isinstance(sl.value, bool):
        return sl.value
    if (isinstance(sl, ast.UnaryOp) and isinstance(sl.op, ast.USub)
            and isinstance(sl.operand, ast.Constant)
            and isinstance(sl.operand.value, int)):
        return -sl.operand.value
    return None


_STATS_ROW_NAMES = ("row",)


def _looks_like_stats_row(value: ast.AST) -> bool:
    """The subscripted expression names a stats row: a terminal
    identifier containing 'stats' (``stats``, ``pm.stats``,
    ``stats.at``) or the conventional per-row loop name ``row``."""
    if isinstance(value, ast.Attribute) and value.attr == "at":
        # stats.at[10].set(...) -- look through the .at accessor.
        value = value.value
    if isinstance(value, ast.Name):
        return "stats" in value.id or value.id in _STATS_ROW_NAMES
    if isinstance(value, ast.Attribute):
        return "stats" in value.attr
    return False


_CLOCK_CALLS = {
    "time.time", "time.perf_counter", "time.monotonic", "time.time_ns",
}
_HOST_RNG_PREFIXES = ("random.", "np.random.", "numpy.random.")


def _jit_call_target(call: ast.Call) -> Optional[str]:
    """Name of the function being jitted in ``jax.jit(f, ...)`` /
    ``jit(f)`` / ``partial(jax.jit, ...)(f)`` call sites, if static."""
    dotted = _dotted(call.func)
    if dotted not in ("jax.jit", "jit"):
        return None
    if call.args and isinstance(call.args[0], ast.Name):
        return call.args[0].id
    return None


def _has_jit_decorator(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", ()):
        target = dec.func if isinstance(dec, ast.Call) else dec
        if _dotted(target) in ("jax.jit", "jit"):
            return True
        if isinstance(dec, ast.Call) and _dotted(dec.func) in (
            "functools.partial", "partial"
        ):
            if any(_dotted(a) in ("jax.jit", "jit") for a in dec.args):
                return True
    return False


def _function_depth_map(tree: ast.Module):
    """Yield (node, depth) with depth = number of enclosing defs."""
    stack: List[Tuple[ast.AST, int]] = [(tree, 0)]
    while stack:
        node, depth = stack.pop()
        yield node, depth
        bump = isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
        )
        for child in ast.iter_child_nodes(node):
            stack.append((child, depth + (1 if bump else 0)))


def _rule_hash(tree, path, out):
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_name(node.func, "hash"):
            out.append(LintViolation(
                "MOR001", path, node.lineno, RULES["MOR001"]
            ))


def _rule_bare_assert(tree, path, out):
    norm = path.replace("\\", "/")
    if KERNEL_PATH_FRAGMENT in norm:
        return
    if _MOR002_SCOPE not in norm and norm != "<string>":
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert):
            out.append(LintViolation(
                "MOR002", path, node.lineno, RULES["MOR002"]
            ))


def _rule_stats_magic_index(tree, path, out):
    for node in ast.walk(tree):
        if not isinstance(node, ast.Subscript):
            continue
        if _int_index(node) is None:
            continue
        if _looks_like_stats_row(node.value):
            out.append(LintViolation(
                "MOR003", path, node.lineno,
                RULES["MOR003"] + f" (index {_int_index(node)})",
            ))


def _rule_import_time_config(tree, path, out):
    for node, depth in _function_depth_map(tree):
        if depth > 0 or not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted.endswith("config.update") or dotted in (
            "jax.config.enable_x64", "config.enable_x64"
        ):
            out.append(LintViolation(
                "MOR004", path, node.lineno, RULES["MOR004"]
            ))


def _rule_clock_in_jit(tree, path, out):
    jitted = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            target = _jit_call_target(node)
            if target:
                jitted.add(target)
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if node.name not in jitted and not _has_jit_decorator(node):
            continue
        for inner in ast.walk(node):
            if not isinstance(inner, ast.Call):
                continue
            dotted = _dotted(inner.func)
            if dotted in _CLOCK_CALLS or any(
                dotted.startswith(p) for p in _HOST_RNG_PREFIXES
            ):
                out.append(LintViolation(
                    "MOR005", path, inner.lineno,
                    RULES["MOR005"] + f" ({dotted} in {node.name})",
                ))


def _rule_kernel_assert(tree, path, out):
    norm = path.replace("\\", "/")
    if KERNEL_PATH_FRAGMENT not in norm:
        return
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        # Kernel bodies are identified by the repo's pallas calling
        # convention: two or more `*_ref` buffer parameters (every
        # kernel body takes at least an input and an output ref;
        # launchers take arrays/policies instead).
        args = node.args
        names = [
            a.arg
            for a in (*args.posonlyargs, *args.args, *args.kwonlyargs)
        ]
        if sum(n.endswith("_ref") for n in names) < 2:
            continue
        # Walk this body only, without descending into nested defs --
        # a launcher closure defined inside a kernel body (or vice
        # versa) must be attributed to itself, not its parent.
        stack = list(ast.iter_child_nodes(node))
        while stack:
            inner = stack.pop()
            if isinstance(inner, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            if isinstance(inner, ast.Assert):
                out.append(LintViolation(
                    "MOR006", path, inner.lineno,
                    RULES["MOR006"] + f" (in kernel body {node.name})",
                ))
            stack.extend(ast.iter_child_nodes(inner))


_ALL_RULES = (
    _rule_hash,
    _rule_bare_assert,
    _rule_stats_magic_index,
    _rule_import_time_config,
    _rule_clock_in_jit,
    _rule_kernel_assert,
)


def _inline_allowed(violation: LintViolation, lines: Sequence[str]) -> bool:
    if not 1 <= violation.line <= len(lines):
        return False
    ln = lines[violation.line - 1]
    idx = ln.find(_INLINE_ALLOW)
    if idx < 0:
        return False
    return violation.rule in ln[idx + len(_INLINE_ALLOW):]


def _central_allowed(violation: LintViolation, lines: Sequence[str]) -> bool:
    path = violation.path.replace("\\", "/")
    for entry in ALLOWLIST:
        if entry.rule != violation.rule:
            continue
        if entry.path_fragment not in path:
            continue
        if entry.line_contains is None:
            return True
        if 1 <= violation.line <= len(lines) and \
                entry.line_contains in lines[violation.line - 1]:
            return True
    return False


def lint_source(src: str, path: str = "<string>") -> List[LintViolation]:
    """Run every rule over one module's source text; allowlist applied."""
    tree = ast.parse(src, filename=path)
    raw: List[LintViolation] = []
    for rule in _ALL_RULES:
        rule(tree, path, raw)
    lines = src.splitlines()
    return sorted(
        (
            v for v in raw
            if not _inline_allowed(v, lines)
            and not _central_allowed(v, lines)
        ),
        key=lambda v: (v.path, v.line, v.rule),
    )


def lint_file(path: str) -> List[LintViolation]:
    with open(path, encoding="utf-8") as f:
        return lint_source(f.read(), path)


def lint_paths(paths: Iterable[str]) -> List[LintViolation]:
    """Lint every ``.py`` file under the given files/directories."""
    import os

    out: List[LintViolation] = []
    for root in paths:
        if os.path.isfile(root):
            out.extend(lint_file(root))
            continue
        for dirpath, _dirnames, filenames in os.walk(root):
            for fname in sorted(filenames):
                if fname.endswith(".py"):
                    out.extend(lint_file(os.path.join(dirpath, fname)))
    return out
