"""Precision-flow checker: payload-lane taint analysis over jaxprs.

A :class:`~repro.kernels.ref.MixedOperand` carries six lanes whose
*bytes are not numbers*: ``payload_q`` (raw fp8 bit patterns in uint8),
``payload_nib`` (two E2M1 codes per byte), ``micro_scales`` (E4M3 bit
patterns), plus the ``tags``/``scales``/``payload_bf16`` metadata and
value lanes. Any XLA op that treats those buffers as arithmetic values
outside a sanctioned decode site is silently wrong math -- the class of
bug this checker makes statically impossible.

The walk: flatten the entry point's arguments with key paths, seed
taint on every leaf whose path names a payload lane, then interpret the
closed jaxpr abstractly --

* **structural** primitives (reshape/slice/gather/scatter/concat/...)
  move bytes without reading them: taint propagates through.
* **kernel** calls (``pallas_call`` -- the fused select/pack, the mixed
  GEMM, flash) are the sanctioned consumers: taint stops there (and,
  optionally, their uint8 *outputs* are seeded, which is how the
  producer side of a quantize_pack -> mixed_gemm chain is covered
  inside a single jaxpr).
* **higher-order** primitives (pjit/scan/while/cond/custom_vjp/remat)
  recurse with the taint mapped through their sub-jaxpr signatures
  (loop carries run to a fixpoint).
* any other **compute** primitive consuming a tainted value must come
  from a sanctioned module (``repro/kernels/``, the attention decode
  sites, the moment/QTensor decoders, the paged pool) -- judged by the
  equation's source traceback -- otherwise it is reported.

Contracts attach a taint spec per entry point
(:mod:`repro.analysis.contracts`); ``tests/test_analysis.py`` holds the
positive/negative witnesses and the end-to-end
quantize_pack -> mixed_gemm -> decode chain check.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
from jax import core as jcore
from jax import tree_util as jtu

try:  # jax internal, but stable across the versions this repo supports
    from jax._src import source_info_util as _siu
except ImportError:  # pragma: no cover - very old jax
    _siu = None

__all__ = [
    "PAYLOAD_LANE_REGEX",
    "SANCTIONED_MODULES",
    "TaintViolation",
    "TaintReport",
    "lint_payload_flow",
]

# Default taint seed: argument tree paths naming MixedOperand lanes
# (named key paths via the register_pytree_with_keys registrations of
# MixedOperand / QTensor / PackedMoment).
PAYLOAD_LANE_REGEX = (
    r"payload_q|payload_bf16|payload_nib|micro_scales|\.tags|\.scales"
)

# Source-file fragments whose equations may *consume* payload bytes:
# the kernel implementations themselves, the attention decode sites
# (``_mor_kv_values`` & co), the QTensor/moment decoders, and the paged
# pool (whose gathers/scatters are structural anyway). An equation is
# sanctioned when any frame of its traceback lands in one of these --
# i.e. the consumption happens inside, or on behalf of, a whitelisted
# decode site.
SANCTIONED_MODULES = (
    "repro/kernels/",
    "repro/models/attention.py",
    "repro/optim/moments.py",
    "repro/serve/paged.py",
    "repro/serve/quantized.py",
)

# Primitives that move bytes without interpreting them: taint flows
# through to every output. (select_n mixes whole elements; pad/copy/
# transpose relayout; gather/scatter/dynamic slices relocate.)
STRUCTURAL_PRIMS = frozenset({
    "broadcast_in_dim", "concatenate", "copy", "device_put",
    "dynamic_slice", "dynamic_update_slice", "expand_dims", "gather",
    "pad", "reshape", "rev", "scatter", "scatter-add", "select_n",
    "slice", "squeeze", "stop_gradient", "transpose",
})

# Kernel-call primitives: sanctioned consumers of payload bytes.
KERNEL_PRIMS = frozenset({"pallas_call", "tpu_custom_call", "custom_call"})

_HIGHER_ORDER = frozenset({
    "pjit", "closed_call", "core_call", "remat", "checkpoint",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "scan", "while", "cond", "shard_map", "custom_partitioning",
})


@dataclasses.dataclass(frozen=True)
class TaintViolation:
    prim: str
    lane: str
    where: str

    def render(self) -> str:
        return (
            f"payload lane {self.lane!r} consumed by `{self.prim}` "
            f"outside sanctioned modules at {self.where}"
        )


@dataclasses.dataclass
class TaintReport:
    seeded: List[str]
    violations: List[TaintViolation]
    n_eqns: int

    @property
    def ok(self) -> bool:
        return not self.violations

    def render(self) -> str:
        head = (
            f"payload-flow: {len(self.seeded)} lane(s) seeded, "
            f"{self.n_eqns} eqn(s) walked, "
            f"{len(self.violations)} violation(s)"
        )
        return "\n".join([head] + [v.render() for v in self.violations])


def _eqn_source_files(eqn) -> List[str]:
    tb = getattr(eqn.source_info, "traceback", None)
    if tb is None:
        return []
    try:
        return [f.file_name for f in tb.frames]
    except Exception:  # pragma: no cover - exotic jaxlib traceback
        return []


def _eqn_summary(eqn) -> str:
    if _siu is not None:
        try:
            return _siu.summarize(eqn.source_info)
        except Exception:  # pragma: no cover
            pass
    return "<unknown>"


def _is_sanctioned(eqn, sanctioned: Sequence[str]) -> bool:
    for fname in _eqn_source_files(eqn):
        norm = fname.replace("\\", "/")
        if any(frag in norm for frag in sanctioned):
            return True
    return False


def _sub_jaxprs(eqn):
    """(params key, ClosedJaxpr-or-Jaxpr) pairs of an equation."""
    out = []
    for key, val in eqn.params.items():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, (jcore.ClosedJaxpr, jcore.Jaxpr)):
                out.append((key, v))
    return out


def _inner(j):
    return j.jaxpr if isinstance(j, jcore.ClosedJaxpr) else j


class _Walker:
    def __init__(self, sanctioned, seed_kernel_outputs):
        self.sanctioned = tuple(sanctioned)
        self.seed_kernel_outputs = seed_kernel_outputs
        self.violations: List[TaintViolation] = []
        self.n_eqns = 0

    # -- generic recursion: map outer taint onto inner invars 1:1 ------
    def _recurse(self, jaxpr, in_labels) -> List[Optional[str]]:
        jaxpr = _inner(jaxpr)
        env: Dict[jcore.Var, str] = {}
        n = min(len(jaxpr.invars), len(in_labels))
        for v, lbl in zip(jaxpr.invars[:n], in_labels[:n]):
            if lbl:
                env[v] = lbl
        self._walk(jaxpr, env)
        return [
            env.get(v) if isinstance(v, jcore.Var) else None
            for v in jaxpr.outvars
        ]

    def _walk(self, jaxpr: jcore.Jaxpr, env: Dict[jcore.Var, str]):
        for eqn in jaxpr.eqns:
            self.n_eqns += 1
            name = eqn.primitive.name
            in_labels = [
                env.get(v) if isinstance(v, jcore.Var) else None
                for v in eqn.invars
            ]
            tainted = [lbl for lbl in in_labels if lbl]

            if name in KERNEL_PRIMS or name.endswith("custom_call"):
                # Sanctioned consumer. Optionally treat its uint8
                # outputs as freshly minted payload bytes.
                if self.seed_kernel_outputs:
                    for ov in eqn.outvars:
                        aval = getattr(ov, "aval", None)
                        if aval is not None and getattr(
                            aval, "dtype", None
                        ) is not None and str(aval.dtype) == "uint8":
                            env[ov] = f"{name}:uint8_out"
                continue

            subs = _sub_jaxprs(eqn)
            if subs and (name in _HIGHER_ORDER or not tainted):
                self._recurse_higher_order(eqn, name, in_labels, env)
                continue

            if not tainted:
                continue

            if name in STRUCTURAL_PRIMS:
                for ov in eqn.outvars:
                    env[ov] = tainted[0]
                continue

            if subs:
                self._recurse_higher_order(eqn, name, in_labels, env)
                continue

            if _is_sanctioned(eqn, self.sanctioned):
                # A whitelisted decode: outputs are real numbers again.
                continue

            self.violations.append(TaintViolation(
                prim=name, lane=tainted[0], where=_eqn_summary(eqn)
            ))

    # -- higher-order plumbing ----------------------------------------
    def _recurse_higher_order(self, eqn, name, in_labels, env):
        if name == "scan":
            out_labels = self._run_loop(
                eqn.params["jaxpr"], in_labels
            )
        elif name == "while":
            out_labels = self._run_while(eqn, in_labels)
        elif name == "cond":
            out_labels = self._run_cond(eqn, in_labels)
        else:
            # pjit / closed_call / custom_* / remat / shard_map: the
            # single sub-jaxpr's invars align with eqn.invars (custom_*
            # primitives put the primal jaxpr first; extra symbolic-
            # zero tangent args simply stay untainted).
            subs = _sub_jaxprs(eqn)
            out_labels = self._recurse(subs[0][1], in_labels)
        for ov, lbl in zip(eqn.outvars, out_labels):
            if lbl:
                env[ov] = lbl

    def _run_loop(self, jaxpr, in_labels) -> List[Optional[str]]:
        # scan: invars = [consts..., carry..., xs...]; outvars =
        # [carry..., ys...]. Taint can travel carry-out -> carry-in
        # across iterations: iterate to a fixpoint (bounded by the
        # carry length).
        labels = list(in_labels)
        n_in = len(_inner(jaxpr).invars)
        for _ in range(max(len(labels), 1)):
            out_labels = self._recurse(jaxpr, labels)
            # Feed carries back: scan's carry block sits right after
            # the consts in invars and leads outvars.
            n_carry = min(len(out_labels), n_in)
            new = list(labels)
            changed = False
            offset = n_in - len(out_labels) if n_in >= len(out_labels) \
                else 0
            for i in range(n_carry):
                j = offset + i
                if j < len(new) and out_labels[i] and not new[j]:
                    new[j] = out_labels[i]
                    changed = True
            labels = new
            if not changed:
                break
        return self._recurse(jaxpr, labels)

    def _run_while(self, eqn, in_labels) -> List[Optional[str]]:
        cn = eqn.params.get("cond_nconsts", 0)
        bn = eqn.params.get("body_nconsts", 0)
        cond_j = eqn.params["cond_jaxpr"]
        body_j = eqn.params["body_jaxpr"]
        carry = list(in_labels[cn + bn:])
        body_consts = list(in_labels[cn:cn + bn])
        self._recurse(cond_j, list(in_labels[:cn]) + carry)
        for _ in range(max(len(carry), 1)):
            out = self._recurse(body_j, body_consts + carry)
            changed = False
            for i in range(min(len(out), len(carry))):
                if out[i] and not carry[i]:
                    carry[i] = out[i]
                    changed = True
            if not changed:
                break
        return self._recurse(body_j, body_consts + carry)

    def _run_cond(self, eqn, in_labels) -> List[Optional[str]]:
        branches = eqn.params["branches"]
        operand_labels = list(in_labels[1:])  # invars[0] is the index
        merged: List[Optional[str]] = []
        for br in branches:
            out = self._recurse(br, operand_labels)
            if not merged:
                merged = list(out)
            else:
                merged = [
                    a or b for a, b in
                    zip(merged, out + [None] * len(merged))
                ]
        return merged


def lint_payload_flow(
    fn: Callable,
    args: Tuple,
    *,
    taint: str = PAYLOAD_LANE_REGEX,
    seed_kernel_outputs: bool = False,
    sanctioned: Sequence[str] = SANCTIONED_MODULES,
) -> TaintReport:
    """Trace ``fn(*args)`` to a jaxpr and lint the payload-lane flow.

    ``taint`` is a regex matched against each flattened argument's key
    path (``jax.tree_util.keystr``); matching leaves seed the taint
    set. ``seed_kernel_outputs=True`` additionally taints every uint8
    output of a kernel call, covering chains where the payload is
    *produced* inside the traced function (quantize_pack ->
    mixed_gemm). Returns a :class:`TaintReport`; ``report.ok`` is the
    pass/fail.
    """
    leaves_with_paths, treedef = jtu.tree_flatten_with_path(args)
    paths = [jtu.keystr(p) for p, _ in leaves_with_paths]
    leaves = [leaf for _, leaf in leaves_with_paths]

    def flat_fn(*flat):
        return fn(*jtu.tree_unflatten(treedef, flat))

    closed = jax.make_jaxpr(flat_fn)(*leaves)
    pat = re.compile(taint)
    env: Dict[jcore.Var, str] = {}
    seeded: List[str] = []
    for var, path in zip(closed.jaxpr.invars, paths):
        if pat.search(path):
            env[var] = path
            seeded.append(path)

    walker = _Walker(sanctioned, seed_kernel_outputs)
    walker._walk(closed.jaxpr, env)
    return TaintReport(
        seeded=seeded,
        violations=walker.violations,
        n_eqns=walker.n_eqns,
    )
