"""Precision-flow static analysis for the MoR repro.

Three layers behind one registry (docs/analysis.md):

- :mod:`repro.analysis.contracts` -- declarative structural contracts
  for the hot entry points (launch counts, forbidden op families,
  donation, accumulation dtypes), evaluated over jaxprs and lowered
  HLO. The single source of the repo's structural acceptance literals.
- :mod:`repro.analysis.jaxpr_lint` -- payload-lane taint checker: MoR
  packed lanes may only be consumed by sanctioned kernel calls and
  whitelisted decode sites.
- :mod:`repro.analysis.ast_rules` -- stdlib-only repo-convention
  linter (MOR001..MOR005), runnable without jax via
  ``tools/lint_repro.py``.

``hlo_rules`` holds the shared lowering-text helpers the tests and
benches previously each carried a private copy of.
"""
from repro.analysis import ast_rules, hlo_rules
from repro.analysis.contracts import (
    DECODE_ROW_BLOCK,
    MAX_PACK_OPS_OVER_SELECT,
    MOR_DOT_FWD_LAUNCHES,
    MOR_DOT_GRAD_LAUNCHES,
    REGISTRY,
    SINGLE_LAUNCH,
    AnalysisSummary,
    Contract,
    ContractCase,
    ContractReport,
    assert_contract,
    check,
    check_all,
    check_contract,
    engine_decode_report,
    get,
    register,
)
from repro.analysis.jaxpr_lint import (
    PAYLOAD_LANE_REGEX,
    TaintReport,
    TaintViolation,
    lint_payload_flow,
)

__all__ = [
    "ast_rules",
    "hlo_rules",
    "DECODE_ROW_BLOCK",
    "MAX_PACK_OPS_OVER_SELECT",
    "MOR_DOT_FWD_LAUNCHES",
    "MOR_DOT_GRAD_LAUNCHES",
    "REGISTRY",
    "SINGLE_LAUNCH",
    "AnalysisSummary",
    "Contract",
    "ContractCase",
    "ContractReport",
    "assert_contract",
    "check",
    "check_all",
    "check_contract",
    "engine_decode_report",
    "get",
    "register",
    "PAYLOAD_LANE_REGEX",
    "TaintReport",
    "TaintViolation",
    "lint_payload_flow",
]
