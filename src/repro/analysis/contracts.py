"""Declarative structural contracts for the hot entry points.

One registry, three consumers: ``tests/`` (the HLO-contract tests in
``test_quantize_pack.py`` / ``test_nvfp4.py`` / ``test_mixed_gemm.py``
/ ``test_serve_engine.py`` and the clean-pass suite in
``test_analysis.py``), ``benchmarks/`` (``bench_kernels.py`` /
``bench_serve.py`` assert the same pins and emit the
``kernel/analysis_contracts`` row), and CI's blocking ``lint`` job
(``tools/lint_repro.py --contracts``). The acceptance literals live
*only* here -- deleting a contract or loosening a constant breaks every
consumer at once, which is the point.

A :class:`Contract` names an entry point plus the rules it must
satisfy; :func:`check_contract` evaluates the rules with the
primitives in :mod:`repro.analysis.hlo_rules` (TPU cross-lowering
structure, forbidden op families, donation markers) and
:mod:`repro.analysis.jaxpr_lint` (payload-lane taint flow,
accumulation dtypes). Registering a new entry point is one
:func:`register` call -- see docs/analysis.md.

Cross-lowering rules degrade gracefully on jax versions without the
cross-platform lowering API: the report carries the ``-1``
lane-unavailable sentinel instead of failing (same convention as the
bench rows).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import core as jcore

from . import hlo_rules
from .jaxpr_lint import lint_payload_flow

__all__ = [
    "SINGLE_LAUNCH",
    "MAX_PACK_OPS_OVER_SELECT",
    "MOR_DOT_FWD_LAUNCHES",
    "MOR_DOT_GRAD_LAUNCHES",
    "DECODE_ROW_BLOCK",
    "ENGINE_MIN_DONATED_ARGS",
    "ContractCase",
    "Contract",
    "ContractReport",
    "AnalysisSummary",
    "REGISTRY",
    "register",
    "get",
    "check_contract",
    "check",
    "assert_contract",
    "check_all",
    "engine_decode_case",
    "engine_decode_report",
]

# ----------------------------------------------------------------------
# The acceptance literals. Every bench/test structural pin reads these;
# nothing else in the repo may restate them.
# ----------------------------------------------------------------------

# A real-quantization event (fused select+pack), a mixed block GEMM, a
# serving qdot and a flash call are each ONE tpu_custom_call.
SINGLE_LAUNCH: Tuple[int, int] = (1, 1)

# The fused pack adds ZERO operand-sized XLA ops over bare selection
# (the pre-PR-5 lowering re-blocked / re-scaled / re-cast the operand
# in XLA after the select).
MAX_PACK_OPS_OVER_SELECT = 0

# mor_dot(fuse_gemm=True) forward: 2 selection kernels + 1 GEMM; the
# two selection events share one lowered body when jax dedups nested
# jits (2), or lower separately (3). Anything else means the GEMM
# stopped being a single fused kernel.
MOR_DOT_FWD_LAUNCHES: Tuple[int, int] = (2, 3)

# Full fwd+bwd (dgrad+wgrad) of the fused mor_dot: fwd events plus the
# two grad-operand selections and two grad GEMMs, with the same
# dedup latitude (measured 5 on the pinned jax; 4..7 covers the
# dedup/no-dedup corners without letting an unfused GEMM through).
MOR_DOT_GRAD_LAUNCHES: Tuple[int, int] = (4, 7)

# Decode activations are (slots, K) with slots << 128: the skinny-M
# lane packs activation rows at the 16-row sublane tile, never padded
# toward the 128 MXU tile (PR 6's serving contract).
DECODE_ROW_BLOCK = 16

# The engine's jitted decode step donates (at least) the KV pool tree.
ENGINE_MIN_DONATED_ARGS = 1


# ----------------------------------------------------------------------
# Contract model
# ----------------------------------------------------------------------
@dataclasses.dataclass
class ContractCase:
    """A concrete (fn, args) instantiation of an entry point.

    ``fn`` may be a plain callable or an already-jitted function (the
    engine's donating step); ``operand_shape`` feeds the operand-sized
    pass counter; ``baseline_fn`` is the reference lowering for
    pack-ops-over-baseline rules (same args)."""

    fn: Callable
    args: Tuple
    operand_shape: Optional[Tuple[int, int]] = None
    baseline_fn: Optional[Callable] = None
    donate_argnums: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class Contract:
    """Declarative rules for one entry point. ``None`` disables a rule;
    every enabled rule counts toward ``rules_evaluated``."""

    name: str
    build: Callable[[], ContractCase]
    custom_calls: Optional[Tuple[int, int]] = None
    max_pack_ops_over_baseline: Optional[int] = None
    forbid_f64: bool = True
    forbid_host_transfers: bool = False
    require_f32_accum: bool = False
    min_donated_args: Optional[int] = None
    taint: Optional[str] = None          # arg-path regex to seed
    seed_kernel_outputs: bool = False
    notes: str = ""


@dataclasses.dataclass
class ContractReport:
    name: str
    violations: List[str]
    rules_evaluated: int
    counters: Dict[str, int]

    @property
    def ok(self) -> bool:
        return not self.violations

    def render(self) -> str:
        status = "ok" if self.ok else "FAIL"
        head = (
            f"{self.name}: {status} ({self.rules_evaluated} rule(s), "
            f"counters {self.counters})"
        )
        return "\n".join([head] + [f"  {v}" for v in self.violations])


@dataclasses.dataclass
class AnalysisSummary:
    contracts_checked: int
    rules_evaluated: int
    violations: List[str]
    reports: List[ContractReport]

    @property
    def ok(self) -> bool:
        return not self.violations


REGISTRY: Dict[str, Contract] = {}
_CASE_CACHE: Dict[str, ContractCase] = {}


def register(contract: Contract) -> Contract:
    if contract.name in REGISTRY:
        raise ValueError(f"duplicate contract {contract.name!r}")
    REGISTRY[contract.name] = contract
    return contract


def get(name: str) -> Contract:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no contract {name!r}; registered: {sorted(REGISTRY)}"
        ) from None


def _case_for(contract: Contract) -> ContractCase:
    case = _CASE_CACHE.get(contract.name)
    if case is None:
        case = contract.build()
        _CASE_CACHE[contract.name] = case
    return case


# ----------------------------------------------------------------------
# Rule engine
# ----------------------------------------------------------------------
def _default_lowering(case: ContractCase) -> str:
    fn = case.fn
    if hasattr(fn, "trace"):  # already jitted (donation preserved)
        return fn.trace(*case.args).lower().as_text()
    return hlo_rules.lowering_text(
        fn, *case.args, donate_argnums=case.donate_argnums
    )


def _jaxpr_of(case: ContractCase):
    return jax.make_jaxpr(case.fn)(*case.args)


def _low_precision_accum_dots(jaxpr: jcore.Jaxpr, acc: List[str]):
    """dot_general equations (recursively, pallas kernel bodies
    included) whose accumulator dtype is narrower than f32."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "dot_general":
            for ov in eqn.outvars:
                dt = getattr(getattr(ov, "aval", None), "dtype", None)
                if dt is not None and jnp.issubdtype(
                    dt, jnp.floating
                ) and jnp.finfo(dt).bits < 32:
                    acc.append(f"dot_general accumulates in {dt}")
        for val in eqn.params.values():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for v in vals:
                if isinstance(v, jcore.ClosedJaxpr):
                    _low_precision_accum_dots(v.jaxpr, acc)
                elif isinstance(v, jcore.Jaxpr):
                    _low_precision_accum_dots(v, acc)


def _jaxpr_f64(jaxpr: jcore.Jaxpr, acc: List[str]):
    for eqn in jaxpr.eqns:
        for ov in eqn.outvars:
            dt = getattr(getattr(ov, "aval", None), "dtype", None)
            if dt is not None and str(dt) == "float64":
                acc.append(f"{eqn.primitive.name} produces float64")
        for val in eqn.params.values():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for v in vals:
                if isinstance(v, jcore.ClosedJaxpr):
                    _jaxpr_f64(v.jaxpr, acc)
                elif isinstance(v, jcore.Jaxpr):
                    _jaxpr_f64(v, acc)


def check_contract(contract: Contract) -> ContractReport:
    """Evaluate every enabled rule; never raises on rule failure."""
    case = _case_for(contract)
    violations: List[str] = []
    counters: Dict[str, int] = {}
    rules = 0

    tpu_txt: Optional[str] = None
    wants_tpu = (
        contract.custom_calls is not None
        or contract.max_pack_ops_over_baseline is not None
    )
    if wants_tpu:
        try:
            tpu_txt = hlo_rules.tpu_lowering_text(case.fn, *case.args)
        except hlo_rules.CrossLoweringUnavailable:
            tpu_txt = None

    if contract.custom_calls is not None:
        rules += 1
        if tpu_txt is None:
            counters["tpu_kernel_launches"] = -1
        else:
            lo, hi = contract.custom_calls
            n = hlo_rules.count_custom_calls(tpu_txt)
            counters["tpu_kernel_launches"] = n
            if not lo <= n <= hi:
                violations.append(
                    f"custom calls: {n} outside [{lo}, {hi}]"
                )

    if contract.max_pack_ops_over_baseline is not None:
        rules += 1
        if tpu_txt is None or case.baseline_fn is None:
            counters["tpu_pack_ops"] = -1
        else:
            base_txt = hlo_rules.tpu_lowering_text(
                case.baseline_fn, *case.args
            )
            shape = case.operand_shape
            extra = (
                hlo_rules.operand_sized_ops(tpu_txt, shape)
                - hlo_rules.operand_sized_ops(base_txt, shape)
            )
            counters["tpu_pack_ops"] = max(extra, 0)
            if extra > contract.max_pack_ops_over_baseline:
                violations.append(
                    f"pack ops over baseline: {extra} > "
                    f"{contract.max_pack_ops_over_baseline}"
                )
            # Forbidden packing families must not grow either: no new
            # operand-sized convert/pad/bitcast beyond the baseline.
            new_packing = (
                len(hlo_rules.operand_sized_packing_ops(tpu_txt, shape))
                - len(hlo_rules.operand_sized_packing_ops(
                    base_txt, shape
                ))
            )
            if new_packing > 0:
                violations.append(
                    f"{new_packing} new operand-sized "
                    f"convert/pad/bitcast packing op(s) over baseline"
                )

    needs_default_lowering = (
        contract.forbid_host_transfers
        or contract.min_donated_args is not None
    )
    low_txt = _default_lowering(case) if needs_default_lowering else None

    if contract.forbid_host_transfers:
        rules += 1
        hits = hlo_rules.host_transfer_lines(low_txt)
        counters["host_transfer_ops"] = len(hits)
        if hits:
            violations.append(
                f"host transfers in lowering: {hits[:3]}"
            )

    if contract.min_donated_args is not None:
        rules += 1
        n = hlo_rules.donated_arg_count(low_txt)
        counters["donated_args"] = n
        if n < contract.min_donated_args:
            violations.append(
                f"donated args: {n} < {contract.min_donated_args} "
                "(buffer donation lost)"
            )

    closed = None
    if contract.forbid_f64 or contract.require_f32_accum:
        closed = _jaxpr_of(case)

    if contract.forbid_f64:
        rules += 1
        acc: List[str] = []
        _jaxpr_f64(closed.jaxpr, acc)
        counters["f64_ops"] = len(acc)
        if acc:
            violations.append(f"f64 in jaxpr: {acc[:3]}")

    if contract.require_f32_accum:
        rules += 1
        acc = []
        _low_precision_accum_dots(closed.jaxpr, acc)
        counters["low_precision_accum_dots"] = len(acc)
        if acc:
            violations.append(f"accumulation dtype: {acc[:3]}")

    if contract.taint is not None:
        rules += 1
        rep = lint_payload_flow(
            case.fn, case.args,
            taint=contract.taint,
            seed_kernel_outputs=contract.seed_kernel_outputs,
        )
        counters["tainted_lanes"] = len(rep.seeded)
        if not rep.ok:
            violations.extend(
                v.render() for v in rep.violations[:5]
            )

    return ContractReport(
        name=contract.name,
        violations=violations,
        rules_evaluated=rules,
        counters=counters,
    )


def check(name: str) -> ContractReport:
    return check_contract(get(name))


def assert_contract(name: str) -> ContractReport:
    """check() that raises AssertionError with the rendered report --
    the one-liner tests and benches call."""
    report = check(name)
    if not report.ok:
        raise AssertionError(report.render())
    return report


def check_all(names: Optional[Sequence[str]] = None) -> AnalysisSummary:
    """Evaluate every registered contract (the CI lint job, the
    ``kernel/analysis_contracts`` bench row and ``test_analysis.py``
    all run exactly this)."""
    reports = [check(n) for n in (names or sorted(REGISTRY))]
    return AnalysisSummary(
        contracts_checked=len(reports),
        rules_evaluated=sum(r.rules_evaluated for r in reports),
        violations=[
            f"{r.name}: {v}" for r in reports for v in r.violations
        ],
        reports=reports,
    )


# ----------------------------------------------------------------------
# Entry-point registrations
# ----------------------------------------------------------------------
# Payload taint is seeded by lane name in the flattened argument paths
# (jaxpr_lint.PAYLOAD_LANE_REGEX); pool-tree leaves are keyed by lane
# name too, so the bare-name alternatives cover dict-keyed trees.
_TAINT = (
    r"payload_q|payload_bf16|payload_nib|micro_scales"
    r"|\.tags|\.scales|\['tags'\]|\['scales'\]"
)


def _rng2d(shape, seed, dtype=jnp.bfloat16):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _quantize_pack_case(recipe: str) -> ContractCase:
    from repro.core.mor import quantize_for_gemm
    from repro.core.policy import MoRPolicy
    from repro.kernels import ops as kops

    pol = MoRPolicy(recipe=recipe, partition="block", backend="pallas")
    part = __import__(
        "repro.core.partition", fromlist=["Partition"]
    ).Partition("block", (128, 128), align=(2, 16))
    x = jnp.zeros((256, 256), jnp.bfloat16)
    return ContractCase(
        fn=lambda a: quantize_for_gemm(a, pol),
        args=(x,),
        operand_shape=(256, 256),
        baseline_fn=lambda a: kops.mor_select(
            a, part, recipe, "gam", backend="pallas"
        ).y,
    )


register(Contract(
    name="quantize_pack_sub3",
    build=lambda: _quantize_pack_case("sub3"),
    custom_calls=SINGLE_LAUNCH,
    max_pack_ops_over_baseline=MAX_PACK_OPS_OVER_SELECT,
    notes="fused one-pass selection+packing (PR 5 acceptance)",
))

register(Contract(
    name="quantize_pack_sub4",
    build=lambda: _quantize_pack_case("sub4"),
    custom_calls=SINGLE_LAUNCH,
    max_pack_ops_over_baseline=MAX_PACK_OPS_OVER_SELECT,
    notes="four-way NVFP4 fused pack stays one launch, no XLA "
          "nibble-packing pass",
))


def _mor_quantize_case() -> ContractCase:
    from repro.core import mor_quantize
    from repro.core.policy import MoRPolicy

    pol = MoRPolicy(recipe="sub4", backend="pallas")
    return ContractCase(
        fn=lambda a: mor_quantize(a, pol)[0],
        args=(_rng2d((256, 256), 14),),
        operand_shape=(256, 256),
    )


register(Contract(
    name="mor_quantize_sub4",
    build=_mor_quantize_case,
    custom_calls=SINGLE_LAUNCH,
    notes="fake-quantization event: fused four-way selection",
))


def _mixed_gemm_case() -> ContractCase:
    from repro.core.mor import quantize_for_gemm
    from repro.core.policy import MoRPolicy
    from repro.kernels import ops as kops

    pol = MoRPolicy(recipe="sub3", backend="interpret")
    amo, _ = quantize_for_gemm(_rng2d((256, 256), 0), pol)
    bmo, _ = quantize_for_gemm(_rng2d((128, 256), 1), pol)
    return ContractCase(
        fn=lambda a, b: kops.mixed_gemm(a, b, backend="pallas"),
        args=(amo, bmo),
        operand_shape=(256, 256),
    )


register(Contract(
    name="mixed_gemm",
    build=_mixed_gemm_case,
    custom_calls=SINGLE_LAUNCH,
    require_f32_accum=True,
    taint=_TAINT,
    notes="mixed-representation block GEMM: one launch, payload lanes "
          "only enter the kernel, f32 accumulation",
))


def _qdot_case(recipe: str) -> ContractCase:
    from repro.core.policy import MoRPolicy
    from repro.serve.quantized import qdot, quantize_weight

    w = _rng2d((256, 256), 15)
    qt, _ = quantize_weight(
        w, MoRPolicy(recipe=recipe, partition="block", backend="xla")
    )
    x = _rng2d((64, 256), 16)
    return ContractCase(
        fn=lambda a, q: qdot(a, q, backend="pallas"),
        args=(x, qt),
        operand_shape=(256, 256),
    )


register(Contract(
    name="qdot_sub3",
    build=lambda: _qdot_case("sub3"),
    custom_calls=SINGLE_LAUNCH,
    require_f32_accum=True,
    taint=_TAINT,
    notes="serving GEMM against a sub3 QTensor is one fused kernel",
))

register(Contract(
    name="qdot_sub4",
    build=lambda: _qdot_case("sub4"),
    custom_calls=SINGLE_LAUNCH,
    require_f32_accum=True,
    taint=_TAINT,
    notes="serving GEMM against an NVFP4 QTensor is one fused kernel",
))


def _mor_dot_policy():
    from repro.core import paper_default

    p = paper_default("sub3").replace(fuse_gemm=True)
    return p.replace(
        act=p.act.replace(backend="pallas"),
        weight=p.weight.replace(backend="pallas"),
        grad=p.grad.replace(backend="pallas"),
    )


def _mor_dot_fwd_case() -> ContractCase:
    from repro.core import mor_dot, new_token

    p = _mor_dot_policy()
    return ContractCase(
        fn=lambda a, b: mor_dot(a, b, new_token(), p)[0],
        args=(_rng2d((128, 256), 4), _rng2d((256, 128), 5)),
        operand_shape=(128, 256),
    )


register(Contract(
    name="mor_dot_fused_fwd",
    build=_mor_dot_fwd_case,
    custom_calls=MOR_DOT_FWD_LAUNCHES,
    notes="2 selection events (may dedup to one lowered body) + 1 "
          "fused GEMM",
))


def _mor_dot_grads_case() -> ContractCase:
    from repro.core import mor_dot, new_token

    p = _mor_dot_policy()

    def loss(a, b):
        return mor_dot(a, b, new_token(), p)[0].astype(
            jnp.float32
        ).sum()

    return ContractCase(
        fn=lambda a, b: jax.grad(loss, argnums=(0, 1))(a, b),
        args=(_rng2d((128, 256), 4), _rng2d((256, 128), 5)),
        operand_shape=(128, 256),
    )


register(Contract(
    name="mor_dot_fused_grads",
    build=_mor_dot_grads_case,
    custom_calls=MOR_DOT_GRAD_LAUNCHES,
    require_f32_accum=True,
    notes="dgrad+wgrad keep fused selection + fused GEMMs",
))


def _flash_case() -> ContractCase:
    from repro.kernels import ops as kops

    q = _rng2d((2, 128, 4, 64), 6)
    k = _rng2d((2, 128, 2, 64), 7)
    return ContractCase(
        fn=lambda a, b, c: kops.flash_attention(
            a, b, c, backend="pallas"
        ),
        args=(q, k, k),
        operand_shape=(2 * 4 * 128, 64),
    )


register(Contract(
    name="flash_attention",
    build=_flash_case,
    custom_calls=SINGLE_LAUNCH,
    require_f32_accum=True,
    notes="GQA flash fwd is one fused kernel with f32 accumulation",
))


def _compress_grads_case() -> ContractCase:
    from repro.core.policy import MoRPolicy
    from repro.optim.compress import compress_grads

    pol = MoRPolicy(recipe="sub3", backend="interpret")
    g = {"w": _rng2d((128, 128), 8, jnp.float32)}
    return ContractCase(
        fn=lambda grads: compress_grads(grads, "mor", policy=pol)[0],
        args=(g,),
    )


register(Contract(
    name="compress_grads_mor",
    build=_compress_grads_case,
    taint=_TAINT,
    seed_kernel_outputs=True,
    notes="gradient compression round-trip: packed bytes only decoded "
          "in sanctioned modules, no f64",
))


def _adamw_case() -> ContractCase:
    from repro.optim.adamw import AdamWConfig, adamw_update, \
        init_opt_state
    from repro.optim.moments import FP8_MOMENTS

    cfg = AdamWConfig()
    params = {"w": _rng2d((64, 64), 9)}
    moments = FP8_MOMENTS.replace(min_leaf=0)
    opt = init_opt_state(params, moments=moments)
    grads = {"w": _rng2d((64, 64), 10, jnp.float32)}
    return ContractCase(
        fn=lambda g, o: adamw_update(cfg, g, o, moments=moments)[:2],
        args=(grads, opt),
    )


register(Contract(
    name="adamw_packed_moments",
    build=_adamw_case,
    taint=_TAINT,
    seed_kernel_outputs=True,
    notes="packed Adam moments decode only in optim.moments; update "
          "math stays f64-free",
))


# ------------------------------------------------------------ engine --
def engine_decode_case(eng=None) -> ContractCase:
    """The engine's jitted batched-decode step as a contract case.

    With ``eng=None`` a tiny quantized kv_mor engine is built (reduced
    gemma-2b, 128-token vocab -- the test-suite workhorse config);
    passing a live engine lets ``tests/test_serve_engine.py`` and
    benches evaluate the same rules on *their* engine.
    """
    if eng is None:
        eng = _tiny_engine()
    slots = eng.scfg.slots
    bt = jnp.asarray(np.asarray(eng.pool.block_table, np.int32))
    toks = jnp.zeros((slots, 1), jnp.int32)
    cur = jnp.zeros((slots,), jnp.int32)
    return ContractCase(
        fn=eng._step_fn,  # jitted: donation markers intact
        args=(eng.params, eng.tokens, eng.pool.tree, bt, toks, cur),
    )


_TINY_ENGINE: List = []


def _tiny_engine():
    if _TINY_ENGINE:  # shared by the decode + prefill cases
        return _TINY_ENGINE[0]
    import dataclasses as _dc

    from repro.configs import get_config, reduced
    from repro.core import TENSOR_MOR, MoRPolicy
    from repro.models import init_params
    from repro.serve import Engine, ServeConfig

    cfg = _dc.replace(reduced(get_config("gemma-2b")), vocab=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(slots=4, max_seq=64, page_size=16, kv_mor=True)
    _TINY_ENGINE.append(Engine(
        cfg, TENSOR_MOR, params, scfg,
        quantize=MoRPolicy(recipe="sub3", backend="interpret"),
        quantize_min_size=0,
    ))
    return _TINY_ENGINE[0]


_ENGINE_CONTRACT_KW = dict(
    forbid_host_transfers=True,
    min_donated_args=ENGINE_MIN_DONATED_ARGS,
    taint=_TAINT,
    notes="jitted decode step: no host round-trips, KV pool donated, "
          "payload lanes only consumed by sanctioned decode sites",
)

register(Contract(
    name="engine_decode_step",
    build=engine_decode_case,
    **_ENGINE_CONTRACT_KW,
))


def engine_decode_report(eng) -> ContractReport:
    """Evaluate the ``engine_decode_step`` rules against a live engine
    (same Contract object, caller-supplied case)."""
    contract = get("engine_decode_step")
    case = engine_decode_case(eng)
    probe = dataclasses.replace(
        contract, name=f"engine_decode_step[{type(eng).__name__}]",
        build=lambda: case,
    )
    return check_contract(probe)


def _engine_prefill_case() -> ContractCase:
    eng = _tiny_engine()
    prompt = jnp.zeros((1, 8), jnp.int32)
    return ContractCase(
        fn=eng._prefill,
        args=(eng.params, eng.tokens, {"tokens": prompt}),
    )


register(Contract(
    name="engine_prefill",
    build=_engine_prefill_case,
    forbid_host_transfers=True,
    taint=_TAINT,
    notes="jitted prefill: no host round-trips; quantized weights' "
          "payload lanes stay in sanctioned consumers",
))


# ------------------------------------------------------------ robust --
def _robust_guard_case() -> ContractCase:
    """The PR-10 guard acceptance: a full real-quantization event with
    the layout-v4 guard lanes *consumed* (stats returned alongside the
    pack) lowers with zero operand-sized XLA ops beyond the bare fused
    selection -- nonfinite detection rides the amax / per-block error
    sums the event already computes, so the clean path's structure is
    byte-for-byte the PR-5 one-pass contract."""
    from repro.core.mor import quantize_for_gemm
    from repro.core.partition import Partition
    from repro.core.policy import MoRPolicy
    from repro.kernels import ops as kops

    pol = MoRPolicy(recipe="sub3", partition="block", backend="pallas")
    part = Partition("block", (128, 128))
    x = jnp.zeros((256, 256), jnp.bfloat16)
    return ContractCase(
        fn=lambda a: quantize_for_gemm(a, pol),
        args=(x,),
        operand_shape=(256, 256),
        baseline_fn=lambda a: kops.mor_select(
            a, part, "sub3", "gam", backend="pallas"
        ).y,
    )


register(Contract(
    name="robust_guard_event",
    build=_robust_guard_case,
    custom_calls=SINGLE_LAUNCH,
    max_pack_ops_over_baseline=MAX_PACK_OPS_OVER_SELECT,
    taint=_TAINT,
    notes="stats-v4 guard lanes (guard_flags/fallback_count) cost zero "
          "operand-sized passes on the clean path (docs/robustness.md)",
))


def _train_step_case() -> ContractCase:
    """The *whole* training step -- loss, grads, MoR gradient
    compression, packed-moment AdamW -- as one taint case: every MoR
    payload lane born anywhere in the step (compressed grads, packed
    moments) must reach only sanctioned kernels/decoders. The PR-9
    item this closes ran the walk over single events; this traces the
    full composition on the reduced llama config."""
    import dataclasses as _dc

    from repro.configs import get_config, reduced
    from repro.core import paper_default
    from repro.models import init_params
    from repro.optim.adamw import AdamWConfig, init_opt_state
    from repro.optim.moments import MomentPolicy
    from repro.robust import GuardPolicy
    from repro.train import TrainConfig, make_train_step

    cfg = _dc.replace(reduced(get_config("llama3-8b")), vocab=64)
    pol = paper_default("sub3")
    pol = pol.replace(
        act=pol.act.replace(backend="xla"),
        weight=pol.weight.replace(backend="xla"),
        grad=pol.grad.replace(backend="xla"),
    )
    xla_sub3 = lambda **kw: __import__(
        "repro.core.policy", fromlist=["MoRPolicy"]
    ).MoRPolicy(recipe="sub3", backend="xla", **kw)
    moments = MomentPolicy(m=xla_sub3(), v=xla_sub3(threshold=0.02))
    tcfg = TrainConfig(
        optimizer=AdamWConfig(warmup_steps=5, total_steps=50),
        compress_grads="mor_ef",
        grad_policy=xla_sub3(),
        moments=moments,
        # Guarded: the walk also covers the skip-step selects over the
        # packed-moment payload lanes (docs/robustness.md).
        guard=GuardPolicy(),
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params, moments=moments, ef=True)
    step = make_train_step(cfg, pol, tcfg)
    batch = {
        "tokens": jnp.zeros((4, 32), jnp.int32),
        "labels": jnp.zeros((4, 32), jnp.int32),
    }
    return ContractCase(fn=step, args=(params, opt, batch))


register(Contract(
    name="train_step_taint",
    build=_train_step_case,
    taint=_TAINT,
    seed_kernel_outputs=True,
    notes="payload-lane taint walk over the full train step (grads "
          "compressed mor_ef + packed Adam moments): packed bytes only "
          "decode in sanctioned modules, no f64 anywhere in the step",
))
