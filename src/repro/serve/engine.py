"""Continuous-batching serving engine over a paged KV pool.

Every slot advances at its *own* position: the jitted step takes a
vector ``cur_index`` (one entry per slot), so mixed-prompt-length
batches read and write exactly their true cache rows. (The engine this
replaces shared one ``max(slot_pos)`` across the batch, which wrote
short slots' KV past their real position and left zero-filled holes the
decode mask treated as valid keys -- zero-score keys take real softmax
mass, so mixed-length batches produced wrong tokens.)

The loop is a real scheduler (docs/serving.md):

- KV lives in a ``PagedKVPool`` (block table + free list, page size
  aligned to the MoR ``Partition`` block grid); admission reserves a
  request's worst-case page span, eviction recycles it.
- Prefill is *chunked* and interleaved with decode: each engine step
  runs one fixed-size prompt chunk per prefilling slot (compiled once
  per chunk shape, never re-prefilling the whole sequence) plus one
  batched decode step over the decoding slots. Families with recurrent
  state (Hymba SSM, xLSTM cells) prefill in one shot at admission --
  their recurrence can't resume from a page -- and then join the same
  batched decode.
- Per-request ``max_tokens`` and sampling params (greedy by default;
  ``temperature`` / ``top_k`` / ``seed`` for stochastic decode).
- With quantized weights, decode GEMMs are (slots, K, N) with
  slots << 128: the engine pins the skinny-M lane in the ``GemmTile``
  autotune table so activations pack at the 16-row sublane tile, not
  a padded 128.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import MoRDotPolicy, MoRPolicy
from repro.models import make_decode_fn, make_prefill_fn, make_tokens
from repro.models.attention import quantize_kv, quantize_kv_mor

from .paged import PagedKVPool
from .quantized import quantize_params

__all__ = ["Request", "ServeConfig", "Engine", "PromptTooLongError"]


class PromptTooLongError(ValueError):
    """Prompt has no room in the cache (P >= max_seq): there would be
    nowhere to write even the first generated token's KV."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_tokens: int = 16
    # Sampling: temperature <= 0 is greedy argmax; otherwise softmax
    # sampling at the given temperature, optionally top_k-truncated,
    # seeded per request (host-side RNG -> reproducible per rid/seed).
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # Surfaced condition: explicit truncation at submit, or
    # "unfinished" when run_to_completion exhausts max_steps.
    error: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4
    max_seq: int = 512
    # Paged pool: page_size must divide max_seq and tile the 128-row
    # MoR Partition block; pool_pages < slots * (max_seq / page_size)
    # oversubscribes (requests then queue on the free list).
    page_size: Optional[int] = None
    pool_pages: Optional[int] = None
    # Chunked prefill: tokens per chunk (must divide max_seq). One
    # chunk per prefilling slot per engine step.
    prefill_chunk: int = 32
    kv_fp8: bool = False
    # MoR cache tier (docs/numerics.md): per-(position, head) tag-select
    # E4M3/E5M2 payloads + GAM scales instead of the monolithic fp8
    # cast. Mutually exclusive with kv_fp8.
    kv_mor: bool = False
    # Cold-page policy: with kv_mor, a page is sub4-recompressed (E2M1
    # nibbles + micro scales, 0.5625 logical B/elt) once a slot's write
    # frontier is at least this many positions past the page's end.
    # None disables sealing. Requires head_dim % 16 == 0.
    kv_mor_cold: Optional[int] = None
    # P >= max_seq at submit: 'reject' raises PromptTooLongError,
    # 'truncate' keeps the first max_seq - 1 tokens and records the
    # truncation on request.error.
    on_long_prompt: str = "reject"
    # Robustness tier (docs/robustness.md): also run the pool's
    # KV-page guard over each decoding slot every decode step --
    # host-side finiteness checks of the float/scale lanes, so
    # corrupted pages quarantine the owning slot *before* the poison
    # reaches its logits. Off by default: the nonfinite-logits
    # quarantine below is free, this sweep fetches page lanes.
    kv_guard: bool = False


class Engine:
    def __init__(self, cfg: ArchConfig, policy: MoRDotPolicy, params,
                 scfg: ServeConfig = ServeConfig(),
                 quantize: Optional[MoRPolicy] = None,
                 quantize_min_size: int = 1 << 16,
                 mesh=None):
        """``quantize``: optional ahead-of-time MoR storage decision --
        weight leaves become sub-tensor QTensors (per-block E4M3 / E5M2
        / BF16 payloads) and every prefill/decode matmul against them
        runs through the mixed-representation block GEMM kernel; the
        engine also registers the skinny-M decode tile for each
        quantized weight's block grid (kernels.ops.register_decode_tiles).

        ``mesh``: optional jax Mesh for tensor-parallel serving. Params
        (dense *and* QTensor leaves -- payloads, tags and scales shard
        together on the block grid, see ``sharding.rules
        .quantized_param_specs``) are placed per the Megatron TP rules,
        so sharded serving never materializes a dequantized weight
        copy. Example::

            mesh = make_local_mesh(data=1, model=4)
            eng = Engine(cfg, policy, params,
                         quantize=MoRPolicy(recipe="sub3"), mesh=mesh)
        """
        if cfg.family in ("audio", "vlm"):
            raise NotImplementedError(
                f"family {cfg.family!r} needs a modality frontend the "
                "engine does not drive (frames/patches inputs)"
            )
        if scfg.max_seq % scfg.prefill_chunk:
            raise ValueError(
                f"prefill_chunk {scfg.prefill_chunk} must divide "
                f"max_seq {scfg.max_seq}"
            )
        if scfg.kv_fp8 and scfg.kv_mor:
            raise ValueError("kv_fp8 and kv_mor are mutually exclusive")
        if scfg.kv_mor_cold is not None and not scfg.kv_mor:
            raise ValueError("kv_mor_cold needs kv_mor=True")
        self.cfg = cfg
        self.scfg = scfg
        self.qstats = None
        self.decode_row_block = None
        if quantize is not None:
            params, self.qstats = quantize_params(
                params, quantize, min_size=quantize_min_size
            )
            from repro.kernels import ops as kops

            # Decode activations are (slots, d): pin the skinny-M lane
            # so the GEMM autotune never pads the slots axis to 128.
            self.decode_tile_grids = kops.register_decode_tiles(
                params, scfg.slots
            )
            self.decode_row_block = kops.decode_row_block(scfg.slots)
        if mesh is not None:
            from repro.sharding import rules as _rules

            specs = _rules.quantized_param_specs(cfg, params, mesh)
            params = jax.device_put(
                params, _rules.named_shardings(mesh, specs)
            )
        self.params = params
        self.tokens = make_tokens(cfg)
        self.pool = PagedKVPool(
            cfg, scfg.slots, scfg.max_seq, page_size=scfg.page_size,
            kv_fp8=scfg.kv_fp8, n_pages=scfg.pool_pages,
            kv_mor=scfg.kv_mor,
        )
        self._sealed = set()  # (slot, page_index) sub4-recompressed
        # Chunked prefill needs every cache leaf positional (pageable);
        # recurrent-state families prefill in one shot at admission.
        self.chunked_prefill = self.pool.all_paged and self.pool.has_paged
        self._prefill = jax.jit(make_prefill_fn(cfg, policy))
        decode = make_decode_fn(cfg, policy)
        pool = self.pool

        def step_fn(params, tokens, ptree, bt, toks, cur):
            cache = pool.gather(ptree, bt)
            logits, new_cache, _ = decode(params, tokens, cache, toks, cur)
            S = toks.shape[1]
            positions = (
                cur[:, None] - (S - 1) + jnp.arange(S, dtype=jnp.int32)[None]
            )
            return logits, pool.scatter(ptree, new_cache, bt, positions)

        # One compiled variant per token-block shape: (slots, 1) decode
        # and (1, prefill_chunk) chunked prefill.
        self._step_fn = jax.jit(step_fn, donate_argnums=(2,))

        n = scfg.slots
        self.slot_req: List[Optional[Request]] = [None] * n
        self.slot_pos = np.zeros(n, np.int32)   # next cache write position
        self.slot_next = np.zeros(n, np.int32)  # next input token id
        self.slot_state = ["idle"] * n          # idle | prefill | decode
        self.slot_filled = np.zeros(n, np.int32)  # prompt tokens consumed
        self.queue: Deque[Request] = collections.deque()
        self.unfinished: List[Request] = []
        # Graceful degradation (docs/robustness.md): requests finished
        # early because their slot produced nonfinite logits or failed
        # the KV-page guard, and requests rejected at admission because
        # their worst-case page reservation can never be satisfied.
        self.quarantined: List[Request] = []
        self.rejected: List[Request] = []
        self.steps = 0
        self.decode_steps = 0
        self.prefill_chunks = 0

    # ------------------------------------------------------------- admin --
    def submit(self, req: Request):
        """Queue a request. Prompts with P >= max_seq cannot fit (the
        first generated token's KV is written at position P): per
        ``ServeConfig.on_long_prompt`` they are rejected here or
        explicitly truncated with the event surfaced on ``req.error``."""
        P = len(req.prompt)
        if P < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        limit = self.scfg.max_seq - 1
        if P > limit:
            if self.scfg.on_long_prompt == "truncate":
                req.prompt = np.asarray(req.prompt)[:limit]
                req.error = (
                    f"prompt truncated {P} -> {limit} tokens "
                    f"(max_seq={self.scfg.max_seq})"
                )
            else:
                raise PromptTooLongError(
                    f"request {req.rid}: prompt of {P} tokens exceeds "
                    f"the max_seq - 1 = {limit} limit (set "
                    "on_long_prompt='truncate' to clip instead)"
                )
        self.queue.append(req)

    def _horizon(self, req: Request) -> int:
        """Highest cache position + 1 this request can touch: chunked
        prefill writes (padded) whole chunks; decode writes the
        (max_tokens - 1) sampled continuations after the prompt."""
        P = len(req.prompt)
        C = self.scfg.prefill_chunk
        span = -(-P // C) * C if self.chunked_prefill else P
        return min(max(span, P + req.max_tokens - 1), self.scfg.max_seq)

    def _admit(self):
        # Single scan over the slot list per engine step; pages are
        # reserved all-or-nothing so admitted requests never starve
        # mid-flight when the pool is oversubscribed.
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        for slot in free:
            req = None
            while self.queue and req is None:
                head = self.queue[0]
                need = self.pool.pages_for(self._horizon(head))
                if need > self.pool.n_pages:
                    # No amount of eviction can ever free enough pages:
                    # waiting on this head would starve the whole queue
                    # behind an unsatisfiable reservation. Reject it
                    # with the condition surfaced, like submit-side
                    # truncation.
                    self.queue.popleft()
                    head.error = (
                        f"rejected at admission: worst-case reservation "
                        f"of {need} pages exceeds the pool's "
                        f"{self.pool.n_pages} total pages (page_size="
                        f"{self.pool.page_size}); shrink the prompt or "
                        "max_tokens, or grow pool_pages"
                    )
                    head.done = True
                    self.rejected.append(head)
                    continue
                req = head
            if req is None:
                return
            if not self.pool.alloc(slot, self._horizon(req)):
                return  # wait for evictions to refill the free list
            self.queue.popleft()
            self.slot_req[slot] = req
            self.slot_filled[slot] = 0
            if self.chunked_prefill:
                self.slot_state[slot] = "prefill"
            else:
                self._full_prefill(slot, req)

    # ----------------------------------------------------------- prefill --
    def _full_prefill(self, slot: int, req: Request):
        """One-shot prefill for recurrent-state families: the cache the
        model emits is spliced into this slot's pages / state row."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, pcache, _ = self._prefill(
            self.params, self.tokens, {"tokens": prompt}
        )
        by_key: Dict[str, jnp.ndarray] = {}
        flat, _ = jax.tree_util.tree_flatten_with_path(pcache)
        for path, leaf in flat:
            key = "/".join(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in path
            )
            by_key[key] = leaf
        if self.scfg.kv_fp8:
            for key in list(by_key):
                last = key.rsplit("/", 1)[-1]
                if last in ("k", "v"):
                    pay, sc = quantize_kv(by_key[key])
                    by_key[key] = pay
                    by_key[key + "_scale"] = sc
        elif self.scfg.kv_mor:
            for key in list(by_key):
                last = key.rsplit("/", 1)[-1]
                if last in ("k", "v"):
                    pay, tags, sc = quantize_kv_mor(by_key[key])
                    by_key[key] = pay
                    by_key[key + "_tags"] = tags
                    by_key[key + "_scale"] = sc
        self.pool.splice(slot, by_key, len(req.prompt))
        self._start_decode(slot, req, len(req.prompt),
                           np.asarray(logits[0, -1], np.float32))

    def _prefill_chunk_step(self, slot: int, req: Request):
        """Advance one prompt chunk for a prefilling slot (B=1 call
        against this slot's page-table row)."""
        C = self.scfg.prefill_chunk
        start = int(self.slot_filled[slot])
        P = len(req.prompt)
        chunk = np.zeros(C, np.int32)
        real = min(C, P - start)
        chunk[:real] = np.asarray(req.prompt)[start:start + real]
        bt = self.pool.table_rows([slot])
        logits, tree = self._step_fn(
            self.params, self.tokens, self.pool.tree, bt,
            jnp.asarray(chunk[None]), jnp.asarray([start + C - 1], jnp.int32),
        )
        self.pool.update(tree)
        self.prefill_chunks += 1
        self.slot_filled[slot] = start + real
        if start + real >= P:
            # The chunk's logits at the last *real* prompt token seed
            # generation (padded tail positions are written but masked
            # until real tokens overwrite them).
            row = np.asarray(logits[0, real - 1], np.float32)
            self._start_decode(slot, req, P, row)

    def _start_decode(self, slot: int, req: Request, P: int,
                      logits_row: np.ndarray):
        tok = self._sample(req, logits_row)
        req.out.append(tok)
        self.slot_pos[slot] = P
        self.slot_next[slot] = tok
        self.slot_state[slot] = "decode"
        # The prefill-sampled token counts toward max_tokens: a
        # max_tokens=1 request is complete right here, before any
        # decode step runs.
        if len(req.out) >= req.max_tokens:
            self._finish(slot)

    # ------------------------------------------------------------ decode --
    def _decode_batch(self, dec: List[int]):
        n = self.scfg.slots
        mask = np.zeros(n, bool)
        mask[dec] = True
        # Non-decoding slots ride along in the batched call with their
        # rows pointed at the trash page: their writes can't touch real
        # pages and their (garbage) logits are discarded.
        bt = np.where(
            mask[:, None], self.pool.block_table, self.pool.trash
        ).astype(np.int32)
        toks = np.where(mask, self.slot_next, 0).astype(np.int32)[:, None]
        cur = np.where(mask, self.slot_pos, 0).astype(np.int32)
        logits, tree = self._step_fn(
            self.params, self.tokens, self.pool.tree, jnp.asarray(bt),
            jnp.asarray(toks), jnp.asarray(cur),
        )
        self.pool.update(tree)
        self.decode_steps += 1
        rows = np.asarray(logits[:, 0], np.float32)
        for i in dec:
            r = self.slot_req[i]
            # Slot quarantine (docs/robustness.md): the logits row is
            # already on the host for sampling, so the finiteness check
            # is free; a poisoned slot (corrupted KV page, overflowed
            # cache lane) finishes early with the condition surfaced
            # instead of sampling garbage forever. Decode rows are
            # slot-independent (each attends only over its own pages),
            # so every other slot's tokens are unaffected. The optional
            # page sweep runs *first*: when both would fire, the error
            # should name the corrupted page (the root cause), not the
            # nonfinite logits downstream of it -- and it also catches
            # corruption in reserved-but-not-yet-attended pages the
            # logits cannot see yet.
            if self.scfg.kv_guard:
                bad = self.pool.guard_check(i)
                if bad is not None:
                    self._quarantine(i, bad)
                    continue
            if not np.isfinite(rows[i][: self.cfg.vocab]).all():
                self._quarantine(
                    i,
                    f"nonfinite logits at position "
                    f"{int(self.slot_pos[i])}",
                )
                continue
            tok = self._sample(r, rows[i])
            r.out.append(tok)
            self.slot_pos[i] += 1
            self.slot_next[i] = tok
            # Done when the budget is spent or the *next* write
            # position would overflow the cache (position max_seq - 1
            # is still usable -- stopping at slot_pos + 1 >= max_seq
            # would waste it).
            if len(r.out) >= r.max_tokens or \
                    self.slot_pos[i] >= self.scfg.max_seq:
                self._finish(i)

    def _sample(self, req: Request, row: np.ndarray) -> int:
        V = self.cfg.vocab
        row = row[:V]
        if req.temperature <= 0.0:
            return int(row.argmax())
        rng = getattr(req, "_rng", None)
        if rng is None:
            rng = np.random.default_rng((req.seed, req.rid))
            req._rng = rng
        z = row.astype(np.float64) / req.temperature
        if req.top_k and req.top_k < V:
            kth = np.partition(z, -req.top_k)[-req.top_k]
            z = np.where(z >= kth, z, -np.inf)
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(rng.choice(V, p=p))

    def _quarantine(self, slot: int, reason: str):
        """Finish a poisoned slot early: surface the condition on
        ``req.error``, keep whatever tokens were already emitted, and
        release the pages back to the free list via the normal finish
        path (so queued requests can take the slot next tick)."""
        req = self.slot_req[slot]
        note = f"quarantined: {reason}"
        req.error = f"{req.error}; {note}" if req.error else note
        self.quarantined.append(req)
        self._finish(slot)

    def _finish(self, slot: int):
        self.slot_req[slot].done = True
        self.slot_req[slot] = None
        self.slot_state[slot] = "idle"
        self.slot_pos[slot] = 0
        self.slot_next[slot] = 0
        self.slot_filled[slot] = 0
        self.pool.release(slot)
        self._sealed = {(s, j) for s, j in self._sealed if s != slot}

    # ---------------------------------------------------- MoR cold tier --
    def _seal_cold_pages(self):
        """Sub4-recompress pages a decode slot's write frontier has
        left at least ``kv_mor_cold`` positions behind. Sealed pages
        are never written again while owned (positions only grow), so
        the one-way fp8 -> NVFP4 recompression is safe; the set resets
        when the slot's pages are released."""
        lag = self.scfg.kv_mor_cold
        ps = self.pool.page_size
        cold: List[int] = []
        for i in range(self.scfg.slots):
            if self.slot_state[i] != "decode":
                continue
            frontier = int(self.slot_pos[i])
            for j, page in enumerate(self.pool.block_table[i]):
                if page == self.pool.trash or (i, j) in self._sealed:
                    continue
                if (j + 1) * ps + lag <= frontier:
                    cold.append(int(page))
                    self._sealed.add((i, j))
        if cold:
            self.pool.recompress_pages(cold)

    def kv_cache_stats(self):
        """Tag census / bytes-per-element of the live cache (kv_mor)."""
        return self.pool.kv_cache_stats()

    # -------------------------------------------------------------- step --
    def step(self) -> bool:
        """One scheduler tick: admit, one prefill chunk per prefilling
        slot, one batched decode step over decoding slots. Returns
        False once no request is queued or in flight."""
        self._admit()
        worked = False
        for i in range(self.scfg.slots):
            if self.slot_state[i] == "prefill":
                self._prefill_chunk_step(i, self.slot_req[i])
                worked = True
        dec = [i for i in range(self.scfg.slots)
               if self.slot_state[i] == "decode"]
        if dec:
            self._decode_batch(dec)
            worked = True
        if worked and self.scfg.kv_mor_cold is not None:
            self._seal_cold_pages()
        if worked:
            self.steps += 1
        return worked or bool(self.queue)

    def run_to_completion(self, max_steps: int = 1024) -> int:
        """Drive steps until drained (or ``max_steps``). Requests still
        queued or in flight at exhaustion are reported: each gets
        ``error`` set and they are collected on ``self.unfinished``
        (with ``done`` left False) instead of silently dropped."""
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        self.unfinished = list(self.queue) + [
            r for r in self.slot_req if r is not None
        ]
        for r in self.unfinished:
            note = f"unfinished after {max_steps} engine steps"
            r.error = f"{r.error}; {note}" if r.error else note
        return steps
