"""Batched serving engine: continuous-batching decode loop over a fixed
slot pool, with prefill admission and per-slot completion.

Slots hold one request each; the engine admits new requests into free
slots (prefill -> cache splice), then advances ALL active slots with one
jitted decode step per iteration (the batched serve_step the dry-run
lowers for decode_* shapes). Greedy sampling; per-slot stop on max_tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import MoRDotPolicy, MoRPolicy
from repro.models import (
    init_cache,
    make_decode_fn,
    make_prefill_fn,
    make_tokens,
)

from .quantized import quantize_params

__all__ = ["Request", "ServeConfig", "Engine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4
    max_seq: int = 512


class Engine:
    def __init__(self, cfg: ArchConfig, policy: MoRDotPolicy, params,
                 scfg: ServeConfig = ServeConfig(),
                 quantize: Optional[MoRPolicy] = None,
                 quantize_min_size: int = 1 << 16,
                 mesh=None):
        """``quantize``: optional ahead-of-time MoR storage decision --
        weight leaves become sub-tensor QTensors (per-block E4M3 / E5M2
        / BF16 payloads) and every prefill/decode matmul against them
        runs through the mixed-representation block GEMM kernel.

        ``mesh``: optional jax Mesh for tensor-parallel serving. Params
        (dense *and* QTensor leaves -- payloads, tags and scales shard
        together on the block grid, see ``sharding.rules
        .quantized_param_specs``) are placed per the Megatron TP rules,
        so sharded serving never materializes a dequantized weight
        copy. Example::

            mesh = make_local_mesh(data=1, model=4)
            eng = Engine(cfg, policy, params,
                         quantize=MoRPolicy(recipe="sub3"), mesh=mesh)
        """
        self.cfg = cfg
        self.scfg = scfg
        self.qstats = None
        if quantize is not None:
            params, self.qstats = quantize_params(
                params, quantize, min_size=quantize_min_size
            )
        if mesh is not None:
            from repro.sharding import rules as _rules

            specs = _rules.quantized_param_specs(cfg, params, mesh)
            params = jax.device_put(
                params, _rules.named_shardings(mesh, specs)
            )
        self.params = params
        self.tokens = make_tokens(cfg)
        self._prefill = jax.jit(make_prefill_fn(cfg, policy))
        self._decode = jax.jit(make_decode_fn(cfg, policy))
        self.cache = init_cache(cfg, scfg.slots, scfg.max_seq)
        self.slot_req: List[Optional[Request]] = [None] * scfg.slots
        self.slot_pos = np.zeros(scfg.slots, np.int32)
        self.slot_next = np.zeros(scfg.slots, np.int32)
        self.queue: List[Request] = []

    # ------------------------------------------------------------- admin --
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def _admit(self):
        while self.queue and self._free_slot() is not None:
            slot = self._free_slot()
            req = self.queue.pop(0)
            P = len(req.prompt)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, pcache, _ = self._prefill(
                self.params, self.tokens, {"tokens": prompt}
            )
            # Splice the single-sequence prefill cache into this slot.
            def splice(full, part):
                if full.ndim >= 4 and part.ndim == full.ndim and \
                        full.shape[2] != part.shape[2]:
                    part = jax.lax.dynamic_update_slice_in_dim(
                        jnp.zeros(
                            (part.shape[0], 1, full.shape[2],
                             *part.shape[3:]), full.dtype
                        ),
                        part.astype(full.dtype), 0, axis=2,
                    )
                return jax.lax.dynamic_update_slice_in_dim(
                    full, part.astype(full.dtype), slot, axis=1
                )

            self.cache = jax.tree.map(splice, self.cache, pcache)
            nxt = int(jnp.argmax(logits[0, -1]))
            req.out.append(nxt)
            self.slot_req[slot] = req
            self.slot_pos[slot] = P
            self.slot_next[slot] = nxt

    # -------------------------------------------------------------- step --
    def step(self):
        """One batched decode step across all active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        toks = jnp.asarray(self.slot_next, jnp.int32)[:, None]
        # One shared cur_index per jitted step: use the max position and
        # rely on per-slot masks being monotone (positions beyond a slot's
        # own length hold zeros -- attention over zeros contributes a
        # constant the softmax normalizes out for short overhangs; exact
        # per-slot indices would need a vector cur_index, noted in DESIGN).
        cur = int(self.slot_pos.max())
        logits, self.cache, _ = self._decode(
            self.params, self.tokens, self.cache, toks,
            jnp.asarray(cur, jnp.int32),
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        for i in active:
            r = self.slot_req[i]
            r.out.append(int(nxt[i]))
            self.slot_pos[i] += 1
            self.slot_next[i] = int(nxt[i])
            if len(r.out) >= r.max_tokens or self.slot_pos[i] + 1 >= \
                    self.scfg.max_seq:
                r.done = True
                self.slot_req[i] = None
        return True

    def run_to_completion(self, max_steps: int = 1024) -> int:
        steps = 0
        while (self.queue or any(self.slot_req)) and steps < max_steps:
            self.step()
            steps += 1
        return steps
