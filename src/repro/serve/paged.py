"""Paged KV-cache pool for continuous-batching decode (docs/serving.md).

The dense per-slot cache (``(n_units, slots, max_seq, ...)`` per leaf)
reserves every slot's worst-case sequence up front. This module replaces
it with a vLLM-style page pool: KV leaves are stored as
``(n_units, n_pages + 1, page_size, ...)`` physical pages, a per-slot
block table maps logical position ``p`` to ``(bt[slot, p // page_size],
p % page_size)``, and a host-side free list recycles pages as requests
finish. Only leaves whose sequence axis spans ``max_seq`` are paged
(``k``/``v`` and the fp8 ``k_scale``/``v_scale``); recurrent state
leaves (SSM / xLSTM cells, Whisper cross-KV) have no position axis and
stay slot-dense.

Page size is aligned to the MoR ``Partition`` block grid: a page's row
count must evenly tile the 128-row block dimension (``128 % page_size
== 0`` or ``page_size % 128 == 0``), so a page -- ``(page_size,
hkv * hd)`` tokens-by-features -- can later be stored as a
``MixedOperand`` payload (per-block E4M3/E5M2/BF16/NVFP4, the SNIP-style
sub-byte cache tier) without re-blocking: whole MoR blocks are unions of
whole pages or vice versa.

The last physical page (index ``n_pages``) is the *trash page*: block
tables of empty or still-prefilling slots point every entry at it, so a
batched decode step can always run over all slots -- writes from
inactive rows land in trash, reads from it see garbage that the
per-slot ``cur_index`` mask keeps out of the softmax, and no scatter
index is ever out of bounds.
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import cache_specs

__all__ = ["PagedKVPool", "MOR_BLOCK_ROWS"]

MOR_BLOCK_ROWS = 128  # Partition("block").block_shape[0]


@jax.jit
def _recompress_slab(payload, tags, scales, idx):
    """Sub4-recompress the pages ``idx`` of one paged KV lane group.

    Leaves are pool-shaped -- payload (n_units, n_pages+1, ps, hkv,
    hd), tags/scales (n_units, n_pages+1, ps, hkv) -- and the update
    touches only the selected pages. Jitted once per idx length (the
    engine seals pages one boundary at a time)."""
    from repro.models.attention import recompress_kv_nvfp4

    pay, tg, sc = recompress_kv_nvfp4(
        payload[:, idx], tags[:, idx], scales[:, idx]
    )
    return (
        payload.at[:, idx].set(pay),
        tags.at[:, idx].set(tg.astype(tags.dtype)),
        scales.at[:, idx].set(sc.astype(scales.dtype)),
    )


def _leaf_key(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _is_paged_key(key: str) -> bool:
    """KV leaves with a max_seq position axis; xk/xv (encoder cross-KV,
    enc_seq axis) and recurrent state stay dense."""
    last = key.rsplit("/", 1)[-1]
    return last in ("k", "v", "k_scale", "v_scale", "k_tags", "v_tags")


class PagedKVPool:
    """Page pool + block table + free list over one model's cache tree.

    ``n_pages`` defaults to ``slots * (max_seq // page_size)`` (no
    oversubscription: every slot can hold a full sequence). A smaller
    pool makes admission wait on the free list instead -- the engine
    reserves a request's worst-case page count up front so a running
    request can never starve mid-decode.
    """

    def __init__(self, cfg: ArchConfig, slots: int, max_seq: int,
                 page_size: Optional[int] = None, kv_fp8: bool = False,
                 n_pages: Optional[int] = None, kv_mor: bool = False):
        page_size = page_size or min(64, max_seq)
        if max_seq % page_size:
            raise ValueError(
                f"page_size {page_size} must divide max_seq {max_seq}"
            )
        if (MOR_BLOCK_ROWS % page_size) and (page_size % MOR_BLOCK_ROWS):
            raise ValueError(
                f"page_size {page_size} is not MoR-block aligned: it "
                f"must evenly tile the {MOR_BLOCK_ROWS}-row Partition "
                "block (divide it or be a multiple of it) so pages can "
                "hold MixedOperand payloads"
            )
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.page_size = page_size
        self.kv_fp8 = kv_fp8
        self.kv_mor = kv_mor
        self.pages_per_seq = max_seq // page_size
        self.n_pages = (slots * self.pages_per_seq if n_pages is None
                        else n_pages)
        self.trash = self.n_pages  # last physical page

        specs = cache_specs(cfg, slots, max_seq, kv_fp8, kv_mor)
        flat, self._treedef = jax.tree_util.tree_flatten_with_path(specs)
        self._keys = [_leaf_key(p) for p, _ in flat]
        self._paged = [_is_paged_key(k) for k in self._keys]
        self.has_paged = any(self._paged)
        self.all_paged = all(self._paged)

        def storage(spec, paged):
            if paged:
                # (n_units, B, max_seq, ...) -> (n_units, pages, ps, ...)
                n_units, _, _, *tail = spec.shape
                return jnp.zeros(
                    (n_units, self.n_pages + 1, page_size, *tail),
                    spec.dtype,
                )
            return jnp.zeros(spec.shape, spec.dtype)

        self._leaves = [storage(s, pg)
                        for (_, s), pg in zip(flat, self._paged)]
        # Host-side bookkeeping: block table + free list.
        self.block_table = np.full(
            (slots, self.pages_per_seq), self.trash, np.int32
        )
        self.free: collections.deque = collections.deque(
            range(self.n_pages)
        )
        self._owned: List[List[int]] = [[] for _ in range(slots)]

    # ------------------------------------------------------- allocation --
    def free_pages(self) -> int:
        return len(self.free)

    def pages_for(self, n_positions: int) -> int:
        return -(-n_positions // self.page_size)

    def alloc(self, slot: int, n_positions: int) -> bool:
        """Reserve pages covering positions [0, n_positions) for
        ``slot``. All-or-nothing; False if the free list is short."""
        need = self.pages_for(n_positions) - len(self._owned[slot])
        if need <= 0:
            return True
        if need > len(self.free):
            return False
        got = [self.free.popleft() for _ in range(need)]
        start = len(self._owned[slot])
        self._owned[slot].extend(got)
        self.block_table[slot, start:start + len(got)] = got
        return True

    def release(self, slot: int):
        """Return ``slot``'s pages to the free list (eviction). The
        page *contents* are stale, not zeroed: the per-slot cur_index
        mask hides them until real tokens overwrite each position."""
        self.free.extend(self._owned[slot])
        self._owned[slot] = []
        self.block_table[slot, :] = self.trash

    # ------------------------------------------------- jitted-side view --
    @property
    def tree(self):
        """The pool as a pytree (pool-layout paged leaves + dense state
        leaves) -- pass to the jitted step, then `update` with its
        output so donation can reuse the buffers."""
        return jax.tree_util.tree_unflatten(self._treedef, self._leaves)

    def update(self, tree):
        self._leaves = jax.tree_util.tree_leaves(tree)

    def table_rows(self, rows) -> jnp.ndarray:
        """Device copy of the block-table rows for ``rows`` (list of
        slot ids); inactive callers pass all-trash rows instead."""
        return jnp.asarray(self.block_table[rows], jnp.int32)

    def gather(self, tree, bt: jnp.ndarray):
        """Pool tree -> dense cache tree for the model call.

        ``bt`` (B, pages_per_seq) int32 selects each row's pages; paged
        leaves become (n_units, B, max_seq, ...). Dense state leaves
        pass through (their batch axis is the full slot count -- the
        caller only mixes them into full-width batches).
        """
        B, pp = bt.shape
        ps = self.page_size

        def g(key, leaf):
            if not _is_paged_key(key):
                return leaf
            n_units, _, _, *tail = leaf.shape
            out = leaf[:, bt]  # (n_units, B, pp, ps, *tail)
            return out.reshape(n_units, B, pp * ps, *tail)

        return self._map(g, tree)

    def scatter(self, tree, new_dense, bt: jnp.ndarray,
                positions: jnp.ndarray):
        """Write back the rows a decode/chunk step touched.

        ``positions`` (B, S): the S positions each row wrote this step
        (decode S=1 at cur; a chunk writes start..start+S-1). Only
        those rows move pool-ward -- the rest of the gathered dense
        view is discarded, so per-step traffic is O(S), not O(max_seq).
        Dense state leaves are replaced wholesale (recurrent state has
        no position axis).
        """
        B, S = positions.shape
        ps = self.page_size
        rows = jnp.arange(B, dtype=jnp.int32)[:, None]
        page_ids = bt[rows, positions // ps]  # (B, S)
        offs = positions % ps

        def s(key, pool_leaf, dense_leaf):
            if not _is_paged_key(key):
                return dense_leaf
            vals = dense_leaf[:, rows, positions]  # (n_units, B, S, ...)
            return pool_leaf.at[:, page_ids, offs].set(
                vals.astype(pool_leaf.dtype)
            )

        return self._map(s, tree, new_dense)

    def splice(self, slot: int, dense_by_key: Dict[str, jnp.ndarray],
               n_positions: int):
        """Write a single-sequence (B=1) prefill cache into ``slot``.

        ``dense_by_key`` maps leaf keys (as in ``cache_specs``) to
        (n_units, 1, P, ...) KV leaves / (n_units, 1, ...) state
        leaves. Paged leaves scatter rows 0..P-1 through the slot's
        block table; state leaves land in its batch row. Host-side,
        once per admission (recurrent-family fallback path).
        """
        bt = jnp.asarray(self.block_table[slot], jnp.int32)
        pos = jnp.arange(n_positions, dtype=jnp.int32)
        page_ids, offs = bt[pos // self.page_size], pos % self.page_size
        new = []
        for key, leaf in zip(self._keys, self._leaves):
            d = dense_by_key.get(key)
            if d is None:
                new.append(leaf)
                continue
            if _is_paged_key(key):
                vals = d[:, 0, :n_positions]
                leaf = leaf.at[:, page_ids, offs].set(
                    vals.astype(leaf.dtype)
                )
            else:
                leaf = leaf.at[:, slot].set(d[:, 0].astype(leaf.dtype))
            new.append(leaf)
        self._leaves = new

    def _map(self, fn, *trees):
        flats = [jax.tree_util.tree_leaves(t) for t in trees]
        out = [fn(k, *ls) for k, *ls in zip(self._keys, *flats)]
        return jax.tree_util.tree_unflatten(self._treedef, out)

    # ------------------------------------------------- MoR cold tier --
    def _kv_lane_indices(self):
        """[(payload_idx, tags_idx, scale_idx)] per paged k/v group."""
        by_key = {k: i for i, k in enumerate(self._keys)}
        groups = []
        for key in self._keys:
            if key.rsplit("/", 1)[-1] not in ("k", "v"):
                continue
            t, s = key + "_tags", key + "_scale"
            if t in by_key and s in by_key:
                groups.append((by_key[key], by_key[t], by_key[s]))
        return groups

    def recompress_pages(self, pages) -> int:
        """Sub4-recompress whole (sealed) pages in place: fp8 payload
        bytes -> packed E2M1 nibbles + micro-scale bytes inside the
        same payload lane, tags -> TAG_NVFP4, scales retargeted. The
        caller guarantees the pages are fully written and behind every
        reader's write frontier (the engine's cold-page policy); the
        positional cur_index mask, not these lanes, decides visibility.
        Returns the number of pages recompressed."""
        if not self.kv_mor:
            raise ValueError(
                "recompress_pages needs a kv_mor pool (tags lanes)"
            )
        pages = [int(p) for p in pages if int(p) != self.trash]
        if not pages:
            return 0
        idx = jnp.asarray(pages, jnp.int32)
        for pi, ti, si in self._kv_lane_indices():
            pay, tags, sc = _recompress_slab(
                self._leaves[pi], self._leaves[ti], self._leaves[si], idx
            )
            self._leaves[pi] = pay
            self._leaves[ti] = tags
            self._leaves[si] = sc
        return len(pages)

    # ----------------------------------------------------- inspection --
    def guard_check(self, slot: int) -> Optional[str]:
        """KV-page guard (docs/robustness.md): host-side finiteness
        sweep over ``slot``'s owned pages. Float lanes (bf16/fp8 KV,
        MoR scale grids) must be finite everywhere -- unwritten
        positions are zero-initialized, so any NaN/Inf is corruption,
        not staleness. Returns a surfaced-error string, or None when
        the pages are clean. Cost is a per-slot page fetch; the engine
        gates it behind ``ServeConfig.kv_guard``."""
        pages = self._owned[slot]
        if not pages:
            return None
        idx = np.asarray(pages, np.int32)
        for key, leaf, paged in zip(self._keys, self._leaves,
                                    self._paged):
            if not paged or not jnp.issubdtype(leaf.dtype, jnp.inexact):
                continue
            vals = np.asarray(leaf[:, idx].astype(jnp.float32))
            if not np.isfinite(vals).all():
                return (
                    f"KV-page guard: nonfinite values in lane {key!r} "
                    f"of slot {slot}'s pages"
                )
        return None

    def bytes_per_token(self) -> int:
        """Physical pool bytes moved per cache position by one gather +
        scatter round trip, summed over paged leaves and units -- a
        deterministic property of the cache layout (bf16 2 B/elt vs
        MoR's 1 B payload + tag/scale lanes), so it gates at threshold
        0 in benchmarks.compare."""
        total = 0
        for key, leaf in zip(self._keys, self._leaves):
            if not _is_paged_key(key):
                continue
            per_pos = int(np.prod(leaf.shape[3:], dtype=np.int64))
            total += leaf.shape[0] * per_pos * leaf.dtype.itemsize
        return int(total)

    def kv_cache_stats(self) -> Dict[str, float]:
        """Host-side tag census over written rows of owned pages: tag
        fractions, logical payload bytes per element, and a
        STATS_WIDTH stats row (models.attention.kv_stats_row
        semantics)."""
        from repro.models.attention import kv_bytes_per_element
        from repro.models.attention import kv_stats_row as _row

        if not self.kv_mor:
            return {}
        owned = sorted({p for o in self._owned for p in o})
        if not owned:
            return {"written": 0}
        tags_all, written = [], 0
        for _, ti, si in self._kv_lane_indices():
            tags = np.asarray(self._leaves[ti][:, owned])
            sc = np.asarray(self._leaves[si][:, owned])
            mask = sc > 0  # written rows only (zero scale = never set)
            tags_all.append(tags[mask])
            written += int(mask.sum())
        t = np.concatenate(tags_all) if tags_all else np.zeros(0, np.uint8)
        if t.size == 0:
            return {"written": 0}
        frac = lambda tag: float((t == tag).mean())
        from repro.kernels.ref import (
            TAG_BF16, TAG_E4M3, TAG_E5M2, TAG_NVFP4,
        )

        return {
            "written": written,
            "frac_e4m3": frac(TAG_E4M3),
            "frac_e5m2": frac(TAG_E5M2),
            "frac_bf16": frac(TAG_BF16),
            "frac_nvfp4": frac(TAG_NVFP4),
            "frac_fp8": frac(TAG_E4M3) + frac(TAG_E5M2),
            "payload_bpe": float(kv_bytes_per_element(t)),
            "stats_row": np.asarray(_row(t)),
        }

    def stats(self) -> Dict[str, int]:
        return {
            "n_pages": self.n_pages,
            "free": len(self.free),
            "page_size": self.page_size,
            "owned": sum(len(o) for o in self._owned),
        }
