"""Real-quantization path for serving: QTensor weights (FP8 payload +
GAM scale metadata) decided ahead-of-time by the MoR metric.

Training uses fake quantization (paper Fig. 4); at serving time the same
MoR decision becomes a *storage* decision: tensors whose relative error
passes th_E4M3 are stored as E4M3 bytes + (group mantissa, per-block E8M0
exponents); the rest stay BF16. Matmuls against QTensors dequantize
per-block (repro.kernels.fp8_gemm on TPU; jnp fallback elsewhere),
halving weight HBM traffic for the quantized tensors -- decode is
weight-bandwidth-bound, so this is the serving speedup (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import E4M3, MoRPolicy, Partition
from repro.core.gam import compute_scales
from repro.core.mor import partition_of, quant_dequant_with_scales
from repro.core.metrics import relative_error

__all__ = ["QTensor", "quantize_weight", "qdot", "quantize_params"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """FP8 payload + GAM scales, or a BF16 passthrough (data_bf16)."""

    data_fp8: Optional[jnp.ndarray]  # (M, K) float8_e4m3fn scaled values
    scale: Optional[jnp.ndarray]  # (nm, nk) f32 reconstructed scales
    data_bf16: Optional[jnp.ndarray]
    block: Tuple[int, int]
    shape: Tuple[int, ...]

    def tree_flatten(self):
        return (
            (self.data_fp8, self.scale, self.data_bf16),
            (self.block, self.shape),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def is_quantized(self) -> bool:
        return self.data_fp8 is not None

    def dequant(self) -> jnp.ndarray:
        if not self.is_quantized:
            return self.data_bf16
        bm, bk = self.block
        M, K = self.data_fp8.shape
        xb = self.data_fp8.astype(jnp.float32).reshape(
            M // bm, bm, K // bk, bk
        )
        xb = xb / self.scale[:, None, :, None]
        return xb.reshape(M, K)[: self.shape[0], : self.shape[1]].astype(
            jnp.bfloat16
        )


def _pad_to(x: jnp.ndarray, bm: int, bk: int) -> jnp.ndarray:
    m, k = x.shape
    return jnp.pad(x, ((0, (-m) % bm), (0, (-k) % bk)))


def quantize_weight(
    w: jnp.ndarray, policy: MoRPolicy
) -> Tuple[QTensor, Dict[str, float]]:
    """Apply the MoR tensor-level decision to one weight matrix.

    Returns a QTensor (FP8 if the Eq. 2 metric accepts, else BF16) plus
    decision stats. Host-side, ahead of serving.
    """
    assert w.ndim == 2
    part = partition_of(policy)
    scales = compute_scales(w, part, E4M3, algo=policy.algo)
    wq = quant_dequant_with_scales(w, part, E4M3, scales)
    err = float(relative_error(w, wq))
    ok = policy.enabled and err < policy.threshold
    bm, bk = part.resolve(w.shape)
    if ok:
        wp = _pad_to(w.astype(jnp.float32), bm, bk)
        M, K = wp.shape
        xb = wp.reshape(M // bm, bm, K // bk, bk)
        payload = (
            jnp.clip(
                xb * scales.scale[:, None, :, None], -E4M3.amax, E4M3.amax
            )
            .astype(jnp.float8_e4m3fn)
            .reshape(M, K)
        )
        qt = QTensor(payload, scales.scale, None, (bm, bk), tuple(w.shape))
    else:
        qt = QTensor(None, None, w.astype(jnp.bfloat16), (bm, bk),
                     tuple(w.shape))
    return qt, {"rel_err": err, "quantized": float(ok)}


def qdot(x: jnp.ndarray, qw: QTensor) -> jnp.ndarray:
    """x @ W for a QTensor weight (dequant-fused in XLA; fp8_gemm on TPU)."""
    w = qw.dequant()
    return jnp.dot(
        x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)


def quantize_params(params, policy: MoRPolicy, min_size: int = 1 << 16):
    """Quantize every >=2-D weight leaf of a model params tree; returns
    (new tree with QTensor leaves where accepted, per-leaf stats)."""
    stats: Dict[str, Dict[str, float]] = {}

    def visit(path, leaf):
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        if (
            hasattr(leaf, "ndim") and leaf.ndim == 2
            and leaf.size >= min_size and "embed" not in name
            and "norm" not in name
        ):
            qt, st = quantize_weight(leaf, policy)
            stats[name] = st
            return qt
        return leaf

    new = jax.tree_util.tree_map_with_path(visit, params)
    return new, stats
