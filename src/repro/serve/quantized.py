"""Real-quantization path for serving: sub-tensor QTensor weights
(mixed-representation block layout) decided ahead-of-time by the MoR
metric.

Training uses fake quantization (paper Fig. 4); at serving time the same
MoR decision becomes a *storage* decision -- now per 128x128 block, not
per tensor: each block of a weight is stored as E4M3 bytes, E5M2 bytes,
or BF16 passthrough (``repro.kernels.ref.MixedOperand``: uint8 fp8
payload + original-precision buffer + per-block tag/GAM-scale arrays),
exactly the layout the mixed-representation block GEMM consumes.
``qdot`` feeds the stored payloads straight into
``repro.kernels.ops.mixed_gemm`` (one fused kernel launch on TPU; jnp
reference elsewhere) -- no dequantized weight copy is ever
materialized.

Storage/bandwidth accounting (decode is weight-bandwidth-bound, so
this is the serving speedup): a weight whose blocks all quantize to
fp8 stores ~1 byte/element -- the unused payload lanes collapse to one
don't-care block each (``MixedOperand.compact``) that stays
VMEM-resident -- i.e. half the dense bf16 bytes. A fully-NVFP4 weight
(recipe 'sub4') stores ~0.56 bytes/element: 0.5 B of packed E2M1
nibbles + 1/16 B of E4M3 micro scales, with the fp8 and bf16 lanes
both compact. A genuinely *mixed* weight keeps its referenced lanes
dense (the fused lowering, not the byte count, is this layout's win
there); streaming only each block's chosen payload needs the ragged
per-block DMA follow-up noted in kernels/README.md.
``QTensor.nbytes`` reports the truth.

The MoR recipe is whatever the policy says: 'tensor' reproduces the old
all-or-nothing behaviour (every block E4M3 or every block BF16), 'sub2'
and 'sub3' make genuinely mixed tensors, 'sub4' adds packed-nibble
NVFP4 blocks to the mixture. Layer-stacked (L, K, N)
weights quantize per layer (``quantize_weight_stacked``); the scan over
the block stack slices the QTensor leaves, so every block-stack GEMM of
the engine runs through the mixed kernel too.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MoRPolicy
from repro.core.mor import (
    STAT_FRAC_BF16,
    STAT_FRAC_E4M3,
    STAT_FRAC_E5M2,
    STAT_FRAC_NVFP4,
    STAT_REL_ERR,
    quantize_for_gemm,
)
from repro.kernels import ops as kops
from repro.kernels.ref import TAG_BF16, MixedOperand

__all__ = [
    "QTensor",
    "quantize_weight",
    "quantize_weight_stacked",
    "qdot",
    "quantize_params",
]


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class QTensor:
    """A real-quantized weight: per-block mixed-representation storage.

    ``mo`` is the weight's (N, K) *quantization view* (transposed so the
    serving GEMM's contraction axis is last, paper §3.1); ``shape`` is
    the original (K, N). ``stats`` is the STATS_WIDTH MoR stats vector
    of the quantization event (rides along as a leaf so it survives
    jit/donation).

    A QTensor is an ordinary pytree: it jits, donates, and shards. For
    tensor-parallel serving, ``sharding.rules.qtensor_pspec_from_dense``
    maps the dense weight's PartitionSpec onto all leaves (payloads,
    tags, scales shard together on the block grid; stats replicate) --
    see docs/sharding.md.

    >>> import jax.numpy as jnp
    >>> from repro.core import MoRPolicy
    >>> from repro.serve.quantized import quantize_weight
    >>> w = jnp.ones((128, 64), jnp.bfloat16)          # (K, N)
    >>> qt, info = quantize_weight(w, MoRPolicy(recipe="sub3"))
    >>> qt.shape, qt.mo.shape                          # (K,N) vs (N,K) view
    ((128, 64), (64, 128))
    >>> qt.is_quantized, qt.frac_quantized             # every block fp8
    (True, 1.0)
    >>> bool((qt.dequant() == w).all())                # exact for ones
    True
    """

    mo: MixedOperand
    stats: jnp.ndarray
    shape: Tuple[int, ...]

    def tree_flatten(self):
        return ((self.mo, self.stats), (self.shape,))

    def tree_flatten_with_keys(self):
        # Named key paths for the payload-lane taint checker
        # (repro.analysis.jaxpr_lint): lanes show up as .mo.payload_q
        # etc. in flattened argument paths.
        return (
            (
                (jax.tree_util.GetAttrKey("mo"), self.mo),
                (jax.tree_util.GetAttrKey("stats"), self.stats),
            ),
            (self.shape,),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def as_mixed_operand(self) -> MixedOperand:
        """The hook ``core.linear.mor_dot`` dispatches on: serving
        matmuls consume the payloads directly via the mixed kernel."""
        return self.mo

    @property
    def is_stacked(self) -> bool:
        """Layer-stacked weight: leaves carry a leading layer axis that
        ``lax.scan`` over the block stack slices off per layer."""
        return self.mo.tags.ndim == 3

    @property
    def nbytes(self) -> int:
        """Actual storage bytes (payloads + tags + scales + stats)."""
        return int(sum(
            l.size * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(self)
        ))

    # ---- host-side inspection helpers (concrete arrays only) --------
    @property
    def tags(self) -> jnp.ndarray:
        return self.mo.tags

    @property
    def is_quantized(self) -> bool:
        """True if any block is stored as fp8 payload."""
        return bool((np.asarray(self.mo.tags) != TAG_BF16).any())

    @property
    def frac_quantized(self) -> float:
        return float((np.asarray(self.mo.tags) != TAG_BF16).mean())

    def dequant(self) -> jnp.ndarray:
        """(K, N) -- or (L, K, N) if stacked -- bf16 reconstruction
        (tests / legacy fallback path)."""
        if not self.is_stacked:
            return self.mo.dequant().T.astype(jnp.bfloat16)
        mats = [
            _layer_mo(self.mo, l).dequant().T
            for l in range(self.mo.tags.shape[0])
        ]
        return jnp.stack(mats).astype(jnp.bfloat16)


def _layer_mo(mo: MixedOperand, l: int) -> MixedOperand:
    """Layer ``l``'s 2-D view of a stacked MixedOperand (host-side; the
    in-graph equivalent is lax.scan's leading-axis slicing)."""

    def sl(buf):
        return buf[l] if buf.ndim == 3 else buf

    return MixedOperand(
        payload_q=sl(mo.payload_q),
        payload_bf16=sl(mo.payload_bf16),
        tags=mo.tags[l],
        scales=mo.scales[l],
        block=mo.block,
        shape=mo.shape,
        payload_nib=sl(mo.payload_nib),
        micro_scales=sl(mo.micro_scales),
        has_nvfp4=mo.has_nvfp4,
    )


def quantize_weight(
    w: jnp.ndarray, policy: MoRPolicy
) -> Tuple[QTensor, Dict[str, float]]:
    """Apply the MoR decision to one weight matrix, per block.

    Runs the policy's recipe on the (N, K) transposed view (contraction
    last for the serving GEMM) and packs the winning representation of
    every block for real (``quantize_for_gemm`` handles the disabled
    policy as an all-BF16 passthrough pack). Host-side, ahead of
    serving. Returns the QTensor plus decision stats.
    """
    if w.ndim != 2:
        raise ValueError(
            f"quantize_weight wants a 2-D weight, got {w.shape}"
        )
    pol = policy if policy.partition == "block" else policy.replace(
        partition="block"
    )
    mo, stats = quantize_for_gemm(w.T, pol)
    qt = QTensor(mo.compact(), stats, tuple(w.shape))
    s = np.asarray(stats)
    return qt, {
        "rel_err": float(s[STAT_REL_ERR]),
        "quantized": float(qt.frac_quantized > 0),
        "frac_e4m3": float(s[STAT_FRAC_E4M3]),
        "frac_e5m2": float(s[STAT_FRAC_E5M2]),
        "frac_bf16": float(s[STAT_FRAC_BF16]),
        "frac_nvfp4": float(s[STAT_FRAC_NVFP4]),
    }


def quantize_weight_stacked(
    w3: jnp.ndarray, policy: MoRPolicy
) -> Tuple[QTensor, Dict[str, float]]:
    """Per-block MoR decision for a layer-stacked (L, K, N) weight.

    Each layer quantizes independently (own group amax / decisions);
    the resulting MixedOperand leaves carry a leading L axis that
    ``lax.scan`` over the block stack slices per layer, so the scanned
    model body sees ordinary 2-D QTensors.
    """
    if w3.ndim != 3:
        raise ValueError(
            "quantize_weight_stacked wants a layer-stacked (L, K, N) "
            f"weight, got {w3.shape}"
        )
    pol = policy if policy.partition == "block" else policy.replace(
        partition="block"
    )
    packed = [quantize_for_gemm(w3[l].T, pol) for l in range(w3.shape[0])]
    mo = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[m for m, _ in packed]
    )
    stats = jnp.stack([s for _, s in packed])
    qt = QTensor(mo.compact(), stats, tuple(w3.shape[1:]))
    s = np.asarray(stats)
    return qt, {
        "rel_err": float(s[:, 1].mean()),
        "quantized": float(qt.frac_quantized > 0),
        "frac_e4m3": float(s[:, 3].mean()),
        "frac_e5m2": float(s[:, 4].mean()),
        "frac_bf16": float(s[:, 5].mean()),
        "frac_nvfp4": float(s[:, 8].mean()),
    }


def qdot(x: jnp.ndarray, qw: QTensor, *, backend: str = "auto",
         tile=None) -> jnp.ndarray:
    """x @ W for a (single-matrix) sub-tensor QTensor weight.

    The activation is wrapped as an all-BF16 pack and both operands go
    through the mixed-representation block GEMM -- a single fused kernel
    launch per GEMM on TPU, the jnp reference under ``backend='xla'``.
    ``tile`` (a ``kernels.ops.GemmTile``) overrides the GEMM's
    decode-amortization autotune for this weight's shape.

    >>> import jax.numpy as jnp
    >>> from repro.core import MoRPolicy
    >>> from repro.serve.quantized import quantize_weight, qdot
    >>> w = jnp.ones((128, 64), jnp.bfloat16)
    >>> qt, _ = quantize_weight(w, MoRPolicy(recipe="sub3"))
    >>> y = qdot(jnp.ones((2, 128), jnp.bfloat16), qt)
    >>> y.shape, str(y.dtype)
    ((2, 64), 'bfloat16')
    >>> float(y[0, 0])                 # ones @ ones, exact under fp8
    128.0
    """
    if qw.is_stacked:
        raise ValueError(
            "qdot takes a single-matrix QTensor; a layer-stacked weight "
            "is consumed per layer by lax.scan slicing (or slice it "
            "host-side first)"
        )
    x2, lead = x.reshape(-1, x.shape[-1]), x.shape[:-1]
    y = kops.mixed_dot(x2, qw.mo, out_dtype=x.dtype, backend=backend,
                       tile=tile)
    return y.reshape(*lead, qw.shape[1])


def _is_gemm_weight(name: str, leaf) -> bool:
    """True for leaves that feed a mor_dot / head GEMM as the weight.

    Excluded by name *segment*: embeddings, norm scales (``ln1/scale``
    etc. -- stacked norm scales are 2-D and would otherwise slip past a
    substring check), routers (consumed by a plain einsum), biases.
    2-D = single matrix, 3-D = layer-stacked; 4-D stacked-expert MoE
    weights are not supported yet.
    """
    if not hasattr(leaf, "ndim") or leaf.ndim not in (2, 3):
        return False
    for seg in name.split("/"):
        if (
            "embed" in seg or "norm" in seg or seg.startswith("ln")
            or seg in ("scale", "bias", "router")
        ):
            return False
    return True


def quantize_params(params, policy: MoRPolicy, min_size: int = 1 << 16):
    """Quantize every GEMM-weight leaf of a model params tree (single
    matrices and layer-stacked (L, K, N) weights alike); returns (new
    tree with QTensor leaves, per-leaf stats). ``min_size`` bounds the
    per-matrix element count below which a leaf stays dense."""
    stats: Dict[str, Dict[str, float]] = {}

    def visit(path, leaf):
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        if not _is_gemm_weight(name, leaf):
            return leaf
        per_matrix = leaf.shape[-2] * leaf.shape[-1]
        if per_matrix < min_size:
            return leaf
        if leaf.ndim == 2:
            qt, st = quantize_weight(leaf, policy)
        else:
            qt, st = quantize_weight_stacked(leaf, policy)
        stats[name] = st
        return qt

    new = jax.tree_util.tree_map_with_path(visit, params)
    return new, stats
