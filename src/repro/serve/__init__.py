from .engine import Engine, Request, ServeConfig
from .quantized import (
    QTensor,
    qdot,
    quantize_params,
    quantize_weight,
    quantize_weight_stacked,
)

__all__ = ["Engine", "Request", "ServeConfig", "QTensor", "qdot",
           "quantize_params", "quantize_weight", "quantize_weight_stacked"]
