from .engine import Engine, PromptTooLongError, Request, ServeConfig
from .paged import PagedKVPool
from .quantized import (
    QTensor,
    qdot,
    quantize_params,
    quantize_weight,
    quantize_weight_stacked,
)

__all__ = ["Engine", "Request", "ServeConfig", "PromptTooLongError",
           "PagedKVPool", "QTensor", "qdot", "quantize_params",
           "quantize_weight", "quantize_weight_stacked"]
