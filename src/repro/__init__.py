"""MoR: Mixture Of Representations for mixed-precision training --
JAX/Pallas reproduction. See docs/architecture.md for the module map."""
