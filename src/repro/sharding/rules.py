"""Sharding rules: parameter, optimizer-state, batch and cache
PartitionSpecs for every architecture.

Megatron-style TP over 'model':
  wqkv / fc1 / expert-w1  -> column-parallel (shard output features)
  wo   / fc2 / expert-w2  -> row-parallel    (shard input features)
  embeddings / lm_head    -> vocab-sharded
  MoE experts             -> expert-parallel (shard E)
  norms / small ssm vecs  -> replicated
DP over ('pod','data') shards the batch. ZeRO-1: optimizer moments and
f32 master weights are additionally sharded over 'data' on the largest
dimension the param spec leaves free.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

__all__ = [
    "param_specs", "opt_state_spec_from_param", "batch_spec", "cache_specs_tree",
    "named_shardings", "zero1_spec",
]

# name-fragment -> (spec builder). Matched against the flattened path.
# Specs are for the *unstacked* per-layer shapes; stacked layer params get
# a leading None inserted.


def _leaf_spec(path: str, leaf) -> P:
    ndim = leaf.ndim
    # Embeddings / heads: vocab-sharded.
    if path.endswith("embed") or path.endswith("lm_head"):
        # embed (V, d) -> shard V; lm_head (d, V) -> shard V.
        return P("model", None) if path.endswith("embed") else P(None, "model")
    # Norm scales / biases / small vectors: replicated.
    if ndim <= 1:
        return P(*([None] * ndim))
    # MoE experts (E, d, f): expert-parallel on E.
    if "moe" in path and ("w1" in path or "w2" in path):
        return P("model", None, None)
    if "router" in path:
        return P(None, None)
    # Column-parallel (shard output dim).
    col = ("wqkv", "wi", "w_in", "w_up", "w_qkv", "w_x", "xwq", "xwkv",
           "w_ff1")
    # Row-parallel (shard input dim).
    row = ("wo", "w_out", "w_down", "xwo", "w_ff2")
    last = path.split("/")[-1]
    if last in col:
        return P(*([None] * (ndim - 1)), "model")
    if last in row:
        return P("model", *([None] * (ndim - 1)))
    if last == "r":  # sLSTM recurrence (H, dh, 4dh): head-sharded if even.
        return P(None, None, None)
    if last == "conv_w":
        return P(None, "model")
    if last in ("w_bc", "w_dt_down"):
        return P("model", None)
    if last == "w_dt_up":
        return P(None, "model")
    if last in ("A_log", "D", "dt_bias"):
        return P("model", None) if ndim == 2 else P("model")
    return P(*([None] * ndim))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def param_specs(cfg: ArchConfig, params_shape) -> Any:
    """PartitionSpec pytree matching a params (shape) pytree.

    Stacked block params (leading n_units axis) get a leading None.
    """

    def spec_for(path, leaf):
        p = _path_str(path)
        stacked = "blocks" in p
        base = _leaf_spec(p, _Unstacked(leaf) if stacked else leaf)
        if stacked:
            return P(None, *base)
        return base

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


class _Unstacked:
    """Shape view dropping the stacked layer axis."""

    def __init__(self, leaf):
        self.ndim = leaf.ndim - 1
        self.shape = leaf.shape[1:]


def zero1_spec(spec: P, shape: Tuple[int, ...], data_axes=("data",)) -> P:
    """Extend a param spec with 'data' sharding on the largest free dim
    divisible by the data-axis size (ZeRO-1 optimizer partitioning)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    best, best_size = None, 0
    for i, (s, n) in enumerate(zip(entries, shape)):
        if s is None and n % 16 == 0 and n > best_size:
            best, best_size = i, n
    if best is not None:
        entries[best] = data_axes if len(data_axes) > 1 else data_axes[0]
    return P(*entries)


def opt_state_spec_from_param(cfg: ArchConfig, params_shape, multi_pod=False):
    """Specs for (master, m, v) f32 optimizer triples: param spec + ZeRO-1."""
    pspecs = param_specs(cfg, params_shape)
    data_axes = ("data",)

    def extend(spec, leaf):
        return zero1_spec(spec, leaf.shape, data_axes)

    return jax.tree.map(extend, pspecs, params_shape)


def batch_spec(multi_pod: bool = False) -> P:
    return P(("pod", "data") if multi_pod else "data")


_TP = 16  # model-axis size of the production meshes


def _cache_leaf_spec(path: str, shape, batch) -> P:
    """Cache entries: (n_units, B, ...) -- batch over data axes; the kv
    seq dim over 'model' when divisible (context-parallel decode,
    DESIGN.md §4), else replicated over model."""
    ndim = len(shape)

    def tp_if(axis):
        return "model" if shape[axis] % _TP == 0 else None

    if path.endswith("/k") or path.endswith("/v") or path.endswith("xk") \
            or path.endswith("xv"):
        # (L, B, S, hkv, hd): shard S over model (works for any kv count).
        return P(None, batch, tp_if(2), None, None)
    if path.endswith("k_scale") or path.endswith("v_scale"):
        return P(None, batch, tp_if(2), None)
    if path.endswith("C"):
        return P(None, batch, None, tp_if(3), None)
    if path.endswith("conv"):
        return P(None, batch, None, tp_if(3))
    if path.endswith("/h") and ndim == 4:  # mamba h (L,B,di,N)
        return P(None, batch, tp_if(2), None)
    return P(None, batch, *([None] * (ndim - 2)))


def cache_specs_tree(cfg: ArchConfig, cache_shape, multi_pod: bool = False):
    batch = ("pod", "data") if multi_pod else "data"

    def spec_for(path, leaf):
        return _cache_leaf_spec("/" + _path_str(path), leaf.shape, batch)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def named_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
