"""Sharding rules: parameter, optimizer-state, batch, cache and
quantized-weight PartitionSpecs for every architecture.

Megatron-style TP over 'model':
  wqkv / fc1 / expert-w1  -> column-parallel (shard output features)
  wo   / fc2 / expert-w2  -> row-parallel    (shard input features)
  embeddings / lm_head    -> vocab-sharded
  MoE experts             -> expert-parallel (shard E)
  norms / small ssm vecs  -> replicated
DP over ('pod','data') shards the batch. ZeRO-1: optimizer moments and
f32 master weights are additionally sharded over 'data' on the largest
dimension the param spec leaves free.

Quantized leaves (docs/sharding.md): a ``MixedOperand`` shards *as one
unit* -- uint8 payload, original-precision dual buffer, per-block tag
and GAM-scale grids all partition along the same block grid
(``mixed_operand_pspec``), so a shard owns complete blocks with their
metadata and the mixed GEMM kernel runs shard-locally. ``QTensor``
serving weights reuse the dense rule of the weight they replace,
transposed into the (N, K) quantization view
(``qtensor_pspec_from_dense``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.collectives import compat_shard_map
from repro.core.formats import NVFP4_MICRO
from repro.kernels.ref import MixedOperand

__all__ = [
    "param_specs", "opt_state_spec_from_param", "batch_spec", "cache_specs_tree",
    "named_shardings", "zero1_spec",
    "mixed_operand_pspec", "qtensor_pspec_from_dense",
    "quantized_param_specs", "packed_moment_pspec", "opt_state_specs",
    "compat_shard_map",
]

# name-fragment -> (spec builder). Matched against the flattened path.
# Specs are for the *unstacked* per-layer shapes; stacked layer params get
# a leading None inserted.


def _leaf_spec(path: str, leaf) -> P:
    ndim = leaf.ndim
    # Embeddings / heads: vocab-sharded.
    if path.endswith("embed") or path.endswith("lm_head"):
        # embed (V, d) -> shard V; lm_head (d, V) -> shard V.
        return P("model", None) if path.endswith("embed") else P(None, "model")
    # Norm scales / biases / small vectors: replicated.
    if ndim <= 1:
        return P(*([None] * ndim))
    # MoE experts (E, d, f): expert-parallel on E.
    if "moe" in path and ("w1" in path or "w2" in path):
        return P("model", None, None)
    if "router" in path:
        return P(None, None)
    # Column-parallel (shard output dim).
    col = ("wqkv", "wi", "w_in", "w_up", "w_qkv", "w_x", "xwq", "xwkv",
           "w_ff1")
    # Row-parallel (shard input dim).
    row = ("wo", "w_out", "w_down", "xwo", "w_ff2")
    last = path.split("/")[-1]
    if last in col:
        return P(*([None] * (ndim - 1)), "model")
    if last in row:
        return P("model", *([None] * (ndim - 1)))
    if last == "r":  # sLSTM recurrence (H, dh, 4dh): head-sharded if even.
        return P(None, None, None)
    if last == "conv_w":
        return P(None, "model")
    if last in ("w_bc", "w_dt_down"):
        return P("model", None)
    if last == "w_dt_up":
        return P(None, "model")
    if last in ("A_log", "D", "dt_bias"):
        return P("model", None) if ndim == 2 else P("model")
    return P(*([None] * ndim))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def param_specs(cfg: ArchConfig, params_shape) -> Any:
    """PartitionSpec pytree matching a params (shape) pytree.

    Stacked block params (leading n_units axis) get a leading None.
    """

    def spec_for(path, leaf):
        p = _path_str(path)
        stacked = "blocks" in p
        base = _leaf_spec(p, _Unstacked(leaf) if stacked else leaf)
        if stacked:
            return P(None, *base)
        return base

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


class _Unstacked:
    """Shape view dropping the stacked layer axis."""

    def __init__(self, leaf):
        self.ndim = leaf.ndim - 1
        self.shape = leaf.shape[1:]


def zero1_spec(spec: P, shape: Tuple[int, ...], data_axes=("data",)) -> P:
    """Extend a param spec with 'data' sharding on the largest free dim
    divisible by the data-axis size (ZeRO-1 optimizer partitioning)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    best, best_size = None, 0
    for i, (s, n) in enumerate(zip(entries, shape)):
        if s is None and n % 16 == 0 and n > best_size:
            best, best_size = i, n
    if best is not None:
        entries[best] = data_axes if len(data_axes) > 1 else data_axes[0]
    return P(*entries)


def opt_state_spec_from_param(cfg: ArchConfig, params_shape, multi_pod=False):
    """Specs for (master, m, v) f32 optimizer triples: param spec + ZeRO-1."""
    pspecs = param_specs(cfg, params_shape)
    data_axes = ("data",)

    def extend(spec, leaf):
        return zero1_spec(spec, leaf.shape, data_axes)

    return jax.tree.map(extend, pspecs, params_shape)


def batch_spec(multi_pod: bool = False) -> P:
    return P(("pod", "data") if multi_pod else "data")


_TP = 16  # model-axis size of the production meshes


def _cache_leaf_spec(path: str, shape, batch) -> P:
    """Cache entries: (n_units, B, ...) -- batch over data axes; the kv
    seq dim over 'model' when divisible (context-parallel decode,
    docs/sharding.md), else replicated over model."""
    ndim = len(shape)

    def tp_if(axis):
        return "model" if shape[axis] % _TP == 0 else None

    if path.endswith("/k") or path.endswith("/v") or path.endswith("xk") \
            or path.endswith("xv"):
        # (L, B, S, hkv, hd): shard S over model (works for any kv count).
        return P(None, batch, tp_if(2), None, None)
    if path.endswith("k_scale") or path.endswith("v_scale"):
        return P(None, batch, tp_if(2), None)
    if path.endswith("C"):
        return P(None, batch, None, tp_if(3), None)
    if path.endswith("conv"):
        return P(None, batch, None, tp_if(3))
    if path.endswith("/h") and ndim == 4:  # mamba h (L,B,di,N)
        return P(None, batch, tp_if(2), None)
    return P(None, batch, *([None] * (ndim - 2)))


def cache_specs_tree(cfg: ArchConfig, cache_shape, multi_pod: bool = False):
    batch = ("pod", "data") if multi_pod else "data"

    def spec_for(path, leaf):
        return _cache_leaf_spec("/" + _path_str(path), leaf.shape, batch)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def named_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------- quantized --


def mixed_operand_pspec(
    mo: MixedOperand,
    rows: Optional[str] = None,
    cols: Optional[str] = None,
) -> Tuple[P, P, P, P, P, P]:
    """(payload_q, payload_bf16, payload_nib, micro_scales, tags,
    scales) PartitionSpecs for one mixed-layout operand, sharding its
    quantization-view rows over ``rows`` and its contraction blocks
    over ``cols``.

    All six leaves partition along the same block grid -- the packed
    4-bit NVFP4 lane holds whole (br/2, bk) nibble blocks per payload
    block and the (br, bk/16) micro-scale grid holds whole micro-scale
    rows per block, so a shard owns complete blocks together with
    *all* their metadata -- the invariant the per-shard mixed GEMM
    kernel relies on (the SMEM tag/scale operands of a shard describe
    exactly its payload blocks). A *compact* payload buffer (one
    don't-care block, see ``MixedOperand.compact``) is replicated: it
    has no row extent to shard and is dead weight either way. Leading
    stack axes (layer-stacked serving weights) stay unsharded.
    """
    lead = mo.tags.ndim - 2
    Rp, Kp = mo.padded_shape

    def sp(*axes) -> P:
        return P(*([None] * lead), *axes)

    def payload_spec(buf, full_shape) -> P:
        if tuple(buf.shape[-2:]) != tuple(full_shape):  # compact buffer
            return sp(None, None)
        return sp(rows, cols)

    return (
        payload_spec(mo.payload_q, (Rp, Kp)),
        payload_spec(mo.payload_bf16, (Rp, Kp)),
        payload_spec(mo.payload_nib, (Rp // 2, Kp)),
        payload_spec(mo.micro_scales, (Rp, Kp // NVFP4_MICRO)),
        sp(rows, cols),
        sp(rows, cols),
    )


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def qtensor_pspec_from_dense(qt, dense_spec: P, mesh: Optional[Mesh] = None):
    """A QTensor-shaped PartitionSpec pytree from the dense rule of the
    (K, N) weight it replaced.

    The QTensor stores the weight in its transposed (N, K) quantization
    view, so a dense ``P(a_K, a_N)`` becomes rows=``a_N``,
    cols=``a_K`` on the mixed-operand leaves; stats are replicated.
    Stacked weights (dense ``P(None, a_K, a_N)``) keep the leading
    layer axis unsharded.

    With ``mesh``, an axis that does not divide the *block grid* is
    demoted to replicated: quantized leaves shard in whole 128x128
    blocks or not at all (a split block would separate payload rows
    from their tag/scale cell).
    """
    from repro.serve.quantized import QTensor  # avoid import cycle

    lead = qt.mo.tags.ndim - 2
    entries = list(dense_spec) + [None] * (lead + 2 - len(dense_spec))
    a_k, a_n = entries[-2], entries[-1]
    if mesh is not None:
        nr, nk = qt.mo.tags.shape[-2], qt.mo.tags.shape[-1]
        if nr % _axis_size(mesh, a_n):
            a_n = None
        if nk % _axis_size(mesh, a_k):
            a_k = None
    pq, pbf, nib, ms, tags, scales = mixed_operand_pspec(
        qt.mo, rows=a_n, cols=a_k
    )
    # The spec pytree must share the value pytree's static aux data
    # (including the has_nvfp4 hint) or tree_map over (params, specs)
    # rejects the pair as structure-mismatched.
    mo_spec = MixedOperand(
        payload_q=pq, payload_bf16=pbf, tags=tags, scales=scales,
        block=qt.mo.block, shape=qt.mo.shape,
        payload_nib=nib, micro_scales=ms, has_nvfp4=qt.mo.has_nvfp4,
    )
    stats_spec = P(*([None] * qt.stats.ndim))
    return QTensor(mo=mo_spec, stats=stats_spec, shape=qt.shape)


def quantized_param_specs(
    cfg: ArchConfig, params, mesh: Optional[Mesh] = None
) -> Any:
    """PartitionSpec pytree for a params tree whose GEMM weights were
    replaced by QTensors (``serve.quantized.quantize_params``).

    Dense leaves keep their :func:`param_specs` rule; each QTensor leaf
    derives its spec from the dense rule of the weight it replaced, so
    e.g. a column-parallel ``wo`` stays row-parallel in its (N, K)
    quantization view and the serving GEMMs stay tensor-parallel
    *without dequantizing*. ``mesh`` enables block-grid divisibility
    demotion (see :func:`qtensor_pspec_from_dense`).
    """
    from repro.serve.quantized import QTensor  # avoid import cycle

    def spec_for(path, leaf):
        p = _path_str(path)
        stacked = "blocks" in p
        if isinstance(leaf, QTensor):
            # Dense rule on the original (K, N) shape, stack axis
            # re-inserted for layer-stacked weights, then transposed
            # into the quantization view.
            base = _leaf_spec(p, _ShapeView(leaf.shape))
            dense = P(None, *base) if leaf.is_stacked else base
            return qtensor_pspec_from_dense(leaf, dense, mesh)
        base = _leaf_spec(p, _Unstacked(leaf) if stacked else leaf)
        return P(None, *base) if stacked else base

    return jax.tree_util.tree_map_with_path(
        spec_for, params, is_leaf=lambda x: isinstance(x, QTensor)
    )


class _ShapeView:
    """Duck-typed (ndim, shape) stand-in for _leaf_spec rule matching."""

    def __init__(self, shape):
        self.shape = tuple(shape)
        self.ndim = len(self.shape)


# ------------------------------------------------ compressed opt state --


def packed_moment_pspec(pm, rows=None, mesh: Optional[Mesh] = None):
    """A PackedMoment-shaped PartitionSpec for one packed Adam moment.

    ZeRO-style: the quantization-view *rows* shard over ``rows``
    (normally the 'data' axis) when the block grid divides the axis
    size -- whole 128-row block rows move together with their tag/scale
    cells, the same invariant as :func:`mixed_operand_pspec`. An axis
    that does not divide the block grid is demoted to replicated
    (quantized leaves shard in whole blocks or not at all). The stats
    row is replicated.
    """
    from repro.optim.moments import PackedMoment  # avoid import cycle

    a_r = rows
    if mesh is not None and a_r is not None:
        if pm.mo.tags.shape[-2] % _axis_size(mesh, a_r):
            a_r = None
    pq, pbf, nib, ms, tags, scales = mixed_operand_pspec(
        pm.mo, rows=a_r, cols=None
    )
    mo_spec = MixedOperand(
        payload_q=pq, payload_bf16=pbf, tags=tags, scales=scales,
        block=pm.mo.block, shape=pm.mo.shape,
        payload_nib=nib, micro_scales=ms, has_nvfp4=pm.mo.has_nvfp4,
    )
    return PackedMoment(
        mo=mo_spec, stats=P(None), shape=pm.shape
    )


def opt_state_specs(
    cfg: ArchConfig,
    opt_state,
    data_axes=("data",),
    mesh: Optional[Mesh] = None,
):
    """An OptState-shaped PartitionSpec tree for the (possibly
    MoR-compressed) optimizer state.

    Master weights and dense moment leaves get the param spec extended
    with ZeRO-1 data sharding (:func:`zero1_spec`); PackedMoment leaves
    get :func:`packed_moment_pspec` (rows over the data axis, block-
    grid divisibility demotion under ``mesh``); the error-feedback
    residual -- gradient-shaped -- reuses the master layout, matching
    the ZeRO-2 gradient constraint in the train step; ``step`` is
    replicated.
    """
    from repro.optim.adamw import OptState
    from repro.optim.moments import PackedMoment  # avoid import cycle

    rows = data_axes if len(data_axes) > 1 else data_axes[0]
    pspecs = param_specs(cfg, opt_state.master)

    def ext(spec, leaf):
        return zero1_spec(spec, leaf.shape, data_axes)

    master_specs = jax.tree.map(ext, pspecs, opt_state.master)

    def moment_specs(tree):
        return jax.tree.map(
            lambda leaf, spec: (
                packed_moment_pspec(leaf, rows=rows, mesh=mesh)
                if isinstance(leaf, PackedMoment)
                else zero1_spec(spec, leaf.shape, data_axes)
            ),
            tree, pspecs,
            is_leaf=lambda x: isinstance(x, PackedMoment),
        )

    return OptState(
        master=master_specs,
        m=moment_specs(opt_state.m),
        v=moment_specs(opt_state.v),
        step=P(),
        ef=(None if opt_state.ef is None
            else jax.tree.map(ext, pspecs, opt_state.ef)),
    )
