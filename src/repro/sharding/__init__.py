from . import rules
from .rules import (
    compat_shard_map,
    mixed_operand_pspec,
    qtensor_pspec_from_dense,
    quantized_param_specs,
)

__all__ = [
    "rules",
    "compat_shard_map",
    "mixed_operand_pspec",
    "qtensor_pspec_from_dense",
    "quantized_param_specs",
]
