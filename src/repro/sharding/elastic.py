"""Elastic re-mesh: resume a checkpoint onto a different device topology.

After node failures the healthy device set changes; this module rebuilds
a (possibly smaller) mesh from whatever devices exist, re-derives the
sharding specs for the new mesh, and device_puts the restored arrays --
the checkpoint layout is topology-agnostic (full arrays on host), so any
(data', model') factorization works as long as the model axis still
divides the sharded dims (rules fall back to replication otherwise).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.sharding import rules

__all__ = ["best_mesh_shape", "make_elastic_mesh", "reshard_tree"]


def best_mesh_shape(
    n_devices: int, prefer_model: int = 16
) -> Tuple[int, int]:
    """Largest (data, model) grid with model <= prefer_model that tiles
    the healthy device count (drops remainder devices)."""
    model = min(prefer_model, n_devices)
    while model > 1 and n_devices // model == 0:
        model //= 2
    data = max(n_devices // model, 1)
    return data, model


def make_elastic_mesh(devices=None, prefer_model: int = 16) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    data, model = best_mesh_shape(len(devices), prefer_model)
    used = devices[: data * model]
    import numpy as np

    return Mesh(
        np.asarray(used).reshape(data, model), ("data", "model")
    )


def reshard_tree(tree, spec_tree, mesh: Mesh):
    """device_put every leaf against its spec on the new mesh, demoting
    specs whose sharded dims no longer divide."""

    def put(leaf, spec):
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        fixed = []
        for dim, e in zip(leaf.shape, entries):
            if e is None:
                fixed.append(None)
                continue
            names = e if isinstance(e, tuple) else (e,)
            size = 1
            for n in names:
                size *= mesh.shape.get(n, 1)
            fixed.append(e if dim % size == 0 else None)
        return jax.device_put(leaf, NamedSharding(mesh, P(*fixed)))

    return jax.tree.map(put, tree, spec_tree,
                        is_leaf=lambda x: hasattr(x, "ndim"))


def elastic_restore(ckpt, step: int, target_shapes, cfg: ArchConfig,
                    devices=None):
    """Checkpoint -> host arrays -> new mesh shardings. Returns
    (tree, mesh)."""
    mesh = make_elastic_mesh(devices)
    host_tree = ckpt.restore(step, target_shapes)
    specs = rules.param_specs(cfg, host_tree)
    return reshard_tree(host_tree, specs, mesh), mesh
