"""Mixed-representation block GEMM kernel (Pallas, TPU target).

C = A @ B^T where both operands arrive in their *quantization view*
(rows x contraction, blocks aligned with the dot-product direction,
paper §3.1) and every (bm, bk) block carries its own representation tag
and GAM scale -- the per-block decisions of the fused ``mor_select``
kernel finally reach the matmul instead of being erased by a
dequantize-then-bf16-GEMM round trip.

Tri-lane payload layout (see ``kernels/README.md``):

  * ``payload_q``   (R, K) uint8   -- raw fp8 bits. E4M3 bit patterns for
    tag 0 blocks, E5M2 bit patterns for tag 1 blocks, zero (don't-care)
    for other tags. One byte per element regardless of which fp8
    format the block chose, so the buffer is a single dense array.
  * ``payload_bf16``(R, K) bf16    -- original values for tag 2 (BF16
    passthrough) blocks, zero (don't-care) elsewhere.
  * ``payload_nib`` (R/2, K) uint8 -- packed E2M1 nibbles for tag 3
    (NVFP4) blocks: within block (i, j), byte row r carries logical row
    r in its low nibble and row r + br/2 in its high nibble (row-halves
    packing -- decode is two vector nibble extracts + one sublane
    concat, no lane interleave).
  * ``micro_scales``(R, K/16) uint8 -- E4M3 bits of the NVFP4
    per-16-element micro scales.

Per (bm, bk) block the kernel bitcasts the uint8 payload to *both* fp8
dtypes, decodes the E2M1 nibbles arithmetically straight to the storage
dtype (every grid value and every vals*micro-scale product is exact in
bf16, so no f32 staging is needed) and expands the micro scales with an
exact one-hot f32 matmul, selects by tag, divides by the block's
reconstructed GAM scale, rounds to the stored dtype (Fig. 4: stored
values are BF16 -- this makes the fused GEMM consume exactly the
fake-quantization values of the training path), and upcasts to f32 for
the MXU. Accumulation is f32 in a VMEM scratch tile over the K grid
dimension (innermost, 'arbitrary').

Decode amortization: the naive (i, j, k) grid re-decodes A block
(i, k) once per N tile -- n_n times. Two static counter-measures,
chosen by ``ops.mixed_gemm``'s autotune table:

  * ``decode_cache`` -- a (n_k, bm, bk) f32 VMEM scratch keyed on the
    k step: the A stripe is decoded once per (i, k) (at j == 0) and
    re-read from VMEM for every other j. The j dimension demotes to
    'arbitrary' so the sweep order is guaranteed.
  * ``bn_mult`` -- the wider-bn fallback when the cache would not fit
    VMEM: one kernel step covers ``bn_mult`` B row blocks (each decoded
    with its own tag/scale cell), cutting A re-decodes by the same
    factor with no extra scratch.

Both are bit-exact: the cache replays identical decoded values, and a
wider N tile only concatenates B slabs whose per-output-element FMA
order is unchanged.

Tags (0 = E4M3, 1 = E5M2, 2 = BF16, 3 = NVFP4) and scales are (nr, nk)
arrays that live whole in SMEM; each grid step reads its own cells.
Selection by tag is a vectorized ``where`` over in-register candidates
-- no divergent control flow, which Mosaic would reject anyway.

Grid: (R_a/bm, R_b/(bn*bn_mult), K/bk).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import NVFP4_MICRO, decode_e2m1

from .ref import (
    TAG_BF16,
    TAG_E5M2,
    TAG_NVFP4,
    _ms_compact_shape,
    _nib_compact_shape,
    expand_micro_onehot,
    nvfp4_block_capable,
)

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)

__all__ = ["mixed_gemm_blocks", "DECODE_CACHE_BUDGET", "decode_cache_bytes"]

# VMEM budget for the k-keyed A-decode cache (f32 stripes); past this
# the autotune falls back to the wider-bn sweep. ~4 MiB leaves room for
# the payload blocks + accumulator in the ~16 MiB/core VMEM.
DECODE_CACHE_BUDGET = 4 * 1024 * 1024


def decode_cache_bytes(n_k: int, bm: int, bk: int) -> int:
    """Bytes of the (n_k, bm, bk) f32 decoded-A VMEM cache."""
    return n_k * bm * bk * 4


def _decode(q, bf, nib, ms, tag, scale, has_nv: bool, g0=0):
    """One block's payload lane values -> f32 stored values."""
    st_dtype = bf.dtype
    q4 = jax.lax.bitcast_convert_type(
        q, jnp.float8_e4m3fn
    ).astype(jnp.float32)
    q5 = jax.lax.bitcast_convert_type(
        q, jnp.float8_e5m2
    ).astype(jnp.float32)
    # Stored-value semantics (Fig. 4): the dequantized fp8 value is
    # rounded to the storage dtype before entering the matmul, exactly
    # like the fake-quantization path.
    f8 = (jnp.where(tag == TAG_E5M2, q5, q4) / scale).astype(st_dtype)
    out = jnp.where(tag == TAG_BF16, bf, f8)
    if has_nv:
        # Unpack row-halved E2M1 nibbles straight to the storage dtype
        # (grid values and the vals * micro-scale products are exact in
        # bf16 -- <= 5 significand bits), expand micro scales, apply
        # the two-level dequant. The only f32 step left is the final
        # division by the block scale, whose 23-bit mantissa a bf16
        # divide could double-round -- same op order as
        # ref.decode_mixed_ref after the exact-cast steps, so
        # interpret/xla stay bit-exact.
        n32 = nib.astype(jnp.int32)
        lo = decode_e2m1(n32 & 15, dtype=st_dtype)
        hi = decode_e2m1(n32 >> 4, dtype=st_dtype)
        vals = jnp.concatenate([lo, hi], axis=0)  # (br, bk)
        d = jax.lax.bitcast_convert_type(
            ms, jnp.float8_e4m3fn
        ).astype(jnp.float32)
        d_exp = expand_micro_onehot(d, vals.shape[-1], g0).astype(
            st_dtype
        )
        nv = ((vals * d_exp).astype(jnp.float32) / scale).astype(
            st_dtype
        )
        out = jnp.where(tag == TAG_NVFP4, nv, out)
    return out.astype(jnp.float32)


def _kernel(a_tag_ref, a_sc_ref, b_tag_ref, b_sc_ref,
            a_q_ref, a_bf_ref, a_nib_ref, a_ms_ref,
            b_q_ref, b_bf_ref, b_nib_ref, b_ms_ref, o_ref, acc_ref,
            *cache,
            n_k: int, g16: int, a_has_nv: bool, b_has_nv: bool,
            bn: int, bn_mult: int, b_dense: Tuple[bool, ...]):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Micro-scale stripes ride whole along the contraction axis; the
    # one-hot expansion selects grid step k's group window.
    def decode_a():
        return _decode(
            a_q_ref[...], a_bf_ref[...], a_nib_ref[...], a_ms_ref[...],
            a_tag_ref[i, k], a_sc_ref[i, k], a_has_nv, k * g16,
        )

    if cache:
        # Decode-once cache: the A stripe for this (i, k) is decoded at
        # the first N tile and replayed from VMEM for every other j
        # (the j grid dim is 'arbitrary', so the sweep order holds).
        a_cache_ref = cache[0]

        @pl.when(j == 0)
        def _():
            a_cache_ref[k] = decode_a()

        a = a_cache_ref[k]
    else:
        a = decode_a()

    qd, bfd, nibd, msd = b_dense

    def slab(ref, rows, s, dense):
        # A compact lane's pinned single block serves every sub-tile;
        # dense lanes carve the sub-tile's rows out of the wide block.
        if not dense or bn_mult == 1:
            return ref[...]
        return ref[s * rows:(s + 1) * rows, :]

    slabs = []
    for s in range(bn_mult):
        jj = j * bn_mult + s
        slabs.append(_decode(
            slab(b_q_ref, bn, s, qd),
            slab(b_bf_ref, bn, s, bfd),
            slab(b_nib_ref, bn // 2, s, nibd),
            slab(b_ms_ref, bn, s, msd),
            b_tag_ref[jj, k], b_sc_ref[jj, k], b_has_nv, k * g16,
        ))
    b = slabs[0] if bn_mult == 1 else jnp.concatenate(slabs, axis=0)
    # A (bm, bk) contracted with B (bn*bn_mult, bk) on the K axis:
    # C = A @ B^T.
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block", "out_dtype", "interpret", "a_has_nvfp4", "b_has_nvfp4",
        "decode_cache", "bn_mult",
    ),
)
def mixed_gemm_blocks(
    a_q: jnp.ndarray,
    a_bf: jnp.ndarray,
    a_nib: jnp.ndarray,
    a_ms: jnp.ndarray,
    a_tags: jnp.ndarray,
    a_scales: jnp.ndarray,
    b_q: jnp.ndarray,
    b_bf: jnp.ndarray,
    b_nib: jnp.ndarray,
    b_ms: jnp.ndarray,
    b_tags: jnp.ndarray,
    b_scales: jnp.ndarray,
    *,
    block: Tuple[int, int, int] = (128, 128, 128),
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
    a_has_nvfp4: bool | None = None,
    b_has_nvfp4: bool | None = None,
    decode_cache: bool | None = None,
    bn_mult: int = 1,
) -> jnp.ndarray:
    """a: (M, K)/(M/2, K)/(M, K/16) tri-lane payloads + (M/bm, K/bk)
    tags/scales; b: (N, K) quantization view (contraction last)
    likewise.

    Any payload lane of an operand may be *compact* -- a single
    don't-care block (see ``ref.MixedOperand.compact``) -- in which
    case its BlockSpec pins index (0, 0): the block stays VMEM-resident
    and contributes no per-step HBM traffic. The NVFP4 decode is
    skipped entirely (statically) when the ``{a,b}_has_nvfp4`` hint
    says no TAG_NVFP4 block exists (``MixedOperand.has_nvfp4``), when
    an operand's block geometry cannot hold NVFP4, or -- hint-less
    legacy callers -- when both sub-byte lanes are compact (for a
    single-block operand the compact and full shapes coincide, so only
    the hint can prove the lane dead).

    ``decode_cache`` (None = auto: on when the (n_k, bm, bk) f32 cache
    fits :data:`DECODE_CACHE_BUDGET` and more than one N tile exists)
    decodes each A stripe once per (i, k); ``bn_mult`` widens the N
    tile to ``bn_mult`` B blocks per step (the fallback when the cache
    would not fit). Both preserve bit-exactness; see the module
    docstring.

    Returns (M, N) = A @ B^T in out_dtype, f32-accumulated.
    """
    bm, bn, bk = block
    n_m, n_k = a_tags.shape
    n_n, n_k2 = b_tags.shape
    assert n_k == n_k2, (a_tags.shape, b_tags.shape)
    assert n_n % bn_mult == 0, (b_tags.shape, bn_mult)
    M, N, K = n_m * bm, n_n * bn, n_k * bk
    n_j = n_n // bn_mult

    def payload_spec(buf, compact_shape, blk_shape, idx):
        if buf.shape == compact_shape:  # compact: one shared block
            return pl.BlockSpec(compact_shape, lambda i, j, k: (0, 0))
        return pl.BlockSpec(blk_shape, idx)

    assert a_q.shape in ((M, K), (bm, bk)), (a_q.shape, (M, K), block)
    assert a_bf.shape in ((M, K), (bm, bk)), (a_bf.shape, (M, K), block)
    assert b_q.shape in ((N, K), (bn, bk)), (b_q.shape, (N, K), block)
    assert b_bf.shape in ((N, K), (bn, bk)), (b_bf.shape, (N, K), block)

    def nib_spec(buf, br, mult, idx):
        return payload_spec(
            buf, _nib_compact_shape((br, bk)), (mult * br // 2, bk), idx
        )

    def ms_spec(buf, br, mult, row_idx):
        # Micro-scale stripes ride whole along the contraction axis:
        # their (K/16) lane count is not 128-divisible, and TPU tiling
        # only accepts a non-divisible lane dim when it equals the
        # whole array's (the kernel windows the stripe per grid step).
        if buf.shape == _ms_compact_shape((br, bk)):
            return pl.BlockSpec(buf.shape, lambda i, j, k: (0, 0))
        return pl.BlockSpec(
            (mult * br, buf.shape[-1]),
            lambda i, j, k: (row_idx(i, j, k), 0),
        )

    def has_nv(br, n_r, nib, ms, hint):
        if not nvfp4_block_capable((br, bk)):
            return False
        if hint is not None:
            # The pack layer knows: packs built without the NVFP4
            # lanes, passthrough/transposed packs and compacted packs
            # with no TAG_NVFP4 all skip the decode outright -- this is
            # what resolves the single-block ambiguity below.
            return bool(hint)
        # Legacy heuristic: decode when the operand carries full
        # (dense) sub-byte buffers. For a single-block operand the
        # full and compact shapes coincide -- decode then too (a truly
        # compact don't-care lane has no TAG_NVFP4 to select it, so
        # the extra work is dead but correct).
        full_nib = (n_r * (br // 2), n_k * bk)
        full_ms = (n_r * br, n_k * bk // NVFP4_MICRO)
        return nib.shape == full_nib or tuple(ms.shape) == full_ms

    a_has_nv = has_nv(bm, n_m, a_nib, a_ms, a_has_nvfp4)
    b_has_nv = has_nv(bn, n_n, b_nib, b_ms, b_has_nvfp4)

    if decode_cache is None:
        decode_cache = (
            n_j > 1
            and decode_cache_bytes(n_k, bm, bk) <= DECODE_CACHE_BUDGET
        )

    b_dense = (
        b_q.shape == (N, K),
        b_bf.shape == (N, K),
        tuple(b_nib.shape) == (N // 2, K),
        tuple(b_ms.shape) == (N, K // NVFP4_MICRO),
    )
    kernel = functools.partial(
        _kernel, n_k=n_k, g16=bk // NVFP4_MICRO if a_has_nv or b_has_nv
        else 0, a_has_nv=a_has_nv, b_has_nv=b_has_nv, bn=bn,
        bn_mult=bn_mult, b_dense=b_dense,
    )
    a_idx = lambda i, j, k: (i, k)  # noqa: E731
    b_idx = lambda i, j, k: (j, k)  # noqa: E731
    scratch_shapes = [pltpu.VMEM((bm, bn * bn_mult), jnp.float32)]
    if decode_cache:
        scratch_shapes.append(pltpu.VMEM((n_k, bm, bk), jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=(n_m, n_j, n_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # a_tags (nm, nk)
            pl.BlockSpec(memory_space=pltpu.SMEM),  # a_scales (nm, nk)
            pl.BlockSpec(memory_space=pltpu.SMEM),  # b_tags (nn, nk)
            pl.BlockSpec(memory_space=pltpu.SMEM),  # b_scales (nn, nk)
            payload_spec(a_q, (bm, bk), (bm, bk), a_idx),
            payload_spec(a_bf, (bm, bk), (bm, bk), a_idx),
            nib_spec(a_nib, bm, 1, a_idx),
            ms_spec(a_ms, bm, 1, lambda i, j, k: i),
            payload_spec(b_q, (bn, bk), (bn_mult * bn, bk), b_idx),
            payload_spec(b_bf, (bn, bk), (bn_mult * bn, bk), b_idx),
            nib_spec(b_nib, bn, bn_mult, b_idx),
            ms_spec(b_ms, bn, bn_mult, lambda i, j, k: j),
        ],
        out_specs=pl.BlockSpec(
            (bm, bn * bn_mult), lambda i, j, k: (i, j)
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=scratch_shapes,
        compiler_params=_CompilerParams(
            dimension_semantics=(
                "parallel",
                # The A-decode cache is filled at j == 0 and replayed
                # across the N sweep: j must stay sequential then.
                "arbitrary" if decode_cache else "parallel",
                "arbitrary",
            )
        ),
        interpret=interpret,
    )(a_tags, a_scales, b_tags, b_scales,
      a_q, a_bf, a_nib, a_ms, b_q, b_bf, b_nib, b_ms)
