"""Flash attention forward kernel (Pallas, TPU target).

Online-softmax over KV blocks with (m, l, acc) persisted in VMEM scratch
across the innermost grid dimension; causal masking by true key/query
position. The (S, T) score matrix never leaves VMEM -- this kernel is the
hardware realization of the chunked XLA attention in
repro.models.attention (whose remat-ed scan is the portable fallback used
by the dry-run).

Layout: q (BH, S, d), k/v (BH, T, d) -- callers fold batch x heads.
``ops.flash_attention`` accepts the unfolded GQA layout ((B, S, Hq, dh)
queries against (B, T, Hkv, dh) caches) and repeats kv heads into the
q-head count before folding; this module only ever sees matched head
counts. Grid: (BH, S/bq, T/bk), KV innermost.

Queries need not start at key position 0: ``q_offset`` (scalar or one
entry per folded BH row) gives the key position of query row 0, so a
short query chunk attends correctly against a longer cache (S < T).
The default places the *last* query at the *last* key (offset T - S),
matching ``ref.flash_attention_ref`` and the decode convention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)

__all__ = ["flash_attention_fwd"]

_NEG = -1e30


def _divisor_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (pick_chunk-style): the
    kernel grid needs bq | S and bk | T, so ragged extents shrink the
    block instead of erroring."""
    c = min(target, n)
    while n % c:
        c -= 1
    return c


def _kernel(q_ref, k_ref, v_ref, off_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float, causal: bool, bq: int, bk: int, n_k: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = pl.program_id(0)
    qi = pl.program_id(1)
    off = off_ref[b]  # key position of this row's query 0 (SMEM scalar)

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (bq, bk)
        if causal:
            q_pos = off + qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0
            )
            k_pos = kj * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1
            )
            s = jnp.where(k_pos <= q_pos, s, _NEG)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # Skip key blocks strictly above this query block's last true
        # position (off + qi*bq + bq - 1).
        @pl.when(kj * bk <= off + qi * bq + bq - 1)
        def _():
            compute()
    else:
        compute()

    @pl.when(kj == n_k - 1)
    def _():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention_fwd(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset=None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (BH, S, d); k, v: (BH, T, d). Returns (BH, S, d) in q.dtype.

    ``q_offset``: key position of query row 0 -- a scalar shared by all
    rows or a (BH,) vector (one per folded batch*head row, the serving
    engine's mixed-length chunks). Default ``None`` aligns the last
    query with the last key (offset ``T - S``; identity when S == T).
    Ignored for ``causal=False``.
    """
    if q.ndim != 3 or k.ndim != 3 or v.ndim != 3:
        raise ValueError(
            f"flash_attention_fwd wants folded (BH, S|T, d) operands, "
            f"got q{q.shape} k{k.shape} v{v.shape}"
        )
    BH, S, d = q.shape
    T = k.shape[1]
    if k.shape != (BH, T, d) or v.shape != (BH, T, d):
        raise ValueError(
            f"k/v must be (BH={BH}, T, d={d}) and match: "
            f"got k{k.shape} v{v.shape}"
        )
    if block_q < 1 or block_k < 1:
        raise ValueError(
            f"block sizes must be positive, got block_q={block_q} "
            f"block_k={block_k}"
        )
    # Blocks must tile the sequence extents; ragged S/T shrink to the
    # largest dividing block instead of failing (bq=1 worst case).
    bq = _divisor_block(S, block_q)
    bk = _divisor_block(T, block_k)
    if S % bq or T % bk:  # pragma: no cover - _divisor_block guarantees
        raise ValueError(
            f"block grid does not tile the operand: S={S} bq={bq} "
            f"T={T} bk={bk}"
        )
    n_k = T // bk
    scale = d**-0.5

    off = jnp.asarray(
        T - S if q_offset is None else q_offset, jnp.int32
    ).reshape(-1)
    if off.shape[0] not in (1, BH):
        raise ValueError(
            f"q_offset must be a scalar or one entry per BH={BH} row, "
            f"got shape {off.shape}"
        )
    off = jnp.broadcast_to(off, (BH,))

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, bq=bq, bk=bk, n_k=n_k
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, S // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),  # q_offset (BH,)
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v, off)
