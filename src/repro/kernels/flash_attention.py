"""Flash attention forward kernel (Pallas, TPU target).

Online-softmax over KV blocks with (m, l, acc) persisted in VMEM scratch
across the innermost grid dimension; causal masking by block index. The
(S, T) score matrix never leaves VMEM -- this kernel is the hardware
realization of the chunked XLA attention in repro.models.attention (whose
remat-ed scan is the portable fallback used by the dry-run).

Layout: q (BH, S, d), k/v (BH, T, d) -- callers fold batch x heads (GQA
kv heads are repeated into the q-head count by ops.flash_attention).
Grid: (BH, S/bq, T/bk), KV innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)

__all__ = ["flash_attention_fwd"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float, causal: bool, bq: int, bk: int, n_k: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qi = pl.program_id(1)

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (bq, bk)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0
            )
            k_pos = kj * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1
            )
            s = jnp.where(k_pos <= q_pos, s, _NEG)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # Skip blocks strictly above the diagonal.
        @pl.when(kj * bk <= qi * bq + bq - 1)
        def _():
            compute()
    else:
        compute()

    @pl.when(kj == n_k - 1)
    def _():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention_fwd(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (BH, S, d); k, v: (BH, T, d). Returns (BH, S, d) in q.dtype."""
    BH, S, d = q.shape
    T = k.shape[1]
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0
    n_k = T // bk
    scale = d**-0.5

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, bq=bq, bk=bk, n_k=n_k
    )
    return pl.pallas_call(
        kernel,
        grid=(BH, S // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
