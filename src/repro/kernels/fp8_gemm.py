"""Per-block-scaled FP8 GEMM kernel (Pallas, TPU target).

C = (A_q * a_scale) @ (B_q * b_scale) with E4M3 payloads and one f32
scale per 128x128 block of each operand (the GAM-reconstructed scales:
shared group mantissa x per-block E8M0 exponent). Accumulation is f32 in
a VMEM scratch tile; scales are applied once per K-block, DeepSeek-style.

This is the real-quantization serving path: weights (and optionally
activations) stored as QTensors (repro.serve.quantized) flow through this
kernel; on hardware the 2x bandwidth saving is realized even though the
v5e MXU computes in bf16 (payloads upcast in-register after the VMEM load).

Grid: (M/bm, N/bn, K/bk), K innermost ('arbitrary'), f32 accum scratch.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)

__all__ = ["fp8_gemm"]


def _kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    sa = sa_ref[0, 0]  # scale of this (i, k) block of A
    sb = sb_ref[0, 0]  # scale of this (k, j) block of B
    # Dequantize once per block pair: (A/sa) @ (B/sb) == AB / (sa*sb).
    part = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] += part / (sa * sb)

    @pl.when(k == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block", "out_dtype", "interpret")
)
def fp8_gemm(
    a_q: jnp.ndarray,
    b_q: jnp.ndarray,
    a_scale: jnp.ndarray,
    b_scale: jnp.ndarray,
    *,
    block: Tuple[int, int, int] = (128, 128, 128),
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jnp.ndarray:
    """a_q: (M, K) fp8 (scaled values); b_q: (K, N) fp8;
    a_scale: (M/bm, K/bk) f32; b_scale: (K/bk, N/bn) f32.

    Returns (M, N) in out_dtype: the dequantized product.
    """
    M, K = a_q.shape
    K2, N = b_q.shape
    assert K == K2
    bm, bn, bk = block
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk

    kernel = functools.partial(_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, 1), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a_q, b_q, a_scale, b_scale)
