"""Fused sub-tensor MoR selection kernel (Pallas, TPU target).

One VMEM-resident pass per block realizes the whole §3.2 per-block
decision that the XLA lowering previously spread over three full passes
of the operand (E4M3 quant+error, E5M2 quant+error, abs/min/max range
pass). Per (bm, bk) block the kernel computes:

  * both fp8 candidates, each GAM-scaled (Alg. 1) with its format's own
    group mantissa (reconstructed from the shared exponent-bitcast
    arithmetic used by ``gam_quant_blocks`` -- Mosaic has no frexp),
  * the per-block relative-error sums of both candidates (Eq. 3),
  * the nonzero min/max dynamic-range ratio for the Eq. 4 E5M2 gate,

and emits, per ``emit``:

  * ``emit='select'`` -- the *selected* fake-quantized block (E4M3 /
    E5M2 / original BF16 passthrough) plus the per-block selection id
    and stats. The operand is read from HBM exactly once and only the
    winner is written back (fake-quantization, training numerics).
  * ``emit='pack'`` -- the *real* mixed block layout instead of the
    fake-quant values: the selected candidate's raw fp8 bits
    (``payload_q``), the BF16 passthrough buffer (``payload_bf16``),
    per-block GAM scales, and for ``mode='sub4'`` the packed E2M1
    nibbles + E4M3 micro-scale bytes -- byte-identical to
    ``ref.pack_mixed`` on the selection's tags, with no second XLA
    pass over the operand. The in-register candidates the select mode
    throws away are exactly what packing needs, so the whole
    ``quantize_for_gemm`` event becomes this one kernel.

Selection ids: 0 = E4M3, 1 = E5M2, 2 = BF16 (original values),
3 = NVFP4 (sub4 only).

Modes mirror the paper's recipes (+ the §5 NVFP4 outlook):
  * ``sub2``: E4M3 iff it beats the E5M2 benchmark (Eq. 3), else BF16.
  * ``sub3``: E4M3 -> E5M2 (Eq. 4 range gate) -> BF16.
  * ``sub4``: NVFP4 (Eq. 3 vs the E4M3 benchmark + the Eq. 4-style
    NVFP4 range gate) -> the sub3 cascade. The NVFP4 candidate is the
    two-level scheme of ``core.formats.cast_to_nvfp4``: GAM block scale
    targeting 448*6, then one E4M3 micro scale per 16 contraction
    elements. Per-16 micro amaxes ride in as a (bm, bk/16) input block
    (one cheap XLA segment reduce, like the group mantissas); inside
    the kernel they are broadcast back to (bm, bk) with a one-hot f32
    matmul (exact: one summand per output lane), which Mosaic lowers
    where a lane-splitting reshape/repeat would not.

Grid: (M/bm, K/bk). Group mantissas for all formats plus the
zero-guarded group amax come in as a (1, 4) block computed outside the
kernel from the global amax (one cheap XLA reduce), exactly like
``gam_quant_blocks``. The group amax backs the ``scales_from_bmax``
zero-block guard (all-zero blocks scale as if their amax were the
group's), so pack-mode GAM scales match the XLA packer bit-for-bit.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import (
    E2M1_AMAX,
    NVFP4_MICRO,
    encode_e2m1,
    round_to_e2m1,
)

from .ref import expand_micro_onehot

__all__ = ["mor_select_blocks"]

_F32_BIG = 3.4028235e38  # finfo(f32).max: filler for the nonzero-min reduce


def _split_me(s):
    """Bit-level (mantissa in [1,2), exponent) of positive f32 s.

    s must be a (1, 1) vector, not a scalar: Mosaic's tpu.bitcast only
    accepts vector operands.
    """
    bits = jax.lax.bitcast_convert_type(s, jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127
    m = jax.lax.bitcast_convert_type(
        (bits & 0x7FFFFF) | (127 << 23), jnp.float32
    )
    return m, e


def _exp2i(e):
    # Full E8M0 domain [-126, 127], matching core.gam (the 126 clamp
    # was the double-rounding bug on tiny-amax blocks).
    e = jnp.clip(e, -126, 127)
    return jax.lax.bitcast_convert_type(
        (e + 127) << 23, jnp.float32
    )


def _kernel(mg_ref, *refs, q_amax4: float, q_amax5: float,
            q_amax_nv: float, dt4, dt5, mode: str, algo: str,
            range_ratio: float, nv_range_ratio: float, emit: str):
    if mode == "sub4":
        ma_ref, x_ref, *outs = refs
    else:
        ma_ref = None
        x_ref, *outs = refs
    if emit == "select":
        nib_ref = ms_ref = scl_ref = None
        if mode == "sub4":
            y_ref, sel_ref, e4_ref, e5_ref, cnt_ref, nv_ref = outs
        else:
            y_ref, sel_ref, e4_ref, e5_ref, cnt_ref = outs
    else:
        y_ref = None
        if mode == "sub4":
            (pq_ref, pbf_ref, sel_ref, scl_ref, e4_ref, e5_ref, cnt_ref,
             nv_ref, nib_ref, ms_ref) = outs
        else:
            nib_ref = ms_ref = None
            (pq_ref, pbf_ref, sel_ref, scl_ref, e4_ref, e5_ref,
             cnt_ref) = outs
    i, j = pl.program_id(0), pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    ax = jnp.abs(x)
    bmax = jnp.max(ax)
    # (1, 1) view of the block amax: the exponent/mantissa bit arithmetic
    # must run on vectors (Mosaic's tpu.bitcast rejects scalars).
    bmax11 = jnp.max(ax, axis=(0, 1), keepdims=True)
    # scales_from_bmax zero guard: an all-zero block scales as if its
    # amax were the group's (quantizing zeros is exact either way, but
    # the *reconstructed scale* must match the XLA packer bit-for-bit).
    safe_b = jnp.where(bmax11 > 0, bmax11, mg_ref[0, 3])
    nz = x != 0.0
    cnt = jnp.sum(nz.astype(jnp.float32))

    def gam_scale(q_amax, m_g):
        s_b = q_amax / safe_b  # (1, 1)
        m_b, e_b = _split_me(s_b)
        if algo == "gam":
            # Alg. 1 rounding: avoid saturation when m_g > m_b.
            e_b = jnp.where(m_g <= m_b, e_b, e_b - 1)
            return m_g * _exp2i(e_b)
        if algo == "e8m0":
            return _exp2i(e_b)
        return s_b  # fp32_amax

    def rel_err_sum(xq_stored):
        # Eq. 3 compares errors of the *stored* (Fig. 4: BF16) values.
        xqf = xq_stored.astype(jnp.float32)
        rel = jnp.where(nz, jnp.abs((x - xqf) / jnp.where(nz, x, 1.0)), 0.0)
        return jnp.sum(rel)

    def candidate(q_amax, m_g, out_dtype):
        scale = gam_scale(q_amax, m_g)
        xs = jnp.clip(x * scale, -q_amax, q_amax)
        xq8 = xs.astype(out_dtype)
        xq = xq8.astype(jnp.float32) / scale
        xq_stored = xq.astype(x_ref.dtype)
        return xq_stored, rel_err_sum(xq_stored), xq8, scale

    q4, e4, q4_bits, s4 = candidate(q_amax4, mg_ref[0, 0], dt4)
    q5, e5, q5_bits, s5 = candidate(q_amax5, mg_ref[0, 1], dt5)

    m1 = e4 < e5  # Eq. 3: E4M3 beats the E5M2 benchmark on total rel-err.
    if mode == "sub2":
        use5 = jnp.bool_(False)
    else:  # sub3/sub4: Eq. 4 dynamic-range gate for the E5M2 fallback.
        anynz = cnt > 0
        bmin = jnp.min(jnp.where(nz, ax, _F32_BIG))
        ratio = jnp.where(anynz, bmax / jnp.where(anynz, bmin, 1.0), 1.0)
        use5 = jnp.logical_and(jnp.logical_not(m1), ratio < range_ratio)

    sel = jnp.where(
        m1, jnp.int32(0), jnp.where(use5, jnp.int32(1), jnp.int32(2))
    )
    if emit == "select":
        y = jnp.where(m1, q4, jnp.where(use5, q5, x_ref[...]))

    use_nv = None
    s_nv = None
    if mode == "sub4":
        # Two-level NVFP4 candidate: GAM block scale targeting 448*6,
        # then one E4M3 micro scale per 16 contraction elements (the
        # micro amaxes arrive as a whole row stripe -- see
        # _expand_micro; micro_amax(x)*scale == micro_amax(x*scale)
        # bit-exactly: f32 multiply by a positive scale is monotone
        # and commutes with abs).
        g16 = x.shape[-1] // NVFP4_MICRO
        s_nv = gam_scale(q_amax_nv, mg_ref[0, 2])
        ma = ma_ref[...]  # (bm, K/16) raw micro-group amax stripe
        d = ma * s_nv / E2M1_AMAX
        d_q = jnp.clip(d, -448.0, 448.0).astype(
            jnp.float8_e4m3fn
        ).astype(jnp.float32)
        safe_d = jnp.where(d_q > 0, d_q, 1.0)
        d_exp = expand_micro_onehot(safe_d, x.shape[-1], j * g16)
        xs = x * s_nv
        e2 = round_to_e2m1(xs / d_exp)  # E2M1 grid values
        qn_stored = ((e2 * d_exp) / s_nv).astype(x_ref.dtype)
        env = rel_err_sum(qn_stored)
        # Eq. 4-style gate on this block's micro-group amaxes (what
        # the E4M3 micro scales must represent; intra-group range is
        # already priced into env by Eq. 3). Mask the stripe to grid
        # step j's group window.
        gcol = jax.lax.broadcasted_iota(jnp.int32, ma.shape, 1)
        in_blk = jnp.logical_and(gcol >= j * g16, gcol < (j + 1) * g16)
        ga_min = jnp.min(
            jnp.where(jnp.logical_and(in_blk, ma > 0), ma, _F32_BIG)
        )
        g_ratio = jnp.where(anynz, bmax / jnp.where(anynz, ga_min, 1.0),
                            1.0)
        use_nv = jnp.logical_and(env < e4, g_ratio < nv_range_ratio)
        sel = jnp.where(use_nv, jnp.int32(3), sel)
        if emit == "select":
            y = jnp.where(use_nv, qn_stored, y)
        else:
            # Packed-nibble lane (row-halves packing within the block)
            # + the micro-scale byte stripe, masked to NVFP4 winners --
            # byte-identical to ref._nvfp4_lanes. Byte selects run in
            # the i32 domain and narrow at the store: Mosaic lowers
            # i32 selects and i32 -> u8 casts, but not u8 constants.
            codes = encode_e2m1(e2)  # (bm, bk) int32 in [0, 15]
            half = x.shape[0] // 2
            nib = codes[:half, :] | (codes[half:, :] << 4)
            nib_ref[...] = jnp.where(use_nv, nib, jnp.int32(0)).astype(
                jnp.uint8
            )
            ms_bits = jax.lax.bitcast_convert_type(
                safe_d.astype(jnp.float8_e4m3fn), jnp.uint8
            ).astype(jnp.int32)
            ms_win = jnp.where(
                jnp.logical_and(in_blk, use_nv), ms_bits, jnp.int32(0)
            )
            # The micro-scale stripe block is revisited across the j
            # sweep (index (i, 0)); each step owns its group window.
            @pl.when(j == 0)
            def _():
                ms_ref[...] = ms_win.astype(jnp.uint8)

            @pl.when(j > 0)
            def _():
                ms_ref[...] = jnp.where(
                    in_blk, ms_win, ms_ref[...].astype(jnp.int32)
                ).astype(jnp.uint8)
        nv_ref[i, j] = env

    if emit == "select":
        y_ref[...] = y
    else:
        # Real payload lanes of the winner: raw fp8 bits for fp8 tags,
        # the original values for BF16 tags, zeros (don't-care) in the
        # lanes the tag does not reference -- pack_mixed's layout. The
        # byte select runs in i32 (Mosaic has no u8 constants).
        b4 = jax.lax.bitcast_convert_type(q4_bits, jnp.uint8).astype(
            jnp.int32
        )
        b5 = jax.lax.bitcast_convert_type(q5_bits, jnp.uint8).astype(
            jnp.int32
        )
        pq_ref[...] = jnp.where(
            sel == 0, b4, jnp.where(sel == 1, b5, jnp.int32(0))
        ).astype(jnp.uint8)
        pbf_ref[...] = jnp.where(
            sel == 2, x_ref[...], jnp.zeros_like(x_ref[...])
        )
        scale_sel = jnp.where(
            sel == 0, s4, jnp.where(sel == 1, s5, jnp.float32(1.0))
        )
        if mode == "sub4":
            scale_sel = jnp.where(sel == 3, s_nv, scale_sel)
        scl_ref[i, j] = jnp.sum(scale_sel)  # exact: (1, 1) -> scalar
    # The (nm, nk) stat outputs live whole in SMEM across the grid (TPU
    # tiling forbids (1, 1) VMEM blocks and VMEM rejects scalar stores);
    # each step writes its own cell.
    sel_ref[i, j] = sel
    e4_ref[i, j] = e4
    e5_ref[i, j] = e5
    cnt_ref[i, j] = cnt


@functools.partial(
    jax.jit,
    static_argnames=(
        "block", "q_amax4", "q_amax5", "q_amax_nv", "dt4", "dt5", "mode",
        "algo", "range_ratio", "nv_range_ratio", "emit", "interpret",
    ),
)
def mor_select_blocks(
    x: jnp.ndarray,
    group_mantissas: jnp.ndarray,
    group_amax: jnp.ndarray | None = None,
    *,
    block: Tuple[int, int] = (128, 128),
    q_amax4: float = 448.0,
    q_amax5: float = 57344.0,
    q_amax_nv: float = 448.0 * 6.0,
    dt4=jnp.float8_e4m3fn,
    dt5=jnp.float8_e5m2,
    mode: str = "sub3",
    algo: str = "gam",
    range_ratio: float = 57344.0 / 2.0**-14,
    nv_range_ratio: float = 12.0 * 448.0 / 2.0**-9,  # NVFP4_RANGE_RATIO
    emit: str = "select",
    interpret: bool = False,
):
    """x: (M, K) with M % bm == 0, K % bk == 0 (and bk % 16 == 0 for
    ``mode='sub4'``; ``emit='pack'`` on sub4 additionally wants bm % 2
    == 0 for the nibble row pairing).

    group_mantissas: (3,) f32 -- [m_g(E4M3), m_g(E5M2), m_g(NVFP4)]
    (all 1.0 for the e8m0 / fp32_amax ablations; the NVFP4 slot is
    ignored outside sub4 but always present so the operand layout is
    mode-independent). A legacy (2,) vector is accepted for
    sub2/sub3 callers and padded with 1.0.

    group_amax: () f32 zero-guarded group (tensor) amax -- the
    ``scales_from_bmax`` guard value for all-zero blocks. Computed here
    with one XLA reduce when omitted; recipe callers pass the (possibly
    mesh-allreduced) value they already have.

    emit='select' returns (y selected fake-quant in x.dtype, sel
    (nm, nk) i32, e4_err_sums (nm, nk) f32, e5_err_sums (nm, nk) f32,
    counts (nm, nk) f32[, nv_err_sums (nm, nk) f32 -- sub4 only]).

    emit='pack' returns (payload_q (M, K) uint8, payload_bf16 (M, K)
    x.dtype, sel, scales (nm, nk) f32, e4_err_sums, e5_err_sums,
    counts[, nv_err_sums, payload_nib (M/2, K) uint8, micro_scales
    (M, K/16) uint8 -- sub4 only]) -- the ``ref.MixedOperand`` buffer
    lanes, byte-identical to ``ref.pack_mixed`` on this selection.
    """
    M, K = x.shape
    bm, bk = block
    assert M % bm == 0 and K % bk == 0, (x.shape, block)
    assert mode in ("sub2", "sub3", "sub4"), mode
    assert emit in ("select", "pack"), emit
    nm, nk = M // bm, K // bk
    gm = jnp.reshape(group_mantissas.astype(jnp.float32), (-1,))
    if gm.shape[0] == 2:  # legacy sub2/sub3 callers: no NVFP4 slot
        assert mode != "sub4", "sub4 needs the NVFP4 group mantissa"
        gm = jnp.concatenate([gm, jnp.ones((1,), jnp.float32)])
    if group_amax is None:
        g = jnp.max(jnp.abs(x.astype(jnp.float32)))
        group_amax = jnp.where(g > 0, g, 1.0)
    mg = jnp.reshape(
        jnp.concatenate(
            [gm, jnp.reshape(group_amax.astype(jnp.float32), (1,))]
        ),
        (1, 4),
    )

    kernel = functools.partial(
        _kernel, q_amax4=q_amax4, q_amax5=q_amax5, q_amax_nv=q_amax_nv,
        dt4=dt4, dt5=dt5, mode=mode, algo=algo, range_ratio=range_ratio,
        nv_range_ratio=nv_range_ratio, emit=emit,
    )
    in_specs = [
        pl.BlockSpec((1, 4), lambda i, j: (0, 0)),  # mantissas + amax
    ]
    operands = [mg]
    if mode == "sub4":
        assert bk % NVFP4_MICRO == 0, (block, NVFP4_MICRO)
        if emit == "pack":
            assert bm % 2 == 0, (block, "nibble packing pairs rows")
        # Per-16-element micro amaxes: one XLA segment reduce outside
        # the kernel (like the group mantissas). The stripe rides in
        # whole along the contraction axis -- its (K/16) lane count is
        # not 128-divisible, and TPU tiling only accepts a
        # non-divisible lane dim when it equals the whole array's.
        ma = jnp.max(
            jnp.abs(x.astype(jnp.float32)).reshape(
                M, K // NVFP4_MICRO, NVFP4_MICRO
            ),
            axis=-1,
        )
        in_specs.append(
            pl.BlockSpec((bm, K // NVFP4_MICRO), lambda i, j: (i, 0))
        )
        operands.append(ma)
    in_specs.append(
        pl.BlockSpec((bm, bk), lambda i, j: (i, j))  # x block (VMEM)
    )
    operands.append(x)

    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    nmk_f32 = jax.ShapeDtypeStruct((nm, nk), jnp.float32)
    if emit == "select":
        out_shapes = [jax.ShapeDtypeStruct((M, K), x.dtype)]
        out_specs = [pl.BlockSpec((bm, bk), lambda i, j: (i, j))]
    else:
        out_shapes = [
            jax.ShapeDtypeStruct((M, K), jnp.uint8),   # payload_q
            jax.ShapeDtypeStruct((M, K), x.dtype),     # payload_bf16
        ]
        out_specs = [
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        ]
    out_shapes.append(jax.ShapeDtypeStruct((nm, nk), jnp.int32))  # sel
    out_specs.append(smem)
    if emit == "pack":
        out_shapes.append(nmk_f32)  # reconstructed GAM scales
        out_specs.append(smem)
    out_shapes += [nmk_f32, nmk_f32, nmk_f32]  # e4 / e5 / counts
    out_specs += [smem, smem, smem]
    if mode == "sub4":
        out_shapes.append(nmk_f32)  # nv err sums
        out_specs.append(smem)
        if emit == "pack":
            out_shapes += [
                jax.ShapeDtypeStruct((M // 2, K), jnp.uint8),
                jax.ShapeDtypeStruct((M, K // NVFP4_MICRO), jnp.uint8),
            ]
            out_specs += [
                pl.BlockSpec((bm // 2, bk), lambda i, j: (i, j)),
                # Whole-row micro-scale stripe, revisited across j
                # (each step writes its own group window): the (K/16)
                # lane count is not 128-divisible, so blocks must span
                # the full lane extent, exactly like the ma input.
                pl.BlockSpec(
                    (bm, K // NVFP4_MICRO), lambda i, j: (i, 0)
                ),
            ]

    return pl.pallas_call(
        kernel,
        grid=(nm, nk),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=tuple(out_shapes),
        interpret=interpret,
    )(*operands)
