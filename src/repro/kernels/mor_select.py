"""Fused sub-tensor MoR selection kernel (Pallas, TPU target).

One VMEM-resident pass per block realizes the whole §3.2 per-block
decision that the XLA lowering previously spread over three full passes
of the operand (E4M3 quant+error, E5M2 quant+error, abs/min/max range
pass). Per (bm, bk) block the kernel computes:

  * both fp8 candidates, each GAM-scaled (Alg. 1) with its format's own
    group mantissa (reconstructed from the shared exponent-bitcast
    arithmetic used by ``gam_quant_blocks`` -- Mosaic has no frexp),
  * the per-block relative-error sums of both candidates (Eq. 3),
  * the nonzero min/max dynamic-range ratio for the Eq. 4 E5M2 gate,

and writes the *selected* fake-quantized block (E4M3 / E5M2 / original
BF16 passthrough) plus the per-block selection id and stats. The operand
is read from HBM exactly once and only the winner is written back.

Selection ids: 0 = E4M3, 1 = E5M2, 2 = BF16 (original values).

Modes mirror the paper's recipes:
  * ``sub2``: E4M3 iff it beats the E5M2 benchmark (Eq. 3), else BF16.
  * ``sub3``: E4M3 -> E5M2 (Eq. 4 range gate) -> BF16.

Grid: (M/bm, K/bk). Group mantissas for both formats come in as a (1, 2)
block computed outside the kernel from the global amax (one cheap XLA
reduce), exactly like ``gam_quant_blocks``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mor_select_blocks"]

_F32_BIG = 3.4028235e38  # finfo(f32).max: filler for the nonzero-min reduce


def _split_me(s):
    """Bit-level (mantissa in [1,2), exponent) of positive f32 s.

    s must be a (1, 1) vector, not a scalar: Mosaic's tpu.bitcast only
    accepts vector operands.
    """
    bits = jax.lax.bitcast_convert_type(s, jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127
    m = jax.lax.bitcast_convert_type(
        (bits & 0x7FFFFF) | (127 << 23), jnp.float32
    )
    return m, e


def _exp2i(e):
    e = jnp.clip(e, -126, 126)
    return jax.lax.bitcast_convert_type(
        (e + 127) << 23, jnp.float32
    )


def _kernel(mg_ref, x_ref, y_ref, sel_ref, e4_ref, e5_ref, cnt_ref,
            *, q_amax4: float, q_amax5: float, dt4, dt5,
            mode: str, algo: str, range_ratio: float):
    i, j = pl.program_id(0), pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    ax = jnp.abs(x)
    bmax = jnp.max(ax)
    # (1, 1) view of the block amax: the exponent/mantissa bit arithmetic
    # must run on vectors (Mosaic's tpu.bitcast rejects scalars).
    bmax11 = jnp.max(ax, axis=(0, 1), keepdims=True)
    safe_b = jnp.where(bmax11 > 0, bmax11, 1.0)
    nz = x != 0.0
    cnt = jnp.sum(nz.astype(jnp.float32))

    def candidate(q_amax, m_g, out_dtype):
        s_b = q_amax / safe_b  # (1, 1)
        m_b, e_b = _split_me(s_b)
        if algo == "gam":
            # Alg. 1 rounding: avoid saturation when m_g > m_b.
            e_b = jnp.where(m_g <= m_b, e_b, e_b - 1)
            scale = m_g * _exp2i(e_b)
        elif algo == "e8m0":
            scale = _exp2i(e_b)
        else:  # fp32_amax
            scale = s_b
        xs = jnp.clip(x * scale, -q_amax, q_amax)
        xq = xs.astype(out_dtype).astype(jnp.float32) / scale
        # Eq. 3 compares errors of the *stored* (Fig. 4: BF16) values.
        xq_stored = xq.astype(x_ref.dtype)
        xqf = xq_stored.astype(jnp.float32)
        rel = jnp.where(nz, jnp.abs((x - xqf) / jnp.where(nz, x, 1.0)), 0.0)
        return xq_stored, jnp.sum(rel)

    q4, e4 = candidate(q_amax4, mg_ref[0, 0], dt4)
    q5, e5 = candidate(q_amax5, mg_ref[0, 1], dt5)

    m1 = e4 < e5  # Eq. 3: E4M3 beats the E5M2 benchmark on total rel-err.
    if mode == "sub2":
        use5 = jnp.bool_(False)
    else:  # sub3: Eq. 4 dynamic-range gate for the E5M2 fallback.
        anynz = cnt > 0
        bmin = jnp.min(jnp.where(nz, ax, _F32_BIG))
        ratio = jnp.where(anynz, bmax / jnp.where(anynz, bmin, 1.0), 1.0)
        use5 = jnp.logical_and(jnp.logical_not(m1), ratio < range_ratio)

    y_ref[...] = jnp.where(m1, q4, jnp.where(use5, q5, x_ref[...]))
    # The (nm, nk) stat outputs live whole in SMEM across the grid (TPU
    # tiling forbids (1, 1) VMEM blocks and VMEM rejects scalar stores);
    # each step writes its own cell.
    sel_ref[i, j] = jnp.where(
        m1, jnp.int32(0), jnp.where(use5, jnp.int32(1), jnp.int32(2))
    )
    e4_ref[i, j] = e4
    e5_ref[i, j] = e5
    cnt_ref[i, j] = cnt


@functools.partial(
    jax.jit,
    static_argnames=(
        "block", "q_amax4", "q_amax5", "dt4", "dt5", "mode", "algo",
        "range_ratio", "interpret",
    ),
)
def mor_select_blocks(
    x: jnp.ndarray,
    group_mantissas: jnp.ndarray,
    *,
    block: Tuple[int, int] = (128, 128),
    q_amax4: float = 448.0,
    q_amax5: float = 57344.0,
    dt4=jnp.float8_e4m3fn,
    dt5=jnp.float8_e5m2,
    mode: str = "sub3",
    algo: str = "gam",
    range_ratio: float = 57344.0 / 2.0**-14,
    interpret: bool = False,
):
    """x: (M, K) with M % bm == 0, K % bk == 0.

    group_mantissas: (2,) f32 -- [m_g(E4M3), m_g(E5M2)] (both 1.0 for the
    e8m0 / fp32_amax ablations).

    Returns (y selected fake-quant in x.dtype, sel (nm, nk) i32,
    e4_err_sums (nm, nk) f32, e5_err_sums (nm, nk) f32,
    counts (nm, nk) f32).
    """
    M, K = x.shape
    bm, bk = block
    assert M % bm == 0 and K % bk == 0, (x.shape, block)
    assert mode in ("sub2", "sub3"), mode
    nm, nk = M // bm, K // bk
    mg = jnp.reshape(group_mantissas.astype(jnp.float32), (1, 2))

    kernel = functools.partial(
        _kernel, q_amax4=q_amax4, q_amax5=q_amax5, dt4=dt4, dt5=dt5,
        mode=mode, algo=algo, range_ratio=range_ratio,
    )
    out_shapes = (
        jax.ShapeDtypeStruct((M, K), x.dtype),
        jax.ShapeDtypeStruct((nm, nk), jnp.int32),
        jax.ShapeDtypeStruct((nm, nk), jnp.float32),
        jax.ShapeDtypeStruct((nm, nk), jnp.float32),
        jax.ShapeDtypeStruct((nm, nk), jnp.float32),
    )
    return pl.pallas_call(
        kernel,
        grid=(nm, nk),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),  # group mantissas
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),  # x block (VMEM)
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(mg, x)
