"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are also the ``backend='xla'`` lowerings of the public entry points
in :mod:`repro.kernels.ops`, so they are written to be *bit-identical* to
the pre-kernel XLA paths of :mod:`repro.core.mor` (the recipe regression
tests assert this). This module must not import ``repro.core.mor`` --
``core.mor`` dispatches through ``kernels.ops`` which imports this
module, and a back-edge would close an import cycle.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import E4M3, E5M2, FormatSpec, cast_to_format
from repro.core.gam import compute_scales, scales_from_bmax
from repro.core.metrics import E5M2_RANGE_RATIO
from repro.core.partition import Partition, from_blocks, to_blocks

__all__ = [
    "QuantErr",
    "MorSelect",
    "gam_quant_ref",
    "quant_err_ref",
    "mor_select_ref",
    "fp8_gemm_ref",
    "flash_attention_ref",
]


class QuantErr(NamedTuple):
    """One fused quantize+error event (backend-independent result).

    y:              (M, K) fake-quantized operand in the input dtype.
    err_sums:       (nm, nk) f32 per-block relative-error sums (Eq. 1-3).
    counts:         (nm, nk) f32 per-block non-zero element counts.
    group_amax:     () f32 amax of the whole group (tensor).
    group_mantissa: () f32 GAM shared mantissa m_g (1.0 for ablations).
    """

    y: jnp.ndarray
    err_sums: jnp.ndarray
    counts: jnp.ndarray
    group_amax: jnp.ndarray
    group_mantissa: jnp.ndarray


class MorSelect(NamedTuple):
    """One fused sub-tensor selection event (paper §3.2).

    y:          (M, K) per-block selected output in the input dtype.
    sel:        (nm, nk) i32 selection id: 0=E4M3, 1=E5M2, 2=BF16.
    e4_sums:    (nm, nk) f32 E4M3 per-block relative-error sums.
    e5_sums:    (nm, nk) f32 E5M2 per-block relative-error sums.
    counts:     (nm, nk) f32 per-block non-zero element counts.
    group_amax / group_mantissa: as in :class:`QuantErr` (E4M3's m_g).
    """

    y: jnp.ndarray
    sel: jnp.ndarray
    e4_sums: jnp.ndarray
    e5_sums: jnp.ndarray
    counts: jnp.ndarray
    group_amax: jnp.ndarray
    group_mantissa: jnp.ndarray


def _blocked_quant_err(xb: jnp.ndarray, fmt: FormatSpec, algo: str):
    """Single-pass quantize + per-block error sums on a blocked view.

    xb: (nm, nk, bm, bk) in its *original* dtype (bf16 in training -- the
    paper's Fig. 4 pipeline is BF16-in/BF16-out, so large intermediates
    never materialize in f32; per-block scale math runs in f32 on the tiny
    (nm, nk) arrays). Returns (xqb in xb.dtype, scales, err_sums f32,
    counts f32). This is the XLA analogue of the fused Pallas kernels.
    """
    bmax = jnp.max(jnp.abs(xb), axis=(2, 3)).astype(jnp.float32)
    scales = scales_from_bmax(bmax, fmt, algo)
    s = scales.scale[:, :, None, None]
    xqb_f32 = cast_to_format(xb.astype(jnp.float32) * s, fmt) / s
    xqb = xqb_f32.astype(xb.dtype)  # Fig. 4: output stays BF16
    xf = xb.astype(jnp.float32)
    nz = xf != 0.0
    err = jnp.where(
        nz,
        jnp.abs((xf - xqb.astype(jnp.float32)) / jnp.where(nz, xf, 1.0)),
        0.0,
    )
    return (
        xqb,
        scales,
        jnp.sum(err, (2, 3)),
        jnp.sum(nz, (2, 3)).astype(jnp.float32),
    )


def quant_err_ref(
    x: jnp.ndarray, part: Partition, fmt: FormatSpec, algo: str = "gam"
) -> QuantErr:
    """Reference for the ops.quant_err entry point (one-format events)."""
    xb = to_blocks(x, part)
    xqb, scales, err_sums, counts = _blocked_quant_err(xb, fmt, algo)
    return QuantErr(
        y=from_blocks(xqb, x.shape),
        err_sums=err_sums,
        counts=counts,
        group_amax=scales.group_amax,
        group_mantissa=scales.group_mantissa,
    )


def mor_select_ref(
    x: jnp.ndarray, part: Partition, mode: str = "sub3", algo: str = "gam"
) -> MorSelect:
    """Reference for mor_select_blocks: fused §3.2 per-block selection."""
    assert mode in ("sub2", "sub3"), mode
    xb = to_blocks(x, part)
    q4b, scales4, e4_sums, counts = _blocked_quant_err(xb, E4M3, algo)
    q5b, _, e5_sums, _ = _blocked_quant_err(xb, E5M2, algo)

    m1 = e4_sums < e5_sums  # Eq. 3
    if mode == "sub2":
        use5 = jnp.zeros_like(m1)
    else:
        # Eq. 4 dynamic-range gate on the nonzero magnitudes.
        xabs = jnp.abs(xb)
        anynz = counts > 0
        bmax = jnp.max(xabs, axis=(2, 3)).astype(jnp.float32)
        big = jnp.asarray(jnp.finfo(xb.dtype).max, xb.dtype)
        bmin = jnp.min(jnp.where(xb != 0, xabs, big), axis=(2, 3)).astype(
            jnp.float32
        )
        ratio = jnp.where(anynz, bmax / jnp.where(anynz, bmin, 1.0), 1.0)
        use5 = jnp.logical_and(jnp.logical_not(m1), ratio < E5M2_RANGE_RATIO)

    m1b = m1[:, :, None, None]
    yb = jnp.where(m1b, q4b, jnp.where(use5[:, :, None, None], q5b, xb))
    sel = jnp.where(
        m1, jnp.int32(0), jnp.where(use5, jnp.int32(1), jnp.int32(2))
    )
    return MorSelect(
        y=from_blocks(yb, x.shape),
        sel=sel,
        e4_sums=e4_sums,
        e5_sums=e5_sums,
        counts=counts,
        group_amax=scales4.group_amax,
        group_mantissa=scales4.group_mantissa,
    )


def gam_quant_ref(
    x: jnp.ndarray,
    part: Partition,
    fmt: FormatSpec,
    algo: str = "gam",
):
    """Reference for gam_quant_blocks: (xq, block_exp, err_sums, counts)."""
    scales = compute_scales(x, part, fmt, algo=algo)
    xb = to_blocks(x.astype(jnp.float32), part)
    s = scales.scale[:, :, None, None]
    xqb = cast_to_format(xb * s, fmt) / s
    xq = from_blocks(xqb, x.shape).astype(x.dtype)
    xqb = to_blocks(xq.astype(jnp.float32), part)
    nz = xb != 0
    err = jnp.where(nz, jnp.abs((xb - xqb) / jnp.where(nz, xb, 1.0)), 0.0)
    return (
        xq,
        scales.block_exp,
        jnp.sum(err, (2, 3)),
        jnp.sum(nz, (2, 3)).astype(jnp.float32),
    )


def fp8_gemm_ref(
    a_q: jnp.ndarray,
    b_q: jnp.ndarray,
    a_scale: jnp.ndarray,
    b_scale: jnp.ndarray,
    block: Tuple[int, int, int] = (128, 128, 128),
    out_dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """Dequantize per block then matmul in f32."""
    bm, bn, bk = block
    M, K = a_q.shape
    N = b_q.shape[1]
    a = a_q.astype(jnp.float32).reshape(M // bm, bm, K // bk, bk)
    a = a / a_scale[:, None, :, None]
    b = b_q.astype(jnp.float32).reshape(K // bk, bk, N // bn, bn)
    b = b / b_scale[:, None, :, None]
    return (a.reshape(M, K) @ b.reshape(K, N)).astype(out_dtype)


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = True
) -> jnp.ndarray:
    """Naive softmax attention. q: (BH, S, d), k/v: (BH, T, d)."""
    S, d = q.shape[1], q.shape[2]
    T = k.shape[1]
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (d**-0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bqk,bkd->bqd", p, v.astype(jnp.float32)
    ).astype(q.dtype)
