"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import FormatSpec
from repro.core.gam import compute_scales
from repro.core.mor import quant_dequant_with_scales
from repro.core.partition import Partition, to_blocks

__all__ = ["gam_quant_ref", "fp8_gemm_ref", "flash_attention_ref"]


def gam_quant_ref(
    x: jnp.ndarray,
    part: Partition,
    fmt: FormatSpec,
    algo: str = "gam",
):
    """Reference for gam_quant_blocks: (xq, block_exp, err_sums, counts)."""
    scales = compute_scales(x, part, fmt, algo=algo)
    xq = quant_dequant_with_scales(x, part, fmt, scales).astype(x.dtype)
    xb = to_blocks(x.astype(jnp.float32), part)
    xqb = to_blocks(xq.astype(jnp.float32), part)
    nz = xb != 0
    err = jnp.where(nz, jnp.abs((xb - xqb) / jnp.where(nz, xb, 1.0)), 0.0)
    return (
        xq,
        scales.block_exp,
        jnp.sum(err, (2, 3)),
        jnp.sum(nz, (2, 3)).astype(jnp.float32),
    )


def fp8_gemm_ref(
    a_q: jnp.ndarray,
    b_q: jnp.ndarray,
    a_scale: jnp.ndarray,
    b_scale: jnp.ndarray,
    block: Tuple[int, int, int] = (128, 128, 128),
    out_dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """Dequantize per block then matmul in f32."""
    bm, bn, bk = block
    M, K = a_q.shape
    N = b_q.shape[1]
    a = a_q.astype(jnp.float32).reshape(M // bm, bm, K // bk, bk)
    a = a / a_scale[:, None, :, None]
    b = b_q.astype(jnp.float32).reshape(K // bk, bk, N // bn, bn)
    b = b / b_scale[:, None, :, None]
    return (a.reshape(M, K) @ b.reshape(K, N)).astype(out_dtype)


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = True
) -> jnp.ndarray:
    """Naive softmax attention. q: (BH, S, d), k/v: (BH, T, d)."""
    S, d = q.shape[1], q.shape[2]
    T = k.shape[1]
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (d**-0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bqk,bkd->bqd", p, v.astype(jnp.float32)
    ).astype(q.dtype)
