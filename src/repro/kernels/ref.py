"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are also the ``backend='xla'`` lowerings of the public entry points
in :mod:`repro.kernels.ops`, so they are written to be *bit-identical* to
the pre-kernel XLA paths of :mod:`repro.core.mor` (the recipe regression
tests assert this). This module must not import ``repro.core.mor`` --
``core.mor`` dispatches through ``kernels.ops`` which imports this
module, and a back-edge would close an import cycle.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collectives import pmax_over
from repro.core.formats import (
    E2M1_AMAX,
    E4M3,
    E5M2,
    NVFP4,
    NVFP4_MICRO,
    FormatSpec,
    cast_to_format,
    decode_e2m1,
    encode_e2m1,
    round_to_e2m1,
)
from repro.core.gam import compute_scales, scales_from_bmax
from repro.core.metrics import E5M2_RANGE_RATIO, NVFP4_RANGE_RATIO
from repro.core.partition import Partition, _pad2d, from_blocks, to_blocks

__all__ = [
    "TAG_E4M3",
    "TAG_E5M2",
    "TAG_BF16",
    "TAG_NVFP4",
    "QuantErr",
    "MorSelect",
    "MixedOperand",
    "expand_micro_onehot",
    "nvfp4_block_capable",
    "pack_mixed",
    "passthrough_mixed",
    "activation_row_block",
    "decode_mixed_ref",
    "mixed_gemm_ref",
    "gam_quant_ref",
    "quant_err_ref",
    "mor_select_ref",
    "quantize_pack_ref",
    "fp8_gemm_ref",
    "flash_attention_ref",
]

# Per-block representation tags: the contract between the MoR selection
# (repro.kernels.mor_select emits exactly these ids), the packing layer
# below, and the mixed-representation GEMM kernel. TAG_NVFP4 blocks
# store packed E2M1 nibbles + per-16-element E4M3 micro scales (sub4
# recipe) instead of a byte-per-element fp8 payload.
TAG_E4M3 = 0
TAG_E5M2 = 1
TAG_BF16 = 2
TAG_NVFP4 = 3


def nvfp4_block_capable(block: Tuple[int, int]) -> bool:
    """Whether a block shape can hold NVFP4 payloads: nibble packing
    pairs rows (even rows) and micro scales group NVFP4_MICRO
    contraction elements (16-divisible columns). Non-capable blocks can
    never carry TAG_NVFP4 (the sub4 recipe aligns its partition to
    (2, 16); packing rejects violations)."""
    br, bk = block
    return br % 2 == 0 and bk % NVFP4_MICRO == 0


def expand_micro_onehot(d: jnp.ndarray, bk: int, g0) -> jnp.ndarray:
    """(rows, G) per-micro-group row stripe -> (rows, bk) for the block
    whose first group index is ``g0``, via an exact one-hot f32 matmul
    (each output lane sums its single group value plus zeros).

    Shared by the selection and GEMM kernels: Mosaic lowers
    dot_general where a lane-splitting reshape/repeat would not, and
    the stripes ride in whole because a (rows, bk/16) block would
    violate the 128-lane tile. The matmul is bit-exact (one non-zero
    summand per output lane), so both kernels reproduce the
    jnp.repeat-based references bit-for-bit.
    """
    G = d.shape[-1]
    r = jax.lax.broadcasted_iota(jnp.int32, (G, bk), 0)
    c = jax.lax.broadcasted_iota(jnp.int32, (G, bk), 1)
    onehot = (g0 + c // NVFP4_MICRO == r).astype(jnp.float32)
    return jax.lax.dot_general(
        d, onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _nib_compact_shape(block: Tuple[int, int]) -> Tuple[int, int]:
    return (max(block[0] // 2, 1), block[1])


def _ms_compact_shape(block: Tuple[int, int]) -> Tuple[int, int]:
    return (block[0], max(block[1] // NVFP4_MICRO, 1))


class QuantErr(NamedTuple):
    """One fused quantize+error event (backend-independent result).

    y:              (M, K) fake-quantized operand in the input dtype.
    err_sums:       (nm, nk) f32 per-block relative-error sums (Eq. 1-3).
    counts:         (nm, nk) f32 per-block non-zero element counts.
    group_amax:     () f32 amax of the whole group (tensor).
    group_mantissa: () f32 GAM shared mantissa m_g (1.0 for ablations).
    """

    y: jnp.ndarray
    err_sums: jnp.ndarray
    counts: jnp.ndarray
    group_amax: jnp.ndarray
    group_mantissa: jnp.ndarray


class MorSelect(NamedTuple):
    """One fused sub-tensor selection event (paper §3.2 + the sub4
    NVFP4 extension).

    y:          (M, K) per-block selected output in the input dtype.
    sel:        (nm, nk) i32 selection id: 0=E4M3, 1=E5M2, 2=BF16,
                3=NVFP4 (sub4 only).
    e4_sums:    (nm, nk) f32 E4M3 per-block relative-error sums.
    e5_sums:    (nm, nk) f32 E5M2 per-block relative-error sums.
    counts:     (nm, nk) f32 per-block non-zero element counts.
    group_amax / group_mantissa: as in :class:`QuantErr` (E4M3's m_g).
    nv_sums:    (nm, nk) f32 NVFP4 per-block relative-error sums
                (None for sub2/sub3).
    """

    y: jnp.ndarray
    sel: jnp.ndarray
    e4_sums: jnp.ndarray
    e5_sums: jnp.ndarray
    counts: jnp.ndarray
    group_amax: jnp.ndarray
    group_mantissa: jnp.ndarray
    nv_sums: jnp.ndarray | None = None


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class MixedOperand:
    """One GEMM operand in the mixed-representation block layout.

    The operand is seen in its *quantization view*: (R, K) with the
    contraction axis last, zero-padded to a multiple of ``block``.
    Per-block storage is a tri-lane buffer (see kernels/README.md):

    payload_q:    (Rp, Kp) uint8 -- raw fp8 bits (E4M3 bit patterns for
                  TAG_E4M3 blocks, E5M2 for TAG_E5M2; zero elsewhere).
    payload_bf16: (Rp, Kp) original-precision buffer in the operand's
                  stored dtype (bf16 in training); holds the original
                  values for TAG_BF16 blocks, zero elsewhere.
    payload_nib:  (Rp/2, Kp) uint8 -- packed E2M1 nibbles for TAG_NVFP4
                  blocks (zero elsewhere). Row-halves packing *per
                  block*: within block (i, j), byte row r holds the
                  code of logical row r in its low nibble and of row
                  r + br/2 in its high nibble, so the kernel decode is
                  two vector nibble extracts + one sublane concat.
    micro_scales: (Rp, Kp/16) uint8 -- E4M3 bit patterns of the NVFP4
                  per-16-element micro scales (bits of 1.0f for
                  all-zero micro-groups; zero outside TAG_NVFP4 blocks).
    tags:         (nr, nk) int32 per-block representation tag.
    scales:       (nr, nk) f32 reconstructed GAM scales (1.0 for
                  TAG_BF16 and padding-only blocks; the two-level
                  NVFP4 *block* scale for TAG_NVFP4 blocks).
    block:        (br, bk) static block shape.
    shape:        (R, K) static logical (unpadded) shape.
    has_nvfp4:    static tri-state hint for the GEMM kernel's NVFP4
                  decode: False = no block can be TAG_NVFP4 (skip the
                  decode even when a compact sub-byte lane's shape
                  coincides with the full one -- single-block
                  operands), True = TAG_NVFP4 blocks may be present,
                  None = unknown (legacy packs; shape heuristic).

    Any payload lane may be *compact*: collapsed to one don't-care
    block when no (concrete) tag references it -- see :meth:`compact`.
    A fully-fp8 weight then really is ~1 byte/element, and a
    fully-NVFP4 weight ~0.56 bytes/element (0.5 payload + 1/16
    micro-scale).
    """

    payload_q: jnp.ndarray
    payload_bf16: jnp.ndarray
    tags: jnp.ndarray
    scales: jnp.ndarray
    block: Tuple[int, int]
    shape: Tuple[int, int]
    payload_nib: jnp.ndarray = None
    micro_scales: jnp.ndarray = None
    has_nvfp4: bool | None = None

    def __post_init__(self):
        # Sub-byte lanes are optional at construction (pre-NVFP4 call
        # sites); default to compact don't-care blocks so the pytree
        # structure is uniform and every consumer can assume them.
        if self.payload_nib is None:
            lead = jnp.shape(self.tags)[:-2]
            self.payload_nib = jnp.zeros(
                (*lead, *_nib_compact_shape(self.block)), jnp.uint8
            )
        if self.micro_scales is None:
            lead = jnp.shape(self.tags)[:-2]
            self.micro_scales = jnp.zeros(
                (*lead, *_ms_compact_shape(self.block)), jnp.uint8
            )

    def tree_flatten(self):
        return (
            (self.payload_q, self.payload_bf16, self.tags, self.scales,
             self.payload_nib, self.micro_scales),
            (self.block, self.shape, self.has_nvfp4),
        )

    def tree_flatten_with_keys(self):
        # Same children, same order -- but with named key paths, so the
        # payload-lane taint checker (repro.analysis.jaxpr_lint) can
        # seed taint by lane name anywhere a MixedOperand rides in an
        # argument tree.
        children, aux = self.tree_flatten()
        names = ("payload_q", "payload_bf16", "tags", "scales",
                 "payload_nib", "micro_scales")
        return (
            tuple(
                (jax.tree_util.GetAttrKey(n), c)
                for n, c in zip(names, children)
            ),
            aux,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        pq, pbf, tags, scales, nib, ms = children
        block, shape, has_nvfp4 = aux
        return cls(pq, pbf, tags, scales, block, shape, nib, ms,
                   has_nvfp4)

    @property
    def padded_shape(self) -> Tuple[int, int]:
        # Derived from the tag grid, not the payloads (either buffer may
        # be compact).
        return (
            self.tags.shape[-2] * self.block[0],
            self.tags.shape[-1] * self.block[1],
        )

    def compact(self) -> "MixedOperand":
        """Drop every payload lane no tag references down to a single
        don't-care block. Host-side only (needs concrete tags); leading
        stack axes (layer-stacked serving weights) are preserved so
        ``lax.scan`` slicing keeps working. The ``has_nvfp4`` hint is
        refined to the concrete truth while we are looking at the
        tags."""
        tags = np.asarray(self.tags)
        br, bk = self.block
        lead = self.payload_q.shape[:-2]
        out = dataclasses.replace(
            self, has_nvfp4=bool((tags == TAG_NVFP4).any())
        )
        is_fp8 = (tags == TAG_E4M3) | (tags == TAG_E5M2)
        if not is_fp8.any():
            out = dataclasses.replace(
                out, payload_q=jnp.zeros((*lead, br, bk), jnp.uint8)
            )
        if not (tags == TAG_BF16).any():
            out = dataclasses.replace(
                out,
                payload_bf16=jnp.zeros(
                    (*lead, br, bk), self.payload_bf16.dtype
                ),
            )
        if not (tags == TAG_NVFP4).any():
            out = dataclasses.replace(
                out,
                payload_nib=jnp.zeros(
                    (*lead, *_nib_compact_shape(self.block)), jnp.uint8
                ),
                micro_scales=jnp.zeros(
                    (*lead, *_ms_compact_shape(self.block)), jnp.uint8
                ),
            )
        return out

    def transpose(self) -> "MixedOperand":
        """The transposed quantization view (exact: per-block tags,
        scales and payloads are permutation-invariant under block
        transpose). NVFP4 blocks are *not* transpose-invariant -- their
        nibble pairing and 1x16 micro-blocks follow the contraction
        direction -- so packs holding TAG_NVFP4 blocks must re-quantize
        the transposed view instead (core.linear gates this on the
        recipe; concrete tags are also checked here)."""
        assert self.tags.ndim == 2, (
            "transpose() is for single-matrix operands; slice a stacked "
            "operand per layer first (lax.scan or _layer_mo)"
        )
        # NVFP4 precondition, enforced *statically* so it also fires
        # under jit (where tags are tracers and content checks would
        # silently pass): dense sub-byte lanes mean the pack was built
        # with_nvfp4 and may carry TAG_NVFP4 blocks.
        nr, nk = self.tags.shape
        dense_nib = (nr > 1 or nk > 1) and tuple(
            self.payload_nib.shape
        ) == (self.padded_shape[0] // 2, self.padded_shape[1])
        no_nv_msg = (
            "cannot transpose a pack with NVFP4 payload lanes: micro "
            "scales are contraction-directed (re-quantize the "
            "transposed view)"
        )
        assert not dense_nib, no_nv_msg
        if not isinstance(self.tags, jax.core.Tracer):
            assert not (np.asarray(self.tags) == TAG_NVFP4).any(), \
                no_nv_msg
        blockT = (self.block[1], self.block[0])
        return MixedOperand(
            payload_q=self.payload_q.T,
            payload_bf16=self.payload_bf16.T,
            tags=self.tags.T,
            scales=self.scales.T,
            block=blockT,
            shape=(self.shape[1], self.shape[0]),
            # Sub-byte lanes hold no data here (no NVFP4 tags): fresh
            # compact blocks in the transposed geometry.
            payload_nib=jnp.zeros(_nib_compact_shape(blockT), jnp.uint8),
            micro_scales=jnp.zeros(_ms_compact_shape(blockT), jnp.uint8),
            has_nvfp4=False,
        )

    def dequant(self) -> jnp.ndarray:
        """Stored (Fig. 4: original-dtype) values, unpadded (R, K)."""
        R, K = self.shape
        return decode_mixed_ref(self)[:R, :K]


def _nvfp4_lanes(xf, s_nv, tags, block):
    """(nib blocks (nr, nk, br/2, bk), micro-scale blocks (nr, nk, br,
    bk/16)) of the NVFP4 candidate for every block. xf: (nr, nk, br,
    bk) f32; s_nv: (nr, nk) block scales targeting NVFP4.amax. Blocks
    not tagged NVFP4 get zeroed lanes."""
    nr, nk = xf.shape[:2]
    br, bk = block
    ng = bk // NVFP4_MICRO
    xs = xf * s_nv[:, :, None, None]
    g = xs.reshape(nr, nk, br, ng, NVFP4_MICRO)
    d = jnp.max(jnp.abs(g), axis=-1) / E2M1_AMAX  # (nr, nk, br, ng)
    d_q = cast_to_format(d, E4M3)
    safe_d = jnp.where(d_q > 0, d_q, 1.0)
    q = round_to_e2m1(g / safe_d[..., None]).reshape(nr, nk, br, bk)
    codes = encode_e2m1(q)
    nib = (codes[:, :, : br // 2, :]
           | (codes[:, :, br // 2 :, :] << 4)).astype(jnp.uint8)
    ms = jax.lax.bitcast_convert_type(
        safe_d.astype(jnp.float8_e4m3fn), jnp.uint8
    )
    t = tags[:, :, None, None]
    nib = jnp.where(t == TAG_NVFP4, nib, jnp.uint8(0))
    ms = jnp.where(t == TAG_NVFP4, ms, jnp.uint8(0))
    return nib, ms


def pack_mixed(
    x2d: jnp.ndarray,
    tags: jnp.ndarray,
    block: Tuple[int, int],
    algo: str = "gam",
    group_amax: jnp.ndarray | None = None,
    with_nvfp4: bool = False,
) -> MixedOperand:
    """Real-quantize a 2-D operand into the mixed block layout.

    ``tags`` is the (nr, nk) per-block representation decision (e.g.
    ``MorSelect.sel`` or a broadcast tensor-level accept). The fp8 bits
    and GAM scales are computed exactly as the fake-quantization path
    does (same ``scales_from_bmax``, same saturating cast), so
    ``decode_mixed_ref(pack_mixed(x, tags)) == mor fake-quant output``
    bit-for-bit for the selected blocks.

    ``group_amax``: the (already allreduced, when sharded) group amax;
    must be supplied for a shard of a larger operand or the shard-local
    Alg. 1 mantissa would diverge from the decisions in ``tags``.

    ``with_nvfp4``: build the packed-nibble + micro-scale lanes for
    TAG_NVFP4 blocks (sub4 recipe). Static so three-way-and-below
    recipes pay nothing and keep byte-identical packs; requires an
    NVFP4-capable block (even rows, 16-divisible columns).
    """
    br, bk = block
    # Pad up front so the block view keeps the caller's exact block
    # (Partition.resolve would shrink an align-rounded block back to
    # the raw operand extent).
    xp = _pad2d(x2d, br, bk)
    part = Partition("block", (br, bk))
    xb = to_blocks(xp, part)  # (nr, nk, br, bk) original dtype
    nr, nk = xb.shape[:2]
    assert tags.shape == (nr, nk), (tags.shape, (nr, nk))

    bmax = jnp.max(jnp.abs(xb), axis=(2, 3)).astype(jnp.float32)
    s4 = scales_from_bmax(bmax, E4M3, algo, group_amax=group_amax).scale
    s5 = scales_from_bmax(bmax, E5M2, algo, group_amax=group_amax).scale
    xf = xb.astype(jnp.float32)

    def bits(scale, fmt):
        xs = jnp.clip(
            xf * scale[:, :, None, None], -fmt.amax, fmt.amax
        ).astype(fmt.dtype)
        return jax.lax.bitcast_convert_type(xs, jnp.uint8)

    t = tags[:, :, None, None]
    payload_q = jnp.where(
        t == TAG_E4M3, bits(s4, E4M3),
        jnp.where(t == TAG_E5M2, bits(s5, E5M2), jnp.uint8(0)),
    )
    payload_bf16 = jnp.where(t == TAG_BF16, xb, jnp.zeros_like(xb))
    scales = jnp.where(
        tags == TAG_E4M3, s4, jnp.where(tags == TAG_E5M2, s5, 1.0)
    ).astype(jnp.float32)

    padded = (nr * br, nk * bk)
    if with_nvfp4:
        if not nvfp4_block_capable(block):
            raise ValueError(
                f"NVFP4 packing needs an even-row, {NVFP4_MICRO}-"
                f"divisible-column block, got {block} (the sub4 recipe "
                "aligns its partition to (2, 16) automatically)"
            )
        s_nv = scales_from_bmax(
            bmax, NVFP4, algo, group_amax=group_amax
        ).scale
        nib, ms = _nvfp4_lanes(xf, s_nv, tags, block)
        scales = jnp.where(tags == TAG_NVFP4, s_nv, scales).astype(
            jnp.float32
        )
        payload_nib = from_blocks(nib, (padded[0] // 2, padded[1]))
        micro_scales = from_blocks(
            ms, (padded[0], padded[1] // NVFP4_MICRO)
        )
    else:
        payload_nib = jnp.zeros(_nib_compact_shape(block), jnp.uint8)
        micro_scales = jnp.zeros(_ms_compact_shape(block), jnp.uint8)
    return MixedOperand(
        payload_q=from_blocks(payload_q, padded),
        payload_bf16=from_blocks(payload_bf16, padded),
        tags=tags.astype(jnp.int32),
        scales=scales,
        block=(br, bk),
        shape=tuple(x2d.shape),
        payload_nib=payload_nib,
        micro_scales=micro_scales,
        has_nvfp4=with_nvfp4,
    )


def passthrough_mixed(
    x2d: jnp.ndarray, block: Tuple[int, int]
) -> MixedOperand:
    """All-BF16 mixed layout of an unquantized operand (e.g. the
    activation side of a serving GEMM against real-quantized weights).
    The fp8 and sub-byte buffers are compact (one don't-care block) by
    construction."""
    br, bk = block
    xp = _pad2d(x2d, br, bk)
    nr, nk = xp.shape[0] // br, xp.shape[1] // bk
    return MixedOperand(
        payload_q=jnp.zeros((br, bk), jnp.uint8),
        payload_bf16=xp,
        tags=jnp.full((nr, nk), TAG_BF16, jnp.int32),
        scales=jnp.ones((nr, nk), jnp.float32),
        block=(br, bk),
        shape=tuple(x2d.shape),
        has_nvfp4=False,
    )


def activation_row_block(m: int, bk: int) -> int:
    """Row-block size for a passthrough activation pack: full K blocks,
    rows padded only to the 16-sublane TPU tile (decode activations have
    a handful of rows -- padding them to a 128-row block would inflate
    the hot serving GEMM ~8-32x)."""
    return min(bk, -(-m // 16) * 16)


def _full_buffer(buf, padded_shape, fill_dtype):
    """A compact (single-block) payload decodes as zeros; its tags never
    reference it, so the values are don't-care."""
    if tuple(buf.shape) == tuple(padded_shape):
        return buf
    return jnp.zeros(padded_shape, fill_dtype)


def decode_mixed_ref(mo: MixedOperand) -> jnp.ndarray:
    """Padded (Rp, Kp) stored values in the operand's original dtype --
    the exact values the mixed GEMM kernel reconstructs in-register."""
    br, bk = mo.block
    Rp, Kp = mo.padded_shape
    part = Partition("block", (br, bk))
    qb = to_blocks(
        _full_buffer(mo.payload_q, mo.padded_shape, jnp.uint8), part
    )
    q4 = jax.lax.bitcast_convert_type(qb, jnp.float8_e4m3fn).astype(
        jnp.float32
    )
    q5 = jax.lax.bitcast_convert_type(qb, jnp.float8_e5m2).astype(
        jnp.float32
    )
    t = mo.tags[:, :, None, None]
    s = mo.scales[:, :, None, None]
    f8 = (jnp.where(t == TAG_E5M2, q5, q4) / s).astype(
        mo.payload_bf16.dtype
    )
    bfb = to_blocks(
        _full_buffer(
            mo.payload_bf16, mo.padded_shape, mo.payload_bf16.dtype
        ),
        part,
    )
    yb = jnp.where(t == TAG_BF16, bfb, f8)
    if nvfp4_block_capable(mo.block):
        # NVFP4 lane: unpack row-halved nibbles + expand micro scales.
        # Non-capable blocks can never carry TAG_NVFP4, so the branch
        # is a static shape decision.
        nibb = to_blocks(
            _full_buffer(mo.payload_nib, (Rp // 2, Kp), jnp.uint8),
            Partition("block", (br // 2, bk)),
        ).astype(jnp.int32)
        lo = decode_e2m1(nibb & 15)
        hi = decode_e2m1(nibb >> 4)
        vals = jnp.concatenate([lo, hi], axis=2)  # (nr, nk, br, bk)
        msb = to_blocks(
            _full_buffer(
                mo.micro_scales, (Rp, Kp // NVFP4_MICRO), jnp.uint8
            ),
            Partition("block", (br, bk // NVFP4_MICRO)),
        )
        d = jax.lax.bitcast_convert_type(
            msb, jnp.float8_e4m3fn
        ).astype(jnp.float32)
        d_exp = jnp.repeat(d, NVFP4_MICRO, axis=3)
        nv = ((vals * d_exp) / s).astype(mo.payload_bf16.dtype)
        yb = jnp.where(t == TAG_NVFP4, nv, yb)
    return from_blocks(yb, mo.padded_shape)


def mixed_gemm_ref(
    a: MixedOperand,
    b: MixedOperand,
    out_dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """Reference mixed-representation GEMM: C = A @ B^T, unpadded (M, N).

    Decodes both operands to their stored values, then accumulates in
    f32 one K-block at a time in the kernel's grid order, so interpret
    mode and this XLA lowering are bit-identical.
    """
    assert a.block[1] == b.block[1], (a.block, b.block)
    Ka, Kb = a.padded_shape[1], b.padded_shape[1]
    assert Ka == Kb, (a.padded_shape, b.padded_shape)
    bk = a.block[1]
    A = decode_mixed_ref(a).astype(jnp.float32)
    B = decode_mixed_ref(b).astype(jnp.float32)
    acc = jnp.zeros((A.shape[0], B.shape[0]), jnp.float32)
    for k in range(Ka // bk):
        sl = slice(k * bk, (k + 1) * bk)
        acc = acc + jax.lax.dot_general(
            A[:, sl], B[:, sl], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    M, N = a.shape[0], b.shape[0]
    return acc[:M, :N].astype(out_dtype)


def _global_amax(x: jnp.ndarray, mesh_axes) -> jnp.ndarray | None:
    """Allreduced group amax of a sharded operand; None when unsharded
    (scales_from_bmax then derives it from the local block amaxes --
    bit-identical, both are exact maxima of the same elements)."""
    if not mesh_axes:
        return None
    return pmax_over(
        jnp.max(jnp.abs(x.astype(jnp.float32))), mesh_axes
    )


def _blocked_quant_err(
    xb: jnp.ndarray, fmt: FormatSpec, algo: str,
    group_amax: jnp.ndarray | None = None,
):
    """Single-pass quantize + per-block error sums on a blocked view.

    xb: (nm, nk, bm, bk) in its *original* dtype (bf16 in training -- the
    paper's Fig. 4 pipeline is BF16-in/BF16-out, so large intermediates
    never materialize in f32; per-block scale math runs in f32 on the tiny
    (nm, nk) arrays). Returns (xqb in xb.dtype, scales, err_sums f32,
    counts f32). This is the XLA analogue of the fused Pallas kernels.
    ``group_amax`` carries the allreduced global amax for sharded events.
    """
    bmax = jnp.max(jnp.abs(xb), axis=(2, 3)).astype(jnp.float32)
    scales = scales_from_bmax(bmax, fmt, algo, group_amax=group_amax)
    s = scales.scale[:, :, None, None]
    xqb_f32 = cast_to_format(xb.astype(jnp.float32) * s, fmt) / s
    xqb = xqb_f32.astype(xb.dtype)  # Fig. 4: output stays BF16
    xf = xb.astype(jnp.float32)
    nz = xf != 0.0
    err = jnp.where(
        nz,
        jnp.abs((xf - xqb.astype(jnp.float32)) / jnp.where(nz, xf, 1.0)),
        0.0,
    )
    return (
        xqb,
        scales,
        jnp.sum(err, (2, 3)),
        jnp.sum(nz, (2, 3)).astype(jnp.float32),
    )


def quant_err_ref(
    x: jnp.ndarray, part: Partition, fmt: FormatSpec, algo: str = "gam",
    mesh_axes=(),
) -> QuantErr:
    """Reference for the ops.quant_err entry point (one-format events)."""
    xb = to_blocks(x, part)
    xqb, scales, err_sums, counts = _blocked_quant_err(
        xb, fmt, algo, group_amax=_global_amax(x, mesh_axes)
    )
    return QuantErr(
        y=from_blocks(xqb, x.shape),
        err_sums=err_sums,
        counts=counts,
        group_amax=scales.group_amax,
        group_mantissa=scales.group_mantissa,
    )


def mor_select_ref(
    x: jnp.ndarray, part: Partition, mode: str = "sub3", algo: str = "gam",
    mesh_axes=(),
) -> MorSelect:
    """Reference for mor_select_blocks: fused §3.2 per-block selection
    (sub2/sub3), extended with the four-way sub4 NVFP4 cascade."""
    assert mode in ("sub2", "sub3", "sub4"), mode
    xb = to_blocks(x, part)
    g = _global_amax(x, mesh_axes)
    q4b, scales4, e4_sums, counts = _blocked_quant_err(
        xb, E4M3, algo, group_amax=g
    )
    q5b, _, e5_sums, _ = _blocked_quant_err(xb, E5M2, algo, group_amax=g)

    m1 = e4_sums < e5_sums  # Eq. 3
    if mode == "sub2":
        use5 = jnp.zeros_like(m1)
        use_nv, nv_sums, qnb = None, None, None
    else:
        # Eq. 4 dynamic-range gate on the nonzero magnitudes.
        xabs = jnp.abs(xb)
        anynz = counts > 0
        bmax = jnp.max(xabs, axis=(2, 3)).astype(jnp.float32)
        big = jnp.asarray(jnp.finfo(xb.dtype).max, xb.dtype)
        bmin = jnp.min(jnp.where(xb != 0, xabs, big), axis=(2, 3)).astype(
            jnp.float32
        )
        ratio = jnp.where(anynz, bmax / jnp.where(anynz, bmin, 1.0), 1.0)
        use5 = jnp.logical_and(jnp.logical_not(m1), ratio < E5M2_RANGE_RATIO)
        use_nv, nv_sums, qnb = None, None, None
        if mode == "sub4":
            # Four-way cascade: NVFP4 first (Eq. 3 against the E4M3
            # benchmark + the Eq. 4-style NVFP4 range gate), then the
            # plain sub3 cascade for the blocks that fall through. The
            # NVFP4 gate is on *micro-group amaxes* (the quantity the
            # E4M3 micro scales must represent); intra-group range is
            # already priced into nv_sums by Eq. 3.
            qnb, _, nv_sums, _ = _blocked_quant_err(
                xb, NVFP4, algo, group_amax=g
            )
            nm_, nk_, bm_, bk_ = xb.shape
            pad_g = (-bk_) % NVFP4_MICRO
            xbg = xb.astype(jnp.float32)
            if pad_g:
                xbg = jnp.concatenate(
                    [xbg, jnp.zeros((nm_, nk_, bm_, pad_g), jnp.float32)],
                    axis=-1,
                )
            ga = jnp.max(
                jnp.abs(xbg).reshape(nm_, nk_, bm_, -1, NVFP4_MICRO),
                axis=-1,
            )  # (nm, nk, bm, ng) micro-group amaxes
            gnz = ga > 0
            big = jnp.float32(jnp.finfo(jnp.float32).max)
            ga_min = jnp.min(
                jnp.where(gnz, ga, big), axis=(2, 3)
            )
            g_ratio = jnp.where(
                anynz, bmax / jnp.where(anynz, ga_min, 1.0), 1.0
            )
            use_nv = jnp.logical_and(
                nv_sums < e4_sums, g_ratio < NVFP4_RANGE_RATIO
            )

    m1b = m1[:, :, None, None]
    yb = jnp.where(m1b, q4b, jnp.where(use5[:, :, None, None], q5b, xb))
    sel = jnp.where(
        m1, jnp.int32(0), jnp.where(use5, jnp.int32(1), jnp.int32(2))
    )
    if use_nv is not None:
        yb = jnp.where(use_nv[:, :, None, None], qnb, yb)
        sel = jnp.where(use_nv, jnp.int32(TAG_NVFP4), sel)
    return MorSelect(
        y=from_blocks(yb, x.shape),
        sel=sel,
        e4_sums=e4_sums,
        e5_sums=e5_sums,
        counts=counts,
        group_amax=scales4.group_amax,
        group_mantissa=scales4.group_mantissa,
        nv_sums=nv_sums,
    )


def quantize_pack_ref(
    x: jnp.ndarray, part: Partition, mode: str = "sub3",
    algo: str = "gam", mesh_axes=(),
) -> Tuple[MixedOperand, MorSelect]:
    """Reference for ops.quantize_pack: the two-pass lowering (fused
    selection, then the XLA packer over the selection's tags). This is
    the bit-exactness oracle for the pack-emitting kernel: payload
    bytes, nibbles, micro-scale bytes, tags and GAM scales must all
    match. The returned MorSelect carries ``y=None`` -- real
    quantization never materializes the fake-quant output."""
    r = mor_select_ref(x, part, mode, algo, mesh_axes=mesh_axes)
    block = part.resolve(x.shape)
    mo = pack_mixed(
        x, r.sel, block, algo,
        group_amax=r.group_amax,
        with_nvfp4=(mode == "sub4"),
    )
    return mo, r._replace(y=None)


def gam_quant_ref(
    x: jnp.ndarray,
    part: Partition,
    fmt: FormatSpec,
    algo: str = "gam",
):
    """Reference for gam_quant_blocks: (xq, block_exp, err_sums, counts)."""
    scales = compute_scales(x, part, fmt, algo=algo)
    xb = to_blocks(x.astype(jnp.float32), part)
    s = scales.scale[:, :, None, None]
    xqb = cast_to_format(xb * s, fmt) / s
    xq = from_blocks(xqb, x.shape).astype(x.dtype)
    xqb = to_blocks(xq.astype(jnp.float32), part)
    nz = xb != 0
    err = jnp.where(nz, jnp.abs((xb - xqb) / jnp.where(nz, xb, 1.0)), 0.0)
    return (
        xq,
        scales.block_exp,
        jnp.sum(err, (2, 3)),
        jnp.sum(nz, (2, 3)).astype(jnp.float32),
    )


def fp8_gemm_ref(
    a_q: jnp.ndarray,
    b_q: jnp.ndarray,
    a_scale: jnp.ndarray,
    b_scale: jnp.ndarray,
    block: Tuple[int, int, int] = (128, 128, 128),
    out_dtype=jnp.bfloat16,
) -> jnp.ndarray:
    """Dequantize per block then matmul in f32."""
    bm, bn, bk = block
    M, K = a_q.shape
    N = b_q.shape[1]
    a = a_q.astype(jnp.float32).reshape(M // bm, bm, K // bk, bk)
    a = a / a_scale[:, None, :, None]
    b = b_q.astype(jnp.float32).reshape(K // bk, bk, N // bn, bn)
    b = b / b_scale[:, None, :, None]
    return (a.reshape(M, K) @ b.reshape(K, N)).astype(out_dtype)


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = True,
    q_offset=None,
) -> jnp.ndarray:
    """Naive softmax attention. q: (BH, S, d), k/v: (BH, T, d).

    ``q_offset``: key position of query row 0, scalar or (BH,) per-row;
    default aligns the last query with the last key (offset ``T - S``,
    the historical ``tril(k=T-S)`` mask). Ignored when not causal.
    """
    BH, S, d = q.shape
    T = k.shape[1]
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * (d**-0.5)
    if causal:
        off = jnp.broadcast_to(
            jnp.asarray(
                T - S if q_offset is None else q_offset, jnp.int32
            ).reshape(-1),
            (BH,),
        )
        q_pos = off[:, None] + jnp.arange(S)  # (BH, S)
        mask = jnp.arange(T)[None, None, :] <= q_pos[:, :, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum(
        "bqk,bkd->bqd", p, v.astype(jnp.float32)
    ).astype(q.dtype)
