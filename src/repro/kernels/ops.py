"""Jitted public wrappers for the Pallas kernels with backend dispatch.

backend='auto' uses the Pallas kernels on TPU and interpret mode under
REPRO_KERNEL_INTERPRET=1 (CI/CPU validation); otherwise falls back to the
pure-jnp reference path so the library works everywhere.

This module is the single quantization entry point for the MoR recipes:
``repro.core.mor`` routes every quantization event through
:func:`quant_err` (tensor-level / static recipes) and :func:`mor_select`
(sub-tensor recipes), so the Pallas kernels and the XLA lowering can
never drift apart (the refs in :mod:`repro.kernels.ref` ARE the XLA
path). See ``src/repro/kernels/README.md`` for the dispatch matrix.
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.collectives import compat_shard_map, pmax_over
from repro.core.formats import E4M3, E5M2, NVFP4, NVFP4_MICRO, FormatSpec
from repro.core.gam import split_mantissa_exponent
from repro.core.metrics import E5M2_RANGE_RATIO, NVFP4_RANGE_RATIO
from repro.core.partition import Partition, _pad2d

from . import ref as _ref
from .flash_attention import flash_attention_fwd
from .fp8_gemm import fp8_gemm as _fp8_gemm_kernel
from .gam_quant import gam_quant_blocks
from .mixed_gemm import (
    DECODE_CACHE_BUDGET,
    decode_cache_bytes,
    mixed_gemm_blocks,
)
from .mor_select import mor_select_blocks
from .ref import MixedOperand, MorSelect, QuantErr

__all__ = [
    "gam_quant",
    "quant_err",
    "mor_select",
    "quantize_pack",
    "fp8_gemm",
    "mixed_gemm",
    "mixed_dot",
    "sharded_mixed_gemm",
    "flash_attention",
    "resolve_backend",
    "GemmTile",
    "gemm_tile_for",
    "register_gemm_tile",
    "register_decode_tiles",
    "decode_row_block",
    "QuantErr",
    "MorSelect",
    "MixedOperand",
]


def resolve_backend(backend: str = "auto") -> str:
    if backend != "auto":
        if backend not in ("pallas", "interpret", "xla"):
            raise ValueError(
                f"unknown backend: {backend!r} "
                "(want 'auto', 'pallas', 'interpret', or 'xla')"
            )
        return backend
    if os.environ.get("REPRO_KERNEL_INTERPRET") == "1":
        return "interpret"
    if any(d.platform == "tpu" for d in jax.devices()):
        return "pallas"
    return "xla"


def _kernel_backend(backend: str, part: Partition) -> str:
    """Backend for a recipe-level event, demoting kernel-hostile layouts.

    The fused kernels tile the operand as (bm, bk) VMEM blocks; 'channel'
    and 'subchannel' partitions resolve to (1, k) rows, which defeats the
    (8, 128) VPU tiling, and 'tensor' resolves to one whole-operand block
    that can overflow the ~16 MB of VMEM per core -- those events always
    take the XLA lowering.
    """
    be = resolve_backend(backend)
    if be != "xla" and part.kind in ("tensor", "channel", "subchannel"):
        return "xla"
    return be


def _group_amax(x: jnp.ndarray, mesh_axes=()):
    """(g_amax, zero-guarded g_amax): one global XLA reduce, allreduced
    over ``mesh_axes`` when the operand is a shard_map shard -- the
    group amax (and the Alg. 1 mantissa derived from it) must be the
    amax of the *whole* tensor, not of this device's shard."""
    g_amax = pmax_over(
        jnp.max(jnp.abs(x.astype(jnp.float32))), mesh_axes
    )
    # Zero guard AND nonfinite guard: an Inf amax would otherwise pass
    # straight into the Alg. 1 mantissa (Inf > 0 is True) and poison
    # the scales of *every* block, clean ones included. Sanitizing to
    # 1.0 keeps clean blocks' per-block scales finite while the
    # poisoned blocks fall through to the BF16 arm; the raw g_amax is
    # still returned first so the stats guard lanes see the event.
    safe = jnp.where((g_amax > 0) & jnp.isfinite(g_amax), g_amax, 1.0)
    return g_amax, safe


def _group_mantissa(safe_g: jnp.ndarray, fmt: FormatSpec, algo: str):
    """The Alg. 1 shared mantissa m_g (1.0 for the ablation algos)."""
    if algo != "gam":
        return jnp.float32(1.0)
    m_g, _ = split_mantissa_exponent(fmt.amax / safe_g)
    return m_g


def quant_err(
    x: jnp.ndarray,
    part: Partition,
    fmt: FormatSpec = E4M3,
    algo: str = "gam",
    *,
    backend: str = "auto",
    mesh_axes=(),
) -> QuantErr:
    """Fused quantize + per-block error sums of a 2-D operand.

    Backend-dispatched core of the 'tensor' and 'e4m3' recipes. Handles
    block-non-divisible shapes by zero-padding (zeros quantize exactly
    and are excluded from the error sums/counts by construction).
    ``mesh_axes``: shard_map axes to allreduce the group amax over
    (x is then this device's shard; returned err_sums/counts stay
    shard-local, ``group_amax``/``group_mantissa`` are global).
    """
    be = _kernel_backend(backend, part)
    if be == "xla":
        return _ref.quant_err_ref(x, part, fmt, algo, mesh_axes=mesh_axes)
    M, K = x.shape
    bm, bk = part.resolve(x.shape)
    xp = _pad2d(x, bm, bk)
    g_amax, safe_g = _group_amax(x, mesh_axes)
    m_g = _group_mantissa(safe_g, fmt, algo)
    xq, _, err_sums, counts = gam_quant_blocks(
        xp, m_g,
        block=(bm, bk), q_amax=fmt.amax, fmt_dtype=fmt.dtype, algo=algo,
        interpret=(be == "interpret"),
    )
    return QuantErr(
        y=xq[:M, :K],
        err_sums=err_sums,
        counts=counts,
        group_amax=g_amax,
        group_mantissa=m_g,
    )


def _select_kernel_call(x, block, mode, algo, emit, be, mesh_axes):
    """Shared prologue + launch for both selection entry points: pad,
    one global amax reduce (allreduced when sharded), the per-format
    Alg. 1 mantissas, and the kernel call. One definition so the
    fake-quant and pack-emitting paths can never drift on scaling
    inputs. Returns (kernel outputs, group_amax, E4M3 mantissa)."""
    bm, bk = block
    xp = _pad2d(x, bm, bk)
    g_amax, safe_g = _group_amax(x, mesh_axes)
    mg4 = _group_mantissa(safe_g, E4M3, algo)
    mg5 = _group_mantissa(safe_g, E5M2, algo)
    mgnv = _group_mantissa(safe_g, NVFP4, algo)
    out = mor_select_blocks(
        xp, jnp.stack([mg4, mg5, mgnv]), safe_g,
        block=block, q_amax4=E4M3.amax, q_amax5=E5M2.amax,
        q_amax_nv=NVFP4.amax, dt4=E4M3.dtype, dt5=E5M2.dtype, mode=mode,
        algo=algo, range_ratio=E5M2_RANGE_RATIO,
        nv_range_ratio=NVFP4_RANGE_RATIO, emit=emit,
        interpret=(be == "interpret"),
    )
    return out, g_amax, mg4


def mor_select(
    x: jnp.ndarray,
    part: Partition,
    mode: str = "sub3",
    algo: str = "gam",
    *,
    backend: str = "auto",
    mesh_axes=(),
) -> MorSelect:
    """Fused sub-tensor MoR selection (§3.2, + sub4) of a 2-D operand.

    One pass per block: the fp8 candidates (and for ``mode='sub4'`` the
    two-level NVFP4 candidate), Eq. 3 error comparison, Eq. 4 range
    gates, and the per-block select -- versus the three-plus full
    operand passes of the naive lowering. ``mesh_axes``: shard_map
    axes to allreduce the group amax over (per-block sums/selects stay
    shard-local; the Eq. 3/4 gates are per-block, so with a global
    amax every shard makes the single-device choice bit-for-bit --
    NVFP4 micro scales derive from the block data and the allreduced
    group amax, so sharded sub4 packs stay bit-identical too).
    """
    be = _kernel_backend(backend, part)
    M, K = x.shape
    bm, bk = part.resolve(x.shape)
    if mode == "sub4" and bk % NVFP4_MICRO:
        # Micro-blocks need 16-divisible contraction blocks; the sub4
        # recipe's aligned partition guarantees this, direct callers
        # with exotic blocks take the (internally padding) XLA path.
        be = "xla"
    if be == "xla":
        return _ref.mor_select_ref(x, part, mode, algo, mesh_axes=mesh_axes)
    out, g_amax, mg4 = _select_kernel_call(
        x, (bm, bk), mode, algo, "select", be, mesh_axes
    )
    y, sel, e4_sums, e5_sums, counts = out[:5]
    return MorSelect(
        y=y[:M, :K],
        sel=sel,
        e4_sums=e4_sums,
        e5_sums=e5_sums,
        counts=counts,
        group_amax=g_amax,
        group_mantissa=mg4,
        nv_sums=out[5] if mode == "sub4" else None,
    )


def quantize_pack(
    x: jnp.ndarray,
    part: Partition,
    mode: str = "sub3",
    algo: str = "gam",
    *,
    backend: str = "auto",
    mesh_axes=(),
):
    """One-pass fused sub-tensor selection *and* real packing.

    The pack-emitting variant of :func:`mor_select`: the same single
    VMEM pass per block that makes the §3.2 decision also writes the
    winner's real payload -- fp8 bit patterns, BF16 passthrough values,
    per-block GAM scales, and for ``mode='sub4'`` the packed E2M1
    nibbles + E4M3 micro-scale bytes -- so ``quantize_for_gemm`` no
    longer re-derives block amaxes / Alg. 1 scales / payload bits in a
    second XLA pass over the operand. Byte-identical to
    ``ref.pack_mixed`` on the selection's tags (the two-pass lowering
    stays as the ``backend='xla'`` oracle, ``ref.quantize_pack_ref``).

    Returns ``(MixedOperand, MorSelect)``; the MorSelect carries the
    per-block error sums / counts / group scalars the recipe layer
    aggregates into the stats vector, with ``y=None`` (real
    quantization never materializes the fake-quant output).

    ``mesh_axes`` as in :func:`mor_select`: the group amax (and with
    it every Alg. 1 scale and micro scale) is allreduced first, so a
    shard packs exactly the bytes its blocks would get on one device.
    """
    be = _kernel_backend(backend, part)
    M, K = x.shape
    bm, bk = part.resolve(x.shape)
    if mode == "sub4" and (bk % NVFP4_MICRO or bm % 2):
        # Nibble packing pairs rows and micro-blocks need 16-divisible
        # contraction blocks; the sub4 recipe's aligned partition
        # guarantees both, direct callers with exotic blocks take the
        # XLA path (whose packer raises on truly incapable blocks).
        be = "xla"
    if be == "xla":
        return _ref.quantize_pack_ref(x, part, mode, algo,
                                      mesh_axes=mesh_axes)
    out, g_amax, mg4 = _select_kernel_call(
        x, (bm, bk), mode, algo, "pack", be, mesh_axes
    )
    if mode == "sub4":
        (pq, pbf, sel, scales, e4_sums, e5_sums, counts, nv_sums,
         nib, ms) = out
    else:
        pq, pbf, sel, scales, e4_sums, e5_sums, counts = out
        nv_sums, nib, ms = None, None, None
    mo = MixedOperand(
        payload_q=pq,
        payload_bf16=pbf,
        tags=sel,
        scales=scales,
        block=(bm, bk),
        shape=(M, K),
        payload_nib=nib,
        micro_scales=ms,
        has_nvfp4=(mode == "sub4"),
    )
    r = MorSelect(
        y=None,
        sel=sel,
        e4_sums=e4_sums,
        e5_sums=e5_sums,
        counts=counts,
        group_amax=g_amax,
        group_mantissa=mg4,
        nv_sums=nv_sums,
    )
    return mo, r


def gam_quant(
    x: jnp.ndarray,
    *,
    block=(128, 128),
    fmt: FormatSpec = E4M3,
    algo: str = "gam",
    backend: str = "auto",
):
    """Fused quantize of a 2-D operand. Returns (xq, exp, err_sums, counts).

    Pallas path: global amax via one XLA reduce -> group mantissa -> fused
    per-block kernel. XLA path: the pure-jnp oracle.
    """
    be = resolve_backend(backend)
    part = Partition("block", block)
    if be == "xla":
        return _ref.gam_quant_ref(x, part, fmt, algo)
    _, safe_g = _group_amax(x)
    m_g = _group_mantissa(safe_g, fmt, algo)
    return gam_quant_blocks(
        x, m_g,
        block=block, q_amax=fmt.amax, fmt_dtype=fmt.dtype, algo=algo,
        interpret=(be == "interpret"),
    )


def fp8_gemm(a_q, b_q, a_scale, b_scale, *, block=(128, 128, 128),
             out_dtype=jnp.bfloat16, backend: str = "auto"):
    be = resolve_backend(backend)
    if be == "xla":
        return _ref.fp8_gemm_ref(a_q, b_q, a_scale, b_scale, block,
                                 out_dtype)
    return _fp8_gemm_kernel(
        a_q, b_q, a_scale, b_scale, block=block, out_dtype=out_dtype,
        interpret=(be == "interpret"),
    )


class GemmTile(NamedTuple):
    """Static tiling knobs for one mixed-GEMM launch.

    decode_cache: use the k-keyed VMEM cache for the A decode (None =
                  the kernel's fit-based auto rule).
    bn_mult:      B row blocks per kernel step (the wider-bn sweep; 1 =
                  one pack block per tile).
    """

    decode_cache: Optional[bool] = None
    bn_mult: int = 1


# Shape-keyed block-size autotune table consulted by :func:`mixed_gemm`:
# (n_m, n_n, n_k) block-grid key -> GemmTile. Seeded from the bench
# lanes (benchmarks/bench_kernels.py records the chosen tile per row);
# anything absent falls through to gemm_tile_for's heuristic. Extend
# with register_gemm_tile.
_GEMM_TILE_TABLE: dict = {}


def register_gemm_tile(n_m: int, n_n: int, n_k: int, tile: GemmTile):
    """Pin the tile for one block-grid shape (overrides the heuristic)."""
    _GEMM_TILE_TABLE[(n_m, n_n, n_k)] = tile


def decode_row_block(m_rows: int, bk: int = 128) -> int:
    """Activation row block for an m_rows-row decode GEMM: the 16-row
    sublane tile for skinny batches (slots << 128), never a padded 128
    (see ``ref.activation_row_block``)."""
    return _ref.activation_row_block(m_rows, bk)


def register_decode_tiles(params, m_rows: int) -> int:
    """Pin the skinny-M decode lane for every quantized weight.

    Serving decode GEMMs are (m_rows, K) @ (K, N) with m_rows = engine
    slots << 128: the activation packs at the 16-row sublane tile
    (``decode_row_block``), giving a 1 x n_k A grid whose per-(i, k)
    decode stripes are tiny -- the k-keyed VMEM cache always fits, so
    the lane is (decode_cache=True, bn_mult=1). Walks ``params`` for
    QTensor-like leaves (anything exposing ``as_mixed_operand``) and
    registers one table entry per distinct (n_m, n_n, n_k) block grid;
    returns the number of grids registered. Idempotent.
    """
    grids = set()
    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda l: hasattr(l, "as_mixed_operand")
    )
    for leaf in leaves:
        if not hasattr(leaf, "as_mixed_operand"):
            continue
        mo = leaf.as_mixed_operand()
        n_n, n_k = mo.tags.shape[-2], mo.tags.shape[-1]
        bm = decode_row_block(m_rows, mo.block[1])
        key = (-(-m_rows // bm), n_n, n_k)
        register_gemm_tile(
            *key,
            GemmTile(
                decode_cache=decode_cache_bytes(n_k, bm, mo.block[1])
                <= DECODE_CACHE_BUDGET,
                bn_mult=1,
            ),
        )
        grids.add(key)
    return len(grids)


def gemm_tile_for(
    n_m: int, n_n: int, n_k: int, block, tile: Optional[GemmTile] = None
) -> GemmTile:
    """Resolve the tile for a (n_m, n_n, n_k) block grid.

    Explicit ``tile`` wins, then the registered table, then the
    heuristic: prefer the decode cache whenever its (n_k, bm, bk) f32
    stripe store fits the VMEM budget; otherwise sweep a wider N tile
    (largest bn_mult in {4, 2} dividing n_n with bn * bn_mult <= 512)
    so the A decode still amortizes without scratch.
    """
    if tile is not None:
        return tile
    hit = _GEMM_TILE_TABLE.get((n_m, n_n, n_k))
    if hit is not None:
        return hit
    bm, bn, bk = block
    if n_n <= 1:
        return GemmTile(decode_cache=False, bn_mult=1)
    if decode_cache_bytes(n_k, bm, bk) <= DECODE_CACHE_BUDGET:
        return GemmTile(decode_cache=True, bn_mult=1)
    bn_mult = next(
        (m for m in (4, 2) if n_n % m == 0 and bn * m <= 512), 1
    )
    return GemmTile(decode_cache=False, bn_mult=bn_mult)


def mixed_gemm(
    a: MixedOperand,
    b: MixedOperand,
    *,
    out_dtype=jnp.bfloat16,
    backend: str = "auto",
    tile: Optional[GemmTile] = None,
) -> jnp.ndarray:
    """Mixed-representation block GEMM: C = A @ B^T, unpadded (M, N).

    Both operands arrive in their quantization view (rows x contraction,
    see :class:`~repro.kernels.ref.MixedOperand`); every block is decoded
    per its tag (E4M3 / E5M2 / BF16 passthrough / NVFP4) in-register and
    the product is f32-accumulated -- one fused kernel launch on TPU
    versus the dequantize-then-bf16-matmul lowering it replaces. The
    per-(i, k) A decode is amortized across the N sweep (VMEM cache or
    wider-bn tile, :func:`gemm_tile_for`); ``tile`` overrides the
    autotune table end to end (``mixed_dot``/``qdot`` pass it through).
    """
    be = resolve_backend(backend)
    if be == "xla":
        return _ref.mixed_gemm_ref(a, b, out_dtype)
    assert a.block[1] == b.block[1], (a.block, b.block)
    n_m, n_k = a.tags.shape
    n_n = b.tags.shape[0]
    cfg = gemm_tile_for(
        n_m, n_n, n_k, (a.block[0], b.block[0], a.block[1]), tile
    )
    bn_mult = cfg.bn_mult if n_n % max(cfg.bn_mult, 1) == 0 else 1
    out = mixed_gemm_blocks(
        a.payload_q, a.payload_bf16, a.payload_nib, a.micro_scales,
        a.tags, a.scales,
        b.payload_q, b.payload_bf16, b.payload_nib, b.micro_scales,
        b.tags, b.scales,
        block=(a.block[0], b.block[0], a.block[1]),
        out_dtype=out_dtype,
        interpret=(be == "interpret"),
        a_has_nvfp4=a.has_nvfp4,
        b_has_nvfp4=b.has_nvfp4,
        decode_cache=cfg.decode_cache,
        bn_mult=max(bn_mult, 1),
    )
    return out[: a.shape[0], : b.shape[0]]


def mixed_dot(
    x2: jnp.ndarray,
    mo: MixedOperand,
    *,
    out_dtype=jnp.bfloat16,
    backend: str = "auto",
    tile: Optional[GemmTile] = None,
) -> jnp.ndarray:
    """x2 @ W^T for an unquantized (M, K) activation against a mixed
    (N, K)-view operand: the shared serving wrapper behind ``qdot``,
    ``mor_dot``'s QTensor path and the quantized lm-head -- packs the
    activation as an all-BF16 compact pack with the row block sized to
    the activation (decode steps have a handful of rows)."""
    bk = mo.block[1]
    a = _ref.passthrough_mixed(
        x2, (_ref.activation_row_block(x2.shape[0], bk), bk)
    )
    return mixed_gemm(a, mo, out_dtype=out_dtype, backend=backend,
                      tile=tile)


def _local_mixed(payload_q, payload_bf16, nib, ms, tags, scales, block,
                 has_nvfp4):
    """Rebuild a shard-local MixedOperand from shard_map-sliced leaves.

    The local logical shape is the local *padded* shape: per-shard
    padding blocks decode to zeros (zero payloads under scale 1.0), so
    they contribute nothing to the product and the caller slices the
    assembled global output back to the logical (M, N) once. The static
    ``has_nvfp4`` hint survives the leaf round-trip via closure.
    """
    shape = (tags.shape[-2] * block[0], tags.shape[-1] * block[1])
    return MixedOperand(payload_q, payload_bf16, tags, scales, block,
                        shape, nib, ms, has_nvfp4)


def sharded_mixed_gemm(
    a: MixedOperand,
    b: MixedOperand,
    *,
    mesh,
    row_axis=None,
    col_axis=None,
    contract_axis=None,
    out_dtype=jnp.bfloat16,
    backend: str = "auto",
) -> jnp.ndarray:
    """Mesh-sharded mixed-representation GEMM: C = A @ B^T under shard_map.

    Runs the block GEMM *per shard*: each device launches the fused
    kernel on its local payload blocks with the matching local tag/scale
    SMEM operands (tags/scales shard on the same block grid as the
    payload BlockSpecs, so a shard's kernel sees exactly the metadata of
    its own blocks -- see kernels/README.md). Sharding must be
    block-aligned: each sharded axis size must divide the operand's
    block-grid extent.

      row_axis       shards A's rows      -> C rows sharded, no traffic.
      col_axis       shards B's rows      -> C cols sharded, no traffic.
      contract_axis  shards K of both     -> per-shard partial products
                     are f32-psum'd before the out_dtype cast.

    Compact payload buffers (see MixedOperand.compact) are replicated --
    a single don't-care block has no row axis to shard. Operands packed
    by ``quantize_for_gemm`` under a policy with matching ``mesh_axes``
    carry shard-local blocks whose tags/scales are bit-identical to the
    single-device pack (tests/test_sharded_mor.py).
    """
    from repro.sharding.rules import mixed_operand_pspec

    assert a.block[1] == b.block[1], (a.block, b.block)
    if a.padded_shape[1] != b.padded_shape[1]:
        raise ValueError(
            f"contraction extents differ: {a.padded_shape} vs "
            f"{b.padded_shape}"
        )

    def _ax(name):
        return mesh.shape[name] if name else 1

    for mo, rax, who in ((a, row_axis, "A"), (b, col_axis, "B")):
        if mo.tags.shape[-2] % _ax(rax):
            raise ValueError(
                f"{who}: row block grid {mo.tags.shape[-2]} not divisible "
                f"by mesh axis {rax!r} ({_ax(rax)})"
            )
        if mo.tags.shape[-1] % _ax(contract_axis):
            raise ValueError(
                f"{who}: contraction block grid {mo.tags.shape[-1]} not "
                f"divisible by mesh axis {contract_axis!r} "
                f"({_ax(contract_axis)})"
            )

    from jax.sharding import PartitionSpec as P

    a_specs = mixed_operand_pspec(a, row_axis, contract_axis)
    b_specs = mixed_operand_pspec(b, col_axis, contract_axis)
    inner_dtype = jnp.float32 if contract_axis else out_dtype
    block_a, block_b = a.block, b.block
    nv_a, nv_b = a.has_nvfp4, b.has_nvfp4

    def body(aq, abf, anib, ams, at, asc, bq, bbf, bnib, bms, bt, bsc):
        out = mixed_gemm(
            _local_mixed(aq, abf, anib, ams, at, asc, block_a, nv_a),
            _local_mixed(bq, bbf, bnib, bms, bt, bsc, block_b, nv_b),
            out_dtype=inner_dtype,
            backend=backend,
        )
        if contract_axis:
            out = jax.lax.psum(out, contract_axis)
        return out.astype(out_dtype)

    sm = compat_shard_map(
        body, mesh,
        in_specs=a_specs + b_specs,
        out_specs=P(row_axis, col_axis),
    )
    out = sm(
        a.payload_q, a.payload_bf16, a.payload_nib, a.micro_scales,
        a.tags, a.scales,
        b.payload_q, b.payload_bf16, b.payload_nib, b.micro_scales,
        b.tags, b.scales,
    )
    return out[: a.shape[0], : b.shape[0]]


def flash_attention(q, k, v, *, causal=True, q_offset=None,
                    block_q=512, block_k=512, backend: str = "auto"):
    """Backend-dispatched flash attention.

    Two accepted layouts:

    * 4-D GQA contract -- q ``(B, S, Hq, dh)`` against k/v
      ``(B, T, Hkv, dh)`` with ``Hq % Hkv == 0``: kv heads are repeated
      into the q-head count here (each q head ``h`` reads kv head
      ``h // (Hq // Hkv)``), operands fold to ``(B*Hq, S|T, dh)`` for
      the kernel, and the output unfolds back to ``(B, S, Hq, dh)``.
      ``q_offset`` may be a scalar or per-batch-row ``(B,)``.
    * 3-D pre-folded ``(BH, S|T, d)`` passthrough (head counts already
      matched by the caller); ``q_offset`` scalar or ``(BH,)``.

    ``q_offset`` is the key position of query row 0 (default: last
    query aligned with last key, i.e. ``T - S``) -- see
    ``flash_attention_fwd``.
    """
    if q.ndim == 4:
        B, S, Hq, dh = q.shape
        if k.ndim != 4 or v.ndim != 4 or k.shape != v.shape:
            raise ValueError(
                f"4-D q needs matching 4-D k/v, got k{k.shape} v{v.shape}"
            )
        T, Hkv = k.shape[1], k.shape[2]
        if k.shape != (B, T, Hkv, dh) or Hq % Hkv:
            raise ValueError(
                f"GQA contract wants k/v (B={B}, T, Hkv, dh={dh}) with "
                f"Hq={Hq} divisible by Hkv, got k{k.shape}"
            )
        G = Hq // Hkv

        def fold(x):  # (B, L, H, dh) -> (B*H, L, dh)
            H = x.shape[2]
            return jnp.moveaxis(x, 2, 1).reshape(B * H, x.shape[1], dh)

        qf = fold(q)
        kf = fold(jnp.repeat(k, G, axis=2) if G > 1 else k)
        vf = fold(jnp.repeat(v, G, axis=2) if G > 1 else v)
        off = q_offset
        if off is not None:
            off = jnp.asarray(off, jnp.int32).reshape(-1)
            if off.shape[0] == B and B != B * Hq:
                off = jnp.repeat(off, Hq)
        out = flash_attention(
            qf, kf, vf, causal=causal, q_offset=off,
            block_q=block_q, block_k=block_k, backend=backend,
        )
        return jnp.moveaxis(out.reshape(B, Hq, S, dh), 1, 2)
    be = resolve_backend(backend)
    if be == "xla":
        return _ref.flash_attention_ref(q, k, v, causal, q_offset=q_offset)
    return flash_attention_fwd(
        q, k, v, causal=causal, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
        interpret=(be == "interpret"),
    )
