"""Jitted public wrappers for the Pallas kernels with backend dispatch.

backend='auto' uses the Pallas kernels on TPU and interpret mode under
REPRO_KERNEL_INTERPRET=1 (CI/CPU validation); otherwise falls back to the
pure-jnp reference path so the library works everywhere.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.formats import E4M3, FormatSpec
from repro.core.gam import split_mantissa_exponent
from repro.core.partition import Partition

from . import ref as _ref
from .flash_attention import flash_attention_fwd
from .fp8_gemm import fp8_gemm as _fp8_gemm_kernel
from .gam_quant import gam_quant_blocks

__all__ = ["gam_quant", "fp8_gemm", "flash_attention", "resolve_backend"]


def resolve_backend(backend: str = "auto") -> str:
    if backend != "auto":
        return backend
    if os.environ.get("REPRO_KERNEL_INTERPRET") == "1":
        return "interpret"
    if any(d.platform == "tpu" for d in jax.devices()):
        return "pallas"
    return "xla"


def gam_quant(
    x: jnp.ndarray,
    *,
    block=(128, 128),
    fmt: FormatSpec = E4M3,
    algo: str = "gam",
    backend: str = "auto",
):
    """Fused quantize of a 2-D operand. Returns (xq, exp, err_sums, counts).

    Pallas path: global amax via one XLA reduce -> group mantissa -> fused
    per-block kernel. XLA path: the pure-jnp oracle.
    """
    be = resolve_backend(backend)
    part = Partition("block", block)
    if be == "xla":
        return _ref.gam_quant_ref(x, part, fmt, algo)
    g_amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    safe_g = jnp.where(g_amax > 0, g_amax, 1.0)
    m_g, _ = split_mantissa_exponent(fmt.amax / safe_g)
    if algo != "gam":
        m_g = jnp.float32(1.0)
    return gam_quant_blocks(
        x, m_g,
        block=block, q_amax=fmt.amax, fmt_dtype=fmt.dtype, algo=algo,
        interpret=(be == "interpret"),
    )


def fp8_gemm(a_q, b_q, a_scale, b_scale, *, block=(128, 128, 128),
             out_dtype=jnp.bfloat16, backend: str = "auto"):
    be = resolve_backend(backend)
    if be == "xla":
        return _ref.fp8_gemm_ref(a_q, b_q, a_scale, b_scale, block,
                                 out_dtype)
    return _fp8_gemm_kernel(
        a_q, b_q, a_scale, b_scale, block=block, out_dtype=out_dtype,
        interpret=(be == "interpret"),
    )


def flash_attention(q, k, v, *, causal=True, block_q=512, block_k=512,
                    backend: str = "auto"):
    """q/k/v: (BH, S|T, d) head-folded layout."""
    be = resolve_backend(backend)
    if be == "xla":
        return _ref.flash_attention_ref(q, k, v, causal)
    return flash_attention_fwd(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=(be == "interpret"),
    )
