from .ops import flash_attention, fp8_gemm, gam_quant, resolve_backend

__all__ = ["flash_attention", "fp8_gemm", "gam_quant", "resolve_backend"]
