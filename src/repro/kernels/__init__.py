from .ops import (
    MorSelect,
    QuantErr,
    flash_attention,
    fp8_gemm,
    gam_quant,
    mor_select,
    quant_err,
    resolve_backend,
)

__all__ = [
    "MorSelect",
    "QuantErr",
    "flash_attention",
    "fp8_gemm",
    "gam_quant",
    "mor_select",
    "quant_err",
    "resolve_backend",
]
