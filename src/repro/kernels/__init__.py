from .ops import (
    MixedOperand,
    MorSelect,
    QuantErr,
    flash_attention,
    fp8_gemm,
    gam_quant,
    mixed_gemm,
    mor_select,
    quant_err,
    resolve_backend,
)

__all__ = [
    "MixedOperand",
    "MorSelect",
    "QuantErr",
    "flash_attention",
    "fp8_gemm",
    "gam_quant",
    "mixed_gemm",
    "mor_select",
    "quant_err",
    "resolve_backend",
]
