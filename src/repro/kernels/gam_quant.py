"""Fused GAM quantize kernel (Pallas, TPU target).

One VMEM-resident pass per 128x128 block: block amax -> GAM scale
reconstruction (shared group mantissa + per-block E8M0 exponent, Alg. 1)
-> saturating cast -> dequant -> per-block relative-error sums. On TPU
this replaces the ~6 HBM passes of the XLA lowering (see §Perf).

Exponent/mantissa arithmetic uses integer bit manipulation only (Mosaic
has no frexp); `exp2i` is an exponent-field bitcast, exactly as in
repro.core.gam.

Grid: (M/bm, K/bk). The group (tensor) mantissa is computed outside the
kernel from the global amax (one cheap XLA reduce) and broadcast in as a
(1, 1) block.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gam_quant_blocks"]


def _split_me(s):
    """Bit-level (mantissa in [1,2), exponent) of positive f32 (1, 1) s.

    s must be a (1, 1) vector, not a scalar: Mosaic's tpu.bitcast only
    accepts vector operands.
    """
    bits = jax.lax.bitcast_convert_type(s, jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127
    m = jax.lax.bitcast_convert_type(
        (bits & 0x7FFFFF) | (127 << 23), jnp.float32
    )
    return m, e


def _exp2i(e):
    # Full E8M0 domain [-126, 127], matching core.gam (the 126 clamp
    # was the double-rounding bug on tiny-amax blocks).
    e = jnp.clip(e, -126, 127)
    return jax.lax.bitcast_convert_type(
        (e + 127) << 23, jnp.float32
    )


def _kernel(mg_ref, x_ref, out_ref, exp_ref, err_ref, cnt_ref,
            *, q_amax: float, out_dtype, algo: str):
    i, j = pl.program_id(0), pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    m_g = mg_ref[0, 0]

    # (1, 1) block amax: the exponent/mantissa bit arithmetic must run on
    # vectors (Mosaic's tpu.bitcast rejects scalars).
    bmax = jnp.max(jnp.abs(x), axis=(0, 1), keepdims=True)
    safe_b = jnp.where(bmax > 0, bmax, 1.0)
    s_b = q_amax / safe_b
    m_b, e_b = _split_me(s_b)

    if algo == "gam":
        # Alg. 1 rounding: avoid saturation when m_g > m_b.
        e_b = jnp.where(m_g <= m_b, e_b, e_b - 1)
        scale = m_g * _exp2i(e_b)
    elif algo == "e8m0":
        scale = _exp2i(e_b)
    else:  # fp32_amax
        scale = s_b

    xs = jnp.clip(x * scale, -q_amax, q_amax)
    xq = xs.astype(out_dtype).astype(jnp.float32) / scale
    # Error is measured on the *stored* (Fig. 4: BF16) dequantized value.
    xq_stored = xq.astype(out_ref.dtype)
    xq = xq_stored.astype(jnp.float32)

    nz = x != 0.0
    rel = jnp.where(nz, jnp.abs((x - xq) / jnp.where(nz, x, 1.0)), 0.0)

    out_ref[...] = xq_stored
    # The (nm, nk) stat outputs live whole in SMEM across the grid (TPU
    # tiling forbids (1, 1) VMEM blocks and VMEM rejects scalar stores);
    # each step writes its own cell.
    exp_ref[i, j] = e_b[0, 0].astype(jnp.int32)
    err_ref[i, j] = jnp.sum(rel)
    cnt_ref[i, j] = jnp.sum(nz.astype(jnp.float32))


@functools.partial(
    jax.jit,
    static_argnames=("block", "q_amax", "fmt_dtype", "algo", "interpret"),
)
def gam_quant_blocks(
    x: jnp.ndarray,
    group_mantissa: jnp.ndarray,
    *,
    block: Tuple[int, int] = (128, 128),
    q_amax: float = 448.0,
    fmt_dtype=jnp.float8_e4m3fn,
    algo: str = "gam",
    interpret: bool = False,
):
    """x: (M, K) with M % bm == 0, K % bk == 0.

    Returns (xq fake-quantized in x.dtype, block_exp (nm, nk) i32,
    err_sums (nm, nk) f32, counts (nm, nk) f32).
    """
    M, K = x.shape
    bm, bk = block
    assert M % bm == 0 and K % bk == 0, (x.shape, block)
    nm, nk = M // bm, K // bk
    mg = jnp.reshape(group_mantissa.astype(jnp.float32), (1, 1))

    kernel = functools.partial(
        _kernel, q_amax=q_amax, out_dtype=fmt_dtype, algo=algo
    )
    out_shapes = (
        jax.ShapeDtypeStruct((M, K), x.dtype),
        jax.ShapeDtypeStruct((nm, nk), jnp.int32),
        jax.ShapeDtypeStruct((nm, nk), jnp.float32),
        jax.ShapeDtypeStruct((nm, nk), jnp.float32),
    )
    grid = (nm, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),  # group mantissa
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),  # x block (VMEM)
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=out_shapes,
        interpret=interpret,
    )(mg, x)
