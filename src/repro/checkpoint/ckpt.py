"""Fault-tolerant checkpointing: async, atomic, keep-k, reshard-on-restore.

Layout: <dir>/step_<n>/arrays.npz + manifest.json (tree structure, step,
mesh fingerprint). Writes go to a tmp dir then os.rename (atomic on one
filesystem), so a preempted save can never corrupt the latest checkpoint;
`latest_step` only sees fully-renamed directories.

Async mode hands the (host-fetched) arrays to a writer thread so the train
loop overlaps checkpoint IO with compute; `wait()` joins before exit.
Restore works onto a *different* mesh: arrays are loaded on host and
device_put against the target shardings (elastic re-mesh after failures).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["Checkpointer", "latest_step"]

_SEP = "/"


_EXOTIC = {2: np.uint16, 1: np.uint8}  # bf16/f16 and f8 variants


def _key(path) -> str:
    return jax.tree_util.keystr(path)


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    """Returns (arrays bit-cast to npz-safe dtypes, original dtype names).

    np.savez silently degrades ml_dtypes (bf16, f8) to raw void bytes;
    we store them viewed as uintN and restore via the dtype sidecar.
    """
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _key(path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
            arr = arr.view(_EXOTIC[arr.dtype.itemsize])
        flat[key] = arr
    return flat, dtypes


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            manifest = os.path.join(directory, name, "manifest.json")
            if os.path.exists(manifest):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save=True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Snapshot ``tree`` at ``step``. Fetches to host synchronously
        (cheap vs a step), writes in the background when async."""
        self.wait()
        flat, dtypes = _flatten(tree)
        meta = {"step": step, "extra": extra or {}, "dtypes": dtypes}

        def write():
            final = os.path.join(self.dir, f"step_{step}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.dir, n, "manifest.json"))
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- restore
    def restore(self, step: int, target: Any, shardings: Any = None):
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings``: matching pytree of Shardings for
        elastic re-mesh; None keeps arrays on the default device."""
        d = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(d, "arrays.npz")) as zf:
            flat = {k: zf[k] for k in zf.files}
        dtypes = self.manifest(step).get("dtypes", {})

        paths, treedef = jax.tree_util.tree_flatten_with_path(target)
        shard_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None
            else [None] * len(paths)
        )
        out = []
        for (path, leaf), sh in zip(paths, shard_leaves):
            key = _key(path)
            arr = flat[key]
            orig = dtypes.get(key)
            if orig and str(arr.dtype) != orig:
                arr = arr.view(np.dtype(orig))
            if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
                arr = arr.astype(leaf.dtype)
            out.append(
                jax.device_put(arr, sh) if sh is not None
                else jax.numpy.asarray(arr)
            )
        return jax.tree_util.tree_unflatten(treedef, out)

    def manifest(self, step: int) -> dict:
        with open(
            os.path.join(self.dir, f"step_{step}", "manifest.json")
        ) as f:
            return json.load(f)
