"""Shared harness for the paper-quality benchmarks (Tables 2-4, Figs 10-11).

Trains a reduced Nemotron-3-family model (dense, squared-ReLU -- the
paper's experiment model) on the deterministic synthetic stream under a
given MoR policy, and reports train/validation loss plus MoR decision
statistics. CPU-feasible stand-in for the paper's 8B/1T-token runs; the
comparisons (MoR variant vs BF16 baseline) mirror the paper's tables.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import MoRDotPolicy
from repro.data import DataConfig, SyntheticLM
from repro.models import make_loss_fn, make_tokens, init_params
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.train import TrainConfig, make_train_step

VOCAB = 256
SEQ = 128
BATCH = 8


def bench_model_cfg():
    cfg = reduced(get_config("nemotron3-8b"))
    return dataclasses.replace(
        cfg, name="nemotron3-bench", vocab=VOCAB, d_model=128, n_layers=2,
        n_heads=4, n_kv=4, head_dim=32, d_ff=384,
    )


@dataclasses.dataclass
class QualityResult:
    name: str
    train_loss: float
    val_loss: float
    fwd_bf16_pct: float
    bwd_bf16_pct: float
    fwd_rel_err: float
    seconds: float
    losses: List[float]
    history: List[Dict[str, float]]


def run_quality(
    policy: MoRDotPolicy,
    name: str,
    steps: int = 150,
    seed: int = 0,
    collect_stats_every: int = 1,
) -> QualityResult:
    cfg = bench_model_cfg()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = init_opt_state(params)
    step_fn = jax.jit(
        make_train_step(
            cfg, policy,
            TrainConfig(optimizer=AdamWConfig(
                peak_lr=3e-3, final_lr=3e-4, warmup_steps=20,
                total_steps=steps,
            )),
        )
    )
    data = SyntheticLM(
        DataConfig(vocab=VOCAB, seq_len=SEQ, global_batch=BATCH, seed=7)
    )
    val_batch = jax.tree.map(jnp.asarray, data.batch_at(10_000))
    loss_fn = jax.jit(make_loss_fn(cfg, policy, remat=False))
    tokens = make_tokens(cfg)

    t0 = time.time()
    losses, history = [], []
    for s in range(steps):
        batch = jax.tree.map(jnp.asarray, data.batch_at(s))
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        if s % collect_stats_every == 0:
            history.append(
                {
                    "step": s,
                    "loss": losses[-1],
                    "fwd_bf16": float(m.get("fwd_frac_bf16", 0.0)),
                    "bwd_bf16": float(m.get("bwd_frac_bf16", 0.0)),
                    "fwd_rel_err": float(m.get("fwd_rel_err", 0.0)),
                }
            )
    val_loss, _ = loss_fn(params, tokens, val_batch)
    dt = time.time() - t0
    fwd = float(np.mean([h["fwd_bf16"] for h in history[5:]])) * 100
    bwd = float(np.mean([h["bwd_bf16"] for h in history[5:]])) * 100
    err = float(np.mean([h["fwd_rel_err"] for h in history[5:]]))
    return QualityResult(
        name=name,
        train_loss=float(np.mean(losses[-10:])),
        val_loss=float(val_loss),
        fwd_bf16_pct=fwd,
        bwd_bf16_pct=bwd,
        fwd_rel_err=err,
        seconds=dt,
        losses=losses,
        history=history,
    )


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
