"""Paper Table 3 analogue: MoR setting ablations on the per-block strategy:
block 64x64 vs 128x128, threshold 5.0% vs 4.5%, and scaling-algorithm
comparison (GAM vs per-block FP32-amax vs per-block E8M0). Claim under
test: mantissa-consistent scaling (GAM/E8M0) tracks BF16; all variants
stay within ~1% train loss."""
from __future__ import annotations

from repro.core import paper_default

from .common import csv_row, run_quality


def main(steps: int = 150):
    configs = [
        ("block128", paper_default(partition="block")),
        ("block64", paper_default(partition="block", block_shape=(64, 64))),
        ("th5.0", paper_default(partition="block", threshold=0.05)),
        ("fp32_amax", paper_default(partition="block", algo="fp32_amax")),
        ("e8m0", paper_default(partition="block", algo="e8m0")),
    ]
    results = [run_quality(p, n, steps=steps) for n, p in configs]
    rows = [
        csv_row(
            f"table3/{r.name}",
            r.seconds * 1e6 / max(steps, 1),
            f"train={r.train_loss:.4f};val={r.val_loss:.4f};"
            f"fwd_bf16={r.fwd_bf16_pct:.1f}%;rel_err={r.fwd_rel_err:.4f}",
        )
        for r in results
    ]
    return rows, results


if __name__ == "__main__":
    for row in main()[0]:
        print(row)
