"""Frozen schema for the ``bench_kernels --json`` artifact.

PR 1 and PR 2 wrote a bare list of rows whose key names drifted between
lanes; this module freezes the contract (documented in
``benchmarks/README.md``) and validates artifacts against it -- CI's
slow lane runs ``python -m benchmarks.schema bench_kernels.json`` after
the bench smoke, so a drifting producer fails the build instead of
silently breaking downstream consumers.

Schema ``repro.bench_kernels/v6`` (current; the validator also accepts
``v1``..``v5`` artifacts so stored history keeps validating)::

    {
      "schema": "repro.bench_kernels/v6",
      "rows": [
        {"name": "kernel/<lane>_<variant>[_<size>]",   # row id
         "us":   12.3,                                  # mean wall us/call
         "derived": "key=value;key2=value2"}            # lane metrics
      ]
    }

v2 extends v1 only by contract, not by shape: producers must emit at
least one ``kernel/gemm_nvfp4_*`` row when the bench runs the sub4
(NVFP4) recipe lane (``--recipe sub4`` or the default full matrix),
and the version string bumps. v3 is additive the same way: when the
serving lane runs, producers must emit the ``kernel/serve_kv_cache_*``
rows (per-mode KV-cache bytes-per-token counters: bf16 / kv_fp8 /
kv_mor) and a ``kernel/flash_qoffset_*`` row (the query-offset flash
parity lane). v4 (additive again): when the default lane matrix runs,
producers must emit the compressed training-state rows --
``kernel/grad_compress_<mode>_*`` (per-mode gradient-compression
events) and ``kernel/optim_moments_<tier>_*`` rows whose ``derived``
carries the ``moment_bytes_per_param_milli`` HBM budget counter
(physical bytes/param of the packed Adam moment, in milli-bytes;
compare.py gates it at threshold 0). v5 (additive): the smoke emits a
``kernel/analysis_contracts`` row whose ``derived`` carries
``contracts_checked`` / ``contract_rules_evaluated`` /
``contract_violations`` from the structural contract registry
(``repro.analysis.contracts``, docs/analysis.md). ``compare.py`` gates
all three: violations may not grow past 0, and -- via its
``MIN_COUNTER_KEYS`` direction -- the checked/evaluated counts may not
*shrink*, so silently dropping a registered contract fails the gate
the same way dropping a bench row does. v6 (additive): the smoke also
emits a ``kernel/robust_guard`` row (docs/robustness.md) whose
``derived`` carries ``guard_clean_pack_ops`` /
``guard_contract_violations`` (both gated at 0 growth: the stats
guard lanes must stay structurally free on the clean path) and
``fault_classes_registered`` / ``fault_classes_covered`` (MIN-gated:
the chaos registry may not shrink). Row grammar is unchanged
across all versions:

* ``name`` matches ``^kernel/[A-Za-z0-9._-]+$`` and is unique per
  artifact.
* ``us`` is a non-negative finite number (0.0 for lanes that only
  record counts, e.g. TPU cross-lowering launch counts).
* ``derived`` is a ``;``-separated list of ``key=value`` items (value
  text is free-form; keys must be non-empty and ``=`` must be present
  in every non-empty item).

Stdlib-only on purpose: consumers should not need jax to validate.
"""
from __future__ import annotations

import json
import math
import re
import sys
from typing import Any, Dict, List

SCHEMA_V1 = "repro.bench_kernels/v1"
SCHEMA_V2 = "repro.bench_kernels/v2"
SCHEMA_V3 = "repro.bench_kernels/v3"
SCHEMA_V4 = "repro.bench_kernels/v4"
SCHEMA_V5 = "repro.bench_kernels/v5"
SCHEMA_V6 = "repro.bench_kernels/v6"
SCHEMA = SCHEMA_V6
ACCEPTED_SCHEMAS = (
    SCHEMA_V1, SCHEMA_V2, SCHEMA_V3, SCHEMA_V4, SCHEMA_V5, SCHEMA_V6
)
_NAME_RE = re.compile(r"^kernel/[A-Za-z0-9._-]+$")

__all__ = [
    "SCHEMA", "SCHEMA_V1", "SCHEMA_V2", "SCHEMA_V3", "SCHEMA_V4",
    "SCHEMA_V5", "SCHEMA_V6", "ACCEPTED_SCHEMAS",
    "make_artifact", "validate_artifact", "rows_from_csv",
]


def rows_from_csv(csv_rows: List[str]) -> List[Dict[str, Any]]:
    """Parse ``common.csv_row`` strings into schema row dicts."""
    recs = []
    for row in csv_rows:
        name, us, derived = row.split(",", 2)
        recs.append({"name": name, "us": float(us), "derived": derived})
    return recs


def make_artifact(csv_rows: List[str]) -> Dict[str, Any]:
    """The versioned artifact object for a list of csv_row strings."""
    return {"schema": SCHEMA, "rows": rows_from_csv(csv_rows)}


def validate_artifact(doc: Any) -> None:
    """Raise ValueError unless ``doc`` conforms to an accepted schema
    version (v1..v6 -- the row grammar is shared)."""
    if not isinstance(doc, dict):
        raise ValueError(f"artifact must be an object, got {type(doc)}")
    extra = set(doc) - {"schema", "rows"}
    if extra:
        raise ValueError(f"unknown top-level keys: {sorted(extra)}")
    if doc.get("schema") not in ACCEPTED_SCHEMAS:
        raise ValueError(
            f"schema mismatch: {doc.get('schema')!r} not in "
            f"{ACCEPTED_SCHEMAS!r}"
        )
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        raise ValueError("rows must be a non-empty list")
    seen = set()
    for i, row in enumerate(rows):
        ctx = f"rows[{i}]"
        if not isinstance(row, dict):
            raise ValueError(f"{ctx}: must be an object")
        if set(row) != {"name", "us", "derived"}:
            raise ValueError(
                f"{ctx}: keys must be exactly name/us/derived, "
                f"got {sorted(row)}"
            )
        name = row["name"]
        if not isinstance(name, str) or not _NAME_RE.match(name):
            raise ValueError(f"{ctx}: bad name {name!r}")
        if name in seen:
            raise ValueError(f"{ctx}: duplicate name {name!r}")
        seen.add(name)
        us = row["us"]
        if (
            not isinstance(us, (int, float)) or isinstance(us, bool)
            or not math.isfinite(us) or us < 0
        ):
            raise ValueError(f"{ctx}: bad us {us!r}")
        derived = row["derived"]
        if not isinstance(derived, str):
            raise ValueError(f"{ctx}: derived must be a string")
        for item in derived.split(";"):
            if not item:
                continue
            key, eq, _ = item.partition("=")
            if not eq or not key.strip():
                raise ValueError(
                    f"{ctx}: derived item {item!r} is not key=value"
                )


def main(argv: List[str]) -> int:
    if len(argv) != 1:
        print("usage: python -m benchmarks.schema ARTIFACT.json",
              file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        doc = json.load(f)
    try:
        validate_artifact(doc)
    except ValueError as e:
        print(f"SCHEMA INVALID: {e}", file=sys.stderr)
        return 1
    print(
        f"schema OK: {argv[0]} ({len(doc['rows'])} rows, "
        f"{doc['schema']})"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
