"""Serving-engine bench lane (docs/serving.md): a heavy-traffic
continuous-batching trace plus the skinny-M decode-tile contract.

Two rows, emitted under the same ``repro.bench_kernels`` schema as the
kernel lanes (and folded into ``bench_kernels --smoke`` so they ride in
every CI artifact):

* ``kernel/serve_trace_heavy`` — run a deterministic synthetic trace
  (mixed prompt lengths, admissions streaming in throughout, per-request
  token budgets) through the paged engine; ``us`` is wall time **per
  generated token**, with total steps / prefill chunks / tokens and
  tokens-per-second in the derived fields. ``steps`` is deterministic
  for the fixed trace, so it gates at threshold 0 in
  ``benchmarks.compare`` — a scheduler change that adds ticks fails the
  gate even though the wall clock is interpreter-dominated (the name's
  ``serve_trace`` fragment is time-exempt).
* ``kernel/serve_decode_tile`` — assert the decode lane registered
  skinny-M grids: with quantized weights and ``slots <= 16`` the
  activation row block must be 16 (the bf16 TPU sublane minimum), i.e.
  decode GEMMs do NOT pad the slots axis to 128. The row carries
  ``decode_row_block`` as a gated counter.

Standalone: ``PYTHONPATH=src python -m benchmarks.bench_serve --json out.json``
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import TENSOR_MOR, MoRPolicy
from repro.kernels import ops as kops
from repro.models import init_params
from repro.serve import Engine, Request, ServeConfig

from .common import csv_row
from .schema import make_artifact

# Fixed trace: (prompt_len, max_tokens, submit_at_step). Deliberately
# staggered lengths; later requests arrive only once the engine is
# already decoding earlier ones.
TRACE = (
    (5, 6, 0), (19, 4, 0), (11, 6, 0), (27, 3, 0),
    (8, 5, 2), (33, 4, 4), (14, 6, 6), (22, 4, 8),
)
SMOKE_TRACE = TRACE[:5]


def _serve_cfg():
    cfg = dataclasses.replace(reduced(get_config("gemma-2b")), vocab=128)
    return cfg


def bench_serve(rows, smoke: bool = False):
    trace = SMOKE_TRACE if smoke else TRACE
    cfg = _serve_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(slots=4, max_seq=64, page_size=16,
                       prefill_chunk=16)
    eng = Engine(cfg, TENSOR_MOR, params, scfg,
                 quantize=MoRPolicy(recipe="sub3"),
                 quantize_min_size=1024)

    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, P).astype(np.int32),
                    max_tokens=mt)
            for i, (P, mt, _) in enumerate(trace)]

    # Warm both jit traces (decode + chunk) off the clock, then reset.
    warm = Request(10_000, np.arange(20, dtype=np.int32), max_tokens=2)
    eng.submit(warm)
    eng.run_to_completion()
    assert warm.done
    eng.steps = eng.decode_steps = eng.prefill_chunks = 0

    t0 = time.time()
    step = 0
    pending = sorted(range(len(reqs)), key=lambda i: trace[i][2])
    for i in pending:
        if trace[i][2] == 0:
            eng.submit(reqs[i])
    live = True
    while live and step < 500:
        for i in pending:
            if trace[i][2] == step and trace[i][2] > 0:
                eng.submit(reqs[i])
        live = eng.step()
        step += 1
    wall = time.time() - t0

    assert all(r.done and len(r.out) == trace[i][1]
               for i, r in enumerate(reqs)), "trace did not complete"
    tokens = sum(len(r.out) for r in reqs)
    rows.append(csv_row(
        "kernel/serve_trace_heavy", wall / tokens * 1e6,
        f"steps={eng.steps};decode_steps={eng.decode_steps};"
        f"prefill_chunks={eng.prefill_chunks};tokens={tokens};"
        f"requests={len(reqs)};tok_per_s={tokens / wall:.1f}",
    ))

    # Skinny-M contract: slots=4 -> 16-row activation blocks, and the
    # decode-shaped grids actually landed in the autotune table.
    rb = eng.decode_row_block
    assert rb == kops.decode_row_block(scfg.slots) == 16 < 128, (
        f"decode row block {rb}: decode lane is padding the slots axis"
    )
    decode_grids = [g for g in kops._GEMM_TILE_TABLE
                    if g[0] == -(-scfg.slots // rb)]
    assert decode_grids, "no decode-shaped GemmTile registrations"
    rows.append(csv_row(
        "kernel/serve_decode_tile", 0.0,
        f"decode_row_block={rb};registered_grids={len(decode_grids)};"
        f"slots={scfg.slots}",
    ))


def main(argv):
    smoke = "--smoke" in argv
    json_path = None
    if "--json" in argv:
        json_path = argv[argv.index("--json") + 1]
    rows = []
    bench_serve(rows, smoke=smoke)
    for r in rows:
        print(r)
    if json_path:
        with open(json_path, "w", encoding="utf-8") as f:
            json.dump(make_artifact(rows), f, indent=1)
        print(f"wrote {json_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
