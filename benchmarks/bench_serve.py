"""Serving-engine bench lane (docs/serving.md): a heavy-traffic
continuous-batching trace plus the skinny-M decode-tile contract.

Two rows, emitted under the same ``repro.bench_kernels`` schema as the
kernel lanes (and folded into ``bench_kernels --smoke`` so they ride in
every CI artifact):

* ``kernel/serve_trace_heavy`` — run a deterministic synthetic trace
  (mixed prompt lengths, admissions streaming in throughout, per-request
  token budgets) through the paged engine; ``us`` is wall time **per
  generated token**, with total steps / prefill chunks / tokens and
  tokens-per-second in the derived fields. ``steps`` is deterministic
  for the fixed trace, so it gates at threshold 0 in
  ``benchmarks.compare`` — a scheduler change that adds ticks fails the
  gate even though the wall clock is interpreter-dominated (the name's
  ``serve_trace`` fragment is time-exempt).
* ``kernel/serve_decode_tile`` — assert the decode lane registered
  skinny-M grids: with quantized weights and ``slots <= 16`` the
  activation row block must be 16 (the bf16 TPU sublane minimum), i.e.
  decode GEMMs do NOT pad the slots axis to 128. The row carries
  ``decode_row_block`` as a gated counter.
* ``kernel/serve_kv_cache_{bf16,fp8,mor}`` — KV-cache bytes per token
  for each cache mode (docs/serving.md MoR KV tier). Two counters, both
  deterministic and gated at threshold 0: ``kv_bytes_per_token`` is the
  *physical* pool bytes one gather+scatter round trip moves per
  position (a property of the lane dtypes), and for the MoR row
  ``kv_bpe_milli_hot``/``kv_bpe_milli_cold`` are the *logical* payload
  bytes-per-element of the hot (fp8 tag mixture, 1000 = 1.0 B) and cold
  (sub4-recompressed, 562 = 0.5625 B) tiers. The lane asserts the
  acceptance gates inline: hot bpe <= 1.05, cold bpe <= 0.65, and
  MoR physical bytes strictly below bf16's.
* ``kernel/flash_qoffset_interp`` — the PR-7 query-offset flash lane: a
  short query chunk against a longer cache through the Pallas kernel
  (interpret lowering, so the wall clock is time-exempt), parity-checked
  against the dense oracle inline.

Standalone: ``PYTHONPATH=src python -m benchmarks.bench_serve --json out.json``
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time

import jax
import numpy as np

from repro.analysis import contracts
from repro.configs import get_config, reduced
from repro.core import TENSOR_MOR, MoRPolicy
from repro.kernels import ops as kops
from repro.models import init_params
from repro.serve import Engine, Request, ServeConfig

from .common import csv_row
from .schema import make_artifact

# Fixed trace: (prompt_len, max_tokens, submit_at_step). Deliberately
# staggered lengths; later requests arrive only once the engine is
# already decoding earlier ones.
TRACE = (
    (5, 6, 0), (19, 4, 0), (11, 6, 0), (27, 3, 0),
    (8, 5, 2), (33, 4, 4), (14, 6, 6), (22, 4, 8),
)
SMOKE_TRACE = TRACE[:5]


def _serve_cfg():
    cfg = dataclasses.replace(reduced(get_config("gemma-2b")), vocab=128)
    return cfg


def bench_serve(rows, smoke: bool = False):
    trace = SMOKE_TRACE if smoke else TRACE
    cfg = _serve_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(slots=4, max_seq=64, page_size=16,
                       prefill_chunk=16)
    eng = Engine(cfg, TENSOR_MOR, params, scfg,
                 quantize=MoRPolicy(recipe="sub3"),
                 quantize_min_size=1024)

    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, P).astype(np.int32),
                    max_tokens=mt)
            for i, (P, mt, _) in enumerate(trace)]

    # Warm both jit traces (decode + chunk) off the clock, then reset.
    warm = Request(10_000, np.arange(20, dtype=np.int32), max_tokens=2)
    eng.submit(warm)
    eng.run_to_completion()
    assert warm.done
    eng.steps = eng.decode_steps = eng.prefill_chunks = 0

    t0 = time.time()
    step = 0
    pending = sorted(range(len(reqs)), key=lambda i: trace[i][2])
    for i in pending:
        if trace[i][2] == 0:
            eng.submit(reqs[i])
    live = True
    while live and step < 500:
        for i in pending:
            if trace[i][2] == step and trace[i][2] > 0:
                eng.submit(reqs[i])
        live = eng.step()
        step += 1
    wall = time.time() - t0

    assert all(r.done and len(r.out) == trace[i][1]
               for i, r in enumerate(reqs)), "trace did not complete"
    tokens = sum(len(r.out) for r in reqs)
    rows.append(csv_row(
        "kernel/serve_trace_heavy", wall / tokens * 1e6,
        f"steps={eng.steps};decode_steps={eng.decode_steps};"
        f"prefill_chunks={eng.prefill_chunks};tokens={tokens};"
        f"requests={len(reqs)};tok_per_s={tokens / wall:.1f}",
    ))

    # Skinny-M contract: slots=4 -> 16-row activation blocks (the
    # DECODE_ROW_BLOCK pin in repro.analysis.contracts), and the
    # decode-shaped grids actually landed in the autotune table.
    rb = eng.decode_row_block
    assert rb == kops.decode_row_block(scfg.slots) \
        == contracts.DECODE_ROW_BLOCK < 128, (
        f"decode row block {rb}: decode lane is padding the slots axis"
    )
    decode_grids = [g for g in kops._GEMM_TILE_TABLE
                    if g[0] == -(-scfg.slots // rb)]
    assert decode_grids, "no decode-shaped GemmTile registrations"
    rows.append(csv_row(
        "kernel/serve_decode_tile", 0.0,
        f"decode_row_block={rb};registered_grids={len(decode_grids)};"
        f"slots={scfg.slots}",
    ))

    bench_kv_cache(rows)
    bench_flash_qoffset(rows)


def bench_kv_cache(rows):
    """Per-mode KV-cache bytes accounting + the PR-7 acceptance gates."""
    from repro.models.attention import (
        kv_bytes_per_element,
        quantize_kv_mor,
        recompress_kv_nvfp4,
    )
    from repro.serve import PagedKVPool

    cfg = _serve_cfg()
    pool_kw = dict(slots=4, max_seq=64, page_size=16)
    bpt = {
        "bf16": PagedKVPool(cfg, **pool_kw).bytes_per_token(),
        "fp8": PagedKVPool(cfg, kv_fp8=True, **pool_kw).bytes_per_token(),
        "mor": PagedKVPool(cfg, kv_mor=True, **pool_kw).bytes_per_token(),
    }
    # The point of the packed lanes: gather/scatter moves fewer bytes
    # per position than the bf16 cache (payload u8 + tag + scale vs
    # 2 B/elt values).
    assert bpt["mor"] < bpt["bf16"], bpt

    rng = np.random.default_rng(0)
    x = np.asarray(rng.standard_normal((2, 32, cfg.n_kv, cfg.head_dim)),
                   np.float32)
    hot = quantize_kv_mor(x)
    hot_bpe = float(kv_bytes_per_element(hot[1]))
    cold_bpe = float(kv_bytes_per_element(recompress_kv_nvfp4(*hot)[1]))
    assert hot_bpe <= 1.05, hot_bpe    # hot tier: fp8 arms only
    assert cold_bpe <= 0.65, cold_bpe  # cold tier: sub4 nibbles+micros

    for mode in ("bf16", "fp8", "mor"):
        derived = f"kv_bytes_per_token={bpt[mode]}"
        if mode == "mor":
            derived += (
                f";kv_bpe_milli_hot={int(hot_bpe * 1000)}"
                f";kv_bpe_milli_cold={int(cold_bpe * 1000)}"
                f";bytes_vs_bf16={bpt['bf16'] / bpt['mor']:.2f}x"
            )
        rows.append(csv_row(f"kernel/serve_kv_cache_{mode}", 0.0, derived))


def bench_flash_qoffset(rows):
    """Query-offset flash lane: an S < T chunk against a longer cache
    (the chunked-prefill shape) through the Pallas kernel, parity-
    checked against a dense oracle. Interpret lowering: the row name's
    ``_interp`` fragment makes the wall clock advisory in compare."""
    from repro.kernels.flash_attention import flash_attention_fwd

    BH, S, T, d = 8, 16, 128, 64
    rng = np.random.default_rng(1)
    q, k, v = (np.asarray(rng.standard_normal(s), np.float32)
               for s in ((BH, S, d), (BH, T, d), (BH, T, d)))
    f = lambda: flash_attention_fwd(
        jax.numpy.asarray(q), jax.numpy.asarray(k), jax.numpy.asarray(v),
        causal=True, block_q=16, block_k=64, interpret=True,
    )
    out = np.asarray(f(), np.float32)  # warm the trace
    t0 = time.time()
    reps = 3
    for _ in range(reps):
        f().block_until_ready()
    us = (time.time() - t0) / reps * 1e6

    s = np.einsum("bsd,btd->bst", q, k) * d**-0.5
    q_pos = (T - S) + np.arange(S)  # default offset: last q at last k
    s = np.where(np.arange(T)[None, None, :] <= q_pos[None, :, None],
                 s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    ref = np.einsum("bst,btd->bsd", p, v)
    err = float(np.max(np.abs(out - ref)))
    assert err < 1e-4, f"flash q_offset diverged from oracle: {err}"
    rows.append(csv_row(
        "kernel/flash_qoffset_interp", us,
        f"BH={BH};S={S};T={T};d={d};max_err={err:.1e}",
    ))


def main(argv):
    smoke = "--smoke" in argv
    json_path = None
    if "--json" in argv:
        json_path = argv[argv.index("--json") + 1]
    rows = []
    bench_serve(rows, smoke=smoke)
    for r in rows:
        print(r)
    if json_path:
        with open(json_path, "w", encoding="utf-8") as f:
            json.dump(make_artifact(rows), f, indent=1)
        print(f"wrote {json_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
