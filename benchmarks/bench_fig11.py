"""Paper Fig. 11 analogue: per-tensor relative-error histograms (0.5%-wide
bins, ASCII heat rows) collected from a short training run with per-layer
per-event stats streamed out of the train step."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MoRStatsTracker, paper_default
from repro.data import DataConfig, SyntheticLM
from repro.models import init_params, make_loss_fn, make_tokens
from repro.optim import AdamWConfig, adamw_update, init_opt_state

from .common import BATCH, SEQ, VOCAB, bench_model_cfg, csv_row


def main(steps: int = 60):
    cfg = bench_model_cfg()
    policy = paper_default(partition="block")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    loss_fn = make_loss_fn(cfg, policy)
    grad_fn = jax.jit(
        jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)
    )
    ocfg = AdamWConfig(peak_lr=3e-3, final_lr=3e-4, warmup_steps=10,
                       total_steps=steps)
    data = SyntheticLM(
        DataConfig(vocab=VOCAB, seq_len=SEQ, global_batch=BATCH, seed=7)
    )
    tracker = MoRStatsTracker(reset_every=0)
    tokens = make_tokens(cfg)

    t0 = time.time()
    for s in range(steps):
        batch = jax.tree.map(jnp.asarray, data.batch_at(s))
        (_, aux), (g_params, g_tokens) = grad_fn(params, tokens, batch)
        params, opt, _ = adamw_update(ocfg, g_params, opt)
        named = {}
        for t, dots in aux["mor_fwd"]["blocks"].items():
            for dot, st in dots.items():
                if not hasattr(st, "ndim"):
                    continue
                arr = np.asarray(st)
                for layer in range(arr.shape[0]):
                    rows = arr[layer].reshape(-1, arr.shape[-1])
                    for ev, rowname in enumerate(("act", "weight")):
                        if ev < rows.shape[0]:
                            named[
                                f"layer.{layer}.{dot}.{rowname}"
                            ] = rows[ev]
        for t, dots in g_tokens["blocks"].items():
            for dot, st in dots.items():
                arr = np.asarray(st)
                for layer in range(arr.shape[0]):
                    named[f"layer.{layer}.{dot}.grad"] = arr[layer].reshape(
                        -1, arr.shape[-1]
                    )[0]
        tracker.update(named, s)
    dt = time.time() - t0

    heat = tracker.render_heatmap(limit=40)
    print(heat)
    row = csv_row(
        "fig11/heatmap",
        dt * 1e6 / max(steps, 1),
        f"tensors={len(tracker.hists)};fallback="
        f"{tracker.bf16_fallback_pct:.2f}%",
    )
    return [row], heat


if __name__ == "__main__":
    for row in main()[0]:
        print(row)
