"""Roofline report: reads the dry-run JSONs (experiments/dryrun/*.json)
and prints the per-cell three-term table (per-device bytes and flops
per step; see repro.launch.dryrun's traffic model)."""
from __future__ import annotations

import glob
import json
import os

from .common import csv_row

DRYRUN_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments", "dryrun",
)


def load_cells(directory: str = DRYRUN_DIR):
    cells = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def main():
    rows = []
    cells = load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    if not cells:
        rows.append(csv_row("roofline/none", 0.0,
                            "run repro.launch.dryrun --all first"))
        return rows, None
    header = (
        f"{'arch':24s} {'shape':12s} {'mesh':8s} {'compute':>9s} "
        f"{'memory':>9s} {'collective':>11s} {'dominant':>10s} "
        f"{'useful':>7s} {'fits':>5s}"
    )
    print(header)
    print("-" * len(header))
    for c in ok:
        r = c["roofline"]
        print(
            f"{c['arch']:24s} {c['shape']:12s} {c['mesh']:8s} "
            f"{r['compute_s']:9.3f} {r['memory_s']:9.3f} "
            f"{r['collective_s']:11.3f} {r['dominant']:>10s} "
            f"{r['useful_flops_ratio']:7.2f} "
            f"{str(c['memory']['fits_16gb']):>5s}"
        )
        rows.append(
            csv_row(
                f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
                max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
                f"dominant={r['dominant']};useful="
                f"{r['useful_flops_ratio']:.2f};"
                f"fits={c['memory']['fits_16gb']}",
            )
        )
    skips = [c for c in cells if c.get("status") == "skip"]
    for c in skips:
        rows.append(
            csv_row(
                f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}", 0.0,
                "skip:" + c.get("reason", "")[:60],
            )
        )
    return rows, None


if __name__ == "__main__":
    main()
