"""Benchmark orchestrator: one section per paper table/figure + kernel
microbench + roofline. Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150,
                    help="training steps per quality config")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. table2,kernels")
    args = ap.parse_args()

    from . import (
        bench_fig10,
        bench_fig11,
        bench_kernels,
        bench_table2,
        bench_table3,
        bench_table4,
        roofline,
    )

    sections = {
        "table2": lambda: bench_table2.main(steps=args.steps),
        "table3": lambda: bench_table3.main(steps=args.steps),
        "table4": lambda: bench_table4.main(steps=args.steps),
        "fig10": lambda: bench_fig10.main(steps=max(args.steps // 2, 30)),
        "fig11": lambda: bench_fig11.main(steps=max(args.steps // 3, 20)),
        "kernels": bench_kernels.main,
        "roofline": roofline.main,
    }
    chosen = (
        {k: sections[k] for k in args.only.split(",")}
        if args.only
        else sections
    )

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in chosen.items():
        try:
            rows, _ = fn()
            for row in rows:
                print(row)
            sys.stdout.flush()
        except Exception:
            failures += 1
            print(f"{name},0.0,ERROR")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
