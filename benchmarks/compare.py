"""Compare two ``repro.bench_kernels`` JSON artifacts for regressions.

Stdlib-only (like ``benchmarks/schema.py``): CI and developers can diff
a fresh smoke artifact against the checked-in ``BENCH_baseline.json``
without jax installed::

    python -m benchmarks.compare benchmarks/BENCH_baseline.json \\
        bench_kernels.json [--time-threshold 1.5] [--min-us 50]

Per row (matched by name across the two artifacts) two classes of
regression are flagged:

* **time** -- ``current.us > baseline.us * time_threshold`` *and* the
  absolute delta exceeds ``--min-us`` (wall clocks are noisy across
  hosts; the defaults -- 2.0x / 200us -- are tuned so an identical
  same-host rerun compares clean). Rows whose name marks them as
  interpreter-mode or multi-device-subprocess lanes (``_interp``,
  ``_sharded``) are *exempt* from the time check by default: their
  wall clocks routinely swing >2x run to run, and a gate that is red
  on every run buries the count regressions that are its real signal.
  ``--time-all`` re-includes them.
* **counts** -- any *structural* counter in the ``derived`` field that
  grew: operand pass counts and fused-launch counts are deterministic
  properties of the lowering, so *any* increase is a real regression
  (threshold 0). Counter keys: {counter_keys}. A second key set gates
  the opposite direction -- coverage counters that may only grow
  ({min_counter_keys}): a *decrease* means a registered structural
  contract or rule silently vanished, which is flagged exactly like a
  dropped row.

Rows present only in the baseline are flagged as **missing** (a lane
silently disappearing is how perf coverage rots); rows only in the
current artifact are reported as new, never flagged.

Exit status: 0 = clean (new rows / improvements allowed), 1 = at least
one regression or missing row, 2 = usage/validation error. The CI slow
lane runs this non-blocking (the job is advisory) but the exit code
still lands in the log next to the uploaded artifacts.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Tuple

from .schema import validate_artifact

# Derived-field keys whose values are deterministic lowering properties
# (growth = regression at threshold 0). Wall-us keys are deliberately
# absent: they go through the time threshold instead.
COUNTER_KEYS = (
    "operand_passes",
    "tpu_kernel_launches",
    "tpu_pack_ops",
    "per_shard_tpu_kernel_launches",
    "replicated_tpu_kernel_launches",
    # Serving lane: scheduler ticks for the fixed trace and the decode
    # GEMM's activation row block -- both deterministic, so any growth
    # (extra engine steps, slots axis padded toward 128) is structural.
    "steps",
    "decode_row_block",
    # KV-cache tier: physical pool bytes gathered+scattered per
    # position (a property of the lane dtypes) and the logical payload
    # bytes-per-element implied by the tag mixture, in milli-bytes
    # (hot fp8 arms = 1000, cold sub4 = 562). All deterministic.
    "kv_bytes_per_token",
    "kv_bpe_milli_hot",
    "kv_bpe_milli_cold",
    # Compressed training state: physical HBM bytes/param of the
    # packed Adam moment in milli-bytes (fully-fp8 leaf = 1000, the
    # NVFP4-friendly sub4 leaf = ~563). A deterministic property of
    # the pack layout for the lane's fixed-seed data, so any growth
    # means the moment store re-inflated.
    "moment_bytes_per_param_milli",
    # Static-analysis lane (kernel/analysis_contracts): the registry
    # sweep must stay violation-free, so any growth past 0 is red.
    "contract_violations",
    # Guard-rail lane (kernel/robust_guard, docs/robustness.md): the
    # v4 guard lanes must stay structurally free on the clean path --
    # any operand-sized pack op or contract violation is red.
    "guard_clean_pack_ops",
    "guard_contract_violations",
)

# Coverage counters with the opposite gate direction: a DECREASE is the
# regression (a structural contract or one of its rules was dropped
# from the registry without anyone noticing), growth is just a note.
MIN_COUNTER_KEYS = (
    "contracts_checked",
    "contract_rules_evaluated",
    # Chaos registry (docs/robustness.md): fault classes and their
    # chaos-test coverage may grow but never silently shrink.
    "fault_classes_registered",
    "fault_classes_covered",
)

# Name fragments of lanes whose wall clock is interpreter- or
# subprocess-dominated: counts still compare, times are advisory-only
# unless --time-all.
TIME_EXEMPT_FRAGMENTS = ("_interp", "_sharded", "serve_trace")

__doc__ = __doc__.format(
    counter_keys=", ".join(COUNTER_KEYS),
    min_counter_keys=", ".join(MIN_COUNTER_KEYS),
)

__all__ = [
    "COUNTER_KEYS", "MIN_COUNTER_KEYS", "parse_derived",
    "compare_artifacts", "main",
]


def parse_derived(derived: str) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for item in derived.split(";"):
        if not item:
            continue
        key, _, val = item.partition("=")
        out[key.strip()] = val
    return out


def _load(path: str) -> Dict[str, Any]:
    with open(path) as f:
        doc = json.load(f)
    validate_artifact(doc)
    return doc


def _int_counters(derived: str) -> Dict[str, int]:
    out = {}
    for key, val in parse_derived(derived).items():
        if key not in COUNTER_KEYS and key not in MIN_COUNTER_KEYS:
            continue
        try:
            out[key] = int(float(val))
        except ValueError:
            continue  # free-form text in a counter slot: not comparable
    return out


def compare_artifacts(
    base: Dict[str, Any],
    cur: Dict[str, Any],
    time_threshold: float = 2.0,
    min_us: float = 200.0,
    time_all: bool = False,
) -> Tuple[List[str], List[str]]:
    """Returns (regressions, notes) as printable strings."""
    base_rows = {r["name"]: r for r in base["rows"]}
    cur_rows = {r["name"]: r for r in cur["rows"]}
    regressions: List[str] = []
    notes: List[str] = []

    for name, b in base_rows.items():
        c = cur_rows.get(name)
        if c is None:
            regressions.append(f"MISSING  {name}: row dropped from lane")
            continue
        time_eligible = time_all or not any(
            frag in name for frag in TIME_EXEMPT_FRAGMENTS
        )
        if (
            time_eligible
            and b["us"] > 0
            and c["us"] > b["us"] * time_threshold
            and c["us"] - b["us"] > min_us
        ):
            regressions.append(
                f"TIME     {name}: {c['us']:.1f}us vs baseline "
                f"{b['us']:.1f}us ({c['us'] / b['us']:.2f}x > "
                f"{time_threshold:.2f}x)"
            )
        bc, cc = _int_counters(b["derived"]), _int_counters(c["derived"])
        for key in sorted(set(bc) & set(cc)):
            if bc[key] < 0 or cc[key] < 0:
                continue  # -1 sentinel: lane unavailable on that host
            grew, shrank = cc[key] > bc[key], cc[key] < bc[key]
            if key in MIN_COUNTER_KEYS:
                if shrank:
                    regressions.append(
                        f"COVERAGE {name}: {key} {cc[key]} vs baseline "
                        f"{bc[key]} (structural coverage shrank)"
                    )
                elif grew:
                    notes.append(
                        f"grew     {name}: {key} {cc[key]} vs baseline "
                        f"{bc[key]}"
                    )
                continue
            if grew:
                regressions.append(
                    f"COUNT    {name}: {key} {cc[key]} vs baseline "
                    f"{bc[key]}"
                )
            elif shrank:
                notes.append(
                    f"improved {name}: {key} {cc[key]} vs baseline "
                    f"{bc[key]}"
                )
    for name in sorted(set(cur_rows) - set(base_rows)):
        notes.append(f"new row  {name}")
    return regressions, notes


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.compare",
        description="Flag per-row time/count regressions between two "
                    "bench_kernels JSON artifacts.",
    )
    ap.add_argument("baseline", help="baseline artifact (e.g. "
                                     "benchmarks/BENCH_baseline.json)")
    ap.add_argument("current", help="freshly produced artifact")
    ap.add_argument("--time-threshold", type=float, default=2.0,
                    help="flag when current/baseline us exceeds this "
                         "ratio (default 2.0)")
    ap.add_argument("--min-us", type=float, default=200.0,
                    help="absolute wall-delta floor below which time "
                         "ratios are ignored (default 200us)")
    ap.add_argument("--time-all", action="store_true",
                    help="also apply the time check to interpreter/"
                         "sharded lanes (exempt by default)")
    args = ap.parse_args(argv)
    try:
        base = _load(args.baseline)
        cur = _load(args.current)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"compare: cannot load artifacts: {e}", file=sys.stderr)
        return 2
    regressions, notes = compare_artifacts(
        base, cur, args.time_threshold, args.min_us, args.time_all
    )
    for line in notes:
        print(line)
    for line in regressions:
        print(line)
    matched = len(
        {r["name"] for r in base["rows"]}
        & {r["name"] for r in cur["rows"]}
    )
    print(
        f"compared {matched} matched rows: "
        f"{len(regressions)} regression(s), {len(notes)} note(s)"
    )
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
