"""Paper Table 4 analogue: sub-tensor MoR recipes at 128x128 blocks --
Two-Way (E4M3/BF16) vs Three-Way (E4M3/E5M2/BF16) selection vs BF16.
Claim under test: two-way preserves quality; three-way reaches lower
train/val loss (the paper's overfitting signature shows as lower loss)."""
from __future__ import annotations

from repro.core import BF16_BASELINE, paper_default

from .common import csv_row, run_quality


def main(steps: int = 150):
    configs = [
        ("bf16", BF16_BASELINE),
        ("two_way", paper_default("sub2")),
        ("three_way", paper_default("sub3")),
    ]
    results = [run_quality(p, n, steps=steps) for n, p in configs]
    rows = [
        csv_row(
            f"table4/{r.name}",
            r.seconds * 1e6 / max(steps, 1),
            f"train={r.train_loss:.4f};val={r.val_loss:.4f};"
            f"e4m3_blocks={100 - r.fwd_bf16_pct:.1f}%",
        )
        for r in results
    ]
    return rows, results


if __name__ == "__main__":
    for row in main()[0]:
        print(row)
