"""Paper Table 2 analogue: tensor-level MoR across partition strategies
(BF16 baseline vs per-block 128x128 / per-tensor / per-channel), final
train + validation losses. Claim under test: all MoR variants land within
~0.5-1% of the BF16 baseline loss."""
from __future__ import annotations

from repro.core import BF16_BASELINE, paper_default

from .common import csv_row, run_quality


def main(steps: int = 150):
    configs = [
        ("bf16", BF16_BASELINE),
        ("mor_block", paper_default(partition="block")),
        ("mor_tensor", paper_default(partition="tensor")),
        ("mor_channel", paper_default(partition="channel")),
    ]
    results = [run_quality(p, n, steps=steps) for n, p in configs]
    base = results[0]
    rows = []
    for r in results:
        delta = (r.train_loss - base.train_loss) / base.train_loss * 100
        rows.append(
            csv_row(
                f"table2/{r.name}",
                r.seconds * 1e6 / max(steps, 1),
                f"train={r.train_loss:.4f};val={r.val_loss:.4f};"
                f"dtrain={delta:+.2f}%;fwd_bf16={r.fwd_bf16_pct:.1f}%",
            )
        )
    return rows, results


if __name__ == "__main__":
    for row in main()[0]:
        print(row)
