"""Paper Fig. 10 analogue: percentage of tensors falling back to BF16 per
partition strategy (fwd + bwd events), from live training decisions."""
from __future__ import annotations

from repro.core import paper_default

from .common import csv_row, run_quality


def main(steps: int = 120):
    rows = []
    results = []
    for name, part in (
        ("block", "block"), ("tensor", "tensor"), ("channel", "channel")
    ):
        r = run_quality(paper_default(partition=part), name, steps=steps)
        results.append(r)
        rows.append(
            csv_row(
                f"fig10/{name}",
                r.seconds * 1e6 / max(steps, 1),
                f"fwd_bf16={r.fwd_bf16_pct:.2f}%;bwd_bf16="
                f"{r.bwd_bf16_pct:.2f}%",
            )
        )
    return rows, results


if __name__ == "__main__":
    for row in main()[0]:
        print(row)
