"""Kernel microbenchmarks: wall time per call on this host (CPU: the jnp
reference / interpret paths; on a TPU host the same harness times the
Pallas kernels) + derived bandwidth.

``--smoke`` runs a reduced matrix (CI lane); ``--json PATH`` writes the
rows as a machine-readable artifact.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import E4M3, E5M2, PER_BLOCK_128, MoRPolicy, mor_quantize
from repro.core.formats import cast_to_format
from repro.core.gam import scales_from_bmax
from repro.core.metrics import E5M2_RANGE_RATIO
from repro.core.mor import quantize_for_gemm
from repro.core.partition import Partition, from_blocks, to_blocks
from repro.kernels import ref as kref
from repro.kernels.ops import gam_quant, mixed_gemm, mor_select
from repro.kernels.ref import passthrough_mixed
from repro.launch.hlo_analysis import analyze_hlo

from .common import csv_row


def _time(fn, *args, iters=10):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def _hlo_stats(fn, x, *args):
    """(HBM-traffic bytes, operand-sized instruction count) of jit(fn).

    The instruction count is the number of optimized (post-fusion) HLO
    instructions whose text mentions the operand's shape -- i.e. how many
    times XLA touches an operand-sized buffer: the 'pass count'.
    """
    txt = jax.jit(fn).lower(x, *args).compile().as_text()
    shape_tok = f"[{x.shape[0]},{x.shape[1]}]"
    passes = sum(
        1
        for ln in txt.splitlines()
        if shape_tok in ln and "= " in ln and "parameter(" not in ln
    )
    return analyze_hlo(txt).bytes, passes


def _tpu_kernel_launches(fn, x):
    """Count fused-kernel launches in the TPU lowering of jit(fn).

    Cross-lowered on CPU (no TPU needed): the Pallas path becomes a
    single tpu_custom_call -- the whole sub-tensor selection is one
    XLA-visible pass over the operand (plus the global-amax reduce).
    """
    txt = jax.jit(fn).trace(x).lower(lowering_platforms=("tpu",)).as_text()
    return txt.count("tpu_custom_call")


def _three_pass_sub3(x2d):
    """The pre-refactor sub3 lowering: three full passes over the operand
    (E4M3 quant+err, E5M2 quant+err, abs/min/max Eq. 4 range pass).
    Kept here verbatim as the fused-select benchmark baseline."""
    part = PER_BLOCK_128

    def quant_err(xb, fmt):
        bmax = jnp.max(jnp.abs(xb), axis=(2, 3)).astype(jnp.float32)
        scales = scales_from_bmax(bmax, fmt, "gam")
        s = scales.scale[:, :, None, None]
        xqb = (cast_to_format(xb.astype(jnp.float32) * s, fmt) / s).astype(
            xb.dtype
        )
        xf = xb.astype(jnp.float32)
        nz = xf != 0.0
        err = jnp.where(
            nz,
            jnp.abs((xf - xqb.astype(jnp.float32)) / jnp.where(nz, xf, 1.0)),
            0.0,
        )
        return xqb, jnp.sum(err, (2, 3)), jnp.sum(nz, (2, 3))

    xb = to_blocks(x2d, part)
    q4b, e4, n = quant_err(xb, E4M3)                    # pass 1
    q5b, e5, _ = quant_err(xb, E5M2)                    # pass 2
    m1 = e4 < e5
    xabs = jnp.abs(xb)                                  # pass 3
    bmax = jnp.max(xabs, axis=(2, 3)).astype(jnp.float32)
    big = jnp.asarray(jnp.finfo(xb.dtype).max, xb.dtype)
    bmin = jnp.min(jnp.where(xb != 0, xabs, big), axis=(2, 3)).astype(
        jnp.float32
    )
    anynz = n > 0
    ratio = jnp.where(anynz, bmax / jnp.where(anynz, bmin, 1.0), 1.0)
    use5 = jnp.logical_and(jnp.logical_not(m1), ratio < E5M2_RANGE_RATIO)
    y = from_blocks(
        jnp.where(m1[:, :, None, None], q4b,
                  jnp.where(use5[:, :, None, None], q5b, xb)),
        x2d.shape,
    )
    return y


def _legacy_dequant_matmul(x2d, mo):
    """The pre-mixed-GEMM serving lowering, frozen as the baseline: fully
    materialize the dequantized bf16 weight, then a dense bf16 matmul.
    The per-block representation decisions are erased before the dot."""
    w = mo.dequant()
    return jnp.dot(
        x2d, w.T.astype(x2d.dtype), preferred_element_type=jnp.float32
    ).astype(x2d.dtype)


def _bench_mixed_gemm(rows, rng, smoke: bool):
    """Mixed-representation GEMM vs legacy dequantize-then-matmul:
    wall time + HLO bytes + operand-pass counts (xla lowerings) and
    fused-kernel launch counts (TPU cross-lowering)."""
    sizes = ((512, 512, 512),) if smoke else (
        (512, 512, 512), (1024, 1024, 1024)
    )
    pol = MoRPolicy(recipe="sub3", partition="block", backend="xla")
    for M, N, K in sizes:
        x = jnp.asarray(rng.standard_normal((M, K)), jnp.bfloat16)
        w = jnp.asarray(rng.standard_normal((N, K)), jnp.bfloat16)
        mo, _ = quantize_for_gemm(w, pol)
        bk = mo.block[1]

        def legacy(a, m=mo):
            return _legacy_dequant_matmul(a, m)

        def fused_xla(a, m=mo, bk=bk):
            return mixed_gemm(
                passthrough_mixed(a, (bk, bk)), m, backend="xla"
            )

        def fused_pallas(a, m=mo, bk=bk):
            return mixed_gemm(
                passthrough_mixed(a, (bk, bk)), m, backend="pallas"
            )

        iters = 3 if smoke else 10
        us_l = _time(jax.jit(legacy), x, iters=iters)
        us_f = _time(jax.jit(fused_xla), x, iters=iters)
        by_l, ps_l = _hlo_stats(legacy, x)
        by_f, ps_f = _hlo_stats(fused_xla, x)
        try:
            launches = _tpu_kernel_launches(fused_pallas, x)
        except Exception:  # older jax without cross-platform lowering
            launches = -1
        tag = f"{M}x{N}x{K}"
        rows.append(
            csv_row(f"kernel/gemm_legacy_dequant_{tag}", us_l,
                    f"hbm_bytes={by_l:.0f};operand_passes={ps_l}")
        )
        rows.append(
            csv_row(f"kernel/gemm_mixed_xla_{tag}", us_f,
                    f"hbm_bytes={by_f:.0f};operand_passes={ps_f};"
                    f"bytes_vs_legacy={by_f / max(by_l, 1):.2f}x")
        )
        rows.append(
            csv_row(f"kernel/gemm_mixed_pallas_{tag}", 0.0,
                    f"tpu_kernel_launches={launches};"
                    f"legacy_operand_passes={ps_l}")
        )

    # Interpret-mode run of the real kernel body (small, CPU-feasible).
    x = jnp.asarray(rng.standard_normal((256, 256)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((256, 256)), jnp.bfloat16)
    mo, _ = quantize_for_gemm(w, pol)
    us = _time(
        lambda a: mixed_gemm(
            passthrough_mixed(a, (128, 128)), mo, backend="interpret"
        ),
        x, iters=3,
    )
    rows.append(
        csv_row("kernel/gemm_mixed_interp_256", us, "mode=interpret")
    )


def main(smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)

    # Mixed-representation block GEMM vs legacy dequant+matmul.
    _bench_mixed_gemm(rows, rng, smoke)

    # Fused mor_quantize (the XLA lowering used in train steps).
    quant_sizes = ((1024, 1024),) if smoke else ((1024, 1024), (4096, 1024))
    for mkn in quant_sizes:
        x = jnp.asarray(rng.standard_normal(mkn), jnp.bfloat16)
        pol = MoRPolicy(recipe="tensor", partition="block")
        f = jax.jit(lambda a: mor_quantize(a, pol)[0])
        us = _time(f, x)
        gbps = x.size * 2 * 2 / (us * 1e-6) / 1e9
        rows.append(
            csv_row(f"kernel/mor_quantize_{mkn[0]}x{mkn[1]}", us,
                    f"GB/s={gbps:.1f}")
        )

    # Fused sub-tensor select vs the pre-refactor 3-pass lowering.
    part = PER_BLOCK_128
    for mkn in quant_sizes:
        x = jnp.asarray(rng.standard_normal(mkn), jnp.bfloat16)

        def fused_xla(a):
            return mor_select(a, part, "sub3", "gam", backend="xla").y

        def fused_pallas(a):
            return mor_select(a, part, "sub3", "gam", backend="pallas").y

        us_l = _time(jax.jit(_three_pass_sub3), x)
        us_f = _time(jax.jit(fused_xla), x)
        by_l, ps_l = _hlo_stats(_three_pass_sub3, x)
        by_f, ps_f = _hlo_stats(fused_xla, x)
        try:
            launches = _tpu_kernel_launches(fused_pallas, x)
        except Exception:  # older jax without cross-platform lowering
            launches = -1
        tag = f"{mkn[0]}x{mkn[1]}"
        rows.append(
            csv_row(f"kernel/sub3_3pass_{tag}", us_l,
                    f"hbm_bytes={by_l:.0f};operand_passes={ps_l}")
        )
        rows.append(
            csv_row(f"kernel/sub3_fused_xla_{tag}", us_f,
                    f"hbm_bytes={by_f:.0f};operand_passes={ps_f};"
                    f"speedup={us_l / us_f:.2f}x")
        )
        rows.append(
            csv_row(f"kernel/sub3_fused_pallas_{tag}", 0.0,
                    f"tpu_kernel_launches={launches};"
                    "operand_passes=2(amax reduce + fused select);"
                    f"vs_3pass_passes={ps_l}")
        )

    # mor_select pallas kernel (interpret mode on CPU).
    x = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    us = _time(
        lambda a: mor_select(a, part, "sub3", "gam", backend="interpret").y,
        x, iters=3,
    )
    rows.append(csv_row("kernel/mor_select_interp_512", us,
                        "mode=interpret"))

    # gam_quant pallas kernel (interpret mode on CPU).
    x = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    us = _time(
        lambda a: gam_quant(a, backend="interpret")[0], x, iters=3
    )
    rows.append(csv_row("kernel/gam_quant_interp_512", us, "mode=interpret"))
    us = _time(lambda a: gam_quant(a, backend="xla")[0], x)
    rows.append(csv_row("kernel/gam_quant_xla_512", us, "mode=xla-ref"))

    # flash attention reference vs model chunked attention.
    from repro.models.attention import flash_attention as xla_flash

    q = jnp.asarray(rng.standard_normal((2, 512, 4, 64)), jnp.bfloat16)
    f = jax.jit(
        lambda a: xla_flash(a, a, a, kind="causal", q_chunk=128,
                            k_chunk=128)
    )
    us = _time(f, q)
    flops = 4 * 2 * 512 * 512 * 4 * 64  # 2 gemms, causal not discounted
    rows.append(
        csv_row("kernel/chunked_attention_b2s512", us,
                f"GFLOP/s={flops / (us * 1e-6) / 1e9:.1f}")
    )
    return rows, None


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced matrix for the CI bench lane")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    out_rows = main(smoke=args.smoke)[0]
    for row in out_rows:
        print(row)
    if args.json:
        recs = []
        for row in out_rows:
            name, us, derived = row.split(",", 2)
            recs.append({"name": name, "us": float(us), "derived": derived})
        with open(args.json, "w") as f:
            json.dump(recs, f, indent=2)
        print(f"wrote {len(recs)} rows to {args.json}")
