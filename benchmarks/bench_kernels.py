"""Kernel microbenchmarks: wall time per call on this host (CPU: the jnp
reference / interpret paths; on a TPU host the same harness times the
Pallas kernels) + derived bandwidth.

``--smoke`` runs a reduced matrix (CI lane); ``--json PATH`` writes the
rows as a machine-readable artifact conforming to the frozen
``repro.bench_kernels`` schema (``benchmarks/schema.py``, documented
in ``benchmarks/README.md``).

The sharded lane (``kernel/*_sharded_*`` rows) needs >= 4 devices;
on a single-device host it respawns itself in a subprocess with 4
forced CPU host devices (``launch.mesh.host_device_env``) and merges
the child's rows, so every artifact records the multi-device story.
``--no-sharded`` skips it; ``--sharded-child`` is the internal child
mode.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts
from repro.analysis.hlo_rules import (
    CrossLoweringUnavailable,
    count_custom_calls,
    operand_sized_ops,
    tpu_lowering_text,
)
from repro.core import E4M3, E5M2, PER_BLOCK_128, MoRPolicy, mor_quantize
from repro.core.formats import cast_to_format
from repro.core.gam import scales_from_bmax
from repro.core.metrics import E5M2_RANGE_RATIO
from repro.core.mor import (
    STAT_FRAC_NVFP4,
    STAT_PAYLOAD_BPE,
    quantize_for_gemm,
)
from repro.core.partition import Partition, from_blocks, to_blocks
from repro.kernels import ref as kref
from repro.kernels.ops import (
    gam_quant,
    mixed_gemm,
    mor_select,
    sharded_mixed_gemm,
)
from repro.kernels.ref import passthrough_mixed
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import host_device_env

from .common import csv_row
from .schema import make_artifact


def _time(fn, *args, iters=10):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def _hlo_stats(fn, x, *args):
    """(HBM-traffic bytes, operand-sized instruction count) of jit(fn).

    The instruction count is the number of optimized (post-fusion) HLO
    instructions whose text mentions the operand's shape -- i.e. how many
    times XLA touches an operand-sized buffer: the 'pass count'.
    """
    txt = jax.jit(fn).lower(x, *args).compile().as_text()
    shape_tok = f"[{x.shape[0]},{x.shape[1]}]"
    passes = sum(
        1
        for ln in txt.splitlines()
        if shape_tok in ln and "= " in ln and "parameter(" not in ln
    )
    return analyze_hlo(txt).bytes, passes


def _tpu_kernel_launches(fn, x):
    """Fused-kernel launch count in the TPU cross-lowering of jit(fn)
    (repro.analysis.hlo_rules; no TPU needed)."""
    return count_custom_calls(tpu_lowering_text(fn, x))


def _three_pass_sub3(x2d):
    """The pre-refactor sub3 lowering: three full passes over the operand
    (E4M3 quant+err, E5M2 quant+err, abs/min/max Eq. 4 range pass).
    Kept here verbatim as the fused-select benchmark baseline."""
    part = PER_BLOCK_128

    def quant_err(xb, fmt):
        bmax = jnp.max(jnp.abs(xb), axis=(2, 3)).astype(jnp.float32)
        scales = scales_from_bmax(bmax, fmt, "gam")
        s = scales.scale[:, :, None, None]
        xqb = (cast_to_format(xb.astype(jnp.float32) * s, fmt) / s).astype(
            xb.dtype
        )
        xf = xb.astype(jnp.float32)
        nz = xf != 0.0
        err = jnp.where(
            nz,
            jnp.abs((xf - xqb.astype(jnp.float32)) / jnp.where(nz, xf, 1.0)),
            0.0,
        )
        return xqb, jnp.sum(err, (2, 3)), jnp.sum(nz, (2, 3))

    xb = to_blocks(x2d, part)
    q4b, e4, n = quant_err(xb, E4M3)                    # pass 1
    q5b, e5, _ = quant_err(xb, E5M2)                    # pass 2
    m1 = e4 < e5
    xabs = jnp.abs(xb)                                  # pass 3
    bmax = jnp.max(xabs, axis=(2, 3)).astype(jnp.float32)
    big = jnp.asarray(jnp.finfo(xb.dtype).max, xb.dtype)
    bmin = jnp.min(jnp.where(xb != 0, xabs, big), axis=(2, 3)).astype(
        jnp.float32
    )
    anynz = n > 0
    ratio = jnp.where(anynz, bmax / jnp.where(anynz, bmin, 1.0), 1.0)
    use5 = jnp.logical_and(jnp.logical_not(m1), ratio < E5M2_RANGE_RATIO)
    y = from_blocks(
        jnp.where(m1[:, :, None, None], q4b,
                  jnp.where(use5[:, :, None, None], q5b, xb)),
        x2d.shape,
    )
    return y


def _legacy_dequant_matmul(x2d, mo):
    """The pre-mixed-GEMM serving lowering, frozen as the baseline: fully
    materialize the dequantized bf16 weight, then a dense bf16 matmul.
    The per-block representation decisions are erased before the dot."""
    w = mo.dequant()
    return jnp.dot(
        x2d, w.T.astype(x2d.dtype), preferred_element_type=jnp.float32
    ).astype(x2d.dtype)


def _nvfp4_friendly(rng, shape, span=9):
    """Micro-structured data the sub4 cascade sends to NVFP4: E2M1-grid
    magnitudes under per-16-element group scales (see docs/numerics.md
    -- NVFP4 wins exactly where one per-block E4M3 scale underflows)."""
    r, k = shape
    grid = np.array([0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0])
    vals = grid[rng.integers(0, len(grid), (r, k))]
    signs = np.where(rng.standard_normal((r, k)) > 0, 1.0, -1.0)
    gs = np.exp2(rng.integers(-span, span + 1, (r, k // 16))).repeat(
        16, axis=1
    )
    return jnp.asarray(signs * vals * gs, jnp.bfloat16)


def _bench_nvfp4_gemm(rows, rng, smoke: bool):
    """The sub4 (NVFP4) serving lane: a fully-NVFP4 weight's packed
    4-bit payload through the mixed GEMM vs the legacy dequant+matmul,
    with the bytes/element of the pack and the fused launch count --
    the ``kernel/gemm_nvfp4_*`` rows the v2 schema contract names."""
    M, N, K = (256, 512, 512) if smoke else (512, 1024, 1024)
    pol = MoRPolicy(recipe="sub4", partition="block", backend="xla")
    w = _nvfp4_friendly(rng, (N, K))
    mo, stats = quantize_for_gemm(w, pol)
    mo = mo.compact()
    bpe = sum(
        l.size * l.dtype.itemsize
        for l in (mo.payload_q, mo.payload_bf16, mo.payload_nib,
                  mo.micro_scales, mo.tags, mo.scales)
    ) / (N * K)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.bfloat16)
    bk = mo.block[1]

    def legacy(a, m=mo):
        return _legacy_dequant_matmul(a, m)

    def fused_xla(a, m=mo, bk=bk):
        return mixed_gemm(passthrough_mixed(a, (bk, bk)), m,
                          backend="xla")

    def fused_pallas(a, m=mo, bk=bk):
        return mixed_gemm(passthrough_mixed(a, (bk, bk)), m,
                          backend="pallas")

    iters = 3 if smoke else 10
    us_l = _time(jax.jit(legacy), x, iters=iters)
    us_f = _time(jax.jit(fused_xla), x, iters=iters)
    try:
        launches = _tpu_kernel_launches(fused_pallas, x)
    except Exception:  # older jax without cross-platform lowering
        launches = -1
    tag = f"{M}x{N}x{K}"
    rows.append(csv_row(
        f"kernel/gemm_nvfp4_xla_{tag}", us_f,
        f"frac_nvfp4={float(stats[STAT_FRAC_NVFP4]):.2f};"
        f"weight_bytes_per_elt={bpe:.3f};"
        f"us_legacy_dequant={us_l:.1f}",
    ))
    rows.append(csv_row(
        f"kernel/gemm_nvfp4_pallas_{tag}", 0.0,
        f"tpu_kernel_launches={launches};"
        f"weight_bytes_per_elt={bpe:.3f}",
    ))


def _bench_mixed_gemm(rows, rng, smoke: bool, recipe: str = "sub3"):
    """Mixed-representation GEMM vs legacy dequantize-then-matmul:
    wall time + HLO bytes + operand-pass counts (xla lowerings) and
    fused-kernel launch counts (TPU cross-lowering)."""
    sizes = ((512, 512, 512),) if smoke else (
        (512, 512, 512), (1024, 1024, 1024)
    )
    pol = MoRPolicy(recipe=recipe, partition="block", backend="xla")
    for M, N, K in sizes:
        x = jnp.asarray(rng.standard_normal((M, K)), jnp.bfloat16)
        w = (_nvfp4_friendly(rng, (N, K)) if recipe == "sub4"
             else jnp.asarray(rng.standard_normal((N, K)), jnp.bfloat16))
        mo, _ = quantize_for_gemm(w, pol)
        bk = mo.block[1]

        def legacy(a, m=mo):
            return _legacy_dequant_matmul(a, m)

        def fused_xla(a, m=mo, bk=bk):
            return mixed_gemm(
                passthrough_mixed(a, (bk, bk)), m, backend="xla"
            )

        def fused_pallas(a, m=mo, bk=bk):
            return mixed_gemm(
                passthrough_mixed(a, (bk, bk)), m, backend="pallas"
            )

        iters = 3 if smoke else 10
        us_l = _time(jax.jit(legacy), x, iters=iters)
        us_f = _time(jax.jit(fused_xla), x, iters=iters)
        by_l, ps_l = _hlo_stats(legacy, x)
        by_f, ps_f = _hlo_stats(fused_xla, x)
        try:
            launches = _tpu_kernel_launches(fused_pallas, x)
        except Exception:  # older jax without cross-platform lowering
            launches = -1
        tag = f"{M}x{N}x{K}"
        rows.append(
            csv_row(f"kernel/gemm_legacy_dequant_{tag}", us_l,
                    f"hbm_bytes={by_l:.0f};operand_passes={ps_l}")
        )
        rows.append(
            csv_row(f"kernel/gemm_mixed_xla_{tag}", us_f,
                    f"hbm_bytes={by_f:.0f};operand_passes={ps_f};"
                    f"bytes_vs_legacy={by_f / max(by_l, 1):.2f}x")
        )
        rows.append(
            csv_row(f"kernel/gemm_mixed_pallas_{tag}", 0.0,
                    f"tpu_kernel_launches={launches};"
                    f"legacy_operand_passes={ps_l}")
        )

    # Interpret-mode run of the real kernel body (small, CPU-feasible).
    x = jnp.asarray(rng.standard_normal((256, 256)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((256, 256)), jnp.bfloat16)
    mo, _ = quantize_for_gemm(w, pol)
    us = _time(
        lambda a: mixed_gemm(
            passthrough_mixed(a, (128, 128)), mo, backend="interpret"
        ),
        x, iters=3,
    )
    rows.append(
        csv_row("kernel/gemm_mixed_interp_256", us, "mode=interpret")
    )


def _bench_quantize_pack(rows, rng, smoke: bool):
    """One-pass fused quantize-to-payload vs the two-pass lowering it
    replaced (fused select + XLA re-pack), per recipe.

    The structural story lives in the TPU cross-lowering counts: the
    fused path must be exactly **one** ``tpu_custom_call`` with **zero**
    operand-sized XLA ops beyond what the bare selection kernel already
    needs (the global-amax reduce; + the micro-amax segment reduce for
    sub4) -- both asserted here so the CI bench smoke fails loudly if
    packing ever grows an XLA pass again. Wall rows time the xla
    lowerings (CPU hosts); the ``kernel/quantize_pack_fused_*`` /
    ``_twopass_*`` row pair is the perf-trajectory contract consumed by
    ``benchmarks/compare.py``.
    """
    from repro.core.partition import Partition
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    sizes = ((1024, 1024),) if smoke else ((1024, 1024), (4096, 1024))
    for recipe in ("sub3", "sub4"):
        part = Partition("block", (128, 128), align=(2, 16))
        pol = MoRPolicy(recipe=recipe, partition="block", backend="xla")
        pol_pl = pol.replace(backend="pallas")
        for mkn in sizes:
            x = (_nvfp4_friendly(rng, mkn) if recipe == "sub4"
                 else jnp.asarray(rng.standard_normal(mkn), jnp.bfloat16))

            def fused(a, pol=pol):
                mo, stats = quantize_for_gemm(a, pol)
                return mo.payload_q, mo.payload_bf16, stats

            def two_pass(a, recipe=recipe, part=part):
                r = kops.mor_select(a, part, recipe, "gam",
                                    backend="xla")
                mo = kref.pack_mixed(
                    a, r.sel, (128, 128), "gam",
                    group_amax=r.group_amax,
                    with_nvfp4=(recipe == "sub4"),
                )
                return mo.payload_q, mo.payload_bf16

            iters = 3 if smoke else 10
            us_f = _time(jax.jit(fused), x, iters=iters)
            us_2 = _time(jax.jit(two_pass), x, iters=iters)

            def fused_pl(a, pol=pol_pl):
                mo, stats = quantize_for_gemm(a, pol)
                return mo.payload_q, mo.payload_bf16, stats

            def select_pl(a, recipe=recipe, part=part):
                return kops.mor_select(a, part, recipe, "gam",
                                       backend="pallas").y

            def two_pass_pl(a, recipe=recipe, part=part):
                r = kops.mor_select(a, part, recipe, "gam",
                                    backend="pallas")
                mo = kref.pack_mixed(
                    a, r.sel, (128, 128), "gam",
                    group_amax=r.group_amax,
                    with_nvfp4=(recipe == "sub4"),
                )
                return mo.payload_q, mo.payload_bf16

            try:
                txt_f = tpu_lowering_text(fused_pl, x)
                launches = count_custom_calls(txt_f)
                ops_f = operand_sized_ops(txt_f, x.shape)
                ops_sel = operand_sized_ops(
                    tpu_lowering_text(select_pl, x), x.shape
                )
                ops_2 = operand_sized_ops(
                    tpu_lowering_text(two_pass_pl, x), x.shape
                )
                pack_ops = ops_f - ops_sel
                # The acceptance pins live in the contract registry
                # (repro.analysis.contracts): one fused launch, zero
                # operand-sized XLA packing ops on top of selection.
                lo, hi = contracts.SINGLE_LAUNCH
                if not lo <= launches <= hi:
                    raise AssertionError(
                        f"quantize_pack {recipe} {mkn}: {launches} "
                        f"launches outside {contracts.SINGLE_LAUNCH}"
                    )
                if pack_ops > contracts.MAX_PACK_OPS_OVER_SELECT:
                    raise AssertionError(
                        f"quantize_pack {recipe} {mkn}: {pack_ops} "
                        "operand-sized packing op(s) over bare "
                        "selection (max "
                        f"{contracts.MAX_PACK_OPS_OVER_SELECT})"
                    )
                pack_ops = max(pack_ops, 0)
                twopass_pack_ops = ops_2 - ops_sel
            except CrossLoweringUnavailable:  # older jax
                launches, pack_ops, twopass_pack_ops = -1, -1, -1
            # No wall "speedup" field on purpose: on the xla backend
            # the fused entry point IS the two-pass reference, so the
            # walls only track host drift. The fusion's win is the
            # structural pair (tpu_kernel_launches, tpu_pack_ops) from
            # the TPU cross-lowering, which IS host-independent.
            tag = f"{recipe}_{mkn[0]}x{mkn[1]}"
            rows.append(csv_row(
                f"kernel/quantize_pack_twopass_{tag}", us_2,
                f"tpu_pack_ops={twopass_pack_ops};"
                "lowering=select_kernel_plus_xla_pack",
            ))
            rows.append(csv_row(
                f"kernel/quantize_pack_fused_{tag}", us_f,
                f"tpu_kernel_launches={launches};"
                f"tpu_pack_ops={pack_ops};"
                "lowering=one_pass_kernel",
            ))


def _bench_gemm_decode_reuse(rows, rng, smoke: bool):
    """Decode-amortization lanes: the autotuned tile per bench shape
    (``kernel/gemm_autotune_*``) and an interpret-mode wall comparison
    of the k-keyed decode cache / wider-bn sweep against the naive
    revisiting grid (``kernel/gemm_decode_reuse_*``). Interpret mode
    runs the real kernel body, so the decode-count difference is what
    the wall clock sees on CPU."""
    from repro.kernels.ops import GemmTile, gemm_tile_for

    shapes = (((512, 512, 512), (128, 128, 128)),
              ((256, 65536, 256), (128, 128, 128)))
    for (M, N, K), blk in shapes:
        n_m, n_n, n_k = M // blk[0], N // blk[1], K // blk[2]
        t = gemm_tile_for(n_m, n_n, n_k, blk)
        from repro.kernels.mixed_gemm import decode_cache_bytes
        rows.append(csv_row(
            f"kernel/gemm_autotune_{M}x{N}x{K}", 0.0,
            f"decode_cache={int(bool(t.decode_cache))};"
            f"bn_mult={t.bn_mult};"
            f"cache_bytes={decode_cache_bytes(n_k, blk[0], blk[2])};"
            f"grid={n_m}x{n_n}x{n_k}",
        ))

    # Interpret-mode decode-reuse wall clock (small, CPU-feasible).
    pol = MoRPolicy(recipe="sub4", partition="block", backend="xla")
    w = _nvfp4_friendly(rng, (512, 256))
    mo, _ = quantize_for_gemm(w, pol)
    x = jnp.asarray(rng.standard_normal((128, 256)), jnp.bfloat16)

    def run(tile):
        return _time(
            lambda a: mixed_gemm(passthrough_mixed(a, (128, 128)), mo,
                                 backend="interpret", tile=tile),
            x, iters=2,
        )

    us_naive = run(GemmTile(decode_cache=False, bn_mult=1))
    us_cache = run(GemmTile(decode_cache=True, bn_mult=1))
    us_wide = run(GemmTile(decode_cache=False, bn_mult=4))
    rows.append(csv_row(
        "kernel/gemm_decode_reuse_interp_128x512x256", us_cache,
        f"us_naive={us_naive:.1f};us_bn_mult4={us_wide:.1f};"
        f"a_decodes_naive={(512 // 128) * (256 // 128)};"
        f"a_decodes_cached={256 // 128}",
    ))


def _bench_optim_state(rows, rng, smoke: bool):
    """Compressed training-state lane (the rows the v4 schema names).

    * ``kernel/grad_compress_<mode>_*`` -- one jitted gradient
      compression event per mode (flat per-tensor E4M3 vs per-block
      MoR, with and without error feedback) on the same wide-range
      leaf, with the payload bytes/element the tag mixture implies.
    * ``kernel/optim_moments_<tier>_*`` -- encode+decode round-trip of
      an Adam moment leaf, carrying the HBM budget counter
      ``moment_bytes_per_param_milli``: physical bytes/param of the
      compacted pack in milli-bytes. Deterministic for the fixed-seed
      data (a fully-fp8 leaf prices ~1000, the NVFP4-friendly sub4
      leaf ~563), so compare.py gates it at threshold 0 -- a lane that
      silently re-inflates the moment store fails the bench diff.

    Moment leaves stay at 1024x1024 even under --smoke: the per-block
    metadata only amortizes below the budget at full leaf size, and
    the counter must not depend on the smoke flag.
    """
    from repro.core import EVENT_MOMENT_M, EVENT_MOMENT_V
    from repro.optim.compress import compress_grads, ef_init
    from repro.optim.moments import (
        decode_moment,
        encode_moment,
        physical_bytes_per_param,
    )

    iters = 3 if smoke else 10
    n = 512 if smoke else 1024
    pol = MoRPolicy(recipe="sub3", backend="xla")
    g = {"w": jnp.asarray(
        rng.standard_normal((n, n)) * np.exp2(
            rng.integers(-8, 8, (n, n))),
        jnp.float32,
    )}
    ef0 = ef_init(g)
    for mode in ("fp8", "mor", "mor_ef"):
        ef = ef0 if mode == "mor_ef" else None

        def event(gg, ee, mode=mode):
            return compress_grads(gg, mode, ee, policy=pol)

        f = jax.jit(event)
        us = _time(f, g, ef, iters=iters)
        _, _, stats = f(g, ef)
        bpe = (1.0 if stats is None
               else float(stats["w"][STAT_PAYLOAD_BPE]))
        rows.append(csv_row(
            f"kernel/grad_compress_{mode}_{n}x{n}", us,
            f"payload_bpe={bpe:.3f};"
            f"ef={int(mode.endswith('_ef'))}",
        ))

    tiers = (
        ("fp8", EVENT_MOMENT_M,
         jnp.ones((1024, 1024), jnp.float32)),
        ("sub4", EVENT_MOMENT_V,
         _nvfp4_friendly(rng, (1024, 1024)).astype(jnp.float32)),
    )
    for tier, kind, leaf in tiers:
        tpol = MoRPolicy(recipe="sub4" if tier == "sub4" else "sub3",
                         backend="xla")
        pm = encode_moment(leaf, tpol, kind=kind)
        milli = int(round(physical_bytes_per_param(pm) * 1000))

        def roundtrip(a, tpol=tpol, kind=kind):
            return decode_moment(encode_moment(a, tpol, kind=kind))

        us = _time(jax.jit(roundtrip), leaf, iters=iters)
        rows.append(csv_row(
            f"kernel/optim_moments_{tier}_1024x1024", us,
            f"moment_bytes_per_param_milli={milli};"
            f"payload_bpe={float(pm.stats[STAT_PAYLOAD_BPE]):.3f};"
            f"frac_nvfp4={float(pm.stats[STAT_FRAC_NVFP4]):.2f}",
        ))


def _sharded_rows(smoke: bool):
    """Multi-device lane (>= 4 devices): the sharded mixed GEMM and the
    allreduced-stats quantization under shard_map vs their replicated
    single-device baselines, with per-shard fused-kernel launch counts
    from the TPU cross-lowering of the shard-local computation.

    Own fixed seed so the in-process and --sharded-child paths bench
    identical data."""
    from jax.sharding import PartitionSpec as P

    from repro.core.collectives import compat_shard_map

    rng = np.random.default_rng(7)
    rows = []
    ndev = 4
    mesh = jax.make_mesh((ndev,), ("data",))
    M = N = K = 512
    bm = 128
    pol = MoRPolicy(recipe="sub3", partition="block", backend="xla")
    w = jnp.asarray(rng.standard_normal((N, K)), jnp.bfloat16)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.bfloat16)
    mo, _ = quantize_for_gemm(w, pol)
    iters = 3 if smoke else 10

    def replicated(a):
        return mixed_gemm(
            passthrough_mixed(a, (bm, bm)), mo, backend="xla"
        )

    def row_sharded(a):
        return sharded_mixed_gemm(
            passthrough_mixed(a, (bm, bm)), mo, mesh=mesh,
            row_axis="data", backend="xla",
        )

    us_rep = _time(jax.jit(replicated), x, iters=iters)
    us_sh = _time(jax.jit(row_sharded), x, iters=iters)

    # Per-shard launch count: cross-lower the shard-local computation
    # (rows/ndev of the activation against the full weight) for TPU.
    def pallas_gemm(a):
        return mixed_gemm(
            passthrough_mixed(a, (bm, bm)), mo, backend="pallas"
        )

    try:
        per_shard = _tpu_kernel_launches(pallas_gemm, x[: M // ndev])
        rep_launches = _tpu_kernel_launches(pallas_gemm, x)
    except Exception:  # older jax without cross-platform lowering
        per_shard = rep_launches = -1
    tag = f"{M}x{N}x{K}"
    rows.append(csv_row(
        f"kernel/gemm_sharded_row_data{ndev}_{tag}", us_sh,
        f"devices={ndev};axis=data;"
        f"per_shard_tpu_kernel_launches={per_shard};"
        f"replicated_tpu_kernel_launches={rep_launches};"
        f"us_replicated={us_rep:.1f}",
    ))

    # Contraction-sharded lane: per-shard partials + one f32 psum.
    def k_sharded(a):
        return sharded_mixed_gemm(
            passthrough_mixed(a, (bm, bm)), mo, mesh=mesh,
            contract_axis="data", backend="xla",
        )

    us_k = _time(jax.jit(k_sharded), x, iters=iters)
    rows.append(csv_row(
        f"kernel/gemm_sharded_contract_data{ndev}_{tag}", us_k,
        f"devices={ndev};axis=data;reduce=psum_f32;"
        f"us_replicated={us_rep:.1f}",
    ))

    # Allreduced-stats quantization under shard_map vs single-device:
    # same decisions bit-for-bit (tests/test_sharded_mor.py), cost is
    # one extra pmax/psum handful on scalars.
    qpol = MoRPolicy(recipe="sub3", partition="block", backend="xla")
    qpol_sh = qpol.replace(mesh_axes=("data",))
    xq = jnp.asarray(rng.standard_normal((1024, 1024)), jnp.bfloat16)
    us_q1 = _time(jax.jit(lambda a: mor_quantize(a, qpol)[0]), xq,
                  iters=iters)
    sm = jax.jit(compat_shard_map(
        lambda a: mor_quantize(a, qpol_sh)[0], mesh,
        P("data", None), P("data", None),
    ))
    us_q4 = _time(sm, xq, iters=iters)
    rows.append(csv_row(
        f"kernel/mor_quantize_sharded_data{ndev}_1024", us_q4,
        f"devices={ndev};axis=data;stats=allreduced;"
        f"us_single_device={us_q1:.1f};invariance=bit_identical_tags",
    ))
    return rows


def _bench_sharded(rows, smoke: bool):
    """Run the sharded lane here if this process already has >= 4
    devices, else respawn in a 4-forced-host-device subprocess and
    merge its rows (XLA fixes the device count at backend init)."""
    if len(jax.devices()) >= 4:
        rows.extend(_sharded_rows(smoke))
        return
    with tempfile.TemporaryDirectory() as td:
        tmp = os.path.join(td, "sharded.json")
        cmd = [sys.executable, "-m", "benchmarks.bench_kernels",
               "--sharded-child", "--json", tmp]
        if smoke:
            cmd.append("--smoke")
        try:
            proc = subprocess.run(
                cmd, env=host_device_env(4), capture_output=True,
                text=True, timeout=900, cwd=os.getcwd(),
            )
            if proc.returncode != 0:
                raise RuntimeError(proc.stderr[-500:])
            with open(tmp) as f:
                child = json.load(f)
            rows.extend(
                csv_row(r["name"], r["us"], r["derived"])
                for r in child["rows"]
            )
        except Exception as e:  # never fail the whole bench
            reason = str(e).replace(";", ",").replace("=", ":")
            reason = " ".join(reason.split())[:120] or "unknown"
            rows.append(csv_row(
                "kernel/gemm_sharded_skipped", 0.0,
                f"skipped=1;reason={reason}",
            ))


def main(smoke: bool = False, sharded: bool = True,
         sharded_only: bool = False, recipe: str = "sub3"):
    rows = []
    rng = np.random.default_rng(0)

    if sharded_only:
        return _sharded_rows(smoke), None

    # Mixed-representation block GEMM vs legacy dequant+matmul.
    _bench_mixed_gemm(rows, rng, smoke, recipe=recipe)

    # NVFP4 packed-payload serving lane (the v2 schema's gemm_nvfp4
    # rows ride in every artifact, whatever the main-lane recipe).
    _bench_nvfp4_gemm(rows, rng, smoke)

    # One-pass quantize-to-payload vs the retired two-pass lowering
    # (asserts the 1-launch / 0-pack-pass contract) + the GEMM
    # decode-amortization lanes.
    _bench_quantize_pack(rows, rng, smoke)
    _bench_gemm_decode_reuse(rows, rng, smoke)

    # Compressed training state: gradient-compression events and the
    # packed Adam-moment round-trip with its HBM budget counter (the
    # kernel/grad_compress_* + kernel/optim_moments_* rows the v4
    # schema contract names).
    _bench_optim_state(rows, rng, smoke)

    # Fused mor_quantize (the XLA lowering used in train steps).
    quant_sizes = ((1024, 1024),) if smoke else ((1024, 1024), (4096, 1024))
    for mkn in quant_sizes:
        x = jnp.asarray(rng.standard_normal(mkn), jnp.bfloat16)
        pol = MoRPolicy(recipe="tensor", partition="block")
        f = jax.jit(lambda a: mor_quantize(a, pol)[0])
        us = _time(f, x)
        gbps = x.size * 2 * 2 / (us * 1e-6) / 1e9
        rows.append(
            csv_row(f"kernel/mor_quantize_{mkn[0]}x{mkn[1]}", us,
                    f"GB/s={gbps:.1f}")
        )

    # Fused sub-tensor select vs the pre-refactor 3-pass lowering.
    part = PER_BLOCK_128
    for mkn in quant_sizes:
        x = jnp.asarray(rng.standard_normal(mkn), jnp.bfloat16)

        def fused_xla(a):
            return mor_select(a, part, "sub3", "gam", backend="xla").y

        def fused_pallas(a):
            return mor_select(a, part, "sub3", "gam", backend="pallas").y

        us_l = _time(jax.jit(_three_pass_sub3), x)
        us_f = _time(jax.jit(fused_xla), x)
        by_l, ps_l = _hlo_stats(_three_pass_sub3, x)
        by_f, ps_f = _hlo_stats(fused_xla, x)
        try:
            launches = _tpu_kernel_launches(fused_pallas, x)
        except Exception:  # older jax without cross-platform lowering
            launches = -1
        tag = f"{mkn[0]}x{mkn[1]}"
        rows.append(
            csv_row(f"kernel/sub3_3pass_{tag}", us_l,
                    f"hbm_bytes={by_l:.0f};operand_passes={ps_l}")
        )
        rows.append(
            csv_row(f"kernel/sub3_fused_xla_{tag}", us_f,
                    f"hbm_bytes={by_f:.0f};operand_passes={ps_f};"
                    f"speedup={us_l / us_f:.2f}x")
        )
        rows.append(
            csv_row(f"kernel/sub3_fused_pallas_{tag}", 0.0,
                    f"tpu_kernel_launches={launches};"
                    "operand_passes=2(amax reduce + fused select);"
                    f"vs_3pass_passes={ps_l}")
        )

    # mor_select pallas kernel (interpret mode on CPU).
    x = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    us = _time(
        lambda a: mor_select(a, part, "sub3", "gam", backend="interpret").y,
        x, iters=3,
    )
    rows.append(csv_row("kernel/mor_select_interp_512", us,
                        "mode=interpret"))

    # gam_quant pallas kernel (interpret mode on CPU).
    x = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    us = _time(
        lambda a: gam_quant(a, backend="interpret")[0], x, iters=3
    )
    rows.append(csv_row("kernel/gam_quant_interp_512", us, "mode=interpret"))
    us = _time(lambda a: gam_quant(a, backend="xla")[0], x)
    rows.append(csv_row("kernel/gam_quant_xla_512", us, "mode=xla-ref"))

    # flash attention reference vs model chunked attention.
    from repro.models.attention import flash_attention as xla_flash

    q = jnp.asarray(rng.standard_normal((2, 512, 4, 64)), jnp.bfloat16)
    f = jax.jit(
        lambda a: xla_flash(a, a, a, kind="causal", q_chunk=128,
                            k_chunk=128)
    )
    us = _time(f, q)
    flops = 4 * 2 * 512 * 512 * 4 * 64  # 2 gemms, causal not discounted
    rows.append(
        csv_row("kernel/chunked_attention_b2s512", us,
                f"GFLOP/s={flops / (us * 1e-6) / 1e9:.1f}")
    )

    # Serving lane: heavy-traffic continuous-batching trace + the
    # skinny-M decode-tile contract (benchmarks/bench_serve.py).
    from .bench_serve import bench_serve

    bench_serve(rows, smoke=smoke)

    # Structural-contract sweep (the v5 schema row): every registered
    # entry-point contract in repro.analysis.contracts, evaluated
    # here so the artifact pins how many invariants the bench vouched
    # for -- compare.py fails the gate if contracts_checked ever
    # drops, and any violation fails the bench run itself.
    _bench_analysis_contracts(rows)
    _bench_robust_guard(rows)

    # Multi-device sharded lane (possibly via a forced-device child).
    if sharded:
        _bench_sharded(rows, smoke)
    return rows, None


def _bench_analysis_contracts(rows):
    summary = contracts.check_all()
    if not summary.ok:
        raise AssertionError(
            "structural contract violation(s):\n"
            + "\n".join(summary.violations)
        )
    rows.append(csv_row(
        "kernel/analysis_contracts", 0.0,
        f"contracts_checked={summary.contracts_checked};"
        f"contract_rules_evaluated={summary.rules_evaluated};"
        f"contract_violations={len(summary.violations)}",
    ))


def _bench_robust_guard(rows):
    """Guard-rail lane (docs/robustness.md): re-verify that the v4
    stats guard lanes cost zero extra kernel launches and zero
    operand-sized pack ops over the unguarded baseline (the
    ``robust_guard_event`` contract), and enumerate the chaos
    registry so a silently-dropped fault class or injector shrinks a
    MIN-gated counter in compare.py."""
    from repro.robust.faults import fault_specs

    report = contracts.assert_contract("robust_guard_event")
    specs = fault_specs()
    covered = len(specs)  # registry == coverage, pinned by
    # tests/test_robust_chaos.py::test_every_fault_class_has_chaos_coverage
    rows.append(csv_row(
        "kernel/robust_guard", 0.0,
        f"guard_clean_pack_ops={report.counters.get('tpu_pack_ops', -1)};"
        f"guard_contract_violations={len(report.violations)};"
        f"fault_classes_registered={len(specs)};"
        f"fault_classes_covered={covered}",
    ))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced matrix for the CI bench lane")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as a repro.bench_kernels artifact")
    ap.add_argument("--no-sharded", action="store_true",
                    help="skip the multi-device sharded lane")
    ap.add_argument("--sharded-child", action="store_true",
                    help="internal: run only the sharded lane "
                         "(spawned with forced host devices)")
    ap.add_argument("--recipe", default="sub3",
                    choices=("sub2", "sub3", "sub4"),
                    help="MoR recipe for the mixed-GEMM lane "
                         "(sub4 = NVFP4 four-way)")
    args = ap.parse_args()
    out_rows = main(
        smoke=args.smoke,
        sharded=not args.no_sharded,
        sharded_only=args.sharded_child,
        recipe=args.recipe,
    )[0]
    for row in out_rows:
        print(row)
    if args.json:
        artifact = make_artifact(out_rows)
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(
            f"wrote {len(artifact['rows'])} rows to {args.json} "
            f"({artifact['schema']})"
        )
