"""Kernel microbenchmarks: wall time per call on this host (CPU: the jnp
reference / interpret paths; on a TPU host the same harness times the
Pallas kernels) + derived bandwidth."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import E4M3, PER_BLOCK_128, MoRPolicy, mor_quantize
from repro.core.partition import Partition
from repro.kernels import ref as kref
from repro.kernels.ops import gam_quant

from .common import csv_row


def _time(fn, *args, iters=10):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def main():
    rows = []
    rng = np.random.default_rng(0)

    # Fused mor_quantize (the XLA lowering used in train steps).
    for mkn in ((1024, 1024), (4096, 1024)):
        x = jnp.asarray(rng.standard_normal(mkn), jnp.bfloat16)
        pol = MoRPolicy(recipe="tensor", partition="block")
        f = jax.jit(lambda a: mor_quantize(a, pol)[0])
        us = _time(f, x)
        gbps = x.size * 2 * 2 / (us * 1e-6) / 1e9
        rows.append(
            csv_row(f"kernel/mor_quantize_{mkn[0]}x{mkn[1]}", us,
                    f"GB/s={gbps:.1f}")
        )

    # gam_quant pallas kernel (interpret mode on CPU).
    x = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
    us = _time(
        lambda a: gam_quant(a, backend="interpret")[0], x, iters=3
    )
    rows.append(csv_row("kernel/gam_quant_interp_512", us, "mode=interpret"))
    us = _time(lambda a: gam_quant(a, backend="xla")[0], x)
    rows.append(csv_row("kernel/gam_quant_xla_512", us, "mode=xla-ref"))

    # flash attention reference vs model chunked attention.
    from repro.models.attention import flash_attention as xla_flash

    q = jnp.asarray(rng.standard_normal((2, 512, 4, 64)), jnp.bfloat16)
    f = jax.jit(
        lambda a: xla_flash(a, a, a, kind="causal", q_chunk=128,
                            k_chunk=128)
    )
    us = _time(f, q)
    flops = 4 * 2 * 512 * 512 * 4 * 64  # 2 gemms, causal not discounted
    rows.append(
        csv_row("kernel/chunked_attention_b2s512", us,
                f"GFLOP/s={flops / (us * 1e-6) / 1e9:.1f}")
    )
    return rows, None


if __name__ == "__main__":
    for row in main()[0]:
        print(row)
