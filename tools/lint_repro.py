#!/usr/bin/env python
"""Repo-convention linter + structural-contract runner (CI's blocking
``lint`` job).

Default mode AST-lints the given paths (``src`` ``tools`` ``benchmarks``
``tests`` when none are given) against the MOR001..MOR005 rules in
``repro.analysis.ast_rules`` -- stdlib only, no jax needed.

``--contracts`` additionally evaluates every registered structural
contract (``repro.analysis.contracts.check_all``) -- run it with
``REPRO_KERNEL_INTERPRET=1 JAX_PLATFORMS=cpu`` off-TPU, like CI does.

Exit status is nonzero iff any violation is found. ``--list-rules``
prints the rule inventory and exits.
"""
from __future__ import annotations

import argparse
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

DEFAULT_PATHS = ("src", "tools", "benchmarks", "tests")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "paths", nargs="*",
        help=f"files/dirs to lint (default: {' '.join(DEFAULT_PATHS)})",
    )
    ap.add_argument(
        "--contracts", action="store_true",
        help="also evaluate the structural contract registry "
             "(imports jax, builds the probe cases)",
    )
    ap.add_argument(
        "--list-rules", action="store_true",
        help="print AST rules and registered contracts, then exit",
    )
    args = ap.parse_args(argv)

    from repro.analysis import ast_rules

    if args.list_rules:
        for rule, msg in sorted(ast_rules.RULES.items()):
            print(f"{rule}: {msg}")
        try:
            from repro.analysis import contracts
            for name in sorted(contracts.REGISTRY):
                print(f"contract:{name}: {contracts.REGISTRY[name].notes}")
        except ImportError as e:  # no jax in this interpreter
            print(f"(contract registry unavailable: {e})")
        return 0

    paths = [
        os.path.join(REPO, p) if not os.path.isabs(p) else p
        for p in (args.paths or DEFAULT_PATHS)
    ]
    violations = ast_rules.lint_paths(paths)
    for v in violations:
        print(v.render())
    print(
        f"lint: {len(violations)} violation(s) over "
        f"{len(ast_rules.RULES)} rule(s)"
    )
    failed = bool(violations)

    if args.contracts:
        from repro.analysis import check_all

        summary = check_all()
        for line in summary.violations:
            print(f"contract: {line}")
        print(
            f"contracts: {summary.contracts_checked} checked, "
            f"{summary.rules_evaluated} rule(s) evaluated, "
            f"{len(summary.violations)} violation(s)"
        )
        failed = failed or not summary.ok

    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
