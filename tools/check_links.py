#!/usr/bin/env python3
"""Markdown link checker for the docs CI lane (stdlib only).

Scans the given markdown files/directories for inline links and
verifies that every *relative* target resolves to an existing file (and
that ``#anchors`` into markdown targets match a real heading), so a
renamed module or a mistyped paper-equation reference fails the build.

Python files passed (or found under a directory with ``--py``) are
scanned too: any markdown-file path mentioned in their source — which
in practice means docstrings and comments pointing readers at docs —
must resolve against the file's own directory, the repo root, or
``src/repro``. This is what catches a docstring still citing a deleted
design note.

    python tools/check_links.py README.md docs --py src

External links (http/https/mailto) are not fetched. Fenced code blocks
and inline code spans are stripped before matching, so ASCII diagrams
and code samples cannot produce false links.
"""
from __future__ import annotations

import os
import re
import sys
from typing import Iterable, List, Tuple

LINK_RE = re.compile(r"\[([^\]]*)\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
FENCE_RE = re.compile(r"^(```|~~~)")
INLINE_CODE_RE = re.compile(r"`[^`]*`")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
# A .md path in Python source: must start with an alphanumeric (so the
# bare ".md" literals in this checker don't self-match) and may carry
# a relative path prefix.
PY_MD_RE = re.compile(r"[A-Za-z0-9_][A-Za-z0-9_./-]*\.md\b")


def md_files(paths: Iterable[str]) -> List[str]:
    out = []
    for p in paths:
        if os.path.isdir(p):
            for root, _, names in os.walk(p):
                out.extend(
                    os.path.join(root, n)
                    for n in sorted(names) if n.endswith(".md")
                )
        else:
            out.append(p)
    return out


def strip_code(lines: List[str]) -> List[str]:
    """Blank out fenced blocks and inline code spans."""
    out, fenced = [], False
    for ln in lines:
        if FENCE_RE.match(ln.strip()):
            fenced = not fenced
            out.append("")
            continue
        out.append("" if fenced else INLINE_CODE_RE.sub("", ln))
    return out


def slugify(heading: str) -> str:
    """GitHub-style anchor slug (approximate, ASCII-focused)."""
    h = INLINE_CODE_RE.sub(lambda m: m.group(0).strip("`"), heading)
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", h)  # unwrap links
    h = h.strip().lower()
    h = re.sub(r"[^\w\s-]", "", h)
    return re.sub(r"[\s]+", "-", h)


def headings_of(path: str) -> List[str]:
    slugs = []
    try:
        with open(path, encoding="utf-8") as f:
            lines = f.read().splitlines()
    except OSError:
        return slugs
    fenced = False
    for ln in lines:
        if FENCE_RE.match(ln.strip()):
            fenced = not fenced
            continue
        if fenced:
            continue
        m = HEADING_RE.match(ln)
        if m:
            slugs.append(slugify(m.group(1)))
    return slugs


def check_file(path: str) -> Tuple[List[Tuple[int, str, str]], int]:
    """((line, target, problem) per broken link, total links) for
    ``path``."""
    problems, nlinks = [], 0
    with open(path, encoding="utf-8") as f:
        lines = strip_code(f.read().splitlines())
    base = os.path.dirname(os.path.abspath(path))
    for i, ln in enumerate(lines, 1):
        for m in LINK_RE.finditer(ln):
            nlinks += 1
            target = m.group(2)
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # scheme: skip
                continue
            fpart, _, anchor = target.partition("#")
            if not fpart:  # same-file anchor
                tgt_path = os.path.abspath(path)
            else:
                tgt_path = os.path.normpath(os.path.join(base, fpart))
                if not os.path.exists(tgt_path):
                    problems.append((i, target, "missing file"))
                    continue
            if anchor and tgt_path.endswith(".md"):
                if slugify(anchor) not in headings_of(tgt_path):
                    problems.append((i, target, "missing anchor"))
    return problems, nlinks


def py_files(paths: Iterable[str]) -> List[str]:
    out = []
    for p in paths:
        if os.path.isdir(p):
            for root, _, names in os.walk(p):
                out.extend(
                    os.path.join(root, n)
                    for n in sorted(names) if n.endswith(".py")
                )
        elif p.endswith(".py"):
            out.append(p)
    return out


def repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def check_py_file(path: str) -> Tuple[List[Tuple[int, str, str]], int]:
    """Every ``*.md`` path mentioned in a Python file (docstrings,
    comments) must resolve relative to the file's directory, the repo
    root, or ``src/repro``."""
    problems, nrefs = [], 0
    root = repo_root()
    bases = [os.path.dirname(os.path.abspath(path)), root,
             os.path.join(root, "src", "repro")]
    with open(path, encoding="utf-8") as f:
        lines = f.read().splitlines()
    for i, ln in enumerate(lines, 1):
        for m in PY_MD_RE.finditer(ln):
            nrefs += 1
            target = m.group(0)
            if not any(os.path.exists(os.path.join(b, target))
                       for b in bases):
                problems.append((i, target, "dangling .md reference"))
    return problems, nrefs


def main(argv: List[str]) -> int:
    if not argv:
        print(__doc__)
        return 2
    py_roots = []
    md_args = []
    it = iter(argv)
    for a in it:
        if a == "--py":
            py_roots.append(next(it, ""))
        else:
            md_args.append(a)
    files = md_files(md_args)
    pyfiles = py_files(py_roots)
    total_links, bad = 0, 0
    for path in files:
        probs, nlinks = check_file(path)
        total_links += nlinks
        for line, target, why in probs:
            print(f"{path}:{line}: {why}: {target}", file=sys.stderr)
            bad += 1
    for path in pyfiles:
        probs, nrefs = check_py_file(path)
        total_links += nrefs
        for line, target, why in probs:
            print(f"{path}:{line}: {why}: {target}", file=sys.stderr)
            bad += 1
    print(f"checked {len(files) + len(pyfiles)} files, "
          f"{total_links} links, {bad} broken")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
